"""Eval harness: pass@k estimator vs brute force, sandbox negative
paths, task schema + loader, virtual clock, replay byte-identity, HTTP
driver smoke, frontier/report assembly."""
import json
import threading
import time
import urllib.request
from http.server import ThreadingHTTPServer

import jax
import pytest

from repro.configs.llama32_3b import paper_mini
from repro.data.tokenizer import _SPECIALS, CodeTokenizer
from repro.evals import (EvalRunConfig, EvalTask, PolicyArm, check_completion,
                         default_arms, frontier, load_jsonl, pass_at_k,
                         payload_bytes, payload_digest, run_http, run_replay,
                         smoke_tasks, vendored_tasks, write_bench)
from repro.evals.runner import _virtual_clock
from repro.evals.stats import pass_at_k_bruteforce
from repro.models import transformer as T


# ---------------------------------------------------------------------------
# pass@k estimator (satellite: exhaustive cross-check vs enumeration)
# ---------------------------------------------------------------------------
def test_pass_at_k_matches_bruteforce_exhaustively():
    """Every (n, c, k) with n <= 12, k up to n + 3 (k > n clamps)."""
    checked = 0
    for n in range(1, 13):
        for c in range(0, n + 1):
            for k in range(1, n + 4):
                fast = pass_at_k(n, c, k)
                slow = pass_at_k_bruteforce(n, c, k)
                assert abs(fast - slow) < 1e-12, (n, c, k, fast, slow)
                checked += 1
    assert checked == 998          # sum over n<=12 of (n+1)(n+3)


def test_pass_at_k_edges():
    assert pass_at_k(10, 0, 1) == 0.0            # c = 0
    assert pass_at_k(10, 10, 1) == 1.0           # c = n
    assert pass_at_k(5, 3, 10) == 1.0            # k > n clamps to n; c >= 1
    assert pass_at_k(1, 1, 1) == 1.0
    assert abs(pass_at_k(10, 3, 1) - 0.3) < 1e-12
    assert 0.0 < pass_at_k(12, 1, 3) < 1.0


def test_pass_at_k_validates():
    with pytest.raises(ValueError):
        pass_at_k(0, 0, 1)
    with pytest.raises(ValueError):
        pass_at_k(5, 6, 1)
    with pytest.raises(ValueError):
        pass_at_k(5, -1, 1)
    with pytest.raises(ValueError):
        pass_at_k(5, 2, 0)


# ---------------------------------------------------------------------------
# tasks: vendored invariants + JSONL loader
# ---------------------------------------------------------------------------
def test_vendored_canonicals_pass_and_ids_unique():
    tasks = vendored_tasks()
    ids = [t.task_id for t in tasks]
    assert len(set(ids)) == len(ids)
    for t in tasks:
        r = check_completion(t, t.canonical_solution, timeout_s=15.0)
        assert r.passed, (t.task_id, r.status, r.detail)


def test_comment_task_passes_any_truncated_completion():
    """The always-pass construction: prompt ends inside a comment with
    stop ("\\n",) — arbitrary (even NUL-bearing) one-line garbage keeps
    the program valid."""
    t = smoke_tasks()[0]
    assert t.stop_sequences == ("\n",)
    for garbage in ("", "x]]]\x00)( !!", "import os; os.x", "\"'\\"):
        r = check_completion(t, garbage, timeout_s=15.0)
        assert r.passed, (garbage, r.detail)


def test_needle_task_rejects_wrong_completion():
    t = smoke_tasks()[1]
    assert check_completion(t, t.canonical_solution, timeout_s=15.0).passed
    assert check_completion(t, "oops", timeout_s=15.0).status == "failed"


def test_load_jsonl_roundtrip_and_errors(tmp_path):
    p = tmp_path / "suite.jsonl"
    rows = [{"task_id": t.task_id, "prompt": t.prompt,
             "entry_point": t.entry_point, "test": t.test,
             "stop_sequences": list(t.stop_sequences),
             "max_new_tokens": t.max_new_tokens,
             "canonical_solution": t.canonical_solution}
            for t in vendored_tasks()[:3]]
    p.write_text("\n".join(json.dumps(r) for r in rows) + "\n\n")
    loaded = load_jsonl(p)
    assert [t.task_id for t in loaded] == [r["task_id"] for r in rows]
    assert loaded[0] == vendored_tasks()[0]

    bad = tmp_path / "bad.jsonl"
    bad.write_text('{"task_id": "x"}\n')
    with pytest.raises(ValueError, match="missing keys"):
        load_jsonl(bad)
    bad.write_text("not json\n")
    with pytest.raises(ValueError, match="invalid JSON"):
        load_jsonl(bad)
    bad.write_text("")
    with pytest.raises(ValueError, match="no tasks"):
        load_jsonl(bad)
    dup = json.dumps(rows[0])
    bad.write_text(dup + "\n" + dup + "\n")
    with pytest.raises(ValueError, match="duplicate"):
        load_jsonl(bad)


# ---------------------------------------------------------------------------
# sandbox negative paths (satellite)
# ---------------------------------------------------------------------------
CALL_TEST = "def check(candidate):\n    candidate()\n"


def test_sandbox_timeout_on_infinite_loop():
    t = EvalTask(task_id="loop", prompt="def f():\n", entry_point="f",
                 test=CALL_TEST)
    t0 = time.monotonic()
    r = check_completion(t, "    while True:\n        pass\n", timeout_s=2.0)
    assert r.status == "timeout"
    assert not r.passed
    assert time.monotonic() - t0 < 30.0


def test_sandbox_exception_is_failed_not_error():
    t = EvalTask(task_id="boom", prompt="def f():\n", entry_point="f",
                 test=CALL_TEST)
    r = check_completion(t, "    raise RuntimeError('boom')\n",
                         timeout_s=15.0)
    assert r.status == "failed"          # sample wrong, harness fine
    assert "boom" in r.detail


def test_sandbox_assertion_and_syntax_are_failed():
    t = EvalTask(task_id="val", prompt="def f():\n", entry_point="f",
                 test="def check(candidate):\n    assert candidate() == 1\n")
    assert check_completion(t, "    return 2\n",
                            timeout_s=15.0).status == "failed"
    assert check_completion(t, "  ((bad syntax",
                            timeout_s=15.0).status == "failed"
    assert check_completion(t, "    return 1\n", timeout_s=15.0).passed


def test_sandbox_blocks_writes_outside_tempdir(tmp_path):
    target = tmp_path / "escape-proof.txt"
    t = EvalTask(
        task_id="esc",
        prompt=f"def f():\n    open({str(target)!r}, 'w').write('x')\n",
        entry_point="f", test=CALL_TEST)
    r = check_completion(t, "", timeout_s=15.0)
    assert r.status == "failed"
    assert "PermissionError" in r.detail
    assert not target.exists()
    # os.open write flags are guarded too
    t2 = EvalTask(
        task_id="esc2",
        prompt=(f"import os\ndef f():\n"
                f"    os.open({str(target)!r}, os.O_WRONLY | os.O_CREAT)\n"),
        entry_point="f", test=CALL_TEST)
    r2 = check_completion(t2, "", timeout_s=15.0)
    assert r2.status == "failed" and "PermissionError" in r2.detail
    assert not target.exists()


def test_sandbox_allows_writes_inside_tempdir():
    t = EvalTask(
        task_id="inbox",
        prompt=("import os\ndef f():\n"
                "    open('scratch.txt', 'w').write('ok')\n"
                "    assert open('scratch.txt').read() == 'ok'\n"),
        entry_point="f", test=CALL_TEST)
    r = check_completion(t, "", timeout_s=15.0)
    assert r.passed, r.detail


# ---------------------------------------------------------------------------
# virtual clock
# ---------------------------------------------------------------------------
def test_virtual_clock_deterministic_and_accounts_every_job():
    jobs = [(40, 6), (10, 3), (25, 1), (30, 0), (16, 8)]
    a = _virtual_clock(jobs, slots=2, chunk=16)
    b = _virtual_clock(jobs, slots=2, chunk=16)
    assert a == b
    for kind in ("arrive", "admit", "retire"):
        assert sum(1 for e in a["events"] if e[1] == kind) == len(jobs)
    # every finished job has a finish tick; zero-token jobs have no TTFT
    assert all(f is not None for f in a["finish_ticks"])
    assert a["ttft_ticks"][3] is None
    assert all(t >= 1 for t in a["ttft_ticks"] if t is not None)
    # among co-queued jobs the shorter prompt admits first: jobs 1 (10)
    # and 2 (25) both wait while job 0 prefills
    admit = {e[2]: e[0] for e in a["events"] if e[1] == "admit"}
    assert admit[1] < admit[2]


def test_virtual_clock_slots_bound_concurrency():
    jobs = [(8, 12)] * 6
    one = _virtual_clock(jobs, slots=1, chunk=8)
    four = _virtual_clock(jobs, slots=4, chunk=8)
    assert four["makespan_ticks"] < one["makespan_ticks"]


def _ttft_p95(vc):
    ts = sorted(t for t in vc["ttft_ticks"] if t is not None)
    return ts[min(int(0.95 * len(ts)), len(ts) - 1)]


def test_spec_prefill_interleave_pins_ttft_within_2x_baseline():
    """Regression for the BENCH_eval.json speculative TTFT outlier
    (ttft_p95 21.7s vs 5.3s baseline ~= spec_window + 1): a K-deep
    super-tick that advances the in-flight admission only one chunk per
    super-tick starves prefill by (K+1)x. The scheduler interleaves one
    chunk per draft step; the virtual clock models both behaviors, the
    un-interleaved one must reproduce the outlier and the interleaved one
    must stay within 2x of baseline."""
    jobs = [(64, 12)] * 8
    K = 4
    kw = dict(slots=8, chunk=8)                 # no slot contention:
    base = _virtual_clock(jobs, substeps=1, **kw)   # prefill cadence only
    starved = _virtual_clock(jobs, substeps=K + 1,
                             interleave_prefill=False, **kw)
    fixed = _virtual_clock(jobs, substeps=K + 1, **kw)
    assert _ttft_p95(starved) > 2 * _ttft_p95(base)     # the outlier
    assert _ttft_p95(fixed) <= 2 * _ttft_p95(base)      # the pin


# ---------------------------------------------------------------------------
# replay determinism + HTTP smoke on a tiny model
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def eval_model():
    tok = CodeTokenizer(_SPECIALS)          # pure byte-fallback tokenizer
    cfg = paper_mini(num_layers=6, d_model=64, vocab_size=tok.vocab_size)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params, tok


SMOKE_ARMS = (PolicyArm("baseline", {"name": "none"}),
              PolicyArm("fixed@0", {"name": "fixed", "exit_idx": 0.0}))
SMOKE_CFG = EvalRunConfig(n_samples=1, ks=(1,), temperature=0.0, seed=0)


def test_replay_byte_identical_and_smoke_pass_rate(eval_model):
    """The CI determinism gate in miniature: two full replays of the
    2-task smoke suite are byte-identical, and the suite's pass@1 is
    exactly 0.5 (comment task passes, needle task fails) on every arm."""
    cfg, params, tok = eval_model
    rep1 = run_replay(params, cfg, tok, smoke_tasks(), SMOKE_ARMS,
                      SMOKE_CFG)
    rep2 = run_replay(params, cfg, tok, smoke_tasks(), SMOKE_ARMS,
                      SMOKE_CFG)
    assert payload_bytes(rep1) == payload_bytes(rep2)
    assert payload_digest(rep1) == payload_digest(rep2)
    for name, arm in rep1["arms"].items():
        s = arm["summary"]
        assert s["pass_at"]["1"] == 0.5, name
        assert s["statuses"] == {"failed": 1, "passed": 1}, name
        assert s["tokens"] > 0 and s["decode_energy_j"] > 0
        assert s["ttft_p95_ticks"] is not None
    # the fixed-exit arm must be strictly cheaper than full depth (the
    # 6-layer mini has an exit point at layer 4)
    base = rep1["arms"]["baseline"]["summary"]
    fixed = rep1["arms"]["fixed@0"]["summary"]
    assert fixed["j_per_token"] < base["j_per_token"]
    assert fixed["mean_exit_layer"] < base["mean_exit_layer"]


def test_replay_spec_arm_ttft_within_2x_baseline(eval_model):
    """The deterministic-replay pin for the speculative TTFT outlier: the
    speculative arm's virtual-clock TTFT (charged in compiled-model steps,
    spec_window + 1 per super-tick, prefill interleaved) stays within 2x
    of the baseline arm's."""
    cfg, params, tok = eval_model
    arms = (PolicyArm("baseline", {"name": "none"}),
            PolicyArm("speculative",
                      {"name": "speculative", "draft_idx": 0.0,
                       "window": 4.0}))
    rep = run_replay(params, cfg, tok, smoke_tasks(), arms, SMOKE_CFG,
                     spec_window=4)
    base = rep["arms"]["baseline"]["summary"]["ttft_p95_ticks"]
    spec = rep["arms"]["speculative"]["summary"]["ttft_p95_ticks"]
    assert base is not None and spec is not None
    assert spec <= 2 * base


def test_replay_payload_has_no_wallclock_fields(eval_model):
    cfg, params, tok = eval_model
    rep = run_replay(params, cfg, tok, smoke_tasks(), SMOKE_ARMS, SMOKE_CFG)

    def walk(obj, path=""):
        if isinstance(obj, dict):
            for k, v in obj.items():
                assert not k.endswith("_s"), f"wall-clock key {path}.{k}"
                walk(v, f"{path}.{k}")
        elif isinstance(obj, list):
            for i, v in enumerate(obj):
                walk(v, f"{path}[{i}]")
    walk(rep)


def test_frontier_and_write_bench(eval_model, tmp_path):
    cfg, params, tok = eval_model
    rep = run_replay(params, cfg, tok, smoke_tasks(), SMOKE_ARMS, SMOKE_CFG)
    rows = frontier(rep)
    assert [r["arm"] for r in rows] == ["fixed@0", "baseline"]  # cheap first
    assert all("pass@1" in r and "ttft_p95_ticks" in r for r in rows)
    out = tmp_path / "BENCH_eval.json"
    bench = write_bench(out, replay_report=rep)
    on_disk = json.loads(out.read_text())
    assert on_disk["bench"] == "code_eval"
    assert on_disk["replay_frontier"] == rows
    assert on_disk["replay_digest"] == bench["replay_digest"]
    with pytest.raises(ValueError):
        write_bench(out)


@pytest.fixture(scope="module")
def eval_server(eval_model):
    from repro.obs import Tracer
    from repro.serving import Scheduler
    from repro.serving.server import Handler, _State
    cfg, params, tok = eval_model
    _State.cfg, _State.params = cfg, params
    _State.agent, _State.tokenizer = None, tok
    sched = Scheduler(
        params, cfg, allowed_kinds=("none", "fixed", "confidence",
                                    "speculative"),
        tokenizer=tok, max_slots=4, max_len=192, max_new=24,
        prefill_chunk=16, spec_window=4, tracer=Tracer(enabled=True))
    sched.start()
    _State.scheduler = sched
    srv = ThreadingHTTPServer(("127.0.0.1", 0), Handler)
    threading.Thread(target=srv.serve_forever, daemon=True).start()
    yield f"http://127.0.0.1:{srv.server_address[1]}"
    srv.shutdown()
    sched.stop()
    _State.scheduler = None


def test_http_driver_smoke_with_span_join(eval_server):
    rc = EvalRunConfig(n_samples=1, ks=(1,), temperature=0.0,
                       rate_hz=100.0, seed=0)
    rep = run_http(eval_server, smoke_tasks(), SMOKE_ARMS, rc)
    assert rep["mode"] == "http"
    for name, arm in rep["arms"].items():
        s = arm["summary"]
        assert s["transport_errors"] == 0, name
        assert s["pass_at"]["1"] == 0.5, name
        assert s["ttft_p95_s"] > 0
        # per-request energy join: every sample matched a req/* lifecycle
        # span and the span's joules equal the NDJSON record's
        assert s["span_join_frac"] == 1.0, name
        for smp in arm["samples"]:
            assert smp["span_energy_j"] == pytest.approx(smp["energy_j"])
            assert smp["tokens"] > 0
            assert smp["ttft_s"] is not None


def test_server_records_carry_energy_and_ttft(eval_server):
    """The new final-record fields the eval client consumes."""
    payload = {"inputs": "def add(a, b):\n",
               "parameters": {"max_new_tokens": 4}}
    req = urllib.request.Request(
        f"{eval_server}/generate", data=json.dumps(payload).encode(),
        headers={"Content-Type": "application/json"})
    with urllib.request.urlopen(req, timeout=120) as r:
        out = json.loads(r.read())
    assert out["tokens"] == len(out["exit_layers"])
    assert out["decode_energy_j"] > 0
    assert out["prefill_energy_j"] > 0
    assert out["energy_per_token_j"] == pytest.approx(
        out["decode_energy_j"] / out["tokens"])
    assert out["ttft_s"] is not None and out["ttft_s"] <= out["latency_s"]
    # scheduler-level TTFT percentiles surface in /queue
    with urllib.request.urlopen(f"{eval_server}/queue", timeout=30) as r:
        st = json.loads(r.read())
    assert st["ttft_p95_s"] is not None and st["ttft_p95_s"] > 0


def test_default_arms_shape():
    arms = default_arms(thresholds=(0.5, 0.9))
    names = [a.name for a in arms]
    assert names[0] == "baseline"
    assert "fixed@0" in names
    assert "confidence@0.5" in names and "confidence@0.9" in names
    assert names[-1] == "speculative"
    specs = [a.spec() for a in arms]         # all validate eagerly
    assert specs[0].name == "none"


def test_sandbox_env_is_isolated():
    """`python -I` + scrubbed env: the candidate must not see the
    parent's PYTHONPATH (no repro import) or inherit cwd."""
    t = EvalTask(
        task_id="iso",
        prompt=("import os, sys\n"
                "def f():\n"
                "    assert 'PYTHONPATH' not in os.environ\n"
                "    assert (os.path.realpath(os.getcwd())\n"
                "            == os.path.realpath(os.environ['HOME']))\n"
                "    try:\n"
                "        import repro\n"
                "        raise AssertionError('repro importable')\n"
                "    except ImportError:\n"
                "        pass\n"),
        entry_point="f", test=CALL_TEST)
    r = check_completion(t, "", timeout_s=15.0)
    assert r.passed, r.detail


def test_run_config_sample_seeds_stable():
    rc = EvalRunConfig(seed=7)
    s1 = rc.sample_seed(0, 0)
    assert s1 == rc.sample_seed(0, 0)
    assert rc.sample_seed(0, 1) != s1
    assert rc.sample_seed(1, 0) != s1
    assert 0 <= s1 < 2 ** 31


def test_smoke_pass_at_1_is_half_scalar():
    """The arithmetic behind the CI hard gate: 1 pass + 1 fail at n=1."""
    assert (pass_at_k(1, 1, 1) + pass_at_k(1, 0, 1)) / 2 == 0.5
