"""Sharding rule allocator: divisibility, conflicts, ZeRO."""
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

import jax

from repro.sharding.api import (_allocate, _apply_zero, axis_rules,
                                constrain, param_shardings)


def _mesh2x2():
    devs = jax.devices()
    if len(devs) < 1:
        pytest.skip("no devices")
    # single-device meshes still exercise the allocator logic via shape math
    return Mesh(np.asarray(devs[:1]).reshape(1, 1), ("data", "model"))


class FakeMesh:
    """Shape-only stand-in (allocator never touches devices)."""

    def __init__(self, **axes):
        self.shape = dict(axes)
        self.axis_names = tuple(axes)


def test_allocate_divisibility():
    mesh = FakeMesh(data=16, model=16)
    spec = _allocate(["batch", None, "heads", None], (256, 1, 32, 128), mesh)
    assert spec == P("data", None, "model", None)
    # 8 kv heads can't shard over model=16 -> replicated
    spec = _allocate(["batch", None, "kv_heads", None], (256, 1, 8, 128),
                     mesh)
    assert spec == P("data", None, None, None)


def test_allocate_no_axis_reuse():
    mesh = FakeMesh(data=16, model=16)
    # vocab indivisible -> falls back; seq_mp picks up model
    spec = _allocate(["batch", "seq_mp", "vocab"], (256, 4096, 49155), mesh)
    assert spec == P("data", "model", None)
    # vocab divisible -> takes model; seq_mp must NOT reuse it
    spec = _allocate(["batch", "seq_mp", "vocab"], (256, 4096, 256000), mesh)
    assert spec == P("data", None, "model")


def test_allocate_multi_axis_batch():
    mesh = FakeMesh(pod=2, data=16, model=16)
    spec = _allocate(["batch", None], (256, 4), mesh)
    assert spec == P(("pod", "data"), None)
    # batch=8: pod*data=32 doesn't divide -> drop pod, keep data? 8%32!=0,
    # then try ("data",): 8%16 != 0 -> fully replicated
    spec = _allocate(["batch", None], (8, 4), mesh)
    assert spec == P(None, None)


def test_zero_shards_largest_replicated_dim():
    mesh = FakeMesh(pod=2, data=16, model=16)
    spec = _apply_zero(P(None, "model"), (8192, 1024), mesh,
                       ("pod", "data"))
    assert spec == P(("pod", "data"), "model")


def test_param_rules_moe_expert_parallel():
    """MoE expert weights are expert-parallel: experts are padded to a
    multiple of 16 at init (40 -> 48) and the expert dim takes the model
    axis; d_ff is deliberately unmapped (see PARAM_RULES comment)."""
    mesh = FakeMesh(data=16, model=16)
    from repro.sharding.api import _spec_for_path
    spec = _spec_for_path("segments/0/ffn/moe/up", (48, 1536, 512), mesh)
    assert spec == P("model", None, None)
    spec = _spec_for_path("segments/0/ffn/moe/down", (48, 512, 1536), mesh)
    assert spec == P("model", None, None)
    # un-padded (indivisible) expert count would replicate — the padding
    # in models.moe.padded_experts is what makes EP possible
    spec = _spec_for_path("segments/0/ffn/moe/up", (40, 1536, 512), mesh)
    assert spec == P(None, None, None) or spec == P()


def test_constrain_noop_without_rules(mini_cfg, mini_params):
    import jax.numpy as jnp
    x = jnp.zeros((4, 8))
    y = constrain(x, "batch", "embed")
    assert y.shape == x.shape


def test_constrain_rank_mismatch():
    import jax.numpy as jnp
    mesh = _mesh2x2()
    with axis_rules(mesh):
        with pytest.raises(ValueError):
            constrain(jnp.zeros((2, 2)), "batch")
