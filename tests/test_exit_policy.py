"""Golden-parity suite for the first-class exit-policy API.

Reference controllers below are *verbatim reimplementations of the seed's
ControllerFn closures* (PR-1 core/controller.py), so these tests pin the
new registry/data path to the seed's byte-exact behaviour — solo in
``generate`` and mid-flight inside the scheduler's one compiled step.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import PolicySpec, stack_policies
from repro.core import exit_policy, policy_net
from repro.core.early_exit import generate
from repro.models import transformer as T
from repro.models.transformer import lm_logits
from repro.serving import Engine, Scheduler


# ---------------------------------------------------------------------------
# seed-PR1 reference controllers (closure style, copied semantics)
# ---------------------------------------------------------------------------
def _seed_head_stats(params, cfg, h):
    logits = lm_logits(params, cfg, h[:, None, :])[:, 0, :]
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    p = jnp.exp(logp)
    return p.max(axis=-1), -(p * logp).sum(axis=-1) / jnp.log(cfg.vocab_size)


def seed_controller(kind, *, params=None, cfg=None, agent_params=None,
                    threshold=0.9, exit_idx=0, temperature=1.0):
    if kind == "none":
        return lambda h, i: None
    if kind == "fixed":
        return lambda h, i: jnp.full((h.shape[0],),
                                     1.0 if i >= exit_idx else 0.0)
    if kind == "confidence":
        def ctrl(h, i):
            p1, _ = _seed_head_stats(params, cfg, h)
            return (p1 > threshold).astype(jnp.float32)
        return ctrl
    if kind == "entropy":
        def ctrl(h, i):
            _, ent = _seed_head_stats(params, cfg, h)
            return (ent < threshold).astype(jnp.float32)
        return ctrl
    if kind == "policy":
        def ctrl(h, i):
            p_exit = policy_net.exit_probability(agent_params, h,
                                                 temperature)
            return (p_exit > threshold).astype(jnp.float32)
        return ctrl
    raise ValueError(kind)


@pytest.fixture(scope="module")
def agent(mini_cfg):
    return policy_net.init_policy(jax.random.PRNGKey(3), mini_cfg.d_model)


def _toks(cfg, shape, seed=0):
    return jax.random.randint(jax.random.PRNGKey(seed), shape, 0,
                              cfg.vocab_size)


# a threshold per kind that actually produces mixed exit depths on the
# untrained mini model (pure extremes would not exercise the selection)
CASES = [
    ("none", {}, {}),
    ("fixed", dict(exit_idx=0), {"exit_idx": 0.0}),
    ("confidence", dict(threshold=0.02), {"threshold": 0.02}),
    ("entropy", dict(threshold=0.98), {"threshold": 0.98}),
    ("policy", dict(threshold=0.45), {"threshold": 0.45}),
]


# ---------------------------------------------------------------------------
# registry sanity
# ---------------------------------------------------------------------------
def test_registry_covers_seed_kinds_with_unique_ids():
    assert set(exit_policy.names()) >= {"none", "fixed", "confidence",
                                        "entropy", "policy"}
    ids = [exit_policy.get(n).id for n in exit_policy.names()]
    assert len(set(ids)) == len(ids)
    assert exit_policy.get("none").id == 0
    with pytest.raises(ValueError, match="unknown exit policy"):
        exit_policy.get("nope")


def test_spec_validates_eagerly():
    with pytest.raises(ValueError, match="unknown exit policy"):
        PolicySpec("nope")
    with pytest.raises(ValueError, match="no params"):
        PolicySpec("fixed", {"threshold": 0.5})
    assert PolicySpec("confidence").resolved() == {"threshold": 0.9}
    assert PolicySpec("confidence", {"threshold": 0.5}).resolved() == \
        {"threshold": 0.5}


def test_missing_context_raises_clear_typeerror(mini_cfg, mini_params):
    ctx = exit_policy.PolicyContext()
    with pytest.raises(TypeError, match="model parameter"):
        exit_policy.as_exit_fn(PolicySpec("confidence"), ctx)
    with pytest.raises(TypeError, match="agent"):
        exit_policy.as_exit_fn(PolicySpec("policy"), ctx)
    # the deprecated shim validates the same way
    from repro.core.controller import make_controller
    with pytest.raises(TypeError, match="ModelConfig"):
        make_controller("entropy", params=mini_params)
    with pytest.raises(TypeError, match="agent"):
        make_controller("policy")
    with pytest.raises(ValueError, match="unknown exit policy"):
        make_controller("wat")


# ---------------------------------------------------------------------------
# golden parity: solo generate
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("kind,seed_kw,spec_params",
                         CASES, ids=[c[0] for c in CASES])
def test_generate_matches_seed_controller(kind, seed_kw, spec_params,
                                          mini_cfg, mini_params, agent):
    toks = _toks(mini_cfg, (3, 7), seed=1)
    ref_ctrl = seed_controller(kind, params=mini_params, cfg=mini_cfg,
                               agent_params=agent, **seed_kw)
    ref = generate(mini_params, mini_cfg, toks, 5, ref_ctrl)
    new = generate(mini_params, mini_cfg, toks, 5,
                   policy=PolicySpec(kind, spec_params),
                   agent_params=agent)
    np.testing.assert_array_equal(np.asarray(ref["tokens"]),
                                  np.asarray(new["tokens"]))
    np.testing.assert_array_equal(np.asarray(ref["exit_layers"]),
                                  np.asarray(new["exit_layers"]))


def test_stacked_rows_match_solo_runs(mini_cfg, mini_params, agent):
    """Heterogeneous per-row policies in ONE call == each policy solo."""
    toks = _toks(mini_cfg, (len(CASES), 7), seed=2)
    batch = stack_policies([PolicySpec(k, p) for k, _, p in CASES])
    out = generate(mini_params, mini_cfg, toks, 5, policy=batch,
                   agent_params=agent)
    for row, (kind, _, spec_params) in enumerate(CASES):
        solo = generate(mini_params, mini_cfg, toks[row:row + 1], 5,
                        policy=PolicySpec(kind, spec_params),
                        agent_params=agent)
        np.testing.assert_array_equal(
            np.asarray(out["tokens"])[row], np.asarray(solo["tokens"])[0],
            err_msg=f"tokens diverged for stacked row {kind}")
        np.testing.assert_array_equal(
            np.asarray(out["exit_layers"])[row],
            np.asarray(solo["exit_layers"])[0],
            err_msg=f"exit layers diverged for stacked row {kind}")


# ---------------------------------------------------------------------------
# golden parity: scheduler (mid-flight) vs seed-controller engine
# ---------------------------------------------------------------------------
def test_scheduler_matches_seed_controllers_mid_flight(mini_cfg, mini_params,
                                                       agent):
    """Every kind, joining a running batch, is byte-identical to the seed
    ControllerFn path through the one-shot Engine."""
    sched = Scheduler(mini_params, mini_cfg, agent_params=agent,
                      allowed_kinds=[c[0] for c in CASES],
                      max_slots=3, max_len=64, max_new=6).start()
    eng = Engine(mini_params, mini_cfg, max_new=6, max_context=64)
    rng = np.random.default_rng(3)
    prompt_a = rng.integers(4, mini_cfg.vocab_size, 20).tolist()
    try:
        for kind, seed_kw, spec_params in CASES:
            prompt = rng.integers(4, mini_cfg.vocab_size, 16).tolist()
            ref = eng.serve([prompt], controller=seed_controller(
                kind, params=mini_params, cfg=mini_cfg, agent_params=agent,
                **seed_kw))
            # keep another request mid-decode while this kind joins
            ha = sched.submit(prompt_a, max_new=6)
            it = ha.stream(timeout=60.0)
            next(it), next(it)
            hb = sched.submit(prompt, max_new=6,
                              policy=PolicySpec(kind, spec_params))
            ha.result(60.0)
            hb.result(60.0)
            assert hb.tokens == ref.tokens[0], kind
            assert hb.exit_layers == ref.exit_layers[0], kind
        assert sched.step_compiles == 1, "policy mix caused a recompile"
    finally:
        sched.stop()


# ---------------------------------------------------------------------------
# Engine.serve_requests contracts
# ---------------------------------------------------------------------------
def test_serve_requests_honors_engine_default_policy(mini_cfg, mini_params):
    """policy=None falls back to the engine's configured default, exactly
    like serve(); a legacy callable default can't be stacked and errors."""
    from repro.api import GenerationRequest
    rng = np.random.default_rng(7)
    p = rng.integers(4, mini_cfg.vocab_size, 10).tolist()
    eng = Engine(mini_params, mini_cfg, PolicySpec("fixed", {"exit_idx": 0}),
                 max_context=32)
    res = eng.serve_requests([GenerationRequest(prompt=p,
                                                max_new_tokens=4)])[0]
    assert all(e < mini_cfg.num_layers for e in res.exit_layers[1:])
    eng2 = Engine(mini_params, mini_cfg,
                  seed_controller("fixed", exit_idx=0), max_context=32)
    with pytest.raises(ValueError, match="stacked per-row"):
        eng2.serve_requests([GenerationRequest(prompt=p, max_new_tokens=4)])
    # explicit per-request policies still work with a callable default
    ok = eng2.serve_requests([GenerationRequest(prompt=p, max_new_tokens=4,
                                                policy="none")])[0]
    assert all(e == mini_cfg.num_layers for e in ok.exit_layers)


def test_serve_requests_sampled_rows_independent_of_batch(mini_cfg,
                                                          mini_params):
    """A sampled request's draws are keyed by (seed, own position), never
    by neighbours or batch size. Note the engine left-pads to the batch
    max, so a LONGER co-batched prompt still shifts the row's logits
    (padding is visible to the model) — the invariance contract covers the
    randomness, and token-level equality holds when the padded context is
    unchanged, as here."""
    from repro.api import GenerationRequest, SamplingParams
    rng = np.random.default_rng(5)
    p1 = rng.integers(4, mini_cfg.vocab_size, 12).tolist()
    p2 = rng.integers(4, mini_cfg.vocab_size, 12).tolist()
    p3 = rng.integers(4, mini_cfg.vocab_size, 9).tolist()   # shorter row
    eng = Engine(mini_params, mini_cfg, max_new=6, max_context=32)
    gr = lambda: GenerationRequest(  # noqa: E731
        prompt=p1, max_new_tokens=6,
        sampling=SamplingParams(temperature=0.9, top_k=10, seed=13))
    solo = eng.serve_requests([gr()])[0]
    trio = eng.serve_requests([GenerationRequest(prompt=p2,
                                                 max_new_tokens=6),
                               gr(),
                               GenerationRequest(prompt=p3,
                                                 max_new_tokens=6)])
    assert trio[1].tokens == solo.tokens
    assert trio[1].exit_layers == solo.exit_layers


def test_serve_requests_stop_truncates_tokens_and_energy(mini_cfg,
                                                         mini_params,
                                                         mini_dataset):
    """Stop hits end the token/exit/energy accounting at the completing
    token (scheduler-retirement semantics), not just the text."""
    from repro.api import GenerationRequest
    tok = mini_dataset.tokenizer
    rng = np.random.default_rng(6)
    prompt = rng.integers(4, mini_cfg.vocab_size, 12).tolist()
    eng = Engine(mini_params, mini_cfg, max_new=8, max_context=32,
                 tokenizer=tok)
    free = eng.serve_requests([GenerationRequest(prompt=prompt,
                                                 max_new_tokens=8)])[0]
    import re
    runs = [m.group() for m in re.finditer(r"[^�]{2,}", free.text or "")]
    assert runs, "no clean text to derive a stop sequence from"
    best = max(runs, key=len)
    mid = best[len(best) // 2 - 1:len(best) // 2 + 1]
    res = eng.serve_requests([GenerationRequest(
        prompt=prompt, max_new_tokens=8, stop_sequences=(mid,))])[0]
    assert res.finish_reason == "stop"
    assert mid not in (res.text or "")
    assert len(res.tokens) <= len(free.tokens)
    assert res.tokens == free.tokens[:len(res.tokens)]
    assert res.metrics.n_tokens == max(len(res.tokens), 1)
    assert res.energy_j <= free.energy_j
