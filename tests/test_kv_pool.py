"""Paged KV-cache subsystem: block allocator invariants, prefix sharing,
copy-on-write, and golden parity of the paged scheduler/engine (XLA gather
reference AND Pallas kernel) against the contiguous-cache stack."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import GenerationRequest, PolicySpec, SamplingParams
from repro.core.early_exit import generate
from repro.models import transformer as T
from repro.serving import Engine, PagedKVPool, Scheduler
from repro.serving.kv_pool import BlockAllocator, chain_hashes


def _prompts(vocab, lens, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(4, vocab, n).tolist() for n in lens]


@pytest.fixture(scope="module")
def small_cfg():
    from repro.configs.llama32_3b import paper_mini
    return paper_mini(num_layers=4, d_model=64, vocab_size=256)


@pytest.fixture(scope="module")
def small_params(small_cfg):
    return T.init_params(jax.random.PRNGKey(0), small_cfg)


def _sched(params, cfg, **kw):
    base = dict(controller_kind="fixed", fixed_exit_idx=0,
                allowed_kinds=("none", "fixed"), max_slots=3, max_len=48,
                max_new=8, queue_depth=16)
    base.update(kw)
    return Scheduler(params, cfg, **base)


@pytest.fixture(scope="module")
def contiguous(small_cfg, small_params):
    s = _sched(small_params, small_cfg).start()
    yield s
    s.stop()


@pytest.fixture(scope="module")
def paged(small_cfg, small_params):
    s = _sched(small_params, small_cfg, kv_layout="paged",
               block_size=8).start()
    yield s
    s.stop()


# ---------------------------------------------------------------------------
# block allocator
# ---------------------------------------------------------------------------
def test_block_allocator_invariants():
    a = BlockAllocator(5, reserved=1)          # blocks 1..4 allocatable
    got = [a.alloc() for _ in range(4)]
    assert sorted(got) == [1, 2, 3, 4]
    assert a.alloc() is None and a.n_available == 0 and a.n_in_use == 4
    a.incref(got[0])
    a.decref(got[0])
    assert a.n_in_use == 4                     # still referenced once
    a.decref(got[0])
    assert a.n_in_use == 3 and a.n_available == 1
    with pytest.raises(ValueError, match="double-freed"):
        a.decref(got[0])
    with pytest.raises(ValueError, match="out of range"):
        a.decref(0)                            # reserved scratch block
    with pytest.raises(ValueError, match="while free"):
        a.incref(got[0])
    assert a.peak_in_use == 4


def test_block_allocator_cached_free_reuse_and_eviction():
    a = BlockAllocator(4, reserved=1)
    b1, b2, b3 = a.alloc(), a.alloc(), a.alloc()
    a.register(b1, b"k1")
    a.register(b2, b"k2")
    a.decref(b1)
    a.decref(b2)
    assert a.n_cached_free == 2 and a.n_free == 0
    # a cached-free block revives through its hash without reallocation
    assert a.share(b"k1") == b1 and a.refcount(b1) == 1
    # allocation pressure evicts the LRU cached-free block (b2) and drops
    # its hash entry
    a.decref(b3)
    assert a.alloc() == b3                     # plain free list first
    assert a.alloc() == b2
    assert a.share(b"k2") is None


def test_chain_hashes_prefix_semantics():
    p = list(range(40))
    keys = chain_hashes(p, 8)
    assert len(keys) == 5
    assert chain_hashes(p[:32], 8) == keys[:4]         # shared full blocks
    q = p[:32] + [999] * 8
    assert chain_hashes(q, 8)[:4] == keys[:4]
    assert chain_hashes(q, 8)[4] != keys[4]            # divergent block
    # a partial tail is keyed by its exact tokens, not its block index
    assert chain_hashes(p[:35], 8)[4] != keys[4]


# ---------------------------------------------------------------------------
# pool accounting
# ---------------------------------------------------------------------------
def test_paged_pool_geometry_and_slot_accounting(small_cfg):
    pool = PagedKVPool(small_cfg, max_slots=2, max_len=32, block_size=8)
    assert pool.max_blocks_per_slot == 4
    assert pool.num_blocks == 1 + 2 * 4
    assert pool.blocks_for(1) == 1 and pool.blocks_for(9) == 2
    assert pool.bytes_per_block * pool.num_blocks == pool.kv_bytes_total
    s = pool.alloc()
    assert s is not None
    pool.release(s)
    with pytest.raises(ValueError, match="double-freed"):
        pool.release(s)
    with pytest.raises(ValueError, match="out of range"):
        pool.release(99)


def test_paged_pool_rejects_unsupported_configs():
    from repro.configs.gemma2_9b import smoke as gemma_smoke
    cfg = gemma_smoke()
    with pytest.raises(ValueError, match="sliding-window|unsupported"):
        PagedKVPool(cfg, max_slots=2, max_len=32)


# ---------------------------------------------------------------------------
# golden parity: paged scheduler vs contiguous scheduler
# ---------------------------------------------------------------------------
def test_paged_parity_mixed_traffic(contiguous, paged, small_cfg):
    """Bit-identical tokens / exit layers / energy for mixed-policy,
    mixed-sampling traffic across the two cache layouts (the paged
    reference path reuses the contiguous attention math on gathered
    blocks, so equality is exact, not approximate)."""
    p = _prompts(small_cfg.vocab_size, [20, 14, 11, 17], seed=3)

    def drive(s):
        hs = [
            s.submit(p[0], max_new=6),
            s.submit(p[1], max_new=6, controller="none"),
            s.submit(GenerationRequest(
                prompt=p[2], max_new_tokens=5,
                sampling=SamplingParams(temperature=0.9, top_k=7, seed=3))),
            s.submit(GenerationRequest(
                prompt=p[3], max_new_tokens=5,
                policy=PolicySpec("fixed", {"exit_idx": 1}),
                sampling=SamplingParams(temperature=1.2, top_p=0.7,
                                        seed=9))),
        ]
        return [h.result(60.0) for h in hs]

    rc = drive(contiguous)
    rp = drive(paged)
    for a, b in zip(rc, rp):
        assert a.tokens == b.tokens
        assert a.exit_layers == b.exit_layers
        assert a.energy_j == b.energy_j
    assert paged.step_compiles == 1


def test_mid_flight_prefix_hit_is_byte_identical(contiguous, paged,
                                                 small_cfg):
    """A request admitted mid-flight through a shared-prefix cache hit
    (two full blocks incref'd, not re-allocated) produces tokens identical
    to the contiguous scheduler serving it alone."""
    rng = np.random.default_rng(4)
    a = rng.integers(4, small_cfg.vocab_size, 20).tolist()
    b = a[:16] + rng.integers(4, small_cfg.vocab_size, 5).tolist()
    solo = contiguous.serve_batch([b], max_new=6)

    hits0 = paged.pool.prefix_hits
    ha = paged.submit(a, max_new=10)
    it = ha.stream(timeout=60.0)
    for _ in range(3):
        next(it)                       # A mid-decode when B joins
    hb = paged.submit(b, max_new=6)
    ha.result(60.0), hb.result(60.0)
    assert hb.started_at < ha.finished_at, "B never overlapped A"
    assert hb.tokens == solo.tokens[0]
    assert hb.exit_layers == solo.exit_layers[0]
    assert hb.metrics.energy_j == solo.metrics[0].energy_j
    assert paged.pool.prefix_hits > hits0
    assert paged.pool.prefix_hit_tokens >= 16


def test_duplicate_prompt_shares_tail_and_cows(contiguous, paged,
                                               small_cfg):
    """An exact-duplicate prompt shares every block including the partial
    tail; the first append into the shared tail copies it (COW) and both
    requests still reproduce the solo run exactly."""
    prompt = _prompts(small_cfg.vocab_size, [19], seed=5)[0]  # 19 % 8 != 0
    solo = contiguous.serve_batch([prompt], max_new=6)
    cow0 = paged.pool.cow_copies
    h1 = paged.submit(prompt, max_new=6)
    it = h1.stream(timeout=60.0)
    next(it)
    h2 = paged.submit(prompt, max_new=6)
    h1.result(60.0), h2.result(60.0)
    assert h1.tokens == h2.tokens == solo.tokens[0]
    assert h1.exit_layers == h2.exit_layers == solo.exit_layers[0]
    assert paged.pool.cow_copies > cow0, "shared tail never COWed"


def test_paged_blocks_all_released_after_traffic(paged):
    deadline = 5.0
    import time
    t0 = time.monotonic()
    while paged.pool.n_used:
        assert time.monotonic() - t0 < deadline
        time.sleep(0.01)
    assert paged.pool.blocks.n_in_use == 0
    assert paged.pool.reserved_blocks == 0


def test_kernel_path_scheduler_matches_contiguous(small_cfg, small_params,
                                                  contiguous):
    """The Pallas paged-attention kernel inside the scheduler step produces
    the same tokens and exit layers as the contiguous stack (flash
    accumulation may differ in ulps, so logits-level equality is asserted
    at the generate level, not here)."""
    p = _prompts(small_cfg.vocab_size, [20, 13], seed=6)
    sk = _sched(small_params, small_cfg, kv_layout="paged", block_size=8,
                use_kernel=True).start()
    try:
        rk = sk.serve_batch(p, max_new=6)
    finally:
        sk.stop()
    rc = contiguous.serve_batch(p, max_new=6)
    assert rk.tokens == rc.tokens
    assert rk.exit_layers == rc.exit_layers


# ---------------------------------------------------------------------------
# golden parity: generate / Engine
# ---------------------------------------------------------------------------
def test_generate_paged_ref_bit_identical(small_cfg, small_params):
    rng = np.random.default_rng(7)
    prompt = jnp.asarray(rng.integers(4, small_cfg.vocab_size, (2, 20)),
                         jnp.int32)
    g0 = generate(small_params, small_cfg, prompt, 6, policy="fixed")
    g1 = generate(small_params, small_cfg, prompt, 6, policy="fixed",
                  kv_block_size=8)
    assert (g0["tokens"] == g1["tokens"]).all()
    assert (g0["exit_layers"] == g1["exit_layers"]).all()
    assert (g0["logprobs"] == g1["logprobs"]).all()     # bit-identical


def test_generate_paged_kernel_parity(small_cfg, small_params):
    rng = np.random.default_rng(8)
    prompt = jnp.asarray(rng.integers(4, small_cfg.vocab_size, (2, 20)),
                         jnp.int32)
    g0 = generate(small_params, small_cfg, prompt, 6, policy="fixed")
    g2 = generate(small_params, small_cfg, prompt, 6, policy="fixed",
                  kv_block_size=8, use_kernel=True)
    assert (g0["tokens"] == g2["tokens"]).all()
    assert (g0["exit_layers"] == g2["exit_layers"]).all()
    np.testing.assert_allclose(np.asarray(g0["logprobs"]),
                               np.asarray(g2["logprobs"]),
                               rtol=1e-5, atol=1e-5)


def test_engine_paged_matches_contiguous(small_cfg, small_params):
    reqs = _prompts(small_cfg.vocab_size, [15, 9], seed=9)
    e0 = Engine(small_params, small_cfg, max_new=6)
    e1 = Engine(small_params, small_cfg, max_new=6, kv_layout="paged",
                kv_block_size=8)
    r0 = e0.serve(reqs, policy="fixed")
    r1 = e1.serve(reqs, policy="fixed")
    assert r0.tokens == r1.tokens
    assert r0.exit_layers == r1.exit_layers


# ---------------------------------------------------------------------------
# int8 KV cache under the scheduler (satellite: previously only solo)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("layout", ["contiguous", "paged"])
def test_int8_mid_flight_matches_solo_generate(small_cfg, small_params,
                                               layout):
    """Golden parity of a mid-flight int8 request against its solo
    ``generate`` run, for both cache layouts."""
    cfg8 = dataclasses.replace(small_cfg, kv_cache_dtype="int8")
    rng = np.random.default_rng(10)
    a = rng.integers(4, cfg8.vocab_size, 18).tolist()
    b = rng.integers(4, cfg8.vocab_size, 12).tolist()
    solo = generate(small_params, cfg8,
                    jnp.asarray([b], jnp.int32), 6, policy="fixed")
    solo_toks = np.asarray(solo["tokens"])[0].tolist()
    if 1 in solo_toks:                                   # EOS truncation
        solo_toks = solo_toks[:solo_toks.index(1)]
    kw = {} if layout == "contiguous" else dict(kv_layout="paged",
                                                block_size=8)
    s = _sched(small_params, cfg8, **kw).start()
    try:
        ha = s.submit(a, max_new=10)
        it = ha.stream(timeout=60.0)
        next(it), next(it)
        hb = s.submit(b, max_new=6)                      # joins mid-flight
        ha.result(60.0)
        r = hb.result(60.0)
    finally:
        s.stop()
    assert r.tokens == solo_toks
    exp_exits = np.asarray(solo["exit_layers"])[0][:max(len(solo_toks),
                                                        1)].tolist()
    assert r.exit_layers == exp_exits


def test_partial_tail_reservation_covers_cow(small_cfg):
    """Regression: every partial-tail admission holds its own +1 COW slack
    while the prefix cache is on. Without it, a later exact-prompt sharer
    can force this slot to COW, stealing a unit from its growth
    reservation and breaking the growth-never-fails invariant (the decode
    loop would die on 'append outran its block reservation')."""
    pool = PagedKVPool(small_cfg, max_slots=3, max_len=32, block_size=4,
                       num_blocks=12)
    pool._writer = lambda c, *a, **k: c        # accounting-only test
    pool._copier = lambda c, *a, **k: c
    sa = pool.alloc()
    pool.write_prompt(sa, list(range(6)), None, max_new=10)
    assert int(pool._reserved[sa]) == pool.blocks_for(16) - 2 + 1
    sb = pool.alloc()
    pool.write_prompt(sb, list(range(6)), None, max_new=10)  # shares tail
    cow0 = pool.cow_copies
    pool.prepare_append(sa, 6)                 # A appends into shared tail
    assert pool.cow_copies == cow0 + 1
    # the COW consumed A's own slack — its growth budget is untouched
    assert int(pool._reserved[sa]) == pool.blocks_for(16) - 2
    pool.release(sb)                           # B retires early
    sc = pool.alloc()                          # C admits into the headroom
    pool.write_prompt(sc, list(range(8)), None, max_new=8)
    for pos in range(7, 16):                   # A grows to its full budget
        pool.prepare_append(sa, pos)
    for pos in range(8, 16):
        pool.prepare_append(sc, pos)
    pool.release(sa)
    pool.release(sc)
    assert pool.blocks.n_in_use == 0 and pool.reserved_blocks == 0


def test_submit_checks_capacity_on_final_prompt(small_cfg, small_params):
    """Regression: the capacity check must run on the exact prompt submit
    will hand to admission — can_admit sees that same length, so a request
    accepted by submit must always be admittable (no permanent requeue /
    head-of-line hang). Bucket padding no longer exists to inflate it."""
    s = _sched(small_params, small_cfg, kv_layout="paged", block_size=8,
               num_blocks=6, max_len=48)
    prompt = _prompts(small_cfg.vocab_size, [20], seed=13)[0]
    # blocks_for(20 + 20) + 1 COW = 6 > capacity 5: rejected up front
    with pytest.raises(ValueError, match="KV blocks"):
        s.submit(prompt, max_new=20)
    h = s.submit(prompt, max_new=10)           # need 5 <= 5: fine
    assert len(h.prompt) == 20                 # exact length, no padding


# ---------------------------------------------------------------------------
# block-gated admission
# ---------------------------------------------------------------------------
def test_admission_gates_on_free_blocks(small_cfg, small_params):
    """More slots than block capacity: admission must defer on blocks (not
    just slots), every request still completes, and an impossible request
    is rejected at submit."""
    s = _sched(small_params, small_cfg, max_slots=4, kv_layout="paged",
               block_size=8, num_blocks=6, max_len=48).start()
    # capacity: 5 usable blocks; each request below reserves 4 worst-case
    # (3 for prompt+decode, +1 COW slack), so residency is block-limited
    try:
        with pytest.raises(ValueError, match="KV blocks"):
            s.submit(_prompts(small_cfg.vocab_size, [40], seed=11)[0],
                     max_new=8)                         # 6 blocks > capacity
        reqs = _prompts(small_cfg.vocab_size, [14, 14, 14, 14], seed=12)
        res = s.serve_batch(reqs, max_new=5)
        assert [len(t) for t in res.tokens] == [5] * 4
        assert s.stats()["blocked_admissions"] >= 1
    finally:
        s.stop()
