"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.decode_attn import flash_decode
from repro.kernels.exit_head import exit_check
from repro.kernels.paged_decode_attn import paged_flash_decode
from repro.kernels.ssd_scan import ssd_scan
from repro.kernels.verify_attn import paged_verify_window


@pytest.mark.parametrize("B,D,V,cap", [
    (4, 64, 512, 0.0), (3, 128, 1000, 0.0), (8, 256, 2048, 30.0),
    (1, 32, 96, 0.0), (5, 64, 777, 0.0),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_exit_head(B, D, V, cap, dtype):
    key = jax.random.PRNGKey(B * V)
    h = jax.random.normal(key, (B, D), dtype)
    w = (jax.random.normal(key, (D, V)) * 0.05).astype(dtype)
    t1, l1, e1 = exit_check(h, w, cap, block_b=2, block_v=128)
    t2, l2, e2 = ref.exit_check_ref(h, w, cap)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    for a, b in [(t1, t2), (l1, l2), (e1, e2)]:
        assert float(jnp.abs(a - b).max()) < tol


def test_exit_head_probability_semantics():
    """exp(top1 - lse) must equal the top-1 softmax probability."""
    key = jax.random.PRNGKey(0)
    h = jax.random.normal(key, (4, 64))
    w = jax.random.normal(key, (64, 300)) * 0.1
    t, l, _ = exit_check(h, w)
    p_kernel = jnp.exp(t - l)
    logits = h @ w
    p_true = jax.nn.softmax(logits, -1).max(-1)
    assert float(jnp.abs(p_kernel - p_true).max()) < 1e-5


@pytest.mark.parametrize("B,KH,G,d,S,win,cap", [
    (2, 2, 4, 32, 64, 0, 0.0), (3, 4, 1, 64, 100, 0, 0.0),
    (2, 1, 8, 16, 48, 16, 50.0), (1, 8, 2, 128, 256, 0, 0.0),
    (2, 2, 2, 32, 33, 8, 0.0),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_decode(B, KH, G, d, S, win, cap, dtype):
    ks = jax.random.split(jax.random.PRNGKey(S), 5)
    q = jax.random.normal(ks[0], (B, KH, G, d), dtype)
    k = jax.random.normal(ks[1], (B, S, KH, d), dtype)
    v = jax.random.normal(ks[2], (B, S, KH, d), dtype)
    pos = jnp.arange(B) * 3 + S // 2
    kv_pos = jnp.broadcast_to(jnp.arange(S), (B, S))
    kv_pos = jnp.where(kv_pos < S - 5, kv_pos, -1)
    o1 = flash_decode(q, k, v, kv_pos, pos, window=win, softcap=cap,
                      block_s=32)
    o2 = ref.flash_decode_ref(q, k, v, kv_pos, pos, win, cap)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    assert float(jnp.abs(o1.astype(jnp.float32)
                         - o2.astype(jnp.float32)).max()) < tol


def _paged_case(seed, B, KH, G, d, bs, NB, nb, dtype=jnp.float32):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (B, KH, G, d), dtype)
    kp = jax.random.normal(ks[1], (NB, bs, KH, d), dtype)
    vp = jax.random.normal(ks[2], (NB, bs, KH, d), dtype)
    rng = np.random.default_rng(seed)
    tables = jnp.asarray(np.stack([rng.permutation(NB)[:nb]
                                   for _ in range(B)]).astype(np.int32))
    pos = jnp.asarray(rng.integers(0, nb * bs, B), jnp.int32)
    return q, kp, vp, tables, pos


@pytest.mark.parametrize("B,KH,G,d,bs,NB,nb,cap", [
    (2, 2, 4, 32, 8, 11, 4, 0.0), (3, 4, 1, 64, 16, 9, 3, 0.0),
    (1, 1, 8, 16, 4, 20, 7, 50.0), (4, 2, 2, 32, 8, 8, 2, 0.0),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_flash_decode(B, KH, G, d, bs, NB, nb, cap, dtype):
    q, kp, vp, tables, pos = _paged_case(B * nb + d, B, KH, G, d, bs, NB,
                                         nb, dtype)
    o1 = paged_flash_decode(q, kp, vp, tables, pos, softcap=cap)
    o2 = ref.paged_decode_ref(q, kp, vp, tables, pos, softcap=cap)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    assert float(jnp.abs(o1.astype(jnp.float32)
                         - o2.astype(jnp.float32)).max()) < tol


def test_paged_flash_decode_int8_dequant_in_kernel():
    q, kp, vp, tables, pos = _paged_case(5, B=3, KH=2, G=4, d=32, bs=8,
                                         NB=13, nb=5)

    def quant(x):
        sc = jnp.max(jnp.abs(x), axis=-1) / 127.0
        qv = jnp.round(x / jnp.maximum(sc[..., None], 1e-8)).astype(jnp.int8)
        return qv, sc

    kq, ksc = quant(kp)
    vq, vsc = quant(vp)
    o1 = paged_flash_decode(q, kq, vq, tables, pos, ksc, vsc)
    o2 = ref.paged_decode_ref(q, kq, vq, tables, pos, ksc, vsc)
    assert float(jnp.abs(o1 - o2).max()) < 1e-4


def _verify_case(seed, B, S, KH, G, d, bs, NB, nb, dtype=jnp.float32):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (B, S, KH, G, d), dtype)
    kp = jax.random.normal(ks[1], (NB, bs, KH, d), dtype)
    vp = jax.random.normal(ks[2], (NB, bs, KH, d), dtype)
    rng = np.random.default_rng(seed)
    tables = jnp.asarray(np.stack([rng.permutation(NB)[:nb]
                                   for _ in range(B)]).astype(np.int32))
    pos0 = jnp.asarray(rng.integers(0, nb * bs - S, B), jnp.int32)
    return q, kp, vp, tables, pos0


@pytest.mark.parametrize("B,S,KH,G,d,bs,NB,nb,cap", [
    (2, 4, 2, 4, 32, 8, 11, 4, 0.0), (3, 5, 4, 1, 64, 16, 9, 3, 0.0),
    (1, 3, 1, 8, 16, 4, 20, 7, 50.0), (4, 2, 2, 2, 32, 8, 8, 2, 0.0),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_verify_window(B, S, KH, G, d, bs, NB, nb, cap, dtype):
    q, kp, vp, tables, pos0 = _verify_case(B * nb + d + S, B, S, KH, G, d,
                                           bs, NB, nb, dtype)
    o1 = paged_verify_window(q, kp, vp, tables, pos0, softcap=cap)
    o2 = ref.paged_verify_ref(q, kp, vp, tables, pos0, softcap=cap)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    assert float(jnp.abs(o1.astype(jnp.float32)
                         - o2.astype(jnp.float32)).max()) < tol


def test_paged_verify_window_int8_dequant_in_kernel():
    q, kp, vp, tables, pos0 = _verify_case(5, B=3, S=4, KH=2, G=4, d=32,
                                           bs=8, NB=13, nb=5)

    def quant(x):
        sc = jnp.max(jnp.abs(x), axis=-1) / 127.0
        qv = jnp.round(x / jnp.maximum(sc[..., None], 1e-8)).astype(jnp.int8)
        return qv, sc

    kq, ksc = quant(kp)
    vq, vsc = quant(vp)
    o1 = paged_verify_window(q, kq, vq, tables, pos0, ksc, vsc)
    o2 = ref.paged_verify_ref(q, kq, vq, tables, pos0, ksc, vsc)
    assert float(jnp.abs(o1 - o2).max()) < 1e-4


def test_paged_verify_ref_matches_per_token_decode():
    """A window of S queries equals S successive single-token paged decodes
    (each query one position deeper) — the q_len>1 kernel's semantic
    anchor to the decode kernel's."""
    B, S, KH, G, d, bs, NB, nb = 2, 3, 2, 2, 16, 8, 10, 4
    q, kp, vp, tables, pos0 = _verify_case(7, B, S, KH, G, d, bs, NB, nb)
    win = ref.paged_verify_ref(q, kp, vp, tables, pos0)
    for j in range(S):
        one = ref.paged_decode_ref(q[:, j], kp, vp, tables, pos0 + j)
        assert float(jnp.abs(win[:, j] - one).max()) < 1e-5


def test_paged_decode_ref_matches_contiguous_gather():
    """The paged reference equals ring-cache flash_decode_ref on the same
    logical sequence (pages laid out by an identity table)."""
    B, KH, G, d, bs, nb = 2, 2, 2, 16, 8, 3
    q, kp, vp, _, _ = _paged_case(9, B, KH, G, d, bs, B * nb, nb)
    tables = jnp.arange(B * nb, dtype=jnp.int32).reshape(B, nb)
    pos = jnp.asarray([5, nb * bs - 1], jnp.int32)
    k = kp.reshape(B, nb * bs, KH, d)
    v = vp.reshape(B, nb * bs, KH, d)
    kv_pos = jnp.broadcast_to(jnp.arange(nb * bs), (B, nb * bs))
    o_ref = ref.flash_decode_ref(q, k, v, kv_pos, pos)
    o_paged = ref.paged_decode_ref(q, kp, vp, tables, pos)
    assert float(jnp.abs(o_ref - o_paged).max()) < 1e-6


@pytest.mark.parametrize("Bt,S,H,P,N,Q", [
    (2, 64, 4, 16, 8, 16), (1, 100, 2, 32, 16, 32), (3, 33, 8, 8, 4, 8),
    (2, 256, 4, 64, 32, 64), (1, 17, 2, 8, 4, 32),
])
def test_ssd_scan(Bt, S, H, P, N, Q):
    ks = jax.random.split(jax.random.PRNGKey(S * H), 5)
    x = jax.random.normal(ks[0], (Bt, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (Bt, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    B = jax.random.normal(ks[3], (Bt, S, N))
    C = jax.random.normal(ks[4], (Bt, S, N))
    y1, h1 = ssd_scan(x, dt, A, B, C, Q)
    y2, h2 = ref.ssd_scan_ref(x, dt, A, B, C, Q)
    rel = float(jnp.abs(y1 - y2).max()) / max(float(jnp.abs(y2).max()), 1e-6)
    assert rel < 1e-4
    assert float(jnp.abs(h1 - h2).max()) < 1e-2


def test_ssd_scan_matches_token_recurrence():
    """Chunked scan == naive per-token SSM recurrence."""
    Bt, S, H, P, N = 1, 24, 2, 8, 4
    ks = jax.random.split(jax.random.PRNGKey(7), 5)
    x = jax.random.normal(ks[0], (Bt, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (Bt, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    B = jax.random.normal(ks[3], (Bt, S, N))
    C = jax.random.normal(ks[4], (Bt, S, N))
    y, hfin = ssd_scan(x, dt, A, B, C, 8)
    h = jnp.zeros((Bt, H, P, N))
    for t in range(S):
        decay = jnp.exp(dt[:, t] * A)
        h = h * decay[..., None, None] + jnp.einsum(
            "bh,bhp,bn->bhpn", dt[:, t], x[:, t], B[:, t])
        yt = jnp.einsum("bhpn,bn->bhp", h, C[:, t])
        assert float(jnp.abs(yt - y[:, t]).max()) < 1e-3, t
    assert float(jnp.abs(h - hfin).max()) < 1e-3
