"""Cross-architecture conformance matrix: chunked prefill + speculative
decoding across SSM / MLA / sliding-window / MoE / GQA configs.

One parameterized cell per (architecture, feature): chunked prefill must
be bit-identical to whole-prompt prefill (every chunk's logits, every
ring/state leaf at valid positions, and the decode continuation after the
ring is finalized), and speculative decoding must be bit-identical to the
baseline decode loop (tokens, exit layers AND logprobs). Cells a feature
cannot serve are declared in UNSUPPORTED and asserted against the actual
``*_unsupported`` gates — an undeclared gate (silent fallback) or a
declared-but-passing gate both fail, so the matrix cannot drift.

Cell IDs name the pair directly in CI output, e.g.
``test_arch_matrix[mamba2_1_3b-chunked]``.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.core.early_exit import generate
from repro.core.speculative import speculative_generate
from repro.models import transformer as T

FEATURES = ("chunked", "speculative")

# the declared holes: (arch, feature) -> required substring of the gate's
# reason. Everything NOT listed here must pass bit-exact parity.
UNSUPPORTED = {
    ("musicgen-medium", "chunked"): "frontend",
    ("musicgen-medium", "speculative"): "frontend",
    ("pixtral-12b", "chunked"): "frontend",
    ("pixtral-12b", "speculative"): "frontend",
}

S0 = 9          # prompt length
STEPS = 8       # decode steps (speculative cells)
K = 3           # speculative draft window
CHUNKS = (3, 5)  # misaligned chunk splits checked against one whole chunk


def _cell_id(arch: str, feature: str) -> str:
    return f"{arch.replace('-', '_').replace('.', '_')}-{feature}"


def _cfg(arch: str):
    cfg = get_config(arch, "smoke")
    if arch == "gemma2-9b":
        # shrink the window below the prompt length so eviction, the
        # finalize-time window gather and the windowed speculative
        # rollback are actually exercised (smoke's 64 never wraps here)
        cfg = dataclasses.replace(cfg, sliding_window=8)
    return cfg


_PARAMS: dict = {}


def _model(arch: str):
    if arch not in _PARAMS:
        cfg = _cfg(arch)
        _PARAMS[arch] = (cfg, T.init_params(jax.random.PRNGKey(0), cfg))
    return _PARAMS[arch]


def _prompt(cfg, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return rng.integers(4, cfg.vocab_size, (1, S0)).astype(np.int32)


def _leaf_pairs(ref, got):
    for a, b in zip(jax.tree.leaves(ref), jax.tree.leaves(got)):
        yield np.asarray(a), np.asarray(b)


def _assert_rings_equal(cfg, ref, got, n_valid: int):
    """Bit-equality of prefill rings: mamba state and ``pos`` planes
    exactly, K/V (or MLA latent) planes at prompt positions only — grid
    padding past the prompt is inert garbage the mask never admits."""
    segs = T.plan_segments(cfg)

    def check(spec, ca, cb, stacked):
        if spec.mixer == "mamba":
            for a, b in _leaf_pairs(ca, cb):
                np.testing.assert_array_equal(a, b)
            return
        w_ax = 2 if stacked else 1
        for name in ca:
            a, b = np.asarray(ca[name]), np.asarray(cb[name])
            if name == "pos":
                np.testing.assert_array_equal(a, b)
            else:
                np.testing.assert_array_equal(
                    np.take(a, range(n_valid), axis=w_ax),
                    np.take(b, range(n_valid), axis=w_ax))

    for seg, ca, cb in zip(segs, ref, got):
        if seg.scanned:
            check(seg.specs[0], ca, cb, True)
        else:
            for spec, caj, cbj in zip(seg.specs, ca, cb):
                check(spec, caj, cbj, False)


def _run_chunked(cfg, params, toks: np.ndarray, C: int, ring_len: int):
    """Ingest the prompt in C-token chunks; return (all-position logits,
    final ring)."""
    S = toks.shape[1]
    ring = T.init_prefill_ring(cfg, 1, ring_len)
    logs = []
    for pos0 in range(0, S, C):
        grid = toks[:, pos0:pos0 + C]
        if grid.shape[1] < C:
            grid = np.pad(grid, ((0, 0), (0, C - grid.shape[1])))
        lg, ring = T.prefill_chunk(params, cfg, jnp.asarray(grid), ring,
                                   jnp.asarray([pos0], jnp.int32),
                                   jnp.asarray([S], jnp.int32))
        logs.append(np.asarray(lg[:, :min(C, S - pos0)]))
    return np.concatenate(logs, axis=1), ring


def _chunked_cell(arch: str):
    cfg, params = _model(arch)
    reason = T.chunked_prefill_unsupported(cfg)
    assert reason is None, f"undeclared unsupported cell: {reason}"
    toks = _prompt(cfg)
    ring_len = 24
    ref_log, ref_ring = _run_chunked(cfg, params, toks, S0, ring_len)
    for C in CHUNKS:
        lg, ring = _run_chunked(cfg, params, toks, C, ring_len)
        np.testing.assert_array_equal(ref_log, lg)
        _assert_rings_equal(cfg, ref_ring, ring, S0)
    # decode continuation: the finalized ring (windowed gather, int8
    # quantization) must carry on greedily exactly like the reference arm
    plen = jnp.asarray([S0], jnp.int32)
    ref_caches = T.finalize_prefill_ring(cfg, ref_ring, plen)
    got_caches = T.finalize_prefill_ring(cfg, ring, plen)
    tok = jnp.asarray([int(np.argmax(ref_log[0, -1]))], jnp.int32)
    for s in range(2):
        pos = jnp.asarray([S0 + s], jnp.int32)
        la, ref_caches, _ = T.decode_step(params, cfg, tok, ref_caches, pos)
        lb, got_caches, _ = T.decode_step(params, cfg, tok, got_caches, pos)
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))
        tok = jnp.argmax(la, axis=-1).astype(jnp.int32)


def _speculative_cell(arch: str):
    cfg, params = _model(arch)
    reason = T.speculative_unsupported(cfg)
    assert reason is None, f"undeclared unsupported cell: {reason}"
    prompt = jnp.asarray(_prompt(cfg))
    # the SAME explicit max_len on both arms: different ring extents mean
    # different reduction shapes, and bitwise parity is only defined
    # within one program geometry
    max_len = S0 + STEPS + K + 1
    base = generate(params, cfg, prompt, STEPS, max_len=max_len)
    spec = speculative_generate(params, cfg, prompt, STEPS, draft_idx=0,
                                window=K, max_len=max_len)
    np.testing.assert_array_equal(np.asarray(base["tokens"]),
                                  np.asarray(spec["tokens"]))
    np.testing.assert_array_equal(np.asarray(base["exit_layers"]),
                                  np.asarray(spec["exit_layers"]))
    np.testing.assert_array_equal(np.asarray(base["logprobs"]),
                                  np.asarray(spec["logprobs"]))


@pytest.mark.parametrize(
    "arch,feature",
    [(a, f) for a in ARCH_IDS for f in FEATURES],
    ids=[_cell_id(a, f) for a in ARCH_IDS for f in FEATURES])
def test_arch_matrix(arch, feature):
    declared = UNSUPPORTED.get((arch, feature))
    if declared is not None:
        cfg = _cfg(arch)
        gate = (T.chunked_prefill_unsupported if feature == "chunked"
                else T.speculative_unsupported)
        reason = gate(cfg)
        assert reason is not None and declared in reason, (
            f"declared-unsupported cell ({arch}, {feature}) is no longer "
            f"gated — move it to the supported matrix")
        # the gate fails eagerly, never silently
        if feature == "chunked":
            with pytest.raises(ValueError, match=declared):
                T.init_prefill_ring(cfg, 1, 16)
        return
    if feature == "chunked":
        _chunked_cell(arch)
    else:
        _speculative_cell(arch)


def test_docs_matrix_matches_gates():
    """The support-matrix table in docs/architecture.md is derived from
    the runtime gates — parse it back and diff it against what the gates
    actually say, so the docs cannot drift."""
    import pathlib

    doc = (pathlib.Path(__file__).resolve().parents[1] / "docs"
           / "architecture.md").read_text()
    rows = {}
    for line in doc.splitlines():
        cells = [c.strip() for c in line.strip().strip("|").split("|")]
        if len(cells) == 6 and cells[0] in ARCH_IDS:
            rows[cells[0]] = {"contiguous": cells[2], "paged": cells[3],
                              "chunked prefill": cells[4],
                              "speculative": cells[5]}
    assert set(rows) == set(ARCH_IDS), "table must list every config"
    for arch, got in rows.items():
        cfg = get_config(arch, "smoke")
        want = {
            "contiguous": "yes",
            "paged": "yes" if T.paged_unsupported(cfg) is None else "no",
            "chunked prefill": ("yes" if T.chunked_prefill_unsupported(cfg)
                                is None else "no"),
            "speculative": ("yes" if T.speculative_unsupported(cfg)
                            is None else "no"),
        }
        assert got == want, f"docs row for {arch} drifted: {got} != {want}"


def test_matrix_covers_every_config():
    """Every config module under src/repro/configs/ appears in the matrix
    — a new architecture cannot be added without earning its cells."""
    import pathlib

    import repro.configs as C
    mods = {p.stem for p in
            pathlib.Path(C.__file__).parent.glob("*.py")} - {"__init__"}
    ids = {a.replace("-", "_").replace(".", "_") for a in ARCH_IDS}
    assert mods == ids
