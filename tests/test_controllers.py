"""Exit controllers + early-exit generation semantics."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import policy_net
from repro.core.controller import make_controller
from repro.core.early_exit import generate
from repro.models import transformer as T


def test_none_controller_uses_all_layers(mini_cfg, mini_params):
    toks = jnp.zeros((2, 6), jnp.int32)
    out = generate(mini_params, mini_cfg, toks, 4,
                   make_controller("none"))
    assert (np.asarray(out["exit_layers"]) == mini_cfg.num_layers).all()


def test_fixed_controller_exits_at_boundary(mini_cfg, mini_params):
    segs = T.plan_segments(mini_cfg)
    toks = jnp.zeros((2, 6), jnp.int32)
    out = generate(mini_params, mini_cfg, toks, 4,
                   make_controller("fixed", exit_idx=0))
    el = np.asarray(out["exit_layers"])
    # first generated token comes from prefill (full depth); rest exit early
    assert (el[:, 0] == mini_cfg.num_layers).all()
    assert (el[:, 1:] == segs[0].end).all()


@pytest.mark.parametrize("kind", ["confidence", "entropy"])
def test_score_controllers_threshold_extremes(kind, mini_cfg, mini_params):
    toks = jax.random.randint(jax.random.PRNGKey(0), (2, 6), 0,
                              mini_cfg.vocab_size)
    # impossible threshold -> never exit
    tau = 1.01 if kind == "confidence" else -0.01
    ctrl = make_controller(kind, params=mini_params, cfg=mini_cfg,
                           threshold=tau)
    out = generate(mini_params, mini_cfg, toks, 3, ctrl)
    assert (np.asarray(out["exit_layers"]) == mini_cfg.num_layers).all()
    # trivial threshold -> always exit at the first boundary
    tau = -0.01 if kind == "confidence" else 1.01
    ctrl = make_controller(kind, params=mini_params, cfg=mini_cfg,
                           threshold=tau)
    out = generate(mini_params, mini_cfg, toks, 3, ctrl)
    segs = T.plan_segments(mini_cfg)
    assert (np.asarray(out["exit_layers"])[:, 1:] == segs[0].end).all()


def test_policy_controller_threshold_monotone(mini_cfg, mini_params):
    """Higher threshold T must never exit EARLIER (paper §VI-B)."""
    agent = policy_net.init_policy(jax.random.PRNGKey(3), mini_cfg.d_model)
    toks = jax.random.randint(jax.random.PRNGKey(1), (4, 8), 0,
                              mini_cfg.vocab_size)
    means = []
    for thr in (0.1, 0.5, 0.9, 0.999):
        ctrl = make_controller("policy", agent_params=agent, threshold=thr)
        out = generate(mini_params, mini_cfg, toks, 5, ctrl)
        means.append(float(np.asarray(out["exit_layers"]).mean()))
    assert all(b >= a - 1e-9 for a, b in zip(means, means[1:])), means


def test_confidence_kernel_path_matches_ref(mini_cfg, mini_params):
    """Controller via the fused Pallas exit_check == plain lm_logits path."""
    h = jax.random.normal(jax.random.PRNGKey(0), (4, mini_cfg.d_model))
    c_ref = make_controller("confidence", params=mini_params, cfg=mini_cfg,
                            threshold=0.5, use_kernel=False)
    c_ker = make_controller("confidence", params=mini_params, cfg=mini_cfg,
                            threshold=0.5, use_kernel=True)
    np.testing.assert_allclose(np.asarray(c_ref(h, 0)),
                               np.asarray(c_ker(h, 0)), atol=1e-5)


def test_generate_exit_layers_affect_energy(mini_cfg, trained_mini):
    from repro.core import energy
    params, _ = trained_mini
    toks = jax.random.randint(jax.random.PRNGKey(2), (2, 8), 0,
                              mini_cfg.vocab_size)
    out_full = generate(params, mini_cfg, toks, 5, make_controller("none"))
    out_fast = generate(params, mini_cfg, toks, 5,
                        make_controller("fixed", exit_idx=0))
    e_full = energy.summarize_exit_energy(
        mini_cfg, 16, np.asarray(out_full["exit_layers"]))
    e_fast = energy.summarize_exit_energy(
        mini_cfg, 16, np.asarray(out_fast["exit_layers"]))
    assert e_fast["mean_energy_j"] < e_full["mean_energy_j"]
