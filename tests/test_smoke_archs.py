"""Per-architecture smoke tests (deliverable f): every assigned arch at a
reduced config runs one forward + one LITE train step + one early-exit
decode step on CPU, asserting shapes and finiteness."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, ASSIGNED_ARCH_IDS, get_config
from repro.core.lite_loss import lite_loss
from repro.models import transformer as T
from repro.training.optimizer import adamw_init, adamw_update


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_constraints(arch):
    cfg = get_config(arch, "smoke")
    assert cfg.num_layers <= 2
    assert cfg.d_model <= 512
    if cfg.moe:
        assert cfg.moe.num_experts <= 4
    full = get_config(arch, "full")
    assert full.arch_type == cfg.arch_type


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_config(arch, "smoke")
    key = jax.random.PRNGKey(0)
    params = T.init_params(key, cfg)
    B, S = 2, 16
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    prefix = None
    if cfg.frontend:
        prefix = jax.random.normal(key, (B, 4, cfg.d_model))
    outs, aux = T.forward(params, cfg, toks, prefix)
    S_tot = S + (4 if prefix is not None else 0)
    logits = T.lm_logits(params, cfg, outs[-1])
    assert logits.shape == (B, S_tot, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())

    # one LITE train step
    labels = jax.random.randint(key, (B, S_tot), 0, cfg.vocab_size)

    def loss_fn(p):
        outs, aux = T.forward(p, cfg, toks, prefix)
        loss, _ = lite_loss(p, cfg, outs, labels)
        return loss + 0.01 * aux

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert bool(jnp.isfinite(loss))
    opt = adamw_init(params)
    new_params, _ = adamw_update(params, grads, opt, 1e-3)
    # params actually changed
    changed = any(
        bool((np.asarray(a) != np.asarray(b)).any())
        for a, b in zip(jax.tree.leaves(params),
                        jax.tree.leaves(new_params)))
    assert changed


@pytest.mark.parametrize("arch", ASSIGNED_ARCH_IDS)
def test_smoke_decode_step(arch):
    cfg = get_config(arch, "smoke")
    key = jax.random.PRNGKey(1)
    params = T.init_params(key, cfg)
    B, S0 = 2, 8
    toks = jax.random.randint(key, (B, S0), 0, cfg.vocab_size)
    prefix = None
    if cfg.frontend:
        prefix = jax.random.normal(key, (B, 4, cfg.d_model))
    h, caches, _ = T.prefill(params, cfg, toks, prefix, max_len=S0 + 8)
    total = h.shape[1]
    lg, caches, info = T.decode_step(
        params, cfg, jnp.zeros((B,), jnp.int32), caches,
        jnp.full((B,), total))
    assert lg.shape == (B, cfg.vocab_size)
    assert bool(jnp.isfinite(lg).all())
    assert info["exit_layer"].shape == (B,)
