"""Energy model invariants (hardware adaptation of the paper's §VI-A1).
Property tests run under hypothesis when installed, deterministic example
loops otherwise (see tests/_propcheck.py)."""
import numpy as np
import pytest
from _propcheck import given, settings, strategies as st

from repro.configs import get_config
from repro.core import energy


@pytest.fixture(scope="module")
def cfg():
    return get_config("llama32-3b", "full")


def test_energy_monotone_in_layers(cfg):
    e = [float(energy.decode_token_energy(cfg, 1024, l))
         for l in range(1, cfg.num_layers + 1)]
    assert all(b > a for a, b in zip(e, e[1:]))


def test_full_equals_last_layer(cfg):
    assert energy.full_token_energy(cfg, 1024) == pytest.approx(
        float(energy.decode_token_energy(cfg, 1024, cfg.num_layers)))


def test_skipped_layers_still_pay_kv(cfg):
    """Exit at layer 4 must cost MORE than 4/28 of the full model (KV
    propagation through the remaining 24 layers is still paid)."""
    e4 = float(energy.decode_token_energy(cfg, 1024, 4))
    e_full = energy.full_token_energy(cfg, 1024)
    assert e4 > e_full * 4 / 28 * 0.9
    assert e4 < e_full


def test_energy_grows_with_context(cfg):
    e1 = energy.full_token_energy(cfg, 512)
    e2 = energy.full_token_energy(cfg, 8192)
    assert e2 > e1


def test_moe_uses_active_params():
    moe = get_config("qwen2-moe-a2.7b", "full")
    assert moe.active_param_count() < moe.param_count() * 0.5


@given(st.integers(min_value=1, max_value=28),
       st.integers(min_value=16, max_value=4096))
@settings(max_examples=20, deadline=None)
def test_energy_positive_and_bounded(l, ctx):
    cfg = get_config("llama32-3b", "full")
    e = float(energy.decode_token_energy(cfg, ctx, l))
    assert 0 < e < energy.full_token_energy(cfg, ctx) + 1e-9


def test_summary_saving_fraction(cfg):
    exits = np.full(100, 4)
    s = energy.summarize_exit_energy(cfg, 1024, exits)
    assert 0.0 < s["energy_saving_frac"] < 1.0
    assert s["mean_layers_used"] == 4.0
    full = energy.summarize_exit_energy(cfg, 1024,
                                        np.full(10, cfg.num_layers))
    assert full["energy_saving_frac"] == pytest.approx(0.0)


def test_controller_overhead_below_paper_bound(cfg):
    """Paper §VI-H: agent overhead stays under ~1/5 of total runtime."""
    n_checks = 9
    over = float(energy.controller_overhead_energy(cfg, n_checks))
    full = energy.full_token_energy(cfg, 1024)
    assert over / full < 0.2
