"""Chunked prefill: bit-identical to whole-prompt prefill (tokens, exits,
logprobs) for arbitrary prompt lengths x chunk sizes x KV layouts, one
compiled prefill shape for all prompt lengths, decode-interleaved admission.

The "whole-prompt" arm is the same compiled chunk step with a chunk that
covers the entire prompt in one pass — every reduction in the chunk step
runs at the fixed ring length, which is what makes the result invariant to
the chunk split (the transformer-level test pins this at the K/V level).
Parity of the chunked scheduler against the legacy ``prefill``-based stack
is held at token level by tests/test_scheduler.py's engine-parity test.
"""
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

sys.path.insert(0, str(Path(__file__).resolve().parent))
from _propcheck import given, settings, strategies as st  # noqa: E402

from repro.api import GenerationRequest, SamplingParams  # noqa: E402
from repro.configs.llama32_3b import paper_mini  # noqa: E402
from repro.models import transformer as T  # noqa: E402
from repro.serving import Scheduler  # noqa: E402

MAX_LEN = 48
MAX_NEW = 6
BLOCK = 8
CHUNKS = (5, MAX_LEN)          # 5: misaligned splits; MAX_LEN: one chunk
MAX_PLEN = MAX_LEN - MAX_NEW - 2

_STATE: dict = {}


def _arms():
    """Lazily built (layout, chunk) scheduler grid shared by the property
    tests (module-level, not a fixture: the hypothesis fallback shim
    cannot inject fixtures into @given tests)."""
    if not _STATE:
        cfg = paper_mini(num_layers=4, d_model=64, vocab_size=256)
        params = T.init_params(jax.random.PRNGKey(0), cfg)
        arms = {}
        for layout in ("contiguous", "paged"):
            for chunk in CHUNKS:
                kw = dict(kv_layout="paged", block_size=BLOCK) \
                    if layout == "paged" else {}
                arms[(layout, chunk)] = Scheduler(
                    params, cfg, controller_kind="fixed", fixed_exit_idx=0,
                    allowed_kinds=("none", "fixed"), max_slots=3,
                    max_len=MAX_LEN, max_new=MAX_NEW, queue_depth=32,
                    prefill_chunk=chunk, **kw).start()
        _STATE.update(cfg=cfg, params=params, arms=arms)
    return _STATE


@pytest.fixture(scope="module", autouse=True)
def _teardown_arms():
    yield
    for s in _STATE.get("arms", {}).values():
        s.stop()


def _prompt(plen: int, seed: int) -> list:
    rng = np.random.default_rng(seed)
    return rng.integers(4, 256, plen).tolist()


def _run(sched, prompt, seed):
    sampled = seed % 2 == 1
    req = GenerationRequest(
        prompt=prompt, max_new_tokens=MAX_NEW,
        policy=("fixed" if seed % 3 else "none"),
        sampling=(SamplingParams(temperature=0.8, top_k=12, seed=seed)
                  if sampled else SamplingParams()))
    r = sched.submit(req).result(120.0)
    return r.tokens, r.exit_layers, list(r.logprobs)


# ---------------------------------------------------------------------------
# transformer level: the chunk step is split-invariant bit-for-bit
# ---------------------------------------------------------------------------
def test_prefill_chunk_split_invariant_bitwise():
    """Any chunking of a prompt — including one whole-prompt chunk —
    produces bit-identical ring K/V, positions and logits: reductions all
    run at the fixed ring length, and dot-generals are exact under zero
    padding."""
    st_ = _arms()
    cfg, params = st_["cfg"], st_["params"]
    S, W = 23, MAX_LEN
    toks = np.asarray(_prompt(S, 0), np.int32)

    def run(C):
        ring = T.init_prefill_ring(cfg, 1, W)
        last = None
        for pos0 in range(0, S, C):
            grid = toks[pos0:pos0 + C]
            if len(grid) < C:
                grid = np.pad(grid, (0, C - len(grid)))
            lg, ring = T.prefill_chunk(params, cfg, jnp.asarray(grid[None]),
                                       ring, jnp.asarray([pos0]),
                                       jnp.asarray([S]))
            if pos0 + C >= S:
                last = np.asarray(lg[:, (S - 1) - pos0])
        return last, ring

    ref_log, ref_ring = run(S)                      # whole prompt, 1 chunk
    for C in (3, 7, 16):
        lg, ring = run(C)
        np.testing.assert_array_equal(ref_log, lg)
        for a, b in zip(jax.tree.leaves(ref_ring), jax.tree.leaves(ring)):
            aa, bb = np.asarray(a), np.asarray(b)
            if aa.dtype == np.int32:                # pos plane: exact
                np.testing.assert_array_equal(aa, bb)
            else:                                   # K/V: only positions < S
                w_ax = aa.ndim - 3                  # [..., W, KH, hd]
                np.testing.assert_array_equal(
                    np.take(aa, range(S), axis=w_ax),
                    np.take(bb, range(S), axis=w_ax))


# ---------------------------------------------------------------------------
# property: chunked == whole-prompt, across layouts, arbitrary lengths
# ---------------------------------------------------------------------------
@given(st.integers(min_value=1, max_value=MAX_PLEN),
       st.integers(min_value=0, max_value=10_000))
@settings(max_examples=8, deadline=None, derandomize=True)
def test_chunked_prefill_matches_whole_prompt(plen, seed):
    """Serving the same request through a chunk-5 and a one-chunk
    (whole-prompt) scheduler, on both KV layouts, yields bit-identical
    tokens, exit layers AND logprobs — greedy and sampled rows alike."""
    arms = _arms()["arms"]
    prompt = _prompt(plen, seed)
    results = {key: _run(s, prompt, seed) for key, s in arms.items()}
    ref = results[("contiguous", MAX_LEN)]          # whole-prompt arm
    assert len(ref[0]) >= 1
    for key, got in results.items():
        assert got[0] == ref[0], f"tokens diverged on {key}"
        assert got[1] == ref[1], f"exit layers diverged on {key}"
        assert got[2] == ref[2], f"logprobs diverged on {key}"


def test_mid_flight_admission_interleaves_and_stays_identical():
    """A request whose prompt chunks interleave with a decoding row's
    ticks produces exactly its solo output — and so does the row it
    interleaved with (both layouts, chunked admission)."""
    arms = _arms()["arms"]
    a = _prompt(30, 7)
    b = _prompt(23, 8)                 # 5 chunks at chunk=5
    for layout in ("contiguous", "paged"):
        s = arms[(layout, 5)]
        solo_a = s.serve_batch([a], max_new=10)
        solo_b = s.serve_batch([b], max_new=MAX_NEW)
        ha = s.submit(a, max_new=10)
        it = ha.stream(timeout=60.0)
        next(it), next(it)             # A mid-decode when B's chunks start
        hb = s.submit(b, max_new=MAX_NEW)
        ha.result(60.0), hb.result(60.0)
        assert hb.started_at < ha.finished_at, "B never overlapped A"
        assert ha.tokens == solo_a.tokens[0]
        assert ha.exit_layers == solo_a.exit_layers[0]
        assert hb.tokens == solo_b.tokens[0]
        assert hb.exit_layers == solo_b.exit_layers[0]


# ---------------------------------------------------------------------------
# one compiled shape for the whole admission path
# ---------------------------------------------------------------------------
def test_many_prompt_lengths_one_prefill_shape_one_decode_shape():
    """A mixed batch of 10+ distinct prompt lengths must compile exactly
    ONE prefill-chunk shape and ONE decode shape (extends the PR-2
    no-recompile assert to the admission path — this is what deleted the
    prefill_buckets knob)."""
    st_ = _arms()
    s = Scheduler(st_["params"], st_["cfg"], controller_kind="fixed",
                  fixed_exit_idx=0, allowed_kinds=("none", "fixed"),
                  max_slots=3, max_len=MAX_LEN, max_new=4, queue_depth=32,
                  prefill_chunk=5).start()
    try:
        lens = list(range(7, 18)) + [27, 33]       # 13 distinct lengths
        reqs = [_prompt(n, 100 + n) for n in lens]
        res = s.serve_batch(reqs, max_new=4)
        assert all(len(t) >= 1 for t in res.tokens)
        assert s.step_compiles == 1, \
            f"decode recompiled {s.step_compiles}x across prompt lengths"
        assert s.prefill_compiles == 1, \
            f"prefill compiled {s.prefill_compiles} shapes (want 1 chunk)"
        stats = s.stats()
        assert stats["chunked_prefill"] is True
        assert stats["prefill_compiles"] == 1
        assert stats["fleet_prefill_energy_j"] > 0
    finally:
        s.stop()


def test_prefill_energy_charged_per_request():
    """Chunk FLOPs are charged through core.energy: a longer prompt pays
    more prefill joules, and the fleet counter sees them."""
    arms = _arms()["arms"]
    s = arms[("contiguous", 5)]
    before = s.stats()["fleet_prefill_energy_j"]
    h_short = s.submit(_prompt(6, 40), max_new=2).result(60.0)
    h_long = s.submit(_prompt(36, 41), max_new=2).result(60.0)
    assert 0 < h_short.prefill_energy_j < h_long.prefill_energy_j
    assert s.stats()["fleet_prefill_energy_j"] >= (
        before + h_short.prefill_energy_j + h_long.prefill_energy_j)


def test_chunked_prefill_unsupported_falls_back():
    """Configs whose prefill cannot chunk (frontend conditioning here) keep
    the whole-prompt admission path and still serve."""
    from repro.configs.musicgen_medium import smoke as musicgen_smoke
    cfg = musicgen_smoke()
    reason = T.chunked_prefill_unsupported(cfg)
    assert reason is not None and "frontend" in reason
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    s = Scheduler(params, cfg, max_slots=2, max_len=48, max_new=3,
                  queue_depth=8).start()
    try:
        assert not s.chunked
        r = s.serve_batch([_prompt(9, 50)], max_new=3)
        assert len(r.tokens[0]) >= 1
    finally:
        s.stop()
    # fallback configs still compile per prompt length, so the bucketing
    # knob keeps working there (no deprecation warning, prompts padded)
    import warnings as _w
    with _w.catch_warnings():
        _w.simplefilter("error", DeprecationWarning)
        s2 = Scheduler(params, cfg, max_slots=2, max_len=48, max_new=3,
                       prefill_buckets=(16, 32))
    h = s2.submit(_prompt(9, 51), max_new=3)
    assert len(h.prompt) == 16 and h.prompt[0] == s2.pad_id
