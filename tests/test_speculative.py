"""Self-speculative decoding: greedy bit-parity with the non-speculative
baseline (offline + under the scheduler, both KV layouts, incl. a
mid-flight admission), acceptance-rule unit tests, paged rollback
invariants, energy split, and verify-kernel parity with the scan path."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from _propcheck import given, settings, strategies as st
from repro.api import PolicySpec, SamplingParams
from repro.core import energy
from repro.core.early_exit import generate
from repro.core.speculative import (accept_drafts, draft_boundary_layer,
                                    speculative_generate)
from repro.models import transformer as T
from repro.serving import Engine, PagedKVPool, Scheduler


def _prompts(vocab, lens, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(4, vocab, n).tolist() for n in lens]


SPEC = PolicySpec("speculative", {"draft_idx": 0, "window": 3})


@pytest.fixture(scope="module")
def sched_pair(mini_cfg, mini_params):
    """One scheduler per KV layout, with none/fixed/speculative compiled."""
    scheds = {}
    for layout in ("contiguous", "paged"):
        scheds[layout] = Scheduler(
            mini_params, mini_cfg, default_policy=PolicySpec("none"),
            allowed_kinds=("none", "fixed", "speculative"),
            max_slots=3, max_len=64, max_new=10, queue_depth=16,
            kv_layout=layout, block_size=8, spec_window=3).start()
    yield scheds
    for s in scheds.values():
        s.stop()


# ---------------------------------------------------------------------------
# offline draft-then-verify loop
# ---------------------------------------------------------------------------
def test_offline_greedy_bit_identical_both_layouts(mini_cfg, mini_params):
    rng = np.random.default_rng(3)
    prompt = jnp.asarray(rng.integers(4, mini_cfg.vocab_size, (2, 14)),
                         jnp.int32)
    base = generate(mini_params, mini_cfg, prompt, 10)
    spec = speculative_generate(mini_params, mini_cfg, prompt, 10,
                                draft_idx=0, window=3)
    np.testing.assert_array_equal(np.asarray(base["tokens"]),
                                  np.asarray(spec["tokens"]))
    np.testing.assert_allclose(np.asarray(base["logprobs"]),
                               np.asarray(spec["logprobs"]), atol=1e-5)
    assert spec["n_verifies"] >= 1
    assert (np.asarray(spec["exit_layers"]) == mini_cfg.num_layers).all()
    paged = speculative_generate(mini_params, mini_cfg, prompt, 10,
                                 draft_idx=0, window=3, kv_block_size=8)
    np.testing.assert_array_equal(np.asarray(base["tokens"]),
                                  np.asarray(paged["tokens"]))


def test_offline_kernel_path_matches_scan_path(mini_cfg, mini_params):
    """use_kernel flips verification to the window-parallel Pallas kernel;
    tokens still match the baseline (flash order, same math)."""
    rng = np.random.default_rng(5)
    prompt = jnp.asarray(rng.integers(4, mini_cfg.vocab_size, (2, 11)),
                         jnp.int32)
    base = generate(mini_params, mini_cfg, prompt, 8)
    spec = speculative_generate(mini_params, mini_cfg, prompt, 8,
                                draft_idx=0, window=3, kv_block_size=8,
                                use_kernel=True)
    np.testing.assert_array_equal(np.asarray(base["tokens"]),
                                  np.asarray(spec["tokens"]))


def test_offline_sampled_is_deterministic_and_batch_independent(
        mini_cfg, mini_params):
    rng = np.random.default_rng(9)
    prompts = rng.integers(4, mini_cfg.vocab_size, (2, 12))
    kw = dict(draft_idx=0, window=3, sampling=SamplingParams(
        temperature=0.9, top_k=40), seeds=np.array([7, 8]))
    a = speculative_generate(mini_params, mini_cfg, jnp.asarray(prompts),
                             8, **kw)
    b = speculative_generate(mini_params, mini_cfg, jnp.asarray(prompts),
                             8, **kw)
    np.testing.assert_array_equal(np.asarray(a["tokens"]),
                                  np.asarray(b["tokens"]))
    solo = speculative_generate(
        mini_params, mini_cfg, jnp.asarray(prompts[:1]), 8, draft_idx=0,
        window=3, sampling=SamplingParams(temperature=0.9, top_k=40),
        seeds=np.array([7]))
    np.testing.assert_array_equal(np.asarray(a["tokens"])[0],
                                  np.asarray(solo["tokens"])[0])


def test_speculative_unsupported_configs_fail_eagerly(mini_params):
    from repro.configs.musicgen_medium import smoke as musicgen_smoke
    cfg = musicgen_smoke()
    assert "frontend" in T.speculative_unsupported(cfg)
    with pytest.raises(ValueError, match="frontend"):
        speculative_generate(mini_params, cfg,
                             jnp.zeros((1, 4), jnp.int32), 2)


def test_scheduler_rejects_speculative_for_unsupported_cfg():
    from repro.configs.musicgen_medium import smoke as musicgen_smoke
    cfg = musicgen_smoke()
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    with pytest.raises(ValueError, match="speculative"):
        Scheduler(params, cfg, allowed_kinds=("none", "speculative"),
                  max_slots=2, max_len=32)
    # the refusal is an explicitly-declared unsupported cell, not a crash:
    # a non-speculative scheduler on the same config records the reason
    s = Scheduler(params, cfg, max_slots=2, max_len=32)
    fb = s.stats()["fallbacks"]
    assert "frontend" in fb["speculative"]["reason"]
    assert fb["speculative"]["count"] == 0


# ---------------------------------------------------------------------------
# property: greedy speculative == non-speculative, both layouts
# ---------------------------------------------------------------------------
_PROP_STATE: dict = {}


def _prop_model():
    """A 6-layer mini (one real intermediate exit) built once per session —
    the property decorators cannot consume pytest fixtures under the
    hypothesis-less fallback."""
    if not _PROP_STATE:
        from repro.configs.llama32_3b import paper_mini
        cfg = paper_mini(num_layers=6, d_model=64, vocab_size=256)
        _PROP_STATE["cfg"] = cfg
        _PROP_STATE["params"] = T.init_params(jax.random.PRNGKey(0), cfg)
    return _PROP_STATE["cfg"], _PROP_STATE["params"]


@given(st.integers(min_value=0, max_value=2 ** 20),
       st.integers(min_value=1, max_value=3))
@settings(max_examples=5, deadline=None)
def test_property_greedy_spec_bit_identical(seed, window):
    cfg, params = _prop_model()
    rng = np.random.default_rng(seed)
    prompt = jnp.asarray(rng.integers(4, cfg.vocab_size, (2, 10)),
                         jnp.int32)
    base = generate(params, cfg, prompt, 8)
    for kvb in (None, 8):                     # contiguous and paged
        spec = speculative_generate(params, cfg, prompt, 8, draft_idx=0,
                                    window=window, kv_block_size=kvb)
        np.testing.assert_array_equal(np.asarray(base["tokens"]),
                                      np.asarray(spec["tokens"]))


def test_scheduler_greedy_spec_bit_identical(sched_pair, mini_cfg):
    for seed in (0, 7, 19):
        prompts = _prompts(mini_cfg.vocab_size, [8, 14], seed=seed)
        for layout, sched in sched_pair.items():
            for prompt in prompts:
                base = sched.submit(prompt, max_new=8, policy="none")
                base.result(180.0)
                spec = sched.submit(prompt, max_new=8, policy=SPEC)
                spec.result(180.0)
                assert spec.tokens == base.tokens, (layout, seed)
                assert spec.finish_reason == base.finish_reason
                # verified tokens are full-depth; the energy split is
                # charged through the speculative model instead
                assert all(e == mini_cfg.num_layers
                           for e in spec.exit_layers)
                assert spec.spec_verifies >= 1


def test_scheduler_spec_snapshot_configs_bit_identical():
    """Speculative serving on architectures whose rollback cannot be a
    ``pos``-mask rewind — recurrent SSM state (mamba2) and sliding-window
    rings that evict what a draft overwrote (gemma2) — runs the
    snapshot/restore/commit protocol. Greedy spec tokens must still match
    plain decode bit-for-bit, solo and in a mixed spec+none batch."""
    import dataclasses

    from repro.configs import get_config
    for arch in ("mamba2-1.3b", "gemma2-9b"):
        cfg = get_config(arch, "smoke")
        if arch == "gemma2-9b":
            # window below the prompt length so drafts really overwrite
            # evicted entries and the snapshot is load-bearing
            cfg = dataclasses.replace(cfg, sliding_window=8)
        params = T.init_params(jax.random.PRNGKey(0), cfg)
        sched = Scheduler(params, cfg, default_policy=PolicySpec("none"),
                          allowed_kinds=("none", "speculative"),
                          max_slots=2, max_len=48, max_new=8,
                          queue_depth=8, kv_layout="contiguous",
                          spec_window=3).start()
        try:
            prompts = _prompts(cfg.vocab_size, [12, 9], seed=11)
            base, spec = [], []
            for p in prompts:
                h = sched.submit(p, max_new=8, policy="none")
                h.result(180.0)
                base.append(h)
            for p in prompts:
                h = sched.submit(p, max_new=8, policy=SPEC)
                h.result(180.0)
                spec.append(h)
            for hb, hs in zip(base, spec):
                assert hs.tokens == hb.tokens, arch
                assert hs.spec_verifies >= 1, arch
            # mixed batch: a non-spec row rides the super-tick with its
            # cache blended through the identity rows of the commit
            ha = sched.submit(prompts[0], max_new=8, policy=SPEC)
            hb = sched.submit(prompts[1], max_new=8, policy="none")
            ha.result(180.0), hb.result(180.0)
            assert ha.tokens == base[0].tokens, arch
            assert hb.tokens == base[1].tokens, arch
        finally:
            sched.stop()


def test_mid_flight_spec_admission_is_byte_identical(sched_pair, mini_cfg):
    """A speculative request joining a running speculative batch matches
    its solo run (and therefore the non-speculative baseline) exactly."""
    a, b = _prompts(mini_cfg.vocab_size, [20, 14], seed=21)
    for layout, sched in sched_pair.items():
        base = sched.submit(b, max_new=8, policy="none")
        base.result(180.0)
        ha = sched.submit(a, max_new=16, policy=SPEC)
        it = ha.stream(timeout=120.0)
        for _ in range(3):
            next(it)                    # A is mid-decode...
        hb = sched.submit(b, max_new=8, policy=SPEC)
        ha.result(180.0), hb.result(180.0)
        assert hb.started_at < ha.finished_at, "B never overlapped A"
        assert hb.tokens == base.tokens, layout


def test_spec_mixes_with_other_policies_per_row(sched_pair, mini_cfg):
    """speculative + fixed + none share one batch; every row matches its
    solo run and the step never recompiles."""
    p = _prompts(mini_cfg.vocab_size, [16, 12, 9], seed=4)
    for layout, sched in sched_pair.items():
        solos = [sched.submit(p[0], max_new=6, policy=SPEC),
                 sched.submit(p[1], max_new=6, policy="fixed"),
                 sched.submit(p[2], max_new=6, policy="none")]
        for h in solos:
            h.result(180.0)
        mixed = [sched.submit(p[0], max_new=6, policy=SPEC),
                 sched.submit(p[1], max_new=6, policy="fixed"),
                 sched.submit(p[2], max_new=6, policy="none")]
        for h in mixed:
            h.result(180.0)
        for solo, mix in zip(solos, mixed):
            assert mix.tokens == solo.tokens, layout
        assert sched.step_compiles == 1


def test_scheduler_sampled_spec_join_matches_solo(sched_pair, mini_cfg):
    """Rejection sampling is keyed by (seed, position): a sampled
    speculative request reproduces its solo run when joining mid-flight."""
    a, b = _prompts(mini_cfg.vocab_size, [15, 11], seed=31)
    samp = SamplingParams(temperature=0.8, top_k=50, seed=123)
    for layout, sched in sched_pair.items():
        solo = sched.submit(b, max_new=8, policy=SPEC, sampling=samp)
        solo.result(180.0)
        ha = sched.submit(a, max_new=14, policy=SPEC)
        it = ha.stream(timeout=120.0)
        for _ in range(2):
            next(it)
        hb = sched.submit(b, max_new=8, policy=SPEC, sampling=samp)
        ha.result(180.0), hb.result(180.0)
        assert hb.tokens == solo.tokens, layout


def test_spec_stats_and_energy_split(sched_pair, mini_cfg):
    sched = sched_pair["paged"]
    h = sched.submit(_prompts(mini_cfg.vocab_size, [12], seed=8)[0],
                     max_new=8, policy=SPEC)
    h.result(180.0)
    st = sched.stats()
    assert st["spec_window"] == 3
    assert st["spec_verifies"] >= h.spec_verifies >= 1
    assert 0.0 <= st["acceptance_rate"] <= 1.0
    assert st["tokens_per_verify"] >= 1.0
    # the speculative energy model charges draft + verify separately; the
    # fused verify window costs more than one full-depth step but far
    # less than scoring its positions sequentially (bandwidth-bound)
    dl = draft_boundary_layer(mini_cfg, 0)
    e = energy.speculative_step_energy(mini_cfg, 12, dl, 3, 4)
    assert e["draft_j"] > 0 and e["verify_j"] > 0
    assert e["total_j"] == pytest.approx(e["draft_j"] + e["verify_j"])
    full = energy.full_token_energy(mini_cfg, 12)
    assert full <= e["verify_j"] < 4 * full
    assert e["draft_j"] == pytest.approx(
        3 * energy.draft_token_energy(mini_cfg, 12, dl))
    assert h.energy_j > 0


# ---------------------------------------------------------------------------
# acceptance rule
# ---------------------------------------------------------------------------
def _logits_for(chain, V=32, peak=8.0):
    """[K+1, V] logits whose argmax follows ``chain``."""
    out = np.zeros((len(chain), V), np.float32)
    for j, t in enumerate(chain):
        out[j, t] = peak
    return out


def test_accept_greedy_prefix_and_correction():
    tl = _logits_for([5, 6, 9, 4])[None]          # argmax chain
    drafts = np.array([[5, 6, 7]])                # third draft mismatches
    n, nxt, lp = accept_drafts(drafts, tl, windows=3)
    assert n[0] == 2 and nxt[0] == 9
    assert lp[0, :3].shape == (3,)
    # all accepted -> bonus token from the last window position
    n, nxt, _ = accept_drafts(np.array([[5, 6, 9]]), tl, windows=3)
    assert n[0] == 3 and nxt[0] == 4
    # window caps acceptance even when every draft matches
    n, nxt, _ = accept_drafts(np.array([[5, 6, 9]]), tl, windows=1)
    assert n[0] == 1 and nxt[0] == 6


def test_accept_greedy_lenient_threshold():
    tl = _logits_for([5, 6, 9])[None].copy()
    tl[0, 0, 7] = 7.5                             # near-argmax alternative
    drafts = np.array([[7, 6]])
    n, _, _ = accept_drafts(drafts, tl, windows=2)
    assert n[0] == 0                              # exact mode rejects
    n, _, _ = accept_drafts(drafts, tl, windows=2, accept_threshold=0.2)
    assert n[0] == 2                              # lenient mode accepts


def test_accept_rejection_sampling_limits():
    V = 16
    tl = np.zeros((1, 3, V), np.float32)
    tl[0, :, 3] = 50.0                            # target mass on 3
    dl = np.zeros((1, 2, V), np.float32)
    # draft distribution == target distribution -> ratio 1, always accept
    dl[0, :, 3] = 50.0
    drafts = np.array([[3, 3]])
    n, nxt, _ = accept_drafts(drafts, tl, windows=2, temperature=1.0,
                              seeds=[5], pos0=[10], draft_logits=dl)
    assert n[0] == 2
    # draft token carries ~zero target mass -> reject, residual ~= target
    dl2 = np.zeros((1, 2, V), np.float32)
    dl2[0, :, 9] = 50.0
    n, nxt, _ = accept_drafts(np.array([[9, 9]]), tl, windows=2,
                              temperature=1.0, seeds=[5], pos0=[10],
                              draft_logits=dl2)
    assert n[0] == 0 and nxt[0] == 3
    with pytest.raises(ValueError, match="draft_logits"):
        accept_drafts(drafts, tl, windows=2, temperature=1.0)


def test_accept_is_deterministic():
    rng = np.random.default_rng(0)
    tl = rng.normal(size=(2, 4, 24)).astype(np.float32)
    dl = rng.normal(size=(2, 3, 24)).astype(np.float32)
    drafts = rng.integers(0, 24, (2, 3))
    kw = dict(windows=[3, 2], temperature=[0.0, 1.2], seeds=[1, 2],
              pos0=[4, 9], draft_logits=dl)
    a = accept_drafts(drafts, tl, **kw)
    b = accept_drafts(drafts, tl, **kw)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x, y)


# ---------------------------------------------------------------------------
# paged rollback
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def small_cfg():
    from repro.configs.llama32_3b import paper_mini
    return paper_mini(num_layers=4, d_model=64, vocab_size=256)


def test_rollback_append_restores_allocator_state(small_cfg):
    pool = PagedKVPool(small_cfg, max_slots=2, max_len=32, block_size=4,
                       num_blocks=16)
    pool._writer = lambda c, *a, **k: c        # accounting-only test
    pool._copier = lambda c, *a, **k: c
    s = pool.alloc()
    pool.write_prompt(s, list(range(6)), None, max_new=4)
    in_use0 = pool.blocks.n_in_use
    reserved0 = int(pool._reserved[s])
    tables0 = pool.tables[s].copy()
    nb0 = int(pool._n_blocks[s])
    for pos in range(6, 6 + 6):                # draft overrun: 2 new blocks
        pool.prepare_append(s, pos)
    assert pool.blocks.n_in_use > in_use0
    pool.rollback_append(s, keep_tokens=6)     # reject everything
    assert pool.blocks.n_in_use == in_use0
    assert int(pool._reserved[s]) == reserved0
    assert int(pool._n_blocks[s]) == nb0
    np.testing.assert_array_equal(pool.tables[s], tables0)
    refs = [pool.blocks.refcount(int(b)) for b in tables0[:nb0]]
    assert refs == [1, 1]
    pool.release(s)
    assert pool.blocks.n_in_use == 0 and pool.reserved_blocks == 0


def test_rollback_after_cow_keeps_refcounts_consistent(small_cfg):
    """A draft that COWs a shared tail and then fully rejects must leave
    the sharer's block intact, the COW copy exclusively owned, and no
    refcount drift (no COW leaks)."""
    pool = PagedKVPool(small_cfg, max_slots=2, max_len=32, block_size=4,
                       num_blocks=16)
    pool._writer = lambda c, *a, **k: c
    pool._copier = lambda c, *a, **k: c
    sa = pool.alloc()
    pool.write_prompt(sa, list(range(6)), None, max_new=6)
    sb = pool.alloc()
    pool.write_prompt(sb, list(range(6)), None, max_new=6)  # shares tail
    tail = int(pool.tables[sb, 1])
    assert pool.blocks.refcount(tail) == 2
    in_use0 = pool.blocks.n_in_use
    for pos in range(6, 12):                   # B drafts: COW + growth
        pool.prepare_append(sb, pos)
    assert pool.cow_copies == 1
    pool.rollback_append(sb, keep_tokens=6)    # everything rejected
    # A's tail untouched; B owns its COW copy alone; growth blocks freed
    assert pool.blocks.refcount(tail) == 1
    new_tail = int(pool.tables[sb, 1])
    assert new_tail != tail and pool.blocks.refcount(new_tail) == 1
    assert pool.blocks.n_in_use == in_use0 + 1  # only the COW copy remains
    pool.release(sa)
    pool.release(sb)
    assert pool.blocks.n_in_use == 0 and pool.reserved_blocks == 0


def test_spec_traffic_releases_all_blocks(sched_pair, mini_cfg):
    sched = sched_pair["paged"]
    handles = [sched.submit(p, max_new=8, policy=SPEC)
               for p in _prompts(mini_cfg.vocab_size, [9, 13, 17, 11],
                                 seed=40)]
    for h in handles:
        h.result(180.0)
    st = sched.stats()
    assert st["blocks_in_use"] == 0
    assert st["blocks_reserved"] == 0
    refs = sched.pool.blocks._refcount
    assert int(refs[1:].sum()) == 0            # only scratch block pinned


# ---------------------------------------------------------------------------
# verify step: kernel vs scan parity on the full model
# ---------------------------------------------------------------------------
def test_verify_step_kernel_matches_scan(mini_cfg, mini_params):
    from repro.models.transformer import (init_paged_cache, prefill,
                                          ring_to_paged, verify_step)
    rng = np.random.default_rng(11)
    B, S0, S = 2, 8, 4
    bs = 8
    prompt = jnp.asarray(rng.integers(4, mini_cfg.vocab_size, (B, S0)),
                         jnp.int32)
    _, caches, _ = prefill(mini_params, mini_cfg, prompt, max_len=32)
    caches, tables = ring_to_paged(mini_cfg, caches, bs)
    win = jnp.asarray(rng.integers(4, mini_cfg.vocab_size, (B, S)),
                      jnp.int32)
    pos0 = jnp.full((B,), S0, jnp.int32)
    l_ref, c_ref = verify_step(mini_params, mini_cfg, win, caches, pos0,
                               block_tables=tables, use_kernel=False)
    l_ker, c_ker = verify_step(mini_params, mini_cfg, win, caches, pos0,
                               block_tables=tables, use_kernel=True)
    np.testing.assert_allclose(np.asarray(l_ref), np.asarray(l_ker),
                               atol=2e-4, rtol=2e-4)
    for a, b in zip(jax.tree.leaves(c_ref), jax.tree.leaves(c_ker)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=2e-4, rtol=2e-4)
    del init_paged_cache


def test_verify_write_mask_blocks_all_writes(mini_cfg, mini_params):
    """Masked rows ride through verify with bit-unchanged caches (the
    invariant that protects non-speculative residents)."""
    from repro.models.transformer import prefill, rewind_ring, verify_step
    rng = np.random.default_rng(13)
    B, S0 = 2, 8
    prompt = jnp.asarray(rng.integers(4, mini_cfg.vocab_size, (B, S0)),
                         jnp.int32)
    _, caches, _ = prefill(mini_params, mini_cfg, prompt, max_len=24)
    win = jnp.asarray(rng.integers(4, mini_cfg.vocab_size, (B, 3)),
                      jnp.int32)
    pos0 = jnp.full((B,), S0, jnp.int32)
    mask = jnp.asarray([True, False])
    _, new_caches = verify_step(mini_params, mini_cfg, win, caches, pos0,
                                write_mask=mask)
    for a, b in zip(jax.tree.leaves(caches), jax.tree.leaves(new_caches)):
        a, b = np.asarray(a), np.asarray(b)
        # row 1 (masked) must be bit-identical; row 0 must have changed
        batch_ax = 1 if a.ndim >= 3 and a.shape[0] != B else 0
        np.testing.assert_array_equal(np.take(a, 1, axis=batch_ax),
                                      np.take(b, 1, axis=batch_ax))
    changed = any(
        not np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(caches), jax.tree.leaves(new_caches)))
    assert changed
    del rewind_ring


def test_verify_kernel_learned_positions(mini_params):
    """Regression: the window-parallel kernel path must embed window token
    j at position pos0 + j — learned-positional configs (OPT family) get
    per-window-offset embeddings, not S copies of pos0's."""
    from repro.configs.opt_2_7b import paper_mini as opt_mini
    from repro.core.speculative import speculative_generate
    cfg = opt_mini(num_layers=6, d_model=64, vocab_size=256)
    params = T.init_params(jax.random.PRNGKey(2), cfg)
    rng = np.random.default_rng(23)
    prompt = jnp.asarray(rng.integers(4, 256, (2, 10)), jnp.int32)
    base = generate(params, cfg, prompt, 8)
    spec = speculative_generate(params, cfg, prompt, 8, draft_idx=0,
                                window=3, kv_block_size=8, use_kernel=True)
    np.testing.assert_array_equal(np.asarray(base["tokens"]),
                                  np.asarray(spec["tokens"]))
    del mini_params


# ---------------------------------------------------------------------------
# engine integration
# ---------------------------------------------------------------------------
def test_engine_speculative_matches_plain(mini_cfg, mini_params):
    from repro.api import GenerationRequest
    eng = Engine(mini_params, mini_cfg, max_new=8)
    prompts = _prompts(mini_cfg.vocab_size, [12, 12], seed=17)
    base = eng.serve(prompts, max_new=8)
    spec = eng.serve(prompts, max_new=8, policy=SPEC)
    assert spec.tokens == base.tokens
    # mixed speculative / plain requests partition and keep order + ids
    reqs = [GenerationRequest(prompt=prompts[0], max_new_tokens=8,
                              policy=SPEC),
            GenerationRequest(prompt=prompts[1], max_new_tokens=8,
                              policy=PolicySpec("none"))]
    res = eng.serve_requests(reqs)
    assert [r.request_id for r in res] == [0, 1]
    assert res[0].tokens == base.tokens[0]
    assert res[1].tokens == base.tokens[1]
    # the speculative row carries draft+verify energy, not the exit-layer
    # model's full-depth-per-token number the plain row reports
    full_e = energy.full_token_energy(mini_cfg, 12)
    assert res[0].energy_j == pytest.approx(res[0].metrics.energy_j)
    assert res[0].energy_j != pytest.approx(full_e * len(res[0].tokens))
    assert res[1].energy_j == pytest.approx(full_e * len(res[1].tokens))
