"""Model correctness: decode/forward parity, exit predication, caches."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import transformer as T


def _deepen(cfg, n):
    pat = tuple(cfg.block_pattern[i % len(cfg.block_pattern)]
                for i in range(n))
    return dataclasses.replace(cfg, num_layers=n, block_pattern=pat)


PARITY_ARCHS = ["granite-3-8b", "gemma2-9b", "minicpm3-4b", "mamba2-1.3b",
                "zamba2-1.2b", "qwen2-moe-a2.7b", "opt-2.7b"]


@pytest.mark.parametrize("arch", PARITY_ARCHS)
def test_decode_matches_forward(arch):
    cfg = _deepen(get_config(arch, "smoke"), 8)
    key = jax.random.PRNGKey(2)
    params = T.init_params(key, cfg)
    B, S, S0 = 2, 18, 9
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    outs, _ = T.forward(params, cfg, toks, inference=True)
    ref = T.lm_logits(params, cfg, outs[-1])
    _, caches, _ = T.prefill(params, cfg, toks[:, :S0], max_len=S)
    worst = 0.0
    for t in range(S0, S):
        lg, caches, _ = T.decode_step(params, cfg, toks[:, t], caches,
                                      jnp.full((B,), t))
        worst = max(worst, float(jnp.abs(lg - ref[:, t]).max()))
    assert worst < 5e-3, worst


def test_forward_returns_boundary_hiddens(mini_cfg, mini_params):
    toks = jnp.zeros((2, 12), jnp.int32)
    outs, aux = T.forward(mini_params, mini_cfg, toks)
    segs = T.plan_segments(mini_cfg)
    assert len(outs) == len(segs)
    for h in outs:
        assert h.shape == (2, 12, mini_cfg.d_model)
        assert not jnp.isnan(h).any()


def test_exit_predication_freezes_hidden(mini_cfg, mini_params):
    """Tokens that exit early must produce logits from the frozen hidden."""
    B, S0 = 2, 8
    toks = jax.random.randint(jax.random.PRNGKey(0), (B, S0), 0,
                              mini_cfg.vocab_size)
    _, caches, _ = T.prefill(mini_params, mini_cfg, toks, max_len=S0 + 2)
    nxt = jnp.zeros((B,), jnp.int32)
    pos = jnp.full((B,), S0)

    # exit everyone at the first boundary
    ctrl_all = lambda h, i: jnp.ones((h.shape[0],))  # noqa: E731
    lg_e, _, info_e = T.decode_step(mini_params, mini_cfg, nxt, caches, pos,
                                    ctrl_all)
    # no exits
    lg_f, _, info_f = T.decode_step(mini_params, mini_cfg, nxt, caches, pos,
                                    None)
    segs = T.plan_segments(mini_cfg)
    assert (np.asarray(info_e["exit_layer"]) == segs[0].end).all()
    assert (np.asarray(info_f["exit_layer"]) == mini_cfg.num_layers).all()
    assert float(jnp.abs(lg_e - lg_f).max()) > 1e-6  # genuinely different


def test_exit_kv_propagation_cache_complete(mini_cfg, mini_params):
    """Even with exits, every layer's cache must advance (pos written)."""
    B, S0 = 2, 6
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S0), 0,
                              mini_cfg.vocab_size)
    _, caches, _ = T.prefill(mini_params, mini_cfg, toks, max_len=S0 + 2)
    ctrl = lambda h, i: jnp.ones((h.shape[0],))  # noqa: E731
    _, new_caches, _ = T.decode_step(mini_params, mini_cfg,
                                     jnp.zeros((B,), jnp.int32), caches,
                                     jnp.full((B,), S0), ctrl)
    for seg_cache in jax.tree.leaves(
            jax.tree.map(lambda a, b: (np.asarray(a) != np.asarray(b)).any(),
                         caches, new_caches)):
        assert seg_cache  # every cache leaf was updated


def test_sliding_window_limits_attention():
    cfg = _deepen(get_config("gemma2-9b", "smoke"), 4)
    cfg = dataclasses.replace(cfg, sliding_window=4)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 12), 0,
                              cfg.vocab_size)
    outs, _ = T.forward(params, cfg, toks)
    assert not jnp.isnan(outs[-1]).any()


def test_long_context_config_rewrite():
    from repro.config import SHAPES, config_for_shape
    cfg = get_config("granite-3-8b", "full")
    c2 = config_for_shape(cfg, SHAPES["long_500k"])
    assert c2.name.endswith("+win")
    assert all(s.mixer == "gqa_local" for s in c2.block_pattern)
    # mamba/MLA keep their mixers
    cfg = get_config("minicpm3-4b", "full")
    c3 = config_for_shape(cfg, SHAPES["long_500k"])
    assert all(s.mixer == "mla" for s in c3.block_pattern)
    cfg = get_config("mamba2-1.3b", "full")
    c4 = config_for_shape(cfg, SHAPES["long_500k"])
    assert all(s.mixer == "mamba" for s in c4.block_pattern)
