"""Executable documentation: every ```python fenced block in README.md
and docs/*.md runs against a tiny model, so the docs cannot rot.

Conventions the documents follow:
  * blocks fenced exactly ```python execute, top-to-bottom per file, in
    one namespace seeded with a mini model (``params``/``cfg``/
    ``prompt_ids`` plus ``np``/``jnp``) — later blocks may use earlier
    results;
  * pseudo-code or non-runnable sketches use ```python notest (or
    another language tag) and are skipped;
  * snippets that start a Scheduler stop it themselves.
"""
import re
from pathlib import Path

import numpy as np
import pytest

ROOT = Path(__file__).resolve().parent.parent
DOC_FILES = [ROOT / "README.md", *sorted((ROOT / "docs").glob("*.md"))]

_FENCE = re.compile(r"^```python[ \t]*\n(.*?)^```", re.S | re.M)


def _snippets(path: Path) -> list[str]:
    return [m.group(1) for m in _FENCE.finditer(path.read_text())]


def test_docs_exist_and_are_linked():
    """The docs suite's own contract: README exists and links the
    architecture + speculative docs."""
    readme = (ROOT / "README.md").read_text()
    assert "docs/architecture.md" in readme
    assert "docs/speculative.md" in readme
    assert "docs/fleet.md" in readme
    assert "docs/evals.md" in readme
    assert (ROOT / "docs" / "architecture.md").exists()
    assert (ROOT / "docs" / "speculative.md").exists()
    assert (ROOT / "docs" / "api.md").exists()
    assert (ROOT / "docs" / "fleet.md").exists()
    assert (ROOT / "docs" / "evals.md").exists()


def test_every_doc_has_executable_snippets():
    found = {p.name: len(_snippets(p)) for p in DOC_FILES}
    assert found["README.md"] >= 1
    assert found["api.md"] >= 1
    assert found["architecture.md"] >= 1
    assert found["speculative.md"] >= 1
    assert found["fleet.md"] >= 3
    assert found["evals.md"] >= 2


@pytest.fixture(scope="module")
def doc_ns():
    """The names every doc snippet may assume (a 6-layer mini model: one
    real intermediate exit point, so speculative snippets do real work)."""
    import jax
    import jax.numpy as jnp

    from repro.configs.llama32_3b import paper_mini
    from repro.models import transformer as T

    cfg = paper_mini(num_layers=6, d_model=64, vocab_size=256)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    prompt_ids = jnp.asarray(rng.integers(4, cfg.vocab_size, (1, 12)),
                             jnp.int32)
    return {"cfg": cfg, "params": params, "prompt_ids": prompt_ids,
            "np": np, "jnp": jnp}


@pytest.mark.parametrize("path", DOC_FILES, ids=lambda p: p.name)
def test_doc_snippets_execute(path, doc_ns):
    blocks = _snippets(path)
    if not blocks:
        pytest.skip(f"{path.name}: no executable python snippets")
    ns = dict(doc_ns)          # per-file namespace, shared heavy objects
    for i, src in enumerate(blocks):
        try:
            exec(compile(src, f"{path.name}[snippet {i}]", "exec"), ns)
        except Exception as e:  # noqa: BLE001
            raise AssertionError(
                f"{path.name} snippet {i} failed ({e!r}):\n{src}") from e
