"""Shared fixtures. NOTE: no xla_force_host_platform_device_count here —
tests run single-device; only launch/dryrun.py forces 512 devices."""
import os

import jaxlib

# jaxlib 0.4.x's new XLA:CPU thunk runtime segfaults inside
# backend_compile partway through this suite (deterministically, once
# enough distinct programs have been compiled in one process — the crash
# reproduces at HEAD with no working-tree changes). The legacy runtime
# is stable and ~1.5x faster here. Must be set before the backend
# initializes; version-gated because the flag will not outlive the
# legacy runtime, and unknown XLA_FLAGS are a hard error.
if jaxlib.__version__.startswith("0.4."):
    _flags = os.environ.get("XLA_FLAGS", "")
    if "xla_cpu_use_thunk_runtime" not in _flags:
        os.environ["XLA_FLAGS"] = (
            _flags + " --xla_cpu_use_thunk_runtime=false").strip()

import jax
import pytest

from repro.configs.llama32_3b import paper_mini
from repro.data import CodeCompletionDataset
from repro.models import transformer as T


@pytest.fixture(scope="session")
def mini_cfg():
    return paper_mini(num_layers=8, d_model=96, vocab_size=512)


@pytest.fixture(scope="session")
def mini_params(mini_cfg):
    return T.init_params(jax.random.PRNGKey(0), mini_cfg)


@pytest.fixture(scope="session")
def mini_dataset():
    return CodeCompletionDataset(language="java", n_files=60, seq_len=128,
                                 vocab_size=512)


@pytest.fixture(scope="session")
def trained_mini(mini_cfg, mini_dataset):
    """A briefly LITE-fine-tuned mini model (shared across tests)."""
    from repro.training import train_model
    params, hist = train_model(mini_cfg, mini_dataset, kind="lite",
                               steps=25, batch_size=4, lr=3e-3, log_every=0)
    return params, hist
