"""Shared fixtures. NOTE: no xla_force_host_platform_device_count here —
tests run single-device; only launch/dryrun.py forces 512 devices."""
import jax
import pytest

from repro.configs.llama32_3b import paper_mini
from repro.data import CodeCompletionDataset
from repro.models import transformer as T


@pytest.fixture(scope="session")
def mini_cfg():
    return paper_mini(num_layers=8, d_model=96, vocab_size=512)


@pytest.fixture(scope="session")
def mini_params(mini_cfg):
    return T.init_params(jax.random.PRNGKey(0), mini_cfg)


@pytest.fixture(scope="session")
def mini_dataset():
    return CodeCompletionDataset(language="java", n_files=60, seq_len=128,
                                 vocab_size=512)


@pytest.fixture(scope="session")
def trained_mini(mini_cfg, mini_dataset):
    """A briefly LITE-fine-tuned mini model (shared across tests)."""
    from repro.training import train_model
    params, hist = train_model(mini_cfg, mini_dataset, kind="lite",
                               steps=25, batch_size=4, lr=3e-3, log_every=0)
    return params, hist
