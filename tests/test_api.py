"""The shared request/sampling surface (repro.api) + picker invariants.

Property tests run under hypothesis when installed, else the deterministic
example loops from tests/_propcheck.py.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _propcheck import given, settings, strategies as st

from repro.api import (GenerationRequest, PolicySpec, SamplingParams,
                       find_stop)
from repro.core.early_exit import pick_tokens, request_keys, token_picker


# ---------------------------------------------------------------------------
# dataclasses
# ---------------------------------------------------------------------------
def test_generation_request_normalizes_policy_name():
    r = GenerationRequest(prompt=[1, 2], policy="fixed")
    assert isinstance(r.policy, PolicySpec) and r.policy.name == "fixed"
    assert r.spec().name == "fixed"
    assert GenerationRequest(prompt=[1]).spec(PolicySpec("entropy")).name \
        == "entropy"


def test_generation_request_validation():
    with pytest.raises(ValueError):
        GenerationRequest(prompt=[1], max_new_tokens=0)
    with pytest.raises(ValueError, match="unknown exit policy"):
        GenerationRequest(prompt=[1], policy="wat")
    with pytest.raises(TypeError, match="sequence of strings"):
        GenerationRequest(prompt=[1], stop_sequences="\n")
    with pytest.raises(ValueError, match="empty string"):
        GenerationRequest(prompt=[1], stop_sequences=("ok", ""))


def test_sampling_params_validation():
    with pytest.raises(ValueError):
        SamplingParams(top_p=0.0)
    with pytest.raises(ValueError):
        SamplingParams(top_p=1.5)
    with pytest.raises(ValueError):
        SamplingParams(top_k=-1)
    # int32 overflow must fail at construction, not on the decode thread
    with pytest.raises(ValueError, match="int32"):
        SamplingParams(seed=2 ** 31)
    with pytest.raises(ValueError, match="top_k"):
        SamplingParams(top_k=2 ** 31)
    assert SamplingParams().greedy
    assert not SamplingParams(temperature=0.7).greedy


def test_find_stop_earliest_then_longest():
    assert find_stop("abcdef", ("cd", "e")) == (2, "cd")
    assert find_stop("abab", ("ab", "aba")) == (0, "aba")
    assert find_stop("abc", ("zz",)) is None


# ---------------------------------------------------------------------------
# find_stop properties (satellite: overlapping stops, chunk splits,
# prefix-of-another stops)
# ---------------------------------------------------------------------------
def _stop_ref(text, stops):
    """Naive reference: scan every position left to right; first position
    with any match wins, longest match at that position breaks the tie."""
    for i in range(len(text)):
        matches = [s for s in stops if text.startswith(s, i)]
        if matches:
            return i, max(matches, key=len)
    return None


_ALPHA = "ab\n"


def _text_from(ints):
    return "".join(_ALPHA[i % len(_ALPHA)] for i in ints)


@given(st.lists(st.integers(min_value=0, max_value=2), min_size=0,
                max_size=40),
       st.lists(st.integers(min_value=0, max_value=2), min_size=1,
                max_size=6),
       st.lists(st.integers(min_value=0, max_value=2), min_size=1,
                max_size=4))
@settings(max_examples=60, deadline=None)
def test_find_stop_matches_reference_on_overlapping_stops(ti, s1, s2):
    text = _text_from(ti)
    stops = (_text_from(s1), _text_from(s2), "aba", "ba\n")
    assert find_stop(text, stops) == _stop_ref(text, stops)


@given(st.lists(st.integers(min_value=0, max_value=2), min_size=0,
                max_size=20),
       st.lists(st.integers(min_value=0, max_value=2), min_size=1,
                max_size=4),
       st.integers(min_value=0, max_value=20),
       st.lists(st.integers(min_value=1, max_value=5), min_size=1,
                max_size=30))
@settings(max_examples=60, deadline=None)
def test_find_stop_survives_chunk_splits(ti, si, at, chunks):
    """A stop split across streamed chunks: scanning the accumulated text
    after each chunk first fires at exactly the cut the one-shot scan of
    the full text reports — no matter how the chunk boundaries fall."""
    body = _text_from(ti)
    stop = _text_from(si)
    at = min(at, len(body))
    text = body[:at] + stop + body[at:]
    expected = find_stop(text, (stop,))
    assert expected is not None
    acc = ""
    first = None
    pos = 0
    for c in chunks:
        if pos >= len(text):
            break
        acc += text[pos: pos + c]
        pos += c
        hit = find_stop(acc, (stop,))
        if hit is not None:
            first = hit
            break
    else:
        acc = text                     # drain the remainder in one chunk
        first = find_stop(acc, (stop,))
    assert first == expected
    # the visible text the server would emit is cut identically
    assert acc[: first[0]] == text[: expected[0]]


@given(st.lists(st.integers(min_value=0, max_value=2), min_size=1,
                max_size=6),
       st.lists(st.integers(min_value=0, max_value=2), min_size=1,
                max_size=4),
       st.lists(st.integers(min_value=0, max_value=2), min_size=0,
                max_size=10))
@settings(max_examples=60, deadline=None)
def test_find_stop_prefers_longer_when_one_stop_prefixes_another(si, ext,
                                                                 pre):
    """One stop a strict prefix of another: wherever the long one
    matches, the tie at that index must resolve to the long one."""
    short = _text_from(si)
    long = short + _text_from(ext)
    text = _text_from(pre) + long
    i, s = find_stop(text, (short, long))
    assert (i, s) == _stop_ref(text, (short, long))
    if text.startswith(long, i):
        assert s == long
    assert i <= len(_text_from(pre))   # never later than the planted hit


# ---------------------------------------------------------------------------
# picker invariants (satellite: top_k / top_p property tests)
# ---------------------------------------------------------------------------
def _logits(seed, B=3, V=48):
    return jax.random.normal(jax.random.PRNGKey(seed), (B, V))


def _keys(seed, B=3):
    return request_keys(np.full(B, seed), np.arange(B))


@given(st.integers(min_value=0, max_value=10_000),
       st.integers(min_value=1, max_value=48))
@settings(max_examples=25, deadline=None)
def test_top_k_samples_only_top_k(seed, k):
    logits = _logits(seed)
    tok, _ = pick_tokens(logits, _keys(seed), temperature=1.0, top_k=k)
    order = np.argsort(np.asarray(logits), axis=-1)
    for b, t in enumerate(np.asarray(tok)):
        assert int(t) in order[b, -k:], f"token outside top-{k}"


@given(st.integers(min_value=0, max_value=10_000),
       st.integers(min_value=1, max_value=99))
@settings(max_examples=25, deadline=None)
def test_top_p_samples_inside_nucleus(seed, p_pct):
    p = p_pct / 100.0
    logits = _logits(seed)
    tok, _ = pick_tokens(logits, _keys(seed), temperature=1.0, top_p=p)
    probs = np.asarray(jax.nn.softmax(logits, axis=-1))
    for b, t in enumerate(np.asarray(tok)):
        srt = np.sort(probs[b])[::-1]
        csum = np.cumsum(srt) - srt
        n_keep = max(int((csum < p).sum()), 1)    # smallest nucleus
        thresh = srt[n_keep - 1]
        assert probs[b, int(t)] >= thresh - 1e-7, \
            f"token outside the top-p={p} nucleus"


@given(st.integers(min_value=0, max_value=10_000))
@settings(max_examples=25, deadline=None)
def test_top_p_zero_keeps_exactly_top1(seed):
    """top_p == 0.0 is the nucleus edge case: the `(csum - probs) < p`
    prefix is empty and only the `max(keep_p, 1)` clamp keeps the
    distribution non-empty — the filter must then degenerate to argmax of
    the temperature-scaled logits, i.e. plain argmax, for every key."""
    logits = _logits(seed)
    tok, lp = pick_tokens(logits, _keys(seed), temperature=1.0, top_p=0.0)
    np.testing.assert_array_equal(np.asarray(tok),
                                  np.argmax(np.asarray(logits), -1))
    assert np.all(np.isfinite(np.asarray(lp)))


@given(st.integers(min_value=0, max_value=10_000))
@settings(max_examples=25, deadline=None)
def test_top_p_one_is_unfiltered(seed):
    """top_p == 1.0 disables the filter: the draw must match the same
    temperature-scaled categorical with no nucleus applied."""
    logits = _logits(seed)
    tok, _ = pick_tokens(logits, _keys(seed), temperature=1.0, top_p=1.0)
    ref, _ = pick_tokens(logits, _keys(seed), temperature=1.0)
    np.testing.assert_array_equal(np.asarray(tok), np.asarray(ref))


@given(st.integers(min_value=0, max_value=10_000),
       st.integers(min_value=0, max_value=100))
@settings(max_examples=25, deadline=None)
def test_tied_logit_rows_survive_top_p_edges(seed, p_pct):
    """Rows of identical logits (csum hits p on a knife edge for every
    prefix) must still return a valid token with a finite logprob at any
    top_p, including the 0.0 / 1.0 endpoints."""
    B, V = 3, 32
    logits = jnp.zeros((B, V)) + float(seed % 5)
    p = p_pct / 100.0
    tok, lp = pick_tokens(logits, _keys(seed, B=B), temperature=1.0,
                          top_p=max(p, 0.0))
    tok = np.asarray(tok)
    assert ((0 <= tok) & (tok < V)).all()
    np.testing.assert_allclose(np.asarray(lp), -np.log(V), rtol=1e-5)
    # top_p=0 on a tied row: the clamp keeps the top-1 *threshold*, and
    # every tied token shares it — any of them is a valid draw, but the
    # logprob must still be the exact uniform mass
    t0, lp0 = pick_tokens(logits, _keys(seed, B=B), temperature=1.0,
                          top_p=0.0)
    t0 = np.asarray(t0)
    assert ((0 <= t0) & (t0 < V)).all()
    np.testing.assert_allclose(np.asarray(lp0), -np.log(V), rtol=1e-5)


@given(st.integers(min_value=0, max_value=10_000))
@settings(max_examples=15, deadline=None)
def test_zero_temperature_is_argmax_and_key_independent(seed):
    logits = _logits(seed)
    t1, lp1 = pick_tokens(logits, _keys(seed), temperature=0.0,
                          top_k=3, top_p=0.5)
    t2, lp2 = pick_tokens(logits, _keys(seed + 1), temperature=0.0)
    np.testing.assert_array_equal(np.asarray(t1),
                                  np.argmax(np.asarray(logits), -1))
    np.testing.assert_array_equal(np.asarray(t1), np.asarray(t2))
    np.testing.assert_allclose(np.asarray(lp1), np.asarray(lp2))


def test_per_row_params_mix_greedy_and_filtered():
    """One call, heterogeneous rows: greedy rows are exact argmax while
    sampled rows respect their own top_k — the scheduler's hot path."""
    logits = _logits(7, B=4)
    temp = np.asarray([0.0, 1.0, 0.0, 2.0], np.float32)
    topk = np.asarray([0, 2, 0, 5], np.int32)
    tok, _ = pick_tokens(logits, _keys(11, B=4), temperature=temp,
                         top_k=topk)
    tok = np.asarray(tok)
    order = np.argsort(np.asarray(logits), axis=-1)
    assert tok[0] == order[0, -1] and tok[2] == order[2, -1]
    assert int(tok[1]) in order[1, -2:]
    assert int(tok[3]) in order[3, -5:]


def test_unfiltered_sampling_matches_seed_token_picker():
    """top_k=0/top_p=1 must reproduce the seed picker draw-for-draw.

    The reference below is the seed PR-1 ``token_picker`` body verbatim
    (not the shim, which now delegates to pick_tokens)."""
    logits = _logits(5)
    key = jax.random.PRNGKey(9)
    ref_lp_full = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    ref_tok = jax.random.categorical(key, logits / 0.8, axis=-1)
    ref_lp = jnp.take_along_axis(ref_lp_full, ref_tok[:, None], 1)[:, 0]
    new_tok, new_lp = pick_tokens(logits, key, temperature=0.8)
    shim_tok, _ = token_picker(0.8)(logits, key)
    np.testing.assert_array_equal(np.asarray(ref_tok), np.asarray(new_tok))
    np.testing.assert_array_equal(np.asarray(ref_tok), np.asarray(shim_tok))
    np.testing.assert_allclose(np.asarray(ref_lp), np.asarray(new_lp),
                               atol=1e-6)


def test_request_keys_depend_on_seed_and_step_only():
    k1 = np.asarray(request_keys(np.asarray([1, 1]), np.asarray([4, 5])))
    k2 = np.asarray(request_keys(np.asarray([1, 2]), np.asarray([4, 4])))
    assert not (k1[0] == k1[1]).all()          # step matters
    assert not (k1[0] == k2[1]).all()          # seed matters
    k3 = np.asarray(request_keys(np.asarray([1]), np.asarray([4])))
    np.testing.assert_array_equal(k1[0], k3[0])   # position in batch doesn't


def test_logprob_is_unscaled_head_distribution():
    logits = _logits(3)
    tok, lp = pick_tokens(logits, jax.random.PRNGKey(0), temperature=1.3,
                          top_k=4)
    full = np.asarray(jax.nn.log_softmax(np.asarray(logits), axis=-1))
    got = full[np.arange(len(np.asarray(tok))), np.asarray(tok)]
    np.testing.assert_allclose(np.asarray(lp), got, atol=1e-6)
