"""RL: reward function (Eqs. 2/3), env dynamics, PPO convergence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.rl.env import CONTINUE, EXIT, EarlyExitEnv, RewardCoefs
from repro.rl.rollout import RolloutCache


def _toy_cache(E=4, T=3, n_b=4, D=8, num_layers=12, l_opt_layer=6):
    """Cache where boundary preds match final from boundary index 1 on."""
    rng = np.random.default_rng(0)
    hidden = rng.normal(size=(E, T, n_b, D)).astype(np.float32)
    preds = np.zeros((E, T, n_b), np.int32)
    preds[:, :, 0] = 7          # wrong at first boundary
    preds[:, :, 1:] = 42        # correct from boundary 1 (layer 6)
    bounds = np.asarray([4, 6, 10, 12], np.int32)
    l_opt = np.full((E, T), l_opt_layer, np.int32)
    return RolloutCache(hidden=hidden, preds=preds, l_opt=l_opt,
                        boundaries=bounds, num_layers=num_layers)


@pytest.fixture
def env():
    return EarlyExitEnv(_toy_cache(), RewardCoefs(alpha=0.2, beta=1.0,
                                                  gamma=1.0, epsilon=0.1),
                        n_lanes=4)


def test_reward_optimal_exit(env):
    state, _ = env.reset(jax.random.PRNGKey(0))
    # continue to boundary 1 (layer 6 == l_opt), then exit
    state, _, r, _ = env.step(state, jnp.zeros(4, jnp.int32),
                              jax.random.PRNGKey(1))
    assert np.allclose(np.asarray(r), 1.0)       # continue before l_opt: +1
    state, _, r, _ = env.step(state, jnp.ones(4, jnp.int32),
                              jax.random.PRNGKey(2))
    assert np.allclose(np.asarray(r), 1.0)       # optimal exit: +1


def test_reward_too_early_exit(env):
    state, _ = env.reset(jax.random.PRNGKey(0))
    _, _, r, _ = env.step(state, jnp.ones(4, jnp.int32),
                          jax.random.PRNGKey(1))
    # exit at layer 4, wrong pred, l_opt=6: -(6-4)/12 * beta
    assert np.allclose(np.asarray(r), -(6 - 4) / 12 * 1.0, atol=1e-6)


def test_reward_late_exit(env):
    state, _ = env.reset(jax.random.PRNGKey(0))
    k = jax.random.PRNGKey(1)
    for _ in range(2):                            # continue to boundary 2
        state, _, _, _ = env.step(state, jnp.zeros(4, jnp.int32), k)
    _, _, r, _ = env.step(state, jnp.ones(4, jnp.int32), k)
    # exit at layer 10, correct, l_opt=6: -(10-6)/12 * alpha
    assert np.allclose(np.asarray(r), -(10 - 6) / 12 * 0.2, atol=1e-6)


def test_reward_late_continue(env):
    state, _ = env.reset(jax.random.PRNGKey(0))
    k = jax.random.PRNGKey(1)
    state, _, _, _ = env.step(state, jnp.zeros(4, jnp.int32), k)
    # now at boundary 1 == l_opt; continuing is wrong:
    # penalty -(l_next - l_opt)/N * gamma = -(10-6)/12
    _, _, r, _ = env.step(state, jnp.zeros(4, jnp.int32), k)
    assert np.allclose(np.asarray(r), -(10 - 6) / 12 * 1.0, atol=1e-6)


def test_forced_exit_at_last_boundary(env):
    state, _ = env.reset(jax.random.PRNGKey(0))
    k = jax.random.PRNGKey(1)
    for _ in range(3):
        state, _, _, _ = env.step(state, jnp.zeros(4, jnp.int32), k)
    # at last boundary: CONTINUE is treated as forced EXIT -> token advances
    new_state, _, _, _ = env.step(state, jnp.zeros(4, jnp.int32), k)
    assert (np.asarray(new_state["tok"]) == 1).all()
    assert (np.asarray(new_state["b"]) == 0).all()


def test_episode_reset_on_last_token(env):
    state, _ = env.reset(jax.random.PRNGKey(0))
    k = jax.random.PRNGKey(3)
    done_seen = False
    for i in range(40):
        k, k2 = jax.random.split(k)
        state, _, _, done = env.step(state, jnp.ones(4, jnp.int32), k2)
        done_seen |= bool(np.asarray(done).any())
    assert done_seen
    assert (np.asarray(state["tok"]) < env.T).all()


def test_ppo_learns_toy_env():
    """On the toy cache the optimal policy is deterministic — PPO should
    reach near-optimal mean step reward (continue@0 -> exit@1 = +1/step)."""
    from repro.rl.ppo import PPOConfig, ppo_train
    env = EarlyExitEnv(_toy_cache(E=8, T=4), n_lanes=8)
    agent, hist = ppo_train(
        env, config=PPOConfig(total_steps=60_000, horizon=128, n_lanes=8,
                              lr=3e-4),
        seed=0, log_every=0)
    assert hist[-1]["mean_step_reward"] > 0.5, hist[-1]
    assert hist[-1]["mean_step_reward"] > hist[0]["mean_step_reward"]
