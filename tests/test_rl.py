"""RL: reward function (Eqs. 2/3), env dynamics, PPO convergence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.rl.env import CONTINUE, EXIT, EarlyExitEnv, RewardCoefs
from repro.rl.rollout import RolloutCache


def _toy_cache(E=4, T=3, n_b=4, D=8, num_layers=12, l_opt_layer=6):
    """Cache where boundary preds match final from boundary index 1 on."""
    rng = np.random.default_rng(0)
    hidden = rng.normal(size=(E, T, n_b, D)).astype(np.float32)
    preds = np.zeros((E, T, n_b), np.int32)
    preds[:, :, 0] = 7          # wrong at first boundary
    preds[:, :, 1:] = 42        # correct from boundary 1 (layer 6)
    bounds = np.asarray([4, 6, 10, 12], np.int32)
    l_opt = np.full((E, T), l_opt_layer, np.int32)
    return RolloutCache(hidden=hidden, preds=preds, l_opt=l_opt,
                        boundaries=bounds, num_layers=num_layers)


@pytest.fixture
def env():
    return EarlyExitEnv(_toy_cache(), RewardCoefs(alpha=0.2, beta=1.0,
                                                  gamma=1.0, epsilon=0.1),
                        n_lanes=4)


def test_reward_optimal_exit(env):
    state, _ = env.reset(jax.random.PRNGKey(0))
    # continue to boundary 1 (layer 6 == l_opt), then exit
    state, _, r, _ = env.step(state, jnp.zeros(4, jnp.int32),
                              jax.random.PRNGKey(1))
    assert np.allclose(np.asarray(r), 1.0)       # continue before l_opt: +1
    state, _, r, _ = env.step(state, jnp.ones(4, jnp.int32),
                              jax.random.PRNGKey(2))
    assert np.allclose(np.asarray(r), 1.0)       # optimal exit: +1


def test_reward_too_early_exit(env):
    state, _ = env.reset(jax.random.PRNGKey(0))
    _, _, r, _ = env.step(state, jnp.ones(4, jnp.int32),
                          jax.random.PRNGKey(1))
    # exit at layer 4, wrong pred, l_opt=6: -(6-4)/12 * beta
    assert np.allclose(np.asarray(r), -(6 - 4) / 12 * 1.0, atol=1e-6)


def test_reward_late_exit(env):
    state, _ = env.reset(jax.random.PRNGKey(0))
    k = jax.random.PRNGKey(1)
    for _ in range(2):                            # continue to boundary 2
        state, _, _, _ = env.step(state, jnp.zeros(4, jnp.int32), k)
    _, _, r, _ = env.step(state, jnp.ones(4, jnp.int32), k)
    # exit at layer 10, correct, l_opt=6: -(10-6)/12 * alpha
    assert np.allclose(np.asarray(r), -(10 - 6) / 12 * 0.2, atol=1e-6)


def test_reward_late_continue(env):
    state, _ = env.reset(jax.random.PRNGKey(0))
    k = jax.random.PRNGKey(1)
    state, _, _, _ = env.step(state, jnp.zeros(4, jnp.int32), k)
    # now at boundary 1 == l_opt; continuing is wrong:
    # penalty -(l_next - l_opt)/N * gamma = -(10-6)/12
    _, _, r, _ = env.step(state, jnp.zeros(4, jnp.int32), k)
    assert np.allclose(np.asarray(r), -(10 - 6) / 12 * 1.0, atol=1e-6)


def test_forced_exit_at_last_boundary(env):
    state, _ = env.reset(jax.random.PRNGKey(0))
    k = jax.random.PRNGKey(1)
    for _ in range(3):
        state, _, _, _ = env.step(state, jnp.zeros(4, jnp.int32), k)
    # at last boundary: CONTINUE is treated as forced EXIT -> token advances
    new_state, _, _, _ = env.step(state, jnp.zeros(4, jnp.int32), k)
    assert (np.asarray(new_state["tok"]) == 1).all()
    assert (np.asarray(new_state["b"]) == 0).all()


def test_episode_reset_on_last_token(env):
    state, _ = env.reset(jax.random.PRNGKey(0))
    k = jax.random.PRNGKey(3)
    done_seen = False
    for i in range(40):
        k, k2 = jax.random.split(k)
        state, _, _, done = env.step(state, jnp.ones(4, jnp.int32), k2)
        done_seen |= bool(np.asarray(done).any())
    assert done_seen
    assert (np.asarray(state["tok"]) < env.T).all()


# ---------------------------------------------------------------------------
# serving-side reward shaping (energy_weight / accuracy_weight hooks)
# ---------------------------------------------------------------------------
def _shaping_cfg():
    from repro.configs.llama32_3b import paper_mini
    return paper_mini(num_layers=12, d_model=32, vocab_size=64)


def test_default_coefs_are_paper_reward():
    """The shaping knobs default to 0.0 — the paper's Eq. 2/3 reward is
    reproduced bit-for-bit (subtracting 0.0 * x is the identity), which
    the exact-value tests above already pin. Here: the defaults really
    are zero and an unshaped env needs no cfg."""
    c = RewardCoefs()
    assert c.energy_weight == 0.0 and c.accuracy_weight == 0.0
    EarlyExitEnv(_toy_cache(), c, n_lanes=4)       # no cfg= required


def test_energy_weight_requires_cfg():
    with pytest.raises(ValueError, match="cfg"):
        EarlyExitEnv(_toy_cache(), RewardCoefs(energy_weight=0.5),
                     n_lanes=4)


def test_energy_shaping_charges_exits_and_rejected_drafts():
    cache = _toy_cache()
    cfg = _shaping_cfg()
    k = jax.random.PRNGKey(0)
    base = EarlyExitEnv(cache, RewardCoefs(), n_lanes=4)
    shaped = EarlyExitEnv(cache, RewardCoefs(energy_weight=1.0), n_lanes=4,
                          cfg=cfg)
    ef = np.asarray(shaped.arrays.exit_frac)
    vf = np.asarray(shaped.arrays.verify_frac)
    assert (ef > 0).all() and (vf > 0).all()
    assert (np.diff(ef) > 0).all()       # deeper exit = more energy
    assert np.allclose(np.asarray(base.arrays.exit_frac), 0.0)

    s0, _ = base.reset(k)
    t0, _ = shaped.reset(k)
    ones = jnp.ones(4, jnp.int32)
    zeros = jnp.zeros(4, jnp.int32)
    # CONTINUE pays nothing
    _, _, rb, _ = base.step(s0, zeros, k)
    _, _, rs, _ = shaped.step(t0, zeros, k)
    assert np.allclose(np.asarray(rb), np.asarray(rs))
    # wrong EXIT at boundary 0 pays its exit cost PLUS the full-depth
    # verify pass a rejected speculative draft costs
    _, _, rb, _ = base.step(s0, ones, k)
    _, _, rs, _ = shaped.step(t0, ones, k)
    assert np.allclose(np.asarray(rs), np.asarray(rb) - (ef[0] + vf[0]),
                       atol=1e-6)
    # correct EXIT at boundary 1 pays only the exit cost (draft accepted)
    s1, _, _, _ = base.step(s0, zeros, k)
    t1, _, _, _ = shaped.step(t0, zeros, k)
    _, _, rb, _ = base.step(s1, ones, k)
    _, _, rs, _ = shaped.step(t1, ones, k)
    assert np.allclose(np.asarray(rs), np.asarray(rb) - ef[1], atol=1e-6)


def test_accuracy_shaping_uses_task_delta():
    cache = _toy_cache().with_task_delta(0.25)
    assert cache.task_delta.shape == (4,)
    base = EarlyExitEnv(_toy_cache(), RewardCoefs(), n_lanes=4)
    shaped = EarlyExitEnv(cache, RewardCoefs(accuracy_weight=2.0), n_lanes=4)
    k = jax.random.PRNGKey(0)
    s0, _ = base.reset(k)
    t0, _ = shaped.reset(k)
    ones = jnp.ones(4, jnp.int32)
    zeros = jnp.zeros(4, jnp.int32)
    # wrong EXIT at boundary 0: extra penalty = weight * delta
    _, _, rb, _ = base.step(s0, ones, k)
    _, _, rs, _ = shaped.step(t0, ones, k)
    assert np.allclose(np.asarray(rs), np.asarray(rb) - 2.0 * 0.25,
                       atol=1e-6)
    # correct EXIT at boundary 1: no accuracy penalty
    s1, _, _, _ = base.step(s0, zeros, k)
    t1, _, _, _ = shaped.step(t0, zeros, k)
    _, _, rb, _ = base.step(s1, ones, k)
    _, _, rs, _ = shaped.step(t1, ones, k)
    assert np.allclose(np.asarray(rb), np.asarray(rs))


def test_task_delta_from_reports_join():
    from repro.rl import task_delta_from_reports
    baseline = {"summary": {"pass_at": {"1": 0.6, "10": 0.9}}}
    exit_arm = {"summary": {"pass_at": {"1": 0.45, "10": 0.9}}}
    d = task_delta_from_reports(baseline, exit_arm, 5)
    assert d.shape == (5,) and d.dtype == np.float32
    assert np.allclose(d, 0.15)
    # an exit policy that helps is floored at zero, not rewarded
    d = task_delta_from_reports(exit_arm, baseline, 3)
    assert np.allclose(d, 0.0)
    # k selects the pass@k column
    d = task_delta_from_reports(baseline, exit_arm, 2, k="10")
    assert np.allclose(d, 0.0)


def test_ppo_learns_toy_env():
    """On the toy cache the optimal policy is deterministic — PPO should
    reach near-optimal mean step reward (continue@0 -> exit@1 = +1/step)."""
    from repro.rl.ppo import PPOConfig, ppo_train
    env = EarlyExitEnv(_toy_cache(E=8, T=4), n_lanes=8)
    agent, hist = ppo_train(
        env, config=PPOConfig(total_steps=60_000, horizon=128, n_lanes=8,
                              lr=3e-4),
        seed=0, log_every=0)
    assert hist[-1]["mean_step_reward"] > 0.5, hist[-1]
    assert hist[-1]["mean_step_reward"] > hist[0]["mean_step_reward"]
