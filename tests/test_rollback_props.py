"""Property tests for speculative rollback: random accept/reject
sequences must restore KV state *exactly*.

Two layers of the rollback story are pinned here:

* contiguous rings — ``rewind_ring`` after k drafted writes with a of
  them accepted leaves the cache bit-identical (``pos`` planes exact,
  K/V at every still-valid position exact) to a cache that only ever
  performed the a accepted writes;
* paged pools — ``prepare_append`` + ``rollback_append`` return every
  rejected block to the allocator and its unit to the slot's growth
  reservation, so refcounts, reservations, tables and the free pool are
  exactly what they were before the draft (full reject) and the
  ``reserved + owned == worst case`` ledger never drifts (partial
  accept), over arbitrarily interleaved multi-slot draft rounds.

Uses the hypothesis shim in tests/_propcheck.py: real hypothesis when
installed, deterministic seeded example loops otherwise.
"""
import random
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent))

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _propcheck import given, settings, strategies as st

from repro.configs import get_config
from repro.models import transformer as T
from repro.serving.kv_pool import PagedKVPool

# ---------------------------------------------------------------- rings

S0 = 5          # prompt length
T_DEC = 14      # decode budget a trajectory may commit
MAX_LEN = 24

_MODELS: dict = {}


def _ring_model(arch: str):
    """(cfg, jitted decode step) — compiled once per arch, shared by all
    drawn examples (same shapes throughout)."""
    if arch not in _MODELS:
        cfg = get_config(arch, "smoke")
        params = T.init_params(jax.random.PRNGKey(0), cfg)
        step = jax.jit(lambda tok, caches, pos:
                       T.decode_step(params, cfg, tok, caches, pos))
        _MODELS[arch] = (cfg, params, step)
    return _MODELS[arch]


def _assert_ring_state_equal(cfg, ref, got):
    """pos planes bitwise equal; K/V (or MLA latent) planes bitwise equal
    at every position the pos plane still admits (rewound entries hold
    garbage by design — the mask is the contract)."""
    segs = T.plan_segments(cfg)

    def check(ca, cb):
        pos = np.asarray(ca["pos"])
        np.testing.assert_array_equal(pos, np.asarray(cb["pos"]))
        valid = pos >= 0
        for name in ca:
            if name == "pos":
                continue
            a, b = np.asarray(ca[name]), np.asarray(cb[name])
            m = valid.reshape(valid.shape + (1,) * (a.ndim - valid.ndim))
            np.testing.assert_array_equal(np.where(m, a, 0),
                                          np.where(m, b, 0))

    for seg, ca, cb in zip(segs, ref, got):
        if seg.scanned:
            check(ca, cb)
        else:
            for caj, cbj in zip(ca, cb):
                check(caj, cbj)


@settings(max_examples=6, deadline=None)
@given(st.integers(min_value=0, max_value=10 ** 9))
def test_rewind_ring_random_accept_reject(seed):
    """Random draft-k / accept-a rounds: after every rewind the spec
    arm's ring must be bit-identical to the reference trajectory that
    only ever wrote the accepted tokens, and its next-step logits must
    match the reference bitwise. Runs a GQA ring and an MLA latent ring
    (the two contiguous ring families rewind_ring serves alone — mamba
    and windowed configs rewind via the scheduler's snapshot protocol)."""
    for arch in ("llama32-3b", "minicpm3-4b"):
        _rewind_round_trip(arch, seed)


def _rewind_round_trip(arch: str, seed: int):
    rng = random.Random(seed)
    cfg, params, step = _ring_model(arch)
    prompt = jnp.asarray(
        np.random.default_rng(7).integers(4, cfg.vocab_size, (1, S0)),
        jnp.int32)
    toks = [rng.randrange(4, cfg.vocab_size) for _ in range(T_DEC + 1)]

    _, cache0, _ = T.prefill(params, cfg, prompt, max_len=MAX_LEN)
    # reference trajectory: caches after t committed decode writes
    ref = [cache0]
    for t in range(T_DEC):
        _, c, _ = step(jnp.asarray([toks[t]], jnp.int32), ref[-1],
                       jnp.asarray([S0 + t], jnp.int32))
        ref.append(c)

    spec, n = cache0, 0
    for _ in range(4):
        k = rng.randint(1, min(3, T_DEC - n))
        a = rng.randint(0, k)
        for j in range(k):            # draft writes the same token stream
            _, spec, _ = step(jnp.asarray([toks[n + j]], jnp.int32), spec,
                              jnp.asarray([S0 + n + j], jnp.int32))
        spec = T.rewind_ring(cfg, spec,
                             jnp.asarray([S0 + n + a - 1], jnp.int32))
        n += a
        _assert_ring_state_equal(cfg, ref[n], spec)
    # the rewound cache must also *compute* like the reference arm
    la, _, _ = step(jnp.asarray([toks[n]], jnp.int32), ref[n],
                    jnp.asarray([S0 + n], jnp.int32))
    lb, _, _ = step(jnp.asarray([toks[n]], jnp.int32), spec,
                    jnp.asarray([S0 + n], jnp.int32))
    np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


# ---------------------------------------------------------------- paged

BS = 4


def _paged_pool(prefix: bool) -> PagedKVPool:
    cfg = get_config("llama32-3b", "smoke")
    return PagedKVPool(cfg, max_slots=3, max_len=48, block_size=BS,
                       enable_prefix_cache=prefix)


def _snap(pool):
    return (pool.blocks._refcount.copy(), sorted(pool.blocks._free),
            pool.tables.copy(), pool._n_blocks.copy(),
            pool._reserved.copy(), pool.blocks.n_in_use)


def _assert_snap_equal(before, after):
    ref_rc, ref_free, ref_tab, ref_nb, ref_res, ref_use = before
    rc, free, tab, nb, res, use = after
    np.testing.assert_array_equal(ref_rc, rc)
    assert ref_free == free          # same *set* of free blocks
    np.testing.assert_array_equal(ref_tab, tab)
    np.testing.assert_array_equal(ref_nb, nb)
    np.testing.assert_array_equal(ref_res, res)
    assert ref_use == use


@settings(max_examples=25, deadline=None)
@given(st.integers(min_value=0, max_value=10 ** 9))
def test_paged_rollback_restores_accounting(seed):
    """Multi-slot random draft/accept rounds (prefix cache off, so no
    sharing/COW muddies the ledger): a full reject restores the allocator
    snapshot exactly; any accept count keeps the per-slot invariant
    ``reserved + owned == worst case`` and the global refcount ledger."""
    rng = random.Random(seed)
    pool = _paged_pool(prefix=False)
    reqs = []
    for _ in range(rng.randint(1, 3)):
        S = rng.randint(1, 10)
        max_new = rng.randint(4, 12)
        prompt = [rng.randrange(256) for _ in range(S)]
        slot = pool.alloc()
        assert slot is not None
        ids, n_shared, tail_shared = pool.bind_prompt(prompt)
        pool.install_prompt(slot, S, ids, n_shared, tail_shared, max_new)
        reqs.append({"slot": slot, "S": S, "max_new": max_new, "n": 0})

    def ledger_ok():
        for r in reqs:
            owned = int(pool._n_blocks[r["slot"]])
            res = int(pool._reserved[r["slot"]])
            assert owned + res == pool.blocks_for(r["S"] + r["max_new"])
        used = int(sum(pool._n_blocks[r["slot"]] for r in reqs))
        assert pool.blocks.n_in_use == used

    ledger_ok()
    for _ in range(8):
        r = rng.choice(reqs)
        budget = r["max_new"] - r["n"]
        if budget == 0:
            continue
        k = rng.randint(1, min(4, budget))
        a = rng.randint(0, k)
        before = _snap(pool)
        base = r["S"] + r["n"]
        for j in range(k):
            pool.prepare_append(r["slot"], base + j)
        pool.rollback_append(r["slot"], base + a)
        r["n"] += a
        if a == 0:
            _assert_snap_equal(before, _snap(pool))
        assert int(pool._n_blocks[r["slot"]]) == max(
            pool.blocks_for(base + a), 1)
        ledger_ok()
    # retirement drains everything the rounds ever touched
    for r in reqs:
        pool.release(r["slot"])
    assert pool.blocks.n_in_use == 0
    assert pool.reserved_blocks == 0
    assert int(pool.blocks._refcount[1:].sum()) == 0   # 0 stays pinned


def test_paged_rollback_after_cow_does_not_drift():
    """A draft that copy-on-writes a shared tail and is then fully
    rejected keeps the COWed block (the slot now owns its tail
    exclusively) — and repeated draft/reject cycles after that first COW
    restore the snapshot exactly, so the reservation never drifts."""
    pool = _paged_pool(prefix=True)
    prompt = list(range(BS + 2))                  # partial tail block
    s1 = pool.alloc()
    pool.write_prompt(s1, prompt, _ring_for(pool, prompt), max_new=8)
    s2 = pool.alloc()                             # exact-prompt sharer
    pool.write_prompt(s2, prompt, _ring_for(pool, prompt), max_new=8)
    tail = int(pool.tables[s1, 1])
    assert pool.blocks.refcount(tail) == 2        # shared mutable tail
    # first draft COWs, then rejects — the copy stays, sharing is gone
    pool.prepare_append(s1, len(prompt))
    pool.rollback_append(s1, len(prompt))
    new_tail = int(pool.tables[s1, 1])
    assert new_tail != tail
    assert pool.blocks.refcount(new_tail) == 1
    assert pool.blocks.refcount(tail) == 1        # only s2 holds it now
    assert pool.cow_copies == 1
    # every later cycle is a pure snapshot restore: COW happens at most
    # once per slot, so no reservation unit is ever double-spent
    before = _snap(pool)
    for _ in range(3):
        for j in range(3):
            pool.prepare_append(s1, len(prompt) + j)
        pool.rollback_append(s1, len(prompt))
        _assert_snap_equal(before, _snap(pool))
    assert pool.cow_copies == 1


def _ring_for(pool: PagedKVPool, prompt):
    """Minimal prefilled ring for write_prompt (content irrelevant to the
    accounting properties — attention is never run here)."""
    cfg = pool.cfg
    params = _ring_model("llama32-3b")[1]
    n = pool.blocks_for(len(prompt)) * pool.block_size
    toks = jnp.asarray([prompt], jnp.int32)
    _, caches, _ = T.prefill(params, cfg, toks, max_len=n)
    return caches
