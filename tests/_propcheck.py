"""Property-test shim: use hypothesis when installed, otherwise fall back
to hand-rolled deterministic example loops with the same decorator API.

    from _propcheck import given, settings, strategies as st

The fallback draws ``max_examples`` pseudo-random examples from a fixed
seed, so CI without hypothesis still exercises the properties (just with
less adversarial inputs and no shrinking).
"""
from __future__ import annotations

import functools
import random
import string

try:
    from hypothesis import given, settings, strategies  # noqa: F401
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def example(self, rng: random.Random):
            return self._draw(rng)

    # a few awkward characters on purpose: multi-byte UTF-8, controls,
    # whitespace runs — the cases the tokenizer round-trip must survive
    _CHARS = (string.printable + "äöüßµ€→λ  中日")

    class _Strategies:
        @staticmethod
        def integers(min_value=0, max_value=1 << 16):
            return _Strategy(lambda r: r.randint(min_value, max_value))

        @staticmethod
        def text(min_size=0, max_size=20):
            def draw(r):
                n = r.randint(min_size, max_size)
                return "".join(r.choice(_CHARS) for _ in range(n))
            return _Strategy(draw)

        @staticmethod
        def lists(elements, min_size=0, max_size=10):
            def draw(r):
                n = r.randint(min_size, max_size)
                return [elements.example(r) for _ in range(n)]
            return _Strategy(draw)

    strategies = _Strategies()

    def settings(max_examples: int = 25, **_ignored):
        def deco(fn):
            fn._max_examples = max_examples
            return fn
        return deco

    def given(*strats):
        def deco(fn):
            @functools.wraps(fn)
            def run(*args, **kwargs):
                n = getattr(run, "_max_examples",
                            getattr(fn, "_max_examples", 25))
                rng = random.Random(0)
                for _ in range(n):
                    vals = [s.example(rng) for s in strats]
                    fn(*args, *vals, **kwargs)
            # pytest must see run()'s own (empty) signature, not unwrap to
            # fn and treat the property arguments as fixtures
            del run.__wrapped__
            return run
        return deco
