"""Data pipeline: tokenizer round-trip, packing invariants (property-based;
hypothesis when installed, deterministic example loops otherwise)."""
import numpy as np
from _propcheck import given, settings, strategies as st

from repro.data import CodeCompletionDataset, CodeGenerator
from repro.data.pipeline import pack_sequences, sample_context_split
from repro.data.tokenizer import EOS, PAD, CodeTokenizer


def test_generator_deterministic():
    a = CodeGenerator("java", 3).generate_file()
    b = CodeGenerator("java", 3).generate_file()
    assert a == b
    c = CodeGenerator("java", 4).generate_file()
    assert a != c


def test_tokenizer_roundtrip_corpus():
    for lang in ("java", "python"):
        files = [CodeGenerator(lang, i).generate_file() for i in range(5)]
        tok = CodeTokenizer.train(files, 1024)
        for f in files:
            assert tok.decode(tok.encode(f)) == f


@given(st.text(min_size=0, max_size=200))
@settings(max_examples=50, deadline=None)
def test_tokenizer_roundtrip_any_text(s):
    tok = CodeTokenizer.train(["def f(): return 1"], 512)
    assert tok.decode(tok.encode(s)) == s


@given(st.lists(st.lists(st.integers(min_value=4, max_value=99),
                         min_size=1, max_size=50),
                min_size=1, max_size=20),
       st.integers(min_value=8, max_value=64))
@settings(max_examples=30, deadline=None)
def test_packing_preserves_tokens(token_lists, seq_len):
    packed = pack_sequences(token_lists, seq_len)
    assert packed.shape[1] == seq_len
    flat = packed.reshape(-1).tolist()
    # remove trailing padding
    while flat and flat[-1] == PAD:
        flat.pop()
    expect = []
    for t in token_lists:
        expect.extend(t)
        expect.append(EOS)
    assert flat == expect


@given(st.integers(min_value=16, max_value=10_000))
@settings(max_examples=30, deadline=None)
def test_context_split_bounds(n):
    rng = np.random.default_rng(0)
    cut = sample_context_split(rng, n)
    assert 1 <= cut < n
    assert cut <= 0.6 * n + 1


def test_dataset_splits_disjoint_and_batches(mini_dataset):
    ds = mini_dataset
    n = sum(len(ds.tokens(s)) for s in ("train", "valid", "test"))
    assert n == len(ds.files)
    toks, labels, mask = next(ds.batches("train", 2))
    assert toks.shape == labels.shape == mask.shape
    np.testing.assert_array_equal(toks[:, 1:], labels[:, :-1])
