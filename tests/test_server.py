"""HTTP endpoint contract: request parsing into the shared dataclasses,
per-request policies, stop sequences (finish_reason "stop"), errors."""
import json
import re
import threading
import urllib.error
import urllib.request
from http.server import ThreadingHTTPServer

import pytest

from repro.obs import PROM_CONTENT_TYPE, Tracer, validate_exposition
from repro.serving import Scheduler
from repro.serving.server import Handler, _State


@pytest.fixture(scope="module")
def server(mini_cfg, mini_params, mini_dataset):
    _State.cfg = mini_cfg
    _State.params = mini_params
    _State.agent = None
    _State.tokenizer = mini_dataset.tokenizer
    _State.scheduler = Scheduler(
        mini_params, mini_cfg, controller_kind="none",
        allowed_kinds=("none", "fixed", "confidence"),
        tokenizer=mini_dataset.tokenizer,
        max_slots=2, max_len=96, max_new=8,
        prefill_chunk=16, tracer=Tracer()).start()
    srv = ThreadingHTTPServer(("127.0.0.1", 0), Handler)
    t = threading.Thread(target=srv.serve_forever, daemon=True)
    t.start()
    sched = _State.scheduler      # later fixtures may swap _State over
    yield f"http://127.0.0.1:{srv.server_address[1]}"
    srv.shutdown()
    sched.stop()
    _State.scheduler = None


def _post(url, payload, timeout=120.0):
    req = urllib.request.Request(
        f"{url}/generate", data=json.dumps(payload).encode(),
        headers={"Content-Type": "application/json"})
    with urllib.request.urlopen(req, timeout=timeout) as r:
        return json.loads(r.read())


def _gen(url, text, **params):
    return _post(url, {"inputs": text, "parameters": params})


PROMPT = "public static int add(int a, int b) { return "


def test_generate_basic(server):
    out = _gen(server, PROMPT, max_new_tokens=6)
    assert out["finish_reason"] in ("length", "eos")
    assert isinstance(out["generated_text"], str)
    assert 1 <= len(out["exit_layers"]) <= 6
    assert out["energy_j"] > 0


def test_policy_object_selects_per_request(server, mini_cfg):
    out = _gen(server, PROMPT, max_new_tokens=5,
               policy={"name": "fixed", "exit_idx": 0})
    assert out["exit_layers"][0] == mini_cfg.num_layers
    assert all(e < mini_cfg.num_layers for e in out["exit_layers"][1:])
    # legacy flat controller/threshold parameters still parse
    out = _gen(server, PROMPT, max_new_tokens=4, controller="confidence",
               threshold=1.01)
    assert all(e == mini_cfg.num_layers for e in out["exit_layers"])


def test_stop_sequence_truncates_and_reports_stop(server):
    free = _gen(server, PROMPT, max_new_tokens=8)
    full = free["generated_text"]
    # a fragment from inside one contiguous clean run of the RAW text —
    # slicing the de-�-ed string could span a replacement-char boundary
    # and never occur in the actual output
    runs = [m.group() for m in re.finditer(r"[^�]{2,}", full)]
    assert runs, "mini model produced no clean text to derive a stop from"
    best = max(runs, key=len)
    mid = best[len(best) // 2 - 1:len(best) // 2 + 1]
    out = _gen(server, PROMPT, max_new_tokens=8, stop=[mid])
    assert out["finish_reason"] == "stop"
    assert mid not in out["generated_text"]
    assert full.startswith(out["generated_text"])
    assert len(out["exit_layers"]) <= len(free["exit_layers"])


def test_legacy_threshold_ignored_by_thresholdless_default(server,
                                                           mini_cfg):
    """Seed-era clients send a flat threshold even when the default policy
    ('none' here) has no such knob — accepted and ignored, not a 400."""
    out = _gen(server, PROMPT, max_new_tokens=3, threshold=0.9)
    assert all(e == mini_cfg.num_layers for e in out["exit_layers"])


def test_out_of_range_seed_is_400_not_outage(server):
    with pytest.raises(urllib.error.HTTPError) as e:
        _gen(server, PROMPT, seed=2 ** 31)
    assert e.value.code == 400
    # the scheduler must still be alive afterwards
    out = _gen(server, PROMPT, max_new_tokens=2)
    assert out["finish_reason"] in ("length", "eos")


def test_unknown_policy_is_400(server):
    with pytest.raises(urllib.error.HTTPError) as e:
        _gen(server, PROMPT, controller="wat")
    assert e.value.code == 400
    body = json.loads(e.value.read())
    assert "unknown exit policy" in body["error"]


def test_policy_outside_compiled_set_is_400(server):
    with pytest.raises(urllib.error.HTTPError) as e:
        _gen(server, PROMPT, policy={"name": "entropy", "threshold": 0.5})
    assert e.value.code == 400
    assert "compiled set" in json.loads(e.value.read())["error"]


def test_sampling_params_parse_and_reproduce(server):
    kw = dict(max_new_tokens=6, temperature=0.9, top_k=8, seed=11)
    a = _gen(server, PROMPT, **kw)
    b = _gen(server, PROMPT, **kw)
    assert a["generated_text"] == b["generated_text"]
    c = _gen(server, PROMPT, **{**kw, "seed": 12})
    # different seed *may* coincide on tiny vocabs, but text is still valid
    assert isinstance(c["generated_text"], str)


def test_queue_stats_report_single_compile(server):
    with urllib.request.urlopen(f"{server}/queue", timeout=30) as r:
        st = json.loads(r.read())
    assert st["completed_requests"] >= 1
    assert st["step_compiles"] == 1
    assert set(st["controllers"]) == {"none", "fixed", "confidence"}


def test_stream_ndjson(server):
    req = urllib.request.Request(
        f"{server}/generate",
        data=json.dumps({"inputs": PROMPT,
                         "parameters": {"max_new_tokens": 4,
                                        "stream": True}}).encode(),
        headers={"Content-Type": "application/json"})
    with urllib.request.urlopen(req, timeout=120) as r:
        lines = [json.loads(ln) for ln in r.read().splitlines() if ln]
    assert len(lines) >= 2                     # token lines + final
    assert all("token" in ln for ln in lines[:-1])
    final = lines[-1]
    assert final["finish_reason"] in ("length", "eos")
    assert len(lines) - 1 == len(final["exit_layers"])
    assert final["truncated"] is False       # surfaced in the final record
    joined = "".join(ln["text"] for ln in lines[:-1])
    # the stream holds back trailing in-progress byte-fallback sequences
    assert final["generated_text"].startswith(joined)


def test_truncated_prompt_surfaces_in_response(server):
    """An over-long prompt is tail-clipped to the pool geometry; the
    response (and the NDJSON final record, same _req_json payload) must
    say so instead of silently dropping context."""
    out = _gen(server, PROMPT * 80, max_new_tokens=2)
    assert out["truncated"] is True
    assert _gen(server, PROMPT, max_new_tokens=2)["truncated"] is False


def test_unknown_get_path_is_404(server):
    """The seed server answered 200 {"status": "ok"} for ANY GET path —
    typos like /metricz read as healthy scrapes. Unknown paths are 404."""
    for path in ("/metricz", "/nope", "/queue/extra", "/generate"):
        with pytest.raises(urllib.error.HTTPError) as e:
            urllib.request.urlopen(f"{server}{path}", timeout=30)
        assert e.value.code == 404, path
    # the known roots still answer
    with urllib.request.urlopen(f"{server}/", timeout=30) as r:
        root = json.loads(r.read())
    assert root["status"] == "ok"
    assert root["scheduler"]["tracing"] is True


def test_metrics_prometheus_exposition(server):
    _gen(server, PROMPT, max_new_tokens=3)     # ensure traffic to report
    with urllib.request.urlopen(f"{server}/metrics", timeout=30) as r:
        assert r.headers["Content-Type"] == PROM_CONTENT_TYPE
        text = r.read().decode()
    summ = validate_exposition(text, {
        "repro_queue_depth", "repro_completed_requests",
        "repro_throughput_tok_s", "repro_dispatches", "repro_sync_points",
        "repro_lifetime_fleet_tokens", "repro_phase_seconds",
        "repro_events_total"})
    assert summ["lines"] > 10
    # phase histograms carry the per-phase label
    assert 'repro_phase_seconds_bucket{phase="decode_step",le=' in text
    assert 'repro_events_total{event="dispatch"}' in text


def test_trace_returns_and_drains_chrome_trace(server):
    from repro.obs import validate_chrome_trace
    _gen(server, PROMPT, max_new_tokens=3)     # ensure spans to drain
    with urllib.request.urlopen(f"{server}/trace", timeout=30) as r:
        trace = json.loads(r.read())
    assert trace["traceEvents"], "first GET /trace returned no events"
    # a live tick may straddle the drain boundary; structure still holds
    summ = validate_chrome_trace(trace, allow_partial=True)
    assert {"tick", "decode_step"} <= set(summ["span_names"])
    # drain semantics: an immediate second GET only has events from the
    # gap between the two requests (possibly none beyond metadata)
    with urllib.request.urlopen(f"{server}/trace", timeout=30) as r:
        again = json.loads(r.read())
    assert len(again["traceEvents"]) < len(trace["traceEvents"])


# ---------------------------------------------------------------------------
# fleet mode: N replicas behind the router, same HTTP surface
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def fleet_server(mini_cfg, mini_params, mini_dataset):
    """The endpoint in --replicas 2 mode (defined after the single-server
    tests: _State is process-global, so the fixtures take turns)."""
    from repro.serving import Router
    _State.cfg = mini_cfg
    _State.params = mini_params
    _State.agent = None
    _State.tokenizer = mini_dataset.tokenizer

    def make_scheduler(rid):
        return Scheduler(mini_params, mini_cfg, controller_kind="none",
                         allowed_kinds=("none", "fixed"),
                         tokenizer=mini_dataset.tokenizer,
                         max_slots=2, max_len=96, max_new=8,
                         prefill_chunk=16, tracer=Tracer())

    router = Router(make_scheduler, n_replicas=2,
                    placement="energy").start()
    _State.scheduler = router
    srv = ThreadingHTTPServer(("127.0.0.1", 0), Handler)
    t = threading.Thread(target=srv.serve_forever, daemon=True)
    t.start()
    yield f"http://127.0.0.1:{srv.server_address[1]}"
    srv.shutdown()
    router.stop()
    _State.scheduler = None


def test_fleet_root_reports_fleet_shape(fleet_server):
    with urllib.request.urlopen(f"{fleet_server}/", timeout=30) as r:
        root = json.loads(r.read())
    assert root["status"] == "ok"
    info = root["scheduler"]
    assert info["replicas"] == 2
    assert info["placement"] == "energy"
    assert info["max_slots"] == 4          # aggregate across replicas
    assert info["tracing"] is True


def test_fleet_generate_and_queue_per_replica_breakdown(fleet_server):
    for _ in range(3):                     # traffic for both replicas
        out = _gen(fleet_server, PROMPT, max_new_tokens=3)
        assert out["finish_reason"] in ("length", "eos")
    with urllib.request.urlopen(f"{fleet_server}/queue", timeout=30) as r:
        st = json.loads(r.read())
    assert st["placement"] == "energy" and st["replicas"] == 2
    fl = st["fleet"]
    per = st["per_replica"]
    assert [p["replica_id"] for p in per] == [0, 1]
    for p in per:
        # the router's placement inputs are all inspectable per replica
        assert {"queue_depth", "active_slots", "power_w_ema",
                "blocked_admissions", "draining", "routed"} <= set(p)
        assert p["draining"] is False
    assert fl["completed_requests"] == sum(p["completed_requests"]
                                           for p in per) >= 3
    assert fl["max_slots"] == 4
    assert 0.0 <= fl["max_replica_energy_share"] <= 1.0


def test_fleet_metrics_labeled_exposition(fleet_server):
    _gen(fleet_server, PROMPT, max_new_tokens=2)
    with urllib.request.urlopen(f"{fleet_server}/metrics", timeout=30) as r:
        assert r.headers["Content-Type"] == PROM_CONTENT_TYPE
        text = r.read().decode()
    summ = validate_exposition(text, {
        "repro_fleet_fleet_tokens", "repro_fleet_queue_depth",
        "repro_fleet_placement_info", "repro_queue_depth",
        "repro_completed_requests", "repro_phase_seconds",
        "repro_events_total"})
    assert summ["lines"] > 20
    for rid in ("0", "1"):
        assert f'repro_queue_depth{{replica="{rid}"}}' in text
        assert f'repro_completed_requests{{replica="{rid}"}}' in text


def test_fleet_trace_merges_replicas_as_tid_groups(fleet_server):
    from repro.obs import validate_chrome_trace
    from repro.serving.fleet import TID_STRIDE
    _gen(fleet_server, PROMPT, max_new_tokens=2)
    with urllib.request.urlopen(f"{fleet_server}/trace", timeout=30) as r:
        trace = json.loads(r.read())
    assert trace["traceEvents"]
    validate_chrome_trace(trace, allow_partial=True)
    names = {e["args"]["name"] for e in trace["traceEvents"]
             if e.get("ph") == "M" and e.get("name") == "thread_name"}
    assert {"replica-0", "replica-1"} <= names
    tids = {e["tid"] for e in trace["traceEvents"] if e.get("ph") != "M"}
    assert any(t < TID_STRIDE for t in tids)      # replica 0 decoded
    # replica 1 has tracks iff it saw traffic; its metadata row is there
    # either way (asserted above) — don't flake on placement timing


# ---------------------------------------------------------------------------
# graceful shutdown: drain keeps streams alive, turns new work away
# ---------------------------------------------------------------------------
def test_graceful_shutdown_drains_streams_and_503s_new_work(
        mini_cfg, mini_params, mini_dataset):
    """server.shutdown(): begin_drain stops admissions (POST -> 503 while
    the drain runs, and the scheduler stays draining after), but an open
    NDJSON stream keeps emitting and still gets its final metrics record."""
    from repro.serving import server as server_mod
    prev = _State.scheduler
    _State.cfg, _State.params, _State.agent = mini_cfg, mini_params, None
    _State.tokenizer = mini_dataset.tokenizer
    sched = Scheduler(mini_params, mini_cfg, controller_kind="none",
                      allowed_kinds=("none",),
                      tokenizer=mini_dataset.tokenizer,
                      max_slots=1, max_len=96, max_new=16,
                      prefill_chunk=16).start()
    _State.scheduler = sched
    srv = ThreadingHTTPServer(("127.0.0.1", 0), Handler)
    threading.Thread(target=srv.serve_forever, daemon=True).start()
    url = f"http://127.0.0.1:{srv.server_address[1]}"
    lines, errors = [], []

    def stream():
        req = urllib.request.Request(
            f"{url}/generate",
            data=json.dumps({"inputs": PROMPT,
                             "parameters": {"max_new_tokens": 12,
                                            "stream": True}}).encode(),
            headers={"Content-Type": "application/json"})
        try:
            with urllib.request.urlopen(req, timeout=120) as r:
                lines.extend(json.loads(ln)
                             for ln in r.read().splitlines() if ln)
        except Exception as e:  # noqa: BLE001
            errors.append(e)

    t = threading.Thread(target=stream, daemon=True)
    t.start()
    # wait until the stream's request is actually in a slot, then start
    # the drain UNDER it (generous bound: this scheduler is fresh, so
    # its first admission pays the per-instance jit compiles)
    deadline = __import__("time").monotonic() + 120.0
    while (__import__("time").monotonic() < deadline
           and sched.pool.n_used == 0):
        __import__("time").sleep(0.005)
    assert sched.pool.n_used == 1, "stream request never started"
    sched.begin_drain()                       # what shutdown() issues first
    with pytest.raises(urllib.error.HTTPError) as e:
        _gen(url, PROMPT, max_new_tokens=2)
    assert e.value.code == 503
    assert "draining" in json.loads(e.value.read())["error"]
    # the bounded drain lets the open stream finish
    assert server_mod.shutdown(drain_timeout=60.0) is True
    t.join(60.0)
    assert not t.is_alive() and not errors, errors
    assert len(lines) == 13                   # 12 token lines + final
    assert lines[-1]["finish_reason"] in ("length", "eos")
    assert len(lines[-1]["exit_layers"]) == 12
    srv.shutdown()
    _State.scheduler = prev
