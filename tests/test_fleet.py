"""Fleet serving: placement policies, the replica router, graceful
lifecycle (spawn/drain/rebalance) and the routing-invariance property —
per-request output is bit-identical no matter which replica serves it.
"""
import threading
import time

import numpy as np
import pytest

from repro.api import SamplingParams
from repro.obs import Tracer, validate_chrome_trace, validate_exposition
from repro.serving import Router, Scheduler, SchedulerQueueFull
from repro.serving.fleet import (AFFINITY_SLACK, TID_STRIDE, EnergyHeadroom,
                                 LeastQueue, ReplicaSnapshot, RoundRobin,
                                 make_placement)


@pytest.fixture(scope="module")
def tiny_cfg():
    from repro.configs.llama32_3b import paper_mini
    return paper_mini(num_layers=4, d_model=64, vocab_size=256)


@pytest.fixture(scope="module")
def tiny_params(tiny_cfg):
    import jax

    from repro.models import transformer as T
    return T.init_params(jax.random.PRNGKey(0), tiny_cfg)


def _snap(rid, queue=0, active=0, ema=0.0, budget=None, prefilling=False):
    return ReplicaSnapshot(replica_id=rid, queue_depth=queue,
                           active_slots=active, prefilling=prefilling,
                           power_w_ema=ema, power_budget_w=budget)


# ---------------------------------------------------------------------------
# placement policies (pure — no schedulers)
# ---------------------------------------------------------------------------
def test_round_robin_cycles_over_snapshot_order():
    pol = RoundRobin()
    snaps = [_snap(0), _snap(2), _snap(5)]
    assert [pol.choose(snaps) for _ in range(6)] == [0, 2, 5, 0, 2, 5]


def test_least_queue_counts_queue_active_and_prefill():
    pol = LeastQueue()
    assert pol.choose([_snap(0, queue=2), _snap(1, queue=1, active=2)]) == 0
    # prefill stream in flight counts as one unit of load
    assert pol.choose([_snap(0, queue=1), _snap(1, active=1,
                                                prefilling=True)]) == 0
    # ties break to the lowest replica id
    assert pol.choose([_snap(1, queue=1), _snap(0, queue=1)]) == 0


def test_energy_routes_to_most_headroom():
    pol = EnergyHeadroom()
    # budgets set: headroom = budget - committed power
    assert pol.choose([_snap(0, active=1, ema=9.0, budget=10.0),
                       _snap(1, active=1, ema=2.0, budget=10.0)]) == 1
    # no budget: most headroom = coolest committed power
    assert pol.choose([_snap(0, active=1, ema=1.0),
                       _snap(1, active=1, ema=3.0)]) == 0


def test_energy_committed_power_sees_through_the_lagging_ema():
    """The EMA is a lagging signal: a replica with a deep queue still
    reads cool until that work starts decoding. Committed power projects
    each queued request at the cost of a current resident, so the
    raw-EMA-cooler-but-deeply-queued replica must LOSE the placement."""
    pol = EnergyHeadroom()
    cool_but_queued = _snap(0, queue=4, active=1, ema=2.0)   # -> 10 W
    warm_but_empty = _snap(1, queue=0, active=1, ema=3.0)    # ->  3 W
    assert cool_but_queued.committed_power_w == pytest.approx(10.0)
    assert warm_but_empty.committed_power_w == pytest.approx(3.0)
    assert pol.choose([cool_but_queued, warm_but_empty]) == 1


def test_energy_idle_fleet_balances_cumulative_joules():
    """Under paced arrivals the whole fleet reads idle at routing time:
    the EMAs carry decayed residue, not signal, and chasing them herds
    the entire workload onto one replica. A fully idle fleet must
    balance the window's cumulative joules (coolest history wins); any
    live work anywhere must flip back to committed-power headroom."""
    pol = EnergyHeadroom()
    # everything idle: the replica that burned less this window wins,
    # even though its EMA residue reads warmer right now
    warm_residue_but_rested = _snap(0, ema=1.1)
    cool_residue_but_worked = _snap(1, ema=1.0)
    warm_residue_but_rested.energy_j = 5.0
    cool_residue_but_worked.energy_j = 25.0
    assert pol.choose([warm_residue_but_rested,
                       cool_residue_but_worked]) == 0
    # one live resident anywhere: headroom decides again, and any
    # cumulative-joules deficit is irrelevant
    busy = _snap(0, active=1, ema=3.0)
    idle = _snap(1, ema=1.0)
    idle.energy_j = 1000.0
    assert pol.choose([busy, idle]) == 1


def test_scheduler_snapshot_decays_stale_ema_while_idle():
    """The power EMA only blends on decode ticks, so an idle scheduler's
    EMA freezes at whatever it last burned — placement_snapshot must
    report it decayed by the idle time, or a frozen-high warmup EMA
    repels placements forever."""
    import time as _time

    from repro.serving.scheduler import Scheduler as _S

    sched = object.__new__(_S)                 # snapshot-only fields
    sched._lock = __import__("threading").Lock()
    sched._queue = []
    sched._prefill_job = None
    sched._blocked_admissions = 0
    sched._fleet_energy_j = 0.0
    sched.power_budget_w = None
    sched.pool = type("P", (), {"n_used": 0})()
    sched._power_w_ema = 50.0
    sched._power_ema_t = _time.monotonic()
    fresh = sched.placement_snapshot()["power_w_ema"]
    assert fresh == pytest.approx(50.0, rel=0.01)
    sched._power_ema_t = _time.monotonic() - 30.0       # 30 s idle
    stale = sched.placement_snapshot()["power_w_ema"]
    assert stale < 50.0 * 0.9 ** 29
    assert sched._power_w_ema == 50.0          # the gate's own EMA is untouched


def test_energy_cold_fleet_ties_break_to_least_loaded():
    """Before any EMA diverges (a cold fleet) every headroom is equal —
    placements must still spread by load instead of pinning replica 0."""
    pol = EnergyHeadroom()
    assert pol.choose([_snap(0, queue=1), _snap(1), _snap(2, queue=2)]) == 1


def test_energy_affinity_wins_within_slack_only():
    pol = EnergyHeadroom()
    snaps = [_snap(0, active=1, ema=10.0), _snap(1, active=1, ema=11.0)]
    # replica 1's headroom (-11) is within 25% of the best (-10): the
    # warm prefix pulls the request home
    assert pol.choose(snaps, prefix_home=1) == 1
    # far outside the slack band the affinity must NOT override
    snaps = [_snap(0, active=1, ema=10.0),
             _snap(1, active=1, ema=10.0 * (1 + AFFINITY_SLACK) + 1.0)]
    assert pol.choose(snaps, prefix_home=1) == 0
    # a home that drained away is ignored
    assert pol.choose(snaps, prefix_home=7) == 0


def test_make_placement_factory():
    assert isinstance(make_placement("rr"), RoundRobin)
    assert isinstance(make_placement("least_queue"), LeastQueue)
    assert isinstance(make_placement("energy"), EnergyHeadroom)
    # fresh state per instance (rr carries a cursor)
    assert make_placement("rr") is not make_placement("rr")
    with pytest.raises(ValueError, match="unknown placement"):
        make_placement("wat")


# ---------------------------------------------------------------------------
# virtual-clock fleet trace (deterministic, CI hard-gates it)
# ---------------------------------------------------------------------------
def test_fleet_trace_deterministic_and_energy_beats_rr(tiny_cfg):
    """Two replays of the routing trace must be byte-identical per policy
    (pure function of workload + geometry + policy: no wall clock), and
    the energy-headroom policy must end with a lower max-replica energy
    share than cost-blind round-robin on the class-mixed workload."""
    from benchmarks.serving_load import run_fleet_trace
    kw = dict(n_replicas=2, slots=1, n=32, seed=0)
    a = run_fleet_trace(tiny_cfg, **kw)
    b = run_fleet_trace(tiny_cfg, **kw)
    for policy in ("rr", "least_queue", "energy"):
        assert a[policy] == b[policy], \
            f"{policy} fleet trace is not deterministic"
        ev = a[policy]["events"]
        for kind in ("route", "admit", "retire"):
            assert sum(1 for e in ev if e[1] == kind) == 32, (policy, kind)
        assert all(e[3] in (0, 1) for e in ev)
        share = a[policy]["max_replica_energy_share"]
        assert 0.5 <= share <= 1.0          # 2 replicas: 0.5 is perfect
    assert a["energy_beats_rr"], (
        a["energy"]["max_replica_energy_share"],
        a["rr"]["max_replica_energy_share"])


# ---------------------------------------------------------------------------
# live router (shared 2-replica fleet)
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def router(tiny_params, tiny_cfg):
    def make_scheduler(rid):
        return Scheduler(tiny_params, tiny_cfg, controller_kind="fixed",
                         fixed_exit_idx=0, allowed_kinds=("none", "fixed"),
                         max_slots=2, max_len=64, max_new=8,
                         queue_depth=16, tracer=Tracer())
    r = Router(make_scheduler, n_replicas=2, placement="energy").start()
    yield r
    r.stop()


def _prompts(vocab, lens, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(4, vocab, size=n).tolist() for n in lens]


def test_router_serves_and_spreads_a_cold_fleet(router, tiny_cfg):
    handles = [router.submit(p, max_new=4)
               for p in _prompts(tiny_cfg.vocab_size, [8, 10, 12, 14])]
    for h in handles:
        h.result(timeout=120.0)
        assert len(h.tokens) == 4
        assert h.replica_id in (0, 1)
        assert not h.rebalanced
    # the cold-fleet load tiebreak must have used both replicas
    assert {h.replica_id for h in handles} == {0, 1}
    # distinct fleet ids, monotonic submission order
    ids = [h.fleet_id for h in handles]
    assert ids == sorted(ids) and len(set(ids)) == 4


def test_submit_pinned_replica(router, tiny_cfg):
    p = _prompts(tiny_cfg.vocab_size, [9], seed=3)[0]
    h = router.submit(p, max_new=2, replica_id=1)
    h.result(timeout=120.0)
    assert h.replica_id == 1
    with pytest.raises(KeyError):
        router.submit(p, max_new=2, replica_id=99)


def test_fleet_request_stream_survives_delegation(router, tiny_cfg):
    p = _prompts(tiny_cfg.vocab_size, [11], seed=4)[0]
    h = router.submit(p, max_new=5)
    toks = list(h.stream(timeout=120.0))
    h.result(timeout=10.0)
    assert toks == list(h.tokens) and len(toks) == 5
    # __getattr__ delegation to the inner Request
    assert h.status == "done" and h.energy_j > 0


def test_fleet_stats_sections_and_aggregates(router):
    st = router.stats()
    assert st["placement"] == "energy"
    assert st["replicas"] == 2
    per = st["per_replica"]
    assert [p["replica_id"] for p in per] == [0, 1]
    for p in per:
        assert p["draining"] is False
        assert p["routed"] >= 1
        assert {"queue_depth", "active_slots", "power_w_ema",
                "blocked_admissions"} <= set(p)
    fl = st["fleet"]
    assert fl["max_slots"] == sum(p["max_slots"] for p in per) == 4
    assert fl["fleet_tokens"] == sum(p["fleet_tokens"] for p in per) > 0
    assert fl["fleet_energy_j"] == pytest.approx(
        sum(p["fleet_energy_j"] for p in per))
    assert 0.5 <= fl["max_replica_energy_share"] <= 1.0
    assert fl["completed_requests"] == sum(p["completed_requests"]
                                           for p in per)
    assert fl["rebalanced_requests"] == 0
    assert fl["throughput_tok_s"] > 0 and fl["fleet_j_per_token"] > 0


def test_fleet_prometheus_labeled_series_validate(router):
    text = router.prometheus()
    summ = validate_exposition(text, {
        "repro_fleet_fleet_tokens", "repro_fleet_queue_depth",
        "repro_fleet_max_replica_energy_share", "repro_fleet_placement_info",
        "repro_queue_depth", "repro_completed_requests",
        "repro_phase_seconds", "repro_events_total"})
    assert summ["lines"] > 20
    assert 'repro_queue_depth{replica="0"}' in text
    assert 'repro_queue_depth{replica="1"}' in text
    assert 'repro_fleet_placement_info{placement="energy"} 1' in text
    assert 'repro_phase_seconds_bucket{replica="0",phase="decode_step"' \
        in text
    # the validator rejects duplicate series, so one pass over the fleet
    # exposition is also the no-collision proof for the label scheme
    assert text.count("# TYPE repro_queue_depth ") == 1


def test_fleet_merged_trace_has_replica_tid_groups(router, tiny_cfg):
    for p in _prompts(tiny_cfg.vocab_size, [8, 8], seed=5):
        router.submit(p, max_new=2).result(timeout=120.0)
    events = router.drain_events()
    summ = validate_chrome_trace({"traceEvents": events},
                                 allow_partial=True)
    assert {"tick", "decode_step"} <= set(summ["span_names"])
    names = {(e["tid"], e["args"]["name"]) for e in events
             if e.get("ph") == "M" and e.get("name") == "thread_name"}
    assert {(0, "replica-0"), (TID_STRIDE, "replica-1")} <= names
    tids = {e["tid"] for e in events if e.get("ph") != "M"}
    assert any(t < TID_STRIDE for t in tids)          # replica 0's tracks
    assert any(t >= TID_STRIDE for t in tids)         # replica 1's tracks
    # drain semantics match the single tracer: a second drain is ~empty
    assert len(router.drain_events()) < len(events)


# ---------------------------------------------------------------------------
# routing invariance: output never depends on where a request runs
# ---------------------------------------------------------------------------
def test_routing_invariance_bit_identical_outputs(tiny_params, tiny_cfg):
    """The fleet contract GREEN-CODE's serving story leans on: sampling
    is keyed by (request seed, position) — never by batch composition or
    replica identity — so the SAME requests produce bit-identical tokens
    and logprobs on a solo scheduler and under every placement policy and
    replica count."""
    prompts = _prompts(tiny_cfg.vocab_size, [8, 12, 10, 14, 9, 13], seed=7)
    sampls = [SamplingParams(temperature=0.8, top_k=8, seed=100 + i)
              for i in range(len(prompts))]

    def serve(sched):
        hs = [sched.submit(p, max_new=6, sampling=s)
              for p, s in zip(prompts, sampls)]
        out = []
        for h in hs:
            h.result(timeout=120.0)
            out.append((list(h.tokens), list(h.logprobs)))
        return out

    def make_scheduler(rid=0):
        return Scheduler(tiny_params, tiny_cfg, controller_kind="fixed",
                         fixed_exit_idx=0, allowed_kinds=("none", "fixed"),
                         max_slots=2, max_len=64, max_new=8, queue_depth=16)

    solo = make_scheduler().start()
    try:
        want = serve(solo)
    finally:
        solo.stop()
    fleets = [("rr", 2), ("least_queue", 2), ("energy", 2), ("energy", 3)]
    for placement, n_replicas in fleets:
        router = Router(make_scheduler, n_replicas=n_replicas,
                        placement=placement).start()
        try:
            got = serve(router)
        finally:
            router.stop()
        assert got == want, (placement, n_replicas)


# ---------------------------------------------------------------------------
# lifecycle: spawn, drain, rebalance
# ---------------------------------------------------------------------------
def test_drain_replica_rebalances_queued_requests(tiny_params, tiny_cfg):
    """Draining a replica steals its queued-but-unstarted requests and
    resubmits them on the surviving replicas; the FleetRequest handles
    rebind transparently and every request still completes."""
    def make_scheduler(rid):
        return Scheduler(tiny_params, tiny_cfg, controller_kind="fixed",
                         fixed_exit_idx=0, allowed_kinds=("none", "fixed"),
                         max_slots=1, max_len=64, max_new=16,
                         queue_depth=16)
    router = Router(make_scheduler, n_replicas=2,
                    placement="least_queue").start()
    try:
        prompts = _prompts(tiny_cfg.vocab_size, [8, 10, 12, 14], seed=9)
        # pin everything to replica 0: one runs, the rest queue behind
        # its single slot
        handles = [router.submit(p, max_new=12, replica_id=0)
                   for p in prompts]
        moved = router.drain_replica(0, timeout=60.0)
        assert moved >= 1, "nothing was queued when the drain started"
        assert router.replica_ids == [1]
        for h in handles:
            h.result(timeout=120.0)
            assert len(h.tokens) == 12
        rebound = [h for h in handles if h.rebalanced]
        assert len(rebound) == moved
        assert all(h.replica_id == 1 for h in rebound)
        assert router.stats()["fleet"]["rebalanced_requests"] == moved
        # draining the last live replica is refused
        with pytest.raises(ValueError, match="last live replica"):
            router.drain_replica(1)
        # spawn restores capacity under a fresh id
        rid = router.spawn_replica()
        assert rid == 2 and router.replica_ids == [1, 2]
        h = router.submit(prompts[0], max_new=2, replica_id=2)
        h.result(timeout=120.0)
        assert len(h.tokens) == 2
    finally:
        router.stop()


def test_router_graceful_drain_finishes_queued_work(tiny_params, tiny_cfg):
    """Router.drain: admissions stop fleet-wide (submit -> queue-full,
    the server's 503), but already-queued requests still run to
    completion before the decode loops stop."""
    def make_scheduler(rid):
        return Scheduler(tiny_params, tiny_cfg, controller_kind="fixed",
                         fixed_exit_idx=0, allowed_kinds=("none", "fixed"),
                         max_slots=1, max_len=64, max_new=8, queue_depth=8)
    router = Router(make_scheduler, n_replicas=2, placement="rr").start()
    prompts = _prompts(tiny_cfg.vocab_size, [8, 10, 12, 14], seed=11)
    handles = [router.submit(p, max_new=6) for p in prompts]
    done = threading.Event()
    result = {}

    def drainer():
        result["clean"] = router.drain(timeout=60.0)
        done.set()

    threading.Thread(target=drainer, daemon=True).start()
    # the drain begins immediately; new work is turned away while queued
    # work keeps decoding
    deadline = time.monotonic() + 10.0
    while time.monotonic() < deadline and len(router) > 0:
        time.sleep(0.005)
    with pytest.raises((SchedulerQueueFull, RuntimeError)):
        router.submit(prompts[0], max_new=1)
    assert done.wait(90.0)
    assert result["clean"] is True
    for h in handles:
        h.result(timeout=1.0)              # already finished by the drain
        assert len(h.tokens) == 6 and h.status == "done"
