"""Observability layer: tracer no-op fast path and overhead bound, span
nesting/bracketing under a deterministic clock, device-wait vs host
attribution, Chrome-trace structural validation, Prometheus exposition,
and a real traced serving run (the CI fast-job gate: every B has an E,
phases nest under ticks, /metrics families present)."""
import json
import time

import numpy as np
import pytest

from repro.obs import (NULL_TRACER, PROM_CONTENT_TYPE, Tracer,
                       TraceValidationError, make_step_clock,
                       render_prometheus, summarize_spans, to_chrome_trace,
                       validate_chrome_trace, validate_exposition)


# ---------------------------------------------------------------------------
# disabled tracer: a no-op, and a cheap one
# ---------------------------------------------------------------------------
def test_disabled_tracer_retains_nothing():
    tr = Tracer(enabled=False)
    with tr.span("tick", cat="tick"):
        with tr.span("decode_step"):
            with tr.wait():
                pass
    tr.count("dispatch")
    tr.instant("mark")
    tr.async_begin("req/queued", 1)
    tr.async_end("req/queued", 1)
    assert tr.drain() == []
    assert tr.counters == {}
    assert tr.histograms() == {}
    assert tr.phase_summary() == {}


def test_disabled_tracer_shares_one_null_context():
    tr = Tracer(enabled=False)
    assert tr.span("a") is tr.span("b") is tr.wait()   # no allocation
    assert NULL_TRACER.span("x") is tr.span("y")       # module-wide


def test_disabled_tracer_never_reads_the_clock():
    calls = {"n": 0}

    def clock():
        calls["n"] += 1
        return 0.0

    tr = Tracer(enabled=False, clock=clock)
    for _ in range(100):
        with tr.span("tick"):
            tr.count("x")
    assert calls["n"] == 0


def test_disabled_tracer_overhead_bounded():
    """The scheduler calls span()/wait()/count() on every tick; disabled
    tracing must stay in no-op territory (~µs/op, generously bounded for
    shared CI runners)."""
    tr = Tracer(enabled=False)
    t0 = time.monotonic()
    for _ in range(100_000):
        with tr.span("decode_step"):
            tr.count("dispatch")
    assert time.monotonic() - t0 < 2.0


def test_wait_context_always_runs_the_body():
    """wait() only times; the guarded fetch must execute either way."""
    ran = []
    with Tracer(enabled=False).wait():
        ran.append("off")
    with Tracer(enabled=True).wait():
        ran.append("on")
    assert ran == ["off", "on"]


# ---------------------------------------------------------------------------
# span structure under a deterministic clock
# ---------------------------------------------------------------------------
def _emit_two_ticks(tr):
    with tr.span("tick", cat="tick"):
        with tr.span("admit"):
            pass
        with tr.span("decode_step"):
            with tr.wait():
                pass
        with tr.span("sample_host"):
            pass
    with tr.span("tick", cat="tick"):
        with tr.span("decode_step", slot=1):
            pass


def test_span_nesting_and_ordering_deterministic():
    def trace_once():
        tr = Tracer(clock=make_step_clock())
        _emit_two_ticks(tr)
        return tr.drain()

    a, b = trace_once(), trace_once()
    assert json.dumps(a) == json.dumps(b)      # byte-identical replays
    summ = validate_chrome_trace(a)
    assert summ["spans"] == 6
    assert summ["span_names"] == ["admit", "decode_step", "sample_host",
                                  "tick"]
    # B/E bracket order is the call order
    seq = [(e["ph"], e["name"]) for e in a]
    assert seq[:4] == [("B", "tick"), ("B", "admit"), ("E", "admit"),
                       ("B", "decode_step")]
    # microsecond timestamps strictly increase under the step clock
    ts = [e["ts"] for e in a]
    assert ts == sorted(ts) and len(set(ts)) == len(ts)


def test_wait_splits_device_and_host_time():
    clock = make_step_clock(step_s=1.0)        # 1 simulated second per read
    tr = Tracer(clock=clock)
    with tr.span("decode_step"):
        with tr.wait():                        # 1 clock read inside wait
            pass
    (end,) = [e for e in tr.drain() if e["ph"] == "E"]
    dur = end["args"]["device_wait_s"] + end["args"]["host_s"]
    assert end["args"]["device_wait_s"] == pytest.approx(1.0)  # one wait
    assert dur == pytest.approx(3.0)           # span B..E spans 3 reads
    assert end["args"]["host_s"] == pytest.approx(2.0)
    assert tr.counters["sync_points"] == 1
    h = tr.histograms()["decode_step"]
    assert h.count == 1
    assert h.device_wait_sum == pytest.approx(1.0)


def test_wait_attributes_to_innermost_open_span():
    tr = Tracer(clock=make_step_clock())
    with tr.span("tick", cat="tick"):
        with tr.span("sample_host"):
            with tr.wait():
                pass
    ends = {e["name"]: e["args"] for e in tr.drain() if e["ph"] == "E"}
    assert ends["sample_host"]["device_wait_s"] > 0
    assert ends["tick"]["device_wait_s"] == 0  # not double-counted


def test_counters_and_event_cap():
    tr = Tracer(clock=make_step_clock(), max_events=4)
    for _ in range(5):
        tr.count("dispatch")
        with tr.span("t", cat="tick"):
            pass
    assert tr.counters["dispatch"] == 5        # counters are uncapped
    assert len(tr.drain()) == 4                # events stop at the cap
    assert tr.dropped_events == 6


def test_drain_clears_events_keeps_aggregates():
    tr = Tracer(clock=make_step_clock())
    with tr.span("tick", cat="tick"):
        pass
    assert len(tr.drain()) == 2
    assert tr.drain() == []                    # windowed
    assert tr.histograms()["tick"].count == 1  # cumulative survives
    summary = tr.phase_summary()
    assert summary["tick"]["count"] == 1
    assert summary["tick"]["total_s"] > 0


def test_summarize_spans_matches_phase_summary():
    tr = Tracer(clock=make_step_clock())
    _emit_two_ticks(tr)
    windowed = summarize_spans(tr.drain())
    cumulative = tr.phase_summary()
    assert set(windowed) == set(cumulative)
    for name in windowed:
        assert windowed[name]["count"] == cumulative[name]["count"]
        assert windowed[name]["total_s"] == pytest.approx(
            cumulative[name]["total_s"])
        assert windowed[name]["device_wait_s"] == pytest.approx(
            cumulative[name]["device_wait_s"])


# ---------------------------------------------------------------------------
# Chrome trace validation: what it accepts and what it must catch
# ---------------------------------------------------------------------------
def test_chrome_trace_wrapping_and_metadata():
    tr = Tracer(clock=make_step_clock())
    _emit_two_ticks(tr)
    obj = to_chrome_trace(tr.drain(), process_name="test-proc")
    assert obj["displayTimeUnit"] == "ms"
    meta = obj["traceEvents"][0]
    assert meta["ph"] == "M" and meta["args"]["name"] == "test-proc"
    assert all("pid" in e for e in obj["traceEvents"])
    validate_chrome_trace(obj)                 # dict form accepted too


def test_validation_catches_unclosed_span():
    tr = Tracer(clock=make_step_clock())
    ctx = tr.span("tick", cat="tick")
    ctx.__enter__()                            # never exited
    with pytest.raises(TraceValidationError, match="unclosed"):
        validate_chrome_trace(tr.drain())


def test_validation_catches_mismatched_end():
    events = [
        {"ph": "B", "ts": 1, "tid": 0, "name": "a", "cat": "tick"},
        {"ph": "E", "ts": 2, "tid": 0, "name": "b", "cat": "tick"},
    ]
    with pytest.raises(TraceValidationError, match="does not match"):
        validate_chrome_trace(events)
    # a mid-window mismatch is corruption even in partial mode
    with pytest.raises(TraceValidationError, match="does not match"):
        validate_chrome_trace(events, allow_partial=True)


def test_validation_catches_phase_outside_tick():
    events = [
        {"ph": "B", "ts": 1, "tid": 0, "name": "decode_step",
         "cat": "phase"},
        {"ph": "E", "ts": 2, "tid": 0, "name": "decode_step",
         "cat": "phase"},
    ]
    with pytest.raises(TraceValidationError, match="outside a tick"):
        validate_chrome_trace(events)
    validate_chrome_trace(events, require_tick_nesting=False)


def test_validation_catches_backwards_timestamps():
    events = [
        {"ph": "B", "ts": 5, "tid": 0, "name": "t", "cat": "tick"},
        {"ph": "E", "ts": 4, "tid": 0, "name": "t", "cat": "tick"},
    ]
    with pytest.raises(TraceValidationError, match="backwards"):
        validate_chrome_trace(events)


def test_validation_partial_mode_tolerates_window_cut():
    """A drained window of a live scheduler may cut a tick in half on
    both edges; partial mode accepts the edges, full mode refuses."""
    tr = Tracer(clock=make_step_clock())
    _emit_two_ticks(tr)
    events = tr.drain()
    cut = events[3:-1]                         # drop B(tick)..B(admit)+last E
    with pytest.raises(TraceValidationError):
        validate_chrome_trace(cut)
    summ = validate_chrome_trace(cut, allow_partial=True)
    assert summ["partial_ends"] > 0 or summ["partial_begins"] > 0


# ---------------------------------------------------------------------------
# Prometheus exposition
# ---------------------------------------------------------------------------
def test_render_prometheus_scalars_and_lifetime():
    stats = {"queue_depth": 3, "throughput_tok_s": 118.4, "tracing": True,
             "kv_layout": "paged", "controllers": ["none"],
             "lifetime": {"fleet_tokens": 42, "uptime_s": 1.5}}
    text = render_prometheus(stats)
    assert "repro_queue_depth 3\n" in text
    assert "repro_throughput_tok_s 118.4\n" in text
    assert "repro_tracing 1\n" in text                  # bool -> 0/1
    assert "repro_lifetime_fleet_tokens 42\n" in text
    assert "kv_layout" not in text                      # strings skipped
    assert "controllers" not in text                    # lists skipped
    validate_exposition(text, {"repro_queue_depth",
                               "repro_lifetime_fleet_tokens"})


def test_render_prometheus_histograms_and_counters():
    tr = Tracer(clock=make_step_clock())
    _emit_two_ticks(tr)
    tr.count("dispatch", 7)
    text = render_prometheus({}, tr)
    assert '# TYPE repro_phase_seconds histogram' in text
    assert 'repro_phase_seconds_bucket{phase="decode_step",le="+Inf"} 2' \
        in text
    assert 'repro_phase_seconds_count{phase="decode_step"} 2' in text
    assert 'repro_events_total{event="dispatch"} 7' in text
    assert 'repro_events_total{event="sync_points"} 1' in text
    summ = validate_exposition(text, {"repro_phase_seconds",
                                      "repro_events_total"})
    assert summ["lines"] > 10
    assert "text/plain" in PROM_CONTENT_TYPE


def test_validate_exposition_rejects_garbage():
    with pytest.raises(ValueError, match="bad exposition line"):
        validate_exposition("this is not a metric line")
    with pytest.raises(ValueError, match="missing"):
        validate_exposition("repro_x 1", {"repro_absent_family"})


def test_validate_exposition_rejects_duplicate_series():
    """Prometheus silently keeps one of two identical series — a renderer
    bug (a fleet family emitted once per replica without a replica label)
    must fail validation, not ship. Series identity is name + label SET:
    label order must not disguise a duplicate."""
    with pytest.raises(ValueError, match="duplicate series"):
        validate_exposition("repro_x 1\nrepro_x 2")
    with pytest.raises(ValueError, match="duplicate series"):
        validate_exposition('repro_x{replica="0"} 1\n'
                            'repro_x{replica="0"} 2')
    with pytest.raises(ValueError, match="duplicate series"):
        validate_exposition('repro_x{a="1",replica="0"} 1\n'
                            'repro_x{replica="0",a="1"} 2')
    # distinct label values are distinct series — the fleet layout
    validate_exposition('repro_x{replica="0"} 1\nrepro_x{replica="1"} 2')


# ---------------------------------------------------------------------------
# the real thing: a traced serving run (also the CI fast-job gate)
# ---------------------------------------------------------------------------
def test_traced_scheduler_run_validates_end_to_end(mini_cfg, mini_params):
    from repro.serving import Scheduler
    tr = Tracer()
    s = Scheduler(mini_params, mini_cfg, controller_kind="fixed",
                  fixed_exit_idx=0, allowed_kinds=("none", "fixed"),
                  max_slots=2, max_len=64, max_new=6,
                  prefill_chunk=16, tracer=tr).start()
    rng = np.random.default_rng(0)
    reqs = [s.submit(rng.integers(4, mini_cfg.vocab_size, 20).tolist(),
                     max_new=6) for _ in range(3)]
    for r in reqs:
        r.result(timeout=120.0)
    st = s.stats()
    s.stop()                                   # drain closes every span
    events = tr.drain()
    summ = validate_chrome_trace(events)       # strict: full run captured
    assert {"tick", "admit", "prefill_chunk", "decode_step", "sample_host",
            "bookkeeping", "retire", "drain"} <= set(summ["span_names"])
    assert summ["partial_begins"] == 0 and summ["partial_ends"] == 0
    # dispatch / sync accounting reached stats()
    assert st["tracing"] is True
    assert st["dispatches"] > 0
    assert st["sync_points"] > 0
    assert tr.counters["dispatch"] == st["dispatches"]
    # per-request lifecycle: queued -> prefill -> decode, begin/end paired
    async_evs = [e for e in events if e["ph"] in ("b", "e")]
    for req in reqs:
        mine = [e for e in async_evs if e["id"] == req.req_id]
        names = [e["name"] for e in mine]
        assert names == ["req/queued", "req/queued", "req/prefill",
                         "req/prefill", "req/decode", "req/decode"]
        phs = [e["ph"] for e in mine]
        assert phs == ["b", "e", "b", "e", "b", "e"]
        final = mine[-1]["args"]
        assert final["tokens"] == len(req.tokens)
        assert final["energy_j"] == pytest.approx(req.energy_j)
        assert final["finish_reason"] == req.finish_reason
    # phase device-wait never exceeds phase wall time
    for name, ph in tr.phase_summary().items():
        assert ph["device_wait_s"] <= ph["total_s"] + 1e-9, name
    # the exposition the server's /metrics would serve
    validate_exposition(render_prometheus(st, tr),
                        {"repro_phase_seconds", "repro_events_total",
                         "repro_dispatches", "repro_sync_points",
                         "repro_lifetime_fleet_tokens"})


def test_traced_speculative_run_has_draft_and_verify_spans(mini_cfg,
                                                           mini_params):
    from repro.core.exit_points import num_exits
    from repro.api import PolicySpec
    from repro.serving import Scheduler
    tr = Tracer()
    policy = PolicySpec("speculative",
                        {"draft_idx": num_exits(mini_cfg) - 1, "window": 3})
    s = Scheduler(mini_params, mini_cfg, default_policy=policy,
                  allowed_kinds=("none", "speculative"),
                  max_slots=2, max_len=64, max_new=6, spec_window=3,
                  kv_layout="paged", block_size=8, tracer=tr).start()
    rng = np.random.default_rng(1)
    reqs = [s.submit(rng.integers(4, mini_cfg.vocab_size, 16).tolist(),
                     max_new=6) for _ in range(2)]
    for r in reqs:
        r.result(timeout=180.0)
    s.stop()
    summ = validate_chrome_trace(tr.drain())
    assert {"tick", "draft", "verify", "bookkeeping",
            "retire"} <= set(summ["span_names"])


def test_virtual_clock_admission_trace_is_deterministic(mini_cfg):
    """run_admission_trace(tracer=) with a step clock: the drained span
    log is a pure function of the workload — byte-identical replays —
    so trace *structure* is CI-assertable without wall-clock races."""
    from benchmarks.serving_load import run_admission_trace

    def traced():
        tr = Tracer(clock=make_step_clock())
        out = run_admission_trace(mini_cfg, slots=3, max_len=68,
                                  block_size=8, n=12, seed=0, tracer=tr)
        return out, tr.drain()

    out_a, ev_a = traced()
    out_b, ev_b = traced()
    assert json.dumps(ev_a) == json.dumps(ev_b)
    assert out_a == out_b
    summ = validate_chrome_trace(ev_a)
    assert summ["span_names"] == ["admit", "decode_step", "retire", "tick"]
    n_retires = sum(1 for e in ev_a
                    if e["ph"] == "B" and e["name"] == "retire")
    assert n_retires == 2 * 12                 # both layouts, every job
