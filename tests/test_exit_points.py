"""Exit schedule (§III-D) + LITE weights (Eq. 1)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import ExitConfig
from repro.configs import get_config
from repro.core.exit_points import (exit_points, exit_points_for,
                                    segment_boundaries)
from repro.core.lite_loss import lite_weights


def test_paper_counts():
    """Paper: 9 exit points for Llama (28L), 10 for OPT (32L)."""
    ec = ExitConfig()
    assert len(exit_points_for(28, ec)) == 9
    assert len(exit_points_for(32, ec)) == 10


def test_schedule_rules():
    ec = ExitConfig()
    pts = exit_points_for(28, ec)
    assert pts[0] == 4                      # earliest exit at layer 4
    half = [p for p in pts if p <= 14]
    second = [p for p in pts if p > 14]
    assert all(b - a == 2 for a, b in zip(half, half[1:]))
    assert all(b - a == 4 for a, b in zip(second, second[1:]))
    assert all(p < 28 for p in pts)


def test_boundaries_end_with_final_layer():
    for arch in ["llama32-3b", "opt-2.7b"]:
        cfg = get_config(arch, "full")
        b = segment_boundaries(cfg)
        assert b[-1] == cfg.num_layers
        assert b[:-1] == exit_points(cfg)
        assert list(b) == sorted(set(b))


def test_lite_weights_sum_and_budgets():
    cfg = get_config("llama32-3b", "full")
    layers, w = lite_weights(cfg)
    w = np.asarray(w)
    assert abs(w.sum() - 1.0) < 1e-6
    assert len(layers) == len(w) == 10       # 9 exits + final
    # final layer budget = 0.1
    assert abs(w[-1] - 0.1) < 1e-6
    half = cfg.num_layers // 2
    first = w[: sum(1 for p in layers[:-1] if p <= half)]
    second = w[len(first):-1]
    assert abs(first.sum() - 0.7) < 1e-6
    assert abs(second.sum() - 0.2) < 1e-6
    # geometric decay: earliest exit has the highest weight in its group
    assert np.all(np.diff(first) < 0)
    assert np.all(np.diff(second) < 0)
    ratios = first[1:] / first[:-1]
    assert np.allclose(ratios, 0.9, atol=1e-5)


@pytest.mark.parametrize("n_layers", [8, 12, 24, 28, 32, 38, 40, 42, 48, 62])
def test_schedule_valid_all_depths(n_layers):
    pts = exit_points_for(n_layers, ExitConfig())
    assert all(4 <= p < n_layers for p in pts)
    assert list(pts) == sorted(set(pts))
