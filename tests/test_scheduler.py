"""Continuous-batching scheduler: slot-pool invariants, mid-flight join
determinism, EOS retirement, per-slot policies + sampling, energy accounting
parity with the one-shot Engine, zero recompiles across mixed traffic,
deterministic (virtual-clock) paged-concurrency admission trace."""
import re
import sys
import time
from pathlib import Path

import numpy as np
import pytest

sys.path.insert(0, str(Path(__file__).resolve().parent.parent))

from repro.api import GenerationRequest, PolicySpec, SamplingParams
from repro.core.controller import make_controller
from repro.serving import Engine, Scheduler, SchedulerQueueFull
from repro.serving.scheduler import KVSlotPool


def _prompts(vocab, lens, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(4, vocab, n).tolist() for n in lens]


@pytest.fixture(scope="module")
def sched(mini_cfg, mini_params):
    s = Scheduler(mini_params, mini_cfg, controller_kind="fixed",
                  fixed_exit_idx=0, allowed_kinds=("none", "fixed"),
                  max_slots=3, max_len=64, max_new=8,
                  queue_depth=16).start()
    yield s
    s.stop()


# ---------------------------------------------------------------------------
# KV slot pool
# ---------------------------------------------------------------------------
def test_pool_alloc_free_invariants(mini_cfg):
    pool = KVSlotPool(mini_cfg, max_slots=3, max_len=16)
    slots = [pool.alloc() for _ in range(3)]
    assert sorted(slots) == [0, 1, 2]
    assert pool.alloc() is None and pool.n_free == 0 and pool.n_used == 3
    pool.release(slots[1])
    assert pool.n_free == 1
    with pytest.raises(ValueError):
        pool.release(slots[1])          # double free
    with pytest.raises(ValueError):
        pool.release(99)                # out of range
    assert pool.alloc() == slots[1]     # LIFO reuse


def test_pool_write_touches_only_target_slot(mini_cfg, mini_params):
    import jax.numpy as jnp
    from repro.models.transformer import prefill
    pool = KVSlotPool(mini_cfg, max_slots=2, max_len=16)
    # copy out before the write: the pool buffer is donated to the jit
    before0 = np.asarray(pool.caches[0]["k"][:, 0])
    prompt = jnp.asarray(_prompts(mini_cfg.vocab_size, [8])[0],
                         jnp.int32)[None]
    _, caches, _ = prefill(mini_params, mini_cfg, prompt, max_len=16)
    pool.write(caches, 1)
    after = pool.caches[0]["k"]         # scanned segment: [L, slots, W, ...]
    assert not np.allclose(np.asarray(after[:, 1]), 0.0)
    np.testing.assert_array_equal(np.asarray(after[:, 0]), before0)


# ---------------------------------------------------------------------------
# determinism: joining mid-flight == serving alone
# ---------------------------------------------------------------------------
def test_join_mid_decode_is_byte_identical(sched, mini_cfg):
    a, b = _prompts(mini_cfg.vocab_size, [20, 14], seed=1)

    solo = sched.serve_batch([b], max_new=8)

    ha = sched.submit(a, max_new=16)
    it = ha.stream(timeout=60.0)
    for _ in range(3):                  # A is mid-decode...
        next(it)
    hb = sched.submit(b, max_new=8)     # ...when B joins the running batch
    ha.result(60.0), hb.result(60.0)

    assert hb.started_at < ha.finished_at, "B never overlapped A"
    assert hb.tokens == solo.tokens[0]
    assert hb.exit_layers == solo.exit_layers[0]
    assert hb.metrics.energy_j == solo.metrics[0].energy_j


def test_early_exit_controller_engaged(sched, mini_cfg):
    # fixed_exit_idx=0 exits every decode token at the first exit point;
    # token 0 always comes from full-depth prefill
    res = sched.serve_batch(_prompts(mini_cfg.vocab_size, [12]), max_new=6)
    el = res.exit_layers[0]
    assert el[0] == mini_cfg.num_layers
    assert all(e < mini_cfg.num_layers for e in el[1:])


def test_per_slot_controller_mix(sched, mini_cfg):
    """'none' and 'fixed' requests share one batch; each slot's exit policy
    applies independently (no shared-state mutation between requests)."""
    p = _prompts(mini_cfg.vocab_size, [16, 16], seed=2)
    h_none = sched.submit(p[0], max_new=6, controller="none")
    h_fixed = sched.submit(p[1], max_new=6, controller="fixed")
    h_none.result(60.0), h_fixed.result(60.0)
    assert all(e == mini_cfg.num_layers for e in h_none.exit_layers)
    assert all(e < mini_cfg.num_layers for e in h_fixed.exit_layers[1:])


# ---------------------------------------------------------------------------
# retirement
# ---------------------------------------------------------------------------
def test_eos_retires_and_frees_slot(mini_cfg, mini_params):
    probe = Scheduler(mini_params, mini_cfg, max_slots=2, max_len=64,
                      max_new=8).start()
    try:
        prompt = _prompts(mini_cfg.vocab_size, [18], seed=3)[0]
        full = probe.serve_batch([prompt], max_new=8).tokens[0]
        # first token value not seen earlier in the sequence -> usable EOS
        cut, eos = next((i, t) for i, t in enumerate(full)
                        if t not in full[:i] and i > 0)
    finally:
        probe.stop()

    s = Scheduler(mini_params, mini_cfg, max_slots=2, max_len=64,
                  max_new=8, eos_id=eos).start()
    try:
        h = s.submit(prompt, max_new=8).result(60.0)
        assert h.finish_reason == "eos"
        assert h.tokens == full[:cut]           # EOS itself excluded
        assert len(h.exit_layers) == max(cut, 1)
        assert s.pool.n_free == s.pool.max_slots
    finally:
        s.stop()


def test_oversubscription_retires_and_reuses_slots(sched, mini_cfg):
    reqs = _prompts(mini_cfg.vocab_size, [10, 12, 14, 10, 12, 14], seed=4)
    res = sched.serve_batch(reqs, max_new=5)    # 6 requests, 3 slots
    assert [len(t) for t in res.tokens] == [5] * 6
    deadline = time.monotonic() + 5
    while sched.pool.n_free != sched.pool.max_slots:
        assert time.monotonic() < deadline
        time.sleep(0.01)


def test_energy_budget_retires_early(sched, mini_cfg):
    prompt = _prompts(mini_cfg.vocab_size, [16], seed=5)[0]
    free = sched.serve_batch([prompt], max_new=8)
    budget = free.metrics[0].energy_j / 2
    h = sched.submit(prompt, max_new=8, energy_budget_j=budget).result(60.0)
    assert h.finish_reason == "energy_budget"
    assert 0 < len(h.tokens) < 8
    assert h.tokens == free.tokens[0][:len(h.tokens)]


# ---------------------------------------------------------------------------
# accounting parity with the one-shot Engine
# ---------------------------------------------------------------------------
def test_energy_accounting_matches_engine(sched, mini_cfg, mini_params):
    # equal-length prompts: Engine pads to the batch max, so only then are
    # its per-request contexts identical to the scheduler's
    reqs = _prompts(mini_cfg.vocab_size, [20, 20, 20], seed=6)
    res = sched.serve_batch(reqs, max_new=8, controller="fixed")
    eng = Engine(mini_params, mini_cfg, max_new=8)
    ref = eng.serve(reqs, max_new=8,
                    controller=make_controller("fixed", exit_idx=0))
    assert res.tokens == ref.tokens
    assert res.exit_layers == ref.exit_layers
    for a, b in zip(res.metrics, ref.metrics):
        assert a.energy_j == pytest.approx(b.energy_j, rel=1e-12)
        assert a.mean_layers == b.mean_layers
        assert a.n_tokens == b.n_tokens


# ---------------------------------------------------------------------------
# admission queue
# ---------------------------------------------------------------------------
def test_queue_overflow_raises(mini_cfg, mini_params):
    s = Scheduler(mini_params, mini_cfg, max_slots=1, max_len=32,
                  max_new=4, queue_depth=2)      # not started: queue fills
    p = _prompts(mini_cfg.vocab_size, [8, 8, 8], seed=7)
    s.submit(p[0]), s.submit(p[1])
    with pytest.raises(SchedulerQueueFull):
        s.submit(p[2])


def test_max_new_zero_rejected(sched, mini_cfg):
    with pytest.raises(ValueError):
        sched.submit(_prompts(mini_cfg.vocab_size, [8])[0], max_new=0)


def test_prefill_buckets_shim_warns_and_ignores(mini_cfg, mini_params):
    """The bucketing knob is gone: chunked prefill serves every prompt
    length with one compiled shape. The deprecated kwarg warns and is
    ignored — prompts keep their exact length (no PAD bucketing)."""
    with pytest.warns(DeprecationWarning, match="prefill_buckets"):
        s = Scheduler(mini_params, mini_cfg, max_slots=1, max_len=48,
                      max_new=4, prefill_buckets=(16, 32))
    h = s.submit(_prompts(mini_cfg.vocab_size, [10])[0])
    assert len(h.prompt) == 10 and not h.truncated
    h2 = s.submit(_prompts(mini_cfg.vocab_size, [60])[0])
    assert len(h2.prompt) == 44          # keep-limit tail clip ...
    assert h2.truncated                  # ... is surfaced, not silent


def test_truncated_prompt_flag_roundtrips(mini_cfg, mini_params):
    """scheduler.py's `prompt[-keep:]` tail clip must surface on the
    result object (satellite: silent truncation fix)."""
    s = Scheduler(mini_params, mini_cfg, max_slots=1, max_len=32,
                  max_new=4).start()
    try:
        long = _prompts(mini_cfg.vocab_size, [64], seed=20)[0]
        short = _prompts(mini_cfg.vocab_size, [8], seed=20)[0]
        r_long = s.submit(long).result(60.0)
        r_short = s.submit(short).result(60.0)
    finally:
        s.stop()
    assert r_long.truncated and r_long.to_result().truncated
    assert not r_short.truncated and not r_short.to_result().truncated


def test_shutdown_drops_queued_requests_cleanly(mini_cfg, mini_params):
    s = Scheduler(mini_params, mini_cfg, max_slots=1, max_len=32, max_new=4)
    h = s.submit(_prompts(mini_cfg.vocab_size, [8])[0])   # never admitted
    s._drain()
    with pytest.raises(RuntimeError, match="aborted: shutdown"):
        h.result(1.0)


def test_decode_loop_crash_fails_waiters(mini_cfg, mini_params, capsys):
    s = Scheduler(mini_params, mini_cfg, max_slots=1, max_len=32, max_new=4)

    def boom(*a, **k):
        raise RuntimeError("injected prefill failure")

    s._chunk = boom          # chunked admission path
    s._prefill = boom        # whole-prompt fallback path
    s.start()
    h = s.submit(_prompts(mini_cfg.vocab_size, [8])[0])
    with pytest.raises(RuntimeError, match="aborted: error"):
        h.result(10.0)
    assert not s._running                 # loop shut itself down
    with pytest.raises(RuntimeError, match="stopped"):
        s.submit(_prompts(mini_cfg.vocab_size, [8])[0])   # fail fast now
    capsys.readouterr()                   # swallow the printed traceback


def test_submit_after_stop_fails_fast(mini_cfg, mini_params):
    s = Scheduler(mini_params, mini_cfg, max_slots=1, max_len=32,
                  max_new=4).start()
    s.stop()
    with pytest.raises(RuntimeError, match="stopped"):
        s.submit(_prompts(mini_cfg.vocab_size, [8])[0])
    with pytest.raises(RuntimeError, match="one-shot"):
        s.start()


def test_stats_shape(sched):
    st = sched.stats()
    for key in ("queue_depth", "active_slots", "free_slots", "max_slots",
                "completed_requests", "fleet_tokens", "fleet_j_per_token",
                "throughput_tok_s", "latency_p50_s", "latency_p95_s",
                "exit_layer_ema", "controllers", "step_compiles",
                "tracing", "dispatches", "sync_points", "lifetime"):
        assert key in st
    assert st["completed_requests"] >= 1
    assert st["fleet_j_per_token"] > 0


def test_reset_peak_stats_resets_throughput_window(mini_cfg, mini_params):
    """reset_peak_stats() is documented as scoping stats() to the timed
    run — but it used to leave the throughput window (_t0, fleet token /
    energy cumulatives, latencies) running since construction, so
    ``throughput_tok_s`` mixed warmup into every 'timed' read. The window
    must restart; the cumulative view moves to the ``lifetime`` sub-dict."""
    s = Scheduler(mini_params, mini_cfg, allowed_kinds=("none",),
                  max_slots=2, max_len=64, max_new=4).start()
    try:
        s.serve_batch(_prompts(mini_cfg.vocab_size, [10, 12]), max_new=4)
        warm = s.stats()
        assert warm["completed_requests"] == 2
        assert warm["fleet_tokens"] > 0
        s.reset_peak_stats()
        st = s.stats()
        assert st["completed_requests"] == 0
        assert st["fleet_tokens"] == 0
        assert st["fleet_energy_j"] == 0.0
        assert st["fleet_prefill_energy_j"] == 0.0
        assert st["latency_p50_s"] is None          # samples cleared
        assert st["uptime_s"] < warm["uptime_s"]    # window restarted
        # the cumulative view survives in lifetime
        assert st["lifetime"]["completed_requests"] == 2
        assert st["lifetime"]["fleet_tokens"] == warm["fleet_tokens"]
        assert st["lifetime"]["uptime_s"] >= warm["uptime_s"] - 1e-3
        # a fresh window counts only its own traffic, lifetime keeps all
        s.serve_batch(_prompts(mini_cfg.vocab_size, [10], seed=3),
                      max_new=4)
        st2 = s.stats()
        assert st2["completed_requests"] == 1
        assert st2["lifetime"]["completed_requests"] == 3
        assert (st2["lifetime"]["fleet_tokens"]
                == warm["fleet_tokens"] + st2["fleet_tokens"])
    finally:
        s.stop()


# ---------------------------------------------------------------------------
# policies + sampling as runtime data (the PR-2 API redesign)
# ---------------------------------------------------------------------------
def test_mixed_traffic_never_recompiles(sched, mini_cfg):
    """Heterogeneous policies, exit indices, temperatures, top-k/top-p and
    seeds across requests must share ONE compiled decode step (asserted via
    the jit-cache-miss counter)."""
    p = _prompts(mini_cfg.vocab_size, [10, 10, 10, 10, 10, 10], seed=8)
    handles = [
        sched.submit(p[0]),
        sched.submit(p[1], controller="fixed"),
        sched.submit(p[2], policy=PolicySpec("fixed", {"exit_idx": 1})),
        sched.submit(GenerationRequest(
            prompt=p[3], max_new_tokens=5,
            sampling=SamplingParams(temperature=0.8, top_k=7, seed=3))),
        sched.submit(GenerationRequest(
            prompt=p[4], max_new_tokens=5, policy="fixed",
            sampling=SamplingParams(temperature=1.4, top_p=0.6, seed=4))),
        sched.submit(p[5], controller="none"),
    ]
    for h in handles:
        h.result(60.0)
    assert sched.step_compiles == 1, \
        f"mixed traffic recompiled the step {sched.step_compiles}x"


def test_generation_request_roundtrip(sched, mini_cfg):
    p = _prompts(mini_cfg.vocab_size, [12], seed=9)[0]
    h = sched.submit(GenerationRequest(prompt=p, max_new_tokens=4,
                                       policy=PolicySpec("fixed",
                                                         {"exit_idx": 0})))
    res = h.result(60.0).to_result()
    assert len(res.tokens) <= 4 and res.finish_reason in ("length", "eos")
    assert res.metrics is not None and res.energy_j > 0
    assert res.exit_layers[0] == mini_cfg.num_layers
    with pytest.raises(ValueError, match="inside the GenerationRequest"):
        sched.submit(GenerationRequest(prompt=p), max_new=3)


def test_sampled_join_matches_solo_run(sched, mini_cfg):
    """A *sampled* request joining mid-flight reproduces its solo tokens:
    the draw stream is keyed by (request seed, position), not by slot or
    batch composition."""
    a, b = _prompts(mini_cfg.vocab_size, [18, 14], seed=10)
    gr = lambda: GenerationRequest(  # noqa: E731
        prompt=b, max_new_tokens=6,
        sampling=SamplingParams(temperature=0.9, top_k=12, seed=21))
    solo = sched.submit(gr()).result(60.0)
    ha = sched.submit(a, max_new=12)
    it = ha.stream(timeout=60.0)
    next(it), next(it)
    hb = sched.submit(gr())
    ha.result(60.0)
    hb.result(60.0)
    assert hb.tokens == solo.tokens
    assert hb.exit_layers == solo.exit_layers


def test_policy_scheduler_without_agent_fails_eagerly(mini_cfg, mini_params):
    with pytest.raises(TypeError, match="agent"):
        Scheduler(mini_params, mini_cfg, controller_kind="policy")


def test_stop_sequences_retire_with_stop_reason(mini_cfg, mini_params,
                                                mini_dataset):
    tok = mini_dataset.tokenizer
    s = Scheduler(mini_params, mini_cfg, tokenizer=tok, max_slots=2,
                  max_len=64, max_new=8).start()
    try:
        prompt = _prompts(mini_cfg.vocab_size, [16], seed=11)[0]
        free = s.submit(prompt, max_new=8).result(60.0)
        full = tok.decode(free.tokens)
        # fragment from a contiguous clean run of the raw text (slicing a
        # de-�-ed copy could straddle a replacement char and never match)
        runs = [m.group() for m in re.finditer(r"[^�]{2,}", full)]
        assert runs, "no clean text to derive a stop sequence from"
        best = max(runs, key=len)
        mid = best[len(best) // 2 - 1:len(best) // 2 + 1]
        h = s.submit(GenerationRequest(prompt=prompt, max_new_tokens=8,
                                       stop_sequences=(mid,)))
        r = h.result(60.0)
        assert r.finish_reason == "stop"
        assert mid not in (r.text or "")
        assert full.startswith(r.text or "")
        assert len(r.tokens) <= len(free.tokens)
        assert s.pool.n_free == s.pool.max_slots  # slot actually retired
    finally:
        s.stop()


def test_stop_sequences_without_tokenizer_rejected(sched, mini_cfg):
    with pytest.raises(ValueError, match="tokenizer"):
        sched.submit(GenerationRequest(
            prompt=_prompts(mini_cfg.vocab_size, [8])[0],
            stop_sequences=("x",)))


def test_raw_submit_validates_stop_sequences(sched, mini_cfg):
    p = _prompts(mini_cfg.vocab_size, [8])[0]
    with pytest.raises(ValueError, match="empty string"):
        sched.submit(p, stop_sequences=("",))
    with pytest.raises(ValueError, match="single string"):
        sched.submit(p, stop_sequences="ab")


def test_admission_trace_deterministic_and_paged_wins(mini_cfg):
    """The paged-concurrency claim, formulated so CI can hard-gate it: a
    virtual-clock replay of one workload through both pools' admission
    bookkeeping. Two replays must produce structurally identical
    admit/retire event logs (no wall-clock race), and at an equal KV-byte
    budget the paged pool must admit strictly more concurrent residents
    (closes the ROADMAP warn-only gate item)."""
    from benchmarks.serving_load import run_admission_trace
    kw = dict(slots=3, max_len=68, block_size=8, n=24, seed=0)
    a = run_admission_trace(mini_cfg, **kw)
    b = run_admission_trace(mini_cfg, **kw)
    for layout in ("contiguous", "paged"):
        assert a[layout]["events"] == b[layout]["events"], \
            f"{layout} admission trace is not deterministic"
        assert a[layout]["events"][0][1] == "admit"
        n_admit = sum(1 for e in a[layout]["events"] if e[1] == "admit")
        n_retire = sum(1 for e in a[layout]["events"] if e[1] == "retire")
        assert n_admit == n_retire == 24          # every job served
    assert a["paged_admits_more_concurrent"]
    assert (a["paged"]["peak_residents"]
            > a["contiguous"]["peak_residents"])


def test_legacy_threshold_override_keeps_default_spec_params(mini_cfg,
                                                             mini_params):
    """submit(threshold=...) on a scheduler whose default policy carries
    extra params (fixed exit_idx here) must override ONLY the threshold
    knob — never silently reset the others."""
    s = Scheduler(mini_params, mini_cfg,
                  default_policy=PolicySpec("fixed", {"exit_idx": 1.0}))
    req = s.submit(_prompts(mini_cfg.vocab_size, [8])[0], threshold=0.5)
    assert req.spec.resolved()["exit_idx"] == 1.0   # survived the override
    s2 = Scheduler(mini_params, mini_cfg, controller_kind="confidence",
                   threshold=0.7, allowed_kinds=("none", "confidence"))
    assert s2.submit(_prompts(mini_cfg.vocab_size, [8])[0],
                     controller="confidence").spec.resolved() == \
        {"threshold": 0.7}                           # ctor default honored
    assert s2.submit(_prompts(mini_cfg.vocab_size, [8])[0],
                     threshold=0.55).spec.resolved() == {"threshold": 0.55}


def test_graceful_drain_finishes_queued_work(mini_cfg, mini_params):
    """begin_drain(): new submissions are turned away (SchedulerQueueFull —
    the server's 503, the router's retry-elsewhere signal) while queued
    and in-flight requests run to completion; drain() then returns True
    once everything finished inside the budget."""
    s = Scheduler(mini_params, mini_cfg, controller_kind="fixed",
                  fixed_exit_idx=0, allowed_kinds=("none", "fixed"),
                  max_slots=1, max_len=64, max_new=8, queue_depth=8).start()
    prompts = _prompts(mini_cfg.vocab_size, [8, 10, 12])
    handles = [s.submit(p, max_new=6) for p in prompts]
    s.begin_drain()
    assert s.draining
    with pytest.raises(SchedulerQueueFull, match="draining"):
        s.submit(prompts[0], max_new=1)
    assert s.drain(timeout=60.0) is True
    for h in handles:
        h.result(timeout=1.0)                # finished during the drain
        assert len(h.tokens) == 6 and h.status == "done"
    assert s.stats()["draining"] is True
