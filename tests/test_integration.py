"""End-to-end integration: LITE fine-tune -> rollout -> PPO -> serve.

This is the paper's full offline+online pipeline (Fig. 2) at mini scale.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.controller import make_controller
from repro.rl import PPOConfig, train_agent
from repro.serving import Engine
from repro.serving.metrics import aggregate_metrics


def test_lite_finetune_improves_all_exits(mini_cfg, mini_dataset,
                                          trained_mini):
    from repro.training.loop import evaluate_ce
    params, hist = trained_mini
    assert hist[-1] < hist[0] * 0.9
    ce, per_layer = evaluate_ce(params, mini_cfg, mini_dataset,
                                max_batches=2)
    assert np.isfinite(per_layer).all()
    # every exit layer decodes sanely (within 2x of the final layer CE)
    assert per_layer.max() < per_layer[-1] * 2 + 1.0


@pytest.mark.slow
def test_full_pipeline(mini_cfg, mini_dataset, trained_mini):
    params, _ = trained_mini
    agent, history, cache = train_agent(
        params, mini_cfg, mini_dataset, n_episodes=12, gen_tokens=6,
        ppo=PPOConfig(total_steps=16_000, horizon=64, n_lanes=8),
        log_every=0)
    # reward improved during training
    assert (history[-1]["mean_step_reward"]
            > history[0]["mean_step_reward"] - 0.05)
    # rollout cache invariants: l_opt within boundaries, shapes consistent
    assert cache.l_opt.min() >= cache.boundaries[0]
    assert cache.l_opt.max() <= mini_cfg.num_layers
    assert cache.hidden.shape[:3] == cache.preds.shape

    # serve with the trained agent
    ctrl = make_controller("policy", agent_params=agent, threshold=0.5)
    eng = Engine(params, mini_cfg, ctrl, max_new=5)
    tasks = mini_dataset.completion_tasks("test", 4, max_context=64)
    res = eng.serve([c for c, _ in tasks])
    agg = aggregate_metrics(res.metrics)
    assert agg["tokens"] > 0
    assert 0.0 <= agg["energy_saving_frac"] < 1.0


def test_serve_step_lowering_host_mesh(mini_cfg, mini_params):
    """serve_step lowers + compiles under a (1,1) host mesh — the same code
    path the 512-device dry-run uses."""
    from repro.launch import steps as S
    from repro.launch.mesh import make_host_mesh
    from repro.config import InputShape
    from repro.sharding.api import axis_rules

    shape = InputShape("t", 64, 2, "decode")
    mesh = make_host_mesh()
    step = S.make_step(mini_cfg, shape)
    specs = S.input_specs(mini_cfg, shape, dtype=jnp.float32)
    sh = S.input_shardings(mini_cfg, shape, mesh, specs)
    with mesh, axis_rules(mesh):
        compiled = jax.jit(step, in_shardings=sh).lower(*specs).compile()
    assert compiled.cost_analysis() is not None
