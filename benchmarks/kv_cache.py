"""Fig. 13: impact of KV-cache propagation on accuracy.

Our decode always propagates K/V from the frozen hidden state of exited
tokens (CALM-style, §VI-G). The paper's Fig. 13 compares the EE model with
KV caching against accuracy-equivalent baselines. Here we quantify the
propagation approximation directly: generation with early exits + cache
propagation vs the *exact* no-cache alternative (recomputing the full
prefix each token at full depth below the exit layer is intractable; the
practical exact reference is the full-depth model).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import artifacts, evaluate, save_result, table
from repro.api import PolicySpec


def run(full: bool = False, n: int = 24):
    cfg, ds, _, ft, agent = artifacts("llama", "java")
    rows = []
    r_full = evaluate(ft, cfg, ds, PolicySpec("none"), n=n)
    rows.append({"setting": "full model (exact)", **r_full})
    for t in (0.6, 0.9):
        spec = PolicySpec("policy", {"threshold": t})
        r = evaluate(ft, cfg, ds, spec, agent_params=agent, n=n)
        rows.append({"setting": f"GC({t}) + KV propagation", **r})
    print(table(rows, ["setting", "rougeL", "codebleu", "mean_layers",
                       "energy_saving_frac"],
                "Fig.13 KV-cache propagation impact (llama/java)"))
    save_result("fig13_kv_cache", rows)
