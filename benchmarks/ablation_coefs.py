"""Ablation (beyond the paper's figures): reward-coefficient sensitivity.

The paper fixes (β, γ) per dataset (§VI-D: 1.0/1.0 for Java, 0.5/0.5 for
Python) without ablating. Here we sweep the trade-off coefficients and
report where the learned policy lands on the layers-used / quality plane —
optional bench: ``python -m benchmarks.run --bench ablation_coefs``.
"""
from __future__ import annotations

from benchmarks.common import artifacts, evaluate, save_result, table
from repro.rl import EarlyExitEnv, PPOConfig, RewardCoefs, agent_policy_spec
from repro.rl.ppo import ppo_train
from repro.rl.rollout import build_rollout_cache


def run(full: bool = False, n: int = 24):
    cfg, ds, _, ft, _ = artifacts("llama", "java")
    cache = build_rollout_cache(ft, cfg, ds, n_episodes=24, gen_tokens=8)
    rows = []
    for alpha, beta, gamma in [(0.2, 1.0, 1.0), (0.2, 0.5, 0.5),
                               (0.05, 1.0, 0.2), (0.5, 1.0, 1.0)]:
        env = EarlyExitEnv(cache, RewardCoefs(alpha=alpha, beta=beta,
                                              gamma=gamma), n_lanes=16)
        agent, hist = ppo_train(
            env, config=PPOConfig(total_steps=60_000, horizon=128),
            log_every=0)
        # T=0.5 (argmax policy): 40-60k-step agents rarely clear 0.9
        r = evaluate(ft, cfg, ds, agent_policy_spec(threshold=0.5),
                     agent_params=agent, n=n)
        rows.append({"alpha": alpha, "beta": beta, "gamma": gamma,
                     "reward": hist[-1]["mean_step_reward"],
                     "mean_layers": r["mean_layers"],
                     "codebleu": r["codebleu"],
                     "energy_saving_frac": r["energy_saving_frac"]})
    print(table(rows, ["alpha", "beta", "gamma", "reward", "mean_layers",
                       "codebleu", "energy_saving_frac"],
                "Ablation: reward coefficients (llama/java, T=0.5)"))
    # expectation: higher beta (early-exit penalty) -> deeper exits
    save_result("ablation_coefs", rows)
