"""Fig. 12: sensitivity to the context fraction (0.2 / 0.3 / 0.5 / 0.6)."""
from __future__ import annotations

from benchmarks.common import artifacts, evaluate, save_result, table
from repro.api import PolicySpec


def run(full: bool = False, n: int = 24):
    cfg, ds, _, ft, agent = artifacts("llama", "java")
    rows = []
    fracs = (0.2, 0.3, 0.5, 0.6) if full else (0.2, 0.5)
    for frac in fracs:
        base = evaluate(ft, cfg, ds, PolicySpec("none"), n=n,
                        ctx_frac=(frac, frac))
        rows.append({"ctx": frac, "setting": "full", **base})
        for t in ((0.6, 0.92) if full else (0.9,)):
            spec = PolicySpec("policy", {"threshold": t})
            r = evaluate(ft, cfg, ds, spec, agent_params=agent, n=n,
                         ctx_frac=(frac, frac))
            rows.append({"ctx": frac, "setting": f"GC({t})", **r})
    print(table(rows, ["ctx", "setting", "codebleu", "energy_j",
                       "energy_saving_frac"],
                "Fig.12 context-length sensitivity — llama/java"))
    save_result("fig12_context", rows)
