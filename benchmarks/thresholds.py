"""Figs. 8-11: GREEN-CODE at thresholds T vs the two baselines.

Baselines exactly as in the paper (§VI-E): (i) base model — non-fine-tuned,
all layers; (ii) fine-tuned model — all layers. GC(T) = fine-tuned model +
RL agent thresholded at T.

The whole GC sweep runs as ONE stacked batch: thresholds are per-row
entries of the exit-policy param pytree (``repro.core.exit_policy``), so
every T shares a single compiled fixed-shape run instead of retracing per
setting. ``--compare-loop`` (default on) also times the seed-style
one-evaluate-per-threshold loop and reports the stacked speedup.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import (LANGS, MODELS, artifacts, evaluate,
                               evaluate_sweep, save_result, table)
from repro.api import PolicySpec


THRESHOLDS = (0.6, 0.8, 0.9, 0.91, 0.92)


def run(full: bool = False, n: int = 32, compare_loop: bool = True):
    models = list(MODELS) if full else ["llama"]
    langs = list(LANGS) if full else ["java"]
    all_rows = []
    for model in models:
        for lang in langs:
            cfg, ds, base, ft, agent = artifacts(model, lang)
            rows = []
            rows.append({"setting": "base(full)",
                         **evaluate(base, cfg, ds, PolicySpec("none"),
                                    n=n)})
            rows.append({"setting": "finetuned(full)",
                         **evaluate(ft, cfg, ds, PolicySpec("none"), n=n)})

            # GC(T) sweep: all thresholds stacked into one compiled run
            specs = [PolicySpec("policy", {"threshold": t})
                     for t in THRESHOLDS]
            gc_rows, sweep_wall = evaluate_sweep(ft, cfg, ds, specs,
                                                 agent_params=agent, n=n)
            for t, r in zip(THRESHOLDS, gc_rows):
                rows.append({"setting": f"GC({t})", **r})

            loop_wall = None
            if compare_loop:
                t0 = time.time()
                for t in THRESHOLDS:
                    evaluate(ft, cfg, ds,
                             PolicySpec("policy", {"threshold": t}),
                             agent_params=agent, n=n)
                loop_wall = time.time() - t0

            for r in rows:
                r.update(model=model, lang=lang)
            all_rows += rows
            print(table(rows, ["setting", "rougeL", "codebleu", "syntax",
                               "dataflow", "mean_layers", "energy_j",
                               "energy_saving_frac",
                               "modeled_throughput_tok_s"],
                        f"Figs.8-11 thresholds — {model}/{lang}"))
            ft_row = rows[1]
            best_gc = max(rows[2:], key=lambda r: r["codebleu"])
            print(f"  -> best GC keeps "
                  f"{best_gc['codebleu']/max(ft_row['codebleu'],1e-9):.0%}"
                  f" CodeBLEU, saves "
                  f"{best_gc['energy_saving_frac']:.0%} energy")
            print(f"  -> stacked sweep: {len(THRESHOLDS)} thresholds in "
                  f"{sweep_wall:.2f}s (one compiled step)", end="")
            if loop_wall is not None:
                print(f" vs {loop_wall:.2f}s per-threshold loop "
                      f"({loop_wall / max(sweep_wall, 1e-9):.1f}x speedup)")
                all_rows.append({"model": model, "lang": lang,
                                 "setting": "sweep_timing",
                                 "sweep_wall_s": sweep_wall,
                                 "loop_wall_s": loop_wall,
                                 "speedup": loop_wall / max(sweep_wall,
                                                            1e-9)})
            else:
                print()
    save_result("fig8_11_thresholds", all_rows)
