"""Figs. 8-11: GREEN-CODE at thresholds T vs the two baselines.

Baselines exactly as in the paper (§VI-E): (i) base model — non-fine-tuned,
all layers; (ii) fine-tuned model — all layers. GC(T) = fine-tuned model +
RL agent thresholded at T.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import (LANGS, MODELS, artifacts, evaluate,
                               save_result, table)
from repro.core.controller import make_controller


THRESHOLDS = (0.6, 0.8, 0.9, 0.91, 0.92)


def run(full: bool = False, n: int = 32):
    models = list(MODELS) if full else ["llama"]
    langs = list(LANGS) if full else ["java"]
    all_rows = []
    for model in models:
        for lang in langs:
            cfg, ds, base, ft, agent = artifacts(model, lang)
            rows = []
            rows.append({"setting": "base(full)",
                         **evaluate(base, cfg, ds, make_controller("none"),
                                    n=n)})
            rows.append({"setting": "finetuned(full)",
                         **evaluate(ft, cfg, ds, make_controller("none"),
                                    n=n)})
            for t in THRESHOLDS:
                ctrl = make_controller("policy", agent_params=agent,
                                       threshold=t)
                rows.append({"setting": f"GC({t})",
                             **evaluate(ft, cfg, ds, ctrl, n=n)})
            for r in rows:
                r.update(model=model, lang=lang)
            all_rows += rows
            print(table(rows, ["setting", "rougeL", "codebleu", "syntax",
                               "dataflow", "mean_layers", "energy_j",
                               "energy_saving_frac",
                               "modeled_throughput_tok_s"],
                        f"Figs.8-11 thresholds — {model}/{lang}"))
            ft_row = rows[1]
            best_gc = max(rows[2:], key=lambda r: r["codebleu"])
            print(f"  -> best GC keeps "
                  f"{best_gc['codebleu']/max(ft_row['codebleu'],1e-9):.0%}"
                  f" CodeBLEU, saves "
                  f"{best_gc['energy_saving_frac']:.0%} energy")
    save_result("fig8_11_thresholds", all_rows)
