"""Shared benchmark infrastructure: cached artifacts (fine-tuned models,
RL agents) + evaluation loop matching the paper's protocol (§VI-C):
line-completion, max_new=15, context = fraction of the file, 1000-sample
corpus-level metrics (reduced to --n samples on CPU).
"""
from __future__ import annotations

import json
import os
import time

import jax
import numpy as np

from repro.core.controller import make_controller
from repro.core import energy
from repro.data import CodeCompletionDataset
from repro.models import transformer as T
from repro.rl import PPOConfig, RewardCoefs, train_agent
from repro.serving import Engine
from repro.serving.metrics import aggregate_metrics, codebleu_like, rouge_l
from repro.training import load_pytree, save_pytree, train_model

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                       "artifacts")
RES_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                       "results")

MODELS = {
    "llama": ("repro.configs.llama32_3b", "Llama-3.2(mini)"),
    "opt": ("repro.configs.opt_2_7b", "OPT(mini)"),
}
LANGS = {"java": "JavaCorpus(syn)", "python": "PY150(syn)"}


def get_cfg(model: str):
    mod = __import__(MODELS[model][0], fromlist=["paper_mini"])
    return mod.paper_mini()


def get_dataset(lang: str, seq_len: int = 256) -> CodeCompletionDataset:
    # enough files that the mini models do NOT saturate — the paper's
    # Fig. 1 signal (deeper layers -> better quality) needs headroom
    return CodeCompletionDataset(language=lang, n_files=360,
                                 seq_len=seq_len, vocab_size=2048)


_CACHE: dict = {}


def artifacts(model: str = "llama", lang: str = "java", *,
              train_steps: int = 120, ppo_steps: int = 80_000,
              force: bool = False):
    """(cfg, dataset, base_params, ft_params, agent) — cached on disk."""
    key = (model, lang)
    if key in _CACHE and not force:
        return _CACHE[key]
    os.makedirs(ART_DIR, exist_ok=True)
    cfg = get_cfg(model)
    ds = get_dataset(lang)
    base_params = T.init_params(jax.random.PRNGKey(0), cfg)
    ft_path = os.path.join(ART_DIR, f"{model}_{lang}_ft")
    ag_path = os.path.join(ART_DIR, f"{model}_{lang}_agent")
    if os.path.exists(ft_path + ".npz") and not force:
        ft_params = load_pytree(ft_path)
    else:
        print(f"[bench] LITE fine-tuning {model}/{lang} "
              f"({train_steps} steps) ...", flush=True)
        ft_params, _ = train_model(cfg, ds, kind="lite", steps=train_steps,
                                   batch_size=4, lr=1e-3, log_every=50)
        save_pytree(ft_params, ft_path)
    if os.path.exists(ag_path + ".npz") and not force:
        agent = load_pytree(ag_path)
    else:
        print(f"[bench] PPO agent {model}/{lang} ...", flush=True)
        coefs = (RewardCoefs(beta=1.0, gamma=1.0) if lang == "java"
                 else RewardCoefs(beta=0.5, gamma=0.5))  # paper §VI-D
        agent, _, _ = train_agent(
            params=ft_params, cfg=cfg, dataset=ds, n_episodes=32,
            gen_tokens=10, coefs=coefs,
            ppo=PPOConfig(total_steps=ppo_steps, horizon=128, n_lanes=16),
            log_every=20)
        save_pytree(agent, ag_path)
    out = (cfg, ds, base_params, ft_params, agent)
    _CACHE[key] = out
    return out


def evaluate(params, cfg, ds, controller, *, n: int = 40, max_new: int = 15,
             ctx_frac: tuple = (0.2, 0.2), max_context: int = 192,
             seed: int = 0):
    """Paper §VI-C evaluation: returns quality + efficiency metrics."""
    tasks = ds.completion_tasks("test", n, seed=seed, ctx_lo=ctx_frac[0],
                                ctx_hi=ctx_frac[1], max_context=max_context)
    eng = Engine(params, cfg, controller, max_new=max_new,
                 max_context=max_context)
    t0 = time.time()
    res = eng.serve([c for c, _ in tasks])
    wall = time.time() - t0
    vocab = ds.tokenizer.vocab
    q = {"rougeL": [], "codebleu": [], "syntax": [], "dataflow": [],
         "em": []}
    for (ctx, ref), toks in zip(tasks, res.tokens):
        ref_t = [vocab[i] if i < len(vocab) else "?"
                 for i in ref[:max_new]]
        hyp_t = [vocab[i] if i < len(vocab) else "?" for i in toks]
        q["rougeL"].append(rouge_l(hyp_t, ref_t))
        cb = codebleu_like(hyp_t, ref_t)
        q["codebleu"].append(cb["codebleu"])
        q["syntax"].append(cb["syntax"])
        q["dataflow"].append(cb["dataflow"])
        q["em"].append(float(hyp_t[:5] == ref_t[:5]))
    agg = aggregate_metrics(res.metrics)
    toks_total = agg["tokens"]
    return {
        **{k: float(np.mean(v)) for k, v in q.items()},
        "mean_layers": agg["mean_layers"],
        "energy_j": agg["energy_j"],
        "energy_saving_frac": agg["energy_saving_frac"],
        "modeled_latency_s": agg["modeled_latency_s"],
        "modeled_throughput_tok_s": toks_total
        / max(agg["modeled_latency_s"], 1e-12),
        "wall_s": wall,
        "tokens": toks_total,
    }


def controllers_for(params, cfg, agent, thresholds=(0.6, 0.8, 0.9, 0.92)):
    out = {"full(ft)": make_controller("none")}
    for t in thresholds:
        out[f"GC({t})"] = make_controller("policy", agent_params=agent,
                                          threshold=t)
    return out


def save_result(name: str, data):
    os.makedirs(RES_DIR, exist_ok=True)
    with open(os.path.join(RES_DIR, f"{name}.json"), "w") as f:
        json.dump(data, f, indent=1, default=float)
    print(f"[bench] wrote experiments/results/{name}.json", flush=True)


def table(rows: list[dict], cols: list[str], title: str) -> str:
    out = [f"\n### {title}\n"]
    out.append("| " + " | ".join(cols) + " |")
    out.append("|" + "---|" * len(cols))
    for r in rows:
        cells = []
        for c in cols:
            v = r.get(c, "")
            cells.append(f"{v:.3f}" if isinstance(v, float) else str(v))
        out.append("| " + " | ".join(cells) + " |")
    return "\n".join(out)
