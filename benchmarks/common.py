"""Shared benchmark infrastructure: cached artifacts (fine-tuned models,
RL agents) + evaluation loop matching the paper's protocol (§VI-C):
line-completion, max_new=15, context = fraction of the file, 1000-sample
corpus-level metrics (reduced to --n samples on CPU).
"""
from __future__ import annotations

import json
import os
import time

import jax
import numpy as np

from repro.api import GenerationRequest, PolicySpec
from repro.core import energy
from repro.data import CodeCompletionDataset
from repro.models import transformer as T
from repro.rl import PPOConfig, RewardCoefs, train_agent
from repro.serving import Engine
from repro.serving.metrics import aggregate_metrics, codebleu_like, rouge_l
from repro.training import load_pytree, save_pytree, train_model

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                       "artifacts")
RES_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                       "results")

MODELS = {
    "llama": ("repro.configs.llama32_3b", "Llama-3.2(mini)"),
    "opt": ("repro.configs.opt_2_7b", "OPT(mini)"),
}
LANGS = {"java": "JavaCorpus(syn)", "python": "PY150(syn)"}


def get_cfg(model: str):
    mod = __import__(MODELS[model][0], fromlist=["paper_mini"])
    return mod.paper_mini()


def get_dataset(lang: str, seq_len: int = 256) -> CodeCompletionDataset:
    # enough files that the mini models do NOT saturate — the paper's
    # Fig. 1 signal (deeper layers -> better quality) needs headroom
    return CodeCompletionDataset(language=lang, n_files=360,
                                 seq_len=seq_len, vocab_size=2048)


_CACHE: dict = {}


def artifacts(model: str = "llama", lang: str = "java", *,
              train_steps: int = 120, ppo_steps: int = 80_000,
              force: bool = False):
    """(cfg, dataset, base_params, ft_params, agent) — cached on disk."""
    key = (model, lang)
    if key in _CACHE and not force:
        return _CACHE[key]
    os.makedirs(ART_DIR, exist_ok=True)
    cfg = get_cfg(model)
    ds = get_dataset(lang)
    base_params = T.init_params(jax.random.PRNGKey(0), cfg)
    ft_path = os.path.join(ART_DIR, f"{model}_{lang}_ft")
    ag_path = os.path.join(ART_DIR, f"{model}_{lang}_agent")
    if os.path.exists(ft_path + ".npz") and not force:
        ft_params = load_pytree(ft_path)
    else:
        print(f"[bench] LITE fine-tuning {model}/{lang} "
              f"({train_steps} steps) ...", flush=True)
        ft_params, _ = train_model(cfg, ds, kind="lite", steps=train_steps,
                                   batch_size=4, lr=1e-3, log_every=50)
        save_pytree(ft_params, ft_path)
    if os.path.exists(ag_path + ".npz") and not force:
        agent = load_pytree(ag_path)
    else:
        print(f"[bench] PPO agent {model}/{lang} ...", flush=True)
        coefs = (RewardCoefs(beta=1.0, gamma=1.0) if lang == "java"
                 else RewardCoefs(beta=0.5, gamma=0.5))  # paper §VI-D
        agent, _, _ = train_agent(
            params=ft_params, cfg=cfg, dataset=ds, n_episodes=32,
            gen_tokens=10, coefs=coefs,
            ppo=PPOConfig(total_steps=ppo_steps, horizon=128, n_lanes=16),
            log_every=20)
        save_pytree(agent, ag_path)
    out = (cfg, ds, base_params, ft_params, agent)
    _CACHE[key] = out
    return out


def _quality_row(ds, tasks, tokens_per_task, max_new):
    vocab = ds.tokenizer.vocab
    q = {"rougeL": [], "codebleu": [], "syntax": [], "dataflow": [],
         "em": []}
    for (ctx, ref), toks in zip(tasks, tokens_per_task):
        ref_t = [vocab[i] if i < len(vocab) else "?"
                 for i in ref[:max_new]]
        hyp_t = [vocab[i] if i < len(vocab) else "?" for i in toks]
        q["rougeL"].append(rouge_l(hyp_t, ref_t))
        cb = codebleu_like(hyp_t, ref_t)
        q["codebleu"].append(cb["codebleu"])
        q["syntax"].append(cb["syntax"])
        q["dataflow"].append(cb["dataflow"])
        q["em"].append(float(hyp_t[:5] == ref_t[:5]))
    return {k: float(np.mean(v)) for k, v in q.items()}


def _efficiency_row(metrics):
    agg = aggregate_metrics(metrics)
    toks_total = agg["tokens"]
    return {
        "mean_layers": agg["mean_layers"],
        "energy_j": agg["energy_j"],
        "energy_saving_frac": agg["energy_saving_frac"],
        "modeled_latency_s": agg["modeled_latency_s"],
        "modeled_throughput_tok_s": toks_total
        / max(agg["modeled_latency_s"], 1e-12),
        "tokens": toks_total,
    }


def evaluate(params, cfg, ds, policy, *, agent_params=None, n: int = 40,
             max_new: int = 15, ctx_frac: tuple = (0.2, 0.2),
             max_context: int = 192, seed: int = 0):
    """Paper §VI-C evaluation: returns quality + efficiency metrics.

    ``policy``: a ``repro.api.PolicySpec`` / name (resolved against
    ``agent_params`` for the RL kind) or a legacy controller callable."""
    tasks = ds.completion_tasks("test", n, seed=seed, ctx_lo=ctx_frac[0],
                                ctx_hi=ctx_frac[1], max_context=max_context)
    eng = Engine(params, cfg, policy, max_new=max_new,
                 max_context=max_context, agent_params=agent_params)
    t0 = time.time()
    res = eng.serve([c for c, _ in tasks])
    wall = time.time() - t0
    return {
        **_quality_row(ds, tasks, res.tokens, max_new),
        **_efficiency_row(res.metrics),
        "wall_s": wall,
    }


def evaluate_sweep(params, cfg, ds, specs, *, agent_params=None, n: int = 40,
                   max_new: int = 15, ctx_frac: tuple = (0.2, 0.2),
                   max_context: int = 192, seed: int = 0):
    """Evaluate MANY policy specs in ONE compiled batched run.

    The task batch is tiled once per spec and the specs are stacked into
    per-row policy ids/params (``stack_policies`` via
    ``Engine.serve_requests``), so the whole sweep — e.g. every GC
    threshold — shares a single fixed-shape compiled step instead of
    retracing per setting. Returns (rows, wall_s): one metrics dict per
    spec, in order.
    """
    specs = list(specs)
    tasks = ds.completion_tasks("test", n, seed=seed, ctx_lo=ctx_frac[0],
                                ctx_hi=ctx_frac[1], max_context=max_context)
    eng = Engine(params, cfg, max_new=max_new, max_context=max_context,
                 agent_params=agent_params)
    reqs = [GenerationRequest(prompt=c, max_new_tokens=max_new, policy=spec)
            for spec in specs for c, _ in tasks]
    t0 = time.time()
    results = eng.serve_requests(reqs)
    wall = time.time() - t0
    rows = []
    for si in range(len(specs)):
        chunk = results[si * len(tasks):(si + 1) * len(tasks)]
        rows.append({
            **_quality_row(ds, tasks, [r.tokens for r in chunk], max_new),
            **_efficiency_row([r.metrics for r in chunk]),
            "wall_s": wall / len(specs),
        })
    return rows, wall


def policies_for(thresholds=(0.6, 0.8, 0.9, 0.92)):
    """Named sweep of the paper's settings: full model + GC(T) specs (pass
    ``agent_params`` to ``evaluate``/``evaluate_sweep`` alongside)."""
    out = {"full(ft)": PolicySpec("none")}
    for t in thresholds:
        out[f"GC({t})"] = PolicySpec("policy", {"threshold": float(t)})
    return out


def save_result(name: str, data):
    os.makedirs(RES_DIR, exist_ok=True)
    with open(os.path.join(RES_DIR, f"{name}.json"), "w") as f:
        json.dump(data, f, indent=1, default=float)
    print(f"[bench] wrote experiments/results/{name}.json", flush=True)


def table(rows: list[dict], cols: list[str], title: str) -> str:
    out = [f"\n### {title}\n"]
    out.append("| " + " | ".join(cols) + " |")
    out.append("|" + "---|" * len(cols))
    for r in rows:
        cells = []
        for c in cols:
            v = r.get(c, "")
            cells.append(f"{v:.3f}" if isinstance(v, float) else str(v))
        out.append("| " + " | ".join(cells) + " |")
    return "\n".join(out)
