"""Fig. 1: fixed exiting at every exit point — quality vs energy/latency.

Reproduces the paper's motivating experiment: a LITE-fine-tuned model exits
at a fixed layer for every token; shallow layers already achieve a large
fraction of final-layer quality while energy/latency grow with depth.
"""
from __future__ import annotations

from benchmarks.common import (LANGS, MODELS, artifacts, evaluate,
                               save_result, table)
from repro.api import PolicySpec
from repro.models.transformer import plan_segments


def run(full: bool = False, n: int = 32):
    models = list(MODELS) if full else ["llama"]
    langs = list(LANGS) if full else ["java"]
    all_rows = []
    for model in models:
        for lang in langs:
            cfg, ds, _, ft, _ = artifacts(model, lang)
            segs = plan_segments(cfg)
            rows = []
            for i, seg in enumerate(segs):
                spec = (PolicySpec("none") if i == len(segs) - 1
                        else PolicySpec("fixed", {"exit_idx": i}))
                r = evaluate(ft, cfg, ds, spec, n=n)
                rows.append({"model": model, "lang": lang,
                             "exit_layer": seg.end, **r})
            all_rows += rows
            print(table(rows, ["exit_layer", "rougeL", "codebleu",
                               "syntax", "dataflow", "energy_j",
                               "modeled_latency_s"],
                        f"Fig.1 fixed exits — {model}/{lang}"))
            # paper's claim: an intermediate exit reaches a large fraction
            # of full quality at a fraction of the energy
            full_row, mid = rows[-1], rows[len(rows) // 2]
            frac_q = mid["codebleu"] / max(full_row["codebleu"], 1e-9)
            frac_e = mid["energy_j"] / max(full_row["energy_j"], 1e-9)
            print(f"  -> mid-exit keeps {frac_q:.0%} CodeBLEU at "
                  f"{frac_e:.0%} energy")
    save_result("fig1_fixed_exit", all_rows)
