"""Table IV: relative energy/time overhead of the RL agent itself.

Modeled exactly as the paper measures it: the extra forward passes through
the policy network (one per exit check) relative to the model's own cost,
at different thresholds (higher T -> more continue actions -> more checks).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import artifacts, save_result, table
from repro.api import PolicySpec
from repro.core import energy
from repro.core.early_exit import generate
from repro.models.transformer import plan_segments

import jax
import jax.numpy as jnp


def run(full: bool = False, n: int = 16):
    rows = []
    for model in (("llama", "opt") if full else ("llama",)):
        cfg, ds, _, ft, agent = artifacts(model, "java")
        segs = plan_segments(cfg)
        tasks = ds.completion_tasks("test", n, max_context=128)
        ctx = np.zeros((n, 128), np.int32)
        for j, (c, _) in enumerate(tasks):
            ctx[j, 128 - len(c):] = c
        for t in (0.6, 0.8, 0.9, 0.92):
            out = generate(ft, cfg, jnp.asarray(ctx), 10,
                           policy=PolicySpec("policy", {"threshold": t}),
                           agent_params=agent)
            exits = np.asarray(out["exit_layers"])
            # checks per token = number of boundaries passed before exit
            bounds = np.asarray([s.end for s in segs])
            checks = (exits[..., None] >= bounds[None, None, :-1]).sum(-1)
            e_model = energy.decode_token_energy(cfg, 128, exits).sum()
            e_agent = energy.controller_overhead_energy(
                cfg, checks).sum()
            e_full = energy.full_token_energy(cfg, 128) * exits.size
            rows.append({
                "model": model, "T": t,
                "mean_checks_per_token": float(checks.mean()),
                "overhead_vs_ee_model": float(e_agent / e_model),
                "overhead_vs_full_model": float(e_agent / e_full),
            })
    print(table(rows, ["model", "T", "mean_checks_per_token",
                       "overhead_vs_ee_model", "overhead_vs_full_model"],
                "Table IV: RL-agent overhead (modeled energy)"))
    worst = max(r["overhead_vs_ee_model"] for r in rows)
    print(f"  -> worst-case agent overhead {worst:.1%} of EE-model energy "
          f"(paper keeps it below ~20%)")
    save_result("tab4_overhead", rows)
