"""Fig. 6 (PPO convergence) and Fig. 7 (optimal-exit histogram)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import artifacts, save_result, table
from repro.rl import EarlyExitEnv, PPOConfig, RewardCoefs
from repro.rl.ppo import ppo_train
from repro.rl.rollout import build_rollout_cache


def run_training(full: bool = False, n: int = 0):
    """Train a fresh agent, record the mean-step-reward curve (Fig. 6)."""
    cfg, ds, _, ft, _ = artifacts("llama", "java")
    cache = build_rollout_cache(ft, cfg, ds, n_episodes=24, gen_tokens=8)
    env = EarlyExitEnv(cache, RewardCoefs(beta=1.0, gamma=1.0), n_lanes=16)
    _, hist = ppo_train(env, config=PPOConfig(total_steps=60_000,
                                              horizon=128, n_lanes=16),
                        log_every=0)
    rows = [{"iter": h["iter"], "mean_step_reward": h["mean_step_reward"]}
            for h in hist[:: max(1, len(hist) // 12)]]
    print(table(rows, ["iter", "mean_step_reward"],
                "Fig.6 PPO mean step reward (llama/java)"))
    first, last = hist[0], hist[-1]
    print(f"  -> reward {first['mean_step_reward']:+.3f} -> "
          f"{last['mean_step_reward']:+.3f} "
          f"({'converged' if last['mean_step_reward'] > 0.3 else 'check'})")
    save_result("fig6_rl_training", hist)


def run_histogram(full: bool = False, n: int = 0):
    """Distribution of optimal exits over training episodes (Fig. 7)."""
    cfg, ds, _, ft, _ = artifacts("llama", "java")
    cache = build_rollout_cache(ft, cfg, ds, n_episodes=48, gen_tokens=10,
                                seed=1)
    vals, counts = np.unique(cache.l_opt, return_counts=True)
    total = counts.sum()
    rows = [{"optimal_exit_layer": int(v),
             "fraction": float(c) / total} for v, c in zip(vals, counts)]
    print(table(rows, ["optimal_exit_layer", "fraction"],
                "Fig.7 optimal exits during RL training (llama/java)"))
    early = sum(c for v, c in zip(vals, counts)
                if v <= cache.boundaries[0]) / total
    print(f"  -> {early:.0%} of tokens are optimally predicted at the "
          f"first exit point (paper: 50-59% within 5 layers)")
    save_result("fig7_optimal_exits", rows)
