"""Benchmark harness — one benchmark per paper table/figure.

  fig1_fixed_exit     Fig. 1  : fixed exits -> quality/energy/latency curves
  fig6_rl_training    Fig. 6  : PPO mean-step-reward convergence
  fig7_optimal_exits  Fig. 7  : optimal-exit histogram over training data
  fig8_11_thresholds  Figs 8-11: GC(T) vs baselines (both models/datasets)
  fig12_context       Fig. 12 : context-length sensitivity
  fig13_kv_cache      Fig. 13 : KV-cache-propagation impact
  tab4_overhead       Table IV: RL-agent energy/time overhead
  roofline            §Roofline summary from the dry-run JSONs

  PYTHONPATH=src python -m benchmarks.run [--bench NAME] [--full]
"""
from __future__ import annotations

import argparse
import sys


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--bench", default="all")
    ap.add_argument("--full", action="store_true",
                    help="both models x both datasets (slower)")
    ap.add_argument("--n", type=int, default=20,
                    help="eval tasks per setting")
    args = ap.parse_args(argv)

    from benchmarks import (ablation_coefs, context_len, fixed_exit,
                            kv_cache, overhead, rl_curves, roofline,
                            thresholds)
    benches = {
        "fig1_fixed_exit": fixed_exit.run,
        "fig6_rl_training": rl_curves.run_training,
        "fig7_optimal_exits": rl_curves.run_histogram,
        "fig8_11_thresholds": thresholds.run,
        "fig12_context": context_len.run,
        "fig13_kv_cache": kv_cache.run,
        "tab4_overhead": overhead.run,
        "roofline": roofline.run,
    }
    # optional benches (not part of "all" — run by name)
    extra = {"ablation_coefs": ablation_coefs.run}
    if args.bench != "all":
        all_benches = {**benches, **extra}
        benches = {args.bench: all_benches[args.bench]}
    failed = []
    for name, fn in benches.items():
        print(f"\n===== {name} =====", flush=True)
        try:
            fn(full=args.full, n=args.n)
        except Exception as e:  # noqa: BLE001
            import traceback
            traceback.print_exc()
            failed.append((name, repr(e)))
    if failed:
        print("\nFAILED:", failed)
        sys.exit(1)
    print("\n[bench] all benchmarks complete")


if __name__ == "__main__":
    main()
