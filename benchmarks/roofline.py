"""§Roofline summary: aggregate the dry-run JSONs into the roofline table.

Reads experiments/dryrun/*.json (produced by ``python -m
repro.launch.dryrun --all``) and prints/writes the per-(arch x shape x
mesh) three-term table with bottleneck classification and
MODEL_FLOPS/HLO_FLOPs usefulness ratio.
"""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import save_result, table

DRY_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                       "dryrun")


def load_all():
    rows = []
    for fn in sorted(glob.glob(os.path.join(DRY_DIR, "*.json"))):
        with open(fn) as f:
            d = json.load(f)
        r = d["roofline"]
        rows.append({
            "arch": d["arch"], "shape": d["shape"], "mesh": d["mesh"],
            "compute_s": r["compute_s"], "memory_s": r["memory_s"],
            "collective_s": r["collective_s"],
            "bottleneck": r["bottleneck"],
            "mfu_ratio": r["mfu_ratio"],
            "hbm_GiB": d["memory"].get("total_hbm_bytes_per_device", 0)
            / 2**30,
            "compile_s": d["compile_s"],
        })
    return rows


def run(full: bool = False, n: int = 0):
    rows = load_all()
    if not rows:
        print("  (no dry-run results yet — run "
              "`python -m repro.launch.dryrun --all` first)")
        return
    print(table(rows, ["arch", "shape", "mesh", "compute_s", "memory_s",
                       "collective_s", "bottleneck", "mfu_ratio",
                       "hbm_GiB"],
                f"Roofline terms per (arch x shape x mesh) — {len(rows)} "
                f"combinations"))
    # summary: bottleneck distribution
    from collections import Counter
    c = Counter(r["bottleneck"] for r in rows)
    print(f"  -> bottleneck distribution: {dict(c)}")
    save_result("roofline_table", rows)
