"""Serving load benchmark: Poisson arrivals vs throughput / latency / energy.

Drives the same workload through two serving stacks at several arrival
rates:

  * ``scheduler`` — the continuous-batching scheduler (serving/scheduler.py):
    requests join/leave the fixed-shape decode batch at token granularity.
  * ``engine``    — the seed one-shot batcher (serving/engine.py) behind a
    naive dynamic batch former: whatever is queued when the engine goes idle
    is padded to a fixed batch and decoded for the batch-max ``max_new``
    (head-of-line blocking, wasted slots — the thing continuous batching
    removes).

The workload mixes prompt lengths and per-request ``max_new`` (the mix is
what the seed Engine cannot exploit: every sequence in its batch decodes for
the batch max). Reported per rate and per system:

  throughput   useful tokens / wall-clock second
  p50/p95      request latency (arrival -> all tokens done), seconds
  J/token      modeled energy per useful token (core.energy, TPU-v5e model)

Both systems are shape-warmed before the timed run so XLA compile time is
excluded — the comparison isolates steady-state scheduling behavior.

  PYTHONPATH=src python -m benchmarks.serving_load            # mini, CPU
  PYTHONPATH=src python -m benchmarks.serving_load --rates 4 10 25 --n 24
"""
from __future__ import annotations

import argparse
import json
import os
import threading
import time
from dataclasses import dataclass

import jax
import numpy as np

from repro.configs.llama32_3b import paper_mini
from repro.api import PolicySpec
from repro.models import transformer as T
from repro.serving import Engine, Scheduler
from repro.serving.metrics import latency_percentiles

RES_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                       "results")

PROMPT_LENS = (24, 40, 56)       # few distinct buckets -> few prefill shapes
MAX_NEWS = (4, 12)               # mixed decode lengths: the engine pays the
                                 # batch max for everyone, the scheduler
                                 # retires each slot at its own max_new


@dataclass
class Job:
    arrival_s: float             # offset from run start
    prompt: list
    max_new: int
    # results
    tokens: int = 0
    energy_j: float = 0.0
    latency_s: float = 0.0


def make_workload(n: int, rate_hz: float, vocab: int,
                  seed: int = 0) -> list[Job]:
    rng = np.random.default_rng(seed)
    t = 0.0
    jobs = []
    for _ in range(n):
        t += float(rng.exponential(1.0 / rate_hz))
        plen = int(rng.choice(PROMPT_LENS))
        jobs.append(Job(arrival_s=t,
                        prompt=rng.integers(4, vocab, plen).tolist(),
                        max_new=int(rng.choice(MAX_NEWS))))
    return jobs


# ---------------------------------------------------------------------------
# scheduler path
# ---------------------------------------------------------------------------
def run_scheduler(sched: Scheduler, jobs: list[Job]) -> dict:
    handles = [None] * len(jobs)
    t0 = time.monotonic()
    for i, job in enumerate(jobs):
        delay = t0 + job.arrival_s - time.monotonic()
        if delay > 0:
            time.sleep(delay)
        handles[i] = sched.submit(job.prompt, max_new=job.max_new)
    for job, h in zip(jobs, handles):
        h.result(timeout=300.0)
        job.tokens = len(h.tokens)
        job.energy_j = h.metrics.energy_j
        job.latency_s = h.latency_s
    wall = time.monotonic() - t0
    return _summarize(jobs, wall)


# ---------------------------------------------------------------------------
# seed-engine baseline: naive dynamic batcher over Engine.serve
# ---------------------------------------------------------------------------
def run_engine(engine: Engine, ctrl, jobs: list[Job], batch: int) -> dict:
    """Form a fixed-size batch from whatever has arrived whenever the engine
    is idle (short rows padded by repeating the first prompt), decode the
    batch-max max_new for everyone, count only each request's own tokens."""
    pending: list[Job] = []
    lock = threading.Lock()
    t0 = time.monotonic()

    def feeder():
        for job in jobs:
            delay = t0 + job.arrival_s - time.monotonic()
            if delay > 0:
                time.sleep(delay)
            with lock:
                pending.append(job)

    th = threading.Thread(target=feeder, daemon=True)
    th.start()
    served = 0
    while served < len(jobs):
        with lock:
            take = pending[:batch]
            del pending[:len(take)]
        if not take:
            time.sleep(0.001)
            continue
        # pad the batch to its fixed shape — the seed batcher's whole-batch
        # shape is what it is regardless of how many requests showed up
        rows = [j.prompt for j in take]
        while len(rows) < batch:
            rows.append(take[0].prompt)
        step_max = max(j.max_new for j in take)
        res = engine.serve(rows, max_new=step_max, controller=ctrl)
        done = time.monotonic()
        for job, toks, el, m in zip(take, res.tokens, res.exit_layers,
                                    res.metrics):
            # the engine decoded step_max tokens for this row; only the
            # request's own max_new are useful, but the energy of the whole
            # row was spent (the waste is the point of this baseline)
            job.tokens = min(len(toks), job.max_new)
            job.energy_j = m.energy_j
            job.latency_s = done - (t0 + job.arrival_s)
        served += len(take)
    wall = time.monotonic() - t0
    return _summarize(jobs, wall)


def warmup(sched: Scheduler, engine: Engine, ctrl, batch: int) -> None:
    """Trigger every XLA compile both systems will hit in the timed runs."""
    rng = np.random.default_rng(123)
    for plen in PROMPT_LENS:
        prompt = rng.integers(4, sched.cfg.vocab_size, plen).tolist()
        sched.serve_batch([prompt], max_new=max(MAX_NEWS))
        for mn in MAX_NEWS:
            engine.serve([prompt] * batch, max_new=mn, controller=ctrl)


def run(rates=(4.0, 10.0, 25.0), n: int = 24, *, num_layers: int = 8,
        d_model: int = 96, vocab: int = 512, slots: int = 4,
        exit_idx: int = 0, seed: int = 0, save: bool = True) -> list[dict]:
    cfg = paper_mini(num_layers=num_layers, d_model=d_model,
                     vocab_size=vocab)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    max_len = max(PROMPT_LENS) + max(MAX_NEWS)
    sched = Scheduler(params, cfg, controller_kind="fixed",
                      fixed_exit_idx=exit_idx,
                      allowed_kinds=("none", "fixed"),
                      max_slots=slots, max_len=max_len,
                      queue_depth=max(64, n)).start()
    engine = Engine(params, cfg, max_context=max(PROMPT_LENS))
    ctrl = PolicySpec("fixed", {"exit_idx": exit_idx})
    print(f"[load] warming shapes (model {num_layers}L/{d_model}d, "
          f"{slots} slots) ...", flush=True)
    warmup(sched, engine, ctrl, slots)

    results = []
    for rate in rates:
        for system in ("scheduler", "engine"):
            jobs = make_workload(n, rate, vocab, seed=seed)
            if system == "scheduler":
                r = run_scheduler(sched, jobs)
            else:
                r = run_engine(engine, ctrl, jobs, slots)
            r.update(system=system, rate_hz=rate)
            results.append(r)
            print(f"[load] rate={rate:6.1f}/s {system:9s} "
                  f"tput={r['throughput_tok_s']:7.1f} tok/s "
                  f"p50={r['latency_p50_s']:.3f}s "
                  f"p95={r['latency_p95_s']:.3f}s "
                  f"J/tok={r['j_per_token']:.3e}", flush=True)
    sched.stop()

    top = max(rates)
    tput = {r["system"]: r["throughput_tok_s"] for r in results
            if r["rate_hz"] == top}
    speedup = tput["scheduler"] / max(tput["engine"], 1e-9)
    print(f"[load] @ {top}/s: continuous batching {speedup:.2f}x the "
          f"seed engine baseline "
          f"({'BEATS' if speedup > 1.0 else 'DOES NOT BEAT'} it)")
    if save:
        os.makedirs(RES_DIR, exist_ok=True)
        out = os.path.join(RES_DIR, "serving_load.json")
        with open(out, "w") as f:
            json.dump({"config": {"num_layers": num_layers,
                                  "d_model": d_model, "vocab": vocab,
                                  "slots": slots, "n": n,
                                  "rates": list(rates)},
                       "results": results,
                       "speedup_at_top_rate": speedup}, f, indent=2)
        print(f"[load] wrote {out}")
    return results


def _summarize(jobs: list[Job], wall: float) -> dict:
    toks = sum(j.tokens for j in jobs)
    e = sum(j.energy_j for j in jobs)
    pct = latency_percentiles([j.latency_s for j in jobs])
    return {
        "requests": len(jobs),
        "useful_tokens": toks,
        "wall_s": wall,
        "throughput_tok_s": toks / max(wall, 1e-9),
        "latency_p50_s": pct["p50_s"],
        "latency_p95_s": pct["p95_s"],
        "j_per_token": e / max(toks, 1),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rates", type=float, nargs="+",
                    default=[4.0, 10.0, 25.0],
                    help="Poisson arrival rates (requests/s)")
    ap.add_argument("--n", type=int, default=24, help="requests per rate")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--layers", type=int, default=8)
    ap.add_argument("--d-model", type=int, default=96)
    ap.add_argument("--vocab", type=int, default=512)
    ap.add_argument("--exit-idx", type=int, default=0,
                    help="fixed-controller exit point index")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--no-save", action="store_true")
    args = ap.parse_args()
    run(tuple(args.rates), args.n, num_layers=args.layers,
        d_model=args.d_model, vocab=args.vocab, slots=args.slots,
        exit_idx=args.exit_idx, seed=args.seed, save=not args.no_save)


if __name__ == "__main__":
    main()
