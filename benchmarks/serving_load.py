"""Serving load benchmark: Poisson arrivals vs throughput / latency / energy.

Drives the same workload through two serving stacks at several arrival
rates:

  * ``scheduler`` — the continuous-batching scheduler (serving/scheduler.py):
    requests join/leave the fixed-shape decode batch at token granularity.
  * ``engine``    — the seed one-shot batcher (serving/engine.py) behind a
    naive dynamic batch former: whatever is queued when the engine goes idle
    is padded to a fixed batch and decoded for the batch-max ``max_new``
    (head-of-line blocking, wasted slots — the thing continuous batching
    removes).

The workload mixes prompt lengths and per-request ``max_new`` (the mix is
what the seed Engine cannot exploit: every sequence in its batch decodes for
the batch max). Reported per rate and per system:

  throughput   useful tokens / wall-clock second
  p50/p95      request latency (arrival -> all tokens done), seconds
  J/token      modeled energy per useful token (core.energy, TPU-v5e model)

A second phase compares the **paged** KV pool against the contiguous one at
an **equal KV-memory budget** and the top arrival rate: the contiguous pool
must size every slot for the worst case (``max_len`` tokens), the paged
pool spends the same bytes on blocks that requests bind per
``ceil(ctx/block_size)`` — so it holds strictly more concurrent residents.
Half the workload's prompts start from a small set of shared system
prefixes, so the prefix cache's hit rate shows up too.

A third phase compares **self-speculative decoding** against the full-depth
baseline at equal accuracy (greedy speculative tokens are asserted
identical) and against plain early exit at the same draft boundary (cheaper
but inexact), reporting acceptance rate, accepted tokens per verify and
modeled J/token (draft-layer + full-depth FLOPs charged separately).

A fourth phase replays one workload through both pools' admission
bookkeeping on a **virtual clock** (``run_admission_trace``): the
admit/retire event log and peak concurrent residents are deterministic
functions of the workload, so ``paged_admits_more_concurrent`` hard-gates
in CI instead of the old warn-only wall-clock race.

A fifth phase (``run_prefill_compare``) measures prompt-ingestion TTFT
and XLA compile counts across many distinct prompt lengths for chunked
vs bucketed vs per-length prefill — chunked compiles exactly ONE shape;
CI gates on ``chunked_compiles <= bucketed_compiles``.

A fleet pair of phases covers data-parallel replica serving
(``repro.serving.fleet``): ``run_fleet_trace`` replays one workload
through N replicas' slot bookkeeping behind each placement policy on a
virtual clock — the route/admit/retire event log (with replica
assignments) and per-replica modeled energy are deterministic, so CI
hard-gates both trace equality and ``energy_beats_rr`` (the
energy-headroom policy ends with a lower max-replica energy share than
round-robin). ``run_fleet_compare`` serves the wall-clock workload
through 1 vs N replicas at an equal aggregate KV budget, per placement
policy (throughput / p95 / J-per-token / energy shares).

A sixth phase (``run_phase_breakdown``) serves the workload through
traced schedulers (contiguous / paged / speculative) and reports where
each tick's time goes — per tick phase, count / total / device-wait vs
host split plus dispatch and sync-point counters (``repro.obs`` spans;
the drained Chrome trace is structurally validated first).

Both systems are shape-warmed before the timed run so XLA compile time is
excluded — the comparison isolates steady-state scheduling behavior.
Results also land in ``BENCH_serving.json`` at the repo root (schema-stable
across PRs: tokens/s, peak cache bytes, prefix-hit rate per system).

  PYTHONPATH=src python -m benchmarks.serving_load            # mini, CPU
  PYTHONPATH=src python -m benchmarks.serving_load --rates 4 10 25 --n 24
  PYTHONPATH=src python -m benchmarks.serving_load --smoke    # CI-speed
"""
from __future__ import annotations

import argparse
import json
import os
import threading
import time
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.llama32_3b import paper_mini
from repro.api import PolicySpec
from repro.models import transformer as T
from repro.serving import Engine, Scheduler
from repro.serving.metrics import latency_percentiles

RES_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                       "results")
REPO_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))

PROMPT_LENS = (24, 40, 56)       # few distinct buckets -> few prefill shapes
MAX_NEWS = (4, 12)               # mixed decode lengths: the engine pays the
                                 # batch max for everyone, the scheduler
                                 # retires each slot at its own max_new
PREFIX_LEN = 16                  # shared "system prompt" prefix pool
N_PREFIXES = 2


@dataclass
class Job:
    arrival_s: float             # offset from run start
    prompt: list
    max_new: int
    # results
    tokens: int = 0
    energy_j: float = 0.0
    latency_s: float = 0.0
    ttft_s: float = None         # submit -> first token (scheduler path)
    result_tokens: list = None   # generated ids (spec-compare exactness)


def make_workload(n: int, rate_hz: float, vocab: int,
                  seed: int = 0, class_mix: bool = False) -> list[Job]:
    """Poisson arrivals; half the prompts start from one of ``N_PREFIXES``
    shared prefixes (block-aligned system prompts — the prefix cache's
    bread and butter), the other half are fully random.

    ``class_mix=True`` makes the cost structure deterministic instead of
    i.i.d.: arrivals alternate an *interactive* class (shortest prompt,
    smallest ``max_new``) with a *batch* class (longest prompt, largest
    ``max_new``, shared prefixes) — the request-class heterogeneity the
    fleet phases route on. Cost-blind round-robin aliases the heavy
    class onto the same replicas (period-2 arrivals, cost ~5x); an
    i.i.d. mix hides that failure mode behind the law of large numbers.
    """
    rng = np.random.default_rng(seed)
    prefixes = [rng.integers(4, vocab, PREFIX_LEN).tolist()
                for _ in range(N_PREFIXES)]
    t = 0.0
    jobs = []
    for i in range(n):
        t += float(rng.exponential(1.0 / rate_hz))
        if class_mix:
            plen = PROMPT_LENS[-1] if i % 2 else PROMPT_LENS[0]
            max_new = MAX_NEWS[-1] if i % 2 else MAX_NEWS[0]
        else:
            plen = int(rng.choice(PROMPT_LENS))
            max_new = int(rng.choice(MAX_NEWS))
        if i % 2:
            head = prefixes[int(rng.integers(N_PREFIXES))]
            prompt = head + rng.integers(4, vocab, plen - len(head)).tolist()
        else:
            prompt = rng.integers(4, vocab, plen).tolist()
        jobs.append(Job(arrival_s=t, prompt=prompt, max_new=max_new))
    return jobs


# ---------------------------------------------------------------------------
# scheduler path
# ---------------------------------------------------------------------------
def run_scheduler(sched: Scheduler, jobs: list[Job]) -> dict:
    handles = [None] * len(jobs)
    t0 = time.monotonic()
    for i, job in enumerate(jobs):
        delay = t0 + job.arrival_s - time.monotonic()
        if delay > 0:
            time.sleep(delay)
        handles[i] = sched.submit(job.prompt, max_new=job.max_new)
    for job, h in zip(jobs, handles):
        h.result(timeout=300.0)
        job.tokens = len(h.tokens)
        # per-request accumulated energy: for speculative requests this is
        # the draft+verify accounting, not the per-exit-layer model
        job.energy_j = h.energy_j
        job.latency_s = h.latency_s
        job.ttft_s = h.ttft_s
        job.result_tokens = list(h.tokens)
    wall = time.monotonic() - t0
    return _summarize(jobs, wall)


# ---------------------------------------------------------------------------
# seed-engine baseline: naive dynamic batcher over Engine.serve
# ---------------------------------------------------------------------------
def run_engine(engine: Engine, ctrl, jobs: list[Job], batch: int) -> dict:
    """Form a fixed-size batch from whatever has arrived whenever the engine
    is idle (short rows padded by repeating the first prompt), decode the
    batch-max max_new for everyone, count only each request's own tokens."""
    pending: list[Job] = []
    lock = threading.Lock()
    t0 = time.monotonic()

    def feeder():
        for job in jobs:
            delay = t0 + job.arrival_s - time.monotonic()
            if delay > 0:
                time.sleep(delay)
            with lock:
                pending.append(job)

    th = threading.Thread(target=feeder, daemon=True)
    th.start()
    served = 0
    while served < len(jobs):
        with lock:
            take = pending[:batch]
            del pending[:len(take)]
        if not take:
            time.sleep(0.001)
            continue
        # pad the batch to its fixed shape — the seed batcher's whole-batch
        # shape is what it is regardless of how many requests showed up
        rows = [j.prompt for j in take]
        while len(rows) < batch:
            rows.append(take[0].prompt)
        step_max = max(j.max_new for j in take)
        res = engine.serve(rows, max_new=step_max, controller=ctrl)
        done = time.monotonic()
        for job, toks, el, m in zip(take, res.tokens, res.exit_layers,
                                    res.metrics):
            # the engine decoded step_max tokens for this row; only the
            # request's own max_new are useful, but the energy of the whole
            # row was spent (the waste is the point of this baseline)
            job.tokens = min(len(toks), job.max_new)
            job.energy_j = m.energy_j
            job.latency_s = done - (t0 + job.arrival_s)
        served += len(take)
    wall = time.monotonic() - t0
    return _summarize(jobs, wall)


def warmup(sched: Scheduler, engine: Engine, ctrl, batch: int) -> None:
    """Trigger every XLA compile both systems will hit in the timed runs."""
    rng = np.random.default_rng(123)
    for plen in PROMPT_LENS:
        prompt = rng.integers(4, sched.cfg.vocab_size, plen).tolist()
        sched.serve_batch([prompt], max_new=max(MAX_NEWS))
        for mn in MAX_NEWS:
            engine.serve([prompt] * batch, max_new=mn, controller=ctrl)


def run_kv_compare(params, cfg, *, rate: float, n: int, slots: int,
                   max_len: int, exit_idx: int, block_size: int = 8,
                   seed: int = 0) -> dict:
    """Contiguous vs paged scheduler at an EQUAL KV-memory budget.

    The contiguous pool spends ``max_slots * max_len`` tokens of cache up
    front; the paged pool gets the same byte budget as blocks plus 4x the
    slots (slot rows are bookkeeping — blocks are the scarce resource) and
    admits on block availability. Reports peak concurrent residents, peak
    cache bytes actually bound, throughput and prefix-hit rate.
    """
    from repro.serving.kv_pool import PagedKVPool

    base = dict(controller_kind="fixed", fixed_exit_idx=exit_idx,
                allowed_kinds=("none", "fixed"), max_len=max_len,
                queue_depth=max(64, n))
    probe = PagedKVPool(cfg, 1, block_size, block_size=block_size,
                        num_blocks=2)
    bytes_per_block = probe.bytes_per_block
    del probe

    out: dict = {}
    budget = None
    for layout in ("contiguous", "paged"):
        if layout == "contiguous":
            sched = Scheduler(params, cfg, max_slots=slots, **base).start()
            budget = sched.pool.kv_bytes_total
            num_blocks = None
        else:
            num_blocks = max(budget // bytes_per_block, 2)
            sched = Scheduler(params, cfg, max_slots=4 * slots,
                              kv_layout="paged", block_size=block_size,
                              num_blocks=num_blocks, **base).start()
        # warm every prefill/step shape outside the timed run — including
        # the paged writer's (n_write, n_skip) variants that only trigger
        # on a prefix-cache hit (half the workload shares prefixes, so the
        # first in-run hit per prompt length would otherwise compile
        # mid-measurement) — then clear the counters so the reported stats
        # cover only the timed run
        rng = np.random.default_rng(123)
        for plen in PROMPT_LENS:
            head = rng.integers(4, cfg.vocab_size, PREFIX_LEN).tolist()
            tail = lambda: rng.integers(                   # noqa: E731
                4, cfg.vocab_size, plen - PREFIX_LEN).tolist()
            sched.serve_batch([head + tail(), head + tail()],
                              max_new=max(MAX_NEWS))
        sched.reset_peak_stats()
        jobs = make_workload(n, rate, cfg.vocab_size, seed=seed)
        r = run_scheduler(sched, jobs)
        st = sched.stats()
        sched.stop()
        r.update(
            kv_layout=layout,
            max_slots=st["max_slots"],
            peak_active_slots=st["peak_active_slots"],
            kv_bytes_budget=int(budget if layout == "contiguous"
                                else num_blocks * bytes_per_block),
            peak_kv_bytes=int(st.get("peak_kv_bytes",
                                     st.get("kv_bytes_total", 0))),
            prefix_hit_rate=st.get("prefix_hit_rate", 0.0),
            blocked_admissions=st.get("blocked_admissions", 0),
        )
        out[layout] = r
        print(f"[load] kv-compare {layout:10s} "
              f"tput={r['throughput_tok_s']:7.1f} tok/s "
              f"peak_residents={r['peak_active_slots']} "
              f"peak_kv={r['peak_kv_bytes']} B "
              f"prefix_hit={r['prefix_hit_rate']:.2f}", flush=True)
    more = (out["paged"]["peak_active_slots"]
            > out["contiguous"]["peak_active_slots"])
    out["paged_admits_more_concurrent"] = bool(more)
    print(f"[load] equal-budget paged admits "
          f"{'STRICTLY MORE' if more else 'NO MORE'} concurrent requests "
          f"({out['paged']['peak_active_slots']} vs "
          f"{out['contiguous']['peak_active_slots']})")
    return out


def run_phase_breakdown(params, cfg, *, rate: float, n: int, slots: int,
                        max_len: int, exit_idx: int, block_size: int = 8,
                        spec_window: int = 4, seed: int = 0) -> dict:
    """Where does a tick go? Per-system tick-phase time breakdown.

    Serves one Poisson workload through three traced schedulers —
    contiguous, paged, and speculative (paged, draft-then-verify) — and
    reports, per system, each phase's count / total / device-wait / host
    split (``repro.obs`` spans), plus dispatch and sync-point counters
    for the timed window only (warmup spans are drained away first).
    The drained trace is structurally validated (every B has an E,
    phases nest under ticks) before it is summarized.
    """
    from repro.core.exit_points import num_exits
    from repro.obs import Tracer, summarize_spans, validate_chrome_trace

    # speculative needs a real intermediate exit point to draft at
    spec_cfg = (cfg if cfg.num_layers >= 6 else
                paper_mini(num_layers=6, d_model=cfg.d_model,
                           vocab_size=cfg.vocab_size))
    spec_params = (params if spec_cfg is cfg
                   else T.init_params(jax.random.PRNGKey(0), spec_cfg))
    fixed = dict(controller_kind="fixed", fixed_exit_idx=exit_idx,
                 allowed_kinds=("none", "fixed"), max_slots=slots,
                 max_len=max_len)
    systems = {
        "contiguous": (params, cfg, dict(fixed)),
        "paged": (params, cfg,
                  dict(fixed, kv_layout="paged", block_size=block_size)),
        "speculative": (spec_params, spec_cfg, dict(
            default_policy=PolicySpec(
                "speculative", {"draft_idx": num_exits(spec_cfg) - 1,
                                "window": spec_window}),
            allowed_kinds=("none", "speculative"), max_slots=slots,
            max_len=max_len + spec_window, kv_layout="paged",
            block_size=block_size, spec_window=spec_window)),
    }
    out: dict = {}
    for system, (p, c, kw) in systems.items():
        tracer = Tracer()
        sched = Scheduler(p, c, queue_depth=max(64, n),
                          tracer=tracer, **kw).start()
        rng = np.random.default_rng(123)
        for plen in PROMPT_LENS:              # warm every shape off-trace
            for mn in MAX_NEWS:
                sched.serve_batch(
                    [rng.integers(4, c.vocab_size, plen).tolist()],
                    max_new=mn)
        sched.reset_peak_stats()
        tracer.drain()                        # warmup spans out the window
        c0 = tracer.counters
        jobs = make_workload(n, rate, c.vocab_size, seed=seed)
        r = run_scheduler(sched, jobs)
        sched.stop()                          # drain tick closes the trace
        events = tracer.drain()
        # the warmup drain may have cut a live tick: boundary-partial OK
        summ = validate_chrome_trace(events, allow_partial=True)
        phases = summarize_spans(events)
        c1 = tracer.counters
        ctrs = {k: c1[k] - c0.get(k, 0) for k in c1}
        tick_s = phases.get("tick", {}).get("total_s", 0.0)
        # leaf phases hold the device waits (attribution is innermost)
        leaf_dw = sum(ph["device_wait_s"] for nm, ph in phases.items()
                      if nm not in ("tick", "drain"))
        out[system] = {
            "phases": phases,
            "dispatches": int(ctrs.get("dispatch", 0)),
            "sync_points": int(ctrs.get("sync_points", 0)),
            "trace_events": summ["events"],
            "span_names": summ["span_names"],
            "ticks": phases.get("tick", {}).get("count", 0),
            "device_wait_frac": leaf_dw / max(tick_s, 1e-9),
            "wall_s": r["wall_s"],
            "throughput_tok_s": r["throughput_tok_s"],
        }
        print(f"[load] phase-breakdown {system:12s} "
              f"ticks={out[system]['ticks']:<5} "
              f"dispatches={out[system]['dispatches']:<5} "
              f"sync={out[system]['sync_points']:<5} "
              f"device_wait={out[system]['device_wait_frac']*100:5.1f}% "
              f"of tick time", flush=True)
    return out


def run_admission_trace(cfg, *, slots: int, max_len: int,
                        block_size: int = 8, n: int = 24,
                        seed: int = 0, tracer=None) -> dict:
    """Deterministic admission trace: paged vs contiguous at an equal
    KV-byte budget on a VIRTUAL clock.

    One workload replays through the two pools' real admission / growth /
    retirement bookkeeping — no decode thread, no device compute, no wall
    clock. One tick = one decode step; job ``i`` arrives at tick ``i``;
    a resident emits one token per tick and retires at its own
    ``max_new``. The admit/retire event log and the peak number of
    concurrent residents are therefore pure functions of (workload, pool
    geometry): two replays produce structurally identical logs, so CI can
    hard-gate ``paged_admits_more_concurrent`` instead of warn-only
    racing on shared runners (the old wall-clock formulation).

    ``tracer`` (a :class:`repro.obs.Tracer`, typically built on
    ``make_step_clock``) records tick / admit / decode_step / retire
    spans for the replay: with the virtual clock the drained span log is
    itself deterministic — two replays are byte-identical — which is what
    tests assert trace *structure* against.
    """
    from repro.obs.trace import NULL_TRACER
    from repro.serving.kv_pool import PagedKVPool
    from repro.serving.scheduler import KVSlotPool

    obs = tracer if tracer is not None else NULL_TRACER

    jobs = make_workload(n, 1.0, cfg.vocab_size, seed=seed)
    # one pool per layout, reused for budget math AND the replay — the
    # trace drives bookkeeping only (device writers stubbed), so no other
    # device allocation is needed
    cont_pool = KVSlotPool(cfg, slots, max_len)
    probe = PagedKVPool(cfg, 1, block_size, block_size=block_size,
                        num_blocks=2)
    num_blocks = max(cont_pool.kv_bytes_total // probe.bytes_per_block, 2)
    del probe
    paged_pool = PagedKVPool(cfg, 4 * slots, max_len,
                             block_size=block_size, num_blocks=num_blocks)
    paged_pool._writer = lambda c, *a, **k: c       # accounting only
    paged_pool._copier = lambda c, *a, **k: c

    def trace(paged: bool) -> dict:
        pool = paged_pool if paged else cont_pool
        pending = list(range(len(jobs)))            # job i arrives at tick i
        queue: list[int] = []
        resident: dict[int, list] = {}              # slot -> [i, pos, left]
        events: list[tuple] = []
        peak = 0
        t = 0
        layout = "paged" if paged else "contiguous"
        while (pending or queue or resident) and t < 100_000:
            with obs.span("tick", cat="tick", layout=layout, t=t):
                with obs.span("admit"):
                    while pending and pending[0] <= t:
                        queue.append(pending.pop(0))
                    # shortest-prompt-first, submit-order tiebreak (the
                    # scheduler's _pick_next rule; its aging clause is
                    # wall-clock and has no virtual-time analogue here)
                    while pool.n_free and queue:
                        order = sorted(queue,
                                       key=lambda i: (len(jobs[i].prompt),
                                                      i))
                        pick = None
                        for i in order:
                            if not paged or pool.can_admit(
                                    jobs[i].prompt, jobs[i].max_new):
                                pick = i
                                break
                        if pick is None:
                            break                   # block-starved
                        queue.remove(pick)
                        slot = pool.alloc()
                        if paged:
                            pool.write_prompt(slot, jobs[pick].prompt,
                                              None,
                                              max_new=jobs[pick].max_new)
                        resident[slot] = [pick, len(jobs[pick].prompt),
                                          jobs[pick].max_new]
                        events.append((t, "admit", pick))
                peak = max(peak, len(resident))
                with obs.span("decode_step", residents=len(resident)):
                    for slot in sorted(resident):
                        i, pos, left = resident[slot]
                        if paged:
                            pool.prepare_append(slot, pos)  # block growth
                        resident[slot] = [i, pos + 1, left - 1]
                        if left - 1 == 0:
                            with obs.span("retire", req_id=i):
                                pool.release(slot)
                                del resident[slot]
                            events.append((t, "retire", i))
            t += 1
        assert not (pending or queue or resident), \
            "admission trace failed to drain"
        return {"peak_residents": peak, "ticks": t,
                "events": [list(e) for e in events]}

    out = {"contiguous": trace(False), "paged": trace(True)}
    more = (out["paged"]["peak_residents"]
            > out["contiguous"]["peak_residents"])
    out["paged_admits_more_concurrent"] = bool(more)
    print(f"[load] admission-trace (virtual clock): paged peak residents "
          f"{out['paged']['peak_residents']} vs contiguous "
          f"{out['contiguous']['peak_residents']} — "
          f"{'STRICTLY MORE' if more else 'NO MORE'} (deterministic)")
    return out


def run_fleet_trace(cfg, *, n_replicas: int = 3, slots: int = 2,
                    n: int = 32, seed: int = 0,
                    policies=("rr", "least_queue", "energy")) -> dict:
    """Deterministic multi-replica routing trace on a VIRTUAL clock.

    One workload replays through ``n_replicas`` replicas' slot
    bookkeeping behind each placement policy — no decode threads, no
    device compute, no wall clock. One tick = one decode step everywhere;
    job ``i`` arrives (and is routed) at tick ``i``; a resident emits one
    token per tick, charges the modeled full-depth J for its position
    (``core.energy.decode_token_energy``), and retires at its own
    ``max_new``. Each replica's power-gate EMA updates per tick exactly
    like the scheduler's (0.9/0.1 blend, 1 virtual second per tick), and
    the router sees those EMAs — so the route/admit/retire event log
    (WITH replica assignments) is a pure function of (workload, fleet
    geometry, policy): two replays are identical, which CI hard-gates.

    Also reports per-replica energy totals per policy:
    ``energy_beats_rr`` asserts the energy-headroom policy ends with a
    lower max-replica energy share than round-robin (it routes away from
    the hottest replica; rr is load-blind).
    """
    from repro.core.energy import decode_token_energy
    from repro.serving.fleet import ReplicaSnapshot, make_placement

    jobs = make_workload(n, 1.0, cfg.vocab_size, seed=seed, class_mix=True)
    full_depth = cfg.num_layers

    def trace(policy_name: str) -> dict:
        policy = make_placement(policy_name)
        queues: list[list[int]] = [[] for _ in range(n_replicas)]
        residents: list[dict[int, list]] = [{} for _ in range(n_replicas)]
        energy = [0.0] * n_replicas
        ema = [0.0] * n_replicas
        prefix_home: dict = {}
        events: list[list] = []
        t = 0
        routed = 0
        while (routed < len(jobs) or any(queues)
               or any(residents)) and t < 100_000:
            # arrivals: job t routes at tick t against the CURRENT EMAs
            if routed < len(jobs) and routed <= t:
                i = routed
                key = tuple(jobs[i].prompt[:PREFIX_LEN])
                snaps = [ReplicaSnapshot(
                    replica_id=r, queue_depth=len(queues[r]),
                    active_slots=len(residents[r]), prefilling=False,
                    power_w_ema=ema[r], power_budget_w=None,
                    energy_j=energy[r])
                    for r in range(n_replicas)]
                rid = policy.choose(snaps, prefix_home=prefix_home.get(key))
                prefix_home[key] = rid
                queues[rid].append(i)
                events.append([t, "route", i, rid])
                routed += 1
            for r in range(n_replicas):
                # admit: shortest-prompt-first, submit-order tiebreak
                # (the scheduler's _pick_next rule, minus its wall-clock
                # aging clause)
                while len(residents[r]) < slots and queues[r]:
                    pick = min(queues[r],
                               key=lambda i: (len(jobs[i].prompt), i))
                    queues[r].remove(pick)
                    slot = min(set(range(slots)) - set(residents[r]))
                    residents[r][slot] = [pick, len(jobs[pick].prompt),
                                          jobs[pick].max_new]
                    events.append([t, "admit", pick, r])
                # decode: one token per resident per tick, full-depth J
                e_tick = 0.0
                for slot in sorted(residents[r]):
                    i, pos, left = residents[r][slot]
                    e_tick += float(decode_token_energy(cfg, pos,
                                                        full_depth))
                    residents[r][slot] = [i, pos + 1, left - 1]
                    if left - 1 == 0:
                        del residents[r][slot]
                        events.append([t, "retire", i, r])
                energy[r] += e_tick
                ema[r] = 0.9 * ema[r] + 0.1 * e_tick    # dt = 1 virtual s
            t += 1
        assert routed == len(jobs) and not any(queues) \
            and not any(residents), "fleet trace failed to drain"
        total = sum(energy)
        return {"ticks": t, "events": events,
                "replica_energy_j": [float(e) for e in energy],
                "max_replica_energy_share": (max(energy) / total
                                             if total > 0 else 0.0),
                "routed_per_replica": [
                    sum(1 for e in events
                        if e[1] == "route" and e[3] == r)
                    for r in range(n_replicas)]}

    out: dict = {"n_replicas": n_replicas, "slots": slots, "n": n}
    for name in policies:
        r = trace(name)
        out[name] = r
        print(f"[load] fleet-trace {name:12s} ticks={r['ticks']:<5} "
              f"routed={r['routed_per_replica']} "
              f"max energy share={r['max_replica_energy_share']:.3f}",
              flush=True)
    if "rr" in out and "energy" in out:
        beats = (out["energy"]["max_replica_energy_share"]
                 < out["rr"]["max_replica_energy_share"])
        out["energy_beats_rr"] = bool(beats)
        print(f"[load] energy-headroom placement "
              f"{'SHIFTS load off' if beats else 'DOES NOT shift load off'}"
              f" the hottest replica vs round-robin "
              f"({out['energy']['max_replica_energy_share']:.3f} vs "
              f"{out['rr']['max_replica_energy_share']:.3f}, deterministic)")
    return out


def run_fleet_compare(params, cfg, *, rate: float, n: int, slots: int,
                      n_replicas: int, max_len: int, exit_idx: int,
                      seed: int = 0) -> dict:
    """1 vs N replicas at an EQUAL aggregate KV budget.

    The single-scheduler baseline gets ``slots * n_replicas`` KV slots
    in one pool (same total cache bytes as the fleet) but one decode
    thread and ONE admission stream; the fleet splits the same budget
    across ``n_replicas`` independent replicas behind the router — N
    decode loops and N concurrent admission streams. Reported per
    placement policy: throughput, p95 latency, J/token, and the
    max-replica energy share (how well placement spread the joules).
    """
    from repro.serving import Router

    base = dict(controller_kind="fixed", fixed_exit_idx=exit_idx,
                allowed_kinds=("none", "fixed"), max_len=max_len,
                queue_depth=max(64, n))

    out: dict = {}
    # -- single-replica baseline at the aggregate budget
    sched = Scheduler(params, cfg, max_slots=slots * n_replicas,
                      **base).start()
    rng = np.random.default_rng(123)
    for plen in PROMPT_LENS:          # warm every prefill shape off-clock
        sched.serve_batch([rng.integers(4, cfg.vocab_size, plen).tolist()],
                          max_new=max(MAX_NEWS))
    sched.reset_peak_stats()
    jobs = make_workload(n, rate, cfg.vocab_size, seed=seed,
                         class_mix=True)
    r = run_scheduler(sched, jobs)
    sched.stop()
    r.update(system="single", replicas=1, slots=slots * n_replicas)
    out["single"] = r
    print(f"[load] fleet-compare single      ({slots * n_replicas} slots) "
          f"tput={r['throughput_tok_s']:7.1f} tok/s "
          f"p95={r['latency_p95_s']:.3f}s "
          f"J/tok={r['j_per_token']:.3e}", flush=True)

    # -- the fleet, per placement policy
    for placement in ("rr", "least_queue", "energy"):
        router = Router(
            lambda rid: Scheduler(params, cfg, max_slots=slots, **base),
            n_replicas=n_replicas, placement=placement).start()
        # warm every replica's shapes (each has its own jit caches):
        # pinned submits reach each replica directly
        rng = np.random.default_rng(123)
        for rid in router.replica_ids:
            hs = [router.submit(
                rng.integers(4, cfg.vocab_size, plen).tolist(),
                max_new=max(MAX_NEWS), replica_id=rid)
                for plen in PROMPT_LENS]
            for h in hs:
                h.result(timeout=300.0)
        router.reset_peak_stats()
        jobs = make_workload(n, rate, cfg.vocab_size, seed=seed,
                             class_mix=True)
        r = run_scheduler(router, jobs)
        st = router.stats()
        router.stop()
        r.update(system=f"fleet_{placement}", replicas=n_replicas,
                 slots=slots, placement=placement,
                 max_replica_energy_share=(
                     st["fleet"]["max_replica_energy_share"]),
                 replica_energy_j=[p["fleet_energy_j"]
                                   for p in st["per_replica"]],
                 routed_per_replica=[p["routed"]
                                     for p in st["per_replica"]])
        out[f"fleet_{placement}"] = r
        print(f"[load] fleet-compare {placement:12s} "
              f"tput={r['throughput_tok_s']:7.1f} tok/s "
              f"p95={r['latency_p95_s']:.3f}s "
              f"J/tok={r['j_per_token']:.3e} "
              f"max energy share={r['max_replica_energy_share']:.3f}",
              flush=True)

    best = max((out[f"fleet_{p}"]["throughput_tok_s"]
                for p in ("rr", "least_queue", "energy")))
    out["fleet_speedup"] = best / max(out["single"]["throughput_tok_s"],
                                      1e-9)
    out["energy_share_energy_vs_rr"] = (
        out["fleet_energy"]["max_replica_energy_share"],
        out["fleet_rr"]["max_replica_energy_share"])
    print(f"[load] fleet of {n_replicas}x{slots} slots: "
          f"{out['fleet_speedup']:.2f}x the single {slots * n_replicas}"
          f"-slot scheduler at equal aggregate KV budget", flush=True)
    return out


def run_prefill_compare(params, cfg, *, chunk: int = 16,
                        lens=(9, 11, 14, 18, 21, 24, 27, 31, 35, 39, 44,
                              52),
                        max_new: int = 8, buckets=(16, 32, 64),
                        seed: int = 0) -> dict:
    """TTFT / compile-count phase: chunked vs bucketed vs per-length
    prefill over a workload of many DISTINCT prompt lengths.

    * ``per_length`` — the seed behavior: one XLA compile per distinct
      prompt length (jit cache size == #lengths).
    * ``bucketed``  — the deleted ``prefill_buckets`` knob: prompts
      left-pad to the next bucket, one compile per bucket used.
    * ``chunked``   — ``transformer.prefill_chunk``: every prompt runs
      the SAME [1, chunk] compiled step against a fixed ring — exactly
      one compile, for any length, ever.

    TTFT proxy: wall time from prompt arrival to prefill completion at
    zero load (the first occurrence of a shape pays its compile — the
    cost the per-length/bucketed modes re-pay per new shape while
    chunked pays once). Emitted into BENCH_serving.json; CI gates on
    ``chunked_compiles <= bucketed_compiles``.
    """
    rng = np.random.default_rng(seed)
    prompts = [rng.integers(4, cfg.vocab_size, n).tolist() for n in lens]
    W = max(lens) + max_new
    W += (-W) % chunk
    out: dict = {}

    def arm(name, fn, compiles):
        ttfts = []
        for p in prompts:
            t0 = time.perf_counter()
            jax.block_until_ready(fn(p))
            ttfts.append(time.perf_counter() - t0)
        out[name] = {
            "compiles": int(compiles()),
            "ttft_mean_s": float(np.mean(ttfts)),
            "ttft_max_s": float(np.max(ttfts)),
            "ttft_first_s": float(ttfts[0]),
        }
        print(f"[load] prefill-compare {name:10s} "
              f"compiles={out[name]['compiles']:3d} "
              f"ttft mean={out[name]['ttft_mean_s']*1e3:7.1f}ms "
              f"max={out[name]['ttft_max_s']*1e3:7.1f}ms", flush=True)

    pf_len = jax.jit(lambda pr, toks: T.prefill(pr, cfg, toks,
                                                max_len=W)[0])
    arm("per_length",
        lambda p: pf_len(params, jnp.asarray([p], jnp.int32)),
        pf_len._cache_size)

    pf_bkt = jax.jit(lambda pr, toks: T.prefill(pr, cfg, toks,
                                                max_len=W)[0])

    def bucketed(p):
        blen = min((b for b in buckets if b >= len(p)),
                   default=max(lens))
        padded = [0] * (max(blen, len(p)) - len(p)) + list(p)
        return pf_bkt(params, jnp.asarray([padded], jnp.int32))

    arm("bucketed", bucketed, pf_bkt._cache_size)

    cj = jax.jit(lambda pr, toks, ring, pos0, nv: T.prefill_chunk(
        pr, cfg, toks, ring, pos0, nv))

    def chunked(p):
        ring = T.init_prefill_ring(cfg, 1, W)
        lg = None
        grid = np.asarray(list(p) + [0] * ((-len(p)) % chunk), np.int32)
        for pos0 in range(0, len(p), chunk):
            lg, ring = cj(params, jnp.asarray(grid[None, pos0:pos0 + chunk]),
                          ring, jnp.asarray([pos0], jnp.int32),
                          jnp.asarray([len(p)], jnp.int32))
        return lg

    arm("chunked", chunked, cj._cache_size)
    out["chunk"] = chunk
    out["buckets"] = list(buckets)
    out["lens"] = list(lens)
    ok = out["chunked"]["compiles"] <= out["bucketed"]["compiles"]
    out["chunked_compiles_leq_bucketed"] = bool(ok)
    print(f"[load] chunked prefill: {out['chunked']['compiles']} compile "
          f"for {len(set(lens))} distinct lengths (bucketed "
          f"{out['bucketed']['compiles']}, per-length "
          f"{out['per_length']['compiles']})")
    return out


def run_spec_compare(*, rate: float, n: int, slots: int, num_layers: int,
                     d_model: int, vocab: int, block_size: int = 8,
                     spec_window: int = 4, train_steps: int = 30,
                     seed: int = 0) -> dict:
    """Speculative vs plain decode at EQUAL accuracy (and the early-exit
    arm that trades accuracy away).

    Three paged schedulers serve the same greedy Poisson workload:

      * ``baseline``    — policy 'none': full-depth decode, exact tokens.
      * ``speculative`` — draft at the last exit boundary, verify
        ``spec_window`` drafts full-depth per super-tick: tokens asserted
        **identical** to the baseline arm, energy charged as draft-layer +
        full-depth FLOPs (core.energy.speculative_step_energy).
      * ``early_exit``  — 'fixed' at the same boundary: cheapest J/token
        but its tokens are the draft head's, not the full model's (the
        accuracy loss speculation exists to avoid).

    The model is briefly LITE-fine-tuned (``train_steps``) first: the
    LITE loss trains exit heads to agree with the full model, and the
    acceptance rate — the whole speculative economy — tracks that
    agreement (raw-init params accept almost nothing; the exactness
    guarantee is unconditional either way). Depth is floored at 6 layers
    so there is a real intermediate exit point.
    """
    from repro.core.exit_points import num_exits

    num_layers = max(num_layers, 6)
    cfg = paper_mini(num_layers=num_layers, d_model=d_model,
                     vocab_size=vocab)
    if train_steps:
        from repro.data import CodeCompletionDataset
        from repro.training import train_model
        ds = CodeCompletionDataset(language="java", n_files=60,
                                   seq_len=128, vocab_size=vocab)
        params, _ = train_model(cfg, ds, kind="lite", steps=train_steps,
                                batch_size=4, lr=3e-3, log_every=0)
    else:
        params = T.init_params(jax.random.PRNGKey(0), cfg)
    draft_idx = num_exits(cfg) - 1            # deepest draft: best agreement
    max_len = max(PROMPT_LENS) + max(MAX_NEWS) + spec_window
    arms = {
        "baseline": PolicySpec("none"),
        "speculative": PolicySpec("speculative",
                                  {"draft_idx": draft_idx,
                                   "window": spec_window}),
        "early_exit": PolicySpec("fixed", {"exit_idx": draft_idx}),
    }
    out: dict = {}
    tokens_by_arm = {}
    for arm, policy in arms.items():
        sched = Scheduler(params, cfg, default_policy=policy,
                          allowed_kinds=("none", "fixed", "speculative"),
                          max_slots=slots, max_len=max_len,
                          kv_layout="paged", block_size=block_size,
                          spec_window=spec_window,
                          queue_depth=max(64, n)).start()
        rng = np.random.default_rng(123)
        for plen in PROMPT_LENS:          # warm every shape off the clock —
            for mn in MAX_NEWS:           # incl. every effective-window
                sched.serve_batch(        # verify size the budgets induce
                    [rng.integers(4, vocab, plen).tolist()], max_new=mn)
        sched.reset_peak_stats()
        jobs = make_workload(n, rate, vocab, seed=seed)
        r = run_scheduler(sched, jobs)
        st = sched.stats()
        sched.stop()
        tokens_by_arm[arm] = [j.result_tokens for j in jobs]
        r.update(policy=arm)
        if arm == "speculative":
            r.update(acceptance_rate=st["acceptance_rate"],
                     tokens_per_verify=st["tokens_per_verify"],
                     spec_window=spec_window, draft_idx=draft_idx)
        out[arm] = r
        extra = (f" acc={r.get('acceptance_rate', 0):.2f}"
                 f" tok/verify={r.get('tokens_per_verify', 0):.2f}"
                 if arm == "speculative" else "")
        print(f"[load] spec-compare {arm:12s} "
              f"tput={r['throughput_tok_s']:7.1f} tok/s "
              f"J/tok={r['j_per_token']:.3e}{extra}", flush=True)
    exact = tokens_by_arm["speculative"] == tokens_by_arm["baseline"]
    out["speculative_exact"] = bool(exact)
    print(f"[load] speculative tokens are "
          f"{'IDENTICAL' if exact else 'NOT IDENTICAL'} to the full-depth "
          f"baseline (early-exit arm trades accuracy for "
          f"{out['early_exit']['j_per_token']:.3e} J/tok)")
    return out


def run(rates=(4.0, 10.0, 25.0), n: int = 24, *, num_layers: int = 8,
        d_model: int = 96, vocab: int = 512, slots: int = 4,
        exit_idx: int = 0, block_size: int = 8, seed: int = 0,
        replicas: int = 2, save: bool = True, smoke: bool = False) -> dict:
    cfg = paper_mini(num_layers=num_layers, d_model=d_model,
                     vocab_size=vocab)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    max_len = max(PROMPT_LENS) + max(MAX_NEWS)
    sched = Scheduler(params, cfg, controller_kind="fixed",
                      fixed_exit_idx=exit_idx,
                      allowed_kinds=("none", "fixed"),
                      max_slots=slots, max_len=max_len,
                      queue_depth=max(64, n)).start()
    engine = Engine(params, cfg, max_context=max(PROMPT_LENS))
    ctrl = PolicySpec("fixed", {"exit_idx": exit_idx})
    print(f"[load] warming shapes (model {num_layers}L/{d_model}d, "
          f"{slots} slots) ...", flush=True)
    warmup(sched, engine, ctrl, slots)

    results = []
    for rate in rates:
        for system in ("scheduler", "engine"):
            jobs = make_workload(n, rate, vocab, seed=seed)
            if system == "scheduler":
                r = run_scheduler(sched, jobs)
            else:
                r = run_engine(engine, ctrl, jobs, slots)
            r.update(system=system, rate_hz=rate)
            results.append(r)
            print(f"[load] rate={rate:6.1f}/s {system:9s} "
                  f"tput={r['throughput_tok_s']:7.1f} tok/s "
                  f"p50={r['latency_p50_s']:.3f}s "
                  f"p95={r['latency_p95_s']:.3f}s "
                  f"J/tok={r['j_per_token']:.3e}", flush=True)
    sched.stop()

    top = max(rates)
    tput = {r["system"]: r["throughput_tok_s"] for r in results
            if r["rate_hz"] == top}
    speedup = tput["scheduler"] / max(tput["engine"], 1e-9)
    print(f"[load] @ {top}/s: continuous batching {speedup:.2f}x the "
          f"seed engine baseline "
          f"({'BEATS' if speedup > 1.0 else 'DOES NOT BEAT'} it)")
    kv_compare = run_kv_compare(params, cfg, rate=top, n=n, slots=slots,
                                max_len=max_len, exit_idx=exit_idx,
                                block_size=block_size, seed=seed)
    spec_compare = run_spec_compare(rate=top, n=n, slots=slots,
                                    num_layers=num_layers, d_model=d_model,
                                    vocab=vocab, block_size=block_size,
                                    seed=seed)
    admission_trace = run_admission_trace(cfg, slots=slots, max_len=max_len,
                                          block_size=block_size, n=n,
                                          seed=seed)
    fleet_trace = run_fleet_trace(cfg, n_replicas=max(replicas, 2),
                                  slots=max(slots // 2, 1), n=n, seed=seed)
    # the energy-share comparison needs arrivals that OVERLAP service
    # without saturating: at a fully saturating rate every queue is deep
    # at routing time and all policies degenerate to count-alternation
    # (the class-mixed workload then aliases equally under every policy),
    # so the mid rate — not the top rate — is the regime where placement
    # signals actually differentiate
    mid = sorted(rates)[len(rates) // 2]
    fleet_compare = run_fleet_compare(params, cfg, rate=mid, n=n,
                                      slots=slots, n_replicas=replicas,
                                      max_len=max_len, exit_idx=exit_idx,
                                      seed=seed)
    prefill_compare = run_prefill_compare(params, cfg, seed=seed)
    phase_breakdown = run_phase_breakdown(params, cfg, rate=top, n=n,
                                          slots=slots, max_len=max_len,
                                          exit_idx=exit_idx,
                                          block_size=block_size, seed=seed)

    payload = {
        "bench": "serving_load",
        "schema_version": 4,
        "smoke": smoke,
        "config": {"num_layers": num_layers, "d_model": d_model,
                   "vocab": vocab, "slots": slots, "n": n,
                   "rates": list(rates), "block_size": block_size,
                   "replicas": replicas},
        "results": results,
        "speedup_at_top_rate": speedup,
        "kv_compare": kv_compare,
        "spec_compare": spec_compare,
        "admission_trace": admission_trace,
        "fleet_trace": fleet_trace,
        "fleet_compare": fleet_compare,
        "prefill_compare": prefill_compare,
        "phase_breakdown": phase_breakdown,
    }
    if save:
        wrote = []
        if not smoke:
            # the canonical full-config artifact: never clobbered by the
            # CI/verify smoke invocation
            os.makedirs(RES_DIR, exist_ok=True)
            out = os.path.join(RES_DIR, "serving_load.json")
            with open(out, "w") as f:
                json.dump(payload, f, indent=2)
            wrote.append(out)
        # machine-readable perf trajectory across PRs (CI smoke reads it)
        bench_out = os.path.join(REPO_ROOT, "BENCH_serving.json")
        with open(bench_out, "w") as f:
            json.dump(payload, f, indent=2)
        wrote.append(bench_out)
        print(f"[load] wrote {' and '.join(wrote)}")
    return payload


def _summarize(jobs: list[Job], wall: float) -> dict:
    toks = sum(j.tokens for j in jobs)
    e = sum(j.energy_j for j in jobs)
    pct = latency_percentiles([j.latency_s for j in jobs])
    # the engine path never sets ttft_s; latency_percentiles drops Nones
    tpct = latency_percentiles([j.ttft_s for j in jobs])
    return {
        "requests": len(jobs),
        "useful_tokens": toks,
        "wall_s": wall,
        "throughput_tok_s": toks / max(wall, 1e-9),
        "latency_p50_s": pct["p50_s"],
        "latency_p95_s": pct["p95_s"],
        "ttft_p50_s": tpct["p50_s"],
        "ttft_p95_s": tpct["p95_s"],
        "j_per_token": e / max(toks, 1),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rates", type=float, nargs="+",
                    default=[4.0, 10.0, 25.0],
                    help="Poisson arrival rates (requests/s)")
    ap.add_argument("--n", type=int, default=24, help="requests per rate")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--layers", type=int, default=8)
    ap.add_argument("--d-model", type=int, default=96)
    ap.add_argument("--vocab", type=int, default=512)
    ap.add_argument("--exit-idx", type=int, default=0,
                    help="fixed-controller exit point index")
    ap.add_argument("--block-size", type=int, default=8,
                    help="paged-pool tokens per KV block")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--replicas", type=int, default=2,
                    help="fleet-compare replica count (1 vs N at equal "
                         "aggregate KV budget)")
    ap.add_argument("--no-save", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-speed run: tiny model, one rate, few requests")
    args = ap.parse_args()
    if args.smoke:
        # the rate must exceed slots/service-time or neither pool ever
        # saturates and the admission comparison is vacuous
        run((60.0,), 32, num_layers=4, d_model=64, vocab=256, slots=3,
            exit_idx=args.exit_idx, block_size=args.block_size,
            seed=args.seed, replicas=args.replicas,
            save=not args.no_save, smoke=True)
        return
    run(tuple(args.rates), args.n, num_layers=args.layers,
        d_model=args.d_model, vocab=args.vocab, slots=args.slots,
        exit_idx=args.exit_idx, block_size=args.block_size, seed=args.seed,
        replicas=args.replicas, save=not args.no_save)


if __name__ == "__main__":
    main()
