"""End-to-end RL agent training: fine-tuned LLM -> rollout cache -> PPO.

Reproduces the paper's offline phase (Fig. 2): the fine-tuned early-exit
model is rolled out over the code corpus; the PPO agent learns the exit
policy from the cached traces; at inference the trained weights plug into
the ``"policy"`` entry of the exit-policy registry — ship
:func:`agent_policy_spec` (plus ``agent_params`` in the context) to
``generate`` / ``Engine`` / ``Scheduler``.
"""
from __future__ import annotations

from repro.config import ModelConfig
from repro.core.exit_policy import PolicySpec
from repro.rl.env import EarlyExitEnv, RewardCoefs
from repro.rl.ppo import PPOConfig, ppo_train
from repro.rl.rollout import build_rollout_cache


def agent_policy_spec(threshold: float = 0.9,
                      temperature: float = 1.0) -> PolicySpec:
    """The serving-side spec for a trained agent (paper §VI-B: exit iff
    softmax(pi(h)/temperature)[EXIT] > threshold)."""
    return PolicySpec("policy", {"threshold": float(threshold),
                                 "temperature": float(temperature)})


def train_agent(params, cfg: ModelConfig, dataset, *,
                n_episodes: int = 64, gen_tokens: int = 15,
                coefs: RewardCoefs | None = None,
                ppo: PPOConfig | None = None, n_lanes: int = 16,
                seed: int = 0, log_every: int = 10):
    """Returns (agent_params, history, cache)."""
    cache = build_rollout_cache(params, cfg, dataset,
                                n_episodes=n_episodes,
                                gen_tokens=gen_tokens, seed=seed)
    env = EarlyExitEnv(cache, coefs or RewardCoefs(), n_lanes=n_lanes)
    agent, history = ppo_train(env, config=ppo or PPOConfig(), seed=seed,
                               log_every=log_every)
    return agent, history, cache
