"""End-to-end RL agent training: fine-tuned LLM -> rollout cache -> PPO.

Reproduces the paper's offline phase (Fig. 2): the fine-tuned early-exit
model is rolled out over the code corpus; the PPO agent learns the
exit policy from the cached traces; the extracted policy network is then
used by ``core.controller.make_policy`` at inference.
"""
from __future__ import annotations

from repro.config import ModelConfig
from repro.rl.env import EarlyExitEnv, RewardCoefs
from repro.rl.ppo import PPOConfig, ppo_train
from repro.rl.rollout import build_rollout_cache


def train_agent(params, cfg: ModelConfig, dataset, *,
                n_episodes: int = 64, gen_tokens: int = 15,
                coefs: RewardCoefs | None = None,
                ppo: PPOConfig | None = None, n_lanes: int = 16,
                seed: int = 0, log_every: int = 10):
    """Returns (agent_params, history, cache)."""
    cache = build_rollout_cache(params, cfg, dataset,
                                n_episodes=n_episodes,
                                gen_tokens=gen_tokens, seed=seed)
    env = EarlyExitEnv(cache, coefs or RewardCoefs(), n_lanes=n_lanes)
    agent, history = ppo_train(env, config=ppo or PPOConfig(), seed=seed,
                               log_every=log_every)
    return agent, history, cache
