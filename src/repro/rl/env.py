"""Vectorized early-exit MDP over a rollout cache (paper §IV A-E).

State   — the current boundary's hidden state (nothing else, §IV-B).
Actions — 0 = CONTINUE (advance one exit boundary), 1 = EXIT (§IV-C).
Rewards — Eqs. (2)/(3), with penalties normalized to [-1, 0] by the model
depth as the paper prescribes. ℓ_opt is the shallowest boundary whose head
prediction matches the final layer's.

Episode = one cached generation (T tokens). EXIT (or CONTINUE past the last
boundary, which the paper treats as a forced exit) advances to the next
token; finishing the last token ends the episode and a new cached episode
is sampled. Fully jax: state is a pytree of arrays over N parallel lanes,
``step`` is jit/scan-compatible.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp

from repro.rl.rollout import RolloutCache

CONTINUE, EXIT = 0, 1


@dataclass(frozen=True)
class RewardCoefs:
    """Paper Eq. 2/3 trade-off coefficients (0 <= a,b,g <= 1, alpha <= beta).

    The two ``*_weight`` knobs extend Eq. 2 with serving-side signals and
    default to 0.0, which reproduces the paper's reward bit-for-bit:

    ``energy_weight``
        speculative-aware energy shaping from
        :func:`repro.core.energy.speculative_step_energy`: an EXIT pays
        its boundary's modeled energy fraction, and a *wrong* EXIT
        additionally pays the full-depth verify pass a rejected draft
        costs the speculative decoder — without this the agent never
        learns that a bad draft is not free.
    ``accuracy_weight``
        task-accuracy-delta shaping from the eval harness
        (``repro.evals``): a wrong EXIT is penalized in proportion to
        the measured pass-rate drop of exiting early on this episode's
        suite (``RolloutCache.task_delta``).
    """
    alpha: float = 0.2       # late-exit penalty (correct but past ℓ_opt)
    beta: float = 1.0        # early-exit penalty (wrong, before ℓ_opt)
    gamma: float = 1.0       # late-continue penalty
    epsilon: float = 0.1     # edge case: wrong and past ℓ_opt
    energy_weight: float = 0.0    # speculative draft/verify energy shaping
    accuracy_weight: float = 0.0  # eval-harness pass-rate-delta shaping


@dataclass
class EnvArrays:
    """Device-resident cache tensors."""
    hidden: jax.Array        # [E, T, n_b, D]
    preds: jax.Array         # [E, T, n_b]
    l_opt: jax.Array         # [E, T]
    boundaries: jax.Array    # [n_b]
    exit_frac: jax.Array     # [n_b] modeled exit energy / full-depth energy
    verify_frac: jax.Array   # [n_b] rejected-draft verify energy / full
    task_delta: jax.Array    # [E] eval pass-rate drop for this episode


class EarlyExitEnv:
    def __init__(self, cache: RolloutCache, coefs: RewardCoefs = RewardCoefs(),
                 n_lanes: int = 16, *, cfg=None, ctx_len: int = 256):
        n_b = len(cache.boundaries)
        if coefs.energy_weight > 0.0:
            # per-boundary energy fractions from the analytic model: what
            # exiting at boundary b costs, and what the full-depth verify
            # pass costs when an exit at b is used as a draft and rejected
            # (speculative_step_energy's split, normalized by the
            # full-depth token cost)
            if cfg is None:
                raise ValueError("energy_weight > 0 needs cfg= (the "
                                 "ModelConfig the energy model prices)")
            from repro.core import energy
            full = energy.full_token_energy(cfg, ctx_len)
            exit_frac = (energy.decode_token_energy(
                cfg, ctx_len, cache.boundaries) / full)
            verify_frac = [energy.speculative_step_energy(
                cfg, ctx_len, int(b), 1, 2)["verify_j"] / full
                for b in cache.boundaries]
        else:
            exit_frac = [0.0] * n_b
            verify_frac = [0.0] * n_b
        task_delta = cache.task_delta
        if task_delta is None:
            task_delta = jnp.zeros((cache.n_episodes,), jnp.float32)
        self.arrays = EnvArrays(
            hidden=jnp.asarray(cache.hidden),
            preds=jnp.asarray(cache.preds),
            l_opt=jnp.asarray(cache.l_opt),
            boundaries=jnp.asarray(cache.boundaries),
            exit_frac=jnp.asarray(exit_frac, jnp.float32),
            verify_frac=jnp.asarray(verify_frac, jnp.float32),
            task_delta=jnp.asarray(task_delta, jnp.float32))
        self.coefs = coefs
        self.n_lanes = n_lanes
        self.num_layers = cache.num_layers
        self.n_b = n_b
        self.T = cache.tokens_per_episode
        self.E = cache.n_episodes
        self.d_model = cache.hidden.shape[-1]

    # state pytree: dict(ep, tok, b) each [N] int32
    def reset(self, key) -> tuple[dict, jax.Array]:
        ep = jax.random.randint(key, (self.n_lanes,), 0, self.E)
        state = {"ep": ep,
                 "tok": jnp.zeros((self.n_lanes,), jnp.int32),
                 "b": jnp.zeros((self.n_lanes,), jnp.int32)}
        return state, self._obs(state)

    def _obs(self, state) -> jax.Array:
        return self.arrays.hidden[state["ep"], state["tok"], state["b"]]

    @partial(jax.jit, static_argnums=(0,))
    def step(self, state, action, key):
        """action: [N] in {0,1}. Returns (state, obs, reward, done)."""
        a = self.arrays
        c = self.coefs
        N = self.num_layers
        ep, tok, b = state["ep"], state["tok"], state["b"]
        l_curr = a.boundaries[b]                          # [N_lanes]
        l_opt = a.l_opt[ep, tok]
        y_pred = a.preds[ep, tok, b]
        y = a.preds[ep, tok, -1]
        correct = y_pred == y
        at_last = b >= self.n_b - 1
        # paper: CONTINUE past the final layer == forced exit
        act = jnp.where(at_last, EXIT, action)

        # ---- Eq. 2: exit reward -----------------------------------------
        dist = jnp.abs(l_curr - l_opt).astype(jnp.float32) / N
        r_exit = jnp.where(
            correct & (l_curr == l_opt), 1.0,
            jnp.where(correct, -dist * c.alpha,                # late exit
                      jnp.where(l_curr < l_opt, -dist * c.beta,  # too early
                                -c.epsilon)))                  # edge case

        # ---- Eq. 3: continue reward -------------------------------------
        l_next = a.boundaries[jnp.minimum(b + 1, self.n_b - 1)]
        d_next = jnp.abs(l_next - l_opt).astype(jnp.float32) / N
        r_cont = jnp.where(l_curr < l_opt, 1.0, -d_next * c.gamma)

        reward = jnp.where(act == EXIT, r_exit, r_cont)

        # ---- serving-side shaping (no-ops at the 0.0 defaults) ----------
        # energy: an EXIT pays its boundary's modeled cost; a wrong EXIT
        # additionally pays the full-depth verify pass a rejected draft
        # costs (speculative_step_energy's split)
        e_pay = a.exit_frac[b] + jnp.where(correct, 0.0, a.verify_frac[b])
        reward = reward - c.energy_weight * jnp.where(
            act == EXIT, e_pay, 0.0)
        # accuracy: a wrong EXIT is penalized by the eval harness's
        # measured pass-rate drop for this episode's suite
        reward = reward - c.accuracy_weight * jnp.where(
            (act == EXIT) & ~correct, a.task_delta[ep], 0.0)

        # ---- transition ---------------------------------------------------
        exit_taken = act == EXIT
        tok_next = jnp.where(exit_taken, tok + 1, tok)
        b_next = jnp.where(exit_taken, 0, b + 1)
        done = tok_next >= self.T
        # resample episode on done
        new_ep = jax.random.randint(key, (self.n_lanes,), 0, self.E)
        ep = jnp.where(done, new_ep, ep)
        tok_next = jnp.where(done, 0, tok_next)
        b_next = jnp.where(done, 0, b_next)
        new_state = {"ep": ep, "tok": tok_next, "b": b_next}
        return new_state, self._obs(new_state), reward, done
