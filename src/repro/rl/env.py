"""Vectorized early-exit MDP over a rollout cache (paper §IV A-E).

State   — the current boundary's hidden state (nothing else, §IV-B).
Actions — 0 = CONTINUE (advance one exit boundary), 1 = EXIT (§IV-C).
Rewards — Eqs. (2)/(3), with penalties normalized to [-1, 0] by the model
depth as the paper prescribes. ℓ_opt is the shallowest boundary whose head
prediction matches the final layer's.

Episode = one cached generation (T tokens). EXIT (or CONTINUE past the last
boundary, which the paper treats as a forced exit) advances to the next
token; finishing the last token ends the episode and a new cached episode
is sampled. Fully jax: state is a pytree of arrays over N parallel lanes,
``step`` is jit/scan-compatible.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp

from repro.rl.rollout import RolloutCache

CONTINUE, EXIT = 0, 1


@dataclass(frozen=True)
class RewardCoefs:
    """Paper Eq. 2/3 trade-off coefficients (0 <= a,b,g <= 1, alpha <= beta)."""
    alpha: float = 0.2       # late-exit penalty (correct but past ℓ_opt)
    beta: float = 1.0        # early-exit penalty (wrong, before ℓ_opt)
    gamma: float = 1.0       # late-continue penalty
    epsilon: float = 0.1     # edge case: wrong and past ℓ_opt


@dataclass
class EnvArrays:
    """Device-resident cache tensors."""
    hidden: jax.Array        # [E, T, n_b, D]
    preds: jax.Array         # [E, T, n_b]
    l_opt: jax.Array         # [E, T]
    boundaries: jax.Array    # [n_b]


class EarlyExitEnv:
    def __init__(self, cache: RolloutCache, coefs: RewardCoefs = RewardCoefs(),
                 n_lanes: int = 16):
        self.arrays = EnvArrays(
            hidden=jnp.asarray(cache.hidden),
            preds=jnp.asarray(cache.preds),
            l_opt=jnp.asarray(cache.l_opt),
            boundaries=jnp.asarray(cache.boundaries))
        self.coefs = coefs
        self.n_lanes = n_lanes
        self.num_layers = cache.num_layers
        self.n_b = len(cache.boundaries)
        self.T = cache.tokens_per_episode
        self.E = cache.n_episodes
        self.d_model = cache.hidden.shape[-1]

    # state pytree: dict(ep, tok, b) each [N] int32
    def reset(self, key) -> tuple[dict, jax.Array]:
        ep = jax.random.randint(key, (self.n_lanes,), 0, self.E)
        state = {"ep": ep,
                 "tok": jnp.zeros((self.n_lanes,), jnp.int32),
                 "b": jnp.zeros((self.n_lanes,), jnp.int32)}
        return state, self._obs(state)

    def _obs(self, state) -> jax.Array:
        return self.arrays.hidden[state["ep"], state["tok"], state["b"]]

    @partial(jax.jit, static_argnums=(0,))
    def step(self, state, action, key):
        """action: [N] in {0,1}. Returns (state, obs, reward, done)."""
        a = self.arrays
        c = self.coefs
        N = self.num_layers
        ep, tok, b = state["ep"], state["tok"], state["b"]
        l_curr = a.boundaries[b]                          # [N_lanes]
        l_opt = a.l_opt[ep, tok]
        y_pred = a.preds[ep, tok, b]
        y = a.preds[ep, tok, -1]
        correct = y_pred == y
        at_last = b >= self.n_b - 1
        # paper: CONTINUE past the final layer == forced exit
        act = jnp.where(at_last, EXIT, action)

        # ---- Eq. 2: exit reward -----------------------------------------
        dist = jnp.abs(l_curr - l_opt).astype(jnp.float32) / N
        r_exit = jnp.where(
            correct & (l_curr == l_opt), 1.0,
            jnp.where(correct, -dist * c.alpha,                # late exit
                      jnp.where(l_curr < l_opt, -dist * c.beta,  # too early
                                -c.epsilon)))                  # edge case

        # ---- Eq. 3: continue reward -------------------------------------
        l_next = a.boundaries[jnp.minimum(b + 1, self.n_b - 1)]
        d_next = jnp.abs(l_next - l_opt).astype(jnp.float32) / N
        r_cont = jnp.where(l_curr < l_opt, 1.0, -d_next * c.gamma)

        reward = jnp.where(act == EXIT, r_exit, r_cont)

        # ---- transition ---------------------------------------------------
        exit_taken = act == EXIT
        tok_next = jnp.where(exit_taken, tok + 1, tok)
        b_next = jnp.where(exit_taken, 0, b + 1)
        done = tok_next >= self.T
        # resample episode on done
        new_ep = jax.random.randint(key, (self.n_lanes,), 0, self.E)
        ep = jnp.where(done, new_ep, ep)
        tok_next = jnp.where(done, 0, tok_next)
        b_next = jnp.where(done, 0, b_next)
        new_state = {"ep": ep, "tok": tok_next, "b": b_next}
        return new_state, self._obs(new_state), reward, done
