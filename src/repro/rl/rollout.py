"""Rollout cache: pre-computed LLM traces for fast RL training.

The paper's Gym env re-runs the LLM inside every RL step. Identical MDP,
different engineering (DESIGN.md §2): we pre-run the fine-tuned LLM over
sampled code-completion episodes and store, per generated token, the hidden
state / head prediction at every exit boundary plus ℓ_opt (the shallowest
boundary whose prediction matches the final layer's — the paper's optimal
exit). Episode dynamics then become pure array indexing; the agent still
observes only the current hidden state + reward.

Decode-vs-forward parity of the model guarantees these teacher-forced
hiddens equal the decode-time hiddens the controller will see at inference.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig
from repro.core.early_exit import generate
from repro.core.exit_points import segment_boundaries
from repro.models import transformer as T


@dataclass
class RolloutCache:
    hidden: np.ndarray      # [E, T, n_b, D] float32 — state at each boundary
    preds: np.ndarray       # [E, T, n_b] int32 — head argmax per boundary
    l_opt: np.ndarray       # [E, T] int32 — optimal exit layer (layer units)
    boundaries: np.ndarray  # [n_b] int32 — layer number of each boundary
    num_layers: int
    # per-episode task-accuracy-delta signal from the eval harness
    # (pass-rate drop of exiting early, >= 0 when exit hurt); None keeps
    # the paper's pure Eq. 2/3 reward
    task_delta: Optional[np.ndarray] = None     # [E] float32

    @property
    def n_episodes(self):
        return self.hidden.shape[0]

    @property
    def tokens_per_episode(self):
        return self.hidden.shape[1]

    def with_task_delta(self, deltas) -> "RolloutCache":
        """Attach a per-episode accuracy-delta array (or scalar)."""
        d = np.broadcast_to(np.asarray(deltas, np.float32),
                            (self.n_episodes,)).copy()
        return replace(self, task_delta=d)


def task_delta_from_reports(baseline_arm: dict, exit_arm: dict,
                            n_episodes: int, k: str = "1") -> np.ndarray:
    """Per-episode accuracy-delta signal from two eval-run arms.

    ``baseline_arm``/``exit_arm`` are ``run_http``/``run_replay`` arm
    payloads (``report["arms"][name]``). The delta is the measured
    pass@k drop of the exit policy vs the full-depth baseline, floored
    at 0 (an exit policy that *helps* should not be rewarded for being
    wrong), broadcast over the cache's episodes — the reward join the
    ROADMAP names: the agent finally sees task accuracy, not just
    head-agreement."""
    b = float(baseline_arm["summary"]["pass_at"][str(k)])
    e = float(exit_arm["summary"]["pass_at"][str(k)])
    return np.full((n_episodes,), max(b - e, 0.0), np.float32)


def build_rollout_cache(params, cfg: ModelConfig, dataset, *,
                        n_episodes: int = 64, gen_tokens: int = 15,
                        batch: int = 8, split: str = "train",
                        seed: int = 0, max_context: int = 256,
                        sampling=None) -> RolloutCache:
    """Sample episodes (context-fraction protocol), generate ``gen_tokens``
    with the full model (greedy by default; pass a
    ``repro.api.SamplingParams`` to roll out under the serving-time
    sampling regime), then collect per-boundary hiddens/preds over the
    generated positions with one forward pass."""
    bounds = np.asarray(segment_boundaries(cfg), np.int32)
    n_b = len(bounds)
    tasks = dataset.completion_tasks(split, n_episodes, seed=seed,
                                     max_context=max_context)
    # left-pad contexts to a common length per mini-batch
    H, P, L = [], [], []
    for i in range(0, n_episodes, batch):
        chunk = tasks[i: i + batch]
        ctx_len = max(len(c) for c, _ in chunk)
        ctxs = np.zeros((len(chunk), ctx_len), np.int32)
        for j, (c, _) in enumerate(chunk):
            ctxs[j, ctx_len - len(c):] = c          # left-pad with PAD=0
        ctxs = jnp.asarray(ctxs)
        # per-chunk key: otherwise every chunk would reuse generate()'s
        # default PRNGKey(0) and sampled rollouts would repeat draw streams
        out = generate(params, cfg, ctxs, gen_tokens, sampling=sampling,
                       key=jax.random.fold_in(jax.random.PRNGKey(seed), i))
        toks = out["tokens"]                         # [b, T]
        full = jnp.concatenate([ctxs, toks], axis=1)
        outs, _ = T.forward(params, cfg, full, inference=True)
        # hidden predicting generated token t sits at position ctx_len-1+t
        pos = ctx_len - 1 + np.arange(gen_tokens)
        hb, pb = [], []
        for h in outs:                               # per boundary
            hsel = h[:, pos, :]                      # [b, T, D]
            logits = T.lm_logits(params, cfg, hsel)
            hb.append(np.asarray(hsel, np.float32))
            pb.append(np.asarray(jnp.argmax(logits, -1), np.int32))
        hb = np.stack(hb, axis=2)                    # [b, T, n_b, D]
        pb = np.stack(pb, axis=2)                    # [b, T, n_b]
        H.append(hb)
        P.append(pb)
    hidden = np.concatenate(H, axis=0)
    preds = np.concatenate(P, axis=0)
    # ℓ_opt: shallowest boundary matching the final boundary's prediction
    final = preds[..., -1:]
    match = preds == final                           # [E, T, n_b]
    first_idx = np.argmax(match, axis=-1)            # first True
    l_opt = bounds[first_idx].astype(np.int32)
    return RolloutCache(hidden=hidden, preds=preds, l_opt=l_opt,
                        boundaries=bounds, num_layers=cfg.num_layers)
