from repro.rl.rollout import (build_rollout_cache,  # noqa: F401
                              task_delta_from_reports)
from repro.rl.env import EarlyExitEnv, RewardCoefs  # noqa: F401
from repro.rl.ppo import PPOConfig, ppo_train  # noqa: F401
from repro.rl.train import agent_policy_spec, train_agent  # noqa: F401
