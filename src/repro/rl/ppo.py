"""PPO (clipped surrogate) from scratch in JAX (paper §V, Table III).

Actor-critic = core/policy_net (shared torso, pi/v heads). Rollout
collection is a ``lax.scan`` over the vectorized cache env; GAE advantages;
minibatched clipped-surrogate updates with Adam. The whole update is one
jit region, so 500k-step trainings run in seconds on CPU.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import policy_net
from repro.training.optimizer import adamw_init, adamw_update


@dataclass(frozen=True)
class PPOConfig:
    total_steps: int = 200_000       # env steps (paper: 200k-500k)
    horizon: int = 256               # steps per lane per iteration
    n_lanes: int = 16
    epochs: int = 4                  # paper: 6, 2
    minibatches: int = 8
    lr: float = 5e-5                 # paper: 5e-5 / 1e-4
    gamma: float = 0.99              # paper Table III
    gae_lambda: float = 0.95
    clip: float = 0.2
    vf_coef: float = 0.5
    ent_coef: float = 0.01
    max_grad_norm: float = 0.5
    hidden: tuple = (64, 64)         # paper: 1-2 layers, 32/64 units


def collect_rollout(agent, env, state, key, horizon: int):
    """lax.scan rollout. Returns (new_state, batch dict, new_key)."""

    def body(carry, _):
        st, k = carry
        k, k_act, k_step = jax.random.split(k, 3)
        obs = env._obs(st)
        logits, v = policy_net.policy_value(agent, obs)
        a = jax.random.categorical(k_act, logits)
        logp = jax.nn.log_softmax(logits)[jnp.arange(a.shape[0]), a]
        st2, obs2, r, done = env.step(st, a, k_step)
        out = {"obs": obs, "action": a, "logp": logp, "value": v,
               "reward": r, "done": done}
        return (st2, k), out

    (state, key), traj = jax.lax.scan(body, (state, key), None,
                                      length=horizon)
    return state, traj, key


def compute_gae(traj, last_value, gamma: float, lam: float):
    """traj arrays are [T, N]."""

    def body(carry, inp):
        adv_next, v_next = carry
        r, v, done = inp
        nonterm = 1.0 - done.astype(jnp.float32)
        delta = r + gamma * v_next * nonterm - v
        adv = delta + gamma * lam * nonterm * adv_next
        return (adv, v), adv

    (_, _), advs = jax.lax.scan(
        body, (jnp.zeros_like(last_value), last_value),
        (traj["reward"], traj["value"], traj["done"]), reverse=True)
    returns = advs + traj["value"]
    return advs, returns


def ppo_loss(agent, batch, clip: float, vf_coef: float, ent_coef: float):
    logits, v = policy_net.policy_value(agent, batch["obs"])
    logp_all = jax.nn.log_softmax(logits)
    logp = jnp.take_along_axis(logp_all, batch["action"][:, None], 1)[:, 0]
    ratio = jnp.exp(logp - batch["logp"])
    adv = batch["adv"]
    adv = (adv - adv.mean()) / (adv.std() + 1e-8)
    pg = -jnp.minimum(ratio * adv,
                      jnp.clip(ratio, 1 - clip, 1 + clip) * adv).mean()
    vf = jnp.square(v - batch["ret"]).mean()
    ent = -(jnp.exp(logp_all) * logp_all).sum(-1).mean()
    return pg + vf_coef * vf - ent_coef * ent, {
        "pg": pg, "vf": vf, "entropy": ent}


def ppo_train(env, *, config: PPOConfig = PPOConfig(), seed: int = 0,
              log_every: int = 10, callback=None):
    """Train the exit agent on a cache env. Returns (agent, history)."""
    cfg = config
    key = jax.random.PRNGKey(seed)
    key, k_init, k_reset = jax.random.split(key, 3)
    agent = policy_net.init_policy(k_init, env.d_model, cfg.hidden)
    opt = adamw_init(agent)
    state, _ = env.reset(k_reset)

    n_lanes = env.n_lanes                 # env is authoritative
    n_iters = max(1, cfg.total_steps // (cfg.horizon * n_lanes))
    batch_size = cfg.horizon * n_lanes
    mb_size = batch_size // cfg.minibatches

    @jax.jit
    def update(agent, opt, traj, last_obs, key):
        _, last_v = policy_net.policy_value(agent, last_obs)
        advs, rets = compute_gae(traj, last_v, cfg.gamma, cfg.gae_lambda)
        flat = {
            "obs": traj["obs"].reshape(batch_size, -1),
            "action": traj["action"].reshape(batch_size),
            "logp": traj["logp"].reshape(batch_size),
            "adv": advs.reshape(batch_size),
            "ret": rets.reshape(batch_size),
        }

        def epoch_body(carry, k_ep):
            agent, opt = carry
            perm = jax.random.permutation(k_ep, batch_size)

            def mb_body(carry, i):
                agent, opt = carry
                idx = jax.lax.dynamic_slice_in_dim(perm, i * mb_size,
                                                   mb_size)
                mb = {k: v[idx] for k, v in flat.items()}
                (loss, aux), g = jax.value_and_grad(
                    ppo_loss, has_aux=True)(agent, mb, cfg.clip,
                                            cfg.vf_coef, cfg.ent_coef)
                agent, opt = adamw_update(
                    agent, g, opt, cfg.lr, weight_decay=0.0,
                    max_grad_norm=cfg.max_grad_norm)
                return (agent, opt), loss

            (agent, opt), losses = jax.lax.scan(
                mb_body, (agent, opt), jnp.arange(cfg.minibatches))
            return (agent, opt), losses.mean()

        keys = jax.random.split(key, cfg.epochs)
        (agent, opt), losses = jax.lax.scan(epoch_body, (agent, opt), keys)
        return agent, opt, losses.mean()

    history = []
    for it in range(n_iters):
        key, k_roll, k_upd = jax.random.split(key, 3)
        state, traj, _ = collect_rollout(agent, env, state, k_roll,
                                         cfg.horizon)
        last_obs = env._obs(state)
        agent, opt, loss = update(agent, opt, traj, last_obs, k_upd)
        mean_r = float(traj["reward"].mean())
        ep_done = float(traj["done"].sum())
        history.append({"iter": it, "mean_step_reward": mean_r,
                        "loss": float(loss), "episodes": ep_done})
        if callback:
            callback(it, history[-1])
        if log_every and it % log_every == 0:
            print(f"  ppo iter {it:4d}/{n_iters}  mean step reward "
                  f"{mean_r:+.4f}", flush=True)
    return agent, history
