"""Distributed LITE fine-tuning launcher.

On real hardware this drives the pjit train step over the production mesh;
on this CPU container it runs the same code path over the host mesh with a
reduced model (--mini), exercising mesh context + shardings end-to-end.

  python -m repro.launch.train --arch llama32-3b --mini --steps 50
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data import CodeCompletionDataset
from repro.launch import steps as S
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models import transformer as T
from repro.sharding.api import axis_rules, param_shardings
from repro.training.checkpoint import save_pytree
from repro.training.optimizer import adamw_init


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama32-3b")
    ap.add_argument("--mini", action="store_true",
                    help="reduced same-family model (CPU scale)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--language", default="java")
    ap.add_argument("--production-mesh", action="store_true")
    ap.add_argument("--ckpt", default="")
    args = ap.parse_args()

    if args.mini:
        mod = __import__(f"repro.configs."
                         f"{args.arch.replace('-', '_').replace('.', '_')}",
                         fromlist=["paper_mini"])
        cfg = mod.paper_mini()
    else:
        cfg = get_config(args.arch, "full")
    print(f"[train] arch={cfg.name} layers={cfg.num_layers} "
          f"params~{cfg.param_count()/1e6:.1f}M")

    ds = CodeCompletionDataset(language=args.language, n_files=300,
                               seq_len=args.seq,
                               vocab_size=min(cfg.vocab_size, 4096))
    mesh = (make_production_mesh() if args.production_mesh
            else make_host_mesh())

    step_fn = S.make_train_step_fn(cfg)
    key = jax.random.PRNGKey(0)
    with mesh, axis_rules(mesh):
        params = T.init_params(key, cfg)
        params = jax.device_put(params, param_shardings(params, mesh))
        opt = adamw_init(params)
        jstep = jax.jit(step_fn, donate_argnums=(0, 1))
        it = ds.batches("train", args.batch, epochs=10_000)
        t0 = time.time()
        for i in range(args.steps):
            toks, labels, mask = next(it)
            # pad labels/mask to full width expected by the step
            params, opt, loss = jstep(params, opt,
                                      (jnp.asarray(toks),
                                       jnp.asarray(labels),
                                       jnp.asarray(mask)))
            if i % 10 == 0 or i == args.steps - 1:
                print(f"  step {i:4d} loss {float(loss):.4f} "
                      f"({time.time()-t0:.1f}s)", flush=True)
    if args.ckpt:
        save_pytree(params, args.ckpt)
        print(f"[train] checkpoint -> {args.ckpt}")


if __name__ == "__main__":
    main()
