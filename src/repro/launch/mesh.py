"""Production meshes.

Single pod: (data=16, model=16) = 256 TPU v5e chips.
Multi-pod:  (pod=2, data=16, model=16) = 512 chips; the ``pod`` axis is pure
data parallelism whose gradient all-reduce crosses the inter-pod link.

``make_production_mesh`` is a function (never a module constant) so that
importing this module touches no jax device state — the dry-run sets
``xla_force_host_platform_device_count`` *before* first jax init.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    import math

    import numpy as np
    from jax.sharding import Mesh
    n = math.prod(shape)
    devs = jax.devices()
    if len(devs) < n:
        raise RuntimeError(
            f"need {n} devices, have {len(devs)} — run under "
            f"XLA_FLAGS=--xla_force_host_platform_device_count=512 "
            f"(launch/dryrun.py sets this)")
    return Mesh(np.asarray(devs[:n]).reshape(shape), axes)


def make_host_mesh(model: int = 1):
    """Tiny mesh over whatever devices exist (tests / CPU smoke runs)."""
    n = len(jax.devices())
    model = min(model, n)
    return jax.make_mesh((n // model, model), ("data", "model"))
