"""Step functions + abstract input specs for every (arch x shape) pair.

Three lowered programs, per the shape's kind:
  train_4k     -> ``train_step``  : LITE fine-tune step (fwd+bwd+AdamW)
  prefill_32k  -> ``prefill_step``: prompt ingestion, builds decode caches
  decode_32k / long_500k -> ``serve_step``: ONE token with a seq_len cache,
      early-exit controller (the paper's RL policy) in the compiled graph.

``input_specs`` returns jax.ShapeDtypeStruct stand-ins (no allocation);
``input_shardings`` the matching NamedSharding pytrees for a mesh.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.config import InputShape, ModelConfig, config_for_shape
from repro.core import policy_net
from repro.core.controller import make_policy
from repro.models import transformer as T
from repro.sharding.api import (_allocate, _path_str, axis_rules,
                                param_shardings)
from repro.training.loop import loss_fn
from repro.training.optimizer import adamw_update

COMPUTE_DTYPE = jnp.bfloat16


def arch_for_shape(cfg: ModelConfig, shape: InputShape,
                   variant: dict = None) -> ModelConfig:
    cfg = config_for_shape(cfg, shape)
    v = variant or {}
    if int(v.get("kv_int8", 0)):
        cfg = dataclasses.replace(cfg, kv_cache_dtype="int8")
    if "moe_cap" in v and cfg.moe is not None:
        cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
            cfg.moe, train_capacity_factor=float(v["moe_cap"])))
    if v.get("attn") in ("seq", "head"):
        cfg = dataclasses.replace(cfg, attn_shard=v["attn"])
    return cfg


# ---------------------------------------------------------------------------
# step functions
# ---------------------------------------------------------------------------
def make_train_step_fn(cfg: ModelConfig, *, accum: int = 1,
                       lite_stride: int = 1):
    """(params, opt, batch) -> (params, opt, loss). LITE loss, remat.

    ``accum`` > 1 splits the global batch into microbatches accumulated
    with lax.scan (activation memory / accum); ``lite_stride`` subsamples
    intermediate-exit CE positions (see core.lite_loss)."""

    def one_grad(params, tokens, labels, mask, prefix):
        grad_fn = jax.value_and_grad(
            partial(loss_fn, cfg=cfg, kind="lite", remat=True,
                    prefix_embed=prefix, lite_stride=lite_stride),
            has_aux=True)
        (loss, _), grads = grad_fn(params, tokens=tokens, labels=labels,
                                   mask=mask)
        return loss, grads

    def step(params, opt, batch):
        tokens, labels, mask = batch[:3]
        prefix = batch[3] if len(batch) > 3 else None
        if accum == 1:
            loss, grads = one_grad(params, tokens, labels, mask, prefix)
        else:
            mb = lambda x: x.reshape(accum, x.shape[0] // accum,
                                     *x.shape[1:])  # noqa: E731
            micro = (mb(tokens), mb(labels), mb(mask)) + (
                (mb(prefix),) if prefix is not None else ())

            def body(carry, m):
                g_acc, l_acc = carry
                pf = m[3] if len(m) > 3 else None
                l, g = one_grad(params, m[0], m[1], m[2], pf)
                return (jax.tree.map(jnp.add, g_acc, g), l_acc + l), None

            g0 = jax.tree.map(jnp.zeros_like, params)
            (grads, loss), _ = jax.lax.scan(body, (g0, jnp.zeros(())),
                                            micro)
            grads = jax.tree.map(lambda g: g / accum, grads)
            loss = loss / accum
        params, opt = adamw_update(params, grads, opt, 1e-5)
        return params, opt, loss

    return step


def make_prefill_step_fn(cfg: ModelConfig):
    """(params, tokens[, prefix]) -> (last_logits, caches)."""

    def step(params, tokens, prefix=None):
        h, caches, _ = T.prefill(params, cfg, tokens, prefix)
        logits = T.lm_logits(params, cfg, h[:, -1:, :])[:, 0]
        return logits, caches

    return step


def make_serve_step_fn(cfg: ModelConfig, threshold: float = 0.9):
    """(params, agent, tokens, caches, pos) -> (next, caches, exit_layer).

    The RL exit policy runs inside the step: this is GREEN-CODE's serving
    graph, with per-token exit predication + KV propagation."""

    def step(params, agent, tokens, caches, pos):
        controller = make_policy(agent, threshold)
        logits, new_caches, info = T.decode_step(params, cfg, tokens, caches,
                                                 pos, controller)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return nxt, new_caches, info["exit_layer"]

    return step


# ---------------------------------------------------------------------------
# abstract inputs
# ---------------------------------------------------------------------------
def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def abstract_params(cfg: ModelConfig, dtype=COMPUTE_DTYPE):
    return jax.eval_shape(
        lambda: T.init_params(jax.random.PRNGKey(0), cfg, dtype=dtype))


def abstract_opt(params_abs):
    # Adam moments in f32 regardless of (bf16) param dtype — mixed precision
    zeros = jax.tree.map(lambda x: _sds(x.shape, jnp.float32), params_abs)
    return {"m": zeros, "v": zeros,
            "step": _sds((), jnp.int32)}


def abstract_caches(cfg: ModelConfig, batch: int, max_len: int,
                    dtype=COMPUTE_DTYPE):
    return jax.eval_shape(
        lambda: T.init_cache(cfg, batch, max_len, dtype=dtype))


def abstract_agent(cfg: ModelConfig):
    return jax.eval_shape(
        lambda: policy_net.init_policy(jax.random.PRNGKey(0), cfg.d_model))


def input_specs(cfg: ModelConfig, shape: InputShape, *,
                dtype=COMPUTE_DTYPE, variant: dict = None) -> tuple:
    """ShapeDtypeStruct stand-ins for the step matching ``shape.kind``."""
    cfg = arch_for_shape(cfg, shape, variant)
    B, S = shape.global_batch, shape.seq_len
    F = cfg.frontend_tokens if cfg.frontend else 0
    params = abstract_params(cfg, dtype)
    if shape.kind == "train":
        batch = [_sds((B, S - F), jnp.int32),        # tokens
                 _sds((B, S), jnp.int32),            # labels (incl. prefix)
                 _sds((B, S), jnp.float32)]          # mask
        if F:
            batch.append(_sds((B, F, cfg.d_model), dtype))
        return params, abstract_opt(params), tuple(batch)
    if shape.kind == "prefill":
        args = [params, _sds((B, S - F), jnp.int32)]
        if F:
            args.append(_sds((B, F, cfg.d_model), dtype))
        return tuple(args)
    # decode: one token with a seq_len-deep cache
    caches = abstract_caches(cfg, B, S, dtype)
    return (params, abstract_agent(cfg), _sds((B,), jnp.int32), caches,
            _sds((B,), jnp.int32))


def make_step(cfg: ModelConfig, shape: InputShape, *, variant: dict = None):
    """``variant``: perf-iteration knobs, e.g. {"accum": 4,
    "lite_stride": 4} for train or {"threshold": 0.9} for serve."""
    v = dict(variant or {})
    cfg = arch_for_shape(cfg, shape, v)
    if shape.kind == "train":
        return make_train_step_fn(cfg, accum=int(v.pop("accum", 1)),
                                  lite_stride=int(v.pop("lite_stride", 1)))
    if shape.kind == "prefill":
        return make_prefill_step_fn(cfg)
    return make_serve_step_fn(cfg, threshold=float(v.pop("threshold", 0.9)))


# ---------------------------------------------------------------------------
# shardings
# ---------------------------------------------------------------------------
_CACHE_AXES = {
    "k": ("batch", "ctx", "kv_heads", None),
    "v": ("batch", "ctx", "kv_heads", None),
    "k_s": ("batch", "ctx", "kv_heads"),
    "v_s": ("batch", "ctx", "kv_heads"),
    "latent": ("batch", "ctx", None),
    "krope": ("batch", "ctx", None),
    "pos": ("batch", "ctx"),
    "state": ("batch", "heads", None, None),
    "conv": ("batch", None, "heads"),
}


def cache_shardings(cache_abs, mesh):
    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_abs)
    leaves = []
    for kp, v in flat:
        key = _path_str(kp).rsplit("/", 1)[-1]
        axes = _CACHE_AXES.get(key)
        if axes is None:
            leaves.append(NamedSharding(mesh, P()))
            continue
        lead = [None] * (v.ndim - len(axes))          # stacked-layer dims
        spec = _allocate(lead + list(axes), v.shape, mesh)
        leaves.append(NamedSharding(mesh, spec))
    return jax.tree_util.tree_unflatten(treedef, leaves)


def batch_sharding(mesh, ndim: int, shape=None):
    axes = ["batch"] + [None] * (ndim - 1)
    spec = _allocate(axes, shape or tuple(1 << 30 for _ in range(ndim)),
                     mesh)
    return NamedSharding(mesh, spec)


def replicated(mesh):
    return NamedSharding(mesh, P())


def input_shardings(cfg: ModelConfig, shape: InputShape, mesh,
                    specs) -> tuple:
    """NamedSharding pytree matching ``input_specs`` output."""
    cfg = arch_for_shape(cfg, shape)  # variant only changes cache dtypes
    if shape.kind == "train":
        params_abs, opt_abs, batch_abs = specs
        p_sh = param_shardings(params_abs, mesh)
        opt_sh = {"m": param_shardings(
                      opt_abs["m"], mesh, zero_axes=("pod", "data")),
                  "v": param_shardings(
                      opt_abs["v"], mesh, zero_axes=("pod", "data")),
                  "step": replicated(mesh)}
        b_sh = tuple(batch_sharding(mesh, b.ndim, b.shape)
                     for b in batch_abs)
        return p_sh, opt_sh, b_sh
    if shape.kind == "prefill":
        params_abs = specs[0]
        out = [param_shardings(params_abs, mesh)]
        for b in specs[1:]:
            out.append(batch_sharding(mesh, b.ndim, b.shape))
        return tuple(out)
    params_abs, agent_abs, tok_abs, cache_abs, pos_abs = specs
    return (param_shardings(params_abs, mesh),
            jax.tree.map(lambda _: replicated(mesh), agent_abs),
            batch_sharding(mesh, 1, tok_abs.shape),
            cache_shardings(cache_abs, mesh),
            batch_sharding(mesh, 1, pos_abs.shape))
