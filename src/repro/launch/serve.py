"""Serving launcher: batched early-exit code completion endpoint (CLI).

  python -m repro.launch.serve --arch llama32-3b --mini --controller policy \
      --threshold 0.9 --requests 8

Loads (or trains on the fly at --mini scale) the LITE model + RL agent, then
serves a batch of code-completion requests and prints quality + energy
metrics — the CPU-scale analogue of the paper's VS-Code endpoint (§V).

``--scheduler`` routes the batch through the continuous-batching scheduler
(serving/scheduler.py) instead of the one-shot Engine: requests are admitted
into a persistent KV-slot pool and retire independently; queue/fleet stats
are printed alongside the quality metrics.
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.core.controller import make_controller
from repro.data import CodeCompletionDataset
from repro.models import transformer as T
from repro.serving import Engine
from repro.serving.metrics import aggregate_metrics, codebleu_like, rouge_l
from repro.training.checkpoint import load_pytree


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama32-3b")
    ap.add_argument("--controller", default="policy",
                    choices=["none", "fixed", "confidence", "entropy",
                             "policy"])
    ap.add_argument("--threshold", type=float, default=0.9)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=15)
    ap.add_argument("--language", default="java")
    ap.add_argument("--params", default="", help="checkpoint path")
    ap.add_argument("--agent", default="", help="RL agent checkpoint path")
    ap.add_argument("--train-steps", type=int, default=60,
                    help="on-the-fly mini fine-tune when no checkpoint")
    ap.add_argument("--scheduler", action="store_true",
                    help="serve via the continuous-batching scheduler")
    ap.add_argument("--slots", type=int, default=4,
                    help="KV slot pool size (with --scheduler)")
    args = ap.parse_args()

    mod = __import__(f"repro.configs."
                     f"{args.arch.replace('-', '_').replace('.', '_')}",
                     fromlist=["paper_mini"])
    cfg = mod.paper_mini()
    ds = CodeCompletionDataset(language=args.language, n_files=120,
                               seq_len=256, vocab_size=cfg.vocab_size)

    if args.params:
        params = load_pytree(args.params)
    else:
        from repro.training import train_model
        print("[serve] no checkpoint; mini LITE fine-tune ...")
        params, _ = train_model(cfg, ds, kind="lite",
                                steps=args.train_steps, batch_size=4,
                                lr=1e-3, log_every=20)

    agent = None
    if args.controller == "policy":
        if args.agent:
            agent = load_pytree(args.agent)
        else:
            from repro.rl import PPOConfig, train_agent
            print("[serve] no agent; training PPO exit agent ...")
            agent, _, _ = train_agent(
                params, cfg, ds, n_episodes=24, gen_tokens=8,
                ppo=PPOConfig(total_steps=30_000), log_every=5)

    tasks = ds.completion_tasks("test", args.requests, max_context=192)
    requests = [c for c, _ in tasks]

    sched = None
    if args.scheduler:
        from repro.serving import Scheduler
        sched = Scheduler(params, cfg, controller_kind=args.controller,
                          agent_params=agent, threshold=args.threshold,
                          allowed_kinds=("none", args.controller),
                          max_slots=args.slots,
                          max_len=192 + args.max_new,
                          max_new=args.max_new,
                          queue_depth=max(64, args.requests)).start()
        try:
            res = sched.serve_batch(requests, max_new=args.max_new)
        except BaseException:
            sched.stop()
            raise
    else:
        ctrl = make_controller(args.controller, params=params, cfg=cfg,
                               agent_params=agent, threshold=args.threshold)
        engine = Engine(params, cfg, max_new=args.max_new)
        res = engine.serve(requests, max_new=args.max_new, controller=ctrl)

    scores = []
    for (ctx, ref), toks in zip(tasks, res.tokens):
        ref_toks = [ds.tokenizer.vocab[i] if i < len(ds.tokenizer.vocab)
                    else "?" for i in ref[:args.max_new]]
        hyp_toks = [ds.tokenizer.vocab[i] if i < len(ds.tokenizer.vocab)
                    else "?" for i in toks]
        scores.append({"rougeL": rouge_l(hyp_toks, ref_toks),
                       **codebleu_like(hyp_toks, ref_toks)})
    agg = aggregate_metrics(res.metrics)
    print(f"[serve] controller={args.controller} T={args.threshold}")
    print(f"  rougeL    {np.mean([s['rougeL'] for s in scores]):.3f}")
    print(f"  codebleu  {np.mean([s['codebleu'] for s in scores]):.3f}")
    print(f"  layers    {agg['mean_layers']:.2f}/{cfg.num_layers}")
    print(f"  energy    {agg['energy_j']:.4f} J "
          f"(saving {agg['energy_saving_frac']*100:.1f}%)")
    for i, (toks, el) in enumerate(zip(res.tokens[:3], res.exit_layers[:3])):
        txt = ds.tokenizer.decode(toks).replace("\n", "\\n")
        print(f"  [{i}] exits={el} -> {txt!r}")
    if sched is not None:
        st = sched.stats()
        print(f"  [scheduler] slots={st['max_slots']} "
              f"throughput={st['throughput_tok_s']:.1f} tok/s "
              f"fleet J/tok={st['fleet_j_per_token']:.3e} "
              f"p95 latency={st['latency_p95_s']:.3f}s")
        sched.stop()


if __name__ == "__main__":
    main()
