"""Serving launcher: batched early-exit code completion endpoint (CLI).

  python -m repro.launch.serve --arch llama32-3b --mini --controller policy \
      --threshold 0.9 --requests 8

Loads (or trains on the fly at --mini scale) the LITE model + RL agent, then
serves a batch of code-completion requests and prints quality + energy
metrics — the CPU-scale analogue of the paper's VS-Code endpoint (§V).

Arguments parse straight into the shared request surface
(``repro.api``): an exit :class:`PolicySpec`, :class:`SamplingParams` and
one :class:`GenerationRequest` per task, served either by the one-shot
``Engine`` or (``--scheduler``) the continuous-batching scheduler, where
requests are admitted into a persistent KV-slot pool and retire
independently; queue/fleet stats are printed alongside quality metrics.
"""
from __future__ import annotations

import argparse

import numpy as np

from repro.api import GenerationRequest, PolicySpec, SamplingParams
from repro.core import exit_policy
from repro.data import CodeCompletionDataset
from repro.serving import Engine
from repro.serving.metrics import aggregate_metrics, codebleu_like, rouge_l
from repro.training.checkpoint import load_pytree


def build_spec(kind: str, threshold: float, exit_idx: int = 0,
               draft_idx: int = 0, spec_window: int = 4) -> PolicySpec:
    pol = exit_policy.get(kind)
    params = {}
    if "threshold" in pol.defaults:
        params["threshold"] = threshold
    if "exit_idx" in pol.defaults:
        params["exit_idx"] = float(exit_idx)
    if "draft_idx" in pol.defaults:       # speculative: draft-then-verify
        params["draft_idx"] = float(draft_idx)
        params["window"] = float(spec_window)
    return PolicySpec(kind, params)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama32-3b")
    ap.add_argument("--controller", default="policy",
                    choices=sorted(exit_policy.names()))
    ap.add_argument("--threshold", type=float, default=0.9)
    ap.add_argument("--exit-idx", type=int, default=0,
                    help="segment index for --controller fixed")
    ap.add_argument("--draft-idx", type=int, default=0,
                    help="draft exit point for --controller speculative")
    ap.add_argument("--spec-window", type=int, default=4,
                    help="draft tokens per verify for --controller "
                         "speculative")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="sampling temperature (0 = greedy)")
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--top-p", type=float, default=1.0)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=15)
    ap.add_argument("--language", default="java")
    ap.add_argument("--params", default="", help="checkpoint path")
    ap.add_argument("--agent", default="", help="RL agent checkpoint path")
    ap.add_argument("--train-steps", type=int, default=60,
                    help="on-the-fly mini fine-tune when no checkpoint")
    ap.add_argument("--scheduler", action="store_true",
                    help="serve via the continuous-batching scheduler")
    ap.add_argument("--slots", type=int, default=4,
                    help="KV slot pool size (with --scheduler)")
    ap.add_argument("--kv-layout", choices=("contiguous", "paged"),
                    default="contiguous",
                    help="KV cache layout (paged = block tables + "
                         "prefix sharing)")
    ap.add_argument("--block-size", type=int, default=16,
                    help="tokens per KV block (with --kv-layout paged)")
    ap.add_argument("--prefill-chunk", type=int, default=32,
                    help="prompt tokens ingested per scheduler tick (one "
                         "compiled prefill shape for every prompt length; "
                         "with --scheduler)")
    ap.add_argument("--trace-out", default="",
                    help="write a Chrome trace-event JSON of the run's "
                         "tick phases here (open in Perfetto; with "
                         "--scheduler)")
    ap.add_argument("--replicas", type=int, default=1,
                    help="data-parallel fleet: N scheduler replicas of "
                         "--slots each behind one placement router "
                         "(with --scheduler)")
    ap.add_argument("--placement", default="energy",
                    help="fleet placement policy: rr | least_queue | "
                         "energy (with --replicas > 1)")
    args = ap.parse_args()
    if args.trace_out and not args.scheduler:
        ap.error("--trace-out requires --scheduler (the one-shot engine "
                 "has no tick phases to trace)")
    if args.replicas > 1 and not args.scheduler:
        ap.error("--replicas requires --scheduler (the one-shot engine "
                 "is single-replica by construction)")

    mod = __import__(f"repro.configs."
                     f"{args.arch.replace('-', '_').replace('.', '_')}",
                     fromlist=["paper_mini"])
    cfg = mod.paper_mini()
    ds = CodeCompletionDataset(language=args.language, n_files=120,
                               seq_len=256, vocab_size=cfg.vocab_size)

    if args.params:
        params = load_pytree(args.params)
    else:
        from repro.training import train_model
        print("[serve] no checkpoint; mini LITE fine-tune ...")
        params, _ = train_model(cfg, ds, kind="lite",
                                steps=args.train_steps, batch_size=4,
                                lr=1e-3, log_every=20)

    agent = None
    if args.controller == "policy":
        if args.agent:
            agent = load_pytree(args.agent)
        else:
            from repro.rl import PPOConfig, train_agent
            print("[serve] no agent; training PPO exit agent ...")
            agent, _, _ = train_agent(
                params, cfg, ds, n_episodes=24, gen_tokens=8,
                ppo=PPOConfig(total_steps=30_000), log_every=5)

    spec = build_spec(args.controller, args.threshold, args.exit_idx,
                      args.draft_idx, args.spec_window)
    sampling = SamplingParams(temperature=args.temperature,
                              top_k=args.top_k, top_p=args.top_p)
    tasks = ds.completion_tasks("test", args.requests, max_context=192)
    reqs = [GenerationRequest(prompt=c, max_new_tokens=args.max_new,
                              policy=spec, sampling=sampling)
            for c, _ in tasks]

    sched = None
    tracer = None
    if args.scheduler:
        from repro.serving import Scheduler

        def make_scheduler(_rid: int = 0) -> Scheduler:
            t = None
            if args.trace_out:
                from repro.obs import Tracer
                t = Tracer()
            return Scheduler(params, cfg, default_policy=spec,
                             agent_params=agent,
                             allowed_kinds=("none", args.controller),
                             tokenizer=ds.tokenizer,
                             max_slots=args.slots,
                             max_len=192 + args.max_new,
                             max_new=args.max_new,
                             kv_layout=args.kv_layout,
                             block_size=args.block_size,
                             spec_window=args.spec_window,
                             prefill_chunk=args.prefill_chunk,
                             queue_depth=max(64, args.requests),
                             tracer=t)

        if args.replicas > 1:
            from repro.serving import Router
            sched = Router(make_scheduler, n_replicas=args.replicas,
                           placement=args.placement).start()
        else:
            sched = make_scheduler().start()
            tracer = sched.obs if args.trace_out else None
        try:
            handles = [sched.submit(r) for r in reqs]
            results = [h.result(300.0).to_result(ds.tokenizer)
                       for h in handles]
        except BaseException:
            sched.stop()
            raise
    else:
        engine = Engine(params, cfg, max_new=args.max_new,
                        agent_params=agent, tokenizer=ds.tokenizer)
        results = engine.serve_requests(reqs)

    scores = []
    for (ctx, ref), res in zip(tasks, results):
        ref_toks = [ds.tokenizer.vocab[i] if i < len(ds.tokenizer.vocab)
                    else "?" for i in ref[:args.max_new]]
        hyp_toks = [ds.tokenizer.vocab[i] if i < len(ds.tokenizer.vocab)
                    else "?" for i in res.tokens]
        scores.append({"rougeL": rouge_l(hyp_toks, ref_toks),
                       **codebleu_like(hyp_toks, ref_toks)})
    agg = aggregate_metrics([r.metrics for r in results])
    print(f"[serve] policy={spec.name} params={spec.resolved()}")
    print(f"  rougeL    {np.mean([s['rougeL'] for s in scores]):.3f}")
    print(f"  codebleu  {np.mean([s['codebleu'] for s in scores]):.3f}")
    print(f"  layers    {agg['mean_layers']:.2f}/{cfg.num_layers}")
    print(f"  energy    {agg['energy_j']:.4f} J "
          f"(saving {agg['energy_saving_frac']*100:.1f}%)")
    for i, res in enumerate(results[:3]):
        txt = (res.text or "").replace("\n", "\\n")
        print(f"  [{i}] finish={res.finish_reason} exits={res.exit_layers} "
              f"-> {txt!r}")
    if sched is not None and args.replicas > 1:
        st = sched.stats()
        fl = st["fleet"]
        print(f"  [fleet] replicas={st['replicas']} "
              f"placement={st['placement']} "
              f"throughput={fl['throughput_tok_s']:.1f} tok/s "
              f"fleet J/tok={fl['fleet_j_per_token']:.3e} "
              f"max energy share={fl['max_replica_energy_share']:.2f}")
        for rst in st["per_replica"]:
            print(f"    replica {rst['replica_id']}: "
                  f"routed={rst['routed']} tokens={rst['fleet_tokens']} "
                  f"energy={rst['fleet_energy_j']:.3e} J "
                  f"power EMA={rst['power_w_ema']:.2f} W")
        if args.trace_out:
            from repro.obs import write_chrome_trace
            events = sched.drain_events()
            sched.stop()
            obj = write_chrome_trace(args.trace_out, events)
            print(f"  [trace] {len(obj['traceEvents'])} merged fleet "
                  f"events -> {args.trace_out} (replica = tid group)")
        else:
            sched.stop()
    elif sched is not None:
        st = sched.stats()
        if st["kv_layout"] == "paged":
            print(f"  [kv] paged: {st['blocks_in_use']}/{st['num_blocks']} "
                  f"blocks in use, peak {st['peak_kv_bytes']} B, "
                  f"prefix hit rate {st['prefix_hit_rate']:.2f}")
        if "acceptance_rate" in st:
            print(f"  [spec] window={st['spec_window']} "
                  f"acceptance={st['acceptance_rate']:.2f} "
                  f"tokens/verify={st['tokens_per_verify']:.2f}")
        print(f"  [scheduler] slots={st['max_slots']} "
              f"throughput={st['throughput_tok_s']:.1f} tok/s "
              f"fleet J/tok={st['fleet_j_per_token']:.3e} "
              f"prefill J={st['fleet_prefill_energy_j']:.3e} "
              f"p95 latency={st['latency_p95_s']:.3f}s "
              f"step compiles={st['step_compiles']} "
              f"prefill compiles={st['prefill_compiles']}")
        sched.stop()
        if tracer is not None:
            from repro.obs import write_chrome_trace
            # stop() above drained residents, so the trace is complete
            obj = write_chrome_trace(args.trace_out, tracer.drain())
            summ = tracer.phase_summary()
            print(f"  [trace] {len(obj['traceEvents'])} events -> "
                  f"{args.trace_out} (load in Perfetto)")
            for name in sorted(summ):
                s = summ[name]
                print(f"    {name:<14} n={s['count']:<5} "
                      f"total={s['total_s']*1e3:8.2f}ms "
                      f"device_wait={s['device_wait_s']*1e3:8.2f}ms")


if __name__ == "__main__":
    main()
