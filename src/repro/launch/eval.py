"""Eval-harness launcher: pass-rate-vs-J/token frontier (CLI).

  python -m repro.launch.eval --mode both --out BENCH_eval.json
  python -m repro.launch.eval --mode replay --tasks suite.jsonl --samples 10

Builds a mini model (optionally lite-trained), drives the vendored (or
``--tasks`` JSONL) completion suite through the exit-policy arms with
``repro.evals``, and writes ``BENCH_eval.json``:

* ``--mode http``   spin an in-process ``repro.serving.server`` and drive
  it with the live Poisson client — wall-clock TTFT, lifecycle-span
  energy join.
* ``--mode replay`` the deterministic virtual-clock driver — the payload
  is a pure function of (weights, tasks, arms, config); ``--replays 2``
  re-runs it and hard-checks byte-identity the way CI does.
* ``--mode both``   HTTP frontier + replay section in one artifact.
"""
from __future__ import annotations

import argparse
import json
import threading

import jax

from repro.evals import (EvalRunConfig, default_arms, frontier, load_jsonl,
                         payload_bytes, run_http, run_replay, smoke_tasks,
                         vendored_tasks, write_bench)


def build_model(num_layers: int, d_model: int, train_steps: int,
                seed: int = 0):
    """Mini model + tokenizer. ``train_steps > 0`` lite-trains on the java
    corpus (the tokenizer then carries real code tokens); 0 keeps random
    weights with a pure byte-fallback tokenizer — fast, fully offline."""
    from repro.configs.llama32_3b import paper_mini
    from repro.models import transformer as T
    if train_steps > 0:
        from repro.data import CodeCompletionDataset
        from repro.training import train_model
        ds = CodeCompletionDataset(language="java", n_files=60, seq_len=128,
                                   vocab_size=512)
        cfg = paper_mini(num_layers=num_layers, d_model=d_model,
                         vocab_size=ds.tokenizer.vocab_size)
        params, _ = train_model(cfg, ds, kind="lite", steps=train_steps,
                                batch_size=4, lr=1e-3, log_every=0)
        return cfg, params, ds.tokenizer
    from repro.data.tokenizer import _SPECIALS, CodeTokenizer
    tok = CodeTokenizer(_SPECIALS)
    cfg = paper_mini(num_layers=num_layers, d_model=d_model,
                     vocab_size=tok.vocab_size)
    params = T.init_params(jax.random.PRNGKey(seed), cfg)
    return cfg, params, tok


def serve_inprocess(params, cfg, tokenizer, *, max_slots: int = 4,
                    max_len: int = 256, max_new: int = 32,
                    spec_window: int = 4):
    """Start an in-process HTTP server (tracing on, so the eval client
    can join the ``req/*`` lifecycle spans). Returns (url, closer)."""
    from http.server import ThreadingHTTPServer

    from repro.obs import Tracer
    from repro.serving import Scheduler
    from repro.serving.server import Handler, _State
    _State.cfg, _State.params = cfg, params
    _State.agent, _State.tokenizer = None, tokenizer
    sched = Scheduler(
        params, cfg,
        allowed_kinds=("none", "fixed", "confidence", "entropy",
                       "speculative"),
        tokenizer=tokenizer, max_slots=max_slots, max_len=max_len,
        max_new=max_new, prefill_chunk=16, spec_window=spec_window,
        tracer=Tracer(enabled=True)).start()
    _State.scheduler = sched
    srv = ThreadingHTTPServer(("127.0.0.1", 0), Handler)
    threading.Thread(target=srv.serve_forever, daemon=True).start()

    def close():
        srv.shutdown()
        sched.stop()
        _State.scheduler = None

    return f"http://127.0.0.1:{srv.server_address[1]}", close


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", choices=("http", "replay", "both"),
                    default="both")
    ap.add_argument("--tasks", default=None,
                    help="external JSONL task file (default: vendored)")
    ap.add_argument("--smoke", action="store_true",
                    help="2-task deterministic smoke suite")
    ap.add_argument("--samples", type=int, default=1,
                    help="completions per task (n for pass@k)")
    ap.add_argument("--ks", type=int, nargs="+", default=[1, 10])
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--top-p", type=float, default=1.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--rate", type=float, default=16.0,
                    help="HTTP Poisson arrival rate (req/s)")
    ap.add_argument("--layers", type=int, default=6,
                    help=">= 6 so the exit-point schedule is non-trivial")
    ap.add_argument("--d-model", type=int, default=64)
    ap.add_argument("--train-steps", type=int, default=0)
    ap.add_argument("--thresholds", type=float, nargs="+", default=[0.8])
    ap.add_argument("--no-speculative", action="store_true")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--replays", type=int, default=2,
                    help="replay invocations; > 1 hard-checks that the "
                         "payloads are byte-identical")
    ap.add_argument("--out", default="BENCH_eval.json")
    args = ap.parse_args(argv)

    if args.tasks:
        tasks = load_jsonl(args.tasks)
    elif args.smoke:
        tasks = smoke_tasks()
    else:
        tasks = vendored_tasks()
    cfg, params, tok = build_model(args.layers, args.d_model,
                                   args.train_steps, args.seed)
    arms = default_arms(thresholds=tuple(args.thresholds),
                        speculative=not args.no_speculative)
    rc = EvalRunConfig(n_samples=args.samples, ks=tuple(args.ks),
                       temperature=args.temperature, top_p=args.top_p,
                       seed=args.seed, rate_hz=args.rate)
    max_new = max(t.max_new_tokens for t in tasks)
    max_plen = max(len(tok.encode(t.prompt)) for t in tasks)

    http_report = None
    if args.mode in ("http", "both"):
        url, close = serve_inprocess(
            params, cfg, tok, max_slots=args.slots,
            max_len=max_plen + max_new + 8, max_new=max_new)
        try:
            print(f"[eval] http driver against {url} "
                  f"({len(tasks)} tasks x {args.samples} samples x "
                  f"{len(arms)} arms)")
            http_report = run_http(url, tasks, arms, rc)
        finally:
            close()

    replay_report = None
    if args.mode in ("replay", "both"):
        payloads = []
        for i in range(max(args.replays, 1)):
            print(f"[eval] replay {i + 1}/{max(args.replays, 1)} "
                  f"(virtual clock)")
            payloads.append(run_replay(params, cfg, tok, tasks, arms, rc,
                                       slots=args.slots))
        replay_report = payloads[0]
        for i, p in enumerate(payloads[1:], 2):
            assert payload_bytes(p) == payload_bytes(replay_report), \
                f"replay {i} diverged from replay 1 — determinism broken"
        if len(payloads) > 1:
            print(f"[eval] {len(payloads)} replays byte-identical")

    bench = write_bench(args.out, http_report, replay_report)
    shown = bench.get("frontier", bench.get("replay_frontier"))
    print(f"[eval] frontier ({'http' if 'frontier' in bench else 'replay'}):")
    print(json.dumps(shown, indent=1))
    print(f"[eval] wrote {args.out}")
    return bench


if __name__ == "__main__":
    main()
