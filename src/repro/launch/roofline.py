"""Roofline analysis from compiled (AOT) artifacts — no hardware needed.

Terms (per chip, seconds):
  compute    = HLO_FLOPs / peak_FLOPs          (197 TFLOP/s bf16, v5e)
  memory     = HLO_bytes / HBM_bw              (819 GB/s)
  collective = collective_bytes / link_bw      (~50 GB/s/link ICI)

``cost_analysis`` of a partitioned executable reports the per-device
program, so the terms are already per-chip. collective_bytes is parsed from
the optimized HLO text: the summed *result* sizes of every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute op (a
consistent, hardware-independent proxy for wire traffic).
"""
from __future__ import annotations

import re
from dataclasses import asdict, dataclass

from repro.core.energy import HBM_BW, ICI_BW, PEAK_FLOPS

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Per-collective-kind result bytes summed over the module."""
    out = {k: 0 for k in _COLLECTIVES}
    count = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        m = re.search(r"=\s*((?:\([^)]*\))|(?:\S+))\s+([a-z\-]+)", line)
        if not m:
            continue
        op = m.group(2)
        for kind in _COLLECTIVES:
            # match both sync and async-start forms, once per line
            if op == kind or op == kind + "-start":
                out[kind] += _shape_bytes(m.group(1))
                count[kind] += 1
                break
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    out["counts"] = count
    return out


@dataclass
class Roofline:
    flops: float                 # per-device HLO FLOPs
    hbm_bytes: float             # per-device HLO bytes accessed
    coll_bytes: float            # per-device collective result bytes
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    model_flops: float           # 6ND / 2ND useful-work estimate (per device)
    mfu_ratio: float             # model_flops / HLO flops

    def to_dict(self):
        return asdict(self)


def analyze(compiled, *, model_flops_global: float, n_devices: int,
            hlo_text: str | None = None) -> Roofline:
    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    ca = ca or {}
    flops = float(ca.get("flops", 0.0))
    hbm = float(ca.get("bytes accessed", 0.0))
    text = hlo_text if hlo_text is not None else compiled.as_text()
    coll = collective_bytes(text)["total"]
    compute_s = flops / PEAK_FLOPS
    memory_s = hbm / HBM_BW
    coll_s = coll / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s, "collective": coll_s}
    bottleneck = max(terms, key=terms.get)
    mf = model_flops_global / n_devices
    return Roofline(flops=flops, hbm_bytes=hbm, coll_bytes=float(coll),
                    compute_s=compute_s, memory_s=memory_s,
                    collective_s=coll_s, bottleneck=bottleneck,
                    model_flops=mf,
                    mfu_ratio=(mf / flops if flops else 0.0))


def model_flops_global(cfg, shape) -> float:
    """Useful-work FLOPs per step: 6·N·D train, 2·N·D inference
    (N = active params for MoE)."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * n * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n * shape.global_batch * shape.seq_len
    return 2.0 * n * shape.global_batch          # one token per sequence
