import os
os.environ["XLA_FLAGS"] = (os.environ.get("REPRO_EXTRA_XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")

"""Multi-pod dry-run: lower + compile every (arch x input-shape x mesh).

The two lines above MUST run before any other import — jax locks the device
count at first init. 512 host-platform placeholder devices back both the
single-pod (16, 16) and multi-pod (2, 16, 16) production meshes.

Per combination this driver:
  1. builds the step function (train / prefill / serve) and abstract inputs,
  2. ``jax.jit(step, in_shardings=...).lower(...).compile()`` under the mesh,
  3. records memory_analysis / cost_analysis / per-collective byte counts
     and the three roofline terms into a JSON report.

CLI:
  python -m repro.launch.dryrun --arch granite-3-8b --shape train_4k
  python -m repro.launch.dryrun --all [--mesh single|multi|both]
``--all`` runs each combo in a subprocess (isolation + restartability);
existing JSON results are skipped unless --force.
"""
import argparse  # noqa: E402
import json  # noqa: E402
import subprocess  # noqa: E402
import sys  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402


def parse_variant(spec: str) -> dict:
    """"accum=4,lite_stride=4" -> {"accum": "4", "lite_stride": "4"}."""
    out = {}
    for kv in (spec or "").split(","):
        if "=" in kv:
            k, v = kv.split("=", 1)
            out[k.strip()] = v.strip()
    return out


def run_one(arch: str, shape_name: str, mesh_kind: str, out_dir: str,
            verbose: bool = True, variant: str = "") -> dict:
    import jax

    from repro.config import SHAPES
    from repro.configs import get_config
    from repro.launch import steps as S
    from repro.launch.mesh import make_production_mesh
    from repro.launch.roofline import analyze, model_flops_global
    from repro.sharding.api import axis_rules

    shape = SHAPES[shape_name]
    cfg = get_config(arch, "full")
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_dev = mesh.devices.size

    vdict = parse_variant(variant)
    step = S.make_step(cfg, shape, variant=vdict)
    specs = S.input_specs(cfg, shape, variant=vdict)
    shardings = S.input_shardings(cfg, shape, mesh, specs)

    donate = ()
    if shape.kind == "train":
        donate = (0, 1)          # params, opt
    elif shape.kind == "decode":
        donate = (3,)            # caches
    t0 = time.time()
    with mesh, axis_rules(mesh):
        lowered = jax.jit(step, in_shardings=shardings,
                          donate_argnums=donate).lower(*specs)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    mem_d = {}
    if mem is not None:
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes",
                  "alias_size_in_bytes"):
            v = getattr(mem, k, None)
            if v is not None:
                mem_d[k] = int(v)
        tot = (mem_d.get("argument_size_in_bytes", 0)
               + mem_d.get("output_size_in_bytes", 0)
               + mem_d.get("temp_size_in_bytes", 0)
               - mem_d.get("alias_size_in_bytes", 0))
        mem_d["total_hbm_bytes_per_device"] = int(tot)

    hlo = compiled.as_text()
    from repro.launch.roofline import collective_bytes
    coll = collective_bytes(hlo)
    cfg_shape = S.arch_for_shape(cfg, shape, vdict)
    roof = analyze(compiled,
                   model_flops_global=model_flops_global(cfg_shape, shape),
                   n_devices=n_dev, hlo_text=hlo)

    result = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "variant": variant,
        "n_devices": int(n_dev), "kind": shape.kind,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory": mem_d,
        "collectives": {k: v for k, v in coll.items() if k != "counts"},
        "collective_counts": coll["counts"],
        "roofline": roof.to_dict(),
        "ok": True,
    }
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        vtag = ("__" + variant.replace("=", "-").replace(",", "_")
                if variant else "")
        fn = os.path.join(out_dir,
                          f"{arch}__{shape_name}__{mesh_kind}{vtag}.json")
        with open(fn, "w") as f:
            json.dump(result, f, indent=1)
    if verbose:
        r = roof
        hbm_gb = mem_d.get("total_hbm_bytes_per_device", 0) / 2**30
        vs = f" [{variant}]" if variant else ""
        print(f"[dryrun] {arch} x {shape_name} x {mesh_kind}{vs}: OK "
              f"compile={t_compile:.1f}s hbm/dev={hbm_gb:.2f}GiB "
              f"compute={r.compute_s:.3e}s memory={r.memory_s:.3e}s "
              f"collective={r.collective_s:.3e}s -> {r.bottleneck}",
              flush=True)
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--variant", default="",
                    help="perf knobs, e.g. accum=4,lite_stride=4")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--timeout", type=int, default=1800)
    args = ap.parse_args()

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    if not args.all:
        for mk in meshes:
            run_one(args.arch, args.shape, mk, args.out,
                    variant=args.variant)
        return

    from repro.config import SHAPES
    from repro.configs import ASSIGNED_ARCH_IDS
    combos = [(a, s, m) for a in ASSIGNED_ARCH_IDS for s in SHAPES
              for m in meshes]
    failures = []
    for arch, shape, mk in combos:
        fn = os.path.join(args.out, f"{arch}__{shape}__{mk}.json")
        if os.path.exists(fn) and not args.force:
            print(f"[dryrun] skip {arch} x {shape} x {mk} (cached)")
            continue
        cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
               "--shape", shape, "--mesh", mk, "--out", args.out]
        try:
            rc = subprocess.run(cmd, timeout=args.timeout).returncode
        except subprocess.TimeoutExpired:
            rc = -1
        if rc != 0:
            failures.append((arch, shape, mk, rc))
            print(f"[dryrun] FAIL {arch} x {shape} x {mk} rc={rc}",
                  flush=True)
    print(f"[dryrun] done, {len(failures)} failures")
    for f in failures:
        print("  FAIL:", f)
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    try:
        main()
    except Exception:
        traceback.print_exc()
        sys.exit(2)
