"""LITE aggregated loss (paper Eq. 1 + §III-D weight schedule).

``Loss = Σ w_i · loss_i / Σ w_i`` over the exit layers plus the final layer,
where ``loss_i`` is the next-token cross-entropy of decoding layer *i*'s
hidden state through the single shared LM head.

Weights (paper §III-D): exit layers are split into first-half and
second-half groups with budgets α = (0.7, 0.2); the final layer gets a fixed
α = 0.1. Within each group the weights follow a geometric sequence with
decay r = 0.9, highest weight at the *earliest* exit, normalized to the
group budget.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.core.exit_points import exit_points
from repro.models.transformer import lm_logits

Array = jax.Array


def lite_weights(cfg: ModelConfig) -> tuple[tuple[int, ...], jnp.ndarray]:
    """Returns (layers, weights): 1-indexed exit layers + final layer, and
    the normalized w_i vector (sums to 1)."""
    ec = cfg.exit
    pts = exit_points(cfg)
    half = cfg.num_layers // 2
    first = [p for p in pts if p <= half]
    second = [p for p in pts if p > half]
    b1, b2, b_final = ec.budgets

    def group_w(n, budget):
        if n == 0:
            return []
        r = ec.decay ** jnp.arange(n)          # highest weight earliest
        return list(budget * r / r.sum())

    w = group_w(len(first), b1) + group_w(len(second), b2) + [b_final]
    w = jnp.asarray(w, jnp.float32)
    return tuple(pts) + (cfg.num_layers,), w / w.sum()


def token_ce(logits: Array, labels: Array, mask: Array | None = None):
    """Mean next-token CE. logits: [B, S, V]; labels: [B, S] (already
    shifted); mask: [B, S] 1 = count.

    The f32 upcast feeds ONLY the logsumexp reduce (single consumer -> XLA
    fuses the convert into the reduction loop instead of materializing a
    [B, S, V] f32 copy); the label gather runs on the original dtype."""
    lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(
        logits, labels[..., None], axis=-1)[..., 0].astype(jnp.float32)
    ce = lse - ll
    if mask is None:
        return ce.mean()
    m = mask.astype(jnp.float32)
    return (ce * m).sum() / jnp.maximum(m.sum(), 1.0)


def lite_loss(params, cfg: ModelConfig, exit_hiddens, labels: Array,
              mask: Array | None = None, *, intermediate_stride: int = 1):
    """Aggregated LITE loss over the per-segment hidden states.

    ``exit_hiddens``: list of [B, S, D], one per segment boundary (last =
    final layer), as returned by ``transformer.forward``. Each is decoded
    through the shared LM head (no extra heads — the paper's core point).

    ``intermediate_stride`` > 1 evaluates the *intermediate* boundaries'
    CE on every stride-th position only (the final layer always uses all
    positions) — a beyond-paper optimization cutting the dominant LM-head
    FLOPs of the LITE step by ~n_exits/stride while keeping an unbiased
    estimate of each layer's loss. Paper-faithful = 1.

    Returns (loss, per_layer_losses [n_exits+1]).
    """
    layers, w = lite_weights(cfg)
    assert len(exit_hiddens) == len(layers), (
        f"{len(exit_hiddens)} hiddens vs {len(layers)} LITE layers")
    s = max(1, intermediate_stride)
    losses = []
    for i, h in enumerate(exit_hiddens):
        last = i == len(exit_hiddens) - 1
        if last or s == 1:
            logits = lm_logits(params, cfg, h)
            losses.append(token_ce(logits, labels, mask))
        else:
            logits = lm_logits(params, cfg, h[:, ::s])
            losses.append(token_ce(logits, labels[:, ::s],
                                   None if mask is None else mask[:, ::s]))
    per_layer = jnp.stack(losses)
    return jnp.sum(per_layer * w), per_layer
