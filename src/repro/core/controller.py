"""Exit controllers: map a hidden state at an exit point to an exit
decision.

All controllers return a float in {0., 1.} per token (already thresholded —
``decode_step`` treats > 0.5 as exit). Kinds:

  * ``none``        never exit (baseline full model)
  * ``fixed``       exit at a fixed exit-point index (paper §II experiment)
  * ``confidence``  top-1 softmax probability of the shared LM head > tau
                    (score-based baseline, CALM-style)
  * ``entropy``     normalized entropy of the head distribution < tau
  * ``policy``      the paper's RL agent: softmax(policy logits / temp)[EXIT]
                    thresholded by T (paper §VI-B)

The confidence/entropy controllers need head logits at intermediate layers;
they use the fused exit-check kernel when enabled (kernels/exit_head).
"""
from __future__ import annotations

from functools import partial
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.core import policy_net
from repro.models.layers import apply_norm
from repro.models.transformer import head_matrix

Array = jax.Array
ControllerFn = Callable[[Array, int], Optional[Array]]


def make_none() -> ControllerFn:
    return lambda h, i: None


def make_fixed(exit_idx: int) -> ControllerFn:
    """Exit every token at exit point ``exit_idx`` (0-based segment index)."""

    def ctrl(h: Array, i: int):
        return jnp.full((h.shape[0],), 1.0 if i >= exit_idx else 0.0)

    return ctrl


def _head_stats(params, cfg: ModelConfig, h: Array, use_kernel: bool):
    """(top1_prob, normalized_entropy) of the shared LM head on h [B, D]."""
    if use_kernel:
        from repro.kernels.ops import exit_check
        hn = apply_norm(params["final_norm"], h)
        top1, lse, ent = exit_check(hn, head_matrix(params, cfg),
                                    cfg.final_logit_softcap)
        p1 = jnp.exp(top1 - lse)
        ent_n = ent / jnp.log(cfg.vocab_size)
        return p1, ent_n
    from repro.models.transformer import lm_logits
    logits = lm_logits(params, cfg, h[:, None, :])[:, 0, :]
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    p = jnp.exp(logp)
    p1 = p.max(axis=-1)
    ent = -(p * logp).sum(axis=-1) / jnp.log(cfg.vocab_size)
    return p1, ent


def make_confidence(params, cfg: ModelConfig, tau: float,
                    use_kernel: bool = False) -> ControllerFn:
    def ctrl(h: Array, i: int):
        p1, _ = _head_stats(params, cfg, h, use_kernel)
        return (p1 > tau).astype(jnp.float32)

    return ctrl


def make_entropy(params, cfg: ModelConfig, tau: float,
                 use_kernel: bool = False) -> ControllerFn:
    def ctrl(h: Array, i: int):
        _, ent = _head_stats(params, cfg, h, use_kernel)
        return (ent < tau).astype(jnp.float32)

    return ctrl


def make_policy(agent_params, threshold: float,
                temperature: float = 1.0) -> ControllerFn:
    """The paper's RL controller: exit iff softmax(pi(h))[EXIT] > T."""

    def ctrl(h: Array, i: int):
        p_exit = policy_net.exit_probability(agent_params, h, temperature)
        return (p_exit > threshold).astype(jnp.float32)

    return ctrl


def make_controller(kind: str, *, params=None, cfg: ModelConfig = None,
                    agent_params=None, threshold: float = 0.9,
                    exit_idx: int = 0, temperature: float = 1.0,
                    use_kernel: bool = False) -> ControllerFn:
    if kind == "none":
        return make_none()
    if kind == "fixed":
        return make_fixed(exit_idx)
    if kind == "confidence":
        return make_confidence(params, cfg, threshold, use_kernel)
    if kind == "entropy":
        return make_entropy(params, cfg, threshold, use_kernel)
    if kind == "policy":
        return make_policy(agent_params, threshold, temperature)
    raise ValueError(f"unknown controller kind {kind!r}")
