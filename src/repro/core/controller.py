"""DEPRECATED closure-based controller construction — thin shims only.

The single implementation of every exit policy now lives in
:mod:`repro.core.exit_policy` (a registry of policies whose parameters are
runtime pytrees). These helpers remain for existing callers and tests: they
validate eagerly (clear messages instead of mid-trace tracer errors) and
return plain ``ControllerFn`` closures bound to the registry's appliers.

Migrate to::

    from repro.api import PolicySpec
    generate(..., policy=PolicySpec("confidence", {"threshold": 0.9}))

See ``docs/api.md`` for the full migration table.
"""
from __future__ import annotations

import warnings
from typing import Callable, Optional

import jax

from repro.config import ModelConfig
from repro.core import exit_policy
from repro.core.exit_policy import PolicyContext, PolicySpec, head_stats

Array = jax.Array
ControllerFn = Callable[[Array, int], Optional[Array]]

# scheduler versions < PR 2 imported this privately
_head_stats = head_stats


def _warn(name: str) -> None:
    warnings.warn(
        f"repro.core.controller.{name} is deprecated; use "
        f"repro.api.PolicySpec / repro.core.exit_policy instead",
        DeprecationWarning, stacklevel=3)


def make_controller(kind: str, *, params=None,
                    cfg: Optional[ModelConfig] = None, agent_params=None,
                    threshold: float = 0.9, exit_idx: int = 0,
                    temperature: float = 1.0,
                    use_kernel: bool = False) -> Optional[ControllerFn]:
    """Build a legacy controller closure for ``kind``.

    Validates eagerly: an unknown ``kind``, a missing ``params``/``cfg``
    (confidence/entropy) or a missing ``agent_params`` (policy) raise here
    with a readable message rather than surfacing later as a cryptic
    tracer error inside jit.
    """
    _warn("make_controller")
    pol = exit_policy.get(kind)                      # unknown kind -> error
    if kind == "fixed":
        spec = PolicySpec(kind, {"exit_idx": float(exit_idx)})
    elif kind == "policy":
        spec = PolicySpec(kind, {"threshold": float(threshold),
                                 "temperature": float(temperature)})
    elif kind in ("confidence", "entropy"):
        spec = PolicySpec(kind, {"threshold": float(threshold)})
    else:
        spec = PolicySpec(kind)
    ctx = PolicyContext(params=params, cfg=cfg, agent_params=agent_params,
                        use_kernel=use_kernel)
    exit_policy.validate_context(pol, ctx)
    if kind == "none":
        return lambda h, i: None                     # seed semantics
    return exit_policy.as_exit_fn(spec, ctx)


def make_none() -> ControllerFn:
    _warn("make_none")
    return lambda h, i: None


def make_fixed(exit_idx: int) -> ControllerFn:
    """Exit every token at exit point ``exit_idx`` (0-based segment index)."""
    return make_controller("fixed", exit_idx=exit_idx)


def make_confidence(params, cfg: ModelConfig, tau: float,
                    use_kernel: bool = False) -> ControllerFn:
    return make_controller("confidence", params=params, cfg=cfg,
                           threshold=tau, use_kernel=use_kernel)


def make_entropy(params, cfg: ModelConfig, tau: float,
                 use_kernel: bool = False) -> ControllerFn:
    return make_controller("entropy", params=params, cfg=cfg, threshold=tau,
                           use_kernel=use_kernel)


def make_policy(agent_params, threshold: float,
                temperature: float = 1.0) -> ControllerFn:
    """The paper's RL controller: exit iff softmax(pi(h))[EXIT] > T."""
    return make_controller("policy", agent_params=agent_params,
                           threshold=threshold, temperature=temperature)
