"""Analytic TPU energy model (hardware adaptation of the paper's Zeus/nvml
GPU measurements — see DESIGN.md §2).

The paper measures wall-plug GPU energy. This runtime is CPU-only with a
TPU-v5e target, so energy is *modeled*: per-layer FLOPs and HBM bytes are
derived from the architecture config, execution time is the roofline
``max(flops/peak, bytes/bw)``, and energy integrates a two-part power model

    E = T_exec · (P_static + P_dyn · util)

with util = compute-roofline fraction. The hardware-independent metric the
paper also reports — layers used/skipped per token — is exact.

Early exit accounting: a token that exits at layer ℓ saves the full cost of
layers ℓ+1..N *except* the K/V-projection + cache-write cost of those layers
(CALM-style propagation keeps the cache complete, paper §VI-G).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax.numpy as jnp
import numpy as np

from repro.config import (FFN_MOE, FFN_NONE, MIXER_MAMBA, MIXER_MLA,
                          ModelConfig)

# TPU v5e constants (also used by the roofline analysis)
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # B/s per chip
ICI_BW = 50e9                # B/s per link
P_STATIC_W = 90.0            # idle/static chip power
P_DYN_W = 110.0              # additional power at full utilization


@dataclass(frozen=True)
class LayerCost:
    flops: float             # per-token FLOPs for this layer
    bytes: float             # per-token HBM bytes (weights + cache traffic)
    kv_flops: float          # K/V projection FLOPs (paid even when skipped)
    kv_bytes: float          # K/V weight + cache-write bytes (paid when skipped)


def _bytes_per_param(dtype_bytes: float = 2.0) -> float:
    return dtype_bytes


def layer_cost(cfg: ModelConfig, layer_idx: int, ctx_len: int,
               dtype_bytes: float = 2.0) -> LayerCost:
    """Decode-step cost of one layer for one token with ``ctx_len`` cache."""
    spec = cfg.block_pattern[layer_idx]
    d = cfg.d_model
    bp = dtype_bytes
    fl = 0.0
    by = 0.0
    kv_fl = 0.0
    kv_by = 0.0

    if spec.mixer == MIXER_MAMBA:
        s = cfg.ssm
        d_in = d * s.expand
        H = d_in // s.head_dim
        n_proj = d * (2 * d_in + 2 * s.state_dim + H) + d_in * d
        fl += 2 * n_proj + 2 * H * s.head_dim * s.state_dim * 2
        by += n_proj * bp + H * s.head_dim * s.state_dim * 4 * 2  # state rw
        # SSM state update is the "cache write" analogue
        kv_fl += 2 * H * s.head_dim * s.state_dim
        kv_by += H * s.head_dim * s.state_dim * 4 * 2
    elif spec.mixer == MIXER_MLA:
        m = cfg.mla
        H = cfg.num_heads
        qk_head = m.qk_nope_head_dim + m.qk_rope_head_dim
        n_q = d * m.q_lora_rank + m.q_lora_rank * H * qk_head
        n_kv = d * (m.kv_lora_rank + m.qk_rope_head_dim)
        n_o = H * m.v_head_dim * d
        n_absorb = H * m.kv_lora_rank * (m.qk_nope_head_dim + m.v_head_dim)
        fl += 2 * (n_q + n_kv + n_o + n_absorb)
        # latent-space attention over the cache
        fl += 2 * ctx_len * H * (m.kv_lora_rank + m.qk_rope_head_dim) * 2
        by += (n_q + n_kv + n_o) * bp
        by += ctx_len * (m.kv_lora_rank + m.qk_rope_head_dim) * bp  # cache read
        kv_fl += 2 * n_kv
        kv_by += n_kv * bp + (m.kv_lora_rank + m.qk_rope_head_dim) * bp
    else:  # gqa variants
        from repro.models.transformer import _window_for
        eff_ctx = min(ctx_len, _window_for(cfg, spec) or ctx_len)
        n_qo = d * cfg.q_dim + cfg.q_dim * d
        n_kv = 2 * d * cfg.kv_dim
        fl += 2 * (n_qo + n_kv)
        fl += 2 * eff_ctx * cfg.num_heads * cfg.head_dim * 2   # scores + AV
        by += (n_qo + n_kv) * bp
        by += eff_ctx * 2 * cfg.kv_dim * bp                    # cache read
        kv_fl += 2 * n_kv
        kv_by += n_kv * bp + 2 * cfg.kv_dim * bp               # cache write

    if spec.ffn == FFN_MOE:
        m = cfg.moe
        act = m.num_experts_per_tok + m.num_shared_experts
        n_ffn = 3 * d * m.d_ff_expert * act + d * m.num_experts
        fl += 2 * n_ffn
        by += n_ffn * bp
    elif spec.ffn != FFN_NONE:
        mult = 3 if cfg.mlp_gated else 2
        n_ffn = mult * d * cfg.d_ff
        fl += 2 * n_ffn
        by += n_ffn * bp

    return LayerCost(fl, by, kv_fl, kv_by)


def head_cost(cfg: ModelConfig, dtype_bytes: float = 2.0):
    n = cfg.d_model * cfg.vocab_size
    return 2.0 * n, n * dtype_bytes


def stack_costs(cfg: ModelConfig, ctx_len: int) -> list[LayerCost]:
    return [layer_cost(cfg, i, ctx_len) for i in range(cfg.num_layers)]


def _exec_time(flops: float, bytes_: float) -> float:
    return max(flops / PEAK_FLOPS, bytes_ / HBM_BW)


def _energy(flops: float, bytes_: float) -> float:
    t = _exec_time(flops, bytes_)
    util = (flops / PEAK_FLOPS) / max(t, 1e-30)
    return t * (P_STATIC_W + P_DYN_W * util)


def decode_token_energy(cfg: ModelConfig, ctx_len: int,
                        exit_layer) -> np.ndarray:
    """Energy (J) per token given its exit layer (1-indexed #layers used).

    ``exit_layer`` may be an int or an array. Skipped layers pay only the
    K/V-propagation cost; the LM head is always paid once.
    """
    costs = stack_costs(cfg, ctx_len)
    h_fl, h_by = head_cost(cfg)
    exit_layer = np.asarray(exit_layer)
    cum_fl = np.cumsum([c.flops for c in costs])
    cum_by = np.cumsum([c.bytes for c in costs])
    tot_kv_fl = np.cumsum([c.kv_flops for c in costs])
    tot_kv_by = np.cumsum([c.kv_bytes for c in costs])
    N = cfg.num_layers
    el = np.clip(exit_layer, 1, N)
    used_fl = cum_fl[el - 1] + (tot_kv_fl[N - 1] - tot_kv_fl[el - 1])
    used_by = cum_by[el - 1] + (tot_kv_by[N - 1] - tot_kv_by[el - 1])
    vec = np.vectorize(lambda f, b: _energy(f + h_fl, b + h_by))
    return vec(used_fl, used_by)


def full_token_energy(cfg: ModelConfig, ctx_len: int) -> float:
    return float(decode_token_energy(cfg, ctx_len, cfg.num_layers))


def draft_token_energy(cfg: ModelConfig, ctx_len: int,
                       draft_layer: int) -> float:
    """Energy (J) of one self-speculative *draft* step.

    The draft pass is the early-exit pass frozen at ``draft_layer``
    (1-indexed layers used): shallow layers run in full, deeper layers pay
    only K/V propagation, and the shared LM head is read once as the exit
    head — exactly :func:`decode_token_energy` at the draft boundary.
    """
    return float(decode_token_energy(cfg, ctx_len, draft_layer))


def verify_window_energy(cfg: ModelConfig, ctx_len: int, S: int) -> float:
    """Energy (J) of ONE full-depth pass scoring an S-token window.

    This is where speculation wins: decode is bandwidth-bound, and the
    verify pass streams each layer's weights and the KV cache **once** for
    all S queries (the window kernel DMAs every cache block a single time
    — kernels/verify_attn.py). So FLOPs and per-token cache *writes* scale
    with S while the dominant weight/cache-read traffic is paid once;
    at decode batch sizes the roofline stays bytes-bound and verifying
    S positions costs barely more than one step.
    """
    costs = stack_costs(cfg, ctx_len)
    h_fl, h_by = head_cost(cfg)
    fl = S * (sum(c.flops for c in costs) + h_fl)
    per_tok_write = sum(c.kv_bytes for c in costs)
    by = sum(c.bytes for c in costs) + h_by + (S - 1) * per_tok_write
    return _energy(fl, by)


def prefill_chunk_energy(cfg: ModelConfig, ctx_len: int,
                         n_tokens: int) -> float:
    """Modeled J of one ``n_tokens``-position prefill chunk at context
    ``ctx_len`` (the chunk's end position).

    A chunk is a fused full-depth pass: per-position FLOPs scale with the
    chunk length while each layer's weights and the attended cache stream
    once — the same roofline shape as the speculative verify window
    (:func:`verify_window_energy`). The serving scheduler charges one of
    these per admitted chunk, so fleet accounting sees prompt-ingestion
    joules per request instead of silently attributing prefill to the
    first decode token.
    """
    return verify_window_energy(cfg, ctx_len, n_tokens)


def speculative_step_energy(cfg: ModelConfig, ctx_len: int,
                            draft_layer: int, n_draft: int,
                            n_verify: int) -> dict:
    """Modeled J of one draft-then-verify super-step at ~``ctx_len``.

    ``n_draft`` sequential shallow draft steps are charged at the draft
    boundary; the ``n_verify``-position window is charged as one fused
    full-depth pass (:func:`verify_window_energy`). Keeping the two terms
    separate is what lets the scheduler report where the joules went: a
    high acceptance rate amortizes the verify pass over many emitted
    tokens, a low one pays it for a single correction.
    """
    e_draft = draft_token_energy(cfg, ctx_len, draft_layer) * n_draft
    e_verify = verify_window_energy(cfg, ctx_len, n_verify)
    return {"draft_j": e_draft, "verify_j": e_verify,
            "total_j": e_draft + e_verify}


def controller_overhead_energy(cfg: ModelConfig, n_checks,
                               hidden: int = 64, n_hidden: int = 2,
                               with_head_check: bool = False,
                               ctx_len: int = 1) -> np.ndarray:
    """Energy of the exit controller itself (paper §VI-H overhead analysis).

    Policy MLP: d_model -> hidden^n -> 2 per check; optionally plus a fused
    LM-head confidence check (the expensive part the Pallas kernel targets).
    """
    n_checks = np.asarray(n_checks)
    mlp_fl = 2 * (cfg.d_model * hidden + (n_hidden - 1) * hidden * hidden
                  + hidden * 2)
    mlp_by = (cfg.d_model * hidden + (n_hidden - 1) * hidden * hidden
              + hidden * 2) * 2.0
    fl, by = mlp_fl, mlp_by
    if with_head_check:
        h_fl, h_by = head_cost(cfg)
        fl, by = fl + h_fl, by + h_by
    vec = np.vectorize(lambda n: _energy(n * fl, n * by))
    return vec(n_checks)


def summarize_exit_energy(cfg: ModelConfig, ctx_len: int,
                          exit_layers: np.ndarray) -> dict:
    """Aggregate energy/latency stats for a batch of per-token exit layers."""
    exit_layers = np.asarray(exit_layers).reshape(-1)
    e = decode_token_energy(cfg, ctx_len, exit_layers)
    e_full = full_token_energy(cfg, ctx_len)
    layers_used = exit_layers.mean()
    return {
        "mean_energy_j": float(e.mean()),
        "full_energy_j": float(e_full),
        "energy_saving_frac": float(1.0 - e.mean() / e_full),
        "mean_layers_used": float(layers_used),
        "layers_skipped_frac": float(1.0 - layers_used / cfg.num_layers),
        "n_tokens": int(exit_layers.size),
    }
