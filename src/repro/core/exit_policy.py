"""First-class exit policies: the paper's controllers as *data*, not closures.

GREEN-CODE's contribution is the exit policy (RL agent vs. CALM-style
confidence/entropy baselines, paper §VI-B). The seed encoded each policy as
an opaque ``ControllerFn`` closure whose knobs (threshold, exit index, agent
weights) were baked in at trace time — so the serving scheduler had to
re-implement every policy as an integer switch to serve mixed traffic in one
compiled step. This module is the single implementation both paths share:

``ExitPolicy``
    A registered ``(name, id, param-pytree defaults, apply)`` module.
    ``apply(ctx, h, exit_idx, params) -> decision [B]`` maps the hidden
    state at an exit boundary to a per-token decision in {0., 1.}
    (``decode_step`` treats > 0.5 as exit). ``params`` is a pytree of
    runtime values (scalars or per-row ``[B]`` arrays), so thresholds are
    *arguments of the compiled step*, never trace-time constants.

``PolicySpec``
    The user-facing declarative selection: ``PolicySpec("confidence",
    {"threshold": 0.95})``. Validated eagerly against the registry.

``stack_policies`` / ``select_apply``
    Heterogeneous per-row policies inside ONE jitted step: specs are
    stacked into ``(ids [B], param-pytree of [B] leaves)`` and each row
    gathers its own branch from the stacked branch outputs. This is the
    fixed-shape lowering of a per-row ``lax.switch`` over the stacked param
    pytree (a vmapped switch computes every branch and selects exactly the
    same way, but would break the batch-rank sharding annotations inside
    the head-stat policies, so the gather form is used). Policies outside
    the candidate set never pay their compute cost — the head-stat kinds in
    particular re-project through the LM head per exit point.

Registered kinds (paper §II / §IV / §VI-B):

  * ``none``        never exit (baseline full model)
  * ``fixed``       exit at a fixed exit-point index
  * ``confidence``  top-1 softmax probability of the shared LM head > tau
  * ``entropy``     normalized entropy of the head distribution < tau
  * ``policy``      the paper's RL agent: softmax(pi(h)/temp)[EXIT] > T
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Any, Callable, Mapping, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig
from repro.core import policy_net
from repro.models.layers import apply_norm
from repro.models.transformer import head_matrix

Array = jax.Array

# decode_step's exit-decision callback: (h [B, D], exit_idx) -> [B] | None
ExitFn = Callable[[Array, int], Optional[Array]]


# ---------------------------------------------------------------------------
# Context: everything an apply() may need beyond its own params
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class PolicyContext:
    """Model-side inputs shared by all policies (never per-request).

    ``params``/``cfg`` feed the head-stat policies, ``agent_params`` the RL
    policy. Request-side knobs (threshold, exit index, ...) travel in the
    policy's own param pytree instead, so they stay runtime data.
    """
    params: Any = None
    cfg: Optional[ModelConfig] = None
    agent_params: Any = None
    use_kernel: bool = False

    def with_params(self, params) -> "PolicyContext":
        return replace(self, params=params)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------
PolicyApplyFn = Callable[[PolicyContext, Array, int, Mapping[str, Array]],
                         Array]


@dataclass(frozen=True)
class ExitPolicy:
    """A registered exit policy: identity + param schema + pure apply fn."""
    name: str
    id: int
    defaults: Mapping[str, float]       # param field -> default value
    apply: PolicyApplyFn
    requires: tuple[str, ...] = ()      # PolicyContext fields that must be set
    doc: str = ""


_REGISTRY: dict[str, ExitPolicy] = {}
_BY_ID: dict[int, ExitPolicy] = {}


def register(name: str, policy_id: int, *,
             defaults: Optional[Mapping[str, float]] = None,
             requires: Sequence[str] = ()):
    """Decorator: register ``fn(ctx, h, exit_idx, params) -> [B]``."""

    def deco(fn: PolicyApplyFn) -> PolicyApplyFn:
        if name in _REGISTRY:
            raise ValueError(f"exit policy {name!r} already registered")
        if policy_id in _BY_ID:
            raise ValueError(
                f"exit policy id {policy_id} already taken by "
                f"{_BY_ID[policy_id].name!r}")
        pol = ExitPolicy(name=name, id=policy_id,
                         defaults=dict(defaults or {}), apply=fn,
                         requires=tuple(requires), doc=fn.__doc__ or "")
        _REGISTRY[name] = pol
        _BY_ID[policy_id] = pol
        return fn

    return deco


def get(name: str) -> ExitPolicy:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(f"unknown exit policy {name!r}; registered: "
                         f"{sorted(_REGISTRY)}") from None


def names() -> tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def param_fields() -> tuple[str, ...]:
    """Union of all registered policies' param fields (stable order)."""
    out: dict[str, None] = {}
    for name in sorted(_REGISTRY):
        for f in _REGISTRY[name].defaults:
            out.setdefault(f)
    return tuple(out)


def field_default(fld: str) -> float:
    """Fill value for rows whose policy does not use ``fld``."""
    for name in sorted(_REGISTRY):
        if fld in _REGISTRY[name].defaults:
            return float(_REGISTRY[name].defaults[fld])
    raise KeyError(fld)


def validate_context(policy: ExitPolicy, ctx: PolicyContext) -> None:
    """Eager, readable failure instead of a mid-trace tracer error."""
    missing = [r for r in policy.requires if getattr(ctx, r) is None]
    if missing:
        hints = {"params": "the model parameter pytree",
                 "cfg": "the ModelConfig",
                 "agent_params": "the trained RL agent parameters"}
        need = ", ".join(f"{m} ({hints.get(m, m)})" for m in missing)
        raise TypeError(f"exit policy {policy.name!r} requires {need} — "
                        f"pass it via PolicyContext / the *_params kwargs")


# ---------------------------------------------------------------------------
# User-facing declarative spec
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class PolicySpec:
    """Declarative exit-policy selection: a name + runtime param overrides.

    ``PolicySpec("policy", {"threshold": 0.92})`` — validated eagerly, turned
    into arrays at the jit boundary. This replaces the seed's
    ``make_controller(...)`` closures as the thing callers hold and ship.
    """
    name: str = "none"
    params: Mapping[str, float] = field(default_factory=dict)

    def __post_init__(self):
        pol = get(self.name)                       # raises on unknown name
        unknown = set(self.params) - set(pol.defaults)
        if unknown:
            raise ValueError(
                f"policy {self.name!r} has no params {sorted(unknown)}; "
                f"accepted: {sorted(pol.defaults)}")
        for k, v in self.params.items():
            float(v)                               # must be a runtime scalar

    def resolved(self) -> dict[str, float]:
        """Defaults overlaid with this spec's overrides."""
        pol = get(self.name)
        out = {k: float(v) for k, v in pol.defaults.items()}
        out.update({k: float(v) for k, v in self.params.items()})
        return out


PolicyLike = Union[None, str, PolicySpec]


def as_spec(policy: PolicyLike) -> PolicySpec:
    if policy is None:
        return PolicySpec("none")
    if isinstance(policy, PolicySpec):
        return policy
    if isinstance(policy, str):
        return PolicySpec(policy)
    raise TypeError(f"expected PolicySpec | str | None, got {policy!r}")


# ---------------------------------------------------------------------------
# Stacking + per-row selection (the scheduler/sweep hot path)
# ---------------------------------------------------------------------------
@dataclass
class PolicyBatch:
    """Per-row exit policies as data: ``ids [B]`` + stacked param pytree.

    ``params`` holds one ``[B]`` float32 leaf per field in
    :func:`param_fields`; rows not using a field carry its global default.
    ``names`` is the *static* candidate set — only these policies are
    compiled into a step consuming this batch.
    """
    ids: Any                      # [B] int32 (numpy or jax)
    params: dict[str, Any]        # field -> [B] float32
    names: tuple[str, ...]

    def __len__(self) -> int:
        return len(self.ids)


def stack_policies(specs: Sequence[PolicyLike]) -> PolicyBatch:
    """Stack heterogeneous per-row specs into runtime arrays."""
    resolved = [as_spec(s) for s in specs]
    if not resolved:
        raise ValueError("stack_policies needs at least one spec")
    fields = param_fields()
    ids = np.asarray([get(s.name).id for s in resolved], np.int32)
    params = {f: np.full(len(resolved), field_default(f), np.float32)
              for f in fields}
    for row, spec in enumerate(resolved):
        for k, v in spec.resolved().items():
            params[k][row] = v
    return PolicyBatch(ids=ids, params=params,
                       names=tuple(sorted({s.name for s in resolved})))


def select_apply(policies: Sequence[ExitPolicy], ctx: PolicyContext,
                 ids: Array, params: Mapping[str, Array]) -> Optional[ExitFn]:
    """One ExitFn serving heterogeneous per-row policies with zero recompiles.

    Every candidate policy (a static set) is evaluated on the whole batch
    and each row gathers its own branch by ``ids`` — the fixed-shape
    equivalent of a per-row ``lax.switch`` over the stacked param pytree.
    ``ids``/``params`` are runtime arrays: new thresholds, temperatures or
    policy mixes never retrace the step. Rows whose id is outside the
    candidate set never exit (the ``none`` semantics the seed scheduler
    gave unknown kinds).
    """
    policies = tuple(policies)
    for pol in policies:
        validate_context(pol, ctx)
    if all(pol.name == "none" for pol in policies):
        return None                      # decode_step skips masking entirely

    lut = np.full(max(_BY_ID) + 2, -1, np.int32)
    for k, pol in enumerate(policies):
        lut[pol.id] = k

    def fn(h: Array, exit_idx: int) -> Array:
        decisions = jnp.stack(
            [pol.apply(ctx, h, exit_idx, params) for pol in policies])
        branch = jnp.asarray(lut)[jnp.clip(ids, 0, len(lut) - 1)]
        picked = jnp.take_along_axis(
            decisions, jnp.maximum(branch, 0)[None, :], axis=0)[0]
        return jnp.where(branch >= 0, picked, 0.0)

    return fn


def as_exit_fn(policy, ctx: PolicyContext) -> Optional[ExitFn]:
    """Normalize any policy description to ``decode_step``'s callback.

    Accepts ``None`` | a legacy ``ControllerFn`` callable (returned as-is) |
    a name | ``PolicySpec`` | ``PolicyBatch``.
    """
    if policy is None:
        return None
    if callable(policy):
        return policy
    if isinstance(policy, PolicyBatch):
        pols = tuple(get(n) for n in policy.names)
        return select_apply(
            pols, ctx, jnp.asarray(policy.ids, jnp.int32),
            {k: jnp.asarray(v, jnp.float32)
             for k, v in policy.params.items()})
    spec = as_spec(policy)
    if spec.name == "none":
        return None
    pol = get(spec.name)
    validate_context(pol, ctx)
    params = {k: jnp.float32(v) for k, v in spec.resolved().items()}
    return lambda h, i: pol.apply(ctx, h, i, params)


# ---------------------------------------------------------------------------
# Shared head statistics (confidence/entropy baselines)
# ---------------------------------------------------------------------------
def head_stats(params, cfg: ModelConfig, h: Array, use_kernel: bool):
    """(top1_prob, normalized_entropy) of the shared LM head on h [B, D]."""
    if use_kernel:
        from repro.kernels.ops import exit_check
        hn = apply_norm(params["final_norm"], h)
        top1, lse, ent = exit_check(hn, head_matrix(params, cfg),
                                    cfg.final_logit_softcap)
        p1 = jnp.exp(top1 - lse)
        ent_n = ent / jnp.log(cfg.vocab_size)
        return p1, ent_n
    from repro.models.transformer import lm_logits
    logits = lm_logits(params, cfg, h[:, None, :])[:, 0, :]
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    p = jnp.exp(logp)
    p1 = p.max(axis=-1)
    ent = -(p * logp).sum(axis=-1) / jnp.log(cfg.vocab_size)
    return p1, ent


# ---------------------------------------------------------------------------
# The registered policies
# ---------------------------------------------------------------------------
def _rows(h: Array, x: Array) -> Array:
    """Broadcast a decision to [B] float32 (params may be scalars)."""
    return jnp.broadcast_to(x.astype(jnp.float32), (h.shape[0],))


@register("none", 0)
def _none(ctx, h, exit_idx, p):
    """Never exit — the full-depth baseline."""
    return jnp.zeros((h.shape[0],), jnp.float32)


@register("policy", 1, defaults={"threshold": 0.9, "temperature": 1.0},
          requires=("agent_params",))
def _policy(ctx, h, exit_idx, p):
    """The paper's RL agent: softmax(pi(h)/temp)[EXIT] > threshold."""
    logits = policy_net.policy_logits(ctx.agent_params, h)
    temp = jnp.maximum(jnp.asarray(p["temperature"], jnp.float32), 1e-6)
    p_exit = jax.nn.softmax(logits / temp[..., None],
                            axis=-1)[..., policy_net.EXIT]
    return _rows(h, p_exit > p["threshold"])


@register("confidence", 2, defaults={"threshold": 0.9},
          requires=("params", "cfg"))
def _confidence(ctx, h, exit_idx, p):
    """CALM-style score baseline: head top-1 probability > threshold."""
    p1, _ = head_stats(ctx.params, ctx.cfg, h, ctx.use_kernel)
    return _rows(h, p1 > p["threshold"])


@register("entropy", 3, defaults={"threshold": 0.9},
          requires=("params", "cfg"))
def _entropy(ctx, h, exit_idx, p):
    """Normalized head entropy < threshold."""
    _, ent = head_stats(ctx.params, ctx.cfg, h, ctx.use_kernel)
    return _rows(h, ent < p["threshold"])


@register("fixed", 4, defaults={"exit_idx": 0.0})
def _fixed(ctx, h, exit_idx, p):
    """Exit every token at exit point >= ``exit_idx`` (segment index)."""
    return _rows(h, jnp.float32(exit_idx) >= p["exit_idx"])


@register("speculative", 5, defaults={"draft_idx": 0.0, "window": 4.0,
                                      "accept_threshold": 1.0})
def _speculative(ctx, h, exit_idx, p):
    """Self-speculative draft pass: exit at the draft boundary (like
    ``fixed`` at ``draft_idx``). Entry points that understand speculation
    (Scheduler, Engine, core/speculative.py) treat the exited tokens as
    *drafts* and verify up to ``window`` of them full-depth in one batched
    step — greedy output is then bit-identical to the full model. Under a
    plain ``generate`` call the policy degrades to ``fixed`` early exit.
    ``accept_threshold`` loosens greedy acceptance (a draft also passes
    when its full-depth probability reaches the threshold); sampled rows
    always use exact rejection sampling and ignore it."""
    return _rows(h, jnp.float32(exit_idx) >= p["draft_idx"])
