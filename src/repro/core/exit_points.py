"""Exit-point schedule (paper §III-D).

Rules (verbatim from the paper):
  * earliest exit at layer 4 (1-indexed layers);
  * first half of the model: exits on alternating layers (every 2nd);
  * second half: exits every 4th layer;
  * the final layer is always an implicit exit (normal full forward).

For Llama-3.2-3B (28 layers) this yields 9 exit points and for OPT-2.7B
(32 layers) 10 exit points, matching the paper's counts.

``exit_points(cfg)`` returns the *intermediate* exit layers (excluding the
final layer). ``segment_boundaries`` adds the final layer, giving the
boundaries the transformer uses to place scan segments so per-exit hidden
states fall out of the layer scan for free.
"""
from __future__ import annotations

from repro.config import ExitConfig, ModelConfig


def exit_points_for(num_layers: int, ec: ExitConfig) -> tuple[int, ...]:
    """1-indexed intermediate exit layers per the paper's rule."""
    half = num_layers // 2
    pts = list(range(ec.min_exit_layer, half + 1, ec.first_half_stride))
    start = pts[-1] + ec.second_half_stride if pts else ec.min_exit_layer
    pts += list(range(start, num_layers, ec.second_half_stride))
    # final layer is the implicit last exit, not an "early" exit
    return tuple(p for p in pts if p < num_layers)


def exit_points(cfg: ModelConfig) -> tuple[int, ...]:
    return exit_points_for(cfg.num_layers, cfg.exit)


def segment_boundaries(cfg: ModelConfig) -> tuple[int, ...]:
    """Exit layers + the final layer: segment ends for the layer scan."""
    return exit_points(cfg) + (cfg.num_layers,)


def num_exits(cfg: ModelConfig) -> int:
    return len(exit_points(cfg))
