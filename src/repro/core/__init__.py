"""GREEN-CODE core: the paper's contribution.

exit_points  — §III-D exit schedule
lite_loss    — Eq. 1 aggregated fine-tuning loss (single LM head)
exit_policy  — first-class exit-policy registry (§IV / §VI-B controllers)
controller   — DEPRECATED closure shims over exit_policy
early_exit   — dynamic early-exit generation loop + runtime-param sampling
energy       — TPU-adapted analytic energy model (§VI efficiency metrics)
policy_net   — the small actor-critic network (Table III)

Submodules are imported lazily to avoid a cycle with repro.models (the
transformer needs the exit schedule; lite_loss needs the transformer head).
"""
import importlib

__all__ = ["exit_points", "lite_loss", "exit_policy", "controller",
           "early_exit", "energy", "policy_net"]


def __getattr__(name):
    if name in __all__:
        return importlib.import_module(f"repro.core.{name}")
    raise AttributeError(name)
