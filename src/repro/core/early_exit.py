"""Autoregressive generation with dynamic early exit.

``generate`` runs prefill (always full-depth — the paper only exits during
token generation) followed by a ``lax.scan`` over early-exit decode steps.
Per-token exit layers are recorded so the energy model can account savings.

Exit behaviour is described by the first-class policy API
(:mod:`repro.core.exit_policy`): pass ``policy=`` a name / ``PolicySpec`` /
``PolicyBatch`` (heterogeneous per-row policies) — or a legacy controller
callable for backward compatibility. Sampling is runtime-parameterized:
:func:`pick_tokens` takes temperature / top-k / top-p as values or per-row
arrays, so one compiled step serves mixed greedy/sampled traffic.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.core import exit_policy
from repro.models.transformer import decode_step, lm_logits, prefill

Array = jax.Array


# ---------------------------------------------------------------------------
# Token picking (runtime-parameterized)
# ---------------------------------------------------------------------------
def _filtered_logits(logits: Array, t: Array, k: Array, p: Array) -> Array:
    """Temperature-scaled logits with top-k / nucleus filters applied
    (-inf outside the keep set). ``logits`` [B, V] f32; ``t``/``k``/``p``
    per-row arrays. The single implementation behind :func:`pick_tokens`
    and :func:`sampling_probs`, so the distribution a draft was sampled
    from and the one its verifier scores can never drift."""
    B, V = logits.shape
    z = logits / jnp.maximum(t, 1e-6)[:, None]
    z_sorted = jnp.sort(z, axis=-1)[:, ::-1]
    probs = jax.nn.softmax(z_sorted, axis=-1)
    csum = jnp.cumsum(probs, axis=-1)
    # nucleus: smallest prefix whose mass reaches top_p (>= 1 token)
    keep_p = jnp.sum((csum - probs) < p[:, None], axis=-1)
    keep_k = jnp.where(k <= 0, V, jnp.clip(k, 1, V))
    n_keep = jnp.minimum(jnp.maximum(keep_p, 1), keep_k)
    z_min = jnp.take_along_axis(z_sorted, (n_keep - 1)[:, None], axis=-1)
    return jnp.where(z >= z_min, z, -jnp.inf)


def sampling_probs(logits: Array, temperature=0.0, top_k=0,
                   top_p=1.0) -> Array:
    """The exact next-token distribution :func:`pick_tokens` draws from.

    [B, V] probabilities: temperature-scaled, top-k/top-p-filtered softmax;
    greedy rows (``temperature <= 0``) collapse to a one-hot at the argmax.
    Speculative rejection-sampling acceptance (core/speculative.py) scores
    draft and target tokens under this function, which is what makes
    sampled speculative output distribution-identical to the baseline.
    """
    logits = logits.astype(jnp.float32)
    B, V = logits.shape
    t = jnp.broadcast_to(jnp.asarray(temperature, jnp.float32), (B,))
    k = jnp.broadcast_to(jnp.asarray(top_k, jnp.int32), (B,))
    p = jnp.broadcast_to(jnp.asarray(top_p, jnp.float32), (B,))
    probs = jax.nn.softmax(_filtered_logits(logits, t, k, p), axis=-1)
    onehot = jax.nn.one_hot(jnp.argmax(logits, axis=-1), V,
                            dtype=jnp.float32)
    return jnp.where((t <= 0.0)[:, None], onehot, probs)


def chosen_logprob_matrix(logits: Array) -> Array:
    """``log_softmax(logits [B, V])`` pinned into its own XLA fusion region.

    Reported token log-probs are part of the speculative bit-exactness
    contract: the generation loop computes them inside its scan body (fused
    with argmax / sampling machinery) while the verify path computes them
    from materialized window logits — two different programs whose fusion
    context can shift the softmax reduction rounding by 1 ulp on CPU. The
    optimization barriers make the region's clusters identical under every
    caller, so both paths produce the same bits (accept_drafts routes its
    per-position slices through this same function)."""
    z = jax.lax.optimization_barrier(logits.astype(jnp.float32))
    return jax.lax.optimization_barrier(jax.nn.log_softmax(z, axis=-1))


def pick_tokens(logits: Array, key: Array, temperature=0.0, top_k=0,
                top_p=1.0):
    """Pick next tokens from ``logits [B, V]``.

    ``temperature`` / ``top_k`` / ``top_p`` are runtime values — scalars or
    per-row ``[B]`` arrays — never trace-time constants, so heterogeneous
    per-request sampling shares one compiled step. ``key`` is either one
    PRNG key for the batch or per-row keys ``[B, 2]`` (see
    :func:`request_keys`). Per row: ``temperature <= 0`` → greedy argmax
    (key ignored); ``top_k <= 0`` / ``top_p >= 1`` disable the filters.

    Returns ``(token [B], logprob [B])`` where the logprob is always the
    full-precision log-softmax of the chosen token under the *unscaled*
    head distribution.

    When ``temperature`` is a static scalar <= 0 the whole batch is greedy
    and the sort/cumsum/categorical machinery is skipped entirely (the
    seed's argmax-only compute). Runtime arrays can't take that shortcut —
    the scheduler deliberately compiles the general path once so mixed
    greedy/sampled traffic never recompiles.
    """
    logits = logits.astype(jnp.float32)
    B, V = logits.shape

    logp = chosen_logprob_matrix(logits)
    greedy_tok = jnp.argmax(logits, axis=-1)
    if isinstance(temperature, (int, float)) and temperature <= 0:
        return greedy_tok, jnp.take_along_axis(logp, greedy_tok[:, None],
                                               1)[:, 0]

    t = jnp.broadcast_to(jnp.asarray(temperature, jnp.float32), (B,))
    k = jnp.broadcast_to(jnp.asarray(top_k, jnp.int32), (B,))
    p = jnp.broadcast_to(jnp.asarray(top_p, jnp.float32), (B,))
    z_filt = _filtered_logits(logits, t, k, p)

    if key.ndim == 2:                   # per-row keys
        sampled = jax.vmap(jax.random.categorical)(key, z_filt)
    else:
        sampled = jax.random.categorical(key, z_filt, axis=-1)
    tok = jnp.where(t <= 0.0, greedy_tok, sampled)
    return tok, jnp.take_along_axis(logp, tok[:, None], 1)[:, 0]


def request_keys(seeds: Array, steps: Array) -> Array:
    """Per-row PRNG keys ``[B, 2]`` from (request seed, token position).

    A row's draw stream depends only on its own seed and position — never
    on slot index or batch composition — so a sampled request joining the
    scheduler mid-flight reproduces its solo run exactly.
    """
    base = jax.random.PRNGKey(0)

    def one(seed, step):
        return jax.random.fold_in(jax.random.fold_in(base, seed), step)

    return jax.vmap(one)(jnp.asarray(seeds, jnp.int32),
                         jnp.asarray(steps, jnp.int32))


def token_picker(temperature: float = 0.0):
    """Legacy shim: returns pick(logits [B, V], key) -> (token, logprob).

    New code should call :func:`pick_tokens` directly with runtime params.
    """

    def pick(logits, key):
        return pick_tokens(logits, key, temperature)

    return pick


def _sampling_args(sampling, temperature):
    """(temperature, top_k, top_p) from a SamplingParams-like or a float."""
    if sampling is None:
        return temperature, 0, 1.0
    return sampling.temperature, sampling.top_k, sampling.top_p


# ---------------------------------------------------------------------------
# Decode step + generation loop
# ---------------------------------------------------------------------------
def make_decode_fn(cfg: ModelConfig, controller=None, *,
                   temperature: float = 0.0, sampling=None,
                   block_tables=None, use_kernel: bool = False):
    """One-token early-exit decode closure, shared by ``generate`` and the
    serving engine (the scheduler builds its own step with per-slot policy
    and sampling arrays).

    ``controller``: anything :func:`repro.core.exit_policy.as_exit_fn`
    accepts — already bound to a context, or a legacy callable.
    ``block_tables`` [B, nb] switches the step to paged caches (see
    ``models.transformer.decode_step``); ``use_kernel`` then picks the
    Pallas paged-attention kernel over the XLA gather reference.

    signature: fn(params, tokens [B], caches, pos [B], key) ->
               (next_tokens [B], new_caches, exit_layer [B], logprob [B],
                logits [B, V] float32)

    The returned logits let the speculative verify loop replay a token
    window through this very closure (teacher-forced) and run acceptance
    against full-depth scores — one step program shared with the baseline
    loop, so speculative == baseline holds bit-for-bit by construction.
    """
    temp, top_k, top_p = _sampling_args(sampling, temperature)

    def fn(params, tokens, caches, pos, key):
        logits, new_caches, info = decode_step(params, cfg, tokens, caches,
                                               pos, controller,
                                               block_tables=block_tables,
                                               use_kernel=use_kernel)
        nxt, lp = pick_tokens(logits, key, temp, top_k, top_p)
        return (nxt.astype(jnp.int32), new_caches, info["exit_layer"], lp,
                logits.astype(jnp.float32))

    return fn


def generate(params, cfg: ModelConfig, prompt: Array, steps: int,
             controller=None, *, max_len: Optional[int] = None,
             temperature: float = 0.0, key: Optional[Array] = None,
             prefix_embed: Optional[Array] = None, policy=None,
             sampling=None, seeds=None, seed_offsets=None, agent_params=None,
             use_kernel: bool = False, kv_block_size: Optional[int] = None):
    """Greedy (or sampled) generation with dynamic early exit.

    prompt: [B, S0] token ids. Exit behaviour comes from ``policy`` (a
    name / PolicySpec / PolicyBatch resolved against this call's params,
    cfg and ``agent_params``) or a pre-built ``controller`` callable;
    passing both is an error. ``sampling`` (SamplingParams-like) overrides
    the legacy ``temperature`` kwarg; its fields may be per-row arrays.

    ``seeds`` ([B] ints) switches sampling to per-row draw streams keyed
    by (seed, token position) — the scheduler's convention — making each
    row's output independent of batch composition; ``key`` is then
    ignored. ``seed_offsets`` ([B] ints) is subtracted from the position
    before key folding — callers that left-pad prompts to a common length
    (Engine) pass the pad amount so the stream is keyed by the row's *own*
    positions, invariant to co-batched prompt lengths. Default: one shared
    key chain for the batch (seed semantics).

    ``kv_block_size`` switches decode to paged KV storage: the prefill
    ring caches are reshaped into block planes with an identity block
    table (``models.transformer.ring_to_paged``) and every decode step
    reads/writes through the table — the offline mirror of the
    scheduler's ``kv_layout="paged"`` path. With ``use_kernel=True`` the
    Pallas paged-attention kernel replaces the XLA gather reference.

    Returns dict with
      tokens      [B, steps]   generated ids
      exit_layers [B, steps]   layers used per generated token
      logprobs    [B, steps]   chosen-token log-probs (full-precision head)
    """
    if controller is not None and policy is not None:
        raise ValueError("pass either controller= (legacy callable) or "
                         "policy=, not both")
    if policy is not None:
        ctx = exit_policy.PolicyContext(params=params, cfg=cfg,
                                        agent_params=agent_params,
                                        use_kernel=use_kernel)
        controller = exit_policy.as_exit_fn(policy, ctx)

    B, S0 = prompt.shape
    n_prefix = prefix_embed.shape[1] if prefix_embed is not None else 0
    total0 = S0 + n_prefix
    max_len = max(max_len or 0, total0 + steps)
    if kv_block_size:
        max_len += (-max_len) % kv_block_size      # round up to block grid
    if key is None:
        key = jax.random.PRNGKey(0)

    h, caches, _ = prefill(params, cfg, prompt, prefix_embed,
                           max_len=max_len)
    logits0 = lm_logits(params, cfg, h[:, -1:, :])[:, 0]

    tables = None
    if kv_block_size:
        from repro.models.transformer import ring_to_paged
        caches, tables = ring_to_paged(cfg, caches, kv_block_size)
    temp, top_k, top_p = _sampling_args(sampling, temperature)
    decode_fn = make_decode_fn(cfg, controller, temperature=temperature,
                               sampling=sampling, block_tables=tables,
                               use_kernel=use_kernel)

    if seeds is not None:
        seeds = jnp.broadcast_to(jnp.asarray(seeds, jnp.int32), (B,))
        off = (jnp.zeros((B,), jnp.int32) if seed_offsets is None
               else jnp.broadcast_to(jnp.asarray(seed_offsets, jnp.int32),
                                     (B,)))
        k0 = request_keys(seeds,
                          jnp.full((B,), total0 - 1, jnp.int32) - off)
    else:
        key, k0 = jax.random.split(key)
    tok0, lp0 = pick_tokens(logits0, k0, temp, top_k, top_p)
    tok0 = tok0.astype(jnp.int32)

    # A host loop over one jitted step (not lax.scan): the speculative
    # verify replays token windows through the very same step program
    # (``make_decode_fn``), which is what makes speculative == baseline
    # bit-exact. Scanned and standalone compilations of the *same* body
    # can differ by 1 ulp on CPU (fusion context shifts reduction
    # rounding), so the baseline must run the shareable program itself.
    decode_jit = jax.jit(decode_fn)
    toks = [tok0]
    # first generated token comes from full-depth prefill
    exits = [jnp.full((B,), cfg.num_layers, jnp.int32)]
    lps = [lp0]
    keys = jax.random.split(key, steps - 1) if steps > 1 else []
    tok = tok0
    pos = jnp.full((B,), total0, jnp.int32)
    for s in range(steps - 1):
        k = request_keys(seeds, pos - off) if seeds is not None else keys[s]
        tok, caches, exit_layer, lp, _ = decode_jit(params, tok, caches,
                                                    pos, k)
        toks.append(tok)
        exits.append(exit_layer)
        lps.append(lp)
        pos = pos + 1

    return {"tokens": jnp.stack(toks, axis=1),
            "exit_layers": jnp.stack(exits, axis=1),
            "logprobs": jnp.stack(lps, axis=1)}
