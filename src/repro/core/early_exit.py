"""Autoregressive generation with dynamic early exit.

``generate`` runs prefill (always full-depth — the paper only exits during
token generation) followed by a ``lax.scan`` over early-exit decode steps.
Per-token exit layers are recorded so the energy model can account savings.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models.transformer import decode_step, lm_logits, prefill

Array = jax.Array


def token_picker(temperature: float = 0.0):
    """Returns pick(logits [B, V], key) -> (token [B], logprob [B]).

    Greedy when ``temperature <= 0`` (key ignored); the logprob is always the
    full-precision log-softmax of the chosen token.
    """

    def pick(logits, key):
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        if temperature <= 0.0:
            tok = jnp.argmax(logits, axis=-1)
        else:
            tok = jax.random.categorical(key, logits / temperature, axis=-1)
        return tok, jnp.take_along_axis(logp, tok[:, None], 1)[:, 0]

    return pick


def make_decode_fn(cfg: ModelConfig, controller=None, *,
                   temperature: float = 0.0):
    """One-token early-exit decode closure, shared by ``generate``, the
    serving engine and the continuous-batching scheduler.

    signature: fn(params, tokens [B], caches, pos [B], key) ->
               (next_tokens [B], new_caches, exit_layer [B], logprob [B])
    """

    pick = token_picker(temperature)

    def fn(params, tokens, caches, pos, key):
        logits, new_caches, info = decode_step(params, cfg, tokens, caches,
                                               pos, controller)
        nxt, lp = pick(logits, key)
        return (nxt.astype(jnp.int32), new_caches, info["exit_layer"], lp)

    return fn


def generate(params, cfg: ModelConfig, prompt: Array, steps: int,
             controller=None, *, max_len: Optional[int] = None,
             temperature: float = 0.0, key: Optional[Array] = None,
             prefix_embed: Optional[Array] = None):
    """Greedy (or sampled) generation.

    prompt: [B, S0] token ids. Returns dict with
      tokens      [B, steps]   generated ids
      exit_layers [B, steps]   layers used per generated token
      logprobs    [B, steps]   chosen-token log-probs (full-precision head)
    """
    B, S0 = prompt.shape
    n_prefix = prefix_embed.shape[1] if prefix_embed is not None else 0
    total0 = S0 + n_prefix
    max_len = max(max_len or 0, total0 + steps)
    if key is None:
        key = jax.random.PRNGKey(0)

    h, caches, _ = prefill(params, cfg, prompt, prefix_embed,
                           max_len=max_len)
    logits0 = lm_logits(params, cfg, h[:, -1:, :])[:, 0]

    pick = token_picker(temperature)
    decode_fn = make_decode_fn(cfg, controller, temperature=temperature)

    key, k0 = jax.random.split(key)
    tok0, lp0 = pick(logits0, k0)

    def step(carry, k):
        tok, caches, pos = carry
        nxt, caches, exit_layer, lp = decode_fn(params, tok, caches, pos, k)
        return (nxt, caches, pos + 1), (tok, exit_layer, lp)

    if steps > 1:
        keys = jax.random.split(key, steps - 1)
        pos0 = jnp.full((B,), total0, jnp.int32)
        (last_tok, caches, _), (toks, exits, lps) = jax.lax.scan(
            step, (tok0, caches, pos0), keys)
        # scan emitted the *input* token of each step; append the last output
        tokens = jnp.concatenate([toks.T, last_tok[:, None]], axis=1)
        # first generated token comes from full-depth prefill
        exit_layers = jnp.concatenate(
            [jnp.full((B, 1), cfg.num_layers, jnp.int32), exits.T], axis=1)
        logprobs = jnp.concatenate([lp0[:, None], lps.T], axis=1)
    else:
        tokens = tok0[:, None]
        exit_layers = jnp.full((B, 1), cfg.num_layers, jnp.int32)
        logprobs = lp0[:, None]

    return {"tokens": tokens, "exit_layers": exit_layers,
            "logprobs": logprobs}
