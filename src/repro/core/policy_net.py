"""Small actor-critic network (paper Table III: 1-2 hidden layers, 32/64
units). Shared torso, separate policy (2 actions: CONTINUE=0, EXIT=1) and
value heads. Pure functional params, used by both PPO training and the
inference-time controller.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array

CONTINUE, EXIT = 0, 1


def init_policy(key, d_in: int, hidden: tuple[int, ...] = (64, 64)):
    ks = jax.random.split(key, len(hidden) + 2)
    p = {"layers": []}
    prev = d_in
    for i, h in enumerate(hidden):
        w = jax.random.normal(ks[i], (prev, h)) * (2.0 / prev) ** 0.5
        p["layers"].append({"w": w, "b": jnp.zeros((h,))})
        prev = h
    p["pi"] = {"w": jax.random.normal(ks[-2], (prev, 2)) * 0.01,
               "b": jnp.zeros((2,))}
    p["v"] = {"w": jax.random.normal(ks[-1], (prev, 1)) * 1.0,
              "b": jnp.zeros((1,))}
    return p


def _torso(p, x: Array) -> Array:
    h = x.astype(jnp.float32)
    # normalize the hidden state (LLM activations vary wildly in scale)
    h = h / jnp.maximum(jnp.linalg.norm(h, axis=-1, keepdims=True), 1e-6) \
        * jnp.sqrt(h.shape[-1])
    for layer in p["layers"]:
        h = jnp.tanh(h @ layer["w"] + layer["b"])
    return h


def policy_logits(p, x: Array) -> Array:
    h = _torso(p, x)
    return h @ p["pi"]["w"] + p["pi"]["b"]


def value(p, x: Array) -> Array:
    h = _torso(p, x)
    return (h @ p["v"]["w"] + p["v"]["b"])[..., 0]


def policy_value(p, x: Array):
    h = _torso(p, x)
    return h @ p["pi"]["w"] + p["pi"]["b"], (h @ p["v"]["w"] + p["v"]["b"])[..., 0]


def exit_probability(p, x: Array, temperature: float = 1.0) -> Array:
    """Softmax(logits / temp)[EXIT] — the quantity thresholded by T."""
    logits = policy_logits(p, x) / max(temperature, 1e-6)
    return jax.nn.softmax(logits, axis=-1)[..., EXIT]
