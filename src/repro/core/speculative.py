"""Self-speculative decoding: early-exit drafts, full-depth verification.

GREEN-CODE's early exit trades accuracy for energy: a token that leaves at
the draft layer is *emitted* from the draft layer. LayerSkip-style
self-speculation removes that trade-off with the same machinery: the exit
head at a configurable draft boundary *proposes* up to ``k`` tokens (a pass
that is exactly the paper's early-exit decode, frozen at ``draft_idx``),
then one full-depth pass over the ``[B, k+1]`` window re-scores every
proposal (``models.transformer.verify_step``). Accepted drafts are
guaranteed exact:

  * greedy rows accept a draft iff it equals the full model's argmax — the
    emitted sequence is **bit-identical** to non-speculative full-depth
    decoding (the reference verify path runs the very same single-token
    arithmetic, scanned);
  * sampled rows use standard rejection sampling — accept ``d`` with
    probability ``min(1, p_target(d) / p_draft(d))``, resample rejects from
    the normalized residual ``max(p_target - p_draft, 0)`` — so output is
    **distribution-identical** to sampling the full model, with both
    distributions produced by the one shared
    :func:`repro.core.early_exit.sampling_probs` implementation;
  * ``accept_threshold < 1`` optionally loosens greedy acceptance (a draft
    also passes when its full-depth probability reaches the threshold),
    trading exactness for acceptance rate.

Rejected positions roll back: contiguous full-length ring caches invalidate
their ``pos`` entries (``rewind_ring``), paged pools unbind the rejected
block appends (``PagedKVPool.rollback_append``) — K/V garbage stays where
it is, masked exactly like never-written slots. Configs whose cache writes
are destructive (mamba recurrent state, sliding-window ring evictions) use
the snapshot/commit protocol instead: the caches are snapshotted before
drafting, restored before the verify pass, and committed per row afterwards
(``transformer.commit_spec_cache``) from the verify scan's own per-step
state snapshots — so every architecture in the zoo keeps the bit-exactness
guarantee (tests/test_arch_matrix.py pins it per config).

Energy: drafts are charged at the draft boundary, verification at full
depth (``core.energy.speculative_step_energy``); the win is wall-clock and
amortized verify cost, not per-layer skipping. Cf. GREEN-CODE
(arXiv 2501.11006) for the exit-head machinery and the energy-measurement
framing of Stojkovic et al. (arXiv 2403.20306) for why joules per emitted
token is the metric that has to come down.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import MIXER_MAMBA, ModelConfig
from repro.core import energy
from repro.core.early_exit import (_sampling_args, chosen_logprob_matrix,
                                   make_decode_fn, pick_tokens, request_keys,
                                   sampling_probs)
from repro.core.exit_points import segment_boundaries
from repro.models.transformer import (_mamba_cache_parts, commit_spec_cache,
                                      decode_step, lm_logits, prefill,
                                      rewind_ring, ring_to_paged,
                                      spec_needs_cache_snapshot,
                                      speculative_unsupported, verify_step)

Array = jax.Array

SPEC_POLICY = "speculative"

_logp_jit = jax.jit(chosen_logprob_matrix)


def draft_boundary_layer(cfg: ModelConfig, draft_idx) -> int:
    """Layers used by a draft that exits at segment index ``draft_idx``."""
    bounds = segment_boundaries(cfg)
    return bounds[int(np.clip(int(draft_idx), 0, len(bounds) - 1))]


def draft_exit_fn(draft_idx):
    """decode_step controller: every row exits at its own draft boundary.

    ``draft_idx`` may be a scalar or a per-row [B] array of segment
    indices (the same semantics as the ``fixed`` policy's ``exit_idx``).
    """
    di = jnp.asarray(draft_idx, jnp.float32)

    def fn(h, exit_idx):
        return jnp.broadcast_to(
            (jnp.float32(exit_idx) >= di).astype(jnp.float32),
            (h.shape[0],))

    return fn


def _uniform(seed: int, pos: int, salt: int) -> float:
    """Deterministic U(0,1) keyed by (request seed, absolute position).

    Independent of batch composition and slot index — the acceptance
    analogue of :func:`repro.core.early_exit.request_keys`.
    """
    rng = np.random.default_rng([int(seed) & 0x7FFFFFFF, int(pos), salt])
    return float(rng.random())


def _residual_sample(seed: int, pos: int, p_t: np.ndarray,
                     p_d: np.ndarray) -> int:
    """Sample the rejection-sampling residual ``max(p_t - p_d, 0)``."""
    resid = np.clip(p_t - p_d, 0.0, None)
    tot = resid.sum()
    if tot <= 0.0:                       # p_d covers p_t: fall back to p_t
        resid, tot = p_t, p_t.sum()
    rng = np.random.default_rng([int(seed) & 0x7FFFFFFF, int(pos), 2])
    return int(rng.choice(len(resid), p=resid / tot))


def accept_drafts(draft_tokens: np.ndarray, target_logits: np.ndarray, *,
                  windows, temperature=0.0, top_k=0, top_p=1.0, seeds=None,
                  pos0=None, accept_threshold=1.0,
                  draft_logits: Optional[np.ndarray] = None,
                  step_picks=None):
    """Accept/reject a draft window against full-depth verify logits.

    draft_tokens: [B, K] proposals; target_logits: [B, K+1, V] full-depth
    scores (entry j conditions on the window up to and including draft j).
    ``windows`` [B] caps how many drafts each row may accept (rows ignore
    drafts beyond their own window). Greedy rows (``temperature <= 0``)
    accept a draft iff it is the target argmax — or, with
    ``accept_threshold < 1``, iff its target probability reaches the
    threshold. Sampled rows run standard rejection sampling against the
    shared :func:`sampling_probs` distributions (``draft_logits`` [B, K, V]
    required) with draws keyed by (seed, absolute position).

    ``step_picks`` — optional ``(tokens [B, K+1], logprobs [B, K+1])`` from
    replaying the window through the baseline decode-step program
    (``speculative_generate``'s contiguous verify loop). When given, greedy
    rows accept against and emit from these values directly: they carry the
    exact bits the non-speculative loop would produce, so parity does not
    depend on recomputing argmax/log-softmax in a second program.

    Returns ``(n_accept [B], next_token [B], emit_logprobs [B, K+1])`` —
    row b emits ``draft_tokens[b, :n_accept[b]]`` then ``next_token[b]``
    (the correction / bonus token), whose log-probs under the full
    unscaled head sit in ``emit_logprobs[b, :n_accept[b] + 1]``.
    """
    draft_tokens = np.asarray(draft_tokens)
    target_logits = np.asarray(target_logits, np.float32)
    B, K = draft_tokens.shape
    windows = np.broadcast_to(np.asarray(windows, np.int64), (B,))
    temp = np.broadcast_to(np.asarray(temperature, np.float32), (B,))
    thr = np.broadcast_to(np.asarray(accept_threshold, np.float32), (B,))
    seeds = np.broadcast_to(np.asarray(0 if seeds is None else seeds,
                                       np.int64), (B,))
    pos0 = np.broadcast_to(np.asarray(0 if pos0 is None else pos0,
                                      np.int64), (B,))

    # per-position [B, V] slices through the same barrier-isolated
    # log-softmax region the baseline loop uses (chosen_logprob_matrix) —
    # emitted log-probs must match the non-speculative path bit-for-bit
    logp = np.stack(
        [np.asarray(_logp_jit(jnp.asarray(target_logits[:, j])))
         for j in range(K + 1)], axis=1)
    any_sampled = bool((temp > 0).any())
    lenient = bool((thr < 1.0).any())
    if any_sampled:
        V = target_logits.shape[-1]
        flat = sampling_probs(
            jnp.asarray(target_logits).reshape(B * (K + 1), V),
            jnp.repeat(jnp.asarray(temp), K + 1),
            jnp.repeat(jnp.broadcast_to(jnp.asarray(top_k, jnp.int32),
                                        (B,)), K + 1),
            jnp.repeat(jnp.broadcast_to(jnp.asarray(top_p, jnp.float32),
                                        (B,)), K + 1))
        p_t = np.asarray(flat).reshape(B, K + 1, V)
        if K > 0:                       # K == 0: nothing to accept/reject
            if draft_logits is None:
                raise ValueError("sampled rows need draft_logits for "
                                 "rejection sampling")
            flat = sampling_probs(
                jnp.asarray(draft_logits, jnp.float32).reshape(B * K, V),
                jnp.repeat(jnp.asarray(temp), K),
                jnp.repeat(jnp.broadcast_to(jnp.asarray(top_k, jnp.int32),
                                            (B,)), K),
                jnp.repeat(jnp.broadcast_to(jnp.asarray(top_p, jnp.float32),
                                            (B,)), K))
            p_d = np.asarray(flat).reshape(B, K, V)

    step_tok = step_lp = None
    if step_picks is not None:
        step_tok = np.asarray(step_picks[0])
        step_lp = np.asarray(step_picks[1], np.float32)

    n_accept = np.zeros(B, np.int64)
    next_tok = np.zeros(B, np.int64)
    emit_lp = np.zeros((B, K + 1), np.float32)
    for b in range(B):
        w = int(min(windows[b], K))
        n = 0
        forced: Optional[int] = None
        while n < w:
            d = int(draft_tokens[b, n])
            if temp[b] <= 0.0:
                if step_tok is not None:
                    ok = d == int(step_tok[b, n])
                else:
                    ok = d == int(np.argmax(target_logits[b, n]))
                strict = ok
                if not ok and lenient and thr[b] < 1.0:
                    # lenient mode: a near-argmax draft passes on its
                    # full-precision head probability, trading exactness
                    # for acceptance rate
                    ok = bool(np.exp(logp[b, n, d]) >= thr[b])
            else:
                ratio = p_t[b, n, d] / max(float(p_d[b, n, d]), 1e-30)
                ok = _uniform(seeds[b], pos0[b] + n, 1) <= ratio
                if not ok:
                    forced = _residual_sample(seeds[b], pos0[b] + n,
                                              p_t[b, n], p_d[b, n])
            if not ok:
                break
            # a strictly-accepted greedy draft IS the step program's pick:
            # emit the exact log-prob bits the baseline loop would report
            if step_lp is not None and temp[b] <= 0.0 and strict:
                emit_lp[b, n] = step_lp[b, n]
            else:
                emit_lp[b, n] = logp[b, n, d]
            n += 1
        if forced is not None:
            t = forced
        elif temp[b] <= 0.0:
            if step_tok is not None:
                t = int(step_tok[b, n])
                n_accept[b] = n
                next_tok[b] = t
                emit_lp[b, n] = step_lp[b, n]
                continue
            t = int(np.argmax(target_logits[b, n]))
        else:                            # bonus draw from the target dist
            rng = np.random.default_rng([int(seeds[b]) & 0x7FFFFFFF,
                                         int(pos0[b] + n), 3])
            t = int(rng.choice(p_t.shape[-1], p=p_t[b, n]
                               / max(p_t[b, n].sum(), 1e-30)))
        n_accept[b] = n
        next_tok[b] = t
        emit_lp[b, n] = logp[b, n, t]
    return n_accept, next_tok, emit_lp


def speculative_generate(params, cfg: ModelConfig, prompt: Array,
                         steps: int, *, draft_idx=0, window=4,
                         accept_threshold=1.0, sampling=None,
                         temperature: float = 0.0, seeds=None,
                         seed_offsets=None, max_len: Optional[int] = None,
                         kv_block_size: Optional[int] = None,
                         use_kernel: bool = False):
    """Draft-then-verify generation (the offline mirror of the scheduler's
    speculative super-tick; ``Engine.serve`` routes speculative policies
    here).

    prompt: [B, S0] token ids. ``draft_idx`` / ``window`` /
    ``accept_threshold`` are scalars or per-row arrays (rows draft the
    batch-max window; smaller windows just accept fewer). Greedy output is
    bit-identical to ``generate(..., policy=None)``; sampled rows need
    ``seeds`` (defaults to ``arange(B)``) and are distribution-identical
    to the baseline, drawn from a different (deterministic,
    batch-independent) stream.

    Returns the ``generate`` dict (tokens / exit_layers / logprobs —
    emitted tokens are full-depth-verified, so their exit layer is
    ``cfg.num_layers``) plus ``energy_j`` ([B] modeled draft + verify
    joules per row) and speculation stats: ``n_verifies``, ``n_drafted``,
    ``n_accepted``, ``acceptance_rate``, ``tokens_per_verify``.
    """
    reason = speculative_unsupported(cfg)
    if reason is not None:
        raise ValueError(f"speculative decoding unsupported for "
                         f"{cfg.name}: {reason}")
    B, S0 = prompt.shape
    windows = np.broadcast_to(np.asarray(window, np.int64), (B,)).copy()
    if (windows < 1).any():
        raise ValueError("speculative window must be >= 1")
    K = int(windows.max())
    temp, top_k, top_p = _sampling_args(sampling, temperature)
    sampled = bool(np.any(np.asarray(temp, np.float32) > 0))
    if seeds is None:
        seeds = np.arange(B) if sampled else np.zeros(B, np.int64)
    seeds = np.broadcast_to(np.asarray(seeds, np.int64), (B,))
    off = np.broadcast_to(np.asarray(0 if seed_offsets is None
                                     else seed_offsets, np.int64), (B,))

    max_len = max(max_len or 0, S0 + steps + K)
    if kv_block_size:
        max_len += (-max_len) % kv_block_size
    h, caches, _ = prefill(params, cfg, prompt, max_len=max_len)
    logits0 = lm_logits(params, cfg, h[:, -1:, :])[:, 0]
    tables = None
    if kv_block_size:
        caches, tables = ring_to_paged(cfg, caches, kv_block_size)

    k0 = request_keys(jnp.asarray(seeds, jnp.int32),
                      jnp.full((B,), S0 - 1, jnp.int32)
                      - jnp.asarray(off, jnp.int32))
    t0, lp0 = pick_tokens(logits0, k0, temp, top_k, top_p)

    draft_fn = draft_exit_fn(draft_idx)

    def _draft(params, tok, caches, pos, keys):
        logits, new_caches, _ = decode_step(
            params, cfg, tok, caches, pos, draft_fn,
            block_tables=tables, use_kernel=use_kernel)
        nxt, _ = pick_tokens(logits, keys, temp, top_k, top_p)
        return nxt.astype(jnp.int32), new_caches, logits.astype(jnp.float32)

    # snapshot configs (mamba state / sliding-window rings): draft writes
    # are destructive, so the loop snapshots before drafting, restores the
    # snapshot for the verify pass, and commits per row afterwards; the
    # cheap pos-rewind protocol covers everything else
    snapshot = tables is None and spec_needs_cache_snapshot(cfg)
    collect = snapshot and any(s.mixer == MIXER_MAMBA
                               for s in cfg.block_pattern)

    def _verify(params, win, caches, pos0):
        return verify_step(params, cfg, win, caches, pos0,
                           block_tables=tables, use_kernel=use_kernel)

    draft_jit = jax.jit(_draft, donate_argnums=2)
    # contiguous caches: verification replays the window teacher-forced
    # through the SAME full-depth step closure the baseline loop compiles
    # (``generate`` -> make_decode_fn, controller None) — one step program
    # for both paths, so greedy tokens and emitted log-probs agree with
    # non-speculative decoding bit-for-bit by construction rather than by
    # cross-program compile luck. Paged caches keep the fused window scan
    # (strict masking makes rollback trivial there).
    step_jit = jax.jit(make_decode_fn(cfg, None, temperature=temperature,
                                      sampling=sampling))
    verify_jit = jax.jit(_verify, donate_argnums=2)
    rewind_jit = jax.jit(partial(rewind_ring, cfg), donate_argnums=0)
    copy_jit = jax.jit(lambda c: jax.tree.map(jnp.copy, c))
    commit_jit = jax.jit(partial(commit_spec_cache, cfg),
                         donate_argnums=(0, 1))

    pos = np.full(B, S0, np.int64)
    cur = np.asarray(t0, np.int64).copy()
    toks = np.zeros((B, steps), np.int64)
    lps = np.zeros((B, steps), np.float32)
    toks[:, 0] = cur
    lps[:, 0] = np.asarray(lp0)
    produced = np.ones(B, np.int64)
    n_verifies = n_drafted = n_accepted = 0
    # per-row modeled energy: token 0 is a full-depth pick off prefill,
    # every super-step charges K drafts at the row's boundary plus one
    # fused verify pass (core.energy.speculative_step_energy semantics)
    di = np.broadcast_to(np.asarray(draft_idx, np.int64), (B,))
    e_draft_row = np.asarray(
        [energy.draft_token_energy(cfg, S0, draft_boundary_layer(cfg, d))
         for d in di])
    e_verify = energy.verify_window_energy(cfg, S0, K + 1)
    energy_j = np.full(B, energy.full_token_energy(cfg, S0))

    while int(produced.min()) < steps:
        p0 = pos.copy()
        win = np.zeros((B, K + 1), np.int64)
        win[:, 0] = cur
        dlogits = []
        snap = copy_jit(caches) if snapshot else None
        tok = jnp.asarray(cur, jnp.int32)
        for j in range(1, K + 1):
            pj = jnp.asarray(p0 + j - 1, jnp.int32)
            keys = request_keys(jnp.asarray(seeds, jnp.int32),
                                pj - jnp.asarray(off, jnp.int32))
            tok, caches, dl = draft_jit(params, tok, caches, pj, keys)
            win[:, j] = np.asarray(tok)
            if sampled:
                dlogits.append(np.asarray(dl))
        if snapshot:
            # draft writes were destructive (mamba state updates, window
            # evictions): verify must start from the pre-draft caches
            caches = copy_jit(snap)
        elif tables is None:
            # the verify pass must see clean slots: the inclusive cache
            # mask plus the explicit self term would double-count a
            # still-valid draft entry at the query's own position
            caches = rewind_jit(caches, jnp.asarray(p0 - 1, jnp.int32))
        state_snaps = picks = None
        if tables is None:
            # teacher-forced replay through the shared baseline step
            tl, parts = [], []
            step_tok = np.zeros((B, K + 1), np.int64)
            step_lp = np.zeros((B, K + 1), np.float32)
            for j in range(K + 1):
                pj = jnp.asarray(p0 + j, jnp.int32)
                kj = request_keys(jnp.asarray(seeds, jnp.int32),
                                  pj - jnp.asarray(off, jnp.int32))
                nxt_j, caches, _, lp_j, lg_j = step_jit(
                    params, jnp.asarray(win[:, j], jnp.int32), caches, pj,
                    kj)
                step_tok[:, j] = np.asarray(nxt_j)
                step_lp[:, j] = np.asarray(lp_j)
                tl.append(np.asarray(lg_j))
                if collect:
                    # mamba state after consuming window position j — the
                    # commit indexes these at each row's acceptance count
                    parts.append(_mamba_cache_parts(cfg, caches))
            tlogits = np.stack(tl, axis=1)
            picks = (step_tok, step_lp)
            if collect:
                state_snaps = jax.tree.map(lambda *xs: jnp.stack(xs),
                                           *parts)
        else:
            tlogits, caches = verify_jit(params, jnp.asarray(win, jnp.int32),
                                         caches, jnp.asarray(p0, jnp.int32))
            tlogits = np.asarray(tlogits)
        live = produced < steps
        eff_w = np.minimum(windows, np.maximum(steps - produced - 1, 0))
        n_acc, nxt, emit_lp = accept_drafts(
            win[:, 1:], tlogits, windows=np.where(live, eff_w, 0),
            temperature=temp, top_k=top_k, top_p=top_p, seeds=seeds,
            # draws are keyed by the row's own (unpadded) positions, like
            # every pick_tokens key above — batch-composition independent
            pos0=p0 - off, accept_threshold=accept_threshold,
            draft_logits=np.stack(dlogits, axis=1) if sampled else None,
            step_picks=picks)
        keep = np.where(live, p0 + n_acc, p0 - 1)
        if snapshot:
            caches = commit_jit(caches, snap, jnp.asarray(keep, jnp.int32),
                                state_snaps,
                                jnp.asarray(n_acc, jnp.int32))
        elif tables is None:
            caches = rewind_jit(caches, jnp.asarray(keep, jnp.int32))
        for b in np.nonzero(live)[0]:
            m = int(n_acc[b]) + 1
            emit = np.concatenate([win[b, 1:1 + n_acc[b]], [nxt[b]]])
            toks[b, produced[b]:produced[b] + m] = emit
            lps[b, produced[b]:produced[b] + m] = emit_lp[b, :m]
            produced[b] += m
            pos[b] = p0[b] + m
            cur[b] = nxt[b]
            energy_j[b] += K * e_draft_row[b] + e_verify
            n_drafted += int(eff_w[b])
            n_accepted += int(n_acc[b])
            n_verifies += 1

    return {
        "tokens": jnp.asarray(toks[:, :steps], jnp.int32),
        "exit_layers": jnp.full((B, steps), cfg.num_layers, jnp.int32),
        "logprobs": jnp.asarray(lps[:, :steps]),
        "energy_j": energy_j,
        "n_verifies": n_verifies,
        "n_drafted": n_drafted,
        "n_accepted": n_accepted,
        "acceptance_rate": n_accepted / max(n_drafted, 1),
        "tokens_per_verify": (int(produced.sum()) - B) / max(n_verifies, 1),
    }
