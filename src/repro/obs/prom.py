"""Prometheus text-exposition rendering of scheduler stats + tracer data.

``render_prometheus(stats, tracer)`` turns the existing
``Scheduler.stats()`` dict into gauge families (numeric scalars only —
strings/lists are skipped; booleans render 0/1; the ``lifetime``
sub-dict gets a ``repro_lifetime_`` prefix) and the tracer's phase
histograms + counters into standard ``histogram``/``counter`` families:

    repro_queue_depth 3
    repro_throughput_tok_s 118.4
    repro_phase_seconds_bucket{phase="decode_step",le="0.002"} 41
    repro_phase_seconds_sum{phase="decode_step"} 0.0712
    repro_phase_seconds_count{phase="decode_step"} 44
    repro_phase_device_wait_seconds_sum{phase="decode_step"} 0.0561
    repro_events_total{event="dispatch"} 97

The output follows the text exposition format version 0.0.4 (one
``# TYPE`` per family, label values escaped) and is what the server's
``GET /metrics`` returns.
"""
from __future__ import annotations

import math
import re
from typing import Optional

PROM_CONTENT_TYPE = "text/plain; version=0.0.4; charset=utf-8"

_NAME_OK = re.compile(r"[^a-zA-Z0-9_]")


def _metric_name(key: str, prefix: str = "repro_") -> str:
    return prefix + _NAME_OK.sub("_", key)


def _fmt(v) -> str:
    v = float(v)
    if math.isnan(v):
        return "NaN"
    if math.isinf(v):
        return "+Inf" if v > 0 else "-Inf"
    return repr(v) if v != int(v) else str(int(v))


def _escape_label(v: str) -> str:
    return v.replace("\\", r"\\").replace('"', r'\"').replace("\n", r"\n")


def _scalar_lines(stats: dict, prefix: str) -> list[str]:
    lines = []
    for key in sorted(stats):
        val = stats[key]
        if isinstance(val, bool):
            val = int(val)
        if isinstance(val, dict):
            if key == "lifetime":
                lines.extend(_scalar_lines(val, prefix + "lifetime_"))
            continue
        if not isinstance(val, (int, float)) or val is None:
            continue        # strings, lists, None: not exposable scalars
        name = _metric_name(key, prefix)
        lines.append(f"# TYPE {name} gauge")
        lines.append(f"{name} {_fmt(val)}")
    return lines


def render_prometheus(stats: dict, tracer=None,
                      prefix: str = "repro_") -> str:
    """Render scheduler stats (+ optional tracer histograms/counters) as
    Prometheus text exposition."""
    lines = _scalar_lines(stats or {}, prefix)

    if tracer is not None:
        hists = tracer.histograms()
        if hists:
            base = prefix + "phase_seconds"
            lines.append(f"# HELP {base} tick-phase wall time (seconds)")
            lines.append(f"# TYPE {base} histogram")
            for phase in sorted(hists):
                h = hists[phase]
                lab = _escape_label(phase)
                for le, cum in h.cumulative():
                    lines.append(
                        f'{base}_bucket{{phase="{lab}",le="{le}"}} {cum}')
                lines.append(f'{base}_sum{{phase="{lab}"}} {_fmt(h.sum)}')
                lines.append(f'{base}_count{{phase="{lab}"}} {h.count}')
            dw = prefix + "phase_device_wait_seconds_sum"
            lines.append(f"# TYPE {dw} gauge")
            for phase in sorted(hists):
                lab = _escape_label(phase)
                lines.append(
                    f'{dw}{{phase="{lab}"}} '
                    f'{_fmt(hists[phase].device_wait_sum)}')
        counters = tracer.counters
        if counters:
            cname = prefix + "events_total"
            lines.append(f"# HELP {cname} tracer event counters "
                         f"(device dispatches, host sync points, ...)")
            lines.append(f"# TYPE {cname} counter")
            for k in sorted(counters):
                lines.append(
                    f'{cname}{{event="{_escape_label(k)}"}} {counters[k]}')
    return "\n".join(lines) + "\n"


_LINE_RE = re.compile(
    r"^[a-zA-Z_:][a-zA-Z0-9_:]*"                      # metric name
    r'(\{[a-zA-Z_][a-zA-Z0-9_]*="(?:[^"\\]|\\.)*"'     # first label
    r'(,[a-zA-Z_][a-zA-Z0-9_]*="(?:[^"\\]|\\.)*")*\})?'  # more labels
    r" (?:[+-]?(?:[0-9.eE+-]+)|NaN|[+-]Inf)$")


def validate_exposition(text: str,
                        required_families: Optional[set] = None) -> dict:
    """Check every non-comment line parses as ``name{labels} value`` and
    (optionally) that required metric families are present. Returns
    ``{"lines": n, "families": {...}}``; raises ValueError on violation.
    """
    families = set()
    n = 0
    for line in text.splitlines():
        if not line.strip():
            continue
        if line.startswith("#"):
            parts = line.split()
            if len(parts) >= 3 and parts[1] in ("TYPE", "HELP"):
                families.add(parts[2])
            continue
        if not _LINE_RE.match(line):
            raise ValueError(f"bad exposition line: {line!r}")
        families.add(line.split("{")[0].split(" ")[0])
        n += 1
    missing = set(required_families or ()) - {
        f for fam in families for f in (fam, fam.rstrip("_"))}
    # histogram child series (_bucket/_sum/_count) count toward the family
    if missing:
        resolved = set()
        for m in missing:
            if any(f.startswith(m) for f in families):
                resolved.add(m)
        missing -= resolved
    if missing:
        raise ValueError(f"missing metric families: {sorted(missing)}")
    return {"lines": n, "families": sorted(families)}


__all__ = ["render_prometheus", "validate_exposition", "PROM_CONTENT_TYPE"]
