"""Prometheus text-exposition rendering of scheduler stats + tracer data.

``render_prometheus(stats, tracer)`` turns the existing
``Scheduler.stats()`` dict into gauge families (numeric scalars only —
strings/lists are skipped; booleans render 0/1; the ``lifetime``
sub-dict gets a ``repro_lifetime_`` prefix) and the tracer's phase
histograms + counters into standard ``histogram``/``counter`` families:

    repro_queue_depth 3
    repro_throughput_tok_s 118.4
    repro_phase_seconds_bucket{phase="decode_step",le="0.002"} 41
    repro_phase_seconds_sum{phase="decode_step"} 0.0712
    repro_phase_seconds_count{phase="decode_step"} 44
    repro_phase_device_wait_seconds_sum{phase="decode_step"} 0.0561
    repro_events_total{event="dispatch"} 97

The output follows the text exposition format version 0.0.4 (one
``# TYPE`` per family, label values escaped) and is what the server's
``GET /metrics`` returns.
"""
from __future__ import annotations

import math
import re
from typing import Optional

PROM_CONTENT_TYPE = "text/plain; version=0.0.4; charset=utf-8"

_NAME_OK = re.compile(r"[^a-zA-Z0-9_]")


def _metric_name(key: str, prefix: str = "repro_") -> str:
    return prefix + _NAME_OK.sub("_", key)


def _fmt(v) -> str:
    v = float(v)
    if math.isnan(v):
        return "NaN"
    if math.isinf(v):
        return "+Inf" if v > 0 else "-Inf"
    return repr(v) if v != int(v) else str(int(v))


def _escape_label(v: str) -> str:
    return v.replace("\\", r"\\").replace('"', r'\"').replace("\n", r"\n")


def _scalar_lines(stats: dict, prefix: str) -> list[str]:
    lines = []
    for key in sorted(stats):
        val = stats[key]
        if isinstance(val, bool):
            val = int(val)
        if isinstance(val, dict):
            if key == "lifetime":
                lines.extend(_scalar_lines(val, prefix + "lifetime_"))
            continue
        if not isinstance(val, (int, float)) or val is None:
            continue        # strings, lists, None: not exposable scalars
        name = _metric_name(key, prefix)
        lines.append(f"# TYPE {name} gauge")
        lines.append(f"{name} {_fmt(val)}")
    return lines


def render_prometheus(stats: dict, tracer=None,
                      prefix: str = "repro_") -> str:
    """Render scheduler stats (+ optional tracer histograms/counters) as
    Prometheus text exposition."""
    lines = _scalar_lines(stats or {}, prefix)

    if tracer is not None:
        hists = tracer.histograms()
        if hists:
            base = prefix + "phase_seconds"
            lines.append(f"# HELP {base} tick-phase wall time (seconds)")
            lines.append(f"# TYPE {base} histogram")
            for phase in sorted(hists):
                h = hists[phase]
                lab = _escape_label(phase)
                for le, cum in h.cumulative():
                    lines.append(
                        f'{base}_bucket{{phase="{lab}",le="{le}"}} {cum}')
                lines.append(f'{base}_sum{{phase="{lab}"}} {_fmt(h.sum)}')
                lines.append(f'{base}_count{{phase="{lab}"}} {h.count}')
            dw = prefix + "phase_device_wait_seconds_sum"
            lines.append(f"# TYPE {dw} gauge")
            for phase in sorted(hists):
                lab = _escape_label(phase)
                lines.append(
                    f'{dw}{{phase="{lab}"}} '
                    f'{_fmt(hists[phase].device_wait_sum)}')
        counters = tracer.counters
        if counters:
            cname = prefix + "events_total"
            lines.append(f"# HELP {cname} tracer event counters "
                         f"(device dispatches, host sync points, ...)")
            lines.append(f"# TYPE {cname} counter")
            for k in sorted(counters):
                lines.append(
                    f'{cname}{{event="{_escape_label(k)}"}} {counters[k]}')
    return "\n".join(lines) + "\n"


def render_fleet_prometheus(fleet_stats: dict, replicas, *,
                            prefix: str = "repro_",
                            placement: Optional[str] = None) -> str:
    """Fleet exposition: unlabeled fleet-aggregate gauges plus one
    ``{replica="N"}``-labeled sample per replica per family.

    ``replicas`` is a sequence of ``(labels, stats, tracer_or_None)``
    triples — ``labels`` is the replica's label dict (typically
    ``{"replica": "0"}``), ``stats`` its ``Scheduler.stats()`` dict, and
    the tracer (when tracing) contributes per-replica phase histograms
    and event counters with the replica labels folded in. The output is
    one well-formed 0.0.4 exposition: exactly one ``# TYPE`` per family,
    no duplicate series (``validate_exposition`` enforces both).
    """
    lines = _scalar_lines(fleet_stats or {}, prefix + "fleet_")
    if placement is not None:
        pname = prefix + "fleet_placement_info"
        lines.append(f"# TYPE {pname} gauge")
        lines.append(f'{pname}{{placement="{_escape_label(placement)}"}} 1')

    def label_block(labels: dict, extra: str = "") -> str:
        parts = [f'{k}="{_escape_label(str(v))}"'
                 for k, v in sorted(labels.items())]
        if extra:
            parts.append(extra)
        return "{" + ",".join(parts) + "}"

    # per-replica scalar families: collect value-per-replica first so each
    # family gets exactly one # TYPE header across the whole fleet
    per_family: dict[str, list[tuple[str, str]]] = {}
    for labels, stats, _ in replicas:
        for key in sorted(stats or {}):
            val = stats[key]
            if isinstance(val, bool):
                val = int(val)
            if isinstance(val, dict) or not isinstance(val, (int, float)):
                continue
            per_family.setdefault(_metric_name(key, prefix), []).append(
                (label_block(labels), _fmt(val)))
    for name in sorted(per_family):
        lines.append(f"# TYPE {name} gauge")
        for block, val in per_family[name]:
            lines.append(f"{name}{block} {val}")

    # per-replica tracer histograms/counters, replica label folded in
    traced = [(labels, tr) for labels, _, tr in replicas if tr is not None]
    hists = [(labels, tr.histograms()) for labels, tr in traced]
    hists = [(labels, h) for labels, h in hists if h]
    if hists:
        base = prefix + "phase_seconds"
        lines.append(f"# HELP {base} tick-phase wall time (seconds)")
        lines.append(f"# TYPE {base} histogram")
        for labels, hh in hists:
            for phase in sorted(hh):
                h = hh[phase]
                lab = label_block(labels,
                                  f'phase="{_escape_label(phase)}"')
                for le, cum in h.cumulative():
                    core = lab[:-1] + f',le="{le}"}}'
                    lines.append(f"{base}_bucket{core} {cum}")
                lines.append(f"{base}_sum{lab} {_fmt(h.sum)}")
                lines.append(f"{base}_count{lab} {h.count}")
        dw = prefix + "phase_device_wait_seconds_sum"
        lines.append(f"# TYPE {dw} gauge")
        for labels, hh in hists:
            for phase in sorted(hh):
                lab = label_block(labels,
                                  f'phase="{_escape_label(phase)}"')
                lines.append(f"{dw}{lab} {_fmt(hh[phase].device_wait_sum)}")
    counters = [(labels, tr.counters) for labels, tr in traced]
    counters = [(labels, c) for labels, c in counters if c]
    if counters:
        cname = prefix + "events_total"
        lines.append(f"# TYPE {cname} counter")
        for labels, ctrs in counters:
            for k in sorted(ctrs):
                lab = label_block(labels,
                                  f'event="{_escape_label(k)}"')
                lines.append(f"{cname}{lab} {ctrs[k]}")
    return "\n".join(lines) + "\n"


_LINE_RE = re.compile(
    r"^([a-zA-Z_:][a-zA-Z0-9_:]*"                      # metric name
    r'(?:\{[a-zA-Z_][a-zA-Z0-9_]*="(?:[^"\\]|\\.)*"'    # first label
    r'(?:,[a-zA-Z_][a-zA-Z0-9_]*="(?:[^"\\]|\\.)*")*\})?)'  # more labels
    r" (?:[+-]?(?:[0-9.eE+-]+)|NaN|[+-]Inf)$")


_LABEL_RE = re.compile(r'([a-zA-Z_][a-zA-Z0-9_]*)="((?:[^"\\]|\\.)*)"')


def _canonical_series(series: str) -> str:
    """Series identity key: metric name + label set with labels sorted
    by name (Prometheus identity ignores label order)."""
    if "{" not in series:
        return series
    name, block = series.split("{", 1)
    labels = sorted(_LABEL_RE.findall(block))
    return name + "{" + ",".join(f'{k}="{v}"' for k, v in labels) + "}"


def validate_exposition(text: str,
                        required_families: Optional[set] = None) -> dict:
    """Check every non-comment line parses as ``name{labels} value``, that
    no series repeats (same name with the same label set twice — the
    collision a per-replica-labeled fleet exposition would produce if
    replica labels were dropped; Prometheus treats it as ingestion
    garbage), and (optionally) that required metric families are present.
    Returns ``{"lines": n, "families": {...}}``; raises ValueError on
    violation.
    """
    families = set()
    seen_series: set[str] = set()
    n = 0
    for line in text.splitlines():
        if not line.strip():
            continue
        if line.startswith("#"):
            parts = line.split()
            if len(parts) >= 3 and parts[1] in ("TYPE", "HELP"):
                families.add(parts[2])
            continue
        m = _LINE_RE.match(line)
        if not m:
            raise ValueError(f"bad exposition line: {line!r}")
        series = _canonical_series(m.group(1))
        if series in seen_series:
            raise ValueError(f"duplicate series: {m.group(1)!r}")
        seen_series.add(series)
        families.add(line.split("{")[0].split(" ")[0])
        n += 1
    missing = set(required_families or ()) - {
        f for fam in families for f in (fam, fam.rstrip("_"))}
    # histogram child series (_bucket/_sum/_count) count toward the family
    if missing:
        resolved = set()
        for m in missing:
            if any(f.startswith(m) for f in families):
                resolved.add(m)
        missing -= resolved
    if missing:
        raise ValueError(f"missing metric families: {sorted(missing)}")
    return {"lines": n, "families": sorted(families)}


__all__ = ["render_prometheus", "render_fleet_prometheus",
           "validate_exposition", "PROM_CONTENT_TYPE"]
