"""Low-overhead span/counter tracer for the serving stack.

The scheduler's decode loop is a hot path: one tick may be a single
sub-millisecond jitted dispatch, so the tracer has to cost nothing when
it is off and very little when it is on.

Design
------
* **Explicit clock.** ``Tracer(clock=...)`` takes any zero-arg callable
  returning seconds. Wall-clock traces use ``time.monotonic`` (the
  default); the virtual-clock admission trace
  (``benchmarks.serving_load.run_admission_trace``) passes a counter so
  two replays of the same workload produce byte-identical span logs —
  which is what lets CI assert trace *structure* instead of racing on
  timings.

* **No-op fast path.** A disabled tracer (``Tracer(enabled=False)``, or
  the shared :data:`NULL_TRACER`) returns one preallocated null context
  manager from ``span()``/``wait()`` and returns immediately from every
  counter method: no allocation, no clock read, no lock. Tier-1 perf is
  unaffected (tests/test_obs.py bounds the overhead).

* **Spans nest per thread.** ``span()`` is a context manager; begin/end
  events are appended in call order, so each thread's event stream is a
  well-formed bracket sequence ("every B has an E"). The per-thread open
  span also accumulates **device wait**: ``wait()`` wraps a
  ``block_until_ready``/host-fetch region, times it, counts it as one
  ``sync_points`` counter tick, and attributes the time to the innermost
  open span — every span's end event carries
  ``{"device_wait_s", "host_s"}`` so a phase's wall time splits into
  "waiting for the device" vs "Python bookkeeping".

* **Counters and histograms.** ``count(name)`` bumps a cumulative
  counter (the scheduler counts ``dispatch`` per jitted call and
  ``sync_points`` per host sync). Every finished span feeds a per-name
  duration histogram (log-spaced second buckets) that
  :mod:`repro.obs.prom` renders as Prometheus histogram families and
  ``phase_summary()`` aggregates for benchmark reports.

Events are stored in Chrome trace-event form (``ph`` B/E/C/b/e/i, ``ts``
in microseconds) and handed out by ``drain()``;
:mod:`repro.obs.chrome_trace` wraps them into a Perfetto-loadable file.
"""
from __future__ import annotations

import threading
import time
from typing import Callable

# log-spaced duration buckets (seconds): 10µs .. 10s
DEFAULT_BUCKETS = (1e-5, 2e-5, 5e-5, 1e-4, 2e-4, 5e-4, 1e-3, 2e-3, 5e-3,
                   1e-2, 2e-2, 5e-2, 1e-1, 2e-1, 5e-1, 1.0, 2.0, 5.0, 10.0)


class Histogram:
    """Fixed-bucket histogram (Prometheus ``le`` semantics: cumulative
    counts per upper bound, plus ``sum``/``count`` and a parallel
    device-wait sum so phase time splits survive aggregation)."""

    __slots__ = ("buckets", "counts", "sum", "count", "device_wait_sum")

    def __init__(self, buckets=DEFAULT_BUCKETS):
        self.buckets = tuple(buckets)
        self.counts = [0] * (len(self.buckets) + 1)   # +inf tail
        self.sum = 0.0
        self.count = 0
        self.device_wait_sum = 0.0

    def observe(self, value: float, device_wait: float = 0.0) -> None:
        i = 0
        for i, b in enumerate(self.buckets):      # noqa: B007
            if value <= b:
                break
        else:
            i = len(self.buckets)
        self.counts[i] += 1
        self.sum += value
        self.count += 1
        self.device_wait_sum += device_wait

    def cumulative(self) -> list[tuple[str, int]]:
        """[(le_label, cumulative_count), ...] ending with ("+Inf", n)."""
        out = []
        acc = 0
        for b, c in zip(self.buckets, self.counts):
            acc += c
            out.append((repr(b), acc))
        out.append(("+Inf", acc + self.counts[-1]))
        return out


class _NullCtx:
    """Shared do-nothing context manager: the disabled tracer's span."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def set(self, **args):      # parity with _SpanCtx
        return self


_NULL_CTX = _NullCtx()


class _SpanCtx:
    """One open span. Created per ``span()`` call on the enabled path."""

    __slots__ = ("tracer", "name", "cat", "args", "t0", "device_wait",
                 "_tid")

    def __init__(self, tracer: "Tracer", name: str, cat: str, args):
        self.tracer = tracer
        self.name = name
        self.cat = cat
        self.args = args
        self.device_wait = 0.0
        self.t0 = 0.0
        self._tid = 0

    def set(self, **args):
        """Attach args to the span's end event (merged in the viewer)."""
        if self.args:
            self.args.update(args)
        else:
            self.args = args
        return self

    def __enter__(self):
        tr = self.tracer
        self._tid = tr._tid()
        self.t0 = tr._clock()
        ev = {"ph": "B", "ts": self.t0 * 1e6, "tid": self._tid,
              "name": self.name, "cat": self.cat}
        if self.args:
            ev["args"] = dict(self.args)
        tr._emit(ev)
        tr._stack().append(self)
        return self

    def __exit__(self, *exc):
        tr = self.tracer
        t1 = tr._clock()
        stack = tr._stack()
        if stack and stack[-1] is self:
            stack.pop()
        dur = max(t1 - self.t0, 0.0)
        host = max(dur - self.device_wait, 0.0)
        ev = {"ph": "E", "ts": t1 * 1e6, "tid": self._tid,
              "name": self.name, "cat": self.cat,
              "args": {"device_wait_s": self.device_wait, "host_s": host}}
        tr._emit(ev)
        with tr._lock:
            h = tr._hists.get(self.name)
            if h is None:
                h = tr._hists[self.name] = Histogram()
            h.observe(dur, self.device_wait)
        return False


class _WaitCtx:
    """Times a device-sync region (``block_until_ready`` / host fetch),
    attributes the elapsed time to the innermost open span, and counts
    one ``sync_points`` tick."""

    __slots__ = ("tracer", "t0")

    def __init__(self, tracer: "Tracer"):
        self.tracer = tracer
        self.t0 = 0.0

    def __enter__(self):
        self.t0 = self.tracer._clock()
        return self

    def __exit__(self, *exc):
        tr = self.tracer
        dt = max(tr._clock() - self.t0, 0.0)
        stack = tr._stack()
        if stack:
            stack[-1].device_wait += dt
        with tr._lock:
            tr._counters["sync_points"] = (
                tr._counters.get("sync_points", 0) + 1)
            tr._wait_total += dt
        return False


class Tracer:
    """Span/counter collector with an explicit clock and a no-op path.

    Thread-safe: the scheduler's decode thread, submitting threads and
    HTTP handler threads may all write concurrently; ``drain()`` swaps
    the event list under a lock.
    """

    def __init__(self, enabled: bool = True,
                 clock: Callable[[], float] = time.monotonic,
                 max_events: int = 200_000):
        self.enabled = enabled
        self._clock = clock
        self.max_events = max_events
        self._events: list[dict] = []
        self._dropped = 0
        self._counters: dict[str, int] = {}
        self._hists: dict[str, Histogram] = {}
        self._wait_total = 0.0
        self._lock = threading.Lock()
        self._local = threading.local()
        self._tids: dict[int, int] = {}

    # -- internals ----------------------------------------------------------
    def _stack(self) -> list:
        st = getattr(self._local, "stack", None)
        if st is None:
            st = self._local.stack = []
        return st

    def _tid(self) -> int:
        """Stable small thread id (first-seen order) — deterministic for
        single-threaded virtual-clock traces."""
        ident = threading.get_ident()
        tid = self._tids.get(ident)
        if tid is None:
            with self._lock:
                tid = self._tids.setdefault(ident, len(self._tids))
        return tid

    def _emit(self, ev: dict) -> None:
        if len(self._events) >= self.max_events:
            self._dropped += 1
            return
        self._events.append(ev)     # list.append is GIL-atomic

    # -- spans --------------------------------------------------------------
    def span(self, name: str, cat: str = "phase", **args):
        """Context manager timing a named phase. Nesting follows Python
        ``with`` nesting per thread."""
        if not self.enabled:
            return _NULL_CTX
        return _SpanCtx(self, name, cat, args or None)

    def wait(self):
        """Context manager around a device sync point — see _WaitCtx."""
        if not self.enabled:
            return _NULL_CTX
        return _WaitCtx(self)

    def instant(self, name: str, cat: str = "mark", **args) -> None:
        if not self.enabled:
            return
        ev = {"ph": "i", "ts": self._clock() * 1e6, "tid": self._tid(),
              "name": name, "cat": cat, "s": "t"}
        if args:
            ev["args"] = args
        self._emit(ev)

    # -- async (cross-tick) spans: per-request lifecycle --------------------
    def async_begin(self, name: str, span_id, cat: str = "request",
                    **args) -> None:
        if not self.enabled:
            return
        ev = {"ph": "b", "ts": self._clock() * 1e6, "tid": self._tid(),
              "id": int(span_id), "name": name, "cat": cat}
        if args:
            ev["args"] = args
        self._emit(ev)

    def async_end(self, name: str, span_id, cat: str = "request",
                  **args) -> None:
        if not self.enabled:
            return
        ev = {"ph": "e", "ts": self._clock() * 1e6, "tid": self._tid(),
              "id": int(span_id), "name": name, "cat": cat}
        if args:
            ev["args"] = args
        self._emit(ev)

    # -- counters -----------------------------------------------------------
    def count(self, name: str, inc: int = 1) -> None:
        if not self.enabled:
            return
        with self._lock:
            self._counters[name] = self._counters.get(name, 0) + inc

    @property
    def counters(self) -> dict:
        with self._lock:
            return dict(self._counters)

    @property
    def dropped_events(self) -> int:
        return self._dropped

    # -- export -------------------------------------------------------------
    def drain(self) -> list[dict]:
        """Return all events collected since the last drain and clear the
        buffer (counters/histograms are cumulative and are NOT cleared)."""
        with self._lock:
            evs, self._events = self._events, []
        return evs

    def histograms(self) -> dict[str, Histogram]:
        with self._lock:
            return dict(self._hists)

    def phase_summary(self) -> dict[str, dict]:
        """Per-phase aggregate: ``{name: {count, total_s, device_wait_s,
        host_s, mean_s}}`` — the benchmark's phase-time breakdown."""
        out = {}
        with self._lock:
            for name, h in self._hists.items():
                host = max(h.sum - h.device_wait_sum, 0.0)
                out[name] = {
                    "count": h.count,
                    "total_s": h.sum,
                    "device_wait_s": h.device_wait_sum,
                    "host_s": host,
                    "mean_s": h.sum / max(h.count, 1),
                }
        return out


def summarize_spans(events: list) -> dict[str, dict]:
    """``phase_summary()``-shaped aggregate over a drained event list.

    Every span end (``E``) event carries ``{device_wait_s, host_s}`` whose
    sum is the span's duration, so a summary can be computed over any
    *window* of events — e.g. the timed run only, after draining warmup
    spans away — where the tracer's cumulative histograms cannot.
    """
    out: dict[str, dict] = {}
    for ev in events:
        if ev.get("ph") != "E":
            continue
        a = ev.get("args") or {}
        dw = float(a.get("device_wait_s", 0.0))
        host = float(a.get("host_s", 0.0))
        d = out.setdefault(ev.get("name"),
                           {"count": 0, "total_s": 0.0,
                            "device_wait_s": 0.0, "host_s": 0.0})
        d["count"] += 1
        d["total_s"] += dw + host
        d["device_wait_s"] += dw
        d["host_s"] += host
    for d in out.values():
        d["mean_s"] = d["total_s"] / max(d["count"], 1)
    return out


#: Shared disabled tracer: the scheduler's default. Retains nothing, so
#: sharing one instance across schedulers is safe.
NULL_TRACER = Tracer(enabled=False)


def make_step_clock(step_s: float = 1e-6) -> Callable[[], float]:
    """A deterministic clock: each call advances by ``step_s``. Used by
    virtual-clock traces so span logs are pure functions of the workload."""
    state = {"t": 0.0}

    def clock() -> float:
        state["t"] += step_s
        return state["t"]

    return clock


__all__ = ["Tracer", "Histogram", "NULL_TRACER", "DEFAULT_BUCKETS",
           "make_step_clock", "summarize_spans"]
