"""Serving observability: span/counter tracing, Chrome-trace and
Prometheus exporters.

    from repro.obs import Tracer
    tracer = Tracer()
    sched = Scheduler(params, cfg, tracer=tracer, ...)
    ...
    write_chrome_trace("trace.json", tracer.drain())   # open in Perfetto
    print(render_prometheus(sched.stats(), tracer))    # /metrics body

See docs/observability.md for the phase glossary and scrape examples.
"""
from repro.obs.chrome_trace import (TraceValidationError,  # noqa: F401
                                    to_chrome_trace, validate_chrome_trace,
                                    write_chrome_trace)
from repro.obs.prom import (PROM_CONTENT_TYPE, render_prometheus,  # noqa
                            render_fleet_prometheus, validate_exposition)
from repro.obs.trace import (DEFAULT_BUCKETS, NULL_TRACER,  # noqa: F401
                             Histogram, Tracer, make_step_clock,
                             summarize_spans)
