"""Chrome trace-event JSON export + structural validation.

The tracer (:mod:`repro.obs.trace`) already stores events in Chrome
trace-event form (``ph``/``ts``/``tid``/``name``); this module wraps them
into the JSON object format that Perfetto / ``chrome://tracing`` load
directly, and validates the structure CI gates on:

* every sync begin (``B``) has a matching end (``E``) on the same thread,
  in proper bracket order;
* timestamps are non-negative and non-decreasing per thread;
* phase spans nest under ``tick`` spans (the scheduler contract: a
  ``cat="phase"`` span only opens while a ``cat="tick"`` span is open on
  the same thread).

``validate_chrome_trace`` raises :class:`TraceValidationError` with the
first violation; tests and the CI fast job call it on real drained
traces.
"""
from __future__ import annotations

import json
from typing import Union

#: phases may also appear outside a tick (e.g. drain-time retirement);
#: the validator treats these categories as tick-scoped when inside one.
TICK_CAT = "tick"
PHASE_CAT = "phase"


class TraceValidationError(AssertionError):
    pass


def to_chrome_trace(events: list[dict], pid: int = 1,
                    process_name: str = "repro-serving") -> dict:
    """Wrap drained tracer events into a Perfetto-loadable trace object."""
    out = [{"ph": "M", "pid": pid, "tid": 0, "name": "process_name",
            "args": {"name": process_name}}]
    for ev in events:
        e = dict(ev)
        e.setdefault("pid", pid)
        out.append(e)
    return {"traceEvents": out, "displayTimeUnit": "ms"}


def write_chrome_trace(path: str, events: list[dict], **kw) -> dict:
    obj = to_chrome_trace(events, **kw)
    with open(path, "w") as f:
        json.dump(obj, f)
    return obj


def validate_chrome_trace(trace: Union[dict, list],
                          require_tick_nesting: bool = True,
                          allow_partial: bool = False) -> dict:
    """Structurally validate a trace; returns summary stats.

    Accepts either the ``{"traceEvents": [...]}`` object or a bare event
    list (e.g. straight from ``Tracer.drain()``).

    ``allow_partial`` tolerates *window-boundary* partial spans — a
    drained window of a live scheduler can start after a span's ``B``
    (its orphan ``E`` is skipped) and end before a span's ``E`` (its
    open ``B`` is reported, not raised). Mid-window corruption (an ``E``
    that mismatches the open ``B``) still raises. Within-window async
    ends with no begin are likewise tolerated only in partial mode.
    The summary gains ``partial_begins`` / ``partial_ends`` counts.
    """
    events = trace["traceEvents"] if isinstance(trace, dict) else trace
    stacks: dict[int, list[dict]] = {}
    last_ts: dict[int, float] = {}
    names = set()
    anchored: set = set()       # tids with an in-window tick B
    n_spans = 0
    partial_ends = 0
    async_open: dict[tuple, int] = {}
    for i, ev in enumerate(events):
        ph = ev.get("ph")
        if ph == "M":
            continue
        tid = ev.get("tid", 0)
        ts = ev.get("ts")
        if ts is None or ts < 0:
            raise TraceValidationError(f"event {i}: bad ts {ts!r}")
        if ph in ("B", "E", "i"):
            if ts < last_ts.get(tid, 0.0) - 1e-9:
                raise TraceValidationError(
                    f"event {i}: ts went backwards on tid {tid} "
                    f"({ts} < {last_ts[tid]})")
            last_ts[tid] = ts
        if ph == "B":
            stack = stacks.setdefault(tid, [])
            if ev.get("cat") == TICK_CAT:
                anchored.add(tid)
            if (require_tick_nesting and ev.get("cat") == PHASE_CAT
                    and not any(e.get("cat") == TICK_CAT for e in stack)):
                # in partial mode the enclosing tick's B may predate the
                # window cut — only enforce nesting once an in-window
                # tick B has anchored this tid
                if not allow_partial or tid in anchored:
                    raise TraceValidationError(
                        f"event {i}: phase span {ev.get('name')!r} opened "
                        f"outside a tick span on tid {tid}")
            stack.append(ev)
            names.add(ev.get("name"))
        elif ph == "E":
            stack = stacks.setdefault(tid, [])
            if not stack:
                if allow_partial:
                    partial_ends += 1     # B was before the window cut
                    continue
                raise TraceValidationError(
                    f"event {i}: E {ev.get('name')!r} with no open B on "
                    f"tid {tid}")
            top = stack.pop()
            if top.get("name") != ev.get("name"):
                raise TraceValidationError(
                    f"event {i}: E {ev.get('name')!r} does not match open "
                    f"B {top.get('name')!r} on tid {tid}")
            n_spans += 1
        elif ph == "b":
            key = (ev.get("cat"), ev.get("name"), ev.get("id"))
            async_open[key] = async_open.get(key, 0) + 1
        elif ph == "e":
            key = (ev.get("cat"), ev.get("name"), ev.get("id"))
            if async_open.get(key, 0) < 1 and not allow_partial:
                raise TraceValidationError(
                    f"event {i}: async end {key!r} with no open begin")
            async_open[key] = max(async_open.get(key, 0) - 1, 0)
        elif ph in ("i", "C", "M"):
            pass
        else:
            raise TraceValidationError(f"event {i}: unknown ph {ph!r}")
    partial_begins = sum(len(s) for s in stacks.values())
    if partial_begins and not allow_partial:
        bad = {t: [e.get("name") for e in s]
               for t, s in stacks.items() if s}
        raise TraceValidationError(f"unclosed B spans: {bad}")
    return {"events": len(events), "spans": n_spans,
            "span_names": sorted(n for n in names if n),
            "threads": sorted(last_ts),
            "partial_begins": partial_begins,
            "partial_ends": partial_ends}


__all__ = ["to_chrome_trace", "write_chrome_trace", "validate_chrome_trace",
           "TraceValidationError"]
