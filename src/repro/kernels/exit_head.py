"""Fused LM-head exit-check kernel.

The expensive part of every score-based exit decision — and of the paper's
overhead analysis (§VI-H) — is decoding an intermediate hidden state through
the LM head. For 256k vocabularies (command-r, gemma2) materializing the
[B, V] logits in HBM costs more than an entire transformer layer.

TPU-native rethink: tile the vocab dimension, keep each [bB, bV] logit tile
in VMEM only, and maintain *running* (max, sumexp, sum p·logit) statistics
across vocab tiles — flash-softmax over the vocabulary. The [B, V] logits
never touch HBM; HBM traffic is just the head weights (compulsory) and
3 floats per row.

Grid: (B/bB, V/bV) with the vocab dimension sequential ("arbitrary"), so the
running statistics carried in VMEM scratch are valid across tiles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax < 0.5 names it TPUCompilerParams; newer releases renamed it
_CompilerParams = getattr(pltpu, "CompilerParams",
                          getattr(pltpu, "TPUCompilerParams", None))

NEG_INF = -1e30


def _kernel(h_ref, w_ref, top1_ref, lse_ref, ent_ref,
            m_s, s_s, t_s, *, softcap: float):
    j = pl.program_id(1)
    nj = pl.num_programs(1)

    logits = jnp.dot(h_ref[...], w_ref[...],
                     preferred_element_type=jnp.float32)
    if softcap and softcap > 0:
        logits = jnp.tanh(logits / softcap) * softcap

    @pl.when(j == 0)
    def _init():
        m_s[...] = jnp.full_like(m_s, NEG_INF)
        s_s[...] = jnp.zeros_like(s_s)
        t_s[...] = jnp.zeros_like(t_s)

    m_old = m_s[...]
    m_new = jnp.maximum(m_old, logits.max(axis=-1))
    alpha = jnp.exp(m_old - m_new)
    p = jnp.exp(logits - m_new[:, None])
    s_s[...] = s_s[...] * alpha + p.sum(axis=-1)
    t_s[...] = t_s[...] * alpha + (p * logits).sum(axis=-1)
    m_s[...] = m_new

    @pl.when(j == nj - 1)
    def _finalize():
        m = m_s[...]
        s = s_s[...]
        lse = m + jnp.log(s)
        top1_ref[...] = m
        lse_ref[...] = lse
        ent_ref[...] = lse - t_s[...] / s


@functools.partial(jax.jit, static_argnames=("softcap", "block_b", "block_v",
                                             "interpret"))
def exit_check(h: jax.Array, w: jax.Array, softcap: float = 0.0,
               *, block_b: int = 128, block_v: int = 1024,
               interpret: bool = True):
    """(top1_logit, logsumexp, entropy) per row; see ref.exit_check_ref.

    h: [B, D] final-normed hidden states; w: [D, V] LM head.
    Tiling: bB x D @ D x bV per grid step — D is kept whole (d_model fits
    VMEM comfortably for all assigned archs; <= 8192 f32 = 32 KiB/row).
    """
    B, D = h.shape
    V = w.shape[1]
    bb = min(block_b, B)
    bv = min(block_v, V)
    pad_b = (-B) % bb
    pad_v = (-V) % bv
    hp = jnp.pad(h, ((0, pad_b), (0, 0))) if pad_b else h
    wp = jnp.pad(w, ((0, 0), (0, pad_v)),
                 constant_values=0.0) if pad_v else w
    # padded vocab columns produce logit 0 which would corrupt the stats;
    # push them to -inf via a large negative bias row trick: instead mask by
    # writing NEG_INF columns into the last tile is costly — we pad with a
    # -inf-producing weight column only when h has a guaranteed nonzero norm,
    # so the simple route is to pad V with explicit -inf logits using a
    # sentinel weight column and zero hidden: not expressible. Use exact-V
    # tiles instead: require V % bv == 0 after choosing bv.
    if pad_v:
        # choose a divisor tile instead of padding
        for cand in range(bv, 0, -1):
            if V % cand == 0:
                bv = cand
                break
        wp = w
    Bp = B + pad_b

    grid = (Bp // bb, V // bv)
    kernel = functools.partial(_kernel, softcap=softcap)
    top1, lse, ent = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, D), lambda i, j: (i, 0)),
            pl.BlockSpec((D, bv), lambda i, j: (0, j)),
        ],
        out_specs=[
            pl.BlockSpec((bb,), lambda i, j: (i,)),
            pl.BlockSpec((bb,), lambda i, j: (i,)),
            pl.BlockSpec((bb,), lambda i, j: (i,)),
        ],
        out_shape=[jax.ShapeDtypeStruct((Bp,), jnp.float32)] * 3,
        scratch_shapes=[pltpu.VMEM((bb,), jnp.float32)] * 3,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(hp, wp)
    return top1[:B], lse[:B], ent[:B]
