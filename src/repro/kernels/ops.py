"""Public jit'd entry points for the Pallas kernels.

Dispatch policy: kernels run in interpret mode on CPU (this container) and
compiled mode on real TPU; set ``REPRO_KERNELS=ref`` to force the pure-jnp
oracles (useful for debugging) or ``REPRO_KERNELS=kernel`` to force the
Pallas path.
"""
from __future__ import annotations

import os

import jax

from repro.kernels import ref as _ref
from repro.kernels.decode_attn import flash_decode as _flash_decode
from repro.kernels.exit_head import exit_check as _exit_check
from repro.kernels.paged_decode_attn import \
    paged_flash_decode as _paged_flash_decode
from repro.kernels.ssd_scan import ssd_scan as _ssd_scan
from repro.kernels.verify_attn import \
    paged_verify_window as _paged_verify_window

_MODE = os.environ.get("REPRO_KERNELS", "kernel")
_INTERPRET = jax.default_backend() != "tpu"


def exit_check(h, w, softcap: float = 0.0):
    """Fused LM-head exit statistics: (top1_logit, lse, entropy)."""
    if _MODE == "ref":
        return _ref.exit_check_ref(h, w, softcap)
    return _exit_check(h, w, softcap, interpret=_INTERPRET)


def flash_decode(q, k, v, kv_pos, pos, *, window: int = 0,
                 softcap: float = 0.0):
    """Single-token GQA decode against a ring cache (insert-then-attend)."""
    if _MODE == "ref":
        return _ref.flash_decode_ref(q, k, v, kv_pos, pos, window, softcap)
    return _flash_decode(q, k, v, kv_pos, pos, window=window,
                         softcap=softcap, interpret=_INTERPRET)


def paged_flash_decode(q, k_pages, v_pages, tables, pos, k_scale=None,
                       v_scale=None, *, softcap: float = 0.0):
    """Single-token GQA decode through a block table (insert-then-attend;
    int8 pages dequantize in-kernel when scales are given)."""
    if _MODE == "ref":
        return _ref.paged_decode_ref(q, k_pages, v_pages, tables, pos,
                                     k_scale, v_scale, softcap)
    return _paged_flash_decode(q, k_pages, v_pages, tables, pos,
                               k_scale, v_scale, softcap=softcap,
                               interpret=_INTERPRET)


def paged_verify(q, k_pages, v_pages, tables, pos0, k_scale=None,
                 v_scale=None, *, softcap: float = 0.0):
    """Multi-token GQA verify window through a block table (query j at
    position pos0 + j; insert-then-attend; int8 pages dequantize in-kernel
    when scales are given)."""
    if _MODE == "ref":
        return _ref.paged_verify_ref(q, k_pages, v_pages, tables, pos0,
                                     k_scale, v_scale, softcap)
    return _paged_verify_window(q, k_pages, v_pages, tables, pos0,
                                k_scale, v_scale, softcap=softcap,
                                interpret=_INTERPRET)


def ssd_scan(x, dt, A, B, C, chunk: int = 256):
    """Chunked SSD scan -> (y, h_final)."""
    if _MODE == "ref":
        return _ref.ssd_scan_ref(x, dt, A, B, C, chunk)
    return _ssd_scan(x, dt, A, B, C, chunk, interpret=_INTERPRET)
