"""Flash-decode GQA kernel for single-token decode against a ring cache.

Decode attention is memory-bound: the whole KV cache streams HBM -> VMEM
once per step. The kernel tiles the cache sequence dimension, keeping the
running (max, denom, acc) flash statistics in VMEM scratch, and applies the
ring-buffer position mask (kv_pos / current pos / sliding window) inside the
tile so masked slots cost no extra HBM traffic.

Grid: (B, S/bS) with the sequence dimension sequential ("arbitrary").
Insert-then-attend convention: the current token's K/V is already in the
cache; causal masking is by absolute position (kv_pos <= pos).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax < 0.5 names it TPUCompilerParams; newer releases renamed it
_CompilerParams = getattr(pltpu, "CompilerParams",
                          getattr(pltpu, "TPUCompilerParams", None))

NEG_INF = -2.0 ** 30


def _kernel(pos_ref, q_ref, k_ref, v_ref, kvp_ref, o_ref,
            m_s, l_s, acc_s, *, window: int, softcap: float, scale: float):
    j = pl.program_id(1)
    nj = pl.num_programs(1)

    q = q_ref[0].astype(jnp.float32) * scale        # [KH, G, d]
    k = k_ref[0].astype(jnp.float32)                # [bS, KH, d]
    v = v_ref[0].astype(jnp.float32)                # [bS, KH, d]
    kvp = kvp_ref[0]                                # [bS]
    pos = pos_ref[0]                                # scalar

    s = jax.lax.dot_general(
        q, k, (((2,), (2,)), ((0,), (1,))),
        preferred_element_type=jnp.float32)         # [KH, G, bS]
    if softcap and softcap > 0:
        s = jnp.tanh(s / softcap) * softcap
    mask = (kvp >= 0) & (kvp <= pos)
    if window and window > 0:
        mask &= kvp > (pos - window)
    s = jnp.where(mask[None, None, :], s, NEG_INF)

    @pl.when(j == 0)
    def _init():
        m_s[...] = jnp.full_like(m_s, NEG_INF)
        l_s[...] = jnp.zeros_like(l_s)
        acc_s[...] = jnp.zeros_like(acc_s)

    m_old = m_s[...]
    m_new = jnp.maximum(m_old, s.max(axis=-1))
    alpha = jnp.exp(m_old - m_new)
    p = jnp.exp(s - m_new[..., None])               # [KH, G, bS]
    l_s[...] = l_s[...] * alpha + p.sum(axis=-1)
    pv = jax.lax.dot_general(
        p, v, (((2,), (0,)), ((0,), (1,))),
        preferred_element_type=jnp.float32)         # [KH, G, d]
    acc_s[...] = acc_s[...] * alpha[..., None] + pv
    m_s[...] = m_new

    @pl.when(j == nj - 1)
    def _finalize():
        denom = jnp.maximum(l_s[...], 1e-30)
        o_ref[0] = (acc_s[...] / denom[..., None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("window", "softcap", "block_s",
                                             "interpret"))
def flash_decode(q: jax.Array, k: jax.Array, v: jax.Array,
                 kv_pos: jax.Array, pos: jax.Array, *, window: int = 0,
                 softcap: float = 0.0, block_s: int = 512,
                 interpret: bool = True):
    """Single-token GQA decode. See ref.flash_decode_ref.

    q: [B, KH, G, d]; k, v: [B, S, KH, d]; kv_pos: [B, S]; pos: [B].
    """
    B, KH, G, d = q.shape
    S = k.shape[1]
    bs = min(block_s, S)
    pad_s = (-S) % bs
    if pad_s:
        k = jnp.pad(k, ((0, 0), (0, pad_s), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_s), (0, 0), (0, 0)))
        kv_pos = jnp.pad(kv_pos, ((0, 0), (0, pad_s)), constant_values=-1)
    Sp = S + pad_s
    grid = (B, Sp // bs)
    kernel = functools.partial(_kernel, window=window, softcap=softcap,
                               scale=d ** -0.5)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda i, j: (i,)),
            pl.BlockSpec((1, KH, G, d), lambda i, j: (i, 0, 0, 0)),
            pl.BlockSpec((1, bs, KH, d), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, bs, KH, d), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, bs), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((1, KH, G, d), lambda i, j: (i, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, KH, G, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((KH, G), jnp.float32),
            pltpu.VMEM((KH, G), jnp.float32),
            pltpu.VMEM((KH, G, d), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(pos, q, k, v, kv_pos)
    return out
