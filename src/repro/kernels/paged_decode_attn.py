"""Paged flash-decode GQA kernel: gather K/V through a block table.

The paged KV pool (serving/kv_pool.py) stores each layer's cache as block
planes ``[num_blocks, block_size, KH, hd]``; a slot's logical sequence is a
chain of blocks named by its block-table row. Decode attention must gather
that chain — doing it with ``plane[table]`` in XLA materializes a
``[B, max_ctx, KH, hd]`` copy per layer per step. This kernel instead uses
scalar-prefetched block-table indexing: the grid walks ``(batch, block)``
and the K/V BlockSpec index maps read ``table[b, j]`` to DMA exactly one
physical block per step — the gather never exists in HBM.

Convention is insert-then-attend (the current token's K/V is already in its
block before the call; logical positions ``<= pos`` attend), matching
kernels/decode_attn.py. Running (max, denom, acc) flash statistics live in
VMEM scratch across the sequential block dimension.

``int8`` caches are dequantized **in-kernel**: the int8 planes plus their
``[num_blocks, block_size, KH]`` float32 scales stream to VMEM and the
multiply happens there — the f32 cache-sized intermediate the pure-XLA
reference path materializes (models/transformer.py `_dequant_kv`) never
exists.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax < 0.5 names it TPUCompilerParams; newer releases renamed it
_CompilerParams = getattr(pltpu, "CompilerParams",
                          getattr(pltpu, "TPUCompilerParams", None))

NEG_INF = -2.0 ** 30


def _flash_body(q_ref, k, v, pos_ref, o_ref, m_s, l_s, acc_s, *,
                block_size: int, softcap: float, scale: float):
    """One (batch row, block) flash step; ``k``/``v`` are already f32."""
    b = pl.program_id(0)
    j = pl.program_id(1)
    nj = pl.num_programs(1)

    q = q_ref[0].astype(jnp.float32) * scale        # [KH, G, d]
    pos = pos_ref[b]                                # scalar

    s = jax.lax.dot_general(
        q, k, (((2,), (2,)), ((0,), (1,))),
        preferred_element_type=jnp.float32)         # [KH, G, bs]
    if softcap and softcap > 0:
        s = jnp.tanh(s / softcap) * softcap
    # logical position of entry t in this block is j*bs + t; valid entries
    # are the ones at or before the current position (insert-then-attend)
    lpos = (j * block_size
            + jax.lax.broadcasted_iota(jnp.int32, (1, 1, block_size), 2))
    s = jnp.where(lpos <= pos, s, NEG_INF)

    @pl.when(j == 0)
    def _init():
        m_s[...] = jnp.full_like(m_s, NEG_INF)
        l_s[...] = jnp.zeros_like(l_s)
        acc_s[...] = jnp.zeros_like(acc_s)

    m_old = m_s[...]
    m_new = jnp.maximum(m_old, s.max(axis=-1))
    alpha = jnp.exp(m_old - m_new)
    p = jnp.exp(s - m_new[..., None])               # [KH, G, bs]
    l_s[...] = l_s[...] * alpha + p.sum(axis=-1)
    pv = jax.lax.dot_general(
        p, v, (((2,), (0,)), ((0,), (1,))),
        preferred_element_type=jnp.float32)         # [KH, G, d]
    acc_s[...] = acc_s[...] * alpha[..., None] + pv
    m_s[...] = m_new

    @pl.when(j == nj - 1)
    def _finalize():
        denom = jnp.maximum(l_s[...], 1e-30)
        o_ref[0] = (acc_s[...] / denom[..., None]).astype(o_ref.dtype)


def _kernel(tbl_ref, pos_ref, q_ref, k_ref, v_ref, o_ref,
            m_s, l_s, acc_s, **kw):
    del tbl_ref  # consumed by the BlockSpec index maps
    _flash_body(q_ref, k_ref[0].astype(jnp.float32),
                v_ref[0].astype(jnp.float32), pos_ref, o_ref,
                m_s, l_s, acc_s, **kw)


def _kernel_int8(tbl_ref, pos_ref, q_ref, k_ref, v_ref, ks_ref, vs_ref,
                 o_ref, m_s, l_s, acc_s, **kw):
    """int8 variant: dequantize the gathered block in VMEM, then attend."""
    del tbl_ref
    k = (k_ref[0].astype(jnp.float32)
         * ks_ref[0].astype(jnp.float32)[..., None])
    v = (v_ref[0].astype(jnp.float32)
         * vs_ref[0].astype(jnp.float32)[..., None])
    _flash_body(q_ref, k, v, pos_ref, o_ref, m_s, l_s, acc_s, **kw)


@functools.partial(jax.jit, static_argnames=("softcap", "interpret"))
def paged_flash_decode(q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
                       tables: jax.Array, pos: jax.Array,
                       k_scale: jax.Array | None = None,
                       v_scale: jax.Array | None = None, *,
                       softcap: float = 0.0, interpret: bool = True):
    """Single-token GQA decode against a paged cache.

    q: [B, KH, G, d]; k_pages/v_pages: [num_blocks, block_size, KH, d]
    (float or int8 — int8 requires ``k_scale``/``v_scale``
    [num_blocks, block_size, KH] f32); tables: [B, nb] int32 block ids
    (rows padded with any in-range id — padded blocks are masked by
    position); pos: [B] current absolute positions (``>= 0``; the current
    token's K/V must already be inserted). See ref.paged_decode_ref.
    """
    B, KH, G, d = q.shape
    bs = k_pages.shape[1]
    nb = tables.shape[1]
    int8 = k_scale is not None

    def page_map(b, j, tbl, p):
        del p
        return (jnp.clip(tbl[b, j], 0, k_pages.shape[0] - 1), 0, 0, 0)

    def scale_map(b, j, tbl, p):
        del p
        return (jnp.clip(tbl[b, j], 0, k_pages.shape[0] - 1), 0, 0)

    in_specs = [
        pl.BlockSpec((1, KH, G, d), lambda b, j, tbl, p: (b, 0, 0, 0)),
        pl.BlockSpec((1, bs, KH, d), page_map),
        pl.BlockSpec((1, bs, KH, d), page_map),
    ]
    if int8:
        in_specs += [pl.BlockSpec((1, bs, KH), scale_map),
                     pl.BlockSpec((1, bs, KH), scale_map)]
    kernel = functools.partial(_kernel_int8 if int8 else _kernel,
                               block_size=bs, softcap=softcap,
                               scale=d ** -0.5)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, nb),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, KH, G, d),
                               lambda b, j, tbl, p: (b, 0, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((KH, G), jnp.float32),
            pltpu.VMEM((KH, G), jnp.float32),
            pltpu.VMEM((KH, G, d), jnp.float32),
        ],
    )
    args = (tables.astype(jnp.int32), pos.astype(jnp.int32), q,
            k_pages, v_pages)
    if int8:
        args += (k_scale, v_scale)
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KH, G, d), q.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(*args)
