"""Paged verify-window attention: q_len > 1 flash decode through a block
table.

Self-speculative verification (core/speculative.py) scores a short draft
window of S tokens full-depth in one pass. Per layer that means S queries
per row attending the row's paged KV chain *plus* the window itself —
query j at absolute position ``pos0 + j`` sees logical positions
``<= pos0 + j`` (the window's K/V is inserted before the call:
insert-then-attend, matching paged_decode_attn.py).

Same structure as the single-token paged kernel — the grid walks
``(batch, block)`` with scalar-prefetched block-table index maps so the
chain gather never materializes in HBM — but the flash statistics carry an
extra window dimension: running (max, denom, acc) live in VMEM scratch as
``[KH, S, G]`` / ``[KH, S, G, d]`` across the sequential block dimension,
and the causal mask is per query row. int8 caches dequantize in-VMEM from
their f32 scale planes, exactly like the decode kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax < 0.5 names it TPUCompilerParams; newer releases renamed it
_CompilerParams = getattr(pltpu, "CompilerParams",
                          getattr(pltpu, "TPUCompilerParams", None))

NEG_INF = -2.0 ** 30


def _flash_body(q_ref, k, v, pos_ref, o_ref, m_s, l_s, acc_s, *,
                block_size: int, softcap: float, scale: float):
    """One (batch row, block) flash step; ``k``/``v`` are already f32."""
    b = pl.program_id(0)
    j = pl.program_id(1)
    nj = pl.num_programs(1)

    q = q_ref[0].astype(jnp.float32) * scale        # [S, KH, G, d]
    S = q.shape[0]
    pos0 = pos_ref[b]                               # scalar

    # s[KH, S, G, bs] = sum_d q[s, kh, g, d] * k[t, kh, d]
    s = jax.lax.dot_general(
        q, k, (((3,), (2,)), ((1,), (1,))),
        preferred_element_type=jnp.float32)
    if softcap and softcap > 0:
        s = jnp.tanh(s / softcap) * softcap
    # query row w sits at absolute position pos0 + w and may attend logical
    # positions <= pos0 + w (insert-then-attend); entry t of this block is
    # logical position j*bs + t
    lpos = (j * block_size
            + jax.lax.broadcasted_iota(jnp.int32, (1, S, 1, block_size), 3))
    qpos = pos0 + jax.lax.broadcasted_iota(jnp.int32, (1, S, 1, block_size),
                                           1)
    s = jnp.where(lpos <= qpos, s, NEG_INF)

    @pl.when(j == 0)
    def _init():
        m_s[...] = jnp.full_like(m_s, NEG_INF)
        l_s[...] = jnp.zeros_like(l_s)
        acc_s[...] = jnp.zeros_like(acc_s)

    m_old = m_s[...]
    m_new = jnp.maximum(m_old, s.max(axis=-1))      # [KH, S, G]
    alpha = jnp.exp(m_old - m_new)
    p = jnp.exp(s - m_new[..., None])               # [KH, S, G, bs]
    l_s[...] = l_s[...] * alpha + p.sum(axis=-1)
    pv = jax.lax.dot_general(
        p, v, (((3,), (0,)), ((0,), (1,))),
        preferred_element_type=jnp.float32)         # [KH, S, G, d]
    acc_s[...] = acc_s[...] * alpha[..., None] + pv
    m_s[...] = m_new

    @pl.when(j == nj - 1)
    def _finalize():
        denom = jnp.maximum(l_s[...], 1e-30)
        out = acc_s[...] / denom[..., None]         # [KH, S, G, d]
        o_ref[0] = jnp.transpose(out, (1, 0, 2, 3)).astype(o_ref.dtype)


def _kernel(tbl_ref, pos_ref, q_ref, k_ref, v_ref, o_ref,
            m_s, l_s, acc_s, **kw):
    del tbl_ref  # consumed by the BlockSpec index maps
    _flash_body(q_ref, k_ref[0].astype(jnp.float32),
                v_ref[0].astype(jnp.float32), pos_ref, o_ref,
                m_s, l_s, acc_s, **kw)


def _kernel_int8(tbl_ref, pos_ref, q_ref, k_ref, v_ref, ks_ref, vs_ref,
                 o_ref, m_s, l_s, acc_s, **kw):
    """int8 variant: dequantize the gathered block in VMEM, then attend."""
    del tbl_ref
    k = (k_ref[0].astype(jnp.float32)
         * ks_ref[0].astype(jnp.float32)[..., None])
    v = (v_ref[0].astype(jnp.float32)
         * vs_ref[0].astype(jnp.float32)[..., None])
    _flash_body(q_ref, k, v, pos_ref, o_ref, m_s, l_s, acc_s, **kw)


@functools.partial(jax.jit, static_argnames=("softcap", "interpret"))
def paged_verify_window(q: jax.Array, k_pages: jax.Array,
                        v_pages: jax.Array, tables: jax.Array,
                        pos0: jax.Array,
                        k_scale: jax.Array | None = None,
                        v_scale: jax.Array | None = None, *,
                        softcap: float = 0.0, interpret: bool = True):
    """Multi-token GQA verify window against a paged cache.

    q: [B, S, KH, G, d] (query j at absolute position ``pos0 + j``);
    k_pages/v_pages: [num_blocks, block_size, KH, d] (float or int8 —
    int8 requires ``k_scale``/``v_scale`` [num_blocks, block_size, KH]
    f32); tables: [B, nb] int32 block ids (padded rows carry any in-range
    id — masked by position); pos0: [B] absolute position of the first
    window token, whose K/V (and the rest of the window's) must already be
    inserted. See ref.paged_verify_ref.
    """
    B, S, KH, G, d = q.shape
    bs = k_pages.shape[1]
    nb = tables.shape[1]
    int8 = k_scale is not None

    def page_map(b, j, tbl, p):
        del p
        return (jnp.clip(tbl[b, j], 0, k_pages.shape[0] - 1), 0, 0, 0)

    def scale_map(b, j, tbl, p):
        del p
        return (jnp.clip(tbl[b, j], 0, k_pages.shape[0] - 1), 0, 0)

    in_specs = [
        pl.BlockSpec((1, S, KH, G, d), lambda b, j, tbl, p: (b, 0, 0, 0, 0)),
        pl.BlockSpec((1, bs, KH, d), page_map),
        pl.BlockSpec((1, bs, KH, d), page_map),
    ]
    if int8:
        in_specs += [pl.BlockSpec((1, bs, KH), scale_map),
                     pl.BlockSpec((1, bs, KH), scale_map)]
    kernel = functools.partial(_kernel_int8 if int8 else _kernel,
                               block_size=bs, softcap=softcap,
                               scale=d ** -0.5)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, nb),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, S, KH, G, d),
                               lambda b, j, tbl, p: (b, 0, 0, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((KH, S, G), jnp.float32),
            pltpu.VMEM((KH, S, G), jnp.float32),
            pltpu.VMEM((KH, S, G, d), jnp.float32),
        ],
    )
    args = (tables.astype(jnp.int32), pos0.astype(jnp.int32), q,
            k_pages, v_pages)
    if int8:
        args += (k_scale, v_scale)
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, S, KH, G, d), q.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(*args)
