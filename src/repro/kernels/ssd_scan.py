"""Mamba2 SSD chunked-scan kernel.

The SSD duality (arXiv:2405.21060) splits the sequence into chunks: within
a chunk the state-space mixing is a small quadratic form (three MXU matmuls
per chunk — TPU-friendly), across chunks only the [H, P, N] recurrent state
is carried. The kernel walks chunks sequentially per batch element, carrying
the state in VMEM scratch, so HBM sees each input tile exactly once and the
[S, S] attention-dual matrix never exists outside a [Q, Q] VMEM tile.

Grid: (B, S/Q) with the chunk dimension sequential ("arbitrary").
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax < 0.5 names it TPUCompilerParams; newer releases renamed it
_CompilerParams = getattr(pltpu, "CompilerParams",
                          getattr(pltpu, "TPUCompilerParams", None))


def _segsum(a):
    """a: [H, Q] -> [H, Q, Q] lower-triangular pairwise decay log-sums."""
    Q = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((Q, Q), bool), 0)
    return jnp.where(mask, diff, -jnp.inf)


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, hfin_ref, h_s):
    j = pl.program_id(1)
    nj = pl.num_programs(1)

    x = x_ref[0].astype(jnp.float32)        # [Q, H, P]
    dt = dt_ref[0].astype(jnp.float32)      # [Q, H]
    A = a_ref[...].astype(jnp.float32)      # [H]
    Bm = b_ref[0].astype(jnp.float32)       # [Q, N]
    Cm = c_ref[0].astype(jnp.float32)       # [Q, N]

    @pl.when(j == 0)
    def _init():
        h_s[...] = jnp.zeros_like(h_s)

    da = dt * A                             # [Q, H]
    xbar = x * dt[..., None]                # [Q, H, P]
    cum = jnp.cumsum(da, axis=0)            # [Q, H]

    # intra-chunk quadratic form
    L = jnp.exp(_segsum(da.T))              # [H, Q, Q]
    scores = jnp.dot(Cm, Bm.T, preferred_element_type=jnp.float32)  # [Q, Q]
    M = scores[None, :, :] * L              # [H, Q, Q]
    y_intra = jnp.einsum("hij,jhp->ihp", M, xbar)

    # inter-chunk: contribution of the carried state
    decay_in = jnp.exp(cum)                 # [Q, H]
    h_in = h_s[...]                         # [H, P, N]
    y_inter = jnp.einsum("in,hpn,ih->ihp", Cm, h_in, decay_in)

    y_ref[0] = (y_intra + y_inter).astype(y_ref.dtype)

    # state update for the next chunk
    decay_to_end = jnp.exp(cum[-1][None, :] - cum)      # [Q, H]
    s_c = jnp.einsum("jn,jh,jhp->hpn", Bm, decay_to_end, xbar)
    h_s[...] = h_in * jnp.exp(cum[-1])[:, None, None] + s_c

    @pl.when(j == nj - 1)
    def _finalize():
        hfin_ref[0] = h_s[...]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x: jax.Array, dt: jax.Array, A: jax.Array, B: jax.Array,
             C: jax.Array, chunk: int = 256, *, interpret: bool = True):
    """Chunked SSD scan. See ref.ssd_scan_ref.

    x: [Bt, S, H, P]; dt: [Bt, S, H]; A: [H]; B, C: [Bt, S, N].
    Returns (y [Bt, S, H, P] float32, h_final [Bt, H, P, N] float32).
    S is padded to a multiple of ``chunk`` (dt = 0 on padding, which is a
    no-op for both output and state).
    """
    Bt, S, H, P = x.shape
    N = B.shape[-1]
    Q = min(chunk, S)
    pad = (-S) % Q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
    Sp = S + pad
    grid = (Bt, Sp // Q)
    y, hfin = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, Q, H, P), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, Q, H), lambda i, j: (i, j, 0)),
            pl.BlockSpec((H,), lambda i, j: (0,)),
            pl.BlockSpec((1, Q, N), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, Q, N), lambda i, j: (i, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, Q, H, P), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, H, P, N), lambda i, j: (i, 0, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bt, Sp, H, P), jnp.float32),
            jax.ShapeDtypeStruct((Bt, H, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((H, P, N), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(x, dt, A, B, C)
    return y[:, :S], hfin
