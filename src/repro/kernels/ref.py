"""Pure-jnp oracles for the Pallas kernels.

Each function is the semantic ground truth for the matching kernel:
  exit_check_ref   <-> exit_head.py
  flash_decode_ref <-> decode_attn.py
  paged_decode_ref <-> paged_decode_attn.py
  paged_verify_ref <-> verify_attn.py
  ssd_scan_ref     <-> ssd_scan.py
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -2.0 ** 30


def exit_check_ref(h: jax.Array, w: jax.Array, softcap: float = 0.0):
    """Fused LM-head exit statistics.

    h: [B, D] (already final-normed), w: [D, V].
    Returns (top1_logit [B], logsumexp [B], entropy [B]) in float32.
    top-1 probability = exp(top1 - lse); entropy is in nats.
    """
    logits = jnp.dot(h, w, preferred_element_type=jnp.float32)
    if softcap and softcap > 0:
        logits = jnp.tanh(logits / softcap) * softcap
    m = logits.max(axis=-1)
    lse = m + jnp.log(jnp.exp(logits - m[:, None]).sum(axis=-1))
    p = jnp.exp(logits - lse[:, None])
    ent = lse - (p * logits).sum(axis=-1)
    return m, lse, ent


def flash_decode_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                     kv_pos: jax.Array, pos: jax.Array,
                     window: int = 0, softcap: float = 0.0):
    """Single-token GQA decode against a ring-buffer cache.

    q: [B, KH, G, d]; k, v: [B, S, KH, d]; kv_pos: [B, S] absolute positions
    (-1 = empty slot); pos: [B] current position. The current token's K/V is
    assumed already inserted into the cache (insert-then-attend).
    Returns out [B, KH, G, d] (q dtype).
    """
    d = q.shape[-1]
    s = jnp.einsum("bkgd,bskd->bkgs", q.astype(jnp.float32) * d ** -0.5,
                   k.astype(jnp.float32))
    if softcap and softcap > 0:
        s = jnp.tanh(s / softcap) * softcap
    mask = (kv_pos >= 0) & (kv_pos <= pos[:, None])
    if window and window > 0:
        mask &= kv_pos > (pos[:, None] - window)
    s = jnp.where(mask[:, None, None, :], s, NEG_INF)
    m = s.max(axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    out = jnp.einsum("bkgs,bskd->bkgd", p, v.astype(jnp.float32))
    return (out / p.sum(axis=-1)[..., None]).astype(q.dtype)


def paged_decode_ref(q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
                     tables: jax.Array, pos: jax.Array,
                     k_scale: jax.Array | None = None,
                     v_scale: jax.Array | None = None,
                     softcap: float = 0.0):
    """Single-token GQA decode against a paged (block-table) cache.

    q: [B, KH, G, d]; k_pages/v_pages: [num_blocks, block_size, KH, d]
    (int8 planes take ``k_scale``/``v_scale`` [num_blocks, block_size, KH]);
    tables: [B, nb] block ids; pos: [B] current positions.
    Insert-then-attend: logical positions ``<= pos`` are attended.
    Gathers the chain into ``[B, nb*block_size, ...]`` logical order and
    defers to :func:`flash_decode_ref`.
    """
    B, nb = tables.shape
    bs = k_pages.shape[1]
    tbl = jnp.clip(tables, 0, k_pages.shape[0] - 1)

    def gather(pages):
        g = pages[tbl]                              # [B, nb, bs, ...]
        return g.reshape(B, nb * bs, *pages.shape[2:])

    k, v = gather(k_pages), gather(v_pages)
    if k_scale is not None:
        k = k.astype(jnp.float32) * gather(k_scale)[..., None]
        v = v.astype(jnp.float32) * gather(v_scale)[..., None]
    lpos = jnp.arange(nb * bs)
    kv_pos = jnp.where(lpos[None, :] <= pos[:, None], lpos[None, :], -1)
    return flash_decode_ref(q.astype(jnp.float32), k, v, kv_pos, pos,
                            0, softcap).astype(q.dtype)


def paged_verify_ref(q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
                     tables: jax.Array, pos0: jax.Array,
                     k_scale: jax.Array | None = None,
                     v_scale: jax.Array | None = None,
                     softcap: float = 0.0):
    """Multi-token GQA verify window against a paged (block-table) cache.

    q: [B, S, KH, G, d] — query j sits at absolute position ``pos0 + j``
    and attends logical positions ``<= pos0 + j`` (the window's K/V is
    already inserted: insert-then-attend). k_pages/v_pages:
    [num_blocks, block_size, KH, d] (int8 planes take ``k_scale``/
    ``v_scale`` [num_blocks, block_size, KH]); tables: [B, nb] block ids;
    pos0: [B]. Gathers each row's chain into logical order and computes the
    masked softmax directly. Returns out [B, S, KH, G, d] (q dtype).
    """
    B, nb = tables.shape
    S = q.shape[1]
    bs = k_pages.shape[1]
    d = q.shape[-1]
    tbl = jnp.clip(tables, 0, k_pages.shape[0] - 1)

    def gather(pages):
        g = pages[tbl]                              # [B, nb, bs, ...]
        return g.reshape(B, nb * bs, *pages.shape[2:])

    k, v = gather(k_pages), gather(v_pages)
    if k_scale is not None:
        k = k.astype(jnp.float32) * gather(k_scale)[..., None]
        v = v.astype(jnp.float32) * gather(v_scale)[..., None]
    s = jnp.einsum("bskgd,btkd->bksgt",
                   q.astype(jnp.float32) * d ** -0.5,
                   k.astype(jnp.float32))           # [B, KH, S, G, T]
    if softcap and softcap > 0:
        s = jnp.tanh(s / softcap) * softcap
    lpos = jnp.arange(nb * bs)
    qpos = pos0[:, None] + jnp.arange(S)[None, :]   # [B, S]
    mask = lpos[None, None, :] <= qpos[:, :, None]  # [B, S, T]
    s = jnp.where(mask[:, None, :, None, :], s, NEG_INF)
    m = s.max(axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    out = jnp.einsum("bksgt,btkd->bskgd", p, v.astype(jnp.float32))
    denom = jnp.transpose(p.sum(axis=-1), (0, 2, 1, 3))  # [B, S, KH, G]
    return (out / denom[..., None]).astype(q.dtype)


def ssd_scan_ref(x: jax.Array, dt: jax.Array, A: jax.Array, B: jax.Array,
                 C: jax.Array, chunk: int):
    """Mamba2 SSD chunked scan (defers to the model's reference impl).

    x: [Bt, S, H, P]; dt: [Bt, S, H] (positive); A: [H] (negative);
    B, C: [Bt, S, N]. Returns (y [Bt, S, H, P], h_final [Bt, H, P, N]).
    """
    from repro.models.ssm import ssd_chunked
    return ssd_chunked(x.astype(jnp.float32), dt.astype(jnp.float32),
                       A.astype(jnp.float32), B.astype(jnp.float32),
                       C.astype(jnp.float32), chunk)
