"""Config system for the GREEN-CODE reproduction framework.

Every architecture is described by a :class:`ModelConfig`; the paper's early-exit
technique is configured by :class:`ExitConfig`. Configs are frozen dataclasses so
they are hashable and can key jit caches.

Layers are described by a ``block_pattern``: a tuple of :class:`LayerSpec`
(mixer, ffn) pairs, one per layer. The transformer composes consecutive
repetitions of the smallest repeating unit into a scanned super-block so the
lowered HLO is O(unit) rather than O(depth).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Mixer / FFN kinds
# ---------------------------------------------------------------------------
MIXER_GQA = "gqa"              # grouped-query attention (global)
MIXER_GQA_LOCAL = "gqa_local"  # sliding-window attention
MIXER_MLA = "mla"              # multi-head latent attention (MiniCPM3/DeepSeek style)
MIXER_MAMBA = "mamba"          # Mamba2 SSD block
MIXER_SHARED_GQA = "shared_gqa"  # zamba2-style shared-weight attention block

FFN_DENSE = "dense"
FFN_MOE = "moe"
FFN_NONE = "none"              # e.g. mamba blocks carry their own expansion


@dataclass(frozen=True)
class LayerSpec:
    mixer: str
    ffn: str

    def __post_init__(self):
        assert self.mixer in (MIXER_GQA, MIXER_GQA_LOCAL, MIXER_MLA, MIXER_MAMBA,
                              MIXER_SHARED_GQA), self.mixer
        assert self.ffn in (FFN_DENSE, FFN_MOE, FFN_NONE), self.ffn


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    num_experts_per_tok: int
    d_ff_expert: int
    num_shared_experts: int = 0
    router_aux_coef: float = 0.01
    router_jitter: float = 0.0
    train_capacity_factor: float = 1.25  # §Perf knob: expert buffer slack


@dataclass(frozen=True)
class SSMConfig:
    """Mamba2 (SSD) block configuration."""
    state_dim: int = 128
    expand: int = 2
    head_dim: int = 64
    conv_dim: int = 4
    chunk_size: int = 256
    # number of SSD heads = d_model * expand // head_dim (derived)


@dataclass(frozen=True)
class MLAConfig:
    """Multi-head Latent Attention (MiniCPM3 / DeepSeek-V2 style)."""
    q_lora_rank: int = 768
    kv_lora_rank: int = 256
    qk_nope_head_dim: int = 64
    qk_rope_head_dim: int = 32
    v_head_dim: int = 64


@dataclass(frozen=True)
class ExitConfig:
    """GREEN-CODE early-exit configuration (paper §III-D)."""
    enabled: bool = True
    min_exit_layer: int = 4          # earliest exit point
    first_half_stride: int = 2       # alternating layers in the first half
    second_half_stride: int = 4      # every 4th layer in the second half
    # LITE aggregated-loss weight budgets: (first half, second half, final layer)
    budgets: Tuple[float, float, float] = (0.7, 0.2, 0.1)
    decay: float = 0.9               # geometric decay ratio inside each group


@dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str                   # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    block_pattern: Tuple[LayerSpec, ...] = ()
    head_dim: int = 0                # 0 -> d_model // num_heads
    # attention options
    rope_theta: float = 10000.0
    positional: str = "rope"         # rope | learned | none
    sliding_window: int = 4096       # window used by gqa_local mixers
    attn_logit_softcap: float = 0.0  # 0 disables (gemma2: 50.)
    final_logit_softcap: float = 0.0  # (gemma2: 30.)
    qk_norm: bool = False
    use_bias: bool = False           # OPT uses biases
    norm: str = "rmsnorm"            # rmsnorm | layernorm
    activation: str = "silu"         # silu | gelu | relu
    mlp_gated: bool = True           # SwiGLU-style gated MLP
    tie_embeddings: bool = False
    max_position: int = 1 << 20
    # KV-cache storage: "compute" (= activation dtype) or "int8"
    # (per-slot-per-head symmetric quantization; beyond-paper, §Perf)
    kv_cache_dtype: str = "compute"
    # full-seq attention sharding: "seq" (query positions over model axis,
    # works for any head count) or "head" (flat heads over model axis with
    # G-fold KV broadcast; needs num_heads % model == 0; §Perf C3)
    attn_shard: str = "seq"
    # substructure configs
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    mla: Optional[MLAConfig] = None
    # modality frontend stub: None | "audio" | "vision"
    frontend: Optional[str] = None
    frontend_tokens: int = 0         # number of prefix embedding positions
    # early exit
    exit: ExitConfig = field(default_factory=ExitConfig)
    # source citation (model card / paper)
    source: str = ""

    def __post_init__(self):
        if not self.block_pattern:
            object.__setattr__(
                self, "block_pattern",
                tuple(LayerSpec(MIXER_GQA, FFN_DENSE) for _ in range(self.num_layers)))
        assert len(self.block_pattern) == self.num_layers, (
            f"{self.name}: pattern len {len(self.block_pattern)} != {self.num_layers}")
        if self.head_dim == 0 and self.num_heads > 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)

    # -- derived ------------------------------------------------------------
    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + blocks + head)."""
        n = self.vocab_size * self.d_model  # embed
        if not self.tie_embeddings:
            n += self.vocab_size * self.d_model
        for spec in self.block_pattern:
            n += self._mixer_params(spec.mixer) + self._ffn_params(spec.ffn)
        # shared block counted once, subtract duplicates
        n_shared = sum(1 for s in self.block_pattern if s.mixer == MIXER_SHARED_GQA)
        if n_shared > 1:
            n -= (n_shared - 1) * self._mixer_params(MIXER_SHARED_GQA)
        return n

    def active_param_count(self) -> int:
        """Params activated per token (MoE: only routed top-k + shared)."""
        n = self.vocab_size * self.d_model
        if not self.tie_embeddings:
            n += self.vocab_size * self.d_model
        for spec in self.block_pattern:
            n += self._mixer_params(spec.mixer)
            if spec.ffn == FFN_MOE:
                m = self.moe
                per = 3 * self.d_model * m.d_ff_expert
                n += per * (m.num_experts_per_tok + m.num_shared_experts)
                n += self.d_model * m.num_experts  # router
            elif spec.ffn == FFN_DENSE:
                n += self._ffn_params(FFN_DENSE)
        return n

    def _mixer_params(self, mixer: str) -> int:
        d = self.d_model
        if mixer in (MIXER_GQA, MIXER_GQA_LOCAL, MIXER_SHARED_GQA):
            return d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
        if mixer == MIXER_MLA:
            m = self.mla
            qk_head = m.qk_nope_head_dim + m.qk_rope_head_dim
            n = d * m.q_lora_rank + m.q_lora_rank * self.num_heads * qk_head
            n += d * (m.kv_lora_rank + m.qk_rope_head_dim)
            n += m.kv_lora_rank * self.num_heads * (m.qk_nope_head_dim + m.v_head_dim)
            n += self.num_heads * m.v_head_dim * d
            return n
        if mixer == MIXER_MAMBA:
            s = self.ssm
            d_in = d * s.expand
            nheads = d_in // s.head_dim
            # in_proj (z, x, B, C, dt) + out_proj
            n = d * (2 * d_in + 2 * s.state_dim + nheads) + d_in * d
            n += s.conv_dim * (d_in + 2 * s.state_dim)  # conv over x, B, C
            n += 2 * nheads  # A_log, D
            return n
        raise ValueError(mixer)

    def _ffn_params(self, ffn: str) -> int:
        d = self.d_model
        if ffn == FFN_DENSE:
            mult = 3 if self.mlp_gated else 2
            return mult * d * self.d_ff
        if ffn == FFN_MOE:
            m = self.moe
            per = 3 * d * m.d_ff_expert
            return per * (m.num_experts + m.num_shared_experts) + d * m.num_experts
        return 0


# ---------------------------------------------------------------------------
# Input shapes (assigned)
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}

# Beyond-paper adaptation: window used by full-attention archs at long_500k so
# that every (arch x shape) combination lowers (see DESIGN.md §4).
LONG_CONTEXT_WINDOW = 8192


def config_for_shape(cfg: ModelConfig, shape: InputShape) -> ModelConfig:
    """Adapt a config for a given input shape.

    For ``long_500k`` all global-attention mixers switch to sliding-window
    attention (window ``LONG_CONTEXT_WINDOW``) so the KV cache stays bounded.
    SSM mixers are untouched (constant state).
    """
    if shape.seq_len < 100_000:
        return cfg
    # shared_gqa and MLA keep their mixer ids (weights/cache layout are
    # unchanged) and become windowed via the "+win" marker — the ring cache
    # of size `window` plus the position mask implements the sliding window.
    # Only plain full-attention GQA mixers are rewritten to gqa_local.
    new_pattern = tuple(
        LayerSpec(MIXER_GQA_LOCAL, s.ffn) if s.mixer == MIXER_GQA
        else s for s in cfg.block_pattern)
    return dataclasses.replace(
        cfg, block_pattern=new_pattern,
        sliding_window=min(cfg.sliding_window, LONG_CONTEXT_WINDOW),
        name=cfg.name + "+win")


# ---------------------------------------------------------------------------
# helpers for building patterns
# ---------------------------------------------------------------------------
def uniform_pattern(n: int, mixer: str = MIXER_GQA, ffn: str = FFN_DENSE):
    return tuple(LayerSpec(mixer, ffn) for _ in range(n))


def alternating_pattern(n: int, specs):
    """specs: sequence of LayerSpec cycled over n layers."""
    return tuple(specs[i % len(specs)] for i in range(n))
