from repro.config.base import (  # noqa: F401
    FFN_DENSE, FFN_MOE, FFN_NONE,
    MIXER_GQA, MIXER_GQA_LOCAL, MIXER_MAMBA, MIXER_MLA, MIXER_SHARED_GQA,
    SHAPES, ExitConfig, InputShape, LayerSpec, MLAConfig, MoEConfig,
    ModelConfig, SSMConfig, alternating_pattern, config_for_shape,
    uniform_pattern, LONG_CONTEXT_WINDOW,
)
