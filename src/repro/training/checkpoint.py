"""Checkpointing: params/optimizer pytrees -> .npz + structure JSON.

No orbax offline; arrays are saved flat with path-derived keys. Works for
any pytree of jnp/np arrays (params, optimizer state, RL agents).
"""
from __future__ import annotations

import json
import os

import jax
import numpy as np


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for kp, v in flat:
        key = "/".join(
            str(getattr(k, "key", getattr(k, "idx", k))) for k in kp)
        out[key] = np.asarray(v)
    return out, treedef


def save_pytree(tree, path: str):
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    arrays, _ = _flatten(tree)
    np.savez(path if path.endswith(".npz") else path + ".npz", **arrays)
    # structure spec for exact reconstruction
    spec = jax.tree.map(lambda x: None, tree)
    with open(_spec_path(path), "w") as f:
        json.dump(_spec_of(tree), f)


def _spec_path(path: str) -> str:
    base = path[:-4] if path.endswith(".npz") else path
    return base + ".spec.json"


def _spec_of(tree):
    if isinstance(tree, dict):
        return {"__kind__": "dict",
                "items": {k: _spec_of(v) for k, v in tree.items()}}
    if isinstance(tree, (list, tuple)):
        return {"__kind__": type(tree).__name__,
                "items": [_spec_of(v) for v in tree]}
    return {"__kind__": "leaf"}


def _build(spec, arrays, prefix):
    kind = spec["__kind__"]
    if kind == "leaf":
        return arrays[prefix]
    if kind == "dict":
        return {k: _build(v, arrays, f"{prefix}/{k}" if prefix else k)
                for k, v in spec["items"].items()}
    items = [_build(v, arrays, f"{prefix}/{i}" if prefix else str(i))
             for i, v in enumerate(spec["items"])]
    return items if kind == "list" else tuple(items)


def load_pytree(path: str):
    npz_path = path if path.endswith(".npz") else path + ".npz"
    arrays = dict(np.load(npz_path, allow_pickle=False))
    with open(_spec_path(path)) as f:
        spec = json.load(f)
    return _build(spec, arrays, "")
