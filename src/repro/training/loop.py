"""Training loop: LITE fine-tuning (the paper's §III-D) and plain CE.

``make_train_step`` builds a jit-able step with optional gradient
accumulation (lax.scan over microbatches) and remat on segment boundaries.
The same step lowers under pjit for the production mesh (launch/train.py
supplies shardings); on CPU it runs the reduced paper models directly.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from functools import partial
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig
from repro.core.lite_loss import lite_loss, token_ce
from repro.models import transformer as T
from repro.training.optimizer import adamw_init, adamw_update, make_schedule


@dataclass
class TrainState:
    params: Any
    opt: Any
    step: int = 0


def loss_fn(params, cfg: ModelConfig, tokens, labels, mask, *,
            kind: str = "lite", remat: bool = False,
            prefix_embed=None, lite_stride: int = 1):
    """kind: 'lite' (paper Eq. 1) or 'ce' (final layer only, baseline)."""
    outs, aux = T.forward(params, cfg, tokens, prefix_embed, remat=remat)
    if kind == "lite":
        loss, per_layer = lite_loss(params, cfg, outs, labels, mask,
                                    intermediate_stride=lite_stride)
    else:
        logits = T.lm_logits(params, cfg, outs[-1])
        loss = token_ce(logits, labels, mask)
        per_layer = loss[None]
    return loss + 0.01 * aux, (loss, per_layer)


def make_train_step(cfg: ModelConfig, *, kind: str = "lite",
                    lr: float = 1e-5, total_steps: int = 1000,
                    warmup: int = 50, accum: int = 1, remat: bool = False,
                    weight_decay: float = 0.01,
                    donate: bool = True) -> Callable:
    """Returns step(state_tuple, batch) -> (state_tuple, metrics).

    ``batch``: (tokens, labels, mask) each [accum * B, S] — reshaped into
    microbatches internally when accum > 1.
    state_tuple = (params, opt_state, step_idx)
    """
    sched = make_schedule("linear", lr, total_steps, warmup)

    def step(state, batch):
        params, opt, istep = state
        tokens, labels, mask = batch

        grad_fn = jax.value_and_grad(
            partial(loss_fn, cfg=cfg, kind=kind, remat=remat), has_aux=True)

        if accum > 1:
            mb = lambda x: x.reshape(accum, -1, *x.shape[1:])  # noqa: E731
            micro = (mb(tokens), mb(labels), mb(mask))

            def acc_body(carry, mb_batch):
                g_acc, l_acc = carry
                (l, (ce, _)), g = grad_fn(params, tokens=mb_batch[0],
                                          labels=mb_batch[1],
                                          mask=mb_batch[2])
                return (jax.tree.map(jnp.add, g_acc, g), l_acc + l), None

            g0 = jax.tree.map(jnp.zeros_like, params)
            (grads, loss_sum), _ = jax.lax.scan(
                acc_body, (g0, jnp.zeros(())), micro)
            grads = jax.tree.map(lambda g: g / accum, grads)
            loss = loss_sum / accum
        else:
            (loss, (ce, _)), grads = grad_fn(params, tokens=tokens,
                                             labels=labels, mask=mask)

        new_params, new_opt = adamw_update(params, grads, opt, sched(istep),
                                           weight_decay=weight_decay)
        return (new_params, new_opt, istep + 1), {"loss": loss}

    return jax.jit(step, donate_argnums=(0,) if donate else ())


def train_model(cfg: ModelConfig, dataset, *, kind: str = "lite",
                steps: int = 200, batch_size: int = 8, lr: float = 1e-4,
                accum: int = 1, seed: int = 0, log_every: int = 20,
                params=None, remat: bool = False,
                callback: Optional[Callable] = None):
    """CPU-scale training driver (reduced paper models / smoke configs).

    Returns (params, history). ``dataset`` is a CodeCompletionDataset.
    """
    key = jax.random.PRNGKey(seed)
    if params is None:
        params = T.init_params(key, cfg)
    opt = adamw_init(params)
    step_fn = make_train_step(cfg, kind=kind, lr=lr, total_steps=steps,
                              accum=accum, remat=remat)
    state = (params, opt, jnp.zeros((), jnp.int32))
    history = []
    it = dataset.batches("train", batch_size * accum, epochs=10_000,
                         seed=seed)
    t0 = time.time()
    for i in range(steps):
        batch = next(it)
        state, metrics = step_fn(state, tuple(map(jnp.asarray, batch)))
        loss = float(metrics["loss"])
        history.append(loss)
        if callback:
            callback(i, loss)
        if log_every and (i % log_every == 0 or i == steps - 1):
            print(f"  step {i:5d}  loss {loss:.4f}  "
                  f"({time.time() - t0:.1f}s)", flush=True)
    return state[0], history


def evaluate_ce(params, cfg: ModelConfig, dataset, *, split: str = "valid",
                batch_size: int = 8, max_batches: int = 10,
                kind: str = "lite"):
    """Mean CE (final layer) and per-exit-layer CE on a held-out split."""
    losses = []
    per_layer = []
    for i, batch in enumerate(dataset.batches(split, batch_size)):
        if i >= max_batches:
            break
        tokens, labels, mask = map(jnp.asarray, batch)
        outs, _ = T.forward(params, cfg, tokens)
        _, pl_losses = lite_loss(params, cfg, outs, labels, mask)
        per_layer.append(np.asarray(pl_losses))
        losses.append(float(pl_losses[-1]))
    return float(np.mean(losses)), np.mean(per_layer, axis=0)
