"""AdamW + LR schedules, dependency-free (no optax offline).

State is a pytree mirroring params: {m, v} plus a scalar step. Weight decay
is decoupled (AdamW). ``adamw_update`` is shard-agnostic — with params
sharded by pjit the optimizer state inherits the same sharding (ZeRO-style
when the caller shards params over data axes too).
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp


def make_schedule(kind: str, base_lr: float, total_steps: int,
                  warmup_steps: int = 0) -> Callable:
    def sched(step):
        step = jnp.asarray(step, jnp.float32)
        warm = jnp.minimum(1.0, (step + 1) / max(warmup_steps, 1))
        frac = jnp.clip((step - warmup_steps) /
                        max(total_steps - warmup_steps, 1), 0.0, 1.0)
        if kind == "linear":
            decay = 1.0 - frac
        elif kind == "cosine":
            decay = 0.5 * (1 + jnp.cos(jnp.pi * frac))
        else:  # constant
            decay = 1.0
        return base_lr * warm * decay

    return sched


def adamw_init(params):
    zeros = jax.tree.map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree.map(jnp.zeros_like, params),
            "step": jnp.zeros((), jnp.int32)}


def adamw_update(params, grads, state, lr, *, b1: float = 0.9,
                 b2: float = 0.999, eps: float = 1e-8,
                 weight_decay: float = 0.01,
                 max_grad_norm: float = 1.0):
    """One AdamW step with global-norm clipping. Returns (params, state)."""
    if max_grad_norm and max_grad_norm > 0:
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g))
                             for g in jax.tree.leaves(grads)))
        scale = jnp.minimum(1.0, max_grad_norm / (gnorm + 1e-9))
        grads = jax.tree.map(lambda g: g * scale, grads)
    step = state["step"] + 1
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        # optimizer math in the state dtype (f32); params keep their dtype
        g32 = g.astype(m.dtype)
        m = b1 * m + (1 - b1) * g32
        v = b2 * v + (1 - b2) * jnp.square(g32)
        mhat = m / bc1
        vhat = v / bc2
        step_ = lr * (mhat / (jnp.sqrt(vhat) + eps)
                      + weight_decay * p.astype(m.dtype))
        new_p = (p.astype(m.dtype) - step_).astype(p.dtype)
        return new_p, m, v

    flat_p, tree = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tree.unflatten([o[0] for o in out])
    new_m = tree.unflatten([o[1] for o in out])
    new_v = tree.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}
