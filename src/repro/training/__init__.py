from repro.training.optimizer import (adamw_init, adamw_update,  # noqa
                                      make_schedule)
from repro.training.loop import (TrainState, make_train_step,  # noqa
                                 train_model)
from repro.training.checkpoint import load_pytree, save_pytree  # noqa
