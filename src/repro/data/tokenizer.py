"""Deterministic code tokenizer: word/symbol level with byte fallback.

Splits source into identifiers/numbers/symbols/whitespace runs; the
vocabulary is built from a corpus sample (most frequent tokens first) with
single-byte fallback entries so any string round-trips exactly.
"""
from __future__ import annotations

import re
from collections import Counter

_TOKEN_RE = re.compile(
    r"[A-Za-z_][A-Za-z_0-9]*|\d+|\n|    |[^\sA-Za-z_0-9]| |\s")

PAD, BOS, EOS, UNK = 0, 1, 2, 3
_SPECIALS = ["<pad>", "<bos>", "<eos>", "<unk>"]


def _lex(text: str) -> list[str]:
    return _TOKEN_RE.findall(text)


class CodeTokenizer:
    def __init__(self, vocab: list[str]):
        self.vocab = list(vocab)
        self.tok2id = {t: i for i, t in enumerate(self.vocab)}
        self.byte_base = len(self.vocab)

    @property
    def vocab_size(self) -> int:
        return self.byte_base + 256

    @classmethod
    def train(cls, corpus: list[str], vocab_size: int = 2048
              ) -> "CodeTokenizer":
        counts = Counter()
        for text in corpus:
            counts.update(_lex(text))
        budget = vocab_size - len(_SPECIALS) - 256
        most = [t for t, _ in counts.most_common(budget)]
        return cls(_SPECIALS + most)

    def encode(self, text: str, add_bos: bool = False,
               add_eos: bool = False) -> list[int]:
        ids = [BOS] if add_bos else []
        for tok in _lex(text):
            i = self.tok2id.get(tok)
            if i is not None:
                ids.append(i)
            else:
                ids.extend(self.byte_base + b for b in tok.encode("utf-8"))
        if add_eos:
            ids.append(EOS)
        return ids

    def decode(self, ids) -> str:
        out = []
        byte_buf = bytearray()
        for i in ids:
            i = int(i)
            if i >= self.vocab_size:
                continue  # model vocab may be padded beyond the tokenizer's
            if i >= self.byte_base:
                byte_buf.append(i - self.byte_base)
                continue
            if byte_buf:
                out.append(byte_buf.decode("utf-8", errors="replace"))
                byte_buf = bytearray()
            if i >= len(_SPECIALS):
                out.append(self.vocab[i])
        if byte_buf:
            out.append(byte_buf.decode("utf-8", errors="replace"))
        return "".join(out)
