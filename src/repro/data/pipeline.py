"""Dataset pipeline: tokenize -> split -> pack -> batch.

Mirrors the paper's setup (§III-B, §VI-C): whole code files, train/valid/
test splits, packing of short samples to a maximum sequence length, and the
context-fraction protocol — the first ``frac`` of a file's tokens are the
prompt, the following tokens the completion target.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.data.corpus import build_corpus
from repro.data.tokenizer import EOS, PAD, CodeTokenizer


def pack_sequences(token_lists: list[list[int]], seq_len: int,
                   pad_id: int = PAD, eos_id: int = EOS) -> np.ndarray:
    """Greedy packing: concatenate samples (EOS-separated), emit fixed-size
    rows. Long samples are split across rows; the tail row is padded."""
    buf: list[int] = []
    rows = []
    for toks in token_lists:
        buf.extend(toks)
        buf.append(eos_id)
        while len(buf) >= seq_len:
            rows.append(buf[:seq_len])
            buf = buf[seq_len:]
    if buf:
        rows.append(buf + [pad_id] * (seq_len - len(buf)))
    return np.asarray(rows, np.int32)


def sample_context_split(rng: np.random.Generator, n_tokens: int,
                         lo: float = 0.2, hi: float = 0.6) -> int:
    """Paper §IV-F: context fraction sampled uniformly from [lo, hi]."""
    frac = rng.uniform(lo, hi)
    return max(1, min(n_tokens - 2, int(n_tokens * frac)))


@dataclass
class CodeCompletionDataset:
    """End-to-end dataset: synthetic (or real) corpus + tokenizer + splits."""
    language: str = "java"
    n_files: int = 400
    seq_len: int = 512
    vocab_size: int = 2048
    seed: int = 0
    path: str | None = None

    def __post_init__(self):
        files = build_corpus(self.language, self.n_files, self.seed,
                             self.path)
        rng = np.random.default_rng(self.seed)
        order = rng.permutation(len(files))
        n_train = int(len(files) * 0.8)
        n_valid = int(len(files) * 0.1)
        self.tokenizer = CodeTokenizer.train(
            [files[i] for i in order[:n_train]], self.vocab_size)
        self._splits = {}
        bounds = {"train": order[:n_train],
                  "valid": order[n_train:n_train + n_valid],
                  "test": order[n_train + n_valid:]}
        for name, idx in bounds.items():
            toks = [self.tokenizer.encode(files[i]) for i in idx]
            self._splits[name] = toks
        self.files = files

    def tokens(self, split: str) -> list[list[int]]:
        return self._splits[split]

    def packed(self, split: str) -> np.ndarray:
        return pack_sequences(self.tokens(split), self.seq_len)

    def batches(self, split: str, batch_size: int, *, epochs: int = 1,
                seed: int = 0, drop_last: bool = True):
        """Yield (tokens [B, S], labels [B, S], mask [B, S]) numpy batches
        for next-token training (labels = tokens shifted left)."""
        packed = self.packed(split)
        rng = np.random.default_rng(seed)
        for _ in range(epochs):
            order = rng.permutation(len(packed))
            for i in range(0, len(order) - (batch_size - 1 if drop_last
                                            else 0), batch_size):
                rows = packed[order[i: i + batch_size]]
                if len(rows) < batch_size and drop_last:
                    break
                toks = rows[:, :-1]
                labels = rows[:, 1:]
                mask = (labels != PAD).astype(np.float32)
                yield toks, labels, mask

    def completion_tasks(self, split: str, n: int, *, seed: int = 0,
                         ctx_lo: float = 0.2, ctx_hi: float = 0.6,
                         max_context: int = 512):
        """Paper §VI-C evaluation protocol: (context_ids, target_ids) pairs
        with the context a sampled fraction of the file."""
        rng = np.random.default_rng(seed)
        toks = [t for t in self.tokens(split) if len(t) >= 16]
        tasks = []
        for i in range(n):
            t = toks[int(rng.integers(len(toks)))]
            cut = sample_context_split(rng, len(t), ctx_lo, ctx_hi)
            ctx = t[max(0, cut - max_context): cut]
            tasks.append((ctx, t[cut:]))
        return tasks
