from repro.data.corpus import CodeGenerator, build_corpus  # noqa: F401
from repro.data.tokenizer import CodeTokenizer  # noqa: F401
from repro.data.pipeline import (CodeCompletionDataset, pack_sequences,  # noqa
                                 sample_context_split)
