"""Synthetic code-corpus generator (offline stand-in for CodeXGlue's
JavaCorpus / PY150 — see DESIGN.md §2/§7).

Grammar-based generation of Java-like and Python-like source files with the
statistical properties that make the paper's observation hold: a mix of
*easy* tokens (keywords, punctuation, indentation — predictable from local
context, learnable by shallow layers) and *hard* tokens (Zipf-distributed
identifiers, call targets — needing deeper context). Deterministic per
(language, seed).

The pipeline consumes any iterable of source strings, so real CodeXGlue
JSONL drops in unchanged (``build_corpus(path=...)``).
"""
from __future__ import annotations

import json
import os
import random
from typing import Iterator

_JAVA_TYPES = ["int", "long", "float", "double", "boolean", "String"]
_PY_BUILTINS = ["len", "range", "print", "sum", "min", "max", "sorted",
                "enumerate", "zip"]
_VERBS = ["get", "set", "compute", "update", "find", "make", "load", "save",
          "parse", "check", "init", "read", "write", "build", "merge"]
_NOUNS = ["value", "index", "count", "result", "data", "item", "node",
          "list", "map", "key", "size", "total", "buffer", "name", "state",
          "config", "entry", "score", "offset", "length"]


class CodeGenerator:
    """Deterministic grammar-based source generator."""

    def __init__(self, language: str = "java", seed: int = 0):
        assert language in ("java", "python")
        self.language = language
        self.rng = random.Random((hash(language) & 0xFFFF) * 7919 + seed)
        # Zipf-weighted identifier pool
        self.idents = [f"{v}{n.capitalize()}" if language == "java"
                       else f"{v}_{n}" for v in _VERBS for n in _NOUNS]
        self.rng.shuffle(self.idents)
        self.vars = _NOUNS + [f"{n}{i}" for n in _NOUNS[:8] for i in "12"]

    # -- helpers ------------------------------------------------------------
    def _zipf_choice(self, pool):
        n = len(pool)
        # P(rank k) ~ 1/(k+1)
        r = self.rng.random()
        total = sum(1.0 / (k + 1) for k in range(n))
        acc = 0.0
        for k in range(n):
            acc += 1.0 / (k + 1) / total
            if r <= acc:
                return pool[k]
        return pool[-1]

    def _var(self):
        return self._zipf_choice(self.vars)

    def _fn(self):
        return self._zipf_choice(self.idents)

    def _num(self):
        return str(self.rng.choice([0, 1, 2, 10, 100, self.rng.randint(0, 64)]))

    def _expr(self, depth=0):
        r = self.rng.random()
        if depth > 2 or r < 0.35:
            return self._var() if self.rng.random() < 0.7 else self._num()
        if r < 0.6:
            op = self.rng.choice(["+", "-", "*", "/", "%"])
            return f"{self._expr(depth + 1)} {op} {self._expr(depth + 1)}"
        args = ", ".join(self._expr(2) for _ in range(self.rng.randint(0, 2)))
        return f"{self._fn()}({args})"

    def _cond(self):
        op = self.rng.choice(["<", ">", "==", "!=", "<=", ">="])
        return f"{self._var()} {op} {self._expr(1)}"

    # -- java ---------------------------------------------------------------
    def _java_stmt(self, indent):
        pad = "    " * indent
        r = self.rng.random()
        if r < 0.35:
            t = self.rng.choice(_JAVA_TYPES)
            return [f"{pad}{t} {self._var()} = {self._expr()};"]
        if r < 0.55:
            return [f"{pad}{self._var()} = {self._expr()};"]
        if r < 0.7:
            body = self._java_stmt(indent + 1)
            v = self._var()
            return ([f"{pad}for (int {v} = 0; {v} < {self._num()}; {v}++) {{"]
                    + body + [f"{pad}}}"])
        if r < 0.85:
            body = self._java_stmt(indent + 1)
            return [f"{pad}if ({self._cond()}) {{"] + body + [f"{pad}}}"]
        return [f"{pad}return {self._expr()};"]

    def _java_method(self):
        t = self.rng.choice(_JAVA_TYPES + ["void"])
        name = self._fn()
        n_args = self.rng.randint(0, 3)
        args = ", ".join(f"{self.rng.choice(_JAVA_TYPES)} {self._var()}"
                         for _ in range(n_args))
        lines = [f"    public {t} {name}({args}) {{"]
        for _ in range(self.rng.randint(2, 6)):
            lines += self._java_stmt(2)
        if t != "void":
            lines.append(f"        return {self._expr()};")
        lines.append("    }")
        return lines

    def _java_file(self):
        cls = self._fn().capitalize()
        lines = [f"// generated corpus file", f"public class {cls} {{"]
        for _ in range(self.rng.randint(1, 3)):
            t = self.rng.choice(_JAVA_TYPES)
            lines.append(f"    private {t} {self._var()};")
        for _ in range(self.rng.randint(2, 5)):
            lines += self._java_method()
            lines.append("")
        lines.append("}")
        return "\n".join(lines)

    # -- python -------------------------------------------------------------
    def _py_stmt(self, indent):
        pad = "    " * indent
        r = self.rng.random()
        if r < 0.4:
            return [f"{pad}{self._var()} = {self._expr()}"]
        if r < 0.55:
            fn = self.rng.choice(_PY_BUILTINS)
            return [f"{pad}{self._var()} = {fn}({self._var()})"]
        if r < 0.7:
            body = self._py_stmt(indent + 1)
            return [f"{pad}for {self._var()} in range({self._num()}):"] + body
        if r < 0.85:
            body = self._py_stmt(indent + 1)
            return [f"{pad}if {self._cond()}:"] + body
        return [f"{pad}return {self._expr()}"]

    def _py_fn(self):
        name = self._fn()
        n_args = self.rng.randint(0, 3)
        args = ", ".join(self._var() for _ in range(n_args))
        lines = [f"def {name}({args}):"]
        for _ in range(self.rng.randint(2, 7)):
            lines += self._py_stmt(1)
        lines.append(f"    return {self._expr()}")
        return lines

    def _py_file(self):
        lines = ["# generated corpus file"]
        for _ in range(self.rng.randint(2, 6)):
            lines += self._py_fn()
            lines.append("")
        return "\n".join(lines)

    # -- public -------------------------------------------------------------
    def generate_file(self) -> str:
        return self._java_file() if self.language == "java" else \
            self._py_file()

    def files(self, n: int) -> Iterator[str]:
        for _ in range(n):
            yield self.generate_file()


def build_corpus(language: str = "java", n_files: int = 500, seed: int = 0,
                 path: str | None = None) -> list[str]:
    """Return a list of source strings.

    If ``path`` points to a CodeXGlue-style JSONL (one {"code": ...} or raw
    string per line) or a directory of source files, the real data is used;
    otherwise the synthetic generator runs.
    """
    if path and os.path.exists(path):
        out = []
        if os.path.isdir(path):
            for fn in sorted(os.listdir(path))[:n_files]:
                with open(os.path.join(path, fn), errors="ignore") as f:
                    out.append(f.read())
            return out
        with open(path, errors="ignore") as f:
            for line in f:
                line = line.strip()
                if not line:
                    continue
                try:
                    obj = json.loads(line)
                    out.append(obj["code"] if isinstance(obj, dict) else obj)
                except json.JSONDecodeError:
                    out.append(line)
                if len(out) >= n_files:
                    break
        return out
    gen = CodeGenerator(language, seed)
    return list(gen.files(n_files))
