"""One policy/request surface for generate, engine, scheduler, RL and server.

Three dataclasses every entry point shares (plus :class:`PolicySpec`
re-exported from :mod:`repro.core.exit_policy`):

``SamplingParams``
    temperature / top_k / top_p / seed. All knobs are runtime values — the
    token picker (:func:`repro.core.early_exit.pick_tokens`) takes them as
    per-row arrays, so one compiled step serves greedy and sampled requests
    side by side with zero recompiles.

``GenerationRequest``
    prompt (text or token ids) + decode budget + exit policy + sampling +
    stop sequences + energy budget + request class. What the HTTP server
    parses into, what ``Scheduler.submit`` / ``Engine.serve_requests``
    accept.

``GenerationResult``
    tokens / text / per-token exit layers / finish reason / energy.

This module stays dependency-light on purpose (dataclasses only — no jax at
import time beyond the registry): ``repro.core`` never imports it, so the
layering is strictly api -> core.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Optional, Sequence, Union

from repro.core.exit_policy import (ExitPolicy, PolicyBatch,  # noqa: F401
                                    PolicyContext, PolicySpec, as_spec,
                                    stack_policies)

TokenIds = Sequence[int]


# ---------------------------------------------------------------------------
# Sampling
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class SamplingParams:
    """Runtime sampling knobs. ``temperature <= 0`` means greedy (argmax).

    ``top_k <= 0`` and ``top_p >= 1`` disable the respective filters. The
    values are data, not trace-time constants: the scheduler carries them in
    per-slot arrays and a request's draw stream is keyed by ``seed`` + token
    position, so results are independent of batch composition.
    """
    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0
    seed: int = 0

    def __post_init__(self):
        # fields may also carry per-row arrays (Engine.serve_requests);
        # validate eagerly only for plain scalars. int32 bounds matter: an
        # out-of-range value would otherwise blow up as an OverflowError
        # inside the scheduler's decode thread and kill it for everyone.
        if isinstance(self.top_p, (int, float)):
            if self.top_p <= 0.0:
                raise ValueError(f"top_p must be > 0, got {self.top_p}")
            if self.top_p > 1.0:
                raise ValueError(f"top_p must be <= 1, got {self.top_p}")
        if isinstance(self.top_k, int):
            if not 0 <= self.top_k < 2 ** 31:
                raise ValueError(f"top_k must be in [0, 2^31), got "
                                 f"{self.top_k}")
        if isinstance(self.seed, int):
            if not -2 ** 31 <= self.seed < 2 ** 31:
                raise ValueError(f"seed must fit int32, got {self.seed}")

    @property
    def greedy(self) -> bool:
        return self.temperature <= 0.0


GREEDY = SamplingParams()


# ---------------------------------------------------------------------------
# Requests / results
# ---------------------------------------------------------------------------
@dataclass
class GenerationRequest:
    """One generation request, shared by every serving entry point.

    ``prompt`` may be raw text (the scheduler/engine tokenizer encodes it)
    or pre-tokenized ids. ``policy`` may be a name, a :class:`PolicySpec`,
    or ``None`` (the serving layer's default policy).
    """
    prompt: Union[str, TokenIds]
    max_new_tokens: int = 15
    policy: Optional[Union[str, PolicySpec]] = None
    sampling: SamplingParams = field(default_factory=SamplingParams)
    stop_sequences: tuple[str, ...] = ()
    energy_budget_j: Optional[float] = None
    request_class: str = "default"

    def __post_init__(self):
        if self.max_new_tokens < 1:
            raise ValueError(
                f"max_new_tokens must be >= 1, got {self.max_new_tokens}")
        if isinstance(self.policy, str):
            self.policy = PolicySpec(self.policy)
        elif self.policy is not None and not isinstance(self.policy,
                                                        PolicySpec):
            raise TypeError(f"policy must be a name, PolicySpec or None, "
                            f"got {type(self.policy).__name__}")
        if isinstance(self.stop_sequences, str):
            raise TypeError("stop_sequences must be a sequence of strings, "
                            "not a single string")
        self.stop_sequences = tuple(str(s) for s in self.stop_sequences)
        if any(not s for s in self.stop_sequences):
            raise ValueError("empty string in stop_sequences")
        if not isinstance(self.sampling, SamplingParams):
            raise TypeError("sampling must be a SamplingParams")

    def spec(self, default: Optional[PolicySpec] = None) -> PolicySpec:
        """The effective policy spec (``default`` fills a ``None`` policy)."""
        if self.policy is not None:
            return self.policy
        return default if default is not None else PolicySpec("none")


@dataclass
class GenerationResult:
    """What every entry point hands back for one request."""
    tokens: list[int]
    exit_layers: list[int]
    finish_reason: str                 # length | eos | stop | energy_budget
    text: Optional[str] = None         # decoded (stop-truncated) text
    energy_j: float = 0.0
    metrics: Any = None                # serving.metrics.RequestMetrics
    request_id: Optional[int] = None
    latency_s: Optional[float] = None
    # serving-layer attribution (zero/None outside the scheduler paths):
    # modeled prompt-ingestion joules and submit→first-token latency
    prefill_energy_j: float = 0.0
    ttft_s: Optional[float] = None
    # the serving layer silently kept only the tail of an over-long prompt
    # (pool geometry / max_context bound) — surfaced, never swallowed
    truncated: bool = False
    # per-token log-probs of the emitted tokens under the distribution
    # that PICKED them — for early-exit rows that is the exited layer's
    # head, not the full-depth model. None when the producing path does
    # not record them (e.g. speculative super-ticks).
    logprobs: Optional[list[float]] = None

    @property
    def n_tokens(self) -> int:
        return len(self.tokens)


def find_stop(text: str, stop_sequences: Sequence[str]
              ) -> Optional[tuple[int, str]]:
    """Earliest stop-sequence hit in ``text`` as (index, sequence), else
    None. Ties at the same index resolve to the longest sequence."""
    best: Optional[tuple[int, str]] = None
    for s in stop_sequences:
        i = text.find(s)
        if i < 0:
            continue
        if best is None or i < best[0] or (i == best[0] and len(s) > len(best[1])):
            best = (i, s)
    return best
