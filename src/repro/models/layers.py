"""Shared primitive layers: norms, embeddings, RoPE, MLP.

All modules are functional: ``init_*`` builds a param pytree, ``apply_*``
consumes it. Params are plain dicts of jnp arrays so they stack cleanly for
scan-over-layers and shard by path-based rules.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config import ModelConfig

Array = jax.Array


def dense_init(key, d_in: int, d_out: int, scale: float | None = None):
    scale = scale if scale is not None else d_in ** -0.5
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale)


def stacked_dense_init(key, n: int, d_in: int, d_out: int, scale=None):
    scale = scale if scale is not None else d_in ** -0.5
    return (jax.random.normal(key, (n, d_in, d_out), jnp.float32) * scale)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------
def init_norm(cfg: ModelConfig, n: int | None = None):
    shape = (cfg.d_model,) if n is None else (n, cfg.d_model)
    if cfg.norm == "layernorm":
        return {"scale": jnp.ones(shape), "bias": jnp.zeros(shape)}
    return {"scale": jnp.ones(shape)}


def apply_norm(p, x: Array, eps: float = 1e-6) -> Array:
    xf = x.astype(jnp.float32)
    if "bias" in p:  # layernorm
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
        y = y * p["scale"] + p["bias"]
    else:  # rmsnorm
        ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + eps) * p["scale"]
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# Embeddings
# ---------------------------------------------------------------------------
def padded_vocab(cfg: ModelConfig, multiple: int = 256) -> int:
    """Vocab rounded up so the embedding/head shard over the model axis
    (e.g. 49155 -> 49408). Padded logit columns are masked to -inf in
    lm_logits; ids never reach the padding."""
    return -(-cfg.vocab_size // multiple) * multiple


def init_embed(key, cfg: ModelConfig):
    k1, k2 = jax.random.split(key)
    p = {"tok": jax.random.normal(
        k1, (padded_vocab(cfg), cfg.d_model)) * 0.02}
    if cfg.positional == "learned":
        p["pos"] = jax.random.normal(k2, (cfg.max_position, cfg.d_model)) * 0.02
    return p


def embed_tokens(p, cfg: ModelConfig, tokens: Array, pos_offset=0) -> Array:
    h = jnp.take(p["tok"], tokens, axis=0)
    if cfg.positional == "learned":
        positions = pos_offset + jnp.arange(tokens.shape[-1])
        positions = jnp.clip(positions, 0, cfg.max_position - 1)
        h = h + jnp.take(p["pos"], positions, axis=0)
    return h


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------
def rope_freqs(dim: int, theta: float, positions: Array) -> tuple[Array, Array]:
    """Return (cos, sin) of shape [len(positions), dim//2], float32."""
    inv = 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    ang = positions.astype(jnp.float32)[:, None] * inv[None, :]
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: Array, cos: Array, sin: Array) -> Array:
    """x: [..., S, H, D]; cos/sin: [S, D//2] (broadcast over batch/heads)."""
    d = x.shape[-1]
    x1, x2 = x[..., : d // 2], x[..., d // 2:]
    # broadcast cos/sin over batch and head dims: [S, 1, D//2]
    c = cos[:, None, :]
    s = sin[:, None, :]
    y1 = x1 * c - x2 * s
    y2 = x2 * c + x1 * s
    return jnp.concatenate([y1, y2], axis=-1).astype(x.dtype)


# ---------------------------------------------------------------------------
# Dense MLP (gated SwiGLU-style or plain)
# ---------------------------------------------------------------------------
def init_mlp(key, cfg: ModelConfig, n: int | None = None, d_ff: int | None = None):
    d_ff = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    mk = (lambda k, a, b: stacked_dense_init(k, n, a, b)) if n is not None \
        else (lambda k, a, b: dense_init(k, a, b))
    p = {"up": mk(ks[0], cfg.d_model, d_ff),
         "down": mk(ks[1], d_ff, cfg.d_model)}
    if cfg.mlp_gated:
        p["gate"] = mk(ks[2], cfg.d_model, d_ff)
    if cfg.use_bias:
        bshape = lambda d: (d,) if n is None else (n, d)  # noqa: E731
        p["up_b"] = jnp.zeros(bshape(d_ff))
        p["down_b"] = jnp.zeros(bshape(cfg.d_model))
    return p


def _act(cfg: ModelConfig, x: Array) -> Array:
    if cfg.activation == "silu":
        return jax.nn.silu(x)
    if cfg.activation == "gelu":
        return jax.nn.gelu(x)
    return jax.nn.relu(x)


def apply_mlp(p, cfg: ModelConfig, x: Array) -> Array:
    up = x @ p["up"]
    if "up_b" in p:
        up = up + p["up_b"]
    if "gate" in p:
        up = _act(cfg, x @ p["gate"]) * up
    else:
        up = _act(cfg, up)
    out = up @ p["down"]
    if "down_b" in p:
        out = out + p["down_b"]
    return out


def softcap(x: Array, cap: float) -> Array:
    if cap and cap > 0:
        return jnp.tanh(x / cap) * cap
    return x
