"""Mamba2 (SSD — state-space duality) mixer.

Implements the chunked SSD algorithm of arXiv:2405.21060: within a chunk the
sequence mixing is a small quadratic attention-like matmul (MXU-friendly);
across chunks a cheap ``lax.scan`` carries the [H, P, N] recurrent state.
This pure-jnp implementation doubles as the oracle for the Pallas
``ssd_scan`` kernel (kernels/ssd_scan.py).

Decode is the exact SSD recurrence: constant-size state
``h_t = h_{t-1}·exp(dt·A) + dt·(B ⊗ x)``, ``y = C·h + D·x`` — no KV cache,
which is what makes mamba2/zamba2 runnable at 500k context.

Layout: d_inner = expand·d_model, H = d_inner/head_dim heads of dim P,
B/C projections of state dim N shared across heads (multi-value attention
analogue in the SSD duality).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models.layers import dense_init, stacked_dense_init

Array = jax.Array


def _dims(cfg: ModelConfig):
    s = cfg.ssm
    d_in = cfg.d_model * s.expand
    nheads = d_in // s.head_dim
    conv_ch = d_in + 2 * s.state_dim  # conv runs over (x, B, C)
    return d_in, nheads, conv_ch


def init_mamba(key, cfg: ModelConfig, n: int | None = None):
    s = cfg.ssm
    d = cfg.d_model
    d_in, H, conv_ch = _dims(cfg)
    ks = jax.random.split(key, 4)
    # in_proj order: [z(d_in), x(d_in), B(N), C(N), dt(H)]
    d_proj = 2 * d_in + 2 * s.state_dim + H
    mk = (lambda k, a, b: stacked_dense_init(k, n, a, b)) if n is not None \
        else (lambda k, a, b: dense_init(k, a, b))
    pre = (n,) if n is not None else ()
    p = {
        "in_proj": mk(ks[0], d, d_proj),
        "out_proj": mk(ks[1], d_in, d),
        "conv_w": jax.random.normal(ks[2], (*pre, s.conv_dim, conv_ch)) * 0.2,
        "conv_b": jnp.zeros((*pre, conv_ch)),
        # A in (-1, 0): A = -exp(A_log); init A in [-1, -0.5]
        "A_log": jnp.log(jnp.broadcast_to(
            jnp.linspace(0.5, 1.0, H), (*pre, H)).copy()),
        "D": jnp.ones((*pre, H)),
        "dt_bias": jnp.broadcast_to(
            jnp.log(jnp.expm1(jnp.linspace(0.001, 0.1, H))), (*pre, H)).copy(),
        "gate_norm": jnp.ones((*pre, d_in)),
    }
    return p


def _split_proj(cfg: ModelConfig, proj: Array):
    s = cfg.ssm
    d_in, H, _ = _dims(cfg)
    N = s.state_dim
    z = proj[..., :d_in]
    xbc = proj[..., d_in: 2 * d_in + 2 * N]
    dt = proj[..., 2 * d_in + 2 * N:]
    return z, xbc, dt


def _gated_norm(p, y: Array, z: Array, eps: float = 1e-6) -> Array:
    """Mamba2 RMSNormGated: rmsnorm(y * silu(z)) * scale."""
    g = (y * jax.nn.silu(z)).astype(jnp.float32)
    ms = jnp.mean(jnp.square(g), axis=-1, keepdims=True)
    return (g * jax.lax.rsqrt(ms + eps) * p["gate_norm"]).astype(y.dtype)


def _causal_conv(cfg: ModelConfig, p, xbc: Array) -> Array:
    """Depthwise causal conv over the sequence. xbc: [B, S, C]."""
    s = cfg.ssm
    w = p["conv_w"]                                     # [K, C]
    pad = s.conv_dim - 1
    xp = jnp.pad(xbc, ((0, 0), (pad, 0), (0, 0)))
    # depthwise: sum_k w[k, c] * x[t - (K-1) + k, c]
    out = sum(xp[:, k: k + xbc.shape[1], :] * w[k] for k in range(s.conv_dim))
    return jax.nn.silu(out + p["conv_b"])


def segsum(a: Array) -> Array:
    """Stable segment-sum: out[..., i, j] = sum_{k=j+1..i} a[..., k], -inf j>i.

    a: [..., Q]; returns [..., Q, Q] lower-triangular log-decay matrix.
    """
    Q = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((Q, Q), bool), 0)
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x: Array, dt: Array, A: Array, B: Array, C: Array,
                chunk: int, h0: Array | None = None):
    """Chunked SSD scan.

    x: [Bt, S, H, P]  (already multiplied by nothing; dt applied inside)
    dt: [Bt, S, H] (positive), A: [H] (negative), B, C: [Bt, S, N].
    Returns (y [Bt, S, H, P], h_final [Bt, H, P, N]).
    """
    Bt, S, H, P = x.shape
    N = B.shape[-1]
    Q = min(chunk, S)
    n_chunks = (S + Q - 1) // Q
    pad = n_chunks * Q - S
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))

    def reshape_c(t):
        return t.reshape(Bt, n_chunks, Q, *t.shape[2:]).swapaxes(0, 1)

    xc, dtc, Bc, Cc = map(reshape_c, (x, dt, B, C))       # [nc, Bt, Q, ...]
    da = dtc * A                                           # [nc, Bt, Q, H]
    xbar = xc * dtc[..., None]                             # dt-weighted input

    # intra-chunk (dual quadratic form), computed for all chunks at once
    L = jnp.exp(segsum(da.swapaxes(-1, -2)))               # [nc,Bt,H,Q,Q]
    scores = jnp.einsum("cbin,cbjn->cbij", Cc, Bc)         # [nc,Bt,Q,Q]
    M = scores[:, :, None] * L                             # [nc,Bt,H,Q,Q]
    y_intra = jnp.einsum("cbhij,cbjhp->cbihp", M, xbar)

    # chunk-final states: S_c = sum_j exp(sum_{k>j} da) B_j x̄_j
    cum = jnp.cumsum(da, axis=2)
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)        # [nc,Bt,Q,H]
    states = jnp.einsum("cbjn,cbjh,cbjhp->cbhpn",
                        Bc, decay_to_end, xbar)            # [nc,Bt,H,P,N]
    chunk_decay = jnp.exp(cum[:, :, -1, :])                # [nc,Bt,H]

    def carry_fn(h, inp):
        s_c, dec = inp                                     # dec: [Bt, H]
        h_out = h                                          # state entering chunk
        h = h * dec[..., None, None] + s_c
        return h, h_out

    h_init = (jnp.zeros((Bt, H, P, N), x.dtype) if h0 is None
              else h0.astype(x.dtype))
    h_last, h_in = jax.lax.scan(carry_fn, h_init, (states, chunk_decay))
    # inter-chunk contribution: y_i += C_i · (decay_in_i · h_in)
    decay_in = jnp.exp(cum)                                # [nc,Bt,Q,H]
    y_inter = jnp.einsum("cbin,cbhpn,cbih->cbihp", Cc, h_in, decay_in)

    y = (y_intra + y_inter).swapaxes(0, 1).reshape(Bt, n_chunks * Q, H, P)
    if pad:
        y = y[:, :S]
    return y, h_last


def apply_mamba_train(p, cfg: ModelConfig, x: Array, *, return_cache=False):
    """Full-sequence SSD. x: [B, S, D] -> (y [B, S, D], cache|None)."""
    s = cfg.ssm
    d_in, H, _ = _dims(cfg)
    proj = x @ p["in_proj"]
    z, xbc_raw, dt = _split_proj(cfg, proj)
    xbc = _causal_conv(cfg, p, xbc_raw)
    xs = xbc[..., :d_in]
    Bs = xbc[..., d_in: d_in + s.state_dim]
    Cs = xbc[..., d_in + s.state_dim:]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    Bt, S, _ = x.shape
    xh = xs.reshape(Bt, S, H, s.head_dim)
    y, h_last = ssd_chunked(xh.astype(jnp.float32), dt, A,
                            Bs.astype(jnp.float32), Cs.astype(jnp.float32),
                            s.chunk_size)
    y = y + xh.astype(jnp.float32) * p["D"][:, None]
    y = y.reshape(Bt, S, d_in).astype(x.dtype)
    y = _gated_norm(p, y, z)
    out = y @ p["out_proj"]
    if not return_cache:
        return out, None
    # decode cache: final recurrent state + conv tail (pre-activation inputs)
    K = s.conv_dim - 1
    tail = xbc_raw[:, -K:, :]
    if S < K:
        tail = jnp.pad(xbc_raw, ((0, 0), (K - S, 0), (0, 0)))
    return out, {"state": h_last, "conv": tail}


def init_mamba_cache(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    """Decode cache: recurrent state + conv ring buffer."""
    s = cfg.ssm
    d_in, H, conv_ch = _dims(cfg)
    return {
        "state": jnp.zeros((batch, H, s.head_dim, s.state_dim), jnp.float32),
        "conv": jnp.zeros((batch, s.conv_dim - 1, conv_ch), dtype),
    }


def apply_mamba_chunk(p, cfg: ModelConfig, x: Array, cache, pos0: Array,
                      n_valid: Array):
    """Chunk-prefill step: per-token SSD recurrence over a ``[B, C, D]``
    chunk, carrying ``(recurrent state, conv tail)`` chunk-to-chunk.

    Every per-position op (conv tap-sum, dt/decay, the scanned h update)
    has a fixed reduction extent, so the result is bit-identical for ANY
    chunk grid — including the one-chunk whole-prompt case the parity
    tests use as reference. Positions at/after ``n_valid`` (final-chunk
    padding) are neutralized by forcing ``dt = 0``: ``decay = exp(0) = 1``
    exactly and the state-update term vanishes, so the carried state
    passes through pad rows bitwise unchanged.

    x: [B, C, D]; cache: ``init_mamba_cache`` layout (state f32, conv
    tail of *pre-activation* xbc rows); pos0/n_valid: [B] int32.
    Returns (y [B, C, D], new cache). Output rows past ``n_valid`` are
    garbage and must be masked by the caller (the scheduler only reads
    the last valid position's logits).
    """
    s = cfg.ssm
    d_in, H, _ = _dims(cfg)
    B, C, _ = x.shape
    K = s.conv_dim - 1
    proj = x @ p["in_proj"]                             # [B, C, d_proj]
    z, xbc_raw, dt = _split_proj(cfg, proj)
    # causal conv over [carried tail | chunk]: position pos0+j reads
    # rows j..j+K of the concatenated window — same tap-sum chain as
    # _causal_conv, with the carry replacing the zero left-pad
    full = jnp.concatenate([cache["conv"].astype(xbc_raw.dtype), xbc_raw],
                           axis=1)                      # [B, K+C, ch]
    w = p["conv_w"]
    conv = sum(full[:, k: k + C, :] * w[k] for k in range(s.conv_dim))
    xbc = jax.nn.silu(conv + p["conv_b"])
    xs = xbc[..., :d_in]
    Bs = xbc[..., d_in: d_in + s.state_dim].astype(jnp.float32)
    Cs = xbc[..., d_in + s.state_dim:].astype(jnp.float32)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # [B, C, H]
    idx = pos0[:, None] + jnp.arange(C, dtype=pos0.dtype)        # [B, C]
    valid = idx < n_valid[:, None]
    dt = jnp.where(valid[..., None], dt, 0.0)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))                 # [H]
    xh = xs.reshape(B, C, H, s.head_dim).astype(jnp.float32)
    decay = jnp.exp(dt * A)                                      # [B, C, H]

    def step(h, inp):
        dt_t, xh_t, B_t, C_t, dec_t = inp
        h = h * dec_t[..., None, None] + jnp.einsum(
            "bh,bhp,bn->bhpn", dt_t, xh_t, B_t)
        y_t = jnp.einsum("bhpn,bn->bhp", h, C_t)
        return h, y_t

    per_t = jax.tree.map(lambda t: jnp.moveaxis(t, 1, 0),
                         (dt, xh, Bs, Cs, decay))
    h_last, ys = jax.lax.scan(step, cache["state"], per_t)
    y = jnp.moveaxis(ys, 0, 1) + xh * p["D"][:, None]            # [B,C,H,P]
    y = y.reshape(B, C, d_in).astype(x.dtype)
    y = _gated_norm(p, y, z)
    out = y @ p["out_proj"]
    # conv tail = raw xbc rows of the last K *valid* positions: rows
    # [v, v+K) of the concatenated window, v = clip(n_valid - pos0, 0, C)
    # (v clips to C on non-final chunks; short prompts pick up the
    # zero-initialized carry rows, matching apply_mamba_train's left-pad)
    v = jnp.clip(n_valid - pos0, 0, C)
    tail_idx = v[:, None] + jnp.arange(K, dtype=v.dtype)         # [B, K]
    tail = jnp.take_along_axis(full, tail_idx[..., None], axis=1)
    return out, {"state": h_last, "conv": tail.astype(cache["conv"].dtype)}


def apply_mamba_decode(p, cfg: ModelConfig, x: Array, cache):
    """One-token SSD recurrence. x: [B, 1, D] -> (y [B, 1, D], new cache)."""
    s = cfg.ssm
    d_in, H, _ = _dims(cfg)
    proj = x[:, 0] @ p["in_proj"]                       # [B, d_proj]
    z, xbc, dt = _split_proj(cfg, proj)
    # causal conv via ring buffer: window = [cache, current]
    win = jnp.concatenate([cache["conv"], xbc[:, None, :]], axis=1)
    w = p["conv_w"]                                     # [K, C]
    conv_out = jnp.einsum("bkc,kc->bc", win, w) + p["conv_b"]
    xbc = jax.nn.silu(conv_out)
    xs = xbc[..., :d_in]
    Bs = xbc[..., d_in: d_in + s.state_dim].astype(jnp.float32)
    Cs = xbc[..., d_in + s.state_dim:].astype(jnp.float32)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])   # [B, H]
    A = -jnp.exp(p["A_log"].astype(jnp.float32))                  # [H]
    xh = xs.reshape(-1, H, s.head_dim).astype(jnp.float32)        # [B, H, P]
    decay = jnp.exp(dt * A)                                       # [B, H]
    h = cache["state"] * decay[..., None, None] + jnp.einsum(
        "bh,bhp,bn->bhpn", dt, xh, Bs)
    y = jnp.einsum("bhpn,bn->bhp", h, Cs) + xh * p["D"][:, None]
    y = y.reshape(-1, d_in).astype(x.dtype)
    y = _gated_norm(p, y[:, None, :], z[:, None, :])[:, 0]
    out = (y @ p["out_proj"])[:, None, :]
    new_cache = {"state": h, "conv": win[:, 1:]}
    return out, new_cache
