"""Mixture-of-Experts FFN with capacity-based token dispatch.

Expert-parallel design (MaxText-style): top-k routing builds one-hot
dispatch/combine tensors of shape [T, E, C]; expert FFNs run as batched
matmuls over [E, C, d]. With experts sharded on the ``model`` mesh axis the
dispatch einsums lower to the expert all-to-all pattern. Compute scales with
top-k (active experts), not total experts — so roofline numbers reflect the
true active FLOPs, unlike a dense "run every expert" emulation.

Shared experts (qwen2-moe) run densely for every token.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models.layers import _act, stacked_dense_init
from repro.sharding import constrain

Array = jax.Array


def padded_experts(cfg: ModelConfig, multiple: int = 16) -> int:
    """Experts rounded up to a multiple of the model-axis size (40 -> 48,
    60 -> 64) so expert weights and dispatch buffers shard expert-parallel;
    padded experts get -inf router logits and are never selected."""
    return -(-cfg.moe.num_experts // multiple) * multiple


def init_moe(key, cfg: ModelConfig, n: int | None = None):
    m = cfg.moe
    ks = jax.random.split(key, 7)
    E, dff, d = padded_experts(cfg), m.d_ff_expert, cfg.d_model

    def mk(k, *shape):
        scale = shape[-2] ** -0.5
        return jax.random.normal(k, shape, jnp.float32) * scale

    pre = (n,) if n is not None else ()
    p = {
        "router": mk(ks[0], *pre, d, E),
        "gate": mk(ks[1], *pre, E, d, dff),
        "up": mk(ks[2], *pre, E, d, dff),
        "down": mk(ks[3], *pre, E, dff, d),
    }
    if m.num_shared_experts:
        S = m.num_shared_experts
        p["shared_gate"] = mk(ks[4], *pre, d, S * dff)
        p["shared_up"] = mk(ks[5], *pre, d, S * dff)
        p["shared_down"] = mk(ks[6], *pre, S * dff, d)
    return p


def _capacity(num_tokens: int, num_experts: int, k: int,
              factor: float = 1.25) -> int:
    c = int(num_tokens * k * factor / num_experts) + 1
    return max(c, k, 4)


def dropless_capacity_factor(cfg: ModelConfig) -> float:
    """Capacity factor guaranteeing zero token drops for ANY routing:
    f = E/K makes ``_capacity`` >= T, so every (token, k) pair gets an
    expert slot regardless of how skewed the router is. With no drops the
    per-token output is independent of which tokens share the batch — the
    invariance chunked prefill needs (the chunk grid must not change
    routing). Costs an [E, T+1, D] dispatch buffer, fine at chunk scale;
    full-length training/decode paths keep ``_moe_capacity_factor``."""
    return float(cfg.moe.num_experts) / cfg.moe.num_experts_per_tok


def apply_moe(p, cfg: ModelConfig, x: Array, *, capacity_factor: float = 1.25):
    """x: [B, S, D] -> (y [B, S, D], aux_loss scalar).

    Scatter/gather dispatch (MegaBlocks-style, linear in tokens): token
    vectors are scattered into per-expert capacity buffers [E, C, D] and
    gathered back with their gate weights. Memory is O(T·K + E·C·D) — the
    classic one-hot [T, E, C] dispatch is O(T²·K) since C grows with T.
    With experts (or their d_ff) sharded on the ``model`` axis the scatter
    lowers to the expert all-to-all pattern.
    """
    m = cfg.moe
    B, S, D = x.shape
    T = B * S
    E, K = m.num_experts, m.num_experts_per_tok
    xt = x.reshape(T, D)

    Ep = p["router"].shape[-1]                               # padded experts
    logits = (xt @ p["router"]).astype(jnp.float32)          # [T, Ep]
    if Ep != E:
        logits = jnp.where(jnp.arange(Ep) < E, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, idx = jax.lax.top_k(probs, K)                 # [T, K]
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(axis=-1, keepdims=True), 1e-9)         # renormalize

    C = _capacity(T, E, K, capacity_factor)
    onehot = jax.nn.one_hot(idx, Ep, dtype=jnp.int32)        # [T, K, Ep]
    # position of each (token, k) within its expert queue
    pos_in_e = (jnp.cumsum(onehot.reshape(T * K, Ep), axis=0)
                .reshape(T, K, Ep) - 1)                      # [T, K, Ep]
    slot = (pos_in_e * onehot).sum(-1)                       # [T, K]
    within = (slot < C) & (slot >= 0)
    # scatter tokens into expert buffers; overflow slots -> index C (drop)
    flat_e = idx.reshape(-1)
    flat_s = jnp.where(within, slot, C).reshape(-1)
    flat_t = jnp.repeat(jnp.arange(T), K)
    xe = jnp.zeros((Ep, C, D), x.dtype)
    xe = xe.at[flat_e, flat_s].set(xt[flat_t], mode="drop")  # [E, C, D]
    xe = constrain(xe, "experts", None, "embed")   # expert-parallel dispatch
    hg = _act(cfg, jnp.einsum("ecd,edf->ecf", xe, p["gate"]))
    hu = jnp.einsum("ecd,edf->ecf", xe, p["up"])
    hg = constrain(hg, "experts", None, "ff")
    ye = jnp.einsum("ecf,efd->ecd", hg * hu, p["down"])      # [E, C, D]
    # gather back with gate weights
    yk = ye[idx, jnp.where(within, slot, 0)]                 # [T, K, D]
    yk = yk * (gate_vals * within).astype(x.dtype)[..., None]
    y = yk.sum(axis=1)                                       # [T, D]

    # load-balance auxiliary loss (Switch-style, real experts only)
    frac_tokens = jnp.mean(
        jax.nn.one_hot(idx[:, 0], E, dtype=jnp.float32), axis=0)
    frac_prob = jnp.mean(probs[:, :E], axis=0)
    aux = m.router_aux_coef * E * jnp.sum(frac_tokens * frac_prob)

    if m.num_shared_experts:
        hg = _act(cfg, xt @ p["shared_gate"]) * (xt @ p["shared_up"])
        y = y + hg @ p["shared_down"]

    return y.reshape(B, S, D), aux
