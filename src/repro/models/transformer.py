"""Decoder composition: segments, exit-aligned layer scan, prefill, decode.

The layer stack is partitioned into *segments* whose boundaries are exactly
the paper's exit points (core/exit_points.py). Each uniform segment is a
``lax.scan`` over stacked per-layer params, so

  * the lowered HLO is O(#segments) not O(depth), and
  * the hidden state after every segment — i.e. at every exit point — falls
    out of the forward pass for free (used by the LITE loss and the RL
    rollout cache).

Heterogeneous segments (e.g. gemma2 local/global pairs, zamba2 mamba+shared
blocks) are unrolled; they are at most ``second_half_stride`` layers long.

Decode (`decode_step`) implements the paper's dynamic early exit under SPMD:
per-token exits are *predicated* — once a token's controller says exit, its
hidden state freezes, but every remaining layer still projects K/V from the
frozen hidden state into the cache (CALM-style propagation, paper §VI-G), so
subsequent tokens attend to a complete cache. The energy model
(core/energy.py) accounts saved FLOPs from the recorded per-token exit
layer.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from functools import partial
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.config import (FFN_DENSE, FFN_MOE, FFN_NONE, MIXER_GQA,
                          MIXER_GQA_LOCAL, MIXER_MAMBA, MIXER_MLA,
                          MIXER_SHARED_GQA, LayerSpec, ModelConfig)
from repro.core.exit_points import segment_boundaries
from repro.models import ssm
from repro.models.attention import (NEG_INF, apply_gqa_decode,
                                    apply_gqa_train, apply_mla_decode,
                                    apply_mla_train, decode_qkv, init_gqa,
                                    init_mla, mla_chunk_attend, mla_chunk_qkv,
                                    window_qkv)
from repro.models.layers import (apply_mlp, apply_norm, embed_tokens,
                                 init_embed, init_mlp, init_norm,
                                 padded_vocab, softcap)
from repro.models.moe import apply_moe, dropless_capacity_factor, init_moe
from repro.sharding import constrain

Array = jax.Array


# ---------------------------------------------------------------------------
# Segmentation
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class Segment:
    start: int               # first layer (0-indexed, inclusive)
    end: int                 # last layer (exclusive) == an exit boundary
    specs: tuple[LayerSpec, ...]
    scanned: bool            # True -> params stacked, lax.scan over layers

    @property
    def length(self) -> int:
        return self.end - self.start


def plan_segments(cfg: ModelConfig) -> tuple[Segment, ...]:
    bounds = segment_boundaries(cfg)
    segs = []
    prev = 0
    for b in bounds:
        specs = cfg.block_pattern[prev:b]
        uniform = all(s == specs[0] for s in specs)
        shared = any(s.mixer == MIXER_SHARED_GQA for s in specs)
        segs.append(Segment(prev, b, tuple(specs),
                            scanned=uniform and not shared and len(specs) > 1))
        prev = b
    return tuple(segs)


def _window_for(cfg: ModelConfig, spec: LayerSpec) -> int:
    if spec.mixer == MIXER_GQA_LOCAL:
        return cfg.sliding_window
    if (spec.mixer in (MIXER_SHARED_GQA, MIXER_MLA)
            and cfg.name.endswith("+win")):
        return cfg.sliding_window
    return 0


# ---------------------------------------------------------------------------
# int8 KV-cache quantization (beyond-paper; cfg.kv_cache_dtype == "int8")
# ---------------------------------------------------------------------------
def _quant_kv(x):
    """[..., KH, hd] -> (int8 values, per-(slot, head) f32 scale)."""
    sc = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1) / 127.0
    q = jnp.round(x.astype(jnp.float32)
                  / jnp.maximum(sc[..., None], 1e-8)).astype(jnp.int8)
    return q, sc


def _dequant_kv(q, sc, dtype):
    # multiply in the target dtype — an f32 intermediate would materialize
    # cache-sized f32 buffers per layer (measured in §Perf iteration B2);
    # on real TPU the int8 cache should instead be dequantized in-VMEM by
    # the flash_decode Pallas kernel.
    return q.astype(dtype) * sc[..., None].astype(dtype)


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------
def _init_layer(key, cfg: ModelConfig, spec: LayerSpec, n: int | None):
    ks = jax.random.split(key, 4)
    p: dict[str, Any] = {"norm1": init_norm(cfg, n)}
    if spec.mixer == MIXER_MAMBA:
        p["mixer"] = ssm.init_mamba(ks[0], cfg, n)
    elif spec.mixer == MIXER_MLA:
        p["mixer"] = init_mla(ks[0], cfg, n)
    elif spec.mixer == MIXER_SHARED_GQA:
        pass  # weights live at the top level (params["shared_attn"])
    else:
        p["mixer"] = init_gqa(ks[0], cfg, n)
    if spec.ffn != FFN_NONE:
        p["norm2"] = init_norm(cfg, n)
        if spec.ffn == FFN_MOE:
            # nested under "moe" so sharding PARAM_RULES can distinguish
            # expert tensors [E, d, f] from dense MLP tensors [d, f]
            p["ffn"] = {"moe": init_moe(ks[1], cfg, n)}
        else:
            p["ffn"] = init_mlp(ks[1], cfg, n)
    return p


def init_params(key, cfg: ModelConfig, dtype=jnp.float32):
    segs = plan_segments(cfg)
    keys = jax.random.split(key, len(segs) + 3)
    params: dict[str, Any] = {"embed": init_embed(keys[0], cfg)}
    if any(s.mixer == MIXER_SHARED_GQA for s in cfg.block_pattern):
        params["shared_attn"] = init_gqa(keys[1], cfg, None)
    seg_params = []
    for i, seg in enumerate(segs):
        k = keys[2 + i]
        if seg.scanned:
            seg_params.append(_init_layer(k, cfg, seg.specs[0], seg.length))
        else:
            lks = jax.random.split(k, seg.length)
            seg_params.append([_init_layer(lks[j], cfg, seg.specs[j], None)
                               for j in range(seg.length)])
    params["segments"] = seg_params
    params["final_norm"] = init_norm(cfg)
    if not cfg.tie_embeddings:
        params["head"] = (jax.random.normal(
            keys[-1], (cfg.d_model, padded_vocab(cfg)))
            * cfg.d_model ** -0.5)
    if dtype != jnp.float32:
        params = jax.tree.map(lambda x: x.astype(dtype), params)
    return params


def head_matrix(params, cfg: ModelConfig) -> Array:
    if cfg.tie_embeddings:
        return params["embed"]["tok"].T
    return params["head"]


def lm_logits(params, cfg: ModelConfig, h: Array) -> Array:
    """Final-norm + (single, shared) LM head; gemma2 final softcap.

    Returns logits over the *padded* vocab (multiple of 256) with padding
    columns at -inf — downstream argmax/softmax/CE are unaffected and the
    vocab dim shards cleanly over the model axis."""
    h = apply_norm(params["final_norm"], h)
    logits = h @ head_matrix(params, cfg)
    logits = softcap(logits, cfg.final_logit_softcap)
    pv = logits.shape[-1]
    if pv != cfg.vocab_size:
        col = jnp.arange(pv)
        logits = jnp.where(col < cfg.vocab_size, logits,
                           jnp.asarray(-1e30, logits.dtype))
    if logits.ndim == 3:
        return constrain(logits, "batch", "seq_mp", "vocab")
    return constrain(logits, "batch", "vocab")


# ---------------------------------------------------------------------------
# Single-layer application
# ---------------------------------------------------------------------------
def _moe_capacity_factor(cfg: ModelConfig, inference: bool) -> float:
    """Training uses the standard 1.25 capacity factor (tokens may drop).

    Inference uses 2.0: effectively dropless at decode/small-batch scales
    (prefill/decode parity holds while T*K*2/E >= max expert load, always
    true in our test regimes) while keeping the [T, E, C] dispatch bounded
    at prefill scale — a fully dropless E/K factor makes C = T, i.e. an
    O(T^2*E) dispatch tensor (31 TiB/device at 1M prefill tokens)."""
    if inference:
        return min(2.0,
                   float(cfg.moe.num_experts) / cfg.moe.num_experts_per_tok)
    return cfg.moe.train_capacity_factor


def _apply_layer_full(lp, shared_p, cfg: ModelConfig, spec: LayerSpec,
                      h: Array, *, want_cache: bool, inference: bool = False,
                      pos_offset: int = 0):
    """Full-sequence layer. Returns (h, cache_or_None, aux)."""
    window = _window_for(cfg, spec)
    aux = jnp.zeros((), jnp.float32)
    x = apply_norm(lp["norm1"], h)
    cache = None
    if spec.mixer == MIXER_MAMBA:
        out, cache = ssm.apply_mamba_train(lp["mixer"], cfg, x,
                                           return_cache=want_cache)
    elif spec.mixer == MIXER_MLA:
        out, (latent, krope) = apply_mla_train(lp["mixer"], cfg, x,
                                               window=window,
                                               pos_offset=pos_offset)
        if want_cache:
            cache = {"latent": latent, "krope": krope}
    else:
        mp = shared_p if spec.mixer == MIXER_SHARED_GQA else lp["mixer"]
        out, (k, v) = apply_gqa_train(mp, cfg, x, window=window,
                                      pos_offset=pos_offset)
        if want_cache:
            cache = {"k": k, "v": v}
    h = h + out
    if spec.ffn != FFN_NONE:
        x = apply_norm(lp["norm2"], h)
        if spec.ffn == FFN_MOE:
            y, aux = apply_moe(lp["ffn"]["moe"], cfg, x,
                               capacity_factor=_moe_capacity_factor(
                                   cfg, inference=inference or want_cache))
        else:
            y = apply_mlp(lp["ffn"], cfg, x)
        h = h + y
    h = constrain(h, "batch", "seq", "embed")
    return h, cache, aux


def _paged_insert(cache, blk: Array, off: Array, k_new: Array, v_new: Array,
                  write_mask: Optional[Array] = None):
    """Scatter one token's K/V per row into block planes at (blk, off).

    ``write_mask`` [B] bool: rows with False never write — their index is
    pushed out of range and dropped (the speculative verify step shares one
    fixed-shape batch with rows whose caches it must not touch)."""
    if write_mask is not None:
        blk = jnp.where(write_mask, blk, cache["k"].shape[0])
    if "k_s" in cache:
        kq, ks = _quant_kv(k_new[:, 0])
        vq, vs = _quant_kv(v_new[:, 0])
        return {"k": cache["k"].at[blk, off].set(kq, mode="drop"),
                "v": cache["v"].at[blk, off].set(vq, mode="drop"),
                "k_s": cache["k_s"].at[blk, off].set(ks, mode="drop"),
                "v_s": cache["v_s"].at[blk, off].set(vs, mode="drop")}
    return {"k": cache["k"].at[blk, off].set(k_new[:, 0], mode="drop"),
            "v": cache["v"].at[blk, off].set(v_new[:, 0], mode="drop")}


def _paged_gqa_decode(mp, cfg: ModelConfig, x: Array, cache, pos: Array,
                      tables: Array, use_kernel: bool,
                      write_mask: Optional[Array] = None):
    """One-token GQA decode against paged cache planes.

    cache leaves are [num_blocks, block_size, ...]; ``tables`` [B, nb] maps
    each row's logical blocks to physical ones. The reference path gathers
    the chain and reuses ``apply_gqa_decode`` verbatim (attend-then-insert
    with an explicit self term) so its arithmetic — and therefore its
    tokens/logits — is bit-identical to the contiguous ring path. The
    kernel path inserts first, then runs the Pallas paged flash kernel
    (insert-then-attend; same math, flash-accumulated).
    """
    B = x.shape[0]
    num_blocks, bs = cache["k"].shape[:2]
    int8 = "k_s" in cache
    tbl = jnp.clip(jnp.asarray(tables, jnp.int32), 0, num_blocks - 1)
    blk = jnp.take_along_axis(tbl, (pos // bs)[:, None], axis=1)[:, 0]
    off = pos % bs
    if use_kernel:
        # ops.py owns kernel dispatch: interpret off on real TPU,
        # REPRO_KERNELS=ref forces the oracle
        from repro.kernels.ops import paged_flash_decode
        q, k_new, v_new = decode_qkv(mp, cfg, x, pos)
        new_cache = _paged_insert(cache, blk, off, k_new, v_new, write_mask)
        KH = cfg.num_kv_heads
        qr = q.reshape(B, KH, cfg.num_heads // KH, cfg.head_dim)
        scales = ((new_cache["k_s"], new_cache["v_s"]) if int8
                  else (None, None))
        o = paged_flash_decode(qr, new_cache["k"], new_cache["v"], tbl, pos,
                               *scales, softcap=cfg.attn_logit_softcap)
        out = o.reshape(B, 1, cfg.q_dim) @ mp["wo"]
        if "bo" in mp:
            out = out + mp["bo"]
        return out, new_cache

    def gather(plane):
        return plane[tbl].reshape(B, tbl.shape[1] * bs, *plane.shape[2:])

    if int8:
        k_read = _dequant_kv(gather(cache["k"]), gather(cache["k_s"]),
                             x.dtype)
        v_read = _dequant_kv(gather(cache["v"]), gather(cache["v_s"]),
                             x.dtype)
    else:
        k_read, v_read = gather(cache["k"]), gather(cache["v"])
    lpos = jnp.arange(tbl.shape[1] * bs)
    kv_pos = jnp.where(lpos[None, :] < pos[:, None], lpos[None, :], -1)
    out, k_new, v_new = apply_gqa_decode(mp, cfg, x, k_read, v_read,
                                         kv_pos, pos, window=0)
    return out, _paged_insert(cache, blk, off, k_new, v_new, write_mask)


def _apply_layer_decode(lp, shared_p, cfg: ModelConfig, spec: LayerSpec,
                        h: Array, cache, pos: Array, active: Array,
                        paged=None, write_mask=None):
    """One-token decode layer with cache update.

    ``active``: [B] bool — tokens that have NOT exited. For exited tokens the
    layer still computes and stores K/V (propagation) but the hidden-state
    update is discarded.
    ``paged``: None for ring caches, else ``(block_tables [B, nb] int32,
    use_kernel: bool)`` and the cache leaves are block planes.
    ``write_mask``: [B] bool — rows with False skip every cache write (the
    speculative verify step batches rows whose caches must stay untouched):
    ring writes scatter out of bounds and drop, mamba state updates are
    where'd back to the old state per row.
    Returns (h, new_cache, aux).
    """
    window = _window_for(cfg, spec)
    aux = jnp.zeros((), jnp.float32)
    # Pin the layer into its own XLA fusion region: different callers
    # (standalone decode step, the batched verify scan) compile different
    # surrounding programs, and on CPU the fusion context can shift
    # reduction rounding by 1 ulp inside windowed-softmax / softcap layers.
    # The barrier keeps the layer's clusters caller-independent, shrinking
    # that drift. (The *guarantee* of speculative == baseline bit-exactness
    # comes from sharing one step program — see core.speculative — not from
    # this; decode-only, so no differentiation rule is needed.)
    h, cache = jax.lax.optimization_barrier((h, cache))
    x = apply_norm(lp["norm1"], h)
    B = h.shape[0]
    if spec.mixer == MIXER_MAMBA:
        out, new_cache = ssm.apply_mamba_decode(lp["mixer"], cfg, x, cache)
        if write_mask is not None:
            # masked rows keep their state bit-unchanged (the speculative
            # verify batches rows whose caches it must not touch)
            new_cache = jax.tree.map(
                lambda new, old: jnp.where(
                    write_mask.reshape((B,) + (1,) * (new.ndim - 1)),
                    new, old), new_cache, cache)
    elif paged is not None:
        # only full-attention GQA layers page (paged_unsupported gates)
        mp = shared_p if spec.mixer == MIXER_SHARED_GQA else lp["mixer"]
        out, new_cache = _paged_gqa_decode(mp, cfg, x, cache, pos,
                                           paged[0], paged[1], write_mask)
    elif spec.mixer == MIXER_MLA:
        W = cache["latent"].shape[1]
        out, lat_new, kr_new = apply_mla_decode(
            lp["mixer"], cfg, x, cache["latent"], cache["krope"],
            cache["pos"], pos, window=window)
        slot = pos % W
        if write_mask is not None:
            slot = jnp.where(write_mask, slot, W)    # OOB -> dropped write
        bidx = jnp.arange(B)
        new_cache = {
            "latent": cache["latent"].at[bidx, slot].set(lat_new[:, 0],
                                                         mode="drop"),
            "krope": cache["krope"].at[bidx, slot].set(kr_new[:, 0],
                                                       mode="drop"),
            "pos": cache["pos"].at[bidx, slot].set(pos, mode="drop"),
        }
    else:
        mp = shared_p if spec.mixer == MIXER_SHARED_GQA else lp["mixer"]
        W = cache["k"].shape[1]
        int8 = "k_s" in cache
        if int8:
            k_read = _dequant_kv(cache["k"], cache["k_s"], x.dtype)
            v_read = _dequant_kv(cache["v"], cache["v_s"], x.dtype)
        else:
            k_read, v_read = cache["k"], cache["v"]
        out, k_new, v_new = apply_gqa_decode(
            mp, cfg, x, k_read, v_read, cache["pos"], pos,
            window=window)
        slot = pos % W
        if write_mask is not None:
            slot = jnp.where(write_mask, slot, W)    # OOB -> dropped write
        bidx = jnp.arange(B)
        if int8:
            kq, ks = _quant_kv(k_new[:, 0])
            vq, vs = _quant_kv(v_new[:, 0])
            new_cache = {
                "k": cache["k"].at[bidx, slot].set(kq, mode="drop"),
                "v": cache["v"].at[bidx, slot].set(vq, mode="drop"),
                "k_s": cache["k_s"].at[bidx, slot].set(ks, mode="drop"),
                "v_s": cache["v_s"].at[bidx, slot].set(vs, mode="drop"),
                "pos": cache["pos"].at[bidx, slot].set(pos, mode="drop"),
            }
        else:
            new_cache = {
                "k": cache["k"].at[bidx, slot].set(k_new[:, 0],
                                                   mode="drop"),
                "v": cache["v"].at[bidx, slot].set(v_new[:, 0],
                                                   mode="drop"),
                "pos": cache["pos"].at[bidx, slot].set(pos, mode="drop"),
            }
    h_new = h + out
    if spec.ffn != FFN_NONE:
        x2 = apply_norm(lp["norm2"], h_new)
        if spec.ffn == FFN_MOE:
            y, aux = apply_moe(lp["ffn"]["moe"], cfg, x2,
                               capacity_factor=_moe_capacity_factor(
                                   cfg, inference=True))
        else:
            y = apply_mlp(lp["ffn"], cfg, x2)
        h_new = h_new + y
    # predication: exited tokens keep their frozen hidden state
    h = jnp.where(active[:, None, None], h_new, h)
    h, new_cache = jax.lax.optimization_barrier((h, new_cache))
    return h, new_cache, aux


# ---------------------------------------------------------------------------
# Segment application
# ---------------------------------------------------------------------------
def _apply_segment_full(sp, shared_p, h, *, cfg, seg: Segment,
                        want_cache: bool, inference: bool = False,
                        pos_offset: int = 0):
    if seg.scanned:
        spec = seg.specs[0]

        def body(carry, lp):
            h, aux = carry
            h, cache, a = _apply_layer_full(lp, shared_p, cfg, spec, h,
                                            want_cache=want_cache,
                                            inference=inference,
                                            pos_offset=pos_offset)
            return (h, aux + a), cache

        (h, aux), caches = jax.lax.scan(body, (h, jnp.zeros((), jnp.float32)),
                                        sp)
        return h, caches, aux
    caches = []
    aux = jnp.zeros((), jnp.float32)
    for j, spec in enumerate(seg.specs):
        h, cache, a = _apply_layer_full(sp[j], shared_p, cfg, spec, h,
                                        want_cache=want_cache,
                                        inference=inference,
                                        pos_offset=pos_offset)
        caches.append(cache)
        aux = aux + a
    return h, caches, aux


def _apply_segment_decode(sp, shared_p, cfg, seg: Segment, h, caches,
                          pos, active, paged=None, write_mask=None):
    if seg.scanned:
        spec = seg.specs[0]

        def body(carry, xs):
            h, aux = carry
            lp, cache = xs
            h, new_cache, a = _apply_layer_decode(lp, shared_p, cfg, spec, h,
                                                  cache, pos, active, paged,
                                                  write_mask)
            return (h, aux + a), new_cache

        (h, aux), new_caches = jax.lax.scan(
            body, (h, jnp.zeros((), jnp.float32)), (sp, caches))
        return h, new_caches, aux
    new_caches = []
    aux = jnp.zeros((), jnp.float32)
    for j, spec in enumerate(seg.specs):
        h, nc, a = _apply_layer_decode(sp[j], shared_p, cfg, spec, h,
                                       caches[j], pos, active, paged,
                                       write_mask)
        new_caches.append(nc)
        aux = aux + a
    return h, new_caches, aux


# ---------------------------------------------------------------------------
# Public forward passes
# ---------------------------------------------------------------------------
def embed_inputs(params, cfg: ModelConfig, tokens: Array,
                 prefix_embed: Optional[Array] = None,
                 pos: Optional[Array] = None) -> Array:
    """Embed tokens; ``pos`` [B] gives per-example absolute positions of
    ``tokens[:, 0]`` (learned positional embeddings) — token j of a
    multi-token window sits at ``pos + j`` (single-token decode is the
    S = 1 case, the speculative verify window the S > 1 one)."""
    if pos is not None and cfg.positional == "learned":
        h = jnp.take(params["embed"]["tok"], tokens, axis=0)
        pidx = jnp.clip(pos[:, None] + jnp.arange(tokens.shape[1]),
                        0, cfg.max_position - 1)
        h = h + jnp.take(params["embed"]["pos"], pidx, axis=0)
    else:
        h = embed_tokens(params["embed"], cfg, tokens)
    if prefix_embed is not None:
        h = jnp.concatenate([prefix_embed.astype(h.dtype), h], axis=1)
    return constrain(h, "batch", "seq", "embed")


def forward(params, cfg: ModelConfig, tokens: Array,
            prefix_embed: Optional[Array] = None, *, remat: bool = False,
            inference: bool = False):
    """Full-sequence forward.

    Returns (exit_hiddens, aux): ``exit_hiddens`` is a list of [B, S, D]
    hidden states, one per segment boundary — entries 0..n-2 are the paper's
    exit points, the last entry is the final layer.
    """
    segs = plan_segments(cfg)
    h = embed_inputs(params, cfg, tokens, prefix_embed)
    shared_p = params.get("shared_attn")
    aux = jnp.zeros((), jnp.float32)
    outs = []
    for i, seg in enumerate(segs):
        fn = partial(_apply_segment_full, cfg=cfg, seg=seg, want_cache=False,
                     inference=inference)
        if remat:
            h, a = jax.checkpoint(
                lambda sp, shp, h, fn=fn: fn(sp, shp, h)[::2])(
                    params["segments"][i], shared_p, h)
        else:
            h, _, a = fn(params["segments"][i], shared_p, h)
        aux = aux + a
        outs.append(h)
    return outs, aux


def prefill(params, cfg: ModelConfig, tokens: Array,
            prefix_embed: Optional[Array] = None,
            max_len: Optional[int] = None):
    """Run the prompt, build decode caches.

    Returns (h_final [B,S,D], caches, aux). Caches are ring buffers of
    length min(max_len, window or max_len) per attention layer, where
    ``max_len`` (default S) must cover prompt + all generated tokens for
    full-attention layers.
    """
    segs = plan_segments(cfg)
    h = embed_inputs(params, cfg, tokens, prefix_embed)
    S = h.shape[1]
    max_len = max(max_len or S, S)
    shared_p = params.get("shared_attn")
    aux = jnp.zeros((), jnp.float32)
    raw_caches = []
    for i, seg in enumerate(segs):
        h, caches, a = _apply_segment_full(params["segments"][i], shared_p,
                                           h, cfg=cfg, seg=seg,
                                           want_cache=True)
        raw_caches.append(caches)
        aux = aux + a
    caches = _ring_from_prefill(cfg, segs, raw_caches, S, max_len)
    return h, caches, aux


def _ring_one(cfg: ModelConfig, spec: LayerSpec, cache, S: int,
              max_len: int, stacked: bool):
    """Convert full-sequence cache entries into a ring buffer.

    Ring invariant (shared with decode insertion at ``slot = pos % W``):
    slot ``s`` holds the most recent position ``p < S`` with ``p % W == s``;
    empty slots carry pos = -1.
    """
    if cache is None:
        return None
    if spec.mixer == MIXER_MAMBA:
        return cache                                  # already constant-size
    window = _window_for(cfg, spec)
    W = min(max_len, window) if window else max_len
    seq_ax = 2 if stacked else 1                      # [L?, B, S, ...]

    def ring(x):
        if S <= W:
            pad = [(0, 0)] * x.ndim
            pad[seq_ax] = (0, W - S)
            return jnp.pad(x, pad)
        last = jax.lax.slice_in_dim(x, S - W, S, axis=seq_ax)
        slot = jnp.arange(S - W, S) % W
        # scatter last[j] -> ring[slot[j]]: slot is a permutation of 0..W-1
        return jnp.take(last, jnp.argsort(slot), axis=seq_ax)

    if S <= W:
        kv_pos = jnp.where(jnp.arange(W) < S, jnp.arange(W), -1)
    else:
        s = jnp.arange(W)
        base = S - W
        kv_pos = base + (s - base) % W
    out = {k: ring(v) for k, v in cache.items()}
    if cfg.kv_cache_dtype == "int8" and "k" in out:
        out["k"], out["k_s"] = _quant_kv(out["k"])
        out["v"], out["v_s"] = _quant_kv(out["v"])
    anchor = next(iter(cache.values()))
    B = anchor.shape[1] if stacked else anchor.shape[0]
    shape = (anchor.shape[0], B, W) if stacked else (B, W)
    out["pos"] = jnp.broadcast_to(kv_pos, shape)
    return out


def _ring_from_prefill(cfg, segs, raw_caches, S, max_len):
    caches = []
    for seg, c in zip(segs, raw_caches):
        if seg.scanned:
            caches.append(_ring_one(cfg, seg.specs[0], c, S, max_len,
                                    stacked=True))
        else:
            caches.append([_ring_one(cfg, spec, cj, S, max_len,
                                     stacked=False)
                           for spec, cj in zip(seg.specs, c)])
    return caches


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.float32):
    """Empty decode caches (pos = -1 everywhere)."""
    segs = plan_segments(cfg)

    def one(spec: LayerSpec, n: int | None):
        pre = (n,) if n is not None else ()
        window = _window_for(cfg, spec)
        W = min(max_len, window) if window else max_len
        if spec.mixer == MIXER_MAMBA:
            c = ssm.init_mamba_cache(cfg, batch, dtype)
            if n is not None:
                c = jax.tree.map(
                    lambda x: jnp.broadcast_to(x, (n, *x.shape)).copy(), c)
            return c
        if spec.mixer == MIXER_MLA:
            m = cfg.mla
            return {
                "latent": jnp.zeros((*pre, batch, W, m.kv_lora_rank), dtype),
                "krope": jnp.zeros((*pre, batch, W, m.qk_rope_head_dim),
                                   dtype),
                "pos": jnp.full((*pre, batch, W), -1, jnp.int32),
            }
        kv_dtype = jnp.int8 if cfg.kv_cache_dtype == "int8" else dtype
        c = {
            "k": jnp.zeros((*pre, batch, W, cfg.num_kv_heads, cfg.head_dim),
                           kv_dtype),
            "v": jnp.zeros((*pre, batch, W, cfg.num_kv_heads, cfg.head_dim),
                           kv_dtype),
            "pos": jnp.full((*pre, batch, W), -1, jnp.int32),
        }
        if cfg.kv_cache_dtype == "int8":
            c["k_s"] = jnp.zeros((*pre, batch, W, cfg.num_kv_heads),
                                 jnp.float32)
            c["v_s"] = jnp.zeros((*pre, batch, W, cfg.num_kv_heads),
                                 jnp.float32)
        return c

    caches = []
    for seg in segs:
        if seg.scanned:
            caches.append(one(seg.specs[0], seg.length))
        else:
            caches.append([one(spec, None) for spec in seg.specs])
    return caches


def write_cache_slots(cfg: ModelConfig, pool_caches, req_caches, slots):
    """Copy per-request decode caches into rows of a persistent slot pool.

    ``pool_caches``: caches built by ``init_cache(cfg, max_slots, max_len)``.
    ``req_caches``: caches for ``b`` requests (e.g. from ``prefill`` with the
    same ``max_len``) whose batch dim is ``b``. ``slots``: [b] int array of
    destination rows. Scanned segments carry the batch on axis 1 ([L, B, ...]),
    unrolled ones on axis 0 — the segment plan disambiguates. Traceable (slots
    may be dynamic), so the pool write can be jitted with donation.
    """
    slots = jnp.asarray(slots)
    segs = plan_segments(cfg)

    def put(pool_leaf, req_leaf, stacked):
        if stacked:
            return pool_leaf.at[:, slots].set(
                req_leaf.astype(pool_leaf.dtype))
        return pool_leaf.at[slots].set(req_leaf.astype(pool_leaf.dtype))

    out = []
    for seg, pc, rc in zip(segs, pool_caches, req_caches):
        if seg.scanned:
            out.append(jax.tree.map(lambda p, r: put(p, r, True), pc, rc))
        else:
            out.append([jax.tree.map(lambda p, r: put(p, r, False), pcj, rcj)
                        for pcj, rcj in zip(pc, rc)])
    return out


# ---------------------------------------------------------------------------
# Paged KV caches (block planes + block tables; serving/kv_pool.py owns the
# allocator/prefix policy, these are the cache-layout primitives)
# ---------------------------------------------------------------------------
def paged_unsupported(cfg: ModelConfig) -> Optional[str]:
    """Why this config cannot use paged KV caches (None = it can).

    Paging covers full-attention GQA layers (incl. shared-weight and int8
    variants). Mamba state is constant-size (nothing to page), MLA latent
    caches and sliding-window ring caches keep the contiguous layout for
    now — a scheduler asked to page them fails eagerly with this reason.
    """
    for spec in cfg.block_pattern:
        if spec.mixer == MIXER_MAMBA:
            return "mamba layers carry constant-size state, not a KV cache"
        if spec.mixer == MIXER_MLA:
            return "MLA latent caches are not paged yet"
        if _window_for(cfg, spec):
            return "sliding-window layers use ring caches, not pages"
    return None


def init_paged_cache(cfg: ModelConfig, num_blocks: int, block_size: int,
                     dtype=jnp.float32):
    """Empty block-pooled decode caches: leaves
    [L?, num_blocks, block_size, KH, hd] (+ int8 scale planes). Unlike the
    contiguous ring caches there is no ``pos`` leaf — validity is derived
    from the block table plus each row's current position."""
    reason = paged_unsupported(cfg)
    if reason is not None:
        raise ValueError(f"paged KV cache unsupported for {cfg.name}: "
                         f"{reason}")
    segs = plan_segments(cfg)
    kv_dtype = jnp.int8 if cfg.kv_cache_dtype == "int8" else dtype

    def one(n: int | None):
        pre = (n,) if n is not None else ()
        c = {
            "k": jnp.zeros((*pre, num_blocks, block_size,
                            cfg.num_kv_heads, cfg.head_dim), kv_dtype),
            "v": jnp.zeros((*pre, num_blocks, block_size,
                            cfg.num_kv_heads, cfg.head_dim), kv_dtype),
        }
        if cfg.kv_cache_dtype == "int8":
            c["k_s"] = jnp.zeros((*pre, num_blocks, block_size,
                                  cfg.num_kv_heads), jnp.float32)
            c["v_s"] = jnp.zeros((*pre, num_blocks, block_size,
                                  cfg.num_kv_heads), jnp.float32)
        return c

    return [one(seg.length) if seg.scanned
            else [one(None) for _ in seg.specs] for seg in segs]


def ring_to_paged(cfg: ModelConfig, caches, block_size: int):
    """Convert batched prefill ring caches into block planes + tables.

    ``caches`` come from ``prefill(..., max_len=W)`` with ``W`` a multiple
    of ``block_size`` and batch ``B``; row ``b``'s logical block ``j`` maps
    to physical block ``b * nb + j`` (identity layout — the offline
    engine's allocation policy). Returns (paged_caches, tables [B, nb]).
    """
    reason = paged_unsupported(cfg)
    if reason is not None:
        raise ValueError(f"paged KV cache unsupported for {cfg.name}: "
                         f"{reason}")
    segs = plan_segments(cfg)
    shape = {}

    def conv(leaf, stacked):
        if stacked:
            L, B, W = leaf.shape[:3]
        else:
            B, W = leaf.shape[:2]
        if W % block_size:
            raise ValueError(f"cache length {W} not a multiple of "
                             f"block_size {block_size}")
        shape["B"], shape["W"] = B, W
        if stacked:
            return leaf.reshape(L, B * (W // block_size), block_size,
                                *leaf.shape[3:])
        return leaf.reshape(B * (W // block_size), block_size,
                            *leaf.shape[2:])

    out = []
    for seg, c in zip(segs, caches):
        if seg.scanned:
            out.append({k: conv(v, True) for k, v in c.items()
                        if k != "pos"})
        else:
            out.append([{k: conv(v, False) for k, v in cj.items()
                         if k != "pos"} for cj in c])
    B, W = shape["B"], shape["W"]
    nb = W // block_size
    tables = jnp.arange(B * nb, dtype=jnp.int32).reshape(B, nb)
    return out, tables


def write_paged_blocks(cfg: ModelConfig, pool_caches, req_caches,
                       block_ids, n_write: int, n_skip: int = 0):
    """Scatter one prefilled request's cache into pool block planes.

    ``req_caches``: ring caches from ``prefill(..., max_len=nb*bs)`` with
    batch 1 (entries in logical order — the ring never wraps at prefill).
    ``block_ids``: [nb] destination block ids; blocks ``[n_skip, n_write)``
    are written (both static): the caller skips prefix-shared blocks —
    the full ones already hold byte-identical content (a prefix's K/V is
    suffix-independent under causal attention), and a shared *mutable*
    tail must never be rewritten (its sharer may have appended).
    Jit-able with pool donation.
    """
    segs = plan_segments(cfg)
    if n_write <= n_skip:
        return pool_caches
    ids = jnp.asarray(block_ids, jnp.int32)[n_skip:n_write]

    def put(pool_leaf, req_leaf, stacked):
        if stacked:
            L = req_leaf.shape[0]
            bs = pool_leaf.shape[2]
            blocks = req_leaf.reshape(L, -1, bs,
                                      *req_leaf.shape[3:])[:,
                                                           n_skip:n_write]
            return pool_leaf.at[:, ids].set(blocks.astype(pool_leaf.dtype))
        bs = pool_leaf.shape[1]
        blocks = req_leaf.reshape(-1, bs,
                                  *req_leaf.shape[2:])[n_skip:n_write]
        return pool_leaf.at[ids].set(blocks.astype(pool_leaf.dtype))

    out = []
    for seg, pc, rc in zip(segs, pool_caches, req_caches):
        if seg.scanned:
            out.append({k: put(pc[k], rc[k], True) for k in pc})
        else:
            out.append([{k: put(pcj[k], rcj[k], False) for k in pcj}
                        for pcj, rcj in zip(pc, rc)])
    return out


def copy_paged_block(cfg: ModelConfig, caches, src, dst):
    """``dst`` block := ``src`` block across every layer plane (the
    copy-on-write primitive: a slot about to append into a shared block
    first duplicates it). Jit-able with donation; src/dst may be traced."""
    segs = plan_segments(cfg)

    def cp(leaf, stacked):
        if stacked:
            return leaf.at[:, dst].set(leaf[:, src])
        return leaf.at[dst].set(leaf[src])

    out = []
    for seg, c in zip(segs, caches):
        if seg.scanned:
            out.append({k: cp(v, True) for k, v in c.items()})
        else:
            out.append([{k: cp(v, False) for k, v in cj.items()}
                        for cj in c])
    return out


# ---------------------------------------------------------------------------
# Chunked prefill (one compiled shape for arbitrary prompt lengths; the
# serving scheduler feeds prompts through these chunk-by-chunk while decode
# ticks keep running — serving/scheduler.py owns the interleaving policy)
# ---------------------------------------------------------------------------
def chunked_prefill_unsupported(cfg: ModelConfig) -> Optional[str]:
    """Why this config cannot use chunked prefill (None = it can).

    Chunking covers the whole architecture zoo: full-attention GQA (incl.
    shared-weight and int8 variants), sliding-window layers (the prefill
    ring is full-length, so later chunks still see every prefix entry the
    window mask admits), MLA latent rings, mamba layers (recurrent state
    and the conv tail carry chunk-to-chunk), and MoE layers (the chunk
    path routes at a dropless capacity, so the chunk grid cannot change
    expert assignment). tests/test_arch_matrix.py pins bit-exact
    chunk-split invariance per config. The one declared hole: frontend
    configs (musicgen/pixtral), whose modality conditioning embeddings are
    not threaded through the chunk step — the scheduler falls back to
    whole-prompt prefill for these and counts the fallback in ``stats()``.
    """
    if cfg.frontend is not None:
        return (f"{cfg.frontend}-frontend conditioning embeddings are not "
                f"threaded through the chunk step")
    return None


def init_prefill_ring(cfg: ModelConfig, batch: int, max_len: int,
                      dtype=jnp.float32):
    """Empty full-precision prompt-ingestion rings (pos = -1 everywhere).

    Unlike :func:`init_cache`, K/V stay in ``dtype`` even for int8 configs:
    chunk attention must read the exact values whole-prompt prefill would
    have attended over; :func:`finalize_prefill_ring` quantizes once at
    splice time (the same one-shot quantization ``_ring_one`` applies).
    Ring layers — including sliding-window ones — get full-length rings
    (the ring never wraps during ingestion; the window is enforced by the
    chunk attention mask and the ring is cut down to the decode window at
    finalize time). Mamba layers get their constant-size recurrent cache.
    """
    reason = chunked_prefill_unsupported(cfg)
    if reason is not None:
        raise ValueError(f"chunked prefill unsupported for {cfg.name}: "
                         f"{reason}")
    segs = plan_segments(cfg)

    def one(spec: LayerSpec, n: int | None):
        pre = (n,) if n is not None else ()
        if spec.mixer == MIXER_MAMBA:
            c = ssm.init_mamba_cache(cfg, batch, dtype)
            if n is not None:
                c = jax.tree.map(
                    lambda x: jnp.broadcast_to(x, (n, *x.shape)).copy(), c)
            return c
        if spec.mixer == MIXER_MLA:
            m = cfg.mla
            return {
                "latent": jnp.zeros((*pre, batch, max_len, m.kv_lora_rank),
                                    dtype),
                "krope": jnp.zeros((*pre, batch, max_len,
                                    m.qk_rope_head_dim), dtype),
                "pos": jnp.full((*pre, batch, max_len), -1, jnp.int32),
            }
        return {
            "k": jnp.zeros((*pre, batch, max_len, cfg.num_kv_heads,
                            cfg.head_dim), dtype),
            "v": jnp.zeros((*pre, batch, max_len, cfg.num_kv_heads,
                            cfg.head_dim), dtype),
            "pos": jnp.full((*pre, batch, max_len), -1, jnp.int32),
        }

    return [one(seg.specs[0], seg.length) if seg.scanned
            else [one(spec, None) for spec in seg.specs] for seg in segs]


def _apply_layer_chunk(lp, shared_p, cfg: ModelConfig, spec: LayerSpec,
                       h: Array, cache, pos0: Array, n_valid: Array):
    """One prompt chunk through one layer (any mixer).

    Ring layers insert-then-attend against the fixed-length ring: the
    chunk's K/V (or MLA latent) is written at its absolute positions first,
    then every query attends over the whole ring under a
    ``kv_pos <= q_pos`` mask (plus ``kv_pos > q_pos - window`` for
    sliding-window layers — the prefill ring is full-length, so the mask,
    not eviction, enforces the horizon). The softmax max and denominator
    therefore always reduce over the same ``W`` entries — reductions are
    the one place XLA's rounding depends on extent, so the fixed extent is
    what makes the result invariant to the chunk split (dot-generals are
    exact under zero padding already). Mamba layers run a per-token
    recurrence whose state carries chunk-to-chunk (models/ssm.py). MoE
    layers route at a dropless capacity so co-chunked tokens cannot evict
    each other's expert slots.
    """
    B, C, _ = h.shape
    window = _window_for(cfg, spec)
    x = apply_norm(lp["norm1"], h)
    idx = pos0[:, None] + jnp.arange(C)[None, :]            # [B, C]
    bidx = jnp.arange(B)[:, None]
    if spec.mixer == MIXER_MAMBA:
        out, new_cache = ssm.apply_mamba_chunk(lp["mixer"], cfg, x, cache,
                                               pos0, n_valid)
    elif spec.mixer == MIXER_MLA:
        q_nope, q_rope, latent, krope = mla_chunk_qkv(lp["mixer"], cfg, x,
                                                      pos0)
        clat = cache["latent"].at[bidx, idx].set(latent, mode="drop")
        ckr = cache["krope"].at[bidx, idx].set(krope, mode="drop")
        newpos = jnp.where(idx < n_valid[:, None], idx, -1)
        cpos = cache["pos"].at[bidx, idx].set(newpos, mode="drop")
        mask = (cpos[:, None, :] >= 0) & (cpos[:, None, :] <= idx[..., None])
        if window:
            mask &= cpos[:, None, :] > (idx[..., None] - window)
        o = mla_chunk_attend(lp["mixer"], cfg, q_nope, q_rope, clat, ckr,
                             mask)
        out = o @ lp["mixer"]["wo"]
        new_cache = {"latent": clat, "krope": ckr, "pos": cpos}
    else:
        mp = shared_p if spec.mixer == MIXER_SHARED_GQA else lp["mixer"]
        q, k, v = window_qkv(mp, cfg, x, pos0)
        ck = cache["k"].at[bidx, idx].set(k, mode="drop")
        cv = cache["v"].at[bidx, idx].set(v, mode="drop")
        # grid-padding positions past the prompt keep pos = -1: their K/V
        # lands in the ring as inert garbage nothing ever attends to
        newpos = jnp.where(idx < n_valid[:, None], idx, -1)
        cpos = cache["pos"].at[bidx, idx].set(newpos, mode="drop")
        KH = cfg.num_kv_heads
        G = cfg.num_heads // KH
        scale = cfg.head_dim ** -0.5
        qr = q.reshape(B, C, KH, G, cfg.head_dim) * scale
        s = jnp.einsum("bckgd,btkd->bkgct", qr, ck,
                       preferred_element_type=jnp.float32)
        s = softcap(s, cfg.attn_logit_softcap)
        mask = (cpos[:, None, :] >= 0) & (cpos[:, None, :] <= idx[..., None])
        if window:
            mask &= cpos[:, None, :] > (idx[..., None] - window)
        s = jnp.where(mask[:, None, None], s, NEG_INF)
        m = s.max(axis=-1)
        pr = jnp.exp(s - m[..., None])
        denom = pr.sum(axis=-1)
        o = jnp.einsum("bkgct,btkd->bkgcd", pr, cv,
                       preferred_element_type=jnp.float32)
        o = (o / denom[..., None]).astype(x.dtype)
        o = o.transpose(0, 3, 1, 2, 4).reshape(B, C, cfg.q_dim)
        out = o @ mp["wo"]
        if "bo" in mp:
            out = out + mp["bo"]
        new_cache = {"k": ck, "v": cv, "pos": cpos}
    h = h + out
    if spec.ffn != FFN_NONE:
        x2 = apply_norm(lp["norm2"], h)
        if spec.ffn == FFN_MOE:
            y, _ = apply_moe(lp["ffn"]["moe"], cfg, x2,
                             capacity_factor=dropless_capacity_factor(cfg))
        else:
            y = apply_mlp(lp["ffn"], cfg, x2)
        h = h + y
    return h, new_cache


# Minimum compiled chunk-grid width. XLA CPU lowers matmuls with fewer
# than 4 rows through a different dot kernel whose K-loop accumulation
# order differs from the wide path by 1 ulp, which would break the
# bit-exact chunk-split invariance prefill_chunk promises. Narrower
# chunks are padded up to this width with inert columns.
_CHUNK_MIN_WIDTH = 4


def prefill_chunk(params, cfg: ModelConfig, tokens: Array, caches,
                  pos0: Array, n_valid: Array):
    """Run one prompt chunk against (and into) prefill ring caches.

    tokens: [B, C] prompt tokens at absolute positions ``pos0 + j``
    (entries at positions >= ``n_valid`` are grid padding — computed but
    never attended). caches: rings from :func:`init_prefill_ring` in
    logical order (the ring never wraps: W >= prompt). Because every
    reduction runs at the fixed ring length, any chunk split of a prompt —
    including one whole-prompt chunk — produces bit-identical hidden
    states, K/V and logits (tests/test_chunked_prefill.py pins this), so
    one compiled shape serves arbitrary prompt lengths.

    Returns (logits [B, C, V] float32, new_caches).
    """
    reason = chunked_prefill_unsupported(cfg)
    if reason is not None:
        raise ValueError(f"chunked prefill unsupported for {cfg.name}: "
                         f"{reason}")
    C = tokens.shape[1]
    if C < _CHUNK_MIN_WIDTH:
        # sub-SIMD-width grids (C in {1, 3}) select a different CPU dot
        # path whose accumulation rounds differently by 1 ulp, breaking
        # bit-exact split invariance against wider grids. Pad the grid to
        # the minimum width and slice the logits back. Clamping n_valid to
        # pos0 + C makes the added columns look exactly like end-of-prompt
        # grid padding (pos = -1, dt = 0), so they neither enter any
        # attention mask nor advance recurrent SSM state, even when the
        # padded chunk sits mid-prompt.
        tokens = jnp.pad(jnp.asarray(tokens), ((0, 0),
                                               (0, _CHUNK_MIN_WIDTH - C)))
        n_valid = jnp.minimum(jnp.asarray(n_valid, jnp.int32),
                              jnp.asarray(pos0, jnp.int32) + C)
        logits, new_caches = prefill_chunk(params, cfg, tokens, caches,
                                           pos0, n_valid)
        return logits[:, :C], new_caches
    segs = plan_segments(cfg)
    pos0 = jnp.asarray(pos0, jnp.int32)
    n_valid = jnp.asarray(n_valid, jnp.int32)
    h = embed_inputs(params, cfg, tokens, pos=pos0)
    shared_p = params.get("shared_attn")
    new_caches = []
    for i, seg in enumerate(segs):
        sp, c = params["segments"][i], caches[i]
        if seg.scanned:
            spec = seg.specs[0]

            def body(hh, xs):
                lp, cache = xs
                return _apply_layer_chunk(lp, shared_p, cfg, spec, hh,
                                          cache, pos0, n_valid)

            h, nc = jax.lax.scan(body, h, (sp, c))
        else:
            nc = []
            for j, spec in enumerate(seg.specs):
                h, ncj = _apply_layer_chunk(sp[j], shared_p, cfg, spec, h,
                                            c[j], pos0, n_valid)
                nc.append(ncj)
        new_caches.append(nc)
    logits = lm_logits(params, cfg, h).astype(jnp.float32)
    return logits, new_caches


def finalize_prefill_ring(cfg: ModelConfig, caches, plen):
    """Convert a finished full-precision prefill ring into pool-layout
    caches: int8 configs quantize K/V once (the same per-entry scheme
    ``_ring_one`` applies after whole-prompt prefill); sliding-window
    layers gather their full-length ingestion ring down to the W-slot
    decode ring (slot ``s`` receives the most recent prompt position
    ``p < plen`` with ``p % W == s`` — the ``_ring_one`` / ``pos % W``
    invariant — and pos = -1 where no such position exists); everything
    else passes through unchanged. ``plen`` [B] (traceable) is each row's
    prompt length. The result feeds ``write_cache_slots`` /
    ``write_paged_ring`` directly."""
    plen = jnp.asarray(plen, jnp.int32)
    segs = plan_segments(cfg)
    int8 = cfg.kv_cache_dtype == "int8"

    def quant(c):
        if not (int8 and "k" in c):
            return c
        out = dict(c)
        out["k"], out["k_s"] = _quant_kv(c["k"])
        out["v"], out["v_s"] = _quant_kv(c["v"])
        return out

    def conv(spec: LayerSpec, c, stacked: bool):
        if spec.mixer == MIXER_MAMBA:
            return c
        window = _window_for(cfg, spec)
        seq_ax = 2 if stacked else 1
        T = c["pos"].shape[-1]
        W = min(T, window) if window else T
        if W == T:
            return quant(c)
        s = jnp.arange(W)
        p = (plen[:, None] - 1) - ((plen[:, None] - 1 - s) % W)    # [B, W]
        src = jnp.clip(p, 0, T - 1)

        def gather(leaf):
            i = src
            if stacked:
                i = jnp.broadcast_to(src, (leaf.shape[0],) + src.shape)
            i = i.reshape(i.shape + (1,) * (leaf.ndim - i.ndim))
            return jnp.take_along_axis(leaf, i, axis=seq_ax)

        out = {k: gather(v) for k, v in c.items() if k != "pos"}
        pos = jnp.where(p >= 0, p, -1)
        if stacked:
            pos = jnp.broadcast_to(pos[None], (c["pos"].shape[0],) + pos.shape)
        out["pos"] = pos
        return quant(out)

    return [conv(seg.specs[0], c, True) if seg.scanned
            else [conv(spec, cj, False)
                  for spec, cj in zip(seg.specs, c)]
            for seg, c in zip(segs, caches)]


def paged_prefix_to_ring(cfg: ModelConfig, pool_caches, ring_caches,
                         block_ids: Array, n_tokens: Array):
    """Copy ``n_tokens`` of prefix-shared block content into a (batch-1)
    prefill ring, dequantized for int8 pools so chunk attention reads
    exactly what decode would read. ``block_ids`` [nb] spans the ring
    (``nb * block_size == ring length``); entries past the shared chain
    may be arbitrary — everything at position >= ``n_tokens`` is masked.
    Jit-able with ring donation; ``n_tokens`` may be traced.
    """
    segs = plan_segments(cfg)
    ids = jnp.asarray(block_ids, jnp.int32)
    n_tokens = jnp.asarray(n_tokens, jnp.int32)

    def conv(pool_c, ring_c, stacked):
        int8 = "k_s" in pool_c
        W = ring_c["k"].shape[2 if stacked else 1]
        valid = jnp.arange(W) < n_tokens

        def gather(name):
            plane = pool_c[name]
            if stacked:
                g = plane[:, ids]                     # [L, nb, bs, ...]
                return g.reshape(g.shape[0], 1, W, *g.shape[3:])
            g = plane[ids]
            return g.reshape(1, W, *g.shape[2:])

        out = {}
        for name in ("k", "v"):
            g = gather(name)
            if int8:
                g = _dequant_kv(g, gather(name + "_s"),
                                ring_c[name].dtype)
            vmask = valid.reshape((1,) * (g.ndim - 3) + (W, 1, 1))
            out[name] = jnp.where(vmask, g.astype(ring_c[name].dtype),
                                  ring_c[name])
        pos = jnp.where(valid, jnp.arange(W), -1)
        out["pos"] = jnp.broadcast_to(pos, ring_c["pos"].shape)
        return out

    out = []
    for seg, pc, rc in zip(segs, pool_caches, ring_caches):
        if seg.scanned:
            out.append(conv(pc, rc, True))
        else:
            out.append([conv(pcj, rcj, False)
                        for pcj, rcj in zip(pc, rc)])
    return out


def write_paged_ring(cfg: ModelConfig, pool_caches, ring_caches,
                     block_ids: Array, n_skip: Array, n_write: Array):
    """Fixed-shape scatter of a finalized prefill ring into pool block
    planes: ring blocks ``[n_skip, n_write)`` land at ``block_ids[j]``.

    Unlike :func:`write_paged_blocks` (static slice bounds — one compile
    per (n_write, n_skip) pair), the bounds here are traced: excluded
    blocks scatter out of range and drop, so every admission shares ONE
    compiled splice. Jit-able with pool donation.
    """
    segs = plan_segments(cfg)
    ids = jnp.asarray(block_ids, jnp.int32)
    nb = ids.shape[0]
    j = jnp.arange(nb)
    keep = (j >= jnp.asarray(n_skip)) & (j < jnp.asarray(n_write))

    def put(pool_leaf, ring_leaf, stacked):
        oob = pool_leaf.shape[1 if stacked else 0]
        ids_eff = jnp.where(keep, ids, oob)
        if stacked:
            bs = pool_leaf.shape[2]
            blocks = ring_leaf.reshape(ring_leaf.shape[0], nb, bs,
                                       *ring_leaf.shape[3:])
            return pool_leaf.at[:, ids_eff].set(
                blocks.astype(pool_leaf.dtype), mode="drop")
        bs = pool_leaf.shape[1]
        blocks = ring_leaf.reshape(nb, bs, *ring_leaf.shape[2:])
        return pool_leaf.at[ids_eff].set(blocks.astype(pool_leaf.dtype),
                                         mode="drop")

    out = []
    for seg, pc, rc in zip(segs, pool_caches, ring_caches):
        if seg.scanned:
            out.append({k: put(pc[k], rc[k], True) for k in pc})
        else:
            out.append([{k: put(pcj[k], rcj[k], False) for k in pcj}
                        for pcj, rcj in zip(pc, rc)])
    return out


# exit-decision callback: (h [B, D], exit_idx) -> decision [B] | None.
# Built by repro.core.exit_policy.as_exit_fn / select_apply — policies are
# registry data with runtime param pytrees, never hand-rolled closures.
ExitFn = Callable[[Array, int], Optional[Array]]


def decode_step(params, cfg: ModelConfig, tokens: Array, caches, pos: Array,
                controller: Optional[ExitFn] = None, *,
                block_tables: Optional[Array] = None,
                use_kernel: bool = False):
    """One decode step with dynamic early exit.

    tokens: [B] current input token ids; pos: [B] absolute positions.
    ``controller(h2d, exit_idx) -> exit_prob [B] | None`` is consulted at
    every exit boundary. ``block_tables`` [B, nb] switches the attention
    layers to paged caches (leaves [num_blocks, block_size, ...], built by
    :func:`init_paged_cache` / :func:`ring_to_paged`); ``use_kernel`` then
    selects the Pallas paged-attention kernel over the pure-XLA gather
    reference. Returns (logits [B, V], new_caches, info) where
    info = {exit_layer: [B] layers *used* per token, aux}.
    """
    segs = plan_segments(cfg)
    B = tokens.shape[0]
    paged = None
    if block_tables is not None:
        paged = (jnp.asarray(block_tables, jnp.int32), bool(use_kernel))
    h = embed_inputs(params, cfg, tokens[:, None], pos=pos)
    shared_p = params.get("shared_attn")
    active = jnp.ones((B,), bool)
    exit_layer = jnp.full((B,), cfg.num_layers, jnp.int32)
    aux = jnp.zeros((), jnp.float32)
    new_caches = []
    for i, seg in enumerate(segs):
        h, nc, a = _apply_segment_decode(params["segments"][i], shared_p, cfg,
                                         seg, h, caches[i], pos, active,
                                         paged)
        new_caches.append(nc)
        aux = aux + a
        is_last = i == len(segs) - 1
        if controller is not None and not is_last:
            p_exit = controller(h[:, 0, :], i)
            if p_exit is not None:
                newly = active & (p_exit > 0.5)
                exit_layer = jnp.where(newly, seg.end, exit_layer)
                active = active & ~newly
    logits = lm_logits(params, cfg, h)[:, 0, :]
    info = {"exit_layer": exit_layer, "aux": aux}
    return logits, new_caches, info


# ---------------------------------------------------------------------------
# Speculative decoding primitives (draft windows are verified full-depth;
# core/speculative.py owns the draft-then-verify loop, the scheduler the
# serving integration)
# ---------------------------------------------------------------------------
def speculative_unsupported(cfg: ModelConfig) -> Optional[str]:
    """Why this config cannot run self-speculative decoding (None = it can).

    Rollback of rejected draft positions is supported for every mixer:
    full-attention GQA and MLA ring entries are invalidated by resetting
    their ``pos`` (or unbinding their block-table append); mamba state and
    sliding-window rings — whose writes are destructive — are covered by
    the snapshot/commit protocol (``spec_needs_cache_snapshot`` /
    ``select_cache_rows`` / ``commit_spec_cache``), which the driver loops
    in core/speculative.py and serving/scheduler.py wire up.
    tests/test_arch_matrix.py pins speculative == baseline bit-exactness
    per config. The one declared hole: frontend configs (musicgen/
    pixtral), whose modality conditioning embeddings are not threaded
    through the draft/verify windows.
    """
    if cfg.frontend is not None:
        return (f"{cfg.frontend}-frontend conditioning embeddings are not "
                f"threaded through the draft/verify windows")
    return None


def spec_needs_cache_snapshot(cfg: ModelConfig) -> bool:
    """True when speculative rollback needs the snapshot/commit protocol.

    A pos rewind (``rewind_ring``) fully undoes draft writes only when
    every cache write is non-destructive: full-length rings just park
    rejected K/V as garbage behind pos = -1. Mamba state updates overwrite
    the recurrence in place, and sliding-window ring writes evict entries
    a rolled-back row still needs — those configs must snapshot before
    drafting and commit per-row after verify.
    """
    return any(spec.mixer == MIXER_MAMBA or _window_for(cfg, spec)
               for spec in cfg.block_pattern)


def select_cache_rows(cfg: ModelConfig, caches_a, caches_b, take_a):
    """Per-row cache blend: row ``b`` comes from ``caches_a`` where
    ``take_a[b]``, else from ``caches_b``.

    The pre-verify restore for snapshot configs: speculative rows return
    wholesale to the pre-draft snapshot (undoing draft-phase window
    evictions and mamba state updates that a pos rewind cannot), while
    co-batched non-speculative rows keep their live caches. Jit-able with
    donation of ``caches_b``.
    """
    take = jnp.asarray(take_a, bool)
    segs = plan_segments(cfg)

    def sel(stacked):
        def f(a, b):
            shape = ((1, take.shape[0]) + (1,) * (a.ndim - 2) if stacked
                     else (take.shape[0],) + (1,) * (a.ndim - 1))
            return jnp.where(take.reshape(shape), a, b)
        return f

    out = []
    for seg, ca, cb in zip(segs, caches_a, caches_b):
        if seg.scanned:
            out.append(jax.tree.map(sel(True), ca, cb))
        else:
            out.append([jax.tree.map(sel(False), caj, cbj)
                        for caj, cbj in zip(ca, cb)])
    return out


def _mamba_cache_parts(cfg: ModelConfig, caches):
    """The mamba sub-caches of a cache pytree (ring entries -> None):
    the per-step state ``verify_step(..., collect_states=True)`` stacks."""
    segs = plan_segments(cfg)
    out = []
    for seg, c in zip(segs, caches):
        if seg.scanned:
            out.append(c if seg.specs[0].mixer == MIXER_MAMBA else None)
        else:
            out.append([cj if spec.mixer == MIXER_MAMBA else None
                        for spec, cj in zip(seg.specs, c)])
    return out


def commit_spec_cache(cfg: ModelConfig, verified, snap, keep_pos,
                      state_snaps=None, accept_steps=None):
    """Post-acceptance cache commit for snapshot configs.

    Ring entries (GQA / MLA, incl. sliding-window): a slot keeps its
    verify-phase write iff its new ``pos`` is <= ``keep_pos[b]``; every
    other slot — a rejected draft position's write, including windowed
    evictions of entries the row still needs — restores from the pre-draft
    snapshot ``snap``. (All snapshot pos values predate the draft window,
    so snapshot slots always satisfy the predicate; for full-length rings
    this is equivalent to a pos rewind, for windowed rings it is the only
    correct rollback.)

    Mamba entries: the committed state is the per-step verify snapshot
    ``state_snaps`` (from ``verify_step(..., collect_states=True)``) at
    index ``accept_steps[b]`` — i.e. the state after consuming position
    ``pos0 + n_accept``, exactly what the baseline sequential loop would
    carry.

    Rows whose caches must stay live (non-speculative residents) pass
    ``keep_pos[b]`` = INT32_MAX and any in-range ``accept_steps[b]``:
    their verify writes were masked no-ops, so every per-step snapshot
    equals their live state. Jit-able with donation of ``verified``.
    """
    keep = jnp.asarray(keep_pos, jnp.int32)
    segs = plan_segments(cfg)
    if state_snaps is None:
        state_snaps = [None] * len(segs)
    steps = (None if accept_steps is None
             else jnp.asarray(accept_steps, jnp.int32))

    def blend_ring(cn, cs, stacked):
        k = keep[None, :, None] if stacked else keep[:, None]
        sel = cn["pos"] <= k                              # [L?, B, W]

        def f(a, b):
            m = sel.reshape(sel.shape + (1,) * (a.ndim - sel.ndim))
            return jnp.where(m, a, b)

        return {name: f(cn[name], cs[name]) for name in cn}

    def pick_state(snaps_c, stacked):
        bax = 2 if stacked else 1                         # [S, L?, B, ...]

        def f(leaf):
            lb = jnp.moveaxis(leaf, bax, 0)               # [B, S, L?, ...]
            out = jax.vmap(lambda l, i: l[i])(lb, steps)  # [B, L?, ...]
            return jnp.moveaxis(out, 0, bax - 1)

        return jax.tree.map(f, snaps_c)

    out = []
    for seg, cn, cs, sn in zip(segs, verified, snap, state_snaps):
        if seg.scanned:
            if "pos" in cn:
                out.append(blend_ring(cn, cs, True))
            else:
                out.append(pick_state(sn, True))
        else:
            row = []
            for j, cnj in enumerate(cn):
                if "pos" in cnj:
                    row.append(blend_ring(cnj, cs[j], False))
                else:
                    row.append(pick_state(sn[j], False))
            out.append(row)
    return out


def rewind_ring(cfg: ModelConfig, caches, keep_pos: Array):
    """Invalidate contiguous ring-cache entries past ``keep_pos`` [B].

    The speculative rollback primitive: a rejected position's K/V stays in
    its slot as garbage but its ``pos`` entry resets to -1, so attention
    masks it exactly like a never-written slot (``keep_pos = -1`` empties a
    row; a huge value leaves it untouched). Jit-able with donation.
    """
    keep = jnp.asarray(keep_pos, jnp.int32)
    segs = plan_segments(cfg)

    def cut(pos_leaf, stacked):
        k = keep[None, :, None] if stacked else keep[:, None]
        return jnp.where(pos_leaf <= k, pos_leaf, -1)

    out = []
    for seg, c in zip(segs, caches):
        if seg.scanned:
            out.append({k: (cut(v, True) if k == "pos" else v)
                        for k, v in c.items()})
        else:
            out.append([{k: (cut(v, False) if k == "pos" else v)
                         for k, v in cj.items()} for cj in c])
    return out


def _paged_gqa_verify(mp, cfg: ModelConfig, x: Array, cache, pos0: Array,
                      tables: Array, write_mask: Optional[Array]):
    """Window-parallel GQA verify against paged caches (kernel path).

    x: [B, S, D] window hidden; pos0 [B] is the absolute position of
    x[:, 0]. Inserts the whole window's K/V, then runs the q-window Pallas
    kernel over each row's block chain (insert-then-attend; query j attends
    logical positions <= pos0 + j).
    """
    from repro.models.attention import window_qkv
    B, S, _ = x.shape
    num_blocks, bs = cache["k"].shape[:2]
    int8 = "k_s" in cache
    q, k_new, v_new = window_qkv(mp, cfg, x, pos0)
    tbl = jnp.clip(jnp.asarray(tables, jnp.int32), 0, num_blocks - 1)
    pos = pos0[:, None] + jnp.arange(S)[None, :]          # [B, S]
    blk = jnp.take_along_axis(tbl, pos // bs, axis=1)
    if write_mask is not None:
        blk = jnp.where(write_mask[:, None], blk, num_blocks)
    off = pos % bs
    if int8:
        kq, ks = _quant_kv(k_new)
        vq, vs = _quant_kv(v_new)
        cache = {"k": cache["k"].at[blk, off].set(kq, mode="drop"),
                 "v": cache["v"].at[blk, off].set(vq, mode="drop"),
                 "k_s": cache["k_s"].at[blk, off].set(ks, mode="drop"),
                 "v_s": cache["v_s"].at[blk, off].set(vs, mode="drop")}
    else:
        cache = {"k": cache["k"].at[blk, off].set(k_new, mode="drop"),
                 "v": cache["v"].at[blk, off].set(v_new, mode="drop")}
    from repro.kernels.ops import paged_verify
    KH = cfg.num_kv_heads
    qr = q.reshape(B, S, KH, cfg.num_heads // KH, cfg.head_dim)
    scales = (cache["k_s"], cache["v_s"]) if int8 else (None, None)
    o = paged_verify(qr, cache["k"], cache["v"], tbl, pos0, *scales,
                     softcap=cfg.attn_logit_softcap)
    out = o.reshape(B, S, cfg.q_dim) @ mp["wo"]
    if "bo" in mp:
        out = out + mp["bo"]
    return out, cache


def _apply_layer_verify(lp, shared_p, cfg: ModelConfig, spec: LayerSpec,
                        h: Array, cache, pos0: Array, tables: Array,
                        write_mask: Optional[Array]):
    x = apply_norm(lp["norm1"], h)
    mp = shared_p if spec.mixer == MIXER_SHARED_GQA else lp["mixer"]
    out, new_cache = _paged_gqa_verify(mp, cfg, x, cache, pos0, tables,
                                       write_mask)
    h = h + out
    if spec.ffn != FFN_NONE:
        x2 = apply_norm(lp["norm2"], h)
        if spec.ffn == FFN_MOE:
            y, _ = apply_moe(lp["ffn"]["moe"], cfg, x2,
                             capacity_factor=_moe_capacity_factor(
                                 cfg, inference=True))
        else:
            y = apply_mlp(lp["ffn"], cfg, x2)
        h = h + y
    return h, new_cache


def _verify_window_kernel(params, cfg: ModelConfig, tokens: Array, caches,
                          pos0: Array, tables: Array,
                          write_mask: Optional[Array]):
    """Kernel verify path: the whole [B, S] window per layer in one shot."""
    segs = plan_segments(cfg)
    B, S = tokens.shape
    h = embed_inputs(params, cfg, tokens, pos=pos0)
    shared_p = params.get("shared_attn")
    new_caches = []
    for i, seg in enumerate(segs):
        sp, c = params["segments"][i], caches[i]
        if seg.scanned:
            spec = seg.specs[0]

            def body(hh, xs):
                lp, cache = xs
                hh, nc = _apply_layer_verify(lp, shared_p, cfg, spec, hh,
                                             cache, pos0, tables, write_mask)
                return hh, nc

            h, nc = jax.lax.scan(body, h, (sp, c))
        else:
            nc = []
            for j, spec in enumerate(seg.specs):
                h, ncj = _apply_layer_verify(sp[j], shared_p, cfg, spec, h,
                                             c[j], pos0, tables, write_mask)
                nc.append(ncj)
        new_caches.append(nc)
    logits = lm_logits(params, cfg, h).astype(jnp.float32)
    return logits, new_caches


def verify_step(params, cfg: ModelConfig, tokens: Array, caches,
                pos0: Array, *, write_mask: Optional[Array] = None,
                block_tables: Optional[Array] = None,
                use_kernel: bool = False, collect_states: bool = False):
    """Score a [B, S] token window full-depth against the decode caches.

    ``tokens[:, j]`` is consumed at position ``pos0 + j`` and its K/V is
    written there (rows with ``write_mask`` False never write — they ride
    along in the fixed-shape batch with untouched caches). The reference
    path runs the S positions as sequential single-token decode steps under
    one scan, so its arithmetic — and therefore greedy acceptance — is
    bit-identical to the non-speculative baseline loop. ``use_kernel`` (with
    ``block_tables``) switches to the window-parallel Pallas verify kernel
    (kernels/verify_attn.py): same math, flash-accumulated, parity-tested
    against the scan path rather than bit-equal to it.

    Contiguous callers must invalidate any draft-phase writes in the window
    first (``rewind_ring(cfg, caches, pos0 - 1)``): the inclusive
    ``kv_pos <= pos`` mask plus the explicit self term would otherwise
    double-count a still-valid entry at the query's own position. Paged
    caches mask strictly (``lpos < pos``), so stale draft K/V is ignored
    and overwritten in place.

    ``collect_states`` (reference path only): additionally return the
    mamba sub-caches after each of the S scan steps (leaves [S, L?, B,
    ...]; ring entries None) — ``commit_spec_cache`` indexes them at each
    row's acceptance count to roll the destructive recurrence back.

    Returns (logits [B, S, V] float32, new_caches[, state_snaps]).
    """
    B, S = tokens.shape
    pos0 = jnp.asarray(pos0, jnp.int32)
    mask = None if write_mask is None else jnp.asarray(write_mask, bool)
    paged = None
    if block_tables is not None:
        if collect_states:
            raise ValueError("collect_states requires contiguous caches "
                             "(snapshot configs never page)")
        paged = (jnp.asarray(block_tables, jnp.int32), bool(use_kernel))
        if use_kernel:
            return _verify_window_kernel(params, cfg, tokens, caches, pos0,
                                         paged[0], mask)
    segs = plan_segments(cfg)
    shared_p = params.get("shared_attn")
    active = jnp.ones((B,), bool)

    def body(caches, xs):
        tok, off = xs
        pos = pos0 + off
        h = embed_inputs(params, cfg, tok[:, None], pos=pos)
        new_caches = []
        for i, seg in enumerate(segs):
            h, nc, _ = _apply_segment_decode(params["segments"][i], shared_p,
                                             cfg, seg, h, caches[i], pos,
                                             active, paged, mask)
            new_caches.append(nc)
        logits = lm_logits(params, cfg, h)[:, 0, :].astype(jnp.float32)
        if collect_states:
            return new_caches, (logits, _mamba_cache_parts(cfg, new_caches))
        return new_caches, logits

    caches, ys = jax.lax.scan(
        body, caches, (tokens.T, jnp.arange(S, dtype=jnp.int32)))
    if collect_states:
        logits, snaps = ys
        return jnp.transpose(logits, (1, 0, 2)), caches, snaps
    return jnp.transpose(ys, (1, 0, 2)), caches
