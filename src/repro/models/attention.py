"""Attention mixers: GQA (global / sliding-window) and MLA.

Two execution paths per mixer:
  * ``*_train``  — full-sequence causal attention, memory-efficient blockwise
    softmax (lax.scan over KV chunks with running max/denominator) so 32k
    prefill never materializes an [S, S] score matrix.
  * ``*_decode`` — single-token query against a KV cache (``kv_pos`` gives
    the absolute position of every cache slot; -1 marks invalid slots).

MLA decode uses the absorbed-weight formulation (queries projected into the
latent space; the per-position latent cache is never expanded to full K/V) —
the TPU-native way to serve MLA.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models.layers import (apply_norm, apply_rope, dense_init,
                                 rope_freqs, softcap, stacked_dense_init)
from repro.sharding import constrain

Array = jax.Array
NEG_INF = -2.0 ** 30


def _mk(key, n, a, b, scale=None):
    if n is None:
        return dense_init(key, a, b, scale)
    return stacked_dense_init(key, n, a, b, scale)


# ---------------------------------------------------------------------------
# GQA
# ---------------------------------------------------------------------------
def init_gqa(key, cfg: ModelConfig, n: int | None = None):
    ks = jax.random.split(key, 4)
    p = {"wq": _mk(ks[0], n, cfg.d_model, cfg.q_dim),
         "wk": _mk(ks[1], n, cfg.d_model, cfg.kv_dim),
         "wv": _mk(ks[2], n, cfg.d_model, cfg.kv_dim),
         "wo": _mk(ks[3], n, cfg.q_dim, cfg.d_model)}
    if cfg.use_bias:
        sh = (lambda d: (d,)) if n is None else (lambda d: (n, d))
        p["bq"], p["bk"], p["bv"] = (jnp.zeros(sh(cfg.q_dim)),
                                     jnp.zeros(sh(cfg.kv_dim)),
                                     jnp.zeros(sh(cfg.kv_dim)))
        p["bo"] = jnp.zeros(sh(cfg.d_model))
    return p


def _qkv(p, cfg: ModelConfig, x: Array):
    B, S, _ = x.shape
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, S, cfg.num_heads, cfg.head_dim)
    k = k.reshape(B, S, cfg.num_kv_heads, cfg.head_dim)
    v = v.reshape(B, S, cfg.num_kv_heads, cfg.head_dim)
    return q, k, v


import functools


def blockwise_causal_attention(q: Array, k: Array, v: Array, *,
                               window: int = 0, logit_cap: float = 0.0,
                               chunk: int = 1024, q_offset: int = 0,
                               shard: str = "seq") -> Array:
    """Causal (optionally windowed) attention without [S,S] materialization.

    q: [B, Sq, H, D]; k, v: [B, Sk, KH, D] with H a multiple of KH.
    ``window > 0`` restricts attention to the last ``window`` positions.
    ``q_offset`` is the absolute position of q[0] relative to k[0].
    Scans over KV chunks keeping running (max, denom, acc) per query.

    The whole function is rematerialized (flash-style backward): the chunk
    softmax probabilities are recomputed in the backward pass instead of
    being stored as scan residuals — peak residual memory drops from
    O(layers·Sq·Sk) to O(Sq·D) per layer.
    """
    fn = functools.partial(_blockwise_impl, window=window,
                           logit_cap=logit_cap, chunk=chunk,
                           q_offset=q_offset, shard=shard)
    return jax.checkpoint(fn)(q, k, v)


def _blockwise_impl(q: Array, k: Array, v: Array, *, window: int,
                    logit_cap: float, chunk: int, q_offset: int,
                    shard: str) -> Array:
    B, Sq, H, D = q.shape
    _, Sk, KH, _ = k.shape
    G = H // KH
    scale = D ** -0.5
    qr = q.reshape(B, Sq, KH, G, D) * scale
    if shard == "head":
        # head-parallel: flat heads over `model` (caller pre-broadcast KV
        # to full heads so KH == H, G == 1); queries stay seq-replicated —
        # no per-layer sequence gathers (§Perf C3)
        qr = constrain(qr, "batch", None, "heads", None, None)
    else:
        # sequence-parallel attention: shard the query positions over
        # `model` (each position's flash stats are independent — no comm
        # in the scan); KV chunks are replicated across the model axis.
        # Works for any (H, KH), including kv_heads < mesh model size.
        qr = constrain(qr, "batch", "seq_attn", None, None, None)
    q_pos = q_offset + jnp.arange(Sq)

    chunk = min(chunk, Sk)
    n_chunks = (Sk + chunk - 1) // chunk
    pad = n_chunks * chunk - Sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kc = k.reshape(B, n_chunks, chunk, KH, D).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, n_chunks, chunk, KH, D).transpose(1, 0, 2, 3, 4)
    kv_ax = "heads" if shard == "head" else None
    kc = constrain(kc, None, "batch", None, kv_ax, None)
    vc = constrain(vc, None, "batch", None, kv_ax, None)

    def body(carry, inp):
        m, l, acc, c_idx = carry
        k_i, v_i = inp
        k_pos = c_idx * chunk + jnp.arange(chunk)
        s = jnp.einsum("bskgd,btkd->bkgst", qr, k_i,
                       preferred_element_type=jnp.float32)
        s = softcap(s, logit_cap)
        mask = k_pos[None, :] <= q_pos[:, None]           # causal
        if window and window > 0:
            mask &= k_pos[None, :] > (q_pos[:, None] - window)
        mask &= (k_pos < Sk)[None, :]                     # padding
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        alpha = jnp.exp(m - m_new)
        pr = jnp.exp(s - m_new[..., None])
        l_new = l * alpha + pr.sum(axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bkgst,btkd->bkgsd", pr, v_i,
            preferred_element_type=jnp.float32)
        return (m_new, l_new, acc_new, c_idx + 1), None

    if shard == "head":
        m0 = constrain(jnp.full((B, KH, G, Sq), NEG_INF, jnp.float32),
                       "batch", "heads", None, None)
        l0 = constrain(jnp.zeros((B, KH, G, Sq), jnp.float32),
                       "batch", "heads", None, None)
        acc0 = constrain(jnp.zeros((B, KH, G, Sq, D), jnp.float32),
                         "batch", "heads", None, None, None)
    else:
        m0 = constrain(jnp.full((B, KH, G, Sq), NEG_INF, jnp.float32),
                       "batch", None, None, "seq_attn")
        l0 = constrain(jnp.zeros((B, KH, G, Sq), jnp.float32),
                       "batch", None, None, "seq_attn")
        acc0 = constrain(jnp.zeros((B, KH, G, Sq, D), jnp.float32),
                         "batch", None, None, "seq_attn", None)
    (m, l, acc, _), _ = jax.lax.scan(body, (m0, l0, acc0, 0), (kc, vc))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    out = out.transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, D)
    if shard == "head":
        out = constrain(out, "batch", None, "heads", None)
    else:
        out = constrain(out, "batch", "seq_attn", None, None)
    return out.astype(q.dtype)


def apply_gqa_train(p, cfg: ModelConfig, x: Array, *, window: int = 0,
                    pos_offset: int = 0):
    """Full-sequence causal GQA. Returns (out, (k, v)) — k/v are the
    rope-applied cache entries so prefill can store them directly."""
    B, S, _ = x.shape
    q, k, v = _qkv(p, cfg, x)
    if cfg.positional == "rope":
        cos, sin = rope_freqs(cfg.head_dim, cfg.rope_theta,
                              pos_offset + jnp.arange(S))
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    ka, va = k, v
    if cfg.attn_shard == "head":
        G = cfg.num_heads // cfg.num_kv_heads
        if G > 1:  # broadcast KV to flat heads (cache keeps KH heads)
            ka = jnp.repeat(k, G, axis=2)
            va = jnp.repeat(v, G, axis=2)
    out = blockwise_causal_attention(q, ka, va, window=window,
                                     logit_cap=cfg.attn_logit_softcap,
                                     q_offset=0, shard=cfg.attn_shard)
    out = out.reshape(B, S, cfg.q_dim) @ p["wo"]
    if "bo" in p:
        out = out + p["bo"]
    return out, (k, v)


def decode_qkv(p, cfg: ModelConfig, x: Array, pos: Array):
    """Single-token q/k/v projection with per-example rope.

    x: [B, 1, D]; pos: [B] absolute positions. Returns
    (q [B,1,H,hd], k [B,1,KH,hd], v [B,1,KH,hd]) — rope already applied.
    Shared by the in-cache decode path (``apply_gqa_decode``) and the
    paged-attention kernel path (``models.transformer``).
    """
    q, k, v = _qkv(p, cfg, x)
    if cfg.positional == "rope":
        # per-example positions: vmap rope over batch
        def rot(qkv, pb):
            cos, sin = rope_freqs(cfg.head_dim, cfg.rope_theta, pb[None])
            return apply_rope(qkv, cos, sin)
        q = jax.vmap(rot)(q, pos)
        k = jax.vmap(rot)(k, pos)
    return q, k, v


def window_qkv(p, cfg: ModelConfig, x: Array, pos0: Array):
    """Multi-token q/k/v projection with per-row absolute rope positions.

    x: [B, S, D] (a draft window); pos0: [B] absolute position of x[:, 0].
    Returns (q [B,S,H,hd], k [B,S,KH,hd], v [B,S,KH,hd]) — rope applied at
    positions ``pos0 + j`` per window index j. The speculative verify path's
    window analogue of :func:`decode_qkv`.
    """
    S = x.shape[1]
    q, k, v = _qkv(p, cfg, x)
    if cfg.positional == "rope":
        def rot(qb, kb, p0):
            cos, sin = rope_freqs(cfg.head_dim, cfg.rope_theta,
                                  p0 + jnp.arange(S))
            return apply_rope(qb, cos, sin), apply_rope(kb, cos, sin)
        q, k = jax.vmap(rot)(q, k, pos0)
    return q, k, v


def apply_gqa_decode(p, cfg: ModelConfig, x: Array, k_cache: Array,
                     v_cache: Array, kv_pos: Array, pos: Array, *,
                     window: int = 0):
    """One-token decode.

    x: [B, 1, D]; k_cache/v_cache: [B, Skv, KH, hd]; kv_pos: [B, Skv]
    absolute positions (-1 invalid); pos: [B] current absolute position.
    Returns (out [B,1,D], k_new [B,1,KH,hd], v_new [B,1,KH,hd]) — cache
    insertion is the caller's job (ring-buffer for sliding window).
    """
    B = x.shape[0]
    q, k, v = decode_qkv(p, cfg, x, pos)
    KH = cfg.num_kv_heads
    G = cfg.num_heads // KH
    scale = cfg.head_dim ** -0.5
    qr = q.reshape(B, KH, G, cfg.head_dim) * scale
    s = jnp.einsum("bkgd,btkd->bkgt", qr, k_cache,
                   preferred_element_type=jnp.float32)
    # new token attends to itself too
    s_self = jnp.einsum("bkgd,bkd->bkg", qr,
                        k[:, 0].astype(qr.dtype),
                        preferred_element_type=jnp.float32)
    s = softcap(s, cfg.attn_logit_softcap)
    s_self = softcap(s_self, cfg.attn_logit_softcap)
    mask = (kv_pos >= 0) & (kv_pos <= pos[:, None])
    if window and window > 0:
        mask &= kv_pos > (pos[:, None] - window)
    s = jnp.where(mask[:, None, None, :], s, NEG_INF)
    m = jnp.maximum(s.max(axis=-1), s_self)
    pr = jnp.exp(s - m[..., None])
    pr_self = jnp.exp(s_self - m)
    denom = pr.sum(axis=-1) + pr_self
    out = jnp.einsum("bkgt,btkd->bkgd", pr, v_cache,
                     preferred_element_type=jnp.float32)
    out = out + pr_self[..., None] * v[:, 0, :, None, :]
    out = (out / denom[..., None]).astype(x.dtype)
    out = out.reshape(B, 1, cfg.q_dim) @ p["wo"]
    if "bo" in p:
        out = out + p["bo"]
    return out, k, v


# ---------------------------------------------------------------------------
# MLA (multi-head latent attention)
# ---------------------------------------------------------------------------
def init_mla(key, cfg: ModelConfig, n: int | None = None):
    m = cfg.mla
    ks = jax.random.split(key, 8)
    H = cfg.num_heads
    qk_head = m.qk_nope_head_dim + m.qk_rope_head_dim
    sh = (lambda d: (d, cfg.d_model)) if n is None else (lambda d: (n, d, cfg.d_model))
    p = {
        "wdq": _mk(ks[0], n, cfg.d_model, m.q_lora_rank),
        "wuq": _mk(ks[1], n, m.q_lora_rank, H * qk_head),
        "wdkv": _mk(ks[2], n, cfg.d_model, m.kv_lora_rank),
        "wkr": _mk(ks[3], n, cfg.d_model, m.qk_rope_head_dim),
        "wuk": _mk(ks[4], n, m.kv_lora_rank, H * m.qk_nope_head_dim),
        "wuv": _mk(ks[5], n, m.kv_lora_rank, H * m.v_head_dim),
        "wo": _mk(ks[6], n, H * m.v_head_dim, cfg.d_model),
        "q_norm": jnp.ones((m.q_lora_rank,) if n is None else (n, m.q_lora_rank)),
        "kv_norm": jnp.ones((m.kv_lora_rank,) if n is None else (n, m.kv_lora_rank)),
    }
    del sh
    return p


def _mla_qkv_latent(p, cfg: ModelConfig, x: Array, positions: Array):
    """Returns per-head q (nope/rope parts), latent, shared rope key."""
    m = cfg.mla
    B, S, _ = x.shape
    H = cfg.num_heads
    qk_head = m.qk_nope_head_dim + m.qk_rope_head_dim
    q_lat = apply_norm({"scale": p["q_norm"]}, x @ p["wdq"])
    q = (q_lat @ p["wuq"]).reshape(B, S, H, qk_head)
    q_nope, q_rope = q[..., : m.qk_nope_head_dim], q[..., m.qk_nope_head_dim:]
    latent = apply_norm({"scale": p["kv_norm"]}, x @ p["wdkv"])  # [B,S,kvr]
    k_rope = (x @ p["wkr"])[:, :, None, :]                        # [B,S,1,rope]
    cos, sin = rope_freqs(m.qk_rope_head_dim, cfg.rope_theta, positions)
    q_rope = apply_rope(q_rope, cos, sin)
    k_rope = apply_rope(k_rope, cos, sin)
    return q_nope, q_rope, latent, k_rope[:, :, 0, :]


def apply_mla_train(p, cfg: ModelConfig, x: Array, *, window: int = 0,
                    pos_offset: int = 0):
    """Full-sequence MLA. Returns (out, (latent, k_rope)) for prefill."""
    m = cfg.mla
    B, S, _ = x.shape
    H = cfg.num_heads
    positions = pos_offset + jnp.arange(S)
    q_nope, q_rope, latent, k_rope = _mla_qkv_latent(p, cfg, x, positions)
    k_nope = (latent @ p["wuk"]).reshape(B, S, H, m.qk_nope_head_dim)
    v = (latent @ p["wuv"]).reshape(B, S, H, m.v_head_dim)
    # pad v to qk_head so it shares the blockwise kernel, then slice
    qk_head = m.qk_nope_head_dim + m.qk_rope_head_dim
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                  (B, S, H, m.qk_rope_head_dim))], axis=-1)
    if m.v_head_dim < qk_head:
        v = jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, qk_head - m.v_head_dim)))
    out = blockwise_causal_attention(q, k, v, window=window,
                                     logit_cap=cfg.attn_logit_softcap)
    out = out[..., : m.v_head_dim].reshape(B, S, H * m.v_head_dim)
    return out @ p["wo"], (latent, k_rope)


def mla_chunk_qkv(p, cfg: ModelConfig, x: Array, pos0: Array):
    """Multi-token MLA projections with per-row absolute rope positions.

    x: [B, C, D] (a prefill chunk); pos0: [B] absolute position of
    x[:, 0]. Returns (q_nope [B,C,H,nope], q_rope [B,C,H,rope],
    latent [B,C,kvr], k_rope [B,C,rope]) — rope applied at ``pos0 + j``
    per chunk index j. The MLA analogue of :func:`window_qkv`.
    """
    m = cfg.mla
    B, S, _ = x.shape
    H = cfg.num_heads
    qk_head = m.qk_nope_head_dim + m.qk_rope_head_dim
    q_lat = apply_norm({"scale": p["q_norm"]}, x @ p["wdq"])
    q = (q_lat @ p["wuq"]).reshape(B, S, H, qk_head)
    q_nope, q_rope = q[..., : m.qk_nope_head_dim], q[..., m.qk_nope_head_dim:]
    latent = apply_norm({"scale": p["kv_norm"]}, x @ p["wdkv"])
    k_rope = (x @ p["wkr"])[:, :, None, :]

    def rot(qr, kr, p0):
        cos, sin = rope_freqs(m.qk_rope_head_dim, cfg.rope_theta,
                              p0 + jnp.arange(S))
        return apply_rope(qr, cos, sin), apply_rope(kr, cos, sin)
    q_rope, k_rope = jax.vmap(rot)(q_rope, k_rope, pos0)
    return q_nope, q_rope, latent, k_rope[:, :, 0, :]


def mla_chunk_attend(p, cfg: ModelConfig, q_nope: Array, q_rope: Array,
                     latent_ring: Array, krope_ring: Array, mask: Array):
    """Absorbed-weight attention of C chunk queries against the full
    latent ring (insert-then-attend: the chunk's own latents are already
    in the ring, so there is no separate self term and every softmax
    reduction runs at the fixed ring length — the property that makes
    chunked prefill bit-identical for any chunk split).

    q_nope/q_rope: [B,C,H,*]; latent_ring: [B,T,kvr];
    krope_ring: [B,T,rope]; mask: [B,C,T] (True = attendable).
    Returns out [B, C, H * v_head_dim] (pre-``wo``).
    """
    m = cfg.mla
    H = cfg.num_heads
    B, C = q_nope.shape[:2]
    qk_head = m.qk_nope_head_dim + m.qk_rope_head_dim
    wuk_h = jnp.transpose(p["wuk"].reshape(m.kv_lora_rank, H,
                                           m.qk_nope_head_dim), (1, 0, 2))
    q_abs = jnp.einsum("bchd,hrd->bchr", q_nope, wuk_h)
    scale = qk_head ** -0.5
    s = (jnp.einsum("bchr,btr->bhct", q_abs, latent_ring,
                    preferred_element_type=jnp.float32)
         + jnp.einsum("bchd,btd->bhct", q_rope, krope_ring,
                      preferred_element_type=jnp.float32)) * scale
    s = softcap(s, cfg.attn_logit_softcap)
    s = jnp.where(mask[:, None], s, NEG_INF)
    mx = s.max(axis=-1)
    pr = jnp.exp(s - mx[..., None])
    denom = pr.sum(axis=-1)
    o_lat = jnp.einsum("bhct,btr->bhcr", pr, latent_ring,
                       preferred_element_type=jnp.float32)
    o_lat = (o_lat / denom[..., None]).astype(q_nope.dtype)
    wuv_h = jnp.transpose(p["wuv"].reshape(m.kv_lora_rank, H, m.v_head_dim),
                          (1, 0, 2))
    o = jnp.einsum("bhcr,hrd->bchd", o_lat, wuv_h)
    return o.reshape(B, C, H * m.v_head_dim)


def apply_mla_decode(p, cfg: ModelConfig, x: Array, latent_cache: Array,
                     krope_cache: Array, kv_pos: Array, pos: Array, *,
                     window: int = 0):
    """Absorbed-weight MLA decode.

    latent_cache: [B, Skv, kvr]; krope_cache: [B, Skv, rope].
    Scores = (q_nope @ Wuk^T) · latent + q_rope · k_rope; values stay in
    latent space and are expanded only for the single output token.
    Returns (out [B,1,D], latent_new [B,1,kvr], k_rope_new [B,1,rope]).
    """
    m = cfg.mla
    B = x.shape[0]
    H = cfg.num_heads
    qk_head = m.qk_nope_head_dim + m.qk_rope_head_dim
    q_lat = apply_norm({"scale": p["q_norm"]}, x @ p["wdq"])
    q = (q_lat @ p["wuq"]).reshape(B, 1, H, qk_head)
    q_nope, q_rope = q[..., : m.qk_nope_head_dim], q[..., m.qk_nope_head_dim:]
    latent_new = apply_norm({"scale": p["kv_norm"]}, x @ p["wdkv"])
    krope_raw = x @ p["wkr"]

    def rot(qr, kr, pb):
        cos, sin = rope_freqs(m.qk_rope_head_dim, cfg.rope_theta, pb[None])
        return apply_rope(qr, cos, sin), apply_rope(kr[:, None, :], cos, sin)[:, 0]
    q_rope, krope_new = jax.vmap(rot)(q_rope, krope_raw, pos)

    # absorb Wuk into the query: q' = q_nope @ Wuk^T -> latent-space scores
    wuk_h = jnp.transpose(p["wuk"].reshape(m.kv_lora_rank, H,
                                           m.qk_nope_head_dim), (1, 0, 2))
    q_abs = jnp.einsum("bhd,hrd->bhr", q_nope[:, 0], wuk_h)     # [B,H,kvr]
    scale = qk_head ** -0.5
    s = (jnp.einsum("bhr,btr->bht", q_abs, latent_cache,
                    preferred_element_type=jnp.float32)
         + jnp.einsum("bhd,btd->bht", q_rope[:, 0], krope_cache,
                      preferred_element_type=jnp.float32)) * scale
    s_self = (jnp.einsum("bhr,br->bh", q_abs, latent_new[:, 0],
                         preferred_element_type=jnp.float32)
              + jnp.einsum("bhd,bd->bh", q_rope[:, 0], krope_new[:, 0],
                           preferred_element_type=jnp.float32)) * scale
    mask = (kv_pos >= 0) & (kv_pos <= pos[:, None])
    if window and window > 0:
        mask &= kv_pos > (pos[:, None] - window)
    s = jnp.where(mask[:, None, :], s, NEG_INF)
    mx = jnp.maximum(s.max(axis=-1), s_self)
    pr = jnp.exp(s - mx[..., None])
    pr_self = jnp.exp(s_self - mx)
    denom = pr.sum(axis=-1) + pr_self
    # output stays latent: [B,H,kvr]
    o_lat = jnp.einsum("bht,btr->bhr", pr, latent_cache,
                       preferred_element_type=jnp.float32)
    o_lat = o_lat + pr_self[..., None] * latent_new[:, 0][:, None, :]
    o_lat = (o_lat / denom[..., None]).astype(x.dtype)
    wuv_h = jnp.transpose(p["wuv"].reshape(m.kv_lora_rank, H, m.v_head_dim),
                          (1, 0, 2))                            # [H,kvr,vd]
    o = jnp.einsum("bhr,hrd->bhd", o_lat, wuv_h)                # [B,H,vd]
    out = o.reshape(B, 1, H * m.v_head_dim) @ p["wo"]
    return out, latent_new, krope_new
