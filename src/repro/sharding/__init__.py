from repro.sharding.api import (  # noqa: F401
    axis_rules, constrain, current_rules, logical_to_pspec, param_shardings,
    PARAM_RULES, ACT_RULES,
)
