"""Logical-axis sharding (MaxText/flax-linen style, dependency-free).

Model code annotates activations with *logical* axis names via
:func:`constrain`; the launcher activates a rule set mapping logical names to
mesh axes with :func:`axis_rules`. Outside a rule context every annotation is
a no-op, so models run unchanged on a single CPU device.

Mesh-axis allocation is shape-aware: for each array, logical axes are
resolved right-to-left; a mesh axis is assigned at most once and only if the
dimension size is divisible by it. Indivisible or conflicting axes fall back
to replication — e.g. 8 KV heads on a model=16 mesh replicate (the Megatron
GQA convention), and a 49155-row vocab falls back to sequence sharding where
the annotation provides one ("seq_mp").
"""
from __future__ import annotations

import contextlib
import math
import re
import threading

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_STATE = threading.local()

# activation logical axis -> mesh axes (tuple = try to use all that fit)
ACT_RULES = {
    "batch": ("pod", "data"),   # batch shards over pod x data
    "seq": None,                # sequence replicated by default
    "seq_mp": "model",          # fallback sequence sharding (logits, LITE CE)
    "seq_attn": "model",        # query-seq sharding inside blockwise attention
    "ctx": "model",             # KV-cache sequence dim (context parallelism)
    "embed": None,
    "heads": "model",
    "kv_heads": "model",
    "ff": "model",
    "experts": "model",
    "vocab": "model",
    "state": None,
}

# parameter path regex -> logical spec applied to the *trailing* dims;
# leading (stacked-layer) dims are replicated.
PARAM_RULES = [
    (r"embed/tok$", ("vocab", "embed")),
    (r"embed/pos$", (None, "embed")),
    (r"head$", ("embed", "vocab")),
    (r"/w[qkv]$", ("embed", "heads")),
    (r"/wo$", ("heads", "embed")),
    (r"/(wdq|wdkv|wkr)$", ("embed", "heads")),
    (r"/(wuq|wuk|wuv)$", (None, "heads")),
    # expert weights: expert-parallel (experts padded to a multiple of the
    # model-axis size); the right-to-left allocator would otherwise give
    # the mesh axis to d_ff, so ff is deliberately unmapped here.
    (r"moe/(up|gate)$", ("experts", "embed", None)),
    (r"moe/down$", ("experts", None, "embed")),
    (r"/shared_(up|gate)$", ("embed", "ff")),
    (r"/shared_down$", ("ff", "embed")),
    (r"/router$", ("embed", None)),
    (r"/(up|gate)$", ("embed", "ff")),
    (r"/down$", ("ff", "embed")),
    (r"/in_proj$", ("embed", "heads")),
    (r"/out_proj$", ("heads", "embed")),
    (r"/conv_w$", (None, "heads")),
    (r"/conv_b$", ("heads",)),
    (r"/gate_norm$", ("heads",)),
]


@contextlib.contextmanager
def axis_rules(mesh: Mesh):
    """Activate activation-sharding constraints for ``mesh``."""
    prev = getattr(_STATE, "mesh", None)
    _STATE.mesh = mesh
    try:
        yield
    finally:
        _STATE.mesh = prev


def current_rules() -> Mesh | None:
    return getattr(_STATE, "mesh", None)


def _mesh_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    return math.prod(mesh.shape[a] for a in axes)


def _allocate(logical_axes, shape, mesh: Mesh) -> P:
    """Assign mesh axes to dims right-to-left, shape- and conflict-aware."""
    used: set[str] = set()
    out: list = [None] * len(logical_axes)
    for i in range(len(logical_axes) - 1, -1, -1):
        logical = logical_axes[i]
        if logical is None:
            continue
        ax = ACT_RULES.get(logical, logical) if isinstance(logical, str) \
            else logical
        if ax is None:
            continue
        cand = (ax,) if isinstance(ax, str) else tuple(ax)
        cand = tuple(a for a in cand
                     if a in mesh.axis_names and a not in used)
        # drop leading axes until the product divides the dim
        while cand and shape[i] % _mesh_size(mesh, cand) != 0:
            cand = cand[1:]
        if not cand:
            continue
        used.update(cand)
        out[i] = cand if len(cand) > 1 else cand[0]
    return P(*out)


def logical_to_pspec(logical_axes, mesh: Mesh, shape=None) -> P:
    if shape is None:
        shape = tuple(0 for _ in logical_axes)  # unknown: no divisibility

        # unknown shapes: accept everything (legacy callers)
        used: set = set()
        out = []
        for a in logical_axes:
            ax = ACT_RULES.get(a, a) if isinstance(a, str) else a
            if ax is None:
                out.append(None)
                continue
            cand = (ax,) if isinstance(ax, str) else tuple(ax)
            cand = tuple(x for x in cand
                         if x in mesh.axis_names and x not in used)
            used.update(cand)
            out.append(cand if len(cand) > 1 else (cand[0] if cand else None))
        return P(*out)
    return _allocate(logical_axes, shape, mesh)


def constrain(x, *logical_axes):
    """Attach a sharding constraint if a rule context is active."""
    mesh = current_rules()
    if mesh is None:
        return x
    if len(logical_axes) != x.ndim:
        raise ValueError(f"constrain: {len(logical_axes)} axes for rank "
                         f"{x.ndim} array")
    spec = _allocate(logical_axes, x.shape, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def _spec_for_path(path: str, shape, mesh: Mesh) -> P:
    for pat, logical in PARAM_RULES:
        if re.search(pat, path):
            ndim = len(shape)
            trail = list(logical)
            if len(trail) > ndim:
                trail = trail[-ndim:]
            lead = [None] * (ndim - len(trail))
            return _allocate(lead + trail, shape, mesh)
    return P()  # replicated


def _path_str(kp) -> str:
    parts = []
    for k in kp:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


def param_shardings(params, mesh: Mesh, *, zero_axes: tuple = ()):
    """NamedSharding pytree for a param pytree by path-based rules.

    ``zero_axes``: mesh axes (e.g. ("pod", "data")) over which to
    additionally shard the largest replicated dim of every leaf — ZeRO-style
    optimizer-state partitioning.
    """
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    leaves = []
    for kp, v in flat:
        spec = _spec_for_path(_path_str(kp), v.shape, mesh)
        if zero_axes:
            spec = _apply_zero(spec, v.shape, mesh, zero_axes)
        leaves.append(NamedSharding(mesh, spec))
    return jax.tree_util.tree_unflatten(treedef, leaves)


def _apply_zero(spec: P, shape, mesh: Mesh, zero_axes) -> P:
    zero_axes = tuple(a for a in zero_axes if a in mesh.axis_names)
    if not zero_axes:
        return spec
    z = _mesh_size(mesh, zero_axes)
    entries = list(spec) + [None] * (len(shape) - len(spec))
    # shard the largest currently-replicated dim divisible by z
    best, best_size = -1, 0
    for i, (e, s) in enumerate(zip(entries, shape)):
        if e is None and s % z == 0 and s > best_size:
            best, best_size = i, s
    if best >= 0:
        entries[best] = zero_axes if len(zero_axes) > 1 else zero_axes[0]
    return P(*entries)
