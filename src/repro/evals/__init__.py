"""Energy-aware code-generation eval harness (pass-rate vs J/token).

The paper's headline claim — large energy savings *without significantly
affecting accuracy* — needs both axes measured on the same run. This
package supplies the accuracy axis and joins it to the serving stack's
per-request energy attribution:

``tasks``    HumanEval-style completion tasks: a small vendored
             deterministic set plus a JSONL loader for external suites.
``sandbox``  subprocess checker: candidate programs run isolated in a
             tempdir with timeouts and a write guard.
``stats``    the unbiased pass@k estimator.
``loadgen``  seeded Poisson arrival schedules for the HTTP driver.
``runner``   two drivers with one report schema: a live HTTP client
             (Poisson load against ``repro.serving.server``) and a
             deterministic virtual-clock replay mirroring
             ``benchmarks.serving_load.run_admission_trace``.
``report``   frontier assembly + BENCH_eval.json emission.
"""
from repro.evals.report import (frontier, payload_bytes,  # noqa: F401
                                payload_digest, write_bench)
from repro.evals.runner import (EvalRunConfig, PolicyArm,  # noqa: F401
                                default_arms, run_http, run_replay)
from repro.evals.sandbox import CheckResult, check_completion  # noqa: F401
from repro.evals.stats import pass_at_k  # noqa: F401
from repro.evals.tasks import (EvalTask, load_jsonl,  # noqa: F401
                               smoke_tasks, vendored_tasks)
