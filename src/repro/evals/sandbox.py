"""Sandboxed subprocess checker for candidate programs.

Each check runs ``task.program(completion)`` in a fresh ``python -I``
subprocess with:

* a private tempdir as cwd — deleted afterwards;
* a wall-clock timeout (the parent kills the process group) and a CPU
  rlimit one notch above it, so a busy-looping candidate dies either way;
* an address-space rlimit against runaway allocation;
* a write guard installed before the candidate runs: ``open``/``io.open``
  and ``os.open`` refuse to create or write anything that resolves
  outside the sandbox dir (reads stay unrestricted — the test harness
  itself is file-based).

This is a *reliability* sandbox in the HumanEval tradition — it converts
broken generated code into a clean "failed" verdict and keeps stray
writes out of the repo checkout. It is not a security boundary against
an adversarial model.

Status taxonomy (the distinction the negative-path tests pin down):
``passed``   exit code 0
``failed``   nonzero exit — assertion, exception, SyntaxError, killed by
             a signal: the *sample* is wrong, the harness is fine
``timeout``  wall-clock or CPU limit hit
``error``    the harness itself could not run the check (spawn failure)
"""
from __future__ import annotations

import os
import subprocess
import sys
import tempfile
import time
from dataclasses import dataclass

# Installed ahead of the candidate program inside `python -I -c`.
# The guard chdirs are done by the parent (cwd=sandbox); realpath of a
# relative path therefore resolves inside the sandbox.
_GUARD = r"""
import builtins, io, os, sys, tempfile
SANDBOX = os.path.realpath(os.getcwd())
tempfile.tempdir = SANDBOX
try:
    import resource
    _cpu = {cpu_s}
    resource.setrlimit(resource.RLIMIT_CPU, (_cpu, _cpu))
    resource.setrlimit(resource.RLIMIT_AS, (1 << 31, 1 << 31))
except Exception:
    pass

def _inside(p):
    p = os.path.realpath(os.fspath(p))
    return p == SANDBOX or p.startswith(SANDBOX + os.sep)

_open = builtins.open
def _guarded_open(file, mode="r", *a, **k):
    if not isinstance(file, int) and any(ch in str(mode) for ch in "wax+"):
        if not _inside(file):
            raise PermissionError(f"sandbox: write outside tempdir: {{file!r}}")
    return _open(file, mode, *a, **k)
builtins.open = _guarded_open
io.open = _guarded_open

_os_open = os.open
_W = os.O_WRONLY | os.O_RDWR | os.O_CREAT | os.O_APPEND | os.O_TRUNC
def _guarded_os_open(path, flags, *a, **k):
    if not isinstance(path, int) and (flags & _W) and not _inside(path):
        raise PermissionError(f"sandbox: write outside tempdir: {{path!r}}")
    return _os_open(path, flags, *a, **k)
os.open = _guarded_os_open

_src = _open("__candidate__.py", encoding="utf-8").read()
exec(compile(_src, "candidate.py", "exec"), {{"__name__": "__main__"}})
"""


@dataclass(frozen=True)
class CheckResult:
    """Outcome of one sandboxed candidate check."""
    status: str                 # passed | failed | timeout | error
    detail: str = ""            # stderr tail / harness error message
    duration_s: float = 0.0     # wall-clock (excluded from replay payloads)

    @property
    def passed(self) -> bool:
        return self.status == "passed"


def check_completion(task, completion: str,
                     timeout_s: float = 10.0) -> CheckResult:
    """Run ``task.program(completion)`` sandboxed; classify the outcome."""
    program = task.program(completion)
    guard = _GUARD.format(cpu_s=max(int(timeout_s) + 1, 2))
    t0 = time.monotonic()
    with tempfile.TemporaryDirectory(prefix="repro-eval-") as box:
        with open(os.path.join(box, "__candidate__.py"), "w",
                  encoding="utf-8") as f:
            f.write(program)
        env = {"PATH": os.environ.get("PATH", "/usr/bin:/bin"),
               "HOME": box, "TMPDIR": box}
        try:
            proc = subprocess.run(
                [sys.executable, "-I", "-c", guard],
                cwd=box, env=env, timeout=timeout_s,
                stdout=subprocess.PIPE, stderr=subprocess.PIPE,
                stdin=subprocess.DEVNULL)
        except subprocess.TimeoutExpired:
            return CheckResult("timeout",
                               f"wall-clock timeout after {timeout_s}s",
                               time.monotonic() - t0)
        except OSError as e:            # spawn infrastructure failure
            return CheckResult("error", f"spawn failed: {e}",
                               time.monotonic() - t0)
    dt = time.monotonic() - t0
    if proc.returncode == 0:
        return CheckResult("passed", "", dt)
    tail = proc.stderr.decode("utf-8", "replace")[-400:]
    # SIGXCPU (CPU rlimit) presents as a negative returncode; classify a
    # CPU-limit kill as timeout, everything else as a failed sample
    try:
        import signal
        if proc.returncode == -signal.SIGXCPU:
            return CheckResult("timeout", "CPU rlimit exceeded", dt)
    except (ImportError, AttributeError):
        pass
    return CheckResult("failed", tail, dt)
