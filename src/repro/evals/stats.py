"""The unbiased pass@k estimator (Codex paper, eq. 1).

Given ``n`` samples of which ``c`` passed, the estimator is the
probability that a uniformly-drawn size-``k`` subset contains at least
one passing sample:

    pass@k = 1 - C(n-c, k) / C(n, k)
           = 1 - prod_{i=n-c+1..n} (1 - k/i)

The product form is the numerically stable one (no large binomials).
tests/test_evals.py cross-checks it against brute-force subset
enumeration for every (n, c, k) with n <= 12.
"""
from __future__ import annotations

import numpy as np


def pass_at_k(n: int, c: int, k: int) -> float:
    """Unbiased pass@k from ``n`` samples with ``c`` passes.

    ``k > n`` clamps to ``n`` (with all samples drawn, pass@n is the
    right-hand anchor of the curve); ``c == 0`` is exactly 0 and
    ``n - c < k`` exactly 1 without touching the product.
    """
    if n < 1:
        raise ValueError(f"n must be >= 1, got {n}")
    if not 0 <= c <= n:
        raise ValueError(f"c must be in [0, n={n}], got {c}")
    if k < 1:
        raise ValueError(f"k must be >= 1, got {k}")
    k = min(k, n)
    if c == 0:
        return 0.0
    if n - c < k:
        return 1.0
    return float(1.0 - np.prod(1.0 - k / np.arange(n - c + 1, n + 1,
                                                   dtype=np.float64)))


def pass_at_k_bruteforce(n: int, c: int, k: int) -> float:
    """Reference implementation: enumerate every size-k subset of the n
    samples and count those containing >= 1 of the c passes. O(C(n, k)) —
    test-only, feasible for n <= ~12."""
    from itertools import combinations
    k = min(k, n)
    passing = set(range(c))                   # WLOG the first c pass
    total = hit = 0
    for subset in combinations(range(n), k):
        total += 1
        hit += bool(passing.intersection(subset))
    return hit / total
