"""HumanEval-style completion tasks: vendored set + JSONL loader.

A task is a *completion* problem: the model continues ``prompt`` and the
concatenation ``prompt + completion`` must define ``entry_point`` such
that the ``test`` program's ``check(entry_point)`` passes (the HumanEval
contract; see the energy-code-eval harness for the same schema).

The vendored set is deliberately tiny and deterministic. Two task styles
matter for CI on untrained toy models:

* *comment tasks* — the prompt already defines a correct ``entry_point``
  and ends inside a line comment with stop ``("\\n",)``; any truncated
  completion keeps the program valid, so they pass regardless of model
  quality. They give the frontier a nonzero, arm-invariant pass floor.
* *needle tasks* — passing requires emitting an exact short string, which
  an untrained model essentially never does; they pin the failure side.

Every vendored ``canonical_solution`` passes its own test (asserted in
tests/test_evals.py), so the sandbox's positive path is self-checking.
"""
from __future__ import annotations

import json
from dataclasses import dataclass

# standard BigCode-style completion stops: a new top-level definition or
# statement ends the function body being completed
DEFAULT_STOPS = ("\ndef ", "\nclass ", "\nif ", "\nprint")


@dataclass(frozen=True)
class EvalTask:
    """One completion task (HumanEval schema subset)."""
    task_id: str
    prompt: str                       # the model continues this text
    entry_point: str                  # function the test calls
    test: str                         # defines check(candidate)
    stop_sequences: tuple = DEFAULT_STOPS
    max_new_tokens: int = 24
    canonical_solution: str = ""      # reference completion (must pass)

    def program(self, completion: str) -> str:
        """The candidate program the sandbox executes.

        NUL bytes are stripped: the byte-fallback tokenizer can emit them
        mid-stream and CPython rejects NUL in source text — this is the
        harness's only completion post-processing, applied identically to
        every arm.
        """
        body = self.prompt + completion.replace("\x00", "")
        return (f"{body}\n\n{self.test}\n"
                f"check({self.entry_point})\n")


def _task(task_id, prompt, entry_point, test, *, stops=DEFAULT_STOPS,
          max_new=24, canonical="") -> EvalTask:
    return EvalTask(task_id=task_id, prompt=prompt, entry_point=entry_point,
                    test=test, stop_sequences=tuple(stops),
                    max_new_tokens=max_new, canonical_solution=canonical)


def vendored_tasks() -> tuple[EvalTask, ...]:
    """The vendored deterministic suite (8 tasks)."""
    return (
        _task(
            "vend/comment_pad",
            'def pad(xs):\n'
            '    """Identity pad helper."""\n'
            '    return xs\n'
            '\n'
            '# note: ',
            "pad",
            "def check(candidate):\n"
            "    assert candidate([1, 2]) == [1, 2]\n"
            "    assert candidate([]) == []\n",
            stops=("\n",), max_new=12, canonical="identity, no-op"),
        _task(
            "vend/comment_greet",
            'def greet(name):\n'
            '    """Greet by name."""\n'
            '    return "hi " + name\n'
            '\n'
            '# summary: ',
            "greet",
            "def check(candidate):\n"
            "    assert candidate('ada') == 'hi ada'\n",
            stops=("\n",), max_new=12, canonical="string concat"),
        _task(
            "vend/needle",
            'def needle():\n'
            '    """Return the magic string."""\n'
            '    return "xyzzy-',
            "needle",
            "def check(candidate):\n"
            "    assert candidate() == 'xyzzy-plugh'\n",
            stops=("\n",), max_new=12, canonical='plugh"'),
        _task(
            "vend/add_two",
            'def add_two(x):\n'
            '    """Return x plus 2."""\n',
            "add_two",
            "def check(candidate):\n"
            "    assert candidate(0) == 2\n"
            "    assert candidate(-2) == 0\n"
            "    assert candidate(40) == 42\n",
            canonical="    return x + 2\n"),
        _task(
            "vend/is_even",
            'def is_even(n):\n'
            '    """True iff n is even."""\n',
            "is_even",
            "def check(candidate):\n"
            "    assert candidate(2) is True\n"
            "    assert candidate(3) is False\n"
            "    assert candidate(0) is True\n",
            canonical="    return n % 2 == 0\n"),
        _task(
            "vend/reverse_string",
            'def reverse_string(s):\n'
            '    """Return s reversed."""\n',
            "reverse_string",
            "def check(candidate):\n"
            "    assert candidate('abc') == 'cba'\n"
            "    assert candidate('') == ''\n",
            canonical="    return s[::-1]\n"),
        _task(
            "vend/max_of_three",
            'def max_of_three(a, b, c):\n'
            '    """Largest of the three arguments."""\n',
            "max_of_three",
            "def check(candidate):\n"
            "    assert candidate(1, 2, 3) == 3\n"
            "    assert candidate(5, -1, 2) == 5\n",
            canonical="    return max(a, b, c)\n"),
        _task(
            "vend/count_vowels",
            'def count_vowels(s):\n'
            '    """Number of vowels (aeiou) in s."""\n',
            "count_vowels",
            "def check(candidate):\n"
            "    assert candidate('abcde') == 2\n"
            "    assert candidate('xyz') == 0\n",
            canonical="    return sum(1 for ch in s if ch in 'aeiou')\n"),
    )


def smoke_tasks() -> tuple[EvalTask, ...]:
    """The 2-task CI smoke pair: one always-pass comment task, one
    needle task an untrained model cannot hit — pass@1 is exactly 0.5."""
    by_id = {t.task_id: t for t in vendored_tasks()}
    return (by_id["vend/comment_pad"], by_id["vend/needle"])


REQUIRED_KEYS = ("task_id", "prompt", "entry_point", "test")


def load_jsonl(path) -> tuple[EvalTask, ...]:
    """Load an external HumanEval-style JSONL task file.

    Each line is an object with ``task_id``/``prompt``/``entry_point``/
    ``test`` (required) and ``stop_sequences``/``max_new_tokens``/
    ``canonical_solution`` (optional). Errors name the offending line.
    """
    tasks = []
    with open(path, encoding="utf-8") as f:
        for ln, raw in enumerate(f, 1):
            raw = raw.strip()
            if not raw:
                continue
            try:
                obj = json.loads(raw)
            except json.JSONDecodeError as e:
                raise ValueError(f"{path}:{ln}: invalid JSON: {e}") from e
            if not isinstance(obj, dict):
                raise ValueError(f"{path}:{ln}: expected an object")
            missing = [k for k in REQUIRED_KEYS if k not in obj]
            if missing:
                raise ValueError(f"{path}:{ln}: missing keys {missing}")
            for k in REQUIRED_KEYS:
                if not isinstance(obj[k], str) or not obj[k]:
                    raise ValueError(
                        f"{path}:{ln}: {k!r} must be a non-empty string")
            stops = obj.get("stop_sequences", list(DEFAULT_STOPS))
            if (not isinstance(stops, list)
                    or any(not isinstance(s, str) or not s for s in stops)):
                raise ValueError(f"{path}:{ln}: stop_sequences must be a "
                                 f"list of non-empty strings")
            max_new = obj.get("max_new_tokens", 24)
            if not isinstance(max_new, int) or max_new < 1:
                raise ValueError(f"{path}:{ln}: max_new_tokens must be a "
                                 f"positive int")
            tasks.append(EvalTask(
                task_id=obj["task_id"], prompt=obj["prompt"],
                entry_point=obj["entry_point"], test=obj["test"],
                stop_sequences=tuple(stops), max_new_tokens=max_new,
                canonical_solution=obj.get("canonical_solution", "")))
    if not tasks:
        raise ValueError(f"{path}: no tasks found")
    ids = [t.task_id for t in tasks]
    if len(set(ids)) != len(ids):
        dup = sorted({i for i in ids if ids.count(i) > 1})
        raise ValueError(f"{path}: duplicate task_ids {dup}")
    return tuple(tasks)
