"""Two eval drivers, one report schema.

``run_http``
    Live client: per-sample threads POST streaming ``/generate`` requests
    against a running ``repro.serving.server`` at seeded Poisson arrival
    offsets, measure wall-clock TTFT at the first NDJSON token line, take
    per-request energy from the final metrics record, and cross-join the
    scheduler's ``req/*`` lifecycle spans from ``GET /trace`` for the
    attribution audit trail.

``run_replay``
    Deterministic mode mirroring ``benchmarks.serving_load.
    run_admission_trace``: completions are generated *sequentially*
    through one in-process scheduler (exactly one resident at a time, so
    tokens / exit layers / joules are independent of co-residency — the
    speculative window and the sampling streams see a fixed batch), and
    timing comes from an integer virtual clock (job i arrives at tick i,
    one chunked prefill in flight, 1 token per resident per tick). The
    payload contains no wall-clock value, so two replays of the same
    config are byte-identical — CI hard-gates on that.

Both emit the same per-arm summary: per-task pass counts, pass@k, token
and joule totals, J/token, TTFT p95 (seconds live, ticks replayed).
"""
from __future__ import annotations

import hashlib
import json
import threading
import time
import urllib.error
import urllib.request
from collections import Counter
from dataclasses import dataclass, field

import numpy as np

from repro.api import GenerationRequest, PolicySpec, SamplingParams
from repro.evals.loadgen import poisson_times
from repro.evals.sandbox import check_completion
from repro.evals.stats import pass_at_k
from repro.serving.metrics import latency_percentiles

SCHEMA_VERSION = 1


# ---------------------------------------------------------------------------
# Arms and config
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class PolicyArm:
    """One exit-policy configuration on the frontier.

    ``policy`` is the JSON policy object the HTTP server accepts
    (``{"name": ..., **params}``); :meth:`spec` is the same thing for the
    in-process replay scheduler.
    """
    name: str
    policy: dict = field(default_factory=lambda: {"name": "none"})

    def spec(self) -> PolicySpec:
        params = {k: float(v) for k, v in self.policy.items()
                  if k != "name"}
        return PolicySpec(str(self.policy["name"]), params)


def default_arms(*, thresholds=(0.6, 0.8), fixed=(0,),
                 speculative: bool = True,
                 spec_window: int = 4) -> tuple[PolicyArm, ...]:
    """baseline + early-exit sweep (fixed anchor + confidence
    thresholds) + speculative. The fixed-exit anchor always exits at its
    exit point, so the frontier has a guaranteed lower-J/token row even
    for models whose confidence never crosses a threshold; the model
    needs >= 1 exit point (``core.exit_points``) for any non-baseline
    arm to differ."""
    arms = [PolicyArm("baseline", {"name": "none"})]
    arms += [PolicyArm(f"fixed@{i}", {"name": "fixed", "exit_idx": float(i)})
             for i in fixed]
    arms += [PolicyArm(f"confidence@{t:g}",
                       {"name": "confidence", "threshold": float(t)})
             for t in thresholds]
    if speculative:
        arms.append(PolicyArm("speculative",
                              {"name": "speculative", "draft_idx": 0,
                               "window": float(spec_window)}))
    return tuple(arms)


@dataclass(frozen=True)
class EvalRunConfig:
    """Knobs shared by both drivers. Seeds are derived per (task, sample)
    so a sample's draw stream never depends on suite composition."""
    n_samples: int = 1
    ks: tuple = (1, 10)
    temperature: float = 0.0          # <= 0: greedy (n_samples should be 1)
    top_p: float = 1.0
    seed: int = 0
    rate_hz: float = 8.0              # HTTP driver Poisson arrival rate
    check_timeout_s: float = 10.0
    request_timeout_s: float = 300.0

    def sample_seed(self, task_idx: int, sample_idx: int) -> int:
        return (self.seed * 100003 + task_idx * 1009 + sample_idx) % (2**31)


# ---------------------------------------------------------------------------
# Shared aggregation
# ---------------------------------------------------------------------------
def _sha(text: str) -> str:
    return hashlib.sha256(text.encode("utf-8", "surrogatepass")).hexdigest()


def _aggregate_arm(arm: PolicyArm, tasks, samples: list, cfg: EvalRunConfig,
                   ttfts: list, ttft_unit: str) -> dict:
    """Fold per-sample records into the arm summary both drivers share."""
    per_task: dict = {}
    for t in tasks:
        per_task[t.task_id] = {"n": 0, "c": 0}
    tok = 0
    e_dec = 0.0
    e_pre = 0.0
    layer_sum = 0.0
    statuses: Counter = Counter()
    reasons: Counter = Counter()
    for s in samples:
        pt = per_task[s["task_id"]]
        pt["n"] += 1
        pt["c"] += int(s["status"] == "passed")
        tok += s["tokens"]
        e_dec += s["energy_j"]
        e_pre += s["prefill_energy_j"]
        layer_sum += s["mean_exit_layer"] * s["tokens"]
        statuses[s["status"]] += 1
        reasons[s["finish_reason"]] += 1
    pass_at = {}
    for k in cfg.ks:
        vals = [pass_at_k(pt["n"], pt["c"], k)
                for pt in per_task.values() if pt["n"]]
        pass_at[str(k)] = float(np.mean(vals)) if vals else 0.0
    pct = latency_percentiles(ttfts)
    return {
        "policy": dict(arm.policy),
        "samples": len(samples),
        "per_task": per_task,
        "pass_at": pass_at,
        "tokens": tok,
        "decode_energy_j": e_dec,
        "prefill_energy_j": e_pre,
        "j_per_token": e_dec / max(tok, 1),
        "mean_exit_layer": layer_sum / max(tok, 1),
        "statuses": dict(sorted(statuses.items())),
        "finish_reasons": dict(sorted(reasons.items())),
        f"ttft_p50_{ttft_unit}": pct["p50_s"],
        f"ttft_p95_{ttft_unit}": pct["p95_s"],
    }


def _flat_samples(tasks, cfg: EvalRunConfig):
    """Deterministic submission order: tasks in suite order, samples
    innermost. Yields (flat_idx, task_idx, task, sample_idx)."""
    j = 0
    for ti, t in enumerate(tasks):
        for si in range(cfg.n_samples):
            yield j, ti, t, si
            j += 1


# ---------------------------------------------------------------------------
# Deterministic replay driver
# ---------------------------------------------------------------------------
def _virtual_clock(jobs, *, slots: int = 4, chunk: int = 16,
                   substeps: int = 1, interleave_prefill: bool = True,
                   tokens_per_super: int = 1) -> dict:
    """Integer virtual-clock timing for a list of (prompt_len, n_tokens)
    jobs, mirroring ``run_admission_trace``: job i arrives at step i, one
    chunked prefill in flight at a time (shortest prompt first, id
    tiebreak, ``ceil(plen/chunk)`` chunk steps), then ``tokens_per_super``
    tokens per resident per super-tick. TTFT is arrival → end of the job's
    last prefill chunk, measured in compiled-model *steps* so arms with
    different super-tick depths stay comparable.

    ``substeps`` models the super-tick depth: a speculative arm runs
    ``spec_window`` draft steps + 1 verify per scheduler tick, so its
    super-tick costs ``spec_window + 1`` steps. With
    ``interleave_prefill`` (the scheduler's behavior) the in-flight
    admission advances one chunk per *step*; without it (the pre-fix
    scheduler, kept as the regression baseline) prefill advances only one
    chunk per super-tick — which is exactly the ``(K+1)x`` TTFT
    starvation the BENCH_eval.json speculative outlier showed."""
    n = len(jobs)
    queue: list = []
    prefill = None                    # [job_idx, chunks_left]
    residents: dict = {}              # job_idx -> tokens left to emit
    ttft = [None] * n
    finish = [None] * n
    events = []
    done = 0
    arrived = 0
    chunks_per_super = substeps if interleave_prefill else 1
    for s in range(1_000_000):
        if done == n:
            break
        t = s * substeps              # clock in compiled-model steps
        while arrived < n and arrived <= t:
            queue.append(arrived)
            events.append([t, "arrive", arrived])
            arrived += 1
        if prefill is None and queue and len(residents) < slots:
            queue.sort(key=lambda i: (jobs[i][0], i))
            i = queue.pop(0)
            prefill = [i, max(-(-jobs[i][0] // chunk), 1)]
            events.append([t, "admit", i])
        for i in sorted(residents):
            residents[i] -= min(tokens_per_super, residents[i])
            if residents[i] == 0:
                del residents[i]
                finish[i] = t
                events.append([t, "retire", i])
                done += 1
        if prefill is not None:
            advanced = min(chunks_per_super, prefill[1])
            prefill[1] -= advanced
            if prefill[1] == 0:
                i, prefill = prefill[0], None
                t_done = t + advanced            # chunk steps consumed
                n_tok = jobs[i][1]
                if n_tok > 0:
                    ttft[i] = t_done - i         # arrival step is i
                    events.append([t_done, "first_token", i])
                if n_tok <= 1:                   # 0 or 1 token: no decode
                    finish[i] = t_done
                    events.append([t_done, "retire", i])
                    done += 1
                else:
                    residents[i] = n_tok - 1
    else:
        raise RuntimeError("virtual clock did not converge")
    return {"events": events, "ttft_ticks": ttft,
            "finish_ticks": finish, "makespan_ticks": s * substeps}


def run_replay(params, model_cfg, tokenizer, tasks, arms, cfg: EvalRunConfig,
               *, slots: int = 4, prefill_chunk: int = 16,
               spec_window: int = 4) -> dict:
    """Deterministic eval replay; the returned payload is a pure function
    of (params, model_cfg, tasks, arms, cfg) — no wall clock anywhere."""
    from repro.obs import Tracer
    from repro.serving.scheduler import Scheduler

    tasks = tuple(tasks)
    arms = tuple(arms)
    kinds = sorted({"none"} | {str(a.policy["name"]) for a in arms})
    enc = {t.task_id: tokenizer.encode(t.prompt) for t in tasks}
    max_plen = max(len(v) for v in enc.values())
    max_new = max(t.max_new_tokens for t in tasks)
    sched = Scheduler(
        params, model_cfg, allowed_kinds=kinds, tokenizer=tokenizer,
        default_policy="none", max_slots=1,
        max_len=max_plen + max_new + spec_window + 2, max_new=max_new,
        prefill_chunk=prefill_chunk, spec_window=spec_window,
        kv_layout="contiguous", tracer=Tracer(enabled=False))
    sched.start()
    arms_out = {}
    try:
        for arm in arms:
            samples = []
            jobs = []
            for _, ti, task, si in _flat_samples(tasks, cfg):
                greedy = cfg.temperature <= 0.0
                req = GenerationRequest(
                    prompt=task.prompt,
                    max_new_tokens=task.max_new_tokens,
                    policy=arm.spec(),
                    sampling=SamplingParams(
                        temperature=max(cfg.temperature, 0.0),
                        top_p=cfg.top_p if not greedy else 1.0,
                        seed=cfg.sample_seed(ti, si)),
                    stop_sequences=task.stop_sequences)
                h = sched.submit(req)
                h.result(timeout=cfg.request_timeout_s)
                res = h.to_result(tokenizer)
                check = check_completion(task, res.text or "",
                                         timeout_s=cfg.check_timeout_s)
                el = res.exit_layers or [model_cfg.num_layers]
                samples.append({
                    "task_id": task.task_id, "sample": si,
                    "status": check.status,
                    "tokens": res.n_tokens,
                    "energy_j": res.energy_j,
                    "prefill_energy_j": res.prefill_energy_j,
                    "mean_exit_layer": float(np.mean(el)),
                    "finish_reason": res.finish_reason,
                    "text_sha256": _sha(res.text or ""),
                })
                jobs.append((len(enc[task.task_id]), res.n_tokens))
            # speculative arms run spec_window drafts + 1 verify per
            # super-tick; the clock charges them in compiled-model steps
            # (with the scheduler's chunk-per-step prefill interleave) so
            # TTFT stays comparable to the baseline arm
            is_spec = str(arm.policy["name"]) == "speculative"
            vc = _virtual_clock(jobs, slots=slots, chunk=prefill_chunk,
                                substeps=(spec_window + 1) if is_spec else 1)
            ttfts = [float(x) for x in vc["ttft_ticks"] if x is not None]
            summary = _aggregate_arm(arm, tasks, samples, cfg, ttfts,
                                     "ticks")
            summary["makespan_ticks"] = vc["makespan_ticks"]
            summary["clock_events"] = len(vc["events"])
            arms_out[arm.name] = {"summary": summary, "samples": samples}
    finally:
        sched.stop()
    return {
        "schema_version": SCHEMA_VERSION,
        "mode": "replay",
        "model": model_cfg.name,
        "num_layers": model_cfg.num_layers,
        "config": {"n_samples": cfg.n_samples, "ks": list(cfg.ks),
                   "temperature": cfg.temperature, "top_p": cfg.top_p,
                   "seed": cfg.seed, "slots": slots,
                   "prefill_chunk": prefill_chunk,
                   "spec_window": spec_window},
        "tasks": [t.task_id for t in tasks],
        "arms": arms_out,
    }


# ---------------------------------------------------------------------------
# Live HTTP driver
# ---------------------------------------------------------------------------
def _post_stream(url: str, payload: dict, timeout_s: float) -> dict:
    """POST a streaming generate; return token lines + final record +
    wall-clock TTFT (first token line) and total latency.

    503 (scheduler queue full / draining) is backpressure, not failure —
    the client retries with backoff until the request deadline, like any
    load generator. TTFT is measured from the *first* attempt: the queue
    wait a saturated server imposes is real latency.
    """
    body = json.dumps(payload).encode()
    req = urllib.request.Request(
        f"{url}/generate", data=body,
        headers={"Content-Type": "application/json"})
    t0 = time.monotonic()
    ttft = None
    final = None
    n_lines = 0
    backoff = 0.05
    while True:
        try:
            resp = urllib.request.urlopen(req, timeout=timeout_s)
            break
        except urllib.error.HTTPError as e:
            if e.code != 503 or time.monotonic() - t0 > timeout_s:
                raise
            e.close()
            time.sleep(backoff)
            backoff = min(backoff * 2, 1.0)
    with resp:
        for raw in resp:
            line = raw.decode().strip()
            if not line:
                continue
            obj = json.loads(line)
            n_lines += 1
            if "token" in obj:
                if ttft is None:
                    ttft = time.monotonic() - t0
            else:
                final = obj
    if final is None:
        raise RuntimeError("stream ended without a final metrics record")
    return {"final": final, "ttft_s": ttft,
            "latency_s": time.monotonic() - t0, "token_lines": n_lines - 1}


def _drain_trace(url: str, timeout_s: float = 30.0) -> dict:
    """``GET /trace`` → {req_id: lifecycle-end args} for the energy join
    (the ``req/*`` async spans carry energy_j / prefill_energy_j /
    finish_reason on their closing event)."""
    with urllib.request.urlopen(f"{url}/trace", timeout=timeout_s) as resp:
        trace = json.loads(resp.read())
    by_req: dict = {}
    for ev in trace.get("traceEvents", []):
        if (ev.get("ph") == "e" and str(ev.get("name", "")).startswith("req/")
                and "energy_j" in ev.get("args", {})):
            by_req[ev["id"]] = ev["args"]
    return by_req


def run_http(url: str, tasks, arms, cfg: EvalRunConfig) -> dict:
    """Drive a running server under Poisson load, one arm at a time."""
    url = url.rstrip("/")
    tasks = tuple(tasks)
    arms = tuple(arms)
    arms_out = {}
    for arm in arms:
        flat = list(_flat_samples(tasks, cfg))
        offsets = poisson_times(len(flat), cfg.rate_hz,
                                seed=cfg.seed ^ 0x5EED)
        results: list = [None] * len(flat)
        errors: list = [None] * len(flat)

        def worker(j, ti, task, si, at, start, arm=arm):
            delay = start + at - time.monotonic()
            if delay > 0:
                time.sleep(delay)
            greedy = cfg.temperature <= 0.0
            par = {"max_new_tokens": task.max_new_tokens,
                   "stop": list(task.stop_sequences),
                   "temperature": max(cfg.temperature, 0.0),
                   "top_p": cfg.top_p if not greedy else 1.0,
                   "seed": cfg.sample_seed(ti, si),
                   "policy": dict(arm.policy),
                   "stream": True}
            try:
                results[j] = _post_stream(
                    url, {"inputs": task.prompt, "parameters": par},
                    cfg.request_timeout_s)
            except Exception as e:  # noqa: BLE001
                errors[j] = repr(e)

        start = time.monotonic()
        threads = [threading.Thread(target=worker,
                                    args=(j, ti, task, si, offsets[j],
                                          start),
                                    daemon=True)
                   for j, ti, task, si in flat]
        for th in threads:
            th.start()
        for th in threads:
            th.join(cfg.request_timeout_s + 30.0)
        span_args = _drain_trace(url)
        samples = []
        ttfts = []
        joined = 0
        for (j, ti, task, si) in flat:
            if results[j] is None:
                samples.append({
                    "task_id": task.task_id, "sample": si,
                    "status": "error", "tokens": 0, "energy_j": 0.0,
                    "prefill_energy_j": 0.0, "mean_exit_layer": 0.0,
                    "finish_reason": "transport_error",
                    "error": errors[j]})
                continue
            r = results[j]
            fin = r["final"]
            check = check_completion(task, fin.get("generated_text") or "",
                                     timeout_s=cfg.check_timeout_s)
            el = fin.get("exit_layers") or [0]
            rec = {
                "task_id": task.task_id, "sample": si,
                "status": check.status,
                "tokens": fin.get("tokens", r["token_lines"]),
                "energy_j": fin.get("decode_energy_j", fin["energy_j"]),
                "prefill_energy_j": fin.get("prefill_energy_j", 0.0),
                "mean_exit_layer": float(np.mean(el)),
                "finish_reason": fin.get("finish_reason", "unknown"),
                "ttft_s": r["ttft_s"],
                "latency_s": r["latency_s"],
                "replica_id": fin.get("replica_id"),
            }
            span = span_args.get(fin.get("request_id"))
            if span is not None:
                joined += 1
                rec["span_energy_j"] = span.get("energy_j")
                rec["span_prefill_energy_j"] = span.get("prefill_energy_j")
            samples.append(rec)
            if r["ttft_s"] is not None:
                ttfts.append(r["ttft_s"])
        summary = _aggregate_arm(arm, tasks, samples, cfg, ttfts, "s")
        summary["span_join_frac"] = joined / max(len(flat), 1)
        summary["transport_errors"] = sum(e is not None for e in errors)
        arms_out[arm.name] = {"summary": summary, "samples": samples}
    return {
        "schema_version": SCHEMA_VERSION,
        "mode": "http",
        "url": url,
        "config": {"n_samples": cfg.n_samples, "ks": list(cfg.ks),
                   "temperature": cfg.temperature, "top_p": cfg.top_p,
                   "seed": cfg.seed, "rate_hz": cfg.rate_hz},
        "tasks": [t.task_id for t in tasks],
        "arms": arms_out,
    }
