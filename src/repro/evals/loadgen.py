"""Seeded arrival-time generation for the HTTP eval driver.

Kept separate from ``benchmarks.serving_load.make_workload`` on purpose:
that generator interleaves arrival-gap and prompt draws from one RNG
stream, and several CI gates (e.g. the fleet energy-vs-rr trace) are
functions of that exact stream. The eval harness draws its own.
"""
from __future__ import annotations

import numpy as np


def poisson_times(n: int, rate_hz: float, seed: int = 0) -> np.ndarray:
    """``n`` Poisson arrival offsets (seconds from t=0, sorted).

    ``rate_hz <= 0`` degenerates to everything arriving at t=0 (a burst).
    """
    if n < 0:
        raise ValueError(f"n must be >= 0, got {n}")
    if rate_hz <= 0:
        return np.zeros(n, dtype=np.float64)
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / rate_hz, size=n)
    return np.cumsum(gaps) - gaps[0] if n else np.zeros(0)
