"""Frontier assembly + BENCH_eval.json emission.

The frontier is the paper's claim in one table: per exit-policy arm, the
pass rate (pass@1 / pass@k) against mean J/token and TTFT p95, sorted by
energy — "cheaper at the same accuracy" reads directly off adjacent rows.
"""
from __future__ import annotations

import hashlib
import json

SCHEMA_VERSION = 1


def frontier(run_report: dict) -> list:
    """Rows of (arm, pass@k..., j_per_token, ttft_p95), sorted cheapest
    first. ``run_report`` is a ``run_http`` / ``run_replay`` payload."""
    unit = "s" if run_report.get("mode") == "http" else "ticks"
    rows = []
    for name, arm in run_report["arms"].items():
        s = arm["summary"]
        row = {"arm": name,
               "j_per_token": s["j_per_token"],
               "mean_exit_layer": s["mean_exit_layer"],
               "tokens": s["tokens"],
               f"ttft_p95_{unit}": s[f"ttft_p95_{unit}"]}
        for k, v in s["pass_at"].items():
            row[f"pass@{k}"] = v
        rows.append(row)
    rows.sort(key=lambda r: (r["j_per_token"], r["arm"]))
    return rows


def payload_bytes(run_report: dict) -> bytes:
    """Canonical byte encoding of a run payload (the replay determinism
    gate compares these across two invocations)."""
    return json.dumps(run_report, sort_keys=True,
                      separators=(",", ":")).encode()


def payload_digest(run_report: dict) -> str:
    return hashlib.sha256(payload_bytes(run_report)).hexdigest()


def write_bench(path, http_report=None, replay_report=None) -> dict:
    """Assemble and write BENCH_eval.json. Either report may be absent
    (e.g. a replay-only CI smoke); present ones get a frontier."""
    if http_report is None and replay_report is None:
        raise ValueError("need at least one of http_report/replay_report")
    bench: dict = {"bench": "code_eval", "schema_version": SCHEMA_VERSION}
    if http_report is not None:
        bench["http"] = http_report
        bench["frontier"] = frontier(http_report)
    if replay_report is not None:
        bench["replay"] = replay_report
        bench["replay_frontier"] = frontier(replay_report)
        bench["replay_digest"] = payload_digest(replay_report)
    with open(path, "w", encoding="utf-8") as f:
        json.dump(bench, f, indent=1, sort_keys=True)
        f.write("\n")
    return bench
