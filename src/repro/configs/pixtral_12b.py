"""pixtral-12b [vlm] — 40L d_model=5120 32H (GQA kv=8) d_ff=14336 vocab=131072.

[hf:mistralai/Pixtral-12B-2409] pixtral-ViT + mistral-nemo decoder. The ViT
vision encoder + projector is a STUB: ``input_specs()`` provides precomputed
patch embeddings; we implement the language decoder.
"""
from repro.config import ModelConfig, uniform_pattern


def full() -> ModelConfig:
    return ModelConfig(
        name="pixtral-12b", arch_type="vlm",
        num_layers=40, d_model=5120, num_heads=32, num_kv_heads=8,
        head_dim=128, d_ff=14336, vocab_size=131072,
        block_pattern=uniform_pattern(40),
        rope_theta=1_000_000.0,
        frontend="vision", frontend_tokens=1024,
        source="hf:mistralai/Pixtral-12B-2409",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="pixtral-smoke", arch_type="vlm",
        num_layers=2, d_model=128, num_heads=4, num_kv_heads=2,
        head_dim=32, d_ff=256, vocab_size=512,
        block_pattern=uniform_pattern(2),
        frontend="vision", frontend_tokens=16,
        source="hf:mistralai/Pixtral-12B-2409",
    )
