"""llama32-3b — the paper's primary model (Llama 3.2 3B, 28 layers).

[GREEN-CODE §III-C, Table II] 28L d_model=3072 24H (GQA kv=8) d_ff=8192.
"""
from repro.config import ModelConfig, uniform_pattern


def full() -> ModelConfig:
    return ModelConfig(
        name="llama32-3b", arch_type="dense",
        num_layers=28, d_model=3072, num_heads=24, num_kv_heads=8,
        d_ff=8192, vocab_size=128256,
        block_pattern=uniform_pattern(28),
        rope_theta=500000.0, tie_embeddings=True,
        source="GREEN-CODE Table II / hf:meta-llama/Llama-3.2-3B",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="llama32-smoke", arch_type="dense",
        num_layers=2, d_model=128, num_heads=4, num_kv_heads=2,
        d_ff=256, vocab_size=512,
        block_pattern=uniform_pattern(2),
        tie_embeddings=True,
        source="GREEN-CODE Table II",
    )


def paper_mini(num_layers: int = 12, d_model: int = 256,
               vocab_size: int = 2048) -> ModelConfig:
    """Reduced same-family model used for the CPU paper-reproduction runs
    (fine-tune + RL agent + threshold sweeps). Enough layers for the paper's
    exit-point schedule to be non-trivial."""
    return ModelConfig(
        name=f"llama32-mini-{num_layers}L{d_model}", arch_type="dense",
        num_layers=num_layers, d_model=d_model,
        num_heads=max(4, d_model // 64), num_kv_heads=max(2, d_model // 128),
        d_ff=d_model * 4, vocab_size=vocab_size,
        block_pattern=uniform_pattern(num_layers),
        tie_embeddings=True,
        source="GREEN-CODE reduced-family variant",
    )
