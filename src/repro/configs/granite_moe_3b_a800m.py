"""granite-moe-3b-a800m [moe] — 32L d_model=1536 24H (GQA kv=8) MoE 40e top-8.

[hf:ibm-granite/granite-3.0-1b-a400m-base] (granite-3.0 MoE family)
"""
from repro.config import (FFN_MOE, MIXER_GQA, ModelConfig, MoEConfig,
                          uniform_pattern)


def full() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-3b-a800m", arch_type="moe",
        num_layers=32, d_model=1536, num_heads=24, num_kv_heads=8,
        d_ff=512, vocab_size=49155,
        block_pattern=uniform_pattern(32, MIXER_GQA, FFN_MOE),
        moe=MoEConfig(num_experts=40, num_experts_per_tok=8, d_ff_expert=512),
        tie_embeddings=True,
        source="hf:ibm-granite/granite-3.0-1b-a400m-base",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-smoke", arch_type="moe",
        num_layers=2, d_model=128, num_heads=4, num_kv_heads=2,
        d_ff=64, vocab_size=512,
        block_pattern=uniform_pattern(2, MIXER_GQA, FFN_MOE),
        moe=MoEConfig(num_experts=4, num_experts_per_tok=2, d_ff_expert=64),
        tie_embeddings=True,
        source="hf:ibm-granite/granite-3.0-1b-a400m-base",
    )
