"""gemma2-9b [dense] — 42L d_model=3584 16H (GQA kv=8) head_dim=256 d_ff=14336.

[arXiv:2408.00118] local(4096)/global alternating attention, logit softcaps.
"""
from repro.config import (FFN_DENSE, LayerSpec, MIXER_GQA, MIXER_GQA_LOCAL,
                          ModelConfig, alternating_pattern)

_ALT = (LayerSpec(MIXER_GQA_LOCAL, FFN_DENSE), LayerSpec(MIXER_GQA, FFN_DENSE))


def full() -> ModelConfig:
    return ModelConfig(
        name="gemma2-9b", arch_type="dense",
        num_layers=42, d_model=3584, num_heads=16, num_kv_heads=8,
        head_dim=256, d_ff=14336, vocab_size=256000,
        block_pattern=alternating_pattern(42, _ALT),
        sliding_window=4096,
        attn_logit_softcap=50.0, final_logit_softcap=30.0,
        activation="gelu", tie_embeddings=True,
        source="arXiv:2408.00118",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="gemma2-smoke", arch_type="dense",
        num_layers=2, d_model=128, num_heads=4, num_kv_heads=2,
        head_dim=32, d_ff=256, vocab_size=512,
        block_pattern=alternating_pattern(2, _ALT),
        sliding_window=64,
        attn_logit_softcap=50.0, final_logit_softcap=30.0,
        activation="gelu", tie_embeddings=True,
        source="arXiv:2408.00118",
    )
