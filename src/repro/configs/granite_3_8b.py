"""granite-3-8b [dense] — 40L d_model=4096 32H (GQA kv=8) d_ff=12800.

[hf:ibm-granite/granite-3.0-2b-base] (granite-3.0 dense family)
"""
from repro.config import ModelConfig, uniform_pattern


def full() -> ModelConfig:
    return ModelConfig(
        name="granite-3-8b", arch_type="dense",
        num_layers=40, d_model=4096, num_heads=32, num_kv_heads=8,
        d_ff=12800, vocab_size=49155,
        block_pattern=uniform_pattern(40),
        source="hf:ibm-granite/granite-3.0-2b-base",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="granite-3-smoke", arch_type="dense",
        num_layers=2, d_model=128, num_heads=4, num_kv_heads=2,
        d_ff=256, vocab_size=512,
        block_pattern=uniform_pattern(2),
        source="hf:ibm-granite/granite-3.0-2b-base",
    )
