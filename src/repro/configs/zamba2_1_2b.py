"""zamba2-1.2b [hybrid] — 38L d_model=2048, Mamba2 backbone + shared attn blocks.

[arXiv:2411.15242] Zamba2: one shared-weight attention(+MLP) block invoked
periodically over a Mamba2 backbone. We invoke the shared block every 6th
layer (6 invocations over 38 layers); per-invocation LoRA deltas of the
original are omitted (DESIGN.md §7).
"""
from repro.config import (FFN_DENSE, FFN_NONE, LayerSpec, MIXER_MAMBA,
                          MIXER_SHARED_GQA, ModelConfig, SSMConfig)


def _pattern(n_layers: int, period: int):
    specs = []
    for i in range(n_layers):
        if (i + 1) % period == 0:
            specs.append(LayerSpec(MIXER_SHARED_GQA, FFN_DENSE))
        else:
            specs.append(LayerSpec(MIXER_MAMBA, FFN_NONE))
    return tuple(specs)


def full() -> ModelConfig:
    return ModelConfig(
        name="zamba2-1.2b", arch_type="hybrid",
        num_layers=38, d_model=2048, num_heads=32, num_kv_heads=32,
        d_ff=8192, vocab_size=32000,
        block_pattern=_pattern(38, 6),
        ssm=SSMConfig(state_dim=64, expand=2, head_dim=64),
        tie_embeddings=True,
        source="arXiv:2411.15242",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="zamba2-smoke", arch_type="hybrid",
        num_layers=2, d_model=128, num_heads=4, num_kv_heads=4,
        d_ff=256, vocab_size=512,
        block_pattern=_pattern(2, 2),
        ssm=SSMConfig(state_dim=16, expand=2, head_dim=32, chunk_size=32),
        tie_embeddings=True,
        source="arXiv:2411.15242",
    )
