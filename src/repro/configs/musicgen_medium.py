"""musicgen-medium [audio] — 48L d_model=1536 24H (kv=24) d_ff=6144 vocab=2048.

[arXiv:2306.05284] decoder-only over EnCodec tokens. The EnCodec conv
frontend is a STUB: ``input_specs()`` provides precomputed frame embeddings
(the allowed carve-out); we implement the decoder backbone.
"""
from repro.config import ModelConfig, uniform_pattern


def full() -> ModelConfig:
    return ModelConfig(
        name="musicgen-medium", arch_type="audio",
        num_layers=48, d_model=1536, num_heads=24, num_kv_heads=24,
        d_ff=6144, vocab_size=2048,
        block_pattern=uniform_pattern(48),
        activation="gelu", mlp_gated=False, norm="layernorm", use_bias=True,
        frontend="audio", frontend_tokens=256,
        source="arXiv:2306.05284",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="musicgen-smoke", arch_type="audio",
        num_layers=2, d_model=128, num_heads=4, num_kv_heads=4,
        d_ff=256, vocab_size=256,
        block_pattern=uniform_pattern(2),
        activation="gelu", mlp_gated=False, norm="layernorm", use_bias=True,
        frontend="audio", frontend_tokens=16,
        source="arXiv:2306.05284",
    )
