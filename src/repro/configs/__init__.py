"""Architecture registry: ``get_config("<arch-id>", variant="full"|"smoke")``.

Ten assigned architectures (public-literature pool) plus the paper's own two
models (llama32_3b, opt_2_7b). Each module defines ``full()`` and ``smoke()``;
smoke variants are reduced same-family configs (2 layers, d_model<=512,
<=4 experts) runnable on CPU.
"""
from __future__ import annotations

import importlib

from repro.config import ModelConfig

ARCH_IDS = [
    "granite-moe-3b-a800m",
    "zamba2-1.2b",
    "granite-3-8b",
    "command-r-35b",
    "mamba2-1.3b",
    "qwen2-moe-a2.7b",
    "gemma2-9b",
    "musicgen-medium",
    "minicpm3-4b",
    "pixtral-12b",
    # paper's own models
    "llama32-3b",
    "opt-2.7b",
]

# ids assigned from the pool (excludes the paper's own two)
ASSIGNED_ARCH_IDS = ARCH_IDS[:10]

_MODULES = {a: "repro.configs." + a.replace("-", "_").replace(".", "_")
            for a in ARCH_IDS}


def get_config(arch_id: str, variant: str = "full") -> ModelConfig:
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_MODULES)}")
    mod = importlib.import_module(_MODULES[arch_id])
    if variant == "full":
        return mod.full()
    if variant == "smoke":
        return mod.smoke()
    raise ValueError(f"variant must be full|smoke, got {variant!r}")


def list_archs():
    return list(ARCH_IDS)
