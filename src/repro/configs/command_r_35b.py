"""command-r-35b [dense] — 40L d_model=8192 64H (GQA kv=8) d_ff=22528 vocab=256000.

[hf:CohereForAI/c4ai-command-r-v01] GQA, no-bias.
"""
from repro.config import ModelConfig, uniform_pattern


def full() -> ModelConfig:
    return ModelConfig(
        name="command-r-35b", arch_type="dense",
        num_layers=40, d_model=8192, num_heads=64, num_kv_heads=8,
        d_ff=22528, vocab_size=256000,
        block_pattern=uniform_pattern(40),
        use_bias=False, tie_embeddings=True,
        source="hf:CohereForAI/c4ai-command-r-v01",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="command-r-smoke", arch_type="dense",
        num_layers=2, d_model=256, num_heads=8, num_kv_heads=2,
        d_ff=512, vocab_size=1024,
        block_pattern=uniform_pattern(2),
        use_bias=False, tie_embeddings=True,
        source="hf:CohereForAI/c4ai-command-r-v01",
    )
