"""minicpm3-4b [dense/MLA] — 62L d_model=2560 40H d_ff=6400 vocab=73448, MLA.

[hf:openbmb/MiniCPM3-4B] multi-head latent attention (DeepSeek-V2 style).
"""
from repro.config import (FFN_DENSE, MIXER_MLA, MLAConfig, ModelConfig,
                          uniform_pattern)


def full() -> ModelConfig:
    return ModelConfig(
        name="minicpm3-4b", arch_type="dense",
        num_layers=62, d_model=2560, num_heads=40, num_kv_heads=40,
        head_dim=96,  # qk_nope+qk_rope (64+32)
        d_ff=6400, vocab_size=73448,
        block_pattern=uniform_pattern(62, MIXER_MLA, FFN_DENSE),
        mla=MLAConfig(q_lora_rank=768, kv_lora_rank=256,
                      qk_nope_head_dim=64, qk_rope_head_dim=32, v_head_dim=64),
        tie_embeddings=True,
        source="hf:openbmb/MiniCPM3-4B",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="minicpm3-smoke", arch_type="dense",
        num_layers=2, d_model=128, num_heads=4, num_kv_heads=4,
        head_dim=48,
        d_ff=256, vocab_size=512,
        block_pattern=uniform_pattern(2, MIXER_MLA, FFN_DENSE),
        mla=MLAConfig(q_lora_rank=64, kv_lora_rank=32,
                      qk_nope_head_dim=32, qk_rope_head_dim=16, v_head_dim=32),
        tie_embeddings=True,
        source="hf:openbmb/MiniCPM3-4B",
    )
