"""mamba2-1.3b [ssm] — 48L d_model=2048, attn-free, ssm_state=128 (SSD).

[arXiv:2405.21060] Mamba-2 / state-space duality.
"""
from repro.config import (FFN_NONE, MIXER_MAMBA, ModelConfig, SSMConfig,
                          uniform_pattern)


def full() -> ModelConfig:
    return ModelConfig(
        name="mamba2-1.3b", arch_type="ssm",
        num_layers=48, d_model=2048, num_heads=0, num_kv_heads=0,
        d_ff=0, vocab_size=50280,
        block_pattern=uniform_pattern(48, MIXER_MAMBA, FFN_NONE),
        ssm=SSMConfig(state_dim=128, expand=2, head_dim=64),
        positional="none",
        tie_embeddings=True,
        source="arXiv:2405.21060",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="mamba2-smoke", arch_type="ssm",
        num_layers=2, d_model=128, num_heads=0, num_kv_heads=0,
        d_ff=0, vocab_size=512,
        block_pattern=uniform_pattern(2, MIXER_MAMBA, FFN_NONE),
        ssm=SSMConfig(state_dim=16, expand=2, head_dim=32, chunk_size=32),
        positional="none",
        tie_embeddings=True,
        source="arXiv:2405.21060",
    )
