"""qwen2-moe-a2.7b [moe] — 24L d_model=2048 16H (GQA kv=16), 60 routed top-4 + 4 shared.

[hf:Qwen/Qwen1.5-MoE-A2.7B]
"""
from repro.config import (FFN_MOE, MIXER_GQA, ModelConfig, MoEConfig,
                          uniform_pattern)


def full() -> ModelConfig:
    return ModelConfig(
        name="qwen2-moe-a2.7b", arch_type="moe",
        num_layers=24, d_model=2048, num_heads=16, num_kv_heads=16,
        d_ff=1408, vocab_size=151936,
        block_pattern=uniform_pattern(24, MIXER_GQA, FFN_MOE),
        moe=MoEConfig(num_experts=60, num_experts_per_tok=4,
                      d_ff_expert=1408, num_shared_experts=4),
        use_bias=True,  # qwen uses qkv bias; applied to attention projections
        source="hf:Qwen/Qwen1.5-MoE-A2.7B",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="qwen2-moe-smoke", arch_type="moe",
        num_layers=2, d_model=128, num_heads=4, num_kv_heads=4,
        d_ff=64, vocab_size=512,
        block_pattern=uniform_pattern(2, MIXER_GQA, FFN_MOE),
        moe=MoEConfig(num_experts=4, num_experts_per_tok=2,
                      d_ff_expert=64, num_shared_experts=1),
        use_bias=True,
        source="hf:Qwen/Qwen1.5-MoE-A2.7B",
    )
