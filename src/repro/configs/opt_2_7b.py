"""opt-2.7b — the paper's second model (OPT 2.7B, 32 layers).

[GREEN-CODE §III-C, Table II] 32L d_model=2560 32H d_ff=10240, pre-LN
layernorm, ReLU FFN, learned positions, biases.
"""
from repro.config import ModelConfig, uniform_pattern


def full() -> ModelConfig:
    return ModelConfig(
        name="opt-2.7b", arch_type="dense",
        num_layers=32, d_model=2560, num_heads=32, num_kv_heads=32,
        d_ff=10240, vocab_size=50272,
        block_pattern=uniform_pattern(32),
        positional="learned", norm="layernorm", activation="relu",
        mlp_gated=False, use_bias=True, max_position=2048,
        tie_embeddings=True,
        source="GREEN-CODE Table II / hf:facebook/opt-2.7b",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="opt-smoke", arch_type="dense",
        num_layers=2, d_model=128, num_heads=4, num_kv_heads=4,
        d_ff=256, vocab_size=512,
        block_pattern=uniform_pattern(2),
        positional="learned", norm="layernorm", activation="relu",
        mlp_gated=False, use_bias=True, max_position=2048,
        tie_embeddings=True,
        source="GREEN-CODE Table II",
    )


def paper_mini(num_layers: int = 12, d_model: int = 256,
               vocab_size: int = 2048) -> ModelConfig:
    """Reduced same-family OPT variant for CPU paper-reproduction runs."""
    return ModelConfig(
        name=f"opt-mini-{num_layers}L{d_model}", arch_type="dense",
        num_layers=num_layers, d_model=d_model,
        num_heads=max(4, d_model // 64), num_kv_heads=max(4, d_model // 64),
        d_ff=d_model * 4, vocab_size=vocab_size,
        block_pattern=uniform_pattern(num_layers),
        positional="learned", norm="layernorm", activation="relu",
        mlp_gated=False, use_bias=True, max_position=8192,
        tie_embeddings=True,
        source="GREEN-CODE reduced-family variant",
    )
