"""HTTP inference endpoint (paper §V: HF-Inference-API-compatible-ish).

Threaded stdlib server on top of the continuous-batching scheduler
(serving/scheduler.py). Concurrent requests share the decode loop: each
POST submits into the admission queue and its tokens are generated in the
same fixed-shape batch as everyone else's.

  POST /generate {"inputs": "<code>", "parameters": {"max_new_tokens": 15,
                  "policy": {"name": "policy", "threshold": 0.9},
                  "temperature": 0.7, "top_k": 40, "top_p": 0.95,
                  "stop": ["\n\n"], "seed": 1}}
  -> {"generated_text": ..., "exit_layers": [...], "energy_j": ...,
      "energy_saving_frac": ..., "finish_reason": "length|eos|stop|...",
      "truncated": false}   # true when the prompt tail-clipped to fit

  * payloads parse straight into ``repro.api.GenerationRequest`` /
    ``SamplingParams`` / ``PolicySpec`` — the same dataclasses the
    scheduler, engine and benchmarks consume. The seed-era flat
    ``"controller"``/``"threshold"`` parameters still work.
  * ``inputs`` may be a list of strings — one scheduler request each,
    served concurrently; the response carries ``results`` per input.
  * ``"stream": true`` (single input) switches to newline-delimited JSON:
    one ``{"token": ...}`` line per generated token, then a final metrics
    line — tokens go out while later ones are still decoding. A stop
    sequence retires the slot as soon as its token lands, so the stream
    ends there and the final line carries the stop-truncated text.
  * per-request policy/sampling select behaviour per *slot* inside the one
    compiled step; nothing is mutated on shared state and nothing
    recompiles across mixed traffic.
  * ``"policy": {"name": "speculative", "draft_idx": 0, "window": 4}``
    serves the request with self-speculative decoding (early-exit drafts
    verified full-depth — exact greedy output, GET /queue reports
    ``acceptance_rate`` and ``tokens_per_verify``).

  GET /queue   -> scheduler stats (queue depth, slot occupancy, fleet
                  J/token, throughput, latency percentiles, step_compiles)
  GET /metrics -> the same stats + tick-phase histograms as Prometheus
                  text exposition (scrape target)
  GET /trace   -> Chrome trace-event JSON of spans collected since the
                  last GET /trace (open in Perfetto / chrome://tracing)

  Unknown GET paths return 404. ``--no-trace`` disables span collection
  (the no-op tracer path); /metrics then serves stats gauges only.

  ``--replicas N --placement {rr,least_queue,energy}`` serves a
  data-parallel fleet (repro.serving.fleet): N independent scheduler
  replicas behind one placement router. GET /queue then adds a
  ``per_replica`` breakdown, /metrics labels series ``{replica="i"}``,
  and /trace merges the replicas into one log (replica = tid group).
  Shutdown is graceful either way: admissions stop (new POSTs get 503),
  in-flight requests — including open NDJSON streams — run to
  completion bounded by ``--drain-timeout``, then the decode loops stop.

  PYTHONPATH=src python -m repro.serving.server --port 8799   # mini demo
"""
from __future__ import annotations

import argparse
import json
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer

from repro.api import GenerationRequest, PolicySpec, SamplingParams
from repro.core import exit_policy
from repro.obs import (PROM_CONTENT_TYPE, Tracer, render_prometheus,
                       to_chrome_trace)
from repro.serving.fleet import PLACEMENTS, Router
from repro.serving.metrics import aggregate_metrics
from repro.serving.scheduler import Scheduler, SchedulerQueueFull


class _State:
    scheduler = None          # a Scheduler, or a fleet Router (duck-typed)
    tokenizer = None
    params = None
    cfg = None
    agent = None


class RequestError(ValueError):
    """Bad request payload (maps to HTTP 400)."""


def _parse_policy(par: dict):
    """PolicySpec from ``"policy": {"name", ...params}`` or the legacy flat
    ``"controller"``/``"threshold"``/``"exit_idx"`` parameters."""
    po = par.get("policy")
    if po is not None:
        if not isinstance(po, dict) or "name" not in po:
            raise RequestError('parameters.policy must be an object with a '
                               '"name"')
        params = {k: float(v) for k, v in po.items() if k != "name"}
        return PolicySpec(str(po["name"]), params)
    kind = par.get("controller")
    if kind is None and "threshold" not in par and "exit_idx" not in par:
        return None                            # scheduler default policy
    kind = str(kind) if kind is not None else _State.scheduler.default_kind
    accepted = exit_policy.get(kind).defaults  # unknown kind -> 400
    # seed-server compatibility: a flat threshold/exit_idx the policy does
    # not use is ignored, not rejected
    params = {k: float(par[k]) for k in ("threshold", "exit_idx")
              if k in par and k in accepted}
    return PolicySpec(kind, params)


def _parse_generate(payload: dict
                    ) -> tuple[list[GenerationRequest], bool, bool]:
    inputs = payload.get("inputs", "")
    many = isinstance(inputs, list)
    texts = [str(t) for t in inputs] if many else [str(inputs)]
    if not texts:
        raise RequestError("empty inputs")
    par = payload.get("parameters", {}) or {}
    try:
        policy = _parse_policy(par)
        sampling = SamplingParams(
            temperature=float(par.get("temperature", 0.0)),
            top_k=int(par.get("top_k", 0)),
            top_p=float(par.get("top_p", 1.0)),
            seed=int(par.get("seed", 0)))
        stop = par.get("stop", par.get("stop_sequences", ()))
        if isinstance(stop, str):
            stop = (stop,)
        requests = [GenerationRequest(
            prompt=t,
            max_new_tokens=int(par.get("max_new_tokens", 15)),
            policy=policy,
            sampling=sampling,
            stop_sequences=tuple(stop),
            request_class=str(par.get("request_class", "default")),
            energy_budget_j=(float(par["energy_budget_j"])
                             if "energy_budget_j" in par else None))
            for t in texts]
    except (TypeError, ValueError) as e:
        raise RequestError(str(e)) from e
    stream = bool(par.get("stream", payload.get("stream", False)))
    if stream and many:
        raise RequestError("streaming supports a single input only")
    return requests, many, stream


def _submit(req: GenerationRequest):
    try:
        return _State.scheduler.submit(req)
    except ValueError as e:          # empty prompt, unknown policy, ...
        raise RequestError(str(e)) from e


def _req_json(req) -> dict:
    res = req.to_result(_State.tokenizer)
    agg = aggregate_metrics([req.metrics])
    return {
        "generated_text": res.text,
        "exit_layers": res.exit_layers,
        "mean_layers": agg["mean_layers"],
        "energy_j": agg["energy_j"],
        "energy_saving_frac": agg["energy_saving_frac"],
        "finish_reason": res.finish_reason,
        "truncated": res.truncated,
        "latency_s": res.latency_s,
        "request_id": res.request_id,
        # per-request attribution for eval/bench clients: decode joules as
        # charged by the scheduler (exit-layer or draft+verify model),
        # prompt-ingestion joules, and submit→first-token latency
        "tokens": res.n_tokens,
        "decode_energy_j": res.energy_j,
        "prefill_energy_j": res.prefill_energy_j,
        "energy_per_token_j": res.energy_j / max(res.n_tokens, 1),
        "ttft_s": res.ttft_s,
        "replica_id": getattr(req, "replica_id", None),
    }


def _handle_generate(reqs: list[GenerationRequest], many: bool) -> dict:
    handles = [_submit(r) for r in reqs]
    for h in handles:
        h.result(timeout=300.0)
    if not many:
        return _req_json(handles[0])
    agg = aggregate_metrics([h.metrics for h in handles])
    return {"results": [_req_json(h) for h in handles],
            "mean_layers": agg["mean_layers"],
            "energy_j": agg["energy_j"],
            "energy_saving_frac": agg["energy_saving_frac"]}


class Handler(BaseHTTPRequestHandler):
    protocol_version = "HTTP/1.1"

    def log_message(self, *a):  # quiet
        pass

    def _send(self, code: int, obj: dict):
        body = json.dumps(obj).encode()
        self.send_response(code)
        self.send_header("Content-Type", "application/json")
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        self.wfile.write(body)

    def _send_stream(self, req: GenerationRequest):
        """Newline-delimited JSON: a line per token, then final metrics.

        Once the 200 headers are out, errors (client disconnect, scheduler
        shutdown) can only close the connection — a second status line
        would corrupt the already-started body.
        """
        handle = _submit(req)
        self.send_response(200)
        self.send_header("Content-Type", "application/x-ndjson")
        self.send_header("Connection", "close")
        self.close_connection = True
        self.end_headers()
        try:
            ids, emitted = [], ""
            for tok in handle.stream(timeout=300.0):
                # decode the whole prefix each time: byte-fallback tokens
                # (multi-byte UTF-8 split across tokens) only render once
                # their sequence completes — per-token decode would stream
                # U+FFFD replacement characters
                ids.append(tok)
                full = _State.tokenizer.decode(ids)
                # hold back trailing U+FFFD: an in-progress byte sequence
                # streams as its resolved character on a later line
                stable = full.rstrip("�")
                delta, emitted = stable[len(emitted):], stable
                line = {"token": tok, "text": delta}
                self.wfile.write((json.dumps(line) + "\n").encode())
                self.wfile.flush()
            handle.result(timeout=10.0)
            # on a stop hit the final line's generated_text is already the
            # stop-truncated text (_retire sets it before decoding stops)
            self.wfile.write((json.dumps(_req_json(handle)) + "\n").encode())
        except Exception:  # noqa: BLE001
            return

    def do_POST(self):
        if self.path.rstrip("/") not in ("/generate", ""):
            self._send(404, {"error": "unknown path"})
            return
        try:
            n = int(self.headers.get("Content-Length", 0))
            payload = json.loads(self.rfile.read(n) or b"{}")
            reqs, many, stream = _parse_generate(payload)
        except RequestError as e:
            self._send(400, {"error": str(e)})
            return
        except Exception as e:  # noqa: BLE001
            self._send(400, {"error": f"bad request: {e!r}"})
            return
        try:
            if stream:
                self._send_stream(reqs[0])
            else:
                self._send(200, _handle_generate(reqs, many))
        except RequestError as e:
            self._send(400, {"error": str(e)})
        except SchedulerQueueFull as e:
            self._send(503, {"error": str(e)})
        except Exception as e:  # noqa: BLE001
            self._send(500, {"error": repr(e)})

    def _send_text(self, code: int, text: str, content_type: str):
        body = text.encode()
        self.send_response(code)
        self.send_header("Content-Type", content_type)
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        self.wfile.write(body)

    def do_GET(self):
        path = self.path.split("?")[0].rstrip("/")
        sched = _State.scheduler
        fleet = isinstance(sched, Router)
        if path == "/queue":
            # fleet mode: stats() carries the aggregate plus a per-replica
            # breakdown (queue depth, active slots, power EMA, blocked
            # admissions — the router's placement inputs)
            self._send(200, sched.stats())
        elif path == "/metrics":
            if fleet:
                body = sched.prometheus()      # per-replica-labeled series
            else:
                tracer = sched.obs if sched.obs.enabled else None
                body = render_prometheus(sched.stats(), tracer)
            self._send_text(200, body, PROM_CONTENT_TYPE)
        elif path == "/trace":
            # drains the tracer(s): each GET returns the events collected
            # since the previous one (counters/histograms stay cumulative);
            # fleet mode merges replicas into one log, replica = tid group
            events = sched.drain_events() if fleet else sched.obs.drain()
            self._send(200, to_chrome_trace(events))
        elif path == "":
            if fleet:
                st = sched.stats()
                info = {"replicas": st["replicas"],
                        "placement": st["placement"],
                        "max_slots": st["fleet"]["max_slots"],
                        "tracing": sched.tracing}
            else:
                info = {"max_slots": sched.pool.max_slots,
                        "kv_layout": sched.kv_layout,
                        "tracing": sched.obs.enabled,
                        "controllers": sorted(sched.allowed_kinds)}
            self._send(200, {"status": "ok", "model": _State.cfg.name,
                             "num_layers": _State.cfg.num_layers,
                             "scheduler": info})
        else:
            self._send(404, {"error": "unknown path"})


def setup_mini(train_steps: int = 60, rl: bool = True, *,
               max_slots: int = 8, max_len: int = 320,
               power_budget_w: float = None, kv_layout: str = "paged",
               block_size: int = 16, num_blocks: int = None,
               spec_window: int = 4, prefill_chunk: int = 32,
               trace: bool = True, replicas: int = 1,
               placement: str = "energy"):
    """Build a mini model + agent and start the scheduler (CPU demo).

    Default KV layout is **paged**: admission is gated on free cache
    *blocks* (plus a slot), not just free slots, and repeated prompt
    prefixes share ref-counted blocks (GET /queue reports hit rates).
    The ``speculative`` policy is compiled in: POST
    ``{"policy": {"name": "speculative", "draft_idx": 0, "window": 4}}``
    decodes draft-then-verify (``spec_window`` caps the drafted window;
    GET /queue reports ``acceptance_rate`` / ``tokens_per_verify``)."""
    from repro.configs.llama32_3b import paper_mini
    from repro.data import CodeCompletionDataset
    from repro.training import train_model
    cfg = paper_mini(num_layers=12, d_model=192, vocab_size=2048)
    ds = CodeCompletionDataset(language="java", n_files=120, seq_len=256,
                               vocab_size=2048)
    params, _ = train_model(cfg, ds, kind="lite", steps=train_steps,
                            batch_size=4, lr=1e-3, log_every=0)
    agent = None
    if rl:
        from repro.rl import PPOConfig, train_agent
        agent, _, _ = train_agent(params, cfg, ds, n_episodes=16,
                                  gen_tokens=8,
                                  ppo=PPOConfig(total_steps=20_000),
                                  log_every=0)
    _State.cfg, _State.params, _State.agent = cfg, params, agent
    _State.tokenizer = ds.tokenizer
    kinds = ["none", "confidence", "entropy", "fixed", "speculative"]
    if agent is not None:
        kinds.append("policy")

    def make_scheduler(_rid: int = 0) -> Scheduler:
        return Scheduler(
            params, cfg, agent_params=agent,
            controller_kind="policy" if agent is not None else "none",
            allowed_kinds=kinds, tokenizer=ds.tokenizer,
            max_slots=max_slots, max_len=max_len,
            # arbitrary user text: chunked prefill compiles ONE prompt
            # shape for every length and interleaves prompt chunks with
            # decode ticks (prefill_chunk is the TTFT-vs-overhead dial;
            # the old prefill_buckets knob is a deprecation shim)
            prefill_chunk=prefill_chunk,
            power_budget_w=power_budget_w, kv_layout=kv_layout,
            block_size=block_size, num_blocks=num_blocks,
            spec_window=spec_window,
            tracer=Tracer(enabled=trace))

    if replicas > 1:
        # fleet mode: N independent replicas (own KV pool, decode thread
        # and power gate each) behind the placement-policy router
        _State.scheduler = Router(make_scheduler, n_replicas=replicas,
                                  placement=placement).start()
    else:
        _State.scheduler = make_scheduler().start()
    return cfg, ds


def shutdown(drain_timeout: float = 30.0) -> bool:
    """Graceful server shutdown: stop admissions (new POSTs get 503),
    let queued + in-flight requests run to completion — open NDJSON
    streams emit their remaining tokens and final metrics record — then
    stop the decode loop(s). Bounded by ``drain_timeout``; leftovers past
    the deadline are failed. Returns True on a clean (complete) drain."""
    sched = _State.scheduler
    if sched is None:
        return True
    return sched.drain(drain_timeout)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--port", type=int, default=8799)
    ap.add_argument("--train-steps", type=int, default=60)
    ap.add_argument("--no-rl", action="store_true")
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--max-len", type=int, default=320)
    ap.add_argument("--power-budget-w", type=float, default=None,
                    help="defer admission while modeled fleet power exceeds")
    ap.add_argument("--kv-layout", choices=("contiguous", "paged"),
                    default="paged")
    ap.add_argument("--block-size", type=int, default=16,
                    help="tokens per KV block (with --kv-layout paged)")
    ap.add_argument("--num-blocks", type=int, default=None,
                    help="KV pool block count (default: slots*max_len worth)")
    ap.add_argument("--spec-window", type=int, default=4,
                    help="speculative draft window (tokens drafted per "
                         "verify for 'speculative'-policy requests)")
    ap.add_argument("--prefill-chunk", type=int, default=32,
                    help="prompt tokens ingested per decode tick (one "
                         "compiled prefill shape; smaller = fairer "
                         "interleaving, larger = lower TTFT per prompt)")
    ap.add_argument("--no-trace", action="store_true",
                    help="disable tick-phase tracing (GET /trace returns "
                         "an empty trace; /metrics loses phase histograms)")
    ap.add_argument("--replicas", type=int, default=1,
                    help="data-parallel fleet: N independent scheduler "
                         "replicas (own KV pool + decode thread each) "
                         "behind one placement router")
    ap.add_argument("--placement", choices=PLACEMENTS, default="energy",
                    help="fleet request placement: round-robin, least "
                         "queue depth, or power-gate energy headroom with "
                         "prefix-cache affinity")
    ap.add_argument("--drain-timeout", type=float, default=30.0,
                    help="graceful-shutdown budget (seconds): stop "
                         "admissions, let in-flight requests finish, then "
                         "stop; leftovers past the deadline are failed")
    args = ap.parse_args()
    print("[server] preparing mini model ...")
    setup_mini(args.train_steps, rl=not args.no_rl, max_slots=args.slots,
               max_len=args.max_len, power_budget_w=args.power_budget_w,
               kv_layout=args.kv_layout, block_size=args.block_size,
               num_blocks=args.num_blocks, spec_window=args.spec_window,
               prefill_chunk=args.prefill_chunk, trace=not args.no_trace,
               replicas=args.replicas, placement=args.placement)
    srv = ThreadingHTTPServer(("127.0.0.1", args.port), Handler)
    mode = (f"{args.replicas} replicas, placement={args.placement}"
            if args.replicas > 1 else "single scheduler")
    print(f"[server] listening on :{args.port} ({mode}) — POST /generate, "
          f"GET /queue /metrics /trace")
    try:
        srv.serve_forever()
    finally:
        print("[server] draining ...")
        clean = shutdown(args.drain_timeout)
        print(f"[server] drain {'complete' if clean else 'timed out'}")


if __name__ == "__main__":
    main()
