"""HTTP inference endpoint (paper §V: HF-Inference-API-compatible-ish).

Minimal stdlib server exposing the early-exit engine:

  POST /generate {"inputs": "<code>", "parameters": {"max_new_tokens": 15,
                  "threshold": 0.9}}
  -> {"generated_text": ..., "exit_layers": [...], "energy_j": ...,
      "energy_saving_frac": ...}

The paper wires this into the HuggingFace VS Code extension; the JSON
contract here mirrors that usage (runtime-adjustable threshold = the
paper's resource/accuracy knob).

  PYTHONPATH=src python -m repro.serving.server --port 8799   # mini demo
"""
from __future__ import annotations

import argparse
import json
from http.server import BaseHTTPRequestHandler, HTTPServer

from repro.core.controller import make_controller
from repro.serving.engine import Engine
from repro.serving.metrics import aggregate_metrics


class _State:
    engine: Engine = None
    tokenizer = None
    params = None
    cfg = None
    agent = None


def _handle_generate(payload: dict) -> dict:
    text = payload.get("inputs", "")
    par = payload.get("parameters", {})
    max_new = int(par.get("max_new_tokens", 15))
    thr = float(par.get("threshold", 0.9))
    kind = par.get("controller", "policy" if _State.agent else "none")
    ctrl = make_controller(kind, params=_State.params, cfg=_State.cfg,
                           agent_params=_State.agent, threshold=thr)
    _State.engine.controller = ctrl
    ids = _State.tokenizer.encode(text)
    res = _State.engine.serve([ids], max_new=max_new)
    agg = aggregate_metrics(res.metrics)
    return {
        "generated_text": _State.tokenizer.decode(res.tokens[0]),
        "exit_layers": res.exit_layers[0],
        "mean_layers": agg["mean_layers"],
        "energy_j": agg["energy_j"],
        "energy_saving_frac": agg["energy_saving_frac"],
    }


class Handler(BaseHTTPRequestHandler):
    def log_message(self, *a):  # quiet
        pass

    def _send(self, code: int, obj: dict):
        body = json.dumps(obj).encode()
        self.send_response(code)
        self.send_header("Content-Type", "application/json")
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        self.wfile.write(body)

    def do_POST(self):
        if self.path.rstrip("/") not in ("/generate", ""):
            self._send(404, {"error": "unknown path"})
            return
        try:
            n = int(self.headers.get("Content-Length", 0))
            payload = json.loads(self.rfile.read(n) or b"{}")
            self._send(200, _handle_generate(payload))
        except Exception as e:  # noqa: BLE001
            self._send(500, {"error": repr(e)})

    def do_GET(self):
        self._send(200, {"status": "ok", "model": _State.cfg.name,
                         "num_layers": _State.cfg.num_layers})


def setup_mini(train_steps: int = 60, rl: bool = True):
    """Build a mini model + agent for the demo server (CPU)."""
    from repro.configs.llama32_3b import paper_mini
    from repro.data import CodeCompletionDataset
    from repro.training import train_model
    cfg = paper_mini(num_layers=12, d_model=192, vocab_size=2048)
    ds = CodeCompletionDataset(language="java", n_files=120, seq_len=256,
                               vocab_size=2048)
    params, _ = train_model(cfg, ds, kind="lite", steps=train_steps,
                            batch_size=4, lr=1e-3, log_every=0)
    agent = None
    if rl:
        from repro.rl import PPOConfig, train_agent
        agent, _, _ = train_agent(params, cfg, ds, n_episodes=16,
                                  gen_tokens=8,
                                  ppo=PPOConfig(total_steps=20_000),
                                  log_every=0)
    _State.cfg, _State.params, _State.agent = cfg, params, agent
    _State.tokenizer = ds.tokenizer
    _State.engine = Engine(params, cfg, None)
    return cfg, ds


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--port", type=int, default=8799)
    ap.add_argument("--train-steps", type=int, default=60)
    ap.add_argument("--no-rl", action="store_true")
    args = ap.parse_args()
    print("[server] preparing mini model ...")
    setup_mini(args.train_steps, rl=not args.no_rl)
    srv = HTTPServer(("127.0.0.1", args.port), Handler)
    print(f"[server] listening on :{args.port} — POST /generate")
    srv.serve_forever()


if __name__ == "__main__":
    main()
