from repro.api import (GenerationRequest, GenerationResult,  # noqa: F401
                       PolicySpec, SamplingParams)
from repro.serving.engine import Engine, ServeResult  # noqa: F401
from repro.serving.metrics import (RequestMetrics, aggregate_metrics,  # noqa
                                   latency_percentiles)
from repro.serving.kv_pool import (BlockAllocator, PagedKVPool,  # noqa: F401
                                   chain_hashes)
from repro.serving.fleet import (FleetRequest, Router,  # noqa: F401
                                 make_placement)
from repro.serving.scheduler import (KVSlotPool, Request,  # noqa: F401
                                     Scheduler, SchedulerQueueFull)
