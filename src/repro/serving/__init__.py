from repro.serving.engine import Engine, ServeResult  # noqa: F401
from repro.serving.metrics import RequestMetrics, aggregate_metrics  # noqa
