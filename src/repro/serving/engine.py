"""Batched serving engine with early-exit decode (paper §V deployment).

The engine mirrors the paper's endpoint: requests (token lists) are batched,
left-padded, prefetched through full-depth prefill, then decoded with the
exit policy. EOS stops a sequence (its later tokens are masked out of the
response and of the energy accounting).

Exit behaviour is data, not closures: pass ``policy=`` a name /
``PolicySpec`` / ``PolicyBatch`` (heterogeneous per-row policies in one
compiled step — used by the stacked threshold sweep in ``benchmarks/``);
legacy controller callables are still accepted. ``serve_requests`` consumes
:class:`repro.api.GenerationRequest` directly and returns
:class:`repro.api.GenerationResult` per request.

``make_serve_step`` exposes the jit-able one-token step used by the
multi-pod dry-run (launch/dryrun.py) — batch sharded over ``data``,
heads/experts over ``model``.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import (GenerationRequest, GenerationResult, SamplingParams,
                       find_stop, stack_policies)
from repro.config import ModelConfig
from repro.core import exit_policy
from repro.core.early_exit import generate
from repro.data.tokenizer import EOS, PAD
from repro.serving.metrics import RequestMetrics, request_metrics

Array = jax.Array


@dataclass
class ServeResult:
    tokens: list[list[int]]          # per request, truncated at EOS
    exit_layers: list[list[int]]
    metrics: list[RequestMetrics]


class Engine:
    def __init__(self, params, cfg: ModelConfig, controller=None, *,
                 max_new: int = 15, max_context: int = 512,
                 agent_params=None, tokenizer=None,
                 kv_layout: str = "contiguous", kv_block_size: int = 16,
                 use_kernel: bool = False, tracer=None):
        """``controller`` may be a legacy callable or anything
        ``exit_policy.as_exit_fn`` accepts (name / PolicySpec /
        PolicyBatch). ``agent_params`` feeds 'policy' specs,
        ``tokenizer`` enables text prompts and stop sequences.
        ``kv_layout="paged"`` decodes through block-paged KV caches
        (``kv_block_size`` tokens per block; ``use_kernel`` selects the
        Pallas paged-attention kernel) — same tokens, paged substrate.
        ``tracer`` (a :class:`repro.obs.Tracer`) records a ``serve`` span
        per batch with the device-wait / host split."""
        from repro.obs.trace import NULL_TRACER
        self.obs = tracer if tracer is not None else NULL_TRACER
        self.params = params
        self.cfg = cfg
        self.controller = controller
        self.agent_params = agent_params
        self.tokenizer = tokenizer
        self.max_new = max_new
        self.max_context = max_context
        if kv_layout not in ("contiguous", "paged"):
            raise ValueError(f"kv_layout must be 'contiguous' or 'paged', "
                             f"got {kv_layout!r}")
        if kv_layout == "paged":
            from repro.models.transformer import paged_unsupported
            reason = paged_unsupported(cfg)
            if reason is not None:
                raise ValueError(f"paged KV cache unsupported for "
                                 f"{cfg.name}: {reason}")
        self.kv_layout = kv_layout
        self.kv_block_size = kv_block_size
        self.use_kernel = use_kernel

    def _ctx(self) -> exit_policy.PolicyContext:
        return exit_policy.PolicyContext(params=self.params, cfg=self.cfg,
                                         agent_params=self.agent_params)

    @staticmethod
    def _speculative_params(ctrl):
        """Speculative kwargs when ``ctrl`` selects the speculative policy
        (a spec/name, or a PolicyBatch whose rows are all speculative —
        per-row draft_idx/window arrays), else None."""
        if ctrl is None or callable(ctrl):
            return None
        if isinstance(ctrl, exit_policy.PolicyBatch):
            if "speculative" not in ctrl.names:
                return None
            if set(ctrl.names) != {"speculative"}:
                raise ValueError(
                    "the one-shot engine cannot mix speculative with other "
                    "policies in one batch — serve_requests partitions "
                    "them, or use the Scheduler for true per-row mixing")
            return {"draft_idx": np.asarray(ctrl.params["draft_idx"],
                                            np.int64),
                    "window": np.asarray(ctrl.params["window"], np.int64),
                    "accept_threshold": np.asarray(
                        ctrl.params["accept_threshold"], np.float32)}
        spec = exit_policy.as_spec(ctrl)
        if spec.name != "speculative":
            return None
        p = spec.resolved()
        return {"draft_idx": int(p["draft_idx"]),
                "window": int(p["window"]),
                "accept_threshold": float(p["accept_threshold"])}

    def serve(self, requests: Sequence[Sequence[int]],
              max_new: Optional[int] = None,
              controller=None, policy=None,
              sampling: Optional[SamplingParams] = None,
              key: Optional[Array] = None, seeds=None,
              seed_offsets=None) -> ServeResult:
        """Serve one batch. ``controller``/``policy`` override the engine
        default for this call only — concurrent callers must use this
        instead of mutating ``self.controller`` (shared state)."""
        if controller is not None and policy is not None:
            raise ValueError("pass either controller= or policy=, not both")
        max_new = max_new or self.max_new
        ctrl = controller if controller is not None else (
            policy if policy is not None else self.controller)
        spec_like = self._speculative_params(ctrl)
        B = len(requests)
        ctx_len = min(self.max_context, max(len(r) for r in requests))
        ctx = np.full((B, ctx_len), PAD, np.int32)
        for i, r in enumerate(requests):
            r = list(r)[-ctx_len:]
            ctx[i, ctx_len - len(r):] = r
        kv_block_size = (self.kv_block_size if self.kv_layout == "paged"
                         else None)
        spec_energy = None
        with self.obs.span("serve", cat="tick", batch=B, max_new=max_new):
            if spec_like is not None:
                from repro.core.speculative import speculative_generate
                if seeds is None and key is not None:
                    # honor the caller's key: speculative draws are keyed
                    # by per-row seeds, so derive them from it
                    seeds = np.asarray(jax.random.randint(
                        key, (B,), 0, np.iinfo(np.int32).max))
                out = speculative_generate(
                    self.params, self.cfg, jnp.asarray(ctx), max_new,
                    sampling=sampling, seeds=seeds,
                    seed_offsets=seed_offsets,
                    kv_block_size=kv_block_size, use_kernel=self.use_kernel,
                    **spec_like)
                self.obs.count("dispatch")
                with self.obs.wait():
                    spec_energy = np.asarray(out["energy_j"])
            else:
                exit_fn = exit_policy.as_exit_fn(ctrl, self._ctx())
                out = generate(self.params, self.cfg, jnp.asarray(ctx),
                               max_new, exit_fn, max_len=ctx_len + max_new,
                               sampling=sampling, key=key, seeds=seeds,
                               seed_offsets=seed_offsets,
                               kv_block_size=kv_block_size,
                               use_kernel=self.use_kernel)
                self.obs.count("dispatch")
            with self.obs.wait():
                toks = np.asarray(out["tokens"])
                exits = np.asarray(out["exit_layers"])
        tokens, exit_layers, metrics = [], [], []
        for i in range(B):
            row = toks[i].tolist()
            n = row.index(EOS) if EOS in row else len(row)
            tokens.append(row[:n])
            el = exits[i, :max(n, 1)]
            exit_layers.append(el.tolist())
            m = request_metrics(self.cfg, el, ctx_len)
            if spec_energy is not None:
                # speculative rows: draft + verify accounting (pro-rated
                # to the kept tokens), not the exit-layer model — their
                # exit layers are all num_layers by construction
                m.energy_j = float(spec_energy[i]) * max(n, 1) / max_new
            metrics.append(m)
        return ServeResult(tokens, exit_layers, metrics)

    def serve_requests(self, requests: Sequence[GenerationRequest],
                       default_policy=None,
                       key: Optional[Array] = None
                       ) -> list[GenerationResult]:
        """Serve heterogeneous :class:`GenerationRequest`s in ONE batch.

        Per-row exit policies are stacked (``stack_policies``) and per-row
        sampling params become arrays, so requests with different policies,
        thresholds and temperatures share a single compiled step. The batch
        decodes to the largest ``max_new_tokens``; each result is truncated
        to its own budget, at EOS, and at its earliest stop sequence
        (string-level; finish_reason "stop" — tokens, exit layers and
        energy end at the token that completed the stop, matching the
        scheduler's retirement accounting). Sampled rows draw from
        (seed, own-position)-keyed streams, so their randomness never
        depends on neighbours or batch size; note the engine left-pads to
        the batch-max prompt length, so a longer co-batched prompt still
        changes a row's padded context (and thus its logits) — exact
        batch-invariant tokens need the scheduler's exact-length rows.
        Offline semantics: unlike the scheduler, a stop hit cannot retire
        the row early, so extra tokens are computed then discarded here.
        """
        reqs = list(requests)
        if not reqs:
            return []
        prompts = []
        for r in reqs:
            p = r.prompt
            if isinstance(p, str):
                if self.tokenizer is None:
                    raise ValueError("text prompts need an Engine "
                                     "tokenizer (pass tokenizer=)")
                p = self.tokenizer.encode(p)
            prompts.append(list(p))
        if any(r.stop_sequences for r in reqs) and self.tokenizer is None:
            raise ValueError("stop_sequences need an Engine tokenizer")
        # policy=None falls back to the engine default, same as serve()
        if default_policy is None:
            default_policy = self.controller
        if callable(default_policy):
            if any(r.policy is None for r in reqs):
                raise ValueError(
                    "the engine default is a legacy controller callable, "
                    "which cannot be stacked per-row — give each request "
                    "a policy or configure a PolicySpec default")
            default_policy = None
        # speculative rows decode in a different loop shape (draft-then-
        # verify): partition mixed batches and serve each group, keeping
        # the caller's order and request ids
        eff = [r.spec(exit_policy.as_spec(default_policy)) for r in reqs]
        spec_rows = {i for i, s in enumerate(eff) if s.name == "speculative"}
        if spec_rows and len(spec_rows) < len(reqs):
            a = [i for i in range(len(reqs)) if i in spec_rows]
            b = [i for i in range(len(reqs)) if i not in spec_rows]
            out: list = [None] * len(reqs)
            for group in (a, b):
                res = self.serve_requests([reqs[i] for i in group],
                                          default_policy, key=key)
                for j, i in enumerate(group):
                    res[j].request_id = i
                    out[i] = res[j]
            return out
        batch = stack_policies(eff)
        sampling = SamplingParams(
            temperature=np.asarray([r.sampling.temperature for r in reqs],
                                   np.float32),
            top_k=np.asarray([r.sampling.top_k for r in reqs], np.int32),
            top_p=np.asarray([r.sampling.top_p for r in reqs], np.float32))
        seeds = np.asarray([r.sampling.seed for r in reqs], np.int32)
        max_new = max(r.max_new_tokens for r in reqs)
        # draw streams are keyed by each row's *own* (unpadded) positions:
        # serve() left-pads to the batch max, so hand it the pad amounts
        ctx_len = min(self.max_context, max(len(p) for p in prompts))
        offsets = np.asarray([ctx_len - min(len(p), ctx_len)
                              for p in prompts], np.int32)
        res = self.serve(prompts, max_new=max_new, policy=batch,
                         sampling=sampling, key=key, seeds=seeds,
                         seed_offsets=offsets)
        # serve() padded every prompt to the batch context length (ctx_len
        # above) — account energy against the context the model attended to
        out = []
        for i, r in enumerate(reqs):
            toks = res.tokens[i][:r.max_new_tokens]
            exits = res.exit_layers[i][:max(len(toks), 1)]
            hit_eos = (len(res.tokens[i]) < max_new
                       and len(res.tokens[i]) < r.max_new_tokens)
            reason = "eos" if hit_eos else "length"
            text = None
            if self.tokenizer is not None:
                text = self.tokenizer.decode(toks)
                hit = find_stop(text, r.stop_sequences)
                if hit is not None:
                    # retire-at-stop accounting, like the scheduler: keep
                    # tokens only up to the one that completed the stop
                    k = next(kk for kk in range(1, len(toks) + 1)
                             if find_stop(self.tokenizer.decode(toks[:kk]),
                                          r.stop_sequences) is not None)
                    toks = toks[:k]
                    exits = exits[:max(k, 1)]
                    text = text[:hit[0]]
                    reason = "stop"
            metrics = request_metrics(self.cfg, np.asarray(exits, np.int32),
                                      ctx_len)
            if eff[i].name == "speculative":
                # keep the draft+verify energy serve() attached, pro-rated
                # to this request's own truncation
                metrics.energy_j = (res.metrics[i].energy_j
                                    * len(toks)
                                    / max(len(res.tokens[i]), 1))
            out.append(GenerationResult(
                tokens=toks, exit_layers=exits, finish_reason=reason,
                text=text, energy_j=metrics.energy_j, metrics=metrics,
                request_id=i,
                # serve() kept only the last max_context tokens — the same
                # silent tail clip the scheduler now surfaces
                truncated=len(prompts[i]) > ctx_len))
        return out


def make_serve_step(cfg: ModelConfig, controller=None):
    """One-token decode step closure for jit/pjit lowering.

    signature: step(params, tokens [B], caches, pos [B]) ->
               (next_tokens [B], new_caches, exit_layer [B])
    """
    from repro.core.early_exit import make_decode_fn

    fn = make_decode_fn(cfg, controller)
    dummy = jax.random.PRNGKey(0)

    def step(params, tokens, caches, pos):
        nxt, new_caches, exit_layer, _, _ = fn(params, tokens, caches, pos,
                                               dummy)
        return nxt, new_caches, exit_layer

    return step
