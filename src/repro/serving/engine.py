"""Batched serving engine with early-exit decode (paper §V deployment).

The engine mirrors the paper's endpoint: requests (token lists) are batched,
left-padded, prefetched through full-depth prefill, then decoded with the
exit controller. EOS stops a sequence (its later tokens are masked out of
the response and of the energy accounting).

``make_serve_step`` exposes the jit-able one-token step used by the
multi-pod dry-run (launch/dryrun.py) — batch sharded over ``data``,
heads/experts over ``model``.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig
from repro.core.early_exit import generate
from repro.data.tokenizer import EOS, PAD
from repro.serving.metrics import RequestMetrics, request_metrics

Array = jax.Array


@dataclass
class ServeResult:
    tokens: list[list[int]]          # per request, truncated at EOS
    exit_layers: list[list[int]]
    metrics: list[RequestMetrics]


class Engine:
    def __init__(self, params, cfg: ModelConfig, controller=None, *,
                 max_new: int = 15, max_context: int = 512):
        self.params = params
        self.cfg = cfg
        self.controller = controller
        self.max_new = max_new
        self.max_context = max_context

    def serve(self, requests: Sequence[Sequence[int]],
              max_new: Optional[int] = None,
              controller=None) -> ServeResult:
        """Serve one batch. ``controller`` overrides the engine default for
        this call only — concurrent callers must use this instead of mutating
        ``self.controller`` (shared state)."""
        max_new = max_new or self.max_new
        ctrl = controller if controller is not None else self.controller
        B = len(requests)
        ctx_len = min(self.max_context, max(len(r) for r in requests))
        ctx = np.full((B, ctx_len), PAD, np.int32)
        for i, r in enumerate(requests):
            r = list(r)[-ctx_len:]
            ctx[i, ctx_len - len(r):] = r
        out = generate(self.params, self.cfg, jnp.asarray(ctx), max_new,
                       ctrl, max_len=ctx_len + max_new)
        toks = np.asarray(out["tokens"])
        exits = np.asarray(out["exit_layers"])
        tokens, exit_layers, metrics = [], [], []
        for i in range(B):
            row = toks[i].tolist()
            n = row.index(EOS) if EOS in row else len(row)
            tokens.append(row[:n])
            el = exits[i, :max(n, 1)]
            exit_layers.append(el.tolist())
            metrics.append(request_metrics(self.cfg, el, ctx_len))
        return ServeResult(tokens, exit_layers, metrics)


def make_serve_step(cfg: ModelConfig, controller=None):
    """One-token decode step closure for jit/pjit lowering.

    signature: step(params, tokens [B], caches, pos [B]) ->
               (next_tokens [B], new_caches, exit_layer [B])
    """
    from repro.core.early_exit import make_decode_fn

    fn = make_decode_fn(cfg, controller)
    dummy = jax.random.PRNGKey(0)

    def step(params, tokens, caches, pos):
        nxt, new_caches, exit_layer, _ = fn(params, tokens, caches, pos,
                                            dummy)
        return nxt, new_caches, exit_layer

    return step
