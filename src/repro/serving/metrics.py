"""Serving metrics: per-request energy / latency / layers-skipped.

Energy and latency are modeled via core.energy (TPU target, CPU runtime —
DESIGN.md §2); layers-skipped and token counts are exact. Quality metrics
(exact-match / token-level F1 / a CodeBLEU-style syntax-weighted score) are
computed against references when provided.
"""
from __future__ import annotations

from collections import Counter
from dataclasses import dataclass, field

import numpy as np

from repro.config import ModelConfig
from repro.core import energy


@dataclass
class RequestMetrics:
    n_tokens: int
    mean_layers: float
    layers_skipped_frac: float
    energy_j: float
    energy_full_j: float
    modeled_latency_s: float
    exit_histogram: dict = field(default_factory=dict)


def request_metrics(cfg: ModelConfig, exit_layers: np.ndarray,
                    ctx_len: int) -> RequestMetrics:
    exit_layers = np.asarray(exit_layers).reshape(-1)
    e = energy.decode_token_energy(cfg, ctx_len, exit_layers)
    e_full = energy.full_token_energy(cfg, ctx_len)
    # modeled per-token latency: roofline time of the layers actually used
    costs = energy.stack_costs(cfg, ctx_len)
    cum_t = np.cumsum([energy._exec_time(c.flops, c.bytes) for c in costs])
    lat = cum_t[np.clip(exit_layers, 1, cfg.num_layers) - 1].sum()
    hist = Counter(int(x) for x in exit_layers)
    return RequestMetrics(
        n_tokens=int(exit_layers.size),
        mean_layers=float(exit_layers.mean()),
        layers_skipped_frac=float(1 - exit_layers.mean() / cfg.num_layers),
        energy_j=float(e.sum()),
        energy_full_j=float(e_full * exit_layers.size),
        modeled_latency_s=float(lat),
        exit_histogram=dict(sorted(hist.items())))


def aggregate_metrics(metrics: list[RequestMetrics]) -> dict:
    tot_e = sum(m.energy_j for m in metrics)
    tot_full = sum(m.energy_full_j for m in metrics)
    tot_tok = sum(m.n_tokens for m in metrics)
    return {
        "requests": len(metrics),
        "tokens": tot_tok,
        "mean_layers": float(np.mean([m.mean_layers for m in metrics])),
        "energy_j": tot_e,
        "energy_saving_frac": 1.0 - tot_e / max(tot_full, 1e-12),
        "modeled_latency_s": sum(m.modeled_latency_s for m in metrics),
    }


def latency_percentiles(latencies_s, pcts=(50, 95)) -> dict:
    """{"p50_s": ..., "p95_s": ...} over a list of request latencies
    (None entries — unfinished requests — are dropped)."""
    lats = np.asarray([x for x in latencies_s if x is not None], np.float64)
    if lats.size == 0:
        return {f"p{p}_s": None for p in pcts}
    return {f"p{p}_s": float(np.percentile(lats, p)) for p in pcts}


# ---------------------------------------------------------------------------
# quality metrics (paper §VI-A2: ROUGE-L-style, CodeBLEU-style)
# ---------------------------------------------------------------------------
def _lcs(a: list, b: list) -> int:
    if not a or not b:
        return 0
    dp = [0] * (len(b) + 1)
    for x in a:
        prev = 0
        for j, y in enumerate(b, 1):
            cur = dp[j]
            dp[j] = prev + 1 if x == y else max(dp[j], dp[j - 1])
            prev = cur
    return dp[-1]


def rouge_l(pred: list, ref: list) -> float:
    """Token-level ROUGE-L F1."""
    if not pred or not ref:
        return 0.0
    l = _lcs(pred, ref)
    p = l / len(pred)
    r = l / len(ref)
    return 0.0 if p + r == 0 else 2 * p * r / (p + r)


def ngram_bleu(pred: list, ref: list, n_max: int = 4) -> float:
    """Geometric-mean n-gram precision with brevity penalty (BLEU core)."""
    if not pred or not ref:
        return 0.0
    logs = []
    for n in range(1, n_max + 1):
        pn = Counter(tuple(pred[i:i + n]) for i in range(len(pred) - n + 1))
        rn = Counter(tuple(ref[i:i + n]) for i in range(len(ref) - n + 1))
        overlap = sum((pn & rn).values())
        total = max(sum(pn.values()), 1)
        logs.append(np.log(max(overlap, 0.5) / total))
    bp = min(1.0, np.exp(1 - len(ref) / max(len(pred), 1)))
    return float(bp * np.exp(np.mean(logs)))


_SYNTAX_TOKENS = {"def", "return", "if", "for", "while", "class", "public",
                  "private", "int", "void", "(", ")", "{", "}", ":", ";",
                  "=", "in", "range"}


def codebleu_like(pred: list[str], ref: list[str]) -> dict:
    """CodeBLEU-style composite: n-gram + syntax-token-weighted n-gram +
    dataflow proxy (identifier agreement). Sub-metrics reported like the
    paper's 'Syntax'/'Dataflow' columns."""
    bleu = ngram_bleu(pred, ref)
    syn_p = [t for t in pred if t in _SYNTAX_TOKENS]
    syn_r = [t for t in ref if t in _SYNTAX_TOKENS]
    syntax = rouge_l(syn_p, syn_r)
    ids_p = [t for t in pred if t not in _SYNTAX_TOKENS and t.strip()]
    ids_r = [t for t in ref if t not in _SYNTAX_TOKENS and t.strip()]
    dataflow = rouge_l(ids_p, ids_r)
    return {"codebleu": 0.5 * bleu + 0.25 * syntax + 0.25 * dataflow,
            "bleu": bleu, "syntax": syntax, "dataflow": dataflow}
