"""Paged KV-cache subsystem: block allocator, block tables, prefix cache.

The PR-1 ``KVSlotPool`` gives every resident request a contiguous
``[max_len, ...]`` cache row, so the pool holds ``max_slots x max_len``
tokens of KV storage whether requests use it or not — memory, not compute,
caps concurrency. This module replaces that with the vLLM-style substrate
the related energy-evaluation work assumes as baseline:

``BlockAllocator``
    Ref-counted physical blocks with O(1) alloc/free/double-free detection
    (a refcount array, never a membership scan) plus a *cached-free* LRU:
    blocks whose refcount hits zero but that still carry a prefix hash stay
    reusable until the allocator actually needs them back.

``PagedKVPool``
    Owns per-layer block planes ``[num_blocks, block_size, KH, hd]`` (built
    by ``models.transformer.init_paged_cache``; int8 planes carry f32 scale
    planes), a block table ``[max_slots, max_blocks_per_slot]`` int32, and
    the policy around them:

    * token-granularity growth — a slot holds exactly
      ``ceil(ctx_len / block_size)`` blocks; one more is bound only when
      decode reaches a block boundary;
    * prefix sharing — prompt blocks are chain-hashed
      (``hash(prev_hash, block_tokens)``); an admission that matches an
      existing chain increfs those blocks instead of allocating, including
      the partial tail block on an exact-prompt match;
    * copy-on-write — before a slot appends into a block with
      ``refcount > 1`` the block is duplicated (``copy_paged_block``) so
      sharers never observe the write;
    * reservation accounting — admission reserves the worst-case block
      count (``ceil((prompt + max_new)/block_size)`` + a possible COW
      copy) so mid-flight appends can never fail and no preemption logic
      is needed, while unused reservations return on retirement.

Block 0 is a pinned scratch block: free scheduler rows decode garbage and
their (masked, overwritten-at-will) K/V writes land there, never in a live
block.

MoE configs disable prefix *sharing* (expert-capacity routing couples
tokens at prefill, so a prefix's K/V is not suffix-independent); paging
itself still works. Mamba/MLA/sliding-window configs are rejected by
``models.transformer.paged_unsupported`` with a clear reason.
"""
from __future__ import annotations

import hashlib
from collections import OrderedDict
from functools import lru_cache, partial
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig
from repro.models.transformer import (copy_paged_block, init_paged_cache,
                                      paged_prefix_to_ring,
                                      paged_unsupported, write_paged_blocks,
                                      write_paged_ring)


def chain_hashes(tokens: Sequence[int], block_size: int) -> list[bytes]:
    """Per-block chain keys for a prompt.

    Key ``j`` commits to every token in blocks ``0..j`` — two prompts share
    block ``j`` iff they agree on all of its prefix. The final (possibly
    partial) block is keyed by its actual tokens, so only an exact-prompt
    match shares a mutable tail. Stable digests (blake2b), not ``hash()``:
    the map must not depend on PYTHONHASHSEED.
    """
    out: list[bytes] = []
    h = b"kv-prefix"
    for i in range(0, len(tokens), block_size):
        blk = np.asarray(tokens[i:i + block_size], np.int64).tobytes()
        h = hashlib.blake2b(h + blk, digest_size=16).digest()
        out.append(h)
    return out


@lru_cache(maxsize=1024)
def _chain_hashes_cached(tokens: tuple, block_size: int) -> list[bytes]:
    """A prompt's chain never changes, but the admission gate (and the
    backfill scan over the whole queue) re-asks for it every decode tick —
    memoize on the token tuple so blocked queues cost dict lookups, not
    O(queue x prompt) hashing per tick."""
    return chain_hashes(tokens, block_size)


class BlockAllocator:
    """Ref-counted block ids with O(1) accounting and cached-free reuse."""

    def __init__(self, num_blocks: int, reserved: int = 0):
        if num_blocks <= reserved:
            raise ValueError(f"num_blocks={num_blocks} leaves no "
                             f"allocatable blocks (reserved={reserved})")
        self.num_blocks = num_blocks
        self.reserved = reserved
        self._refcount = np.zeros(num_blocks, np.int32)
        self._refcount[:reserved] = 1            # pinned forever
        self._free = list(range(num_blocks - 1, reserved - 1, -1))  # LIFO
        self._cached_free: OrderedDict[int, None] = OrderedDict()
        self._block_hash: dict[int, bytes] = {}
        self._hash_block: dict[bytes, int] = {}
        self._in_use = 0
        self.peak_in_use = 0

    # -- introspection ------------------------------------------------------
    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_cached_free(self) -> int:
        return len(self._cached_free)

    @property
    def n_available(self) -> int:
        return len(self._free) + len(self._cached_free)

    @property
    def n_in_use(self) -> int:
        return self._in_use

    @property
    def capacity(self) -> int:
        return self.num_blocks - self.reserved

    def refcount(self, block: int) -> int:
        return int(self._refcount[block])

    # -- alloc / ref --------------------------------------------------------
    def alloc(self) -> Optional[int]:
        """A fresh block (refcount 1), evicting the LRU cached-free block
        (and its hash entry) if the plain free list is empty."""
        if self._free:
            b = self._free.pop()
        elif self._cached_free:
            b, _ = self._cached_free.popitem(last=False)   # LRU eviction
            key = self._block_hash.pop(b, None)
            if key is not None:
                self._hash_block.pop(key, None)
        else:
            return None
        self._refcount[b] = 1
        self._in_use += 1
        self.peak_in_use = max(self.peak_in_use, self._in_use)
        return b

    def incref(self, block: int) -> None:
        if self._refcount[block] <= 0:
            raise ValueError(f"block {block} incref while free")
        self._refcount[block] += 1

    def decref(self, block: int) -> None:
        if not self.reserved <= block < self.num_blocks:
            raise ValueError(f"block {block} out of range")
        if self._refcount[block] <= 0:
            raise ValueError(f"block {block} double-freed")
        self._refcount[block] -= 1
        if self._refcount[block] == 0:
            self._in_use -= 1
            if block in self._block_hash:
                self._cached_free[block] = None    # reusable until evicted
            else:
                self._free.append(block)

    # -- prefix cache -------------------------------------------------------
    def share(self, key: bytes) -> Optional[int]:
        """Block registered under ``key``, incref'd (revived from the
        cached-free list if its last user retired). None on miss."""
        b = self._hash_block.get(key)
        if b is None:
            return None
        if self._refcount[b] == 0:
            del self._cached_free[b]
            self._refcount[b] = 1
            self._in_use += 1
            self.peak_in_use = max(self.peak_in_use, self._in_use)
        else:
            self._refcount[b] += 1
        return b

    def register(self, block: int, key: bytes) -> None:
        """Publish ``block`` under ``key`` (first registration wins)."""
        if key in self._hash_block or block in self._block_hash:
            return
        self._hash_block[key] = block
        self._block_hash[block] = key


class PagedKVPool:
    """Block-pooled per-layer KV caches + block table + prefix cache.

    Slot-facing surface mirrors ``KVSlotPool`` (``alloc``/``release``/
    ``n_free``/``n_used``/``caches``/``max_slots``/``max_len``) so the
    scheduler treats either pool uniformly; the paged-only surface is
    ``can_admit``/``write_prompt``/``prepare_append``/``device_tables``.
    """

    def __init__(self, cfg: ModelConfig, max_slots: int, max_len: int, *,
                 block_size: int = 16, num_blocks: Optional[int] = None,
                 dtype=jnp.float32, enable_prefix_cache: bool = True):
        reason = paged_unsupported(cfg)
        if reason is not None:
            raise ValueError(f"paged KV cache unsupported for {cfg.name}: "
                             f"{reason} — use kv_layout='contiguous'")
        if block_size < 1:
            raise ValueError("block_size must be >= 1")
        self.cfg = cfg
        self.max_slots = max_slots
        self.max_len = max_len
        self.block_size = block_size
        self.max_blocks_per_slot = -(-max_len // block_size)
        if num_blocks is None:
            # parity default: same token capacity the contiguous pool has
            num_blocks = 1 + max_slots * self.max_blocks_per_slot
        self.num_blocks = num_blocks
        self.caches = init_paged_cache(cfg, num_blocks, block_size, dtype)
        # cache shapes are fixed for the pool's lifetime: size them once
        # (stats() runs under the scheduler lock on every GET /queue)
        self.kv_bytes_total = sum(leaf.nbytes
                                  for leaf in jax.tree.leaves(self.caches))
        self.bytes_per_block = self.kv_bytes_total // num_blocks
        self.blocks = BlockAllocator(num_blocks, reserved=1)  # 0 = scratch
        self.tables = np.zeros((max_slots, self.max_blocks_per_slot),
                               np.int32)
        self._n_blocks = np.zeros(max_slots, np.int32)
        self._reserved = np.zeros(max_slots, np.int32)
        self._slot_used = np.zeros(max_slots, bool)
        self._free_slots = list(range(max_slots - 1, -1, -1))
        # MoE expert-capacity routing couples tokens at prefill: a prefix's
        # K/V then depends on the co-batched suffix, so sharing is unsound
        self.enable_prefix_cache = (enable_prefix_cache
                                    and cfg.moe is None)
        self._writer = jax.jit(partial(write_paged_blocks, cfg),
                               static_argnames=("n_write", "n_skip"),
                               donate_argnums=0)
        # chunked-prefill splice/gather: traced bounds, so every admission
        # shares ONE compile each (the legacy _writer's static slice
        # compiles per (n_write, n_skip) pair)
        self._ring_writer = jax.jit(partial(write_paged_ring, cfg),
                                    donate_argnums=0)
        self._prefix_gather = jax.jit(partial(paged_prefix_to_ring, cfg),
                                      donate_argnums=1)
        self._copier = jax.jit(partial(copy_paged_block, cfg),
                               donate_argnums=0)
        self.prefix_queries = 0
        self.prefix_hits = 0
        self.prefix_hit_tokens = 0
        self.cow_copies = 0

    # -- geometry / accounting ---------------------------------------------
    @property
    def kv_bytes_in_use(self) -> int:
        return self.blocks.n_in_use * self.bytes_per_block

    @property
    def peak_kv_bytes(self) -> int:
        return self.blocks.peak_in_use * self.bytes_per_block

    def blocks_for(self, n_tokens: int) -> int:
        return -(-n_tokens // self.block_size)

    @property
    def n_free(self) -> int:          # free *slots* (KVSlotPool parity)
        return len(self._free_slots)

    @property
    def n_used(self) -> int:
        return self.max_slots - len(self._free_slots)

    @property
    def reserved_blocks(self) -> int:
        return int(self._reserved.sum())

    def can_admit(self, prompt: Sequence[int], max_new: int) -> bool:
        """Free slot + worst-case block reservation available.

        The worst case is discounted by prefix-chain blocks that are
        currently *referenced* (an admission shares them instead of
        allocating; cached-free matches are not discounted — reviving one
        consumes availability just like an allocation)."""
        if not self._free_slots:
            return False
        need = (self.need_blocks(len(prompt), max_new)
                - self._shared_active_blocks(prompt))
        return (self.blocks.n_available - self.reserved_blocks) >= need

    def need_blocks(self, prompt_len: int, max_new: int) -> int:
        """Worst-case blocks a request may allocate over its lifetime.
        A prompt with a partial tail block may share it on an exact-prompt
        match and then needs one COW copy on its first append; full-block
        prompts never append into shared blocks."""
        cow = 1 if (self.enable_prefix_cache
                    and prompt_len % self.block_size) else 0
        return self.blocks_for(prompt_len + max_new) + cow

    def _shared_active_blocks(self, prompt: Sequence[int]) -> int:
        if not self.enable_prefix_cache:
            return 0
        n = 0
        for key in _chain_hashes_cached(tuple(prompt), self.block_size):
            b = self.blocks._hash_block.get(key)
            if b is None:
                break
            if self.blocks.refcount(b) > 0:
                n += 1
        return n

    # -- slots (KVSlotPool-compatible surface) ------------------------------
    def alloc(self) -> Optional[int]:
        if not self._free_slots:
            return None
        slot = self._free_slots.pop()
        self._slot_used[slot] = True
        return slot

    def release(self, slot: int) -> None:
        if not 0 <= slot < self.max_slots:
            raise ValueError(f"slot {slot} out of range")
        if not self._slot_used[slot]:                 # O(1), not a scan
            raise ValueError(f"slot {slot} double-freed")
        for j in range(int(self._n_blocks[slot])):
            self.blocks.decref(int(self.tables[slot, j]))
        self.tables[slot, :] = 0
        self._n_blocks[slot] = 0
        self._reserved[slot] = 0
        self._slot_used[slot] = False
        self._free_slots.append(slot)

    # -- admission ----------------------------------------------------------
    def bind_prompt(self, prompt: Sequence[int]
                    ) -> tuple[list[int], int, bool]:
        """Allocate/share the prompt's blocks WITHOUT touching a slot's
        table or the device planes. Chunked admission binds early — so the
        blocks are owned while the prompt streams in chunk-by-chunk over
        several decode ticks — and installs on the last chunk
        (:meth:`install_prompt`); an aborted admission hands the blocks
        back via :meth:`abort_bind`.

        Returns ``(block_ids, n_shared, tail_shared)``: the bound chain,
        how many leading blocks were prefix-cache shares, and whether the
        final (partial) block is a shared mutable tail (exact-prompt
        match — must never be rewritten, its sharer may have appended).
        """
        S = len(prompt)
        n0 = self.blocks_for(S)
        keys = (_chain_hashes_cached(tuple(prompt), self.block_size)
                if self.enable_prefix_cache else [])
        ids: list[int] = []
        n_shared = 0
        for key in keys:
            b = self.blocks.share(key)
            if b is None:
                break
            ids.append(b)
            n_shared += 1
        tail_partial = S % self.block_size != 0
        tail_shared = n_shared == n0 and tail_partial
        for j in range(n_shared, n0):
            b = self.blocks.alloc()
            assert b is not None, "admission outran its block reservation"
            ids.append(b)
            if keys:
                self.blocks.register(b, keys[j])
        return ids, n_shared, tail_shared

    def abort_bind(self, ids: Sequence[int]) -> None:
        """Return bound-but-never-installed blocks (admission aborted
        mid-prefill — scheduler drain/crash)."""
        for b in ids:
            self.blocks.decref(int(b))

    def install_prompt(self, slot: int, prompt_len: int, ids: Sequence[int],
                       n_shared: int, tail_shared: bool, max_new: int
                       ) -> tuple[int, int]:
        """Install bound blocks into ``slot``'s table row and account the
        growth reservation + prefix stats. Returns the device-write bounds
        ``(n_skip, n_write)``: shared full blocks already hold
        byte-identical content and a shared mutable tail must never be
        rewritten, so only ring blocks in ``[n_skip, n_write)`` are
        spliced."""
        if not self._slot_used[slot]:
            raise ValueError(f"slot {slot} not allocated")
        S = prompt_len
        n0 = len(ids)
        self.tables[slot, :n0] = ids
        self.tables[slot, n0:] = 0
        self._n_blocks[slot] = n0
        # worst-case growth still ahead of this slot: future appends plus
        # one COW copy for ANY partial tail while the prefix cache is on —
        # a fresh partial tail gets registered, so a later exact-prompt
        # sharer can admit and this slot may then be the one that COWs;
        # charging only shared tails would let that COW steal a unit from
        # this slot's growth reservation (each slot COWs at most once:
        # after it, the tail is exclusive and all later blocks are fresh)
        tail_partial = S % self.block_size != 0
        cow_slack = int(bool(self.enable_prefix_cache) and tail_partial)
        self._reserved[slot] = (self.blocks_for(S + max_new) - n0
                                + cow_slack)
        if self.enable_prefix_cache:
            self.prefix_queries += 1
            if n_shared:
                self.prefix_hits += 1
                self.prefix_hit_tokens += min(n_shared * self.block_size, S)
        return n_shared - int(tail_shared), n0 - int(tail_shared)

    def write_prompt(self, slot: int, prompt: Sequence[int], req_caches,
                     max_new: int) -> int:
        """Bind the prompt's blocks to ``slot`` and splice the prefilled
        cache in; returns the number of prefix-cache-shared tokens.

        ``req_caches``: ring caches from
        ``prefill(..., max_len=blocks_for(len(prompt)) * block_size)``.
        The whole-prompt admission path (and the offline engine); chunked
        admission uses bind_prompt / install_prompt / write_ring instead.
        """
        if not self._slot_used[slot]:
            raise ValueError(f"slot {slot} not allocated")
        S = len(prompt)
        ids, n_shared, tail_shared = self.bind_prompt(prompt)
        n_skip, n_write = self.install_prompt(slot, S, ids, n_shared,
                                              tail_shared, max_new)
        if n_write > n_skip:
            ids_arr = jnp.asarray(ids, jnp.int32)
            self.caches = self._writer(self.caches, req_caches, ids_arr,
                                       n_write=n_write, n_skip=n_skip)
        return min(n_shared * self.block_size, S)

    def write_ring(self, slot: int, ring_caches, n_skip: int,
                   n_write: int) -> None:
        """Splice a finalized prefill ring (length
        ``max_blocks_per_slot * block_size``, batch 1) into this slot's
        installed blocks — one compiled scatter for every admission
        (bounds are traced)."""
        ids = np.zeros(self.max_blocks_per_slot, np.int32)
        nb = int(self._n_blocks[slot])
        ids[:nb] = self.tables[slot, :nb]
        self.caches = self._ring_writer(self.caches, ring_caches,
                                        jnp.asarray(ids),
                                        jnp.asarray(n_skip, jnp.int32),
                                        jnp.asarray(n_write, jnp.int32))

    def gather_prefix(self, ring_caches, ids: Sequence[int],
                      n_tokens: int):
        """Prefix-shared block content -> prefill ring positions
        ``[0, n_tokens)`` (dequantized for int8 pools), so chunked prefill
        can skip already-shared leading chunks and still attend the
        prefix. Returns the updated ring."""
        padded = np.zeros(self.max_blocks_per_slot, np.int32)
        padded[:len(ids)] = ids
        return self._prefix_gather(self.caches, ring_caches,
                                   jnp.asarray(padded),
                                   jnp.asarray(n_tokens, jnp.int32))

    # -- decode-time growth --------------------------------------------------
    def prepare_append(self, slot: int, pos: int) -> None:
        """Guarantee the block holding ``pos`` exists and is exclusively
        owned before this tick's K/V write (alloc at a block boundary,
        copy-on-write when shared)."""
        j = pos // self.block_size
        nb = int(self._n_blocks[slot])
        if j >= self.max_blocks_per_slot:
            raise ValueError(f"slot {slot} position {pos} exceeds "
                             f"max_len {self.max_len}")
        if j == nb:
            b = self.blocks.alloc()
            assert b is not None, "append outran its block reservation"
            self.tables[slot, j] = b
            self._n_blocks[slot] = nb + 1
            self._reserved[slot] = max(int(self._reserved[slot]) - 1, 0)
            return
        b = int(self.tables[slot, j])
        if self.blocks.refcount(b) > 1:               # copy-on-write
            nb_new = self.blocks.alloc()
            assert nb_new is not None, "COW outran its block reservation"
            self.caches = self._copier(self.caches,
                                       jnp.asarray(b, jnp.int32),
                                       jnp.asarray(nb_new, jnp.int32))
            self.tables[slot, j] = nb_new
            self.blocks.decref(b)
            self._reserved[slot] = max(int(self._reserved[slot]) - 1, 0)
            self.cow_copies += 1

    def rollback_append(self, slot: int, keep_tokens: int) -> None:
        """Unbind blocks past ``keep_tokens`` valid positions (speculative
        rollback of rejected draft appends).

        The freed blocks return to the allocator and their units go back
        into the slot's growth reservation — a rejected draft leaves the
        slot exactly as reserved as before it drafted. K/V inside the kept
        tail block needs no scrub: paged attention masks strictly by the
        row's current position. A block the draft copy-on-wrote stays
        (the slot now owns its tail exclusively; each slot COWs at most
        once, so no reservation drifts).
        """
        if not self._slot_used[slot]:
            raise ValueError(f"slot {slot} not allocated")
        n_keep = max(self.blocks_for(keep_tokens), 1)
        nb = int(self._n_blocks[slot])
        if n_keep >= nb:
            return
        for j in range(n_keep, nb):
            self.blocks.decref(int(self.tables[slot, j]))
            self.tables[slot, j] = 0
            self._reserved[slot] += 1
        self._n_blocks[slot] = n_keep

    def device_tables(self) -> jax.Array:
        return jnp.asarray(self.tables)

    def reset_stats(self) -> None:
        """Zero the cumulative counters and high-water marks (used after
        benchmark warmup so reported stats cover only the timed run)."""
        self.prefix_queries = 0
        self.prefix_hits = 0
        self.prefix_hit_tokens = 0
        self.cow_copies = 0
        self.blocks.peak_in_use = self.blocks.n_in_use

    # -- introspection -------------------------------------------------------
    def stats(self) -> dict:
        return {
            "block_size": self.block_size,
            "num_blocks": self.num_blocks,
            "blocks_in_use": self.blocks.n_in_use,
            "blocks_available": self.blocks.n_available,
            "blocks_reserved": self.reserved_blocks,
            "kv_bytes_total": self.kv_bytes_total,
            "kv_bytes_in_use": self.kv_bytes_in_use,
            "peak_kv_bytes": self.peak_kv_bytes,
            "prefix_queries": self.prefix_queries,
            "prefix_hits": self.prefix_hits,
            "prefix_hit_tokens": self.prefix_hit_tokens,
            "prefix_hit_rate": (self.prefix_hits
                                / max(self.prefix_queries, 1)),
            "cow_copies": self.cow_copies,
        }
