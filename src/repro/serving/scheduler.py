"""Continuous-batching serving scheduler (paper §V at load).

The seed ``Engine`` re-prefills a fixed batch per call and decodes a fixed
number of steps for everyone — request N+1 waits for the whole batch even if
half the slots finished at token 3. This module is the serving layer the
ROADMAP's "heavy traffic" target needs: requests join and leave the running
batch at *token* granularity.

Pieces
------
``KVSlotPool``
    Owns persistent per-layer decode caches of shape ``[max_slots, W, ...]``
    (built once by ``models.transformer.init_cache``) plus slot alloc/free
    bookkeeping. Slot writes go through ``write_cache_slots`` under one jit
    with donation, so admission never reallocates the pool.

``PagedKVPool`` (``kv_layout="paged"``, serving/kv_pool.py)
    The vLLM-style substrate: per-layer block planes
    ``[num_blocks, block_size, ...]`` addressed through a per-slot block
    table. Requests bind ``ceil(ctx/block_size)`` blocks and grow at block
    granularity; prompt-prefix blocks are ref-count shared (hash chain,
    copy-on-write on divergence); admission is gated on free *blocks*, not
    just free slots. The decode step reads/writes through the table — the
    reference gather path is bit-identical to the contiguous layout, and
    ``use_kernel=True`` swaps in the Pallas paged-attention kernel.

``Scheduler``
    An admission queue + a single decode-loop thread. Each tick it (1)
    advances the in-flight admission by ONE prompt chunk (chunked prefill,
    below), and (2) runs ONE jitted fixed-shape decode step over all
    ``max_slots`` rows. Free rows decode garbage that is masked out of
    accounting and overwritten at the next admission; per-row attention
    masks (``kv_pos``) make every row's math independent of its
    neighbours, which is what makes a mid-flight join byte-identical to a
    solo run (tests/test_scheduler.py).

Chunked prefill (one compiled shape, decode-interleaved admission)
    Prompts are never prefilled whole: admission streams each prompt
    through ``models.transformer.prefill_chunk`` in fixed-size
    ``prefill_chunk``-token chunks against a private full-precision ring,
    one chunk per scheduler tick, while co-resident rows keep emitting
    tokens in the same ticks — admission never stops the decode world.
    Every prompt length shares ONE compiled chunk shape
    (``prefill_compiles`` counts it; the deleted ``prefill_buckets`` knob
    is a deprecation shim that warns and ignores), and because every
    chunk-step reduction runs at the fixed ring length, the result is
    bit-identical for ANY chunk split of the same prompt — tokens, exits
    and logprobs (tests/test_chunked_prefill.py). On the last chunk the
    ring is spliced into the pool (contiguous row / paged blocks; prefix-
    cache hits skip already-shared leading chunks) and the request joins
    the decode batch. Chunk FLOPs are charged through
    ``core.energy.prefill_chunk_energy`` into per-request
    ``prefill_energy_j`` and the fleet power EMA, so the power-gated
    admission sees prompt ingestion too. Configs whose prefill cannot
    chunk (frontend-conditioned models —
    ``transformer.chunked_prefill_unsupported`` names the reason) fall
    back to whole-prompt admission, counted in ``stats()["fallbacks"]``.

Policies and sampling as data
    Exit policies come from the first-class registry
    (:mod:`repro.core.exit_policy`): each resident request carries a policy
    id plus a stacked param pytree row, and ``select_apply`` runs the
    heterogeneous mix inside the one compiled step. Sampling knobs
    (temperature / top-k / top-p) are per-slot arrays consumed by
    ``pick_tokens``; a request's draw stream is keyed by its own seed +
    token position, so sampled output is independent of batch composition.
    New thresholds, policies or sampling mixes therefore never recompile —
    ``Scheduler.step_compiles`` counts decode-step compilations and stays
    at 1 across arbitrary traffic.

Early-exit awareness
    Per-slot exit-layer traces feed ``core.energy`` so the scheduler reports
    fleet J/token, enforces optional per-request energy budgets, retires on
    per-request ``stop_sequences`` (string-level, at detokenize time), and
    gates admission on a fleet power target (fewer layers used -> lower
    modeled power -> more admission).

Self-speculative decoding (``PolicySpec("speculative", ...)``)
    Rows with the speculative policy decode in draft-then-verify
    super-ticks (``_spec_tick``): ``spec_window`` ordinary compiled steps
    draft tokens at the row's ``draft_idx`` exit boundary (co-resident
    non-speculative rows decode real tokens in the same steps), then one
    full-depth ``verify_step`` over each row's window accepts or rejects
    them — greedy rows emit exactly the full model's tokens. Rejected
    positions roll back (ring ``pos`` rewound / paged block appends
    unbound; admission reserves the draft-overrun slack so drafting can
    never fail); ``stats()`` reports ``acceptance_rate`` and
    ``tokens_per_verify``, and ``core.energy.speculative_step_energy``
    charges draft-layer vs full-depth joules separately.
"""
from __future__ import annotations

import queue as _queue
import threading
import time
import warnings
from dataclasses import dataclass, field
from functools import partial
from typing import Any, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import (GenerationRequest, GenerationResult, SamplingParams,
                       find_stop)
from repro.config import MIXER_MAMBA, ModelConfig
from repro.core import energy, exit_policy
from repro.core.early_exit import pick_tokens, request_keys
from repro.core.exit_policy import PolicyContext, PolicySpec
from repro.core.speculative import (SPEC_POLICY, accept_drafts,
                                    draft_boundary_layer)
from repro.data.tokenizer import EOS, PAD
from repro.obs.trace import NULL_TRACER, Tracer
from repro.models.transformer import (_window_for, chunked_prefill_unsupported,
                                      commit_spec_cache, decode_step,
                                      finalize_prefill_ring, init_cache,
                                      init_prefill_ring, lm_logits, prefill,
                                      prefill_chunk, rewind_ring,
                                      select_cache_rows,
                                      spec_needs_cache_snapshot,
                                      speculative_unsupported, verify_step,
                                      write_cache_slots)
from repro.serving.engine import ServeResult
from repro.serving.kv_pool import PagedKVPool
from repro.serving.metrics import (RequestMetrics, latency_percentiles,
                                   request_metrics)


class SchedulerQueueFull(RuntimeError):
    """Raised by ``submit`` when the admission queue is at capacity."""


# ---------------------------------------------------------------------------
# KV slot pool
# ---------------------------------------------------------------------------
class KVSlotPool:
    """Persistent per-layer decode caches [max_slots, W, ...] + slot accounting.

    ``alloc``/``release`` manage rows; ``write`` splices a prefilled
    single-request cache (same ``max_len``) into a row. The buffers live for
    the lifetime of the pool — decode runs under one jitted closure with a
    fixed shape regardless of which requests occupy slots.
    """

    def __init__(self, cfg: ModelConfig, max_slots: int, max_len: int,
                 dtype=jnp.float32):
        self.cfg = cfg
        self.max_slots = max_slots
        self.max_len = max_len
        self.caches = init_cache(cfg, max_slots, max_len, dtype)
        # fixed for the pool's lifetime — sized once, read per stats() call
        self.kv_bytes_total = sum(leaf.nbytes
                                  for leaf in jax.tree.leaves(self.caches))
        self._free = list(range(max_slots - 1, -1, -1))   # LIFO: reuse warm rows
        self._used = np.zeros(max_slots, bool)  # O(1) double-free detection
        self._write = jax.jit(partial(write_cache_slots, cfg),
                              donate_argnums=0)

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_used(self) -> int:
        return self.max_slots - len(self._free)

    def alloc(self) -> Optional[int]:
        if not self._free:
            return None
        slot = self._free.pop()
        self._used[slot] = True
        return slot

    def release(self, slot: int) -> None:
        if not 0 <= slot < self.max_slots:
            raise ValueError(f"slot {slot} out of range")
        if not self._used[slot]:     # O(1), not an O(n) free-list scan
            raise ValueError(f"slot {slot} double-freed")
        self._used[slot] = False
        self._free.append(slot)

    def write(self, req_caches, slot: int) -> None:
        self.caches = self._write(self.caches, req_caches,
                                  jnp.asarray([slot], jnp.int32))


# ---------------------------------------------------------------------------
# Requests
# ---------------------------------------------------------------------------
@dataclass
class Request:
    """One in-flight generation request (also the caller's handle)."""
    req_id: int
    prompt: list[int]
    max_new: int
    spec: PolicySpec
    sampling: SamplingParams = field(default_factory=SamplingParams)
    stop_sequences: tuple[str, ...] = ()
    request_class: str = "default"
    energy_budget_j: Optional[float] = None
    submitted_at: float = field(default_factory=time.monotonic)

    truncated: bool = False              # prompt tail-clipped at submit
    replica_id: Optional[int] = None     # fleet: which replica serves it
    status: str = "queued"               # queued | running | done
    finish_reason: Optional[str] = None  # eos | length | stop | energy_budget
    tokens: list[int] = field(default_factory=list)
    exit_layers: list[int] = field(default_factory=list)
    logprobs: list[float] = field(default_factory=list, repr=False)
    text: Optional[str] = None           # decoded (stop-truncated) output
    energy_j: float = 0.0
    prefill_energy_j: float = 0.0        # modeled J of this prompt's chunks
    # speculative accounting (zero for non-speculative requests)
    spec_verifies: int = 0
    spec_drafted: int = 0
    spec_accepted: int = 0
    metrics: Optional[RequestMetrics] = None
    started_at: Optional[float] = None
    first_token_at: Optional[float] = None
    finished_at: Optional[float] = None

    _exits_all: list[int] = field(default_factory=list, repr=False)
    _done: threading.Event = field(default_factory=threading.Event,
                                   repr=False)
    _stream: _queue.Queue = field(default_factory=_queue.Queue, repr=False)
    # which tracer lifecycle span (req/<stage>) is currently open, so a
    # drain can close it no matter where the request was interrupted
    _obs_stage: Optional[str] = field(default=None, repr=False)

    @property
    def kind(self) -> str:
        return self.spec.name

    @property
    def ctx_len(self) -> int:
        return len(self.prompt)

    @property
    def latency_s(self) -> Optional[float]:
        if self.finished_at is None:
            return None
        return self.finished_at - self.submitted_at

    @property
    def ttft_s(self) -> Optional[float]:
        """Queue wait + prefill: submit → first emitted token."""
        if self.first_token_at is None:
            return None
        return self.first_token_at - self.submitted_at

    def result(self, timeout: Optional[float] = None) -> "Request":
        if not self._done.wait(timeout):
            raise TimeoutError(f"request {self.req_id} still {self.status}")
        if self.metrics is None:
            # dropped from the queue before admission (scheduler shutdown)
            raise RuntimeError(
                f"request {self.req_id} aborted: {self.finish_reason}")
        return self

    def stream(self, timeout: Optional[float] = None):
        """Yield tokens as they are generated; returns at end-of-sequence."""
        while True:
            tok = self._stream.get(timeout=timeout)
            if tok is None:
                return
            yield tok

    def to_result(self, tokenizer=None) -> GenerationResult:
        """Snapshot a finished request as the shared result dataclass."""
        text = self.text
        if text is None and tokenizer is not None:
            text = tokenizer.decode(self.tokens)
        return GenerationResult(
            tokens=list(self.tokens), exit_layers=list(self.exit_layers),
            finish_reason=self.finish_reason or "unknown", text=text,
            energy_j=self.energy_j, metrics=self.metrics,
            request_id=self.req_id, latency_s=self.latency_s,
            prefill_energy_j=self.prefill_energy_j, ttft_s=self.ttft_s,
            truncated=self.truncated,
            # speculative super-ticks emit verified tokens without picker
            # logprobs — surface the trace only when it is complete
            logprobs=(list(self.logprobs)
                      if len(self.logprobs) == len(self.tokens) else None))


@dataclass
class _PrefillJob:
    """One in-flight chunked admission: the prompt streams into a private
    full-precision ring, one ``prefill_chunk``-token step per scheduler
    tick, then splices into the pool on the last chunk."""
    req: Request
    slot: int
    ring: Any                       # per-request prefill ring (device)
    grid: np.ndarray                # prompt padded to the chunk grid
    next_pos: int                   # next chunk's start position
    plen: int                       # true prompt length
    ids: Optional[list] = None      # paged: blocks bound at job start
    n_shared: int = 0               # paged: leading prefix-cache shares
    tail_shared: bool = False       # paged: exact-prompt mutable tail


# ---------------------------------------------------------------------------
# Scheduler
# ---------------------------------------------------------------------------
class Scheduler:
    """Async request queue + continuous-batching early-exit decode loop."""

    def __init__(self, params, cfg: ModelConfig, *,
                 controller_kind: str = "none", agent_params=None,
                 threshold: float = 0.9, temperature: float = 1.0,
                 fixed_exit_idx: int = 0,
                 default_policy: Union[None, str, PolicySpec] = None,
                 default_sampling: Optional[SamplingParams] = None,
                 allowed_kinds: Optional[Sequence[str]] = None,
                 tokenizer=None,
                 max_slots: int = 8, max_len: int = 512, max_new: int = 15,
                 queue_depth: int = 64, max_wait_s: float = 2.0,
                 prefill_chunk: int = 32,
                 prefill_buckets: Optional[Sequence[int]] = None,
                 power_budget_w: Optional[float] = None,
                 class_energy_budgets_j: Optional[dict] = None,
                 eos_id: int = EOS, pad_id: int = PAD,
                 kv_layout: str = "contiguous", block_size: int = 16,
                 num_blocks: Optional[int] = None, use_kernel: bool = False,
                 enable_prefix_cache: bool = True,
                 spec_window: int = 4,
                 tracer: Optional[Tracer] = None,
                 dtype=jnp.float32):
        self.params = params
        # observability: every tick phase runs under a span; the default
        # NULL_TRACER is a shared no-op (no allocation, no clock read) so
        # an untraced scheduler pays nothing on the tick path
        self.obs = tracer if tracer is not None else NULL_TRACER
        self.cfg = cfg
        self.agent_params = agent_params
        self.tokenizer = tokenizer
        self.default_threshold = threshold
        self.default_max_new = max_new
        self.temperature = temperature           # RL-policy softmax temp
        self.fixed_exit_idx = fixed_exit_idx
        if default_policy is not None:
            self.default_spec = exit_policy.as_spec(default_policy)
        else:
            self.default_spec = self._legacy_spec(controller_kind, threshold)
        self.default_kind = self.default_spec.name
        self.default_sampling = default_sampling or SamplingParams()
        self.queue_depth = queue_depth
        self.max_wait_s = max_wait_s
        if prefill_chunk < 1:
            raise ValueError("prefill_chunk must be >= 1")
        self.prefill_chunk = int(prefill_chunk)
        # fallback accounting: every *_unsupported gate that fires on this
        # config records its reason here; the serving-time counter makes
        # slow-path admissions visible in stats() instead of silent
        self._fallback_reasons: dict[str, str] = {}
        self._fallbacks: dict[str, int] = {}
        self._warned_fallbacks: set[str] = set()
        chunk_reason = chunked_prefill_unsupported(cfg)
        self.chunked = chunk_reason is None
        if chunk_reason is not None:
            self._fallback_reasons["chunked_prefill"] = chunk_reason
            self._fallbacks["chunked_prefill"] = 0
        spec_reason = speculative_unsupported(cfg)
        if spec_reason is not None:
            self._fallback_reasons["speculative"] = spec_reason
            self._fallbacks["speculative"] = 0
        self.prefill_buckets = None
        if prefill_buckets is not None:
            if self.chunked:
                # the bucketing knob is moot here: chunked prefill serves
                # arbitrary prompt lengths with one compiled shape, so
                # there is nothing left to bucket — warn and ignore
                # (migration: docs/api.md)
                warnings.warn(
                    "prefill_buckets is deprecated and ignored: chunked "
                    "prefill compiles one shape for every prompt length "
                    "(tune prefill_chunk= instead)",
                    DeprecationWarning, stacklevel=2)
            else:
                # whole-prompt fallback configs (frontend-conditioned)
                # still compile per distinct prompt length — buckets
                # remain their only compile-count mitigation
                self.prefill_buckets = tuple(sorted(prefill_buckets))
        self.power_budget_w = power_budget_w
        self.class_energy_budgets_j = dict(class_energy_budgets_j or {})
        self.eos_id = eos_id
        self.pad_id = pad_id
        self.allowed_kinds = frozenset(allowed_kinds
                                       if allowed_kinds is not None
                                       else {"none", self.default_kind})
        # eager validation: unknown kinds and missing context (e.g. a
        # 'policy' scheduler without agent_params) fail here with a clear
        # message, not as a tracer error on the decode thread
        probe = PolicyContext(params=params, cfg=cfg,
                              agent_params=agent_params)
        for k in sorted(self.allowed_kinds):
            exit_policy.validate_context(exit_policy.get(k), probe)
        if self.default_kind not in self.allowed_kinds:
            raise ValueError(f"default policy {self.default_kind!r} not in "
                             f"allowed_kinds {sorted(self.allowed_kinds)}")
        if SPEC_POLICY in self.allowed_kinds:
            if spec_reason is not None:
                raise ValueError(f"speculative policy unavailable for "
                                 f"{cfg.name}: {spec_reason}")
            if spec_window < 1:
                raise ValueError("spec_window must be >= 1")
        self.spec_window = spec_window

        if kv_layout == "paged":
            self.pool = PagedKVPool(cfg, max_slots, max_len,
                                    block_size=block_size,
                                    num_blocks=num_blocks, dtype=dtype,
                                    enable_prefix_cache=enable_prefix_cache)
        elif kv_layout == "contiguous":
            self.pool = KVSlotPool(cfg, max_slots, max_len, dtype)
        else:
            raise ValueError(f"kv_layout must be 'contiguous' or 'paged', "
                             f"got {kv_layout!r}")
        self.kv_layout = kv_layout
        self.use_kernel = use_kernel
        S = max_slots
        self._slot_req: list[Optional[Request]] = [None] * S
        self._cur_tok = np.full(S, pad_id, np.int32)
        self._pos = np.zeros(S, np.int32)
        # per-slot policy + sampling state: runtime data, never trace-time
        self._ids = np.zeros(S, np.int32)            # exit-policy id ('none')
        self._pp = {f: np.full(S, exit_policy.field_default(f), np.float32)
                    for f in exit_policy.param_fields()}
        self._temp = np.zeros(S, np.float32)
        self._topk = np.zeros(S, np.int32)
        self._topp = np.ones(S, np.float32)
        self._seed = np.zeros(S, np.int32)

        self._step = jax.jit(self._make_step(), donate_argnums=2)
        self._prefill = jax.jit(self._prefill_fn,
                                static_argnames=("max_len",))
        self._verify = jax.jit(self._make_verify(), donate_argnums=2)
        self._rewind = jax.jit(partial(rewind_ring, cfg), donate_argnums=0)
        # speculative rollback for destructive cache writes (mamba state,
        # sliding-window evictions): snapshot before drafting, restore the
        # speculative rows before verify, commit per-row after acceptance.
        # Contiguous only — paged_unsupported keeps these configs off pages.
        self._spec_snapshot = (kv_layout == "contiguous"
                               and spec_needs_cache_snapshot(cfg))
        self._spec_collect = self._spec_snapshot and any(
            spec.mixer == MIXER_MAMBA for spec in cfg.block_pattern)
        self._verify_collect = jax.jit(self._make_verify(collect=True),
                                       donate_argnums=2)
        self._copy = jax.jit(lambda c: jax.tree.map(jnp.copy, c))
        self._restore = jax.jit(partial(select_cache_rows, cfg),
                                donate_argnums=0)
        self._commit = jax.jit(partial(commit_spec_cache, cfg),
                               donate_argnums=(0, 1))
        # chunked-prefill machinery: the prompt-ingestion ring is sized so
        # paged splices land on the block grid; every chunk runs the same
        # compiled [1, prefill_chunk] step (prefill_compiles pins this)
        if kv_layout == "paged":
            self._ring_len = (self.pool.max_blocks_per_slot
                              * self.pool.block_size)
        else:
            self._ring_len = max_len
        self._chunk = jax.jit(self._make_chunk(), donate_argnums=2)
        self._pick0 = jax.jit(self._make_pick0())
        if (cfg.kv_cache_dtype == "int8"
                or any(_window_for(cfg, s) for s in cfg.block_pattern)):
            # int8 rings quantize at splice time; sliding-window rings
            # gather the full-length ingestion ring down to the W-slot
            # decode ring. No donation: the f32 full-length ring cannot
            # back the int8/W-length output buffers.
            self._finalize = jax.jit(partial(finalize_prefill_ring, cfg))
        else:
            self._finalize = lambda ring, plen: ring  # rings splice as-is

        self._lock = threading.Lock()
        self._work = threading.Condition(self._lock)
        self._queue: list[Request] = []
        self._admitting: Optional[Request] = None
        self._prefill_job: Optional[_PrefillJob] = None
        self._seq = 0
        self._running = False
        self._stopped = False     # set once, by stop() or a loop crash
        self._draining = False    # begin_drain(): no new admissions
        self._thread: Optional[threading.Thread] = None

        # fleet accounting. The window counters below reset on
        # reset_peak_stats (so throughput/J-per-token cover only the
        # measured run); _lifetime accumulates every closed window and is
        # reported as stats()["lifetime"].
        self._t0 = time.monotonic()
        self._lifetime = {"completed_requests": 0, "fleet_tokens": 0,
                          "fleet_energy_j": 0.0,
                          "fleet_prefill_energy_j": 0.0, "uptime_s": 0.0}
        self._completed = 0
        self._fleet_tokens = 0
        self._fleet_energy_j = 0.0
        self._fleet_prefill_j = 0.0
        self._deferred_admissions = 0
        self._blocked_admissions = 0
        self._peak_active = 0
        self._spec_verifies = 0
        self._spec_drafted = 0
        self._spec_accepted = 0
        self._spec_emitted = 0
        self._prefill_interleaved = 0
        self._power_w_ema = 0.0
        self._power_ema_t = time.monotonic()
        self._exit_layer_ema = float(cfg.num_layers)
        self._latencies: list[float] = []
        self._ttfts: list[float] = []
        self._ecache: dict[int, np.ndarray] = {}

    def _legacy_spec(self, kind: str, threshold: Optional[float]
                     ) -> PolicySpec:
        """Map the seed (kind, threshold) scalar pair onto a PolicySpec."""
        pol = exit_policy.get(kind)          # unknown kind -> clear error
        params: dict[str, float] = {}
        if "threshold" in pol.defaults and threshold is not None:
            params["threshold"] = float(threshold)
        if "temperature" in pol.defaults:
            params["temperature"] = float(self.temperature)
        if "exit_idx" in pol.defaults:
            params["exit_idx"] = float(self.fixed_exit_idx)
        return PolicySpec(kind, params)

    # -- compiled closures --------------------------------------------------
    def _make_step(self):
        """The one fixed-shape decode step: per-slot exit policies selected
        from the stacked param pytree, per-slot sampling — all runtime
        arrays, so mixed traffic never recompiles. Paged layouts take the
        block table as one more runtime array (same single compile)."""
        cfg = self.cfg
        agent = self.agent_params
        paged = self.kv_layout == "paged"
        use_kernel = self.use_kernel
        policies = tuple(exit_policy.get(k)
                         for k in sorted(self.allowed_kinds))

        def step(params, tokens, caches, tables, pos, ids, pparams, temp,
                 top_k, top_p, seeds):
            ctx = PolicyContext(params=params, cfg=cfg, agent_params=agent)
            ctrl = exit_policy.select_apply(policies, ctx, ids, pparams)
            logits, new_caches, info = decode_step(
                params, cfg, tokens, caches, pos, ctrl,
                block_tables=tables if paged else None,
                use_kernel=use_kernel)
            keys = request_keys(seeds, pos)
            nxt, lp = pick_tokens(logits, keys, temp, top_k, top_p)
            # logits ride along for speculative draft scoring (rejection
            # sampling needs the draft distribution); plain ticks leave
            # them on device unfetched
            return (nxt.astype(jnp.int32), new_caches, info["exit_layer"],
                    lp, logits.astype(jnp.float32))

        return step

    def _make_verify(self, collect: bool = False):
        """The speculative verify step: one full-depth pass over every
        slot's [spec_window + 1] draft window. ``mask`` rows ride along
        with untouched caches (non-speculative residents, free slots).
        ``collect`` additionally returns per-step mamba state snapshots
        for the snapshot-commit rollback (contiguous snapshot configs)."""
        cfg = self.cfg
        paged = self.kv_layout == "paged"
        use_kernel = self.use_kernel

        def vstep(params, win, caches, tables, pos0, mask):
            return verify_step(params, cfg, win, caches, pos0,
                               write_mask=mask,
                               block_tables=tables if paged else None,
                               use_kernel=use_kernel,
                               collect_states=collect)

        return vstep

    def _make_chunk(self):
        """The one compiled prefill-chunk step: a fixed [1, prefill_chunk]
        token window against the fixed-length ingestion ring — every
        prompt length shares this single shape."""
        cfg = self.cfg

        def cstep(params, tokens, ring, pos0, n_valid):
            return prefill_chunk(params, cfg, tokens, ring, pos0, n_valid)

        return cstep

    def _make_pick0(self):
        """First-token picker for a freshly prefilled prompt: same
        (seed, position)-keyed draw the whole-prompt path used."""

        def pick0(logits, seeds, pos, temp, top_k, top_p):
            keys = request_keys(seeds, pos)
            t0, lp = pick_tokens(logits, keys, temp, top_k, top_p)
            return t0.astype(jnp.int32), lp

        return pick0

    def _prefill_fn(self, params, prompt, seed, pos0, temp, top_k, top_p,
                    *, max_len):
        """[1, P] prompt -> (first token [1], its logprob, ring caches).
        Whole-prompt fallback for configs chunked prefill cannot serve."""
        h, caches, _ = prefill(params, self.cfg, prompt, max_len=max_len)
        logits = lm_logits(params, self.cfg, h[:, -1:, :])[:, 0]
        keys = request_keys(seed, pos0)
        t0, lp = pick_tokens(logits, keys, temp, top_k, top_p)
        return t0.astype(jnp.int32), lp, caches

    @property
    def step_compiles(self) -> int:
        """Decode-step jit-cache size — a compile counter. Heterogeneous
        policies/sampling must keep this at 1 (tests assert it)."""
        return int(self._step._cache_size())

    @property
    def prefill_compiles(self) -> int:
        """Prefill-path jit-cache size: stays at 1 under chunked prefill
        (arbitrary prompt lengths share the one chunk shape); the
        whole-prompt fallback compiles one shape per distinct length."""
        if self.chunked:
            return int(self._chunk._cache_size())
        return int(self._prefill._cache_size())

    # -- lifecycle ----------------------------------------------------------
    def start(self) -> "Scheduler":
        if self._running:
            return self
        if self._stopped:
            raise RuntimeError("scheduler lifecycle is one-shot: build a "
                               "new Scheduler instead of restarting")
        self._running = True
        self._thread = threading.Thread(target=self._loop,
                                        name="scheduler-decode", daemon=True)
        self._thread.start()
        return self

    def stop(self, timeout: float = 10.0) -> None:
        with self._work:
            self._running = False
            self._stopped = True
            self._work.notify_all()
        if self._thread is not None:
            self._thread.join(timeout)
            self._thread = None

    def begin_drain(self) -> None:
        """Stop taking new work (``submit`` raises
        :class:`SchedulerQueueFull`); everything already queued or
        in-flight keeps running. First half of a graceful shutdown —
        :meth:`drain` is the blocking second half."""
        with self._work:
            self._draining = True
            self._work.notify_all()

    def take_queued(self) -> list[Request]:
        """Steal every queued-but-unstarted request (for a fleet router to
        rebalance onto other replicas). The stolen requests are NOT
        failed — the caller owns resubmitting them; their handles stay
        pending meanwhile. Call :meth:`begin_drain` first or the queue
        may refill behind the steal."""
        with self._work:
            stolen, self._queue = self._queue, []
        return stolen

    def drain(self, timeout: float = 30.0, poll_s: float = 0.005) -> bool:
        """Graceful shutdown: :meth:`begin_drain`, wait (bounded by
        ``timeout``) until queued + in-flight requests all complete, then
        :meth:`stop`. Returns True when everything finished in time;
        False means the deadline hit and the leftovers were failed with
        the abrupt ``_drain`` path."""
        self.begin_drain()
        deadline = time.monotonic() + max(timeout, 0.0)
        clean = True
        if self._thread is not None:        # never-started: nothing in flight
            while time.monotonic() < deadline:
                with self._lock:
                    idle = (not self._queue and self._admitting is None
                            and self._prefill_job is None
                            and self.pool.n_used == 0)
                if idle or not self._running:
                    break
                time.sleep(poll_s)
            else:
                clean = False
        with self._lock:
            clean = clean and not self._queue and self.pool.n_used == 0
        self.stop()
        return clean

    @property
    def draining(self) -> bool:
        return self._draining

    def placement_snapshot(self) -> dict:
        """The cheap, lock-consistent subset of :meth:`stats` a fleet
        router needs per placement decision.

        The reported EMA is decayed by the time since the last decode
        tick touched it — an idle loop stops blending, and a frozen-high
        EMA would otherwise repel placements forever (one cool replica
        then absorbs an entire paced workload). ``0.9 ** idle_seconds``
        is the continuous analog of the zero-power 0.9 blend an idle
        tick would apply; the gate's own `_power_w_ema` is untouched."""
        idle_s = max(time.monotonic() - self._power_ema_t, 0.0)
        with self._lock:
            return {
                "queue_depth": len(self._queue),
                "active_slots": self.pool.n_used,
                "prefilling": self._prefill_job is not None,
                "power_w_ema": self._power_w_ema * 0.9 ** min(idle_s, 60.0),
                "power_budget_w": self.power_budget_w,
                "blocked_admissions": self._blocked_admissions,
                "energy_j": self._fleet_energy_j,
            }

    def __enter__(self) -> "Scheduler":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # -- submission ---------------------------------------------------------
    def submit(self, request: Union[GenerationRequest, Sequence[int]], *,
               max_new: Optional[int] = None,
               threshold: Optional[float] = None,
               controller: Optional[str] = None,
               policy: Union[None, str, PolicySpec] = None,
               sampling: Optional[SamplingParams] = None,
               stop_sequences: Optional[Sequence[str]] = None,
               request_class: str = "default",
               energy_budget_j: Optional[float] = None) -> Request:
        """Queue one request. ``request`` is either a
        :class:`repro.api.GenerationRequest` (kwargs must then be left at
        their defaults) or a raw token-id sequence plus kwargs (the seed
        calling convention — ``controller``/``threshold`` map onto a
        :class:`PolicySpec`)."""
        if isinstance(request, GenerationRequest):
            if (max_new is not None or threshold is not None
                    or controller is not None or policy is not None
                    or sampling is not None or stop_sequences is not None
                    or request_class != "default"
                    or energy_budget_j is not None):
                raise ValueError("options must live inside the "
                                 "GenerationRequest when one is submitted")
            prompt = request.prompt
            if isinstance(prompt, str):
                if self.tokenizer is None:
                    raise ValueError("text prompt needs a scheduler "
                                     "tokenizer (pass tokenizer=)")
                prompt = self.tokenizer.encode(prompt)
            spec = request.spec(self.default_spec)
            sampling = request.sampling
            stop_sequences = request.stop_sequences
            max_new = request.max_new_tokens
            request_class = request.request_class
            energy_budget_j = request.energy_budget_j
        else:
            prompt = request
            if policy is not None:
                if controller is not None or threshold is not None:
                    raise ValueError("pass either policy= or the legacy "
                                     "controller=/threshold= pair, not both")
                spec = exit_policy.as_spec(policy)
            elif controller is None and threshold is None:
                spec = self.default_spec
            else:
                # legacy (kind, threshold) pair: start from the configured
                # default spec when the kind matches (its non-threshold
                # params — policy temperature, fixed exit_idx — must
                # survive a mere threshold override)
                kind = controller or self.default_kind
                base = (self.default_spec if kind == self.default_kind
                        else self._legacy_spec(kind, None))
                params = dict(base.params)
                if "threshold" in exit_policy.get(kind).defaults:
                    params.setdefault("threshold", self.default_threshold)
                    if threshold is not None:
                        params["threshold"] = float(threshold)
                spec = PolicySpec(kind, params)
            sampling = sampling or self.default_sampling
            if isinstance(stop_sequences, str):
                raise ValueError("stop_sequences must be a sequence of "
                                 "strings, not a single string")
            stop_sequences = tuple(str(s) for s in (stop_sequences or ()))
            if any(not s for s in stop_sequences):
                raise ValueError("empty string in stop_sequences")

        if spec.name not in self.allowed_kinds:
            raise ValueError(
                f"controller {spec.name!r} not in this scheduler's compiled "
                f"set {sorted(self.allowed_kinds)}")
        if stop_sequences and self.tokenizer is None:
            raise ValueError("stop_sequences need a scheduler tokenizer "
                             "(pass tokenizer=)")
        if max_new is None:
            max_new = self.default_max_new
        if max_new < 1:
            raise ValueError("max_new must be >= 1")
        # speculative rows draft up to spec_window positions past their
        # committed length before rollback — their cache footprint must
        # reserve that overrun
        extra = self.spec_window if spec.name == SPEC_POLICY else 0
        keep = self.pool.max_len - max_new - extra
        if keep < 1:
            raise ValueError(f"max_new={max_new} leaves no room for a prompt "
                             f"(pool max_len={self.pool.max_len}"
                             + (f", speculative draft slack={extra}"
                                if extra else "") + ")")
        prompt = list(prompt)
        truncated = len(prompt) > keep
        prompt = prompt[-keep:]
        if not prompt:
            raise ValueError("empty prompt")
        if self.prefill_buckets is not None:
            # whole-prompt fallback only: left-pad to the smallest bucket
            # >= len(prompt) so prefill compiles O(#buckets) shapes
            # instead of one per distinct length
            blen = min((b for b in self.prefill_buckets
                        if b >= len(prompt)), default=keep)
            prompt = [self.pad_id] * (min(blen, keep) - len(prompt)) + prompt
        if (self.kv_layout == "paged"
                and (self.pool.need_blocks(len(prompt), max_new + extra)
                     > self.pool.blocks.capacity)):
            # checked on the final (tail-clipped) prompt — can_admit sees
            # this exact length, so anything accepted here always admits
            raise ValueError(
                f"request needs "
                f"{self.pool.need_blocks(len(prompt), max_new + extra)} "
                f"KV blocks but the pool only has "
                f"{self.pool.blocks.capacity} "
                f"(raise num_blocks or lower max_new)")
        if energy_budget_j is None:
            energy_budget_j = self.class_energy_budgets_j.get(request_class)
        with self._work:
            if self._stopped:
                # queuing before start() is fine; after stop()/a loop crash
                # nothing will ever drain the queue — fail fast
                raise RuntimeError("scheduler is stopped")
            if self._draining:
                # graceful drain: already-queued work finishes, new work is
                # turned away (a fleet router retries it on a live replica;
                # the HTTP server maps this onto 503)
                raise SchedulerQueueFull("scheduler is draining")
            if len(self._queue) >= self.queue_depth:
                raise SchedulerQueueFull(
                    f"admission queue full ({self.queue_depth})")
            req = Request(req_id=self._seq, prompt=prompt, max_new=max_new,
                          spec=spec, sampling=sampling,
                          stop_sequences=tuple(stop_sequences),
                          request_class=request_class,
                          energy_budget_j=energy_budget_j,
                          truncated=truncated)
            self._seq += 1
            self._queue.append(req)
            self._work.notify_all()
        self._obs_req_begin(req, "queued", prompt_len=len(prompt),
                            policy=spec.name, max_new=max_new)
        return req

    def serve_batch(self, requests: Sequence[Sequence[int]],
                    max_new: Optional[int] = None,
                    threshold: Optional[float] = None,
                    controller: Optional[str] = None,
                    timeout: Optional[float] = 300.0) -> ServeResult:
        """Engine-compatible convenience: submit all, wait all. Blocks on a
        full admission queue instead of raising (offline batches may exceed
        ``queue_depth``)."""
        deadline = None if timeout is None else time.monotonic() + timeout
        handles = []
        for r in requests:
            while True:
                try:
                    handles.append(self.submit(r, max_new=max_new,
                                               threshold=threshold,
                                               controller=controller))
                    break
                except SchedulerQueueFull:
                    if not self._running or self._draining:
                        raise
                    if deadline is not None and time.monotonic() > deadline:
                        raise TimeoutError("queue stayed full past timeout")
                    time.sleep(0.01)
        for h in handles:
            h.result(None if deadline is None
                     else max(deadline - time.monotonic(), 0.001))
        return ServeResult([h.tokens for h in handles],
                           [h.exit_layers for h in handles],
                           [h.metrics for h in handles])

    # -- decode loop --------------------------------------------------------
    def _loop(self) -> None:
        reason = "shutdown"
        try:
            while True:
                with self._work:
                    while (self._running and not self._queue
                           and self.pool.n_used == 0):
                        self._work.wait(0.1)
                    if not self._running:
                        break
                # every loop iteration with live work is one tick span;
                # the named phase spans below nest under it (the trace
                # contract validate_chrome_trace asserts)
                with self.obs.span("tick", cat="tick"):
                    with self.obs.span("admit"):
                        self._admit_ready()
                    busy = False
                    if self._prefill_job is not None:
                        # one prompt chunk per tick: admission shares the
                        # step cadence with decode instead of stopping the
                        # world
                        self._prefill_tick()
                        busy = True
                    if any(r is not None for r in self._slot_req):
                        self._tick()
                        busy = True
                if not busy:
                    time.sleep(0.002)   # queued but gated: don't busy-spin
        except Exception:  # noqa: BLE001
            # a dead decode thread must not leave waiters blocked and the
            # queue silently accepting work nothing will ever drain
            import traceback
            traceback.print_exc()
            reason = "error"
            with self._work:
                self._running = False
                self._stopped = True
        self._drain(reason)

    def _pick_next(self, now: float) -> Optional[Request]:
        """Shortest-prompt-first with FIFO aging: once the oldest request has
        waited past ``max_wait_s`` it wins regardless of length (no
        starvation of long prompts)."""
        if not self._queue:
            return None
        oldest = min(self._queue, key=lambda r: r.req_id)
        if now - oldest.submitted_at > self.max_wait_s:
            pick = oldest
        else:
            pick = min(self._queue, key=lambda r: (len(r.prompt), r.req_id))
        self._queue.remove(pick)
        return pick

    def _decode_budget(self, req: Request) -> int:
        """Worst-case decode positions a request may occupy: ``max_new``
        plus the speculative draft-overrun slack for speculative rows."""
        return req.max_new + (self.spec_window
                              if req.spec.name == SPEC_POLICY else 0)

    def _admission_open(self) -> bool:
        if self.power_budget_w is None:
            return True
        return self._power_w_ema <= self.power_budget_w

    def _admit_ready(self) -> None:
        now = time.monotonic()
        while self.pool.n_free and self._prefill_job is None:
            if not self._admission_open():
                # _power_w_ema is only touched by this thread, so the
                # deferred-gate bookkeeping needs no lock — and must not
                # hold it: submit()/stats() would serialize behind the
                # sleep. A deferred scheduler emits no tokens: decay the
                # power estimate so the gate reopens instead of
                # livelocking with a frozen EMA (and don't busy-spin).
                with self._lock:
                    if not self._queue:
                        return
                    self._deferred_admissions += 1
                self._power_w_ema *= 0.95
                self._power_ema_t = time.monotonic()
                time.sleep(0.005)
                return
            with self._lock:
                if not self._queue:
                    return
                req = self._pick_next(now)
                if (req is not None and self.kv_layout == "paged"
                        and not self.pool.can_admit(
                            req.prompt, self._decode_budget(req))):
                    # admission is gated on free *blocks*, not just free
                    # slots: requeue the pick (submit() bounds requests to
                    # the pool capacity, so a retirement always unblocks
                    # it) ...
                    self._queue.append(req)
                    self._blocked_admissions += 1
                    if now - req.submitted_at > self.max_wait_s:
                        # ... an aged pick holds the line — no younger
                        # request may jump it indefinitely (the same
                        # anti-starvation rule _pick_next applies)
                        return
                    # ... otherwise backfill: spare blocks go to the best
                    # request that fits instead of head-of-line blocking
                    fits = [r for r in self._queue
                            if self.pool.can_admit(r.prompt,
                                                   self._decode_budget(r))]
                    if not fits:
                        return
                    req = min(fits, key=lambda r: (len(r.prompt), r.req_id))
                    self._queue.remove(req)
            if req is not None:
                # referenced while in flight: a crash inside _admit /
                # _start_prefill must still let _drain fail this request
                # (it is neither queued nor resident at that point)
                self._admitting = req
                if self.chunked:
                    self._start_prefill(req)
                else:
                    self._count_fallback("chunked_prefill")
                    self._admit(req)
                self._admitting = None

    def _count_fallback(self, feature: str) -> None:
        """One slow-path admission: bump the per-feature fallback counter
        and warn once per (config, feature) so the degradation is visible
        without log-spamming every request."""
        self._fallbacks[feature] = self._fallbacks.get(feature, 0) + 1
        if feature not in self._warned_fallbacks:
            self._warned_fallbacks.add(feature)
            warnings.warn(
                f"{self.cfg.name}: {feature} unsupported "
                f"({self._fallback_reasons.get(feature, 'unknown reason')})"
                f" — serving via the slow fallback path",
                RuntimeWarning, stacklevel=2)

    # -- chunked admission ---------------------------------------------------
    def _start_prefill(self, req: Request) -> None:
        """Open a chunked admission: claim the slot (and bind paged blocks)
        up front so nothing can steal them mid-stream, then let the decode
        loop advance the prompt one chunk per tick. The request joins the
        decode batch only when its last chunk lands (_finish_prefill)."""
        slot = self.pool.alloc()
        assert slot is not None, "admission with no free slot"
        C = self.prefill_chunk
        plen = len(req.prompt)
        grid = np.asarray(req.prompt + [self.pad_id] * ((-plen) % C),
                          np.int32)
        ring = init_prefill_ring(self.cfg, 1, self._ring_len)
        ids = None
        n_shared = 0
        tail_shared = False
        shared_tokens = 0
        if self.kv_layout == "paged":
            ids, n_shared, tail_shared = self.pool.bind_prompt(req.prompt)
            shared_tokens = min(n_shared * self.pool.block_size, plen)
            if n_shared:
                # shared prefix K/V into the ring, so skipped chunks are
                # still attendable by the ones that do run
                ring = self.pool.gather_prefix(ring, ids, shared_tokens)
        # skip chunks fully covered by shared prefix content; the final
        # chunk always runs — its logits carry the first sampled token
        start = (min(shared_tokens, plen - 1) // C) * C
        req.status = "running"
        req.started_at = time.monotonic()
        self._obs_req_begin(req, "prefill", prompt_len=plen,
                            shared_tokens=shared_tokens)
        self._prefill_job = _PrefillJob(req=req, slot=slot, ring=ring,
                                        grid=grid, next_pos=start,
                                        plen=plen, ids=ids,
                                        n_shared=n_shared,
                                        tail_shared=tail_shared)

    def _prefill_tick(self) -> None:
        """Advance the in-flight admission by ONE compiled chunk step."""
        job = self._prefill_job
        t_start = time.monotonic()
        c0 = job.next_pos
        C = self.prefill_chunk
        with self.obs.span("prefill_chunk", req_id=job.req.req_id,
                           pos=int(c0)):
            logits, job.ring = self._chunk(
                self.params, jnp.asarray(job.grid[None, c0:c0 + C]),
                job.ring, jnp.asarray([c0], jnp.int32),
                jnp.asarray([job.plen], jnp.int32))
            self.obs.count("dispatch")
            # sync before timing: jit returns at dispatch, and an async dt
            # would inflate the modeled watts by the dispatch/compute gap
            # and spuriously close the power gate (_plain_tick syncs via
            # its np.asarray fetch; the chunk result is otherwise
            # unfetched)
            with self.obs.wait():
                logits.block_until_ready()
            # prompt ingestion is not free: charge the chunk's modeled
            # joules to the request and the fleet power EMA (the power
            # gate defers admission under prefill load exactly like
            # decode load)
            e = energy.prefill_chunk_energy(self.cfg, min(c0 + C, job.plen),
                                            min(C, job.plen - c0))
            job.req.prefill_energy_j += e
            with self._lock:
                self._fleet_prefill_j += e
            dt = max(time.monotonic() - t_start, 1e-6)
            self._power_w_ema = 0.9 * self._power_w_ema + 0.1 * (e / dt)
            self._power_ema_t = time.monotonic()
            job.next_pos = c0 + C
            if job.next_pos >= job.plen:
                self._prefill_job = None
                self._finish_prefill(job, logits, c0)

    def _finish_prefill(self, job: _PrefillJob, logits, c0: int) -> None:
        """Last chunk landed: sample the first token from its logits,
        splice the ring into the pool, and seat the request in its slot."""
        req, slot = job.req, job.slot
        s = req.sampling
        t0, lp0 = self._pick0(
            logits[:, (job.plen - 1) - c0],
            jnp.asarray([s.seed], jnp.int32),
            jnp.asarray([job.plen - 1], jnp.int32),
            jnp.asarray([s.temperature], jnp.float32),
            jnp.asarray([s.top_k], jnp.int32),
            jnp.asarray([s.top_p], jnp.float32))
        self.obs.count("dispatch")       # first-token picker
        ring = self._finalize(job.ring, jnp.asarray([job.plen], jnp.int32))
        if self.kv_layout == "paged":
            n_skip, n_write = self.pool.install_prompt(
                slot, job.plen, job.ids, job.n_shared, job.tail_shared,
                max_new=self._decode_budget(req))
            if n_write > n_skip:
                self.pool.write_ring(slot, ring, n_skip, n_write)
        else:
            self.pool.write(ring, slot)
        self.obs.count("dispatch")       # ring -> pool splice
        self._bind_slot(req, slot)
        self._account_token(req, int(t0[0]), slot, logprob=float(lp0[0]))

    # -- whole-prompt admission (chunked_prefill_unsupported fallback) ------
    def _admit(self, req: Request) -> None:
        s = req.sampling
        self._obs_req_begin(req, "prefill", prompt_len=req.ctx_len)
        paged = self.kv_layout == "paged"
        if paged:
            # prefill to the block-rounded prompt length: ring entries land
            # in logical order and reshape straight into block planes
            plen = self.pool.block_size * self.pool.blocks_for(
                len(req.prompt))
        else:
            plen = self.pool.max_len
        t0, lp0, req_caches = self._prefill(
            self.params, jnp.asarray([req.prompt], jnp.int32),
            jnp.asarray([s.seed], jnp.int32),
            jnp.asarray([len(req.prompt) - 1], jnp.int32),
            jnp.asarray([s.temperature], jnp.float32),
            jnp.asarray([s.top_k], jnp.int32),
            jnp.asarray([s.top_p], jnp.float32),
            max_len=plen)
        self.obs.count("dispatch")
        slot = self.pool.alloc()
        assert slot is not None, "admission with no free slot"
        if paged:
            self.pool.write_prompt(slot, req.prompt, req_caches,
                                   max_new=self._decode_budget(req))
        else:
            self.pool.write(req_caches, slot)
        req.status = "running"
        req.started_at = time.monotonic()
        self._bind_slot(req, slot)
        self._account_token(req, int(t0[0]), slot, logprob=float(lp0[0]))

    def _bind_slot(self, req: Request, slot: int) -> None:
        """Seat a freshly prefilled request in its slot's runtime arrays."""
        self._obs_req_end(req, prefill_energy_j=req.prefill_energy_j)
        self._obs_req_begin(req, "decode", slot=slot)
        s = req.sampling
        req._exits_all.append(self.cfg.num_layers)   # token 0: full prefill
        self._slot_req[slot] = req
        self._cur_tok[slot] = 0
        self._pos[slot] = req.ctx_len
        self._ids[slot] = exit_policy.get(req.spec.name).id
        resolved = req.spec.resolved()
        for f in self._pp:
            self._pp[f][slot] = resolved.get(f, exit_policy.field_default(f))
        self._temp[slot] = s.temperature
        self._topk[slot] = s.top_k
        self._topp[slot] = s.top_p
        self._seed[slot] = s.seed
        self._peak_active = max(self._peak_active, self.pool.n_used)

    def _tick(self) -> None:
        if any(req is not None and req.spec.name == SPEC_POLICY
               for req in self._slot_req):
            self._spec_tick()
        else:
            self._plain_tick()

    def _run_step(self):
        """One compiled decode step over all slots (shared by plain ticks
        and the speculative draft phase). Returns (tokens, exit layers,
        logprobs, f32 logits) as device arrays."""
        if self.kv_layout == "paged":
            # bind (or copy-on-write) every resident's write-target block
            # before the compiled step scatters this tick's K/V
            for slot, req in enumerate(self._slot_req):
                if req is not None:
                    self.pool.prepare_append(slot, int(self._pos[slot]))
            tables = self.pool.device_tables()
        else:
            tables = jnp.zeros((0,), jnp.int32)   # unused by the step
        nxt, new_caches, exitl, lp, logits = self._step(
            self.params, jnp.asarray(self._cur_tok), self.pool.caches,
            tables, jnp.asarray(self._pos), jnp.asarray(self._ids),
            {f: jnp.asarray(v) for f, v in self._pp.items()},
            jnp.asarray(self._temp), jnp.asarray(self._topk),
            jnp.asarray(self._topp), jnp.asarray(self._seed))
        self.obs.count("dispatch")
        self.pool.caches = new_caches
        return nxt, exitl, lp, logits

    def _plain_tick(self) -> None:
        t_start = time.monotonic()
        obs = self.obs
        with obs.span("decode_step"):            # host-side dispatch only
            out = self._run_step()
        with obs.span("sample_host"):            # the tick's sync point:
            with obs.wait():                     # sampled tokens to host
                nxt = np.asarray(out[0])
                exitl = np.asarray(out[1])
                lp = np.asarray(out[2])
        tick_energy = 0.0
        with obs.span("bookkeeping"):
            for slot, req in enumerate(self._slot_req):
                if req is None:
                    continue
                self._pos[slot] += 1
                req._exits_all.append(int(exitl[slot]))
                tick_energy += self._account_token(req, int(nxt[slot]),
                                                   slot,
                                                   logprob=float(lp[slot]))
        dt = max(time.monotonic() - t_start, 1e-6)
        self._power_w_ema = (0.9 * self._power_w_ema
                             + 0.1 * (tick_energy / dt))
        self._power_ema_t = time.monotonic()

    def _spec_tick(self) -> None:
        """Draft-then-verify super-tick (>= 1 speculative resident).

        Up to ``spec_window`` draft sub-steps run the ordinary compiled
        step:
        speculative rows exit at their draft boundary and their tokens are
        buffered as *drafts*; co-resident non-speculative rows decode real
        tokens as usual. One full-depth verify pass then scores every
        speculative row's window (non-speculative rows ride along with
        cache writes masked off), accepted drafts + the correction token
        are emitted, and the rejected tail rolls back — ring ``pos``
        rewound, paged block appends unbound. Configs with destructive
        cache writes (mamba state, sliding-window evictions) use the
        snapshot/commit protocol instead: caches are snapshotted before
        drafting, speculative rows restore to the snapshot before verify,
        and the post-acceptance commit blends verified entries with the
        snapshot per row (``commit_spec_cache``).

        An in-flight chunked admission advances one chunk per draft
        sub-step (not one per super-tick): without the interleave a
        ``spec_window``-deep super-tick starves prefill by a factor of
        K + 1 and inflates queued requests' TTFT by the same factor.
        """
        t_start = time.monotonic()
        S = self.pool.max_slots
        paged = self.kv_layout == "paged"
        snapshot = self._spec_snapshot
        snap = None
        if snapshot:
            snap = self._copy(self.pool.caches)
            self.obs.count("dispatch")
        spec = {s: r for s, r in enumerate(self._slot_req)
                if r is not None and r.spec.name == SPEC_POLICY}
        # size the super-tick to the largest *effective* window resident:
        # a row one token from its budget must not drag everyone through
        # spec_window drafts it would immediately throw away (K may be 0 —
        # the verify then degenerates to one full-depth step)
        eff = {s: max(min(int(self._pp["window"][s]), self.spec_window,
                          r.max_new - len(r.tokens) - 1), 0)
               for s, r in spec.items()}
        K = max(eff.values())
        p0 = {s: int(self._pos[s]) for s in spec}
        t0 = {s: int(self._cur_tok[s]) for s in spec}
        slots = sorted(spec)
        idx = np.asarray(slots)
        drafts = np.zeros((S, K), np.int64)
        need_dl = any(self._temp[s] > 0 for s in spec)
        dlogits: list[np.ndarray] = []
        tick_energy = 0.0

        for j in range(K):
            with self.obs.span("draft", j=j):
                nxt, exitl, lp, logits = self._run_step()
                with self.obs.wait():
                    nxt = np.asarray(nxt)
                    exitl = np.asarray(exitl)
                    lp = np.asarray(lp)
                if need_dl:
                    # fetch only the speculative rows — the full [S, V]
                    # plane never crosses to host
                    with self.obs.wait():
                        dlogits.append(np.asarray(logits[jnp.asarray(idx)]))
                for slot, req in enumerate(self._slot_req):
                    if req is None:
                        continue
                    if slot in spec:       # buffer the draft, feed it back
                        drafts[slot, j] = int(nxt[slot])
                        self._pos[slot] += 1
                        self._cur_tok[slot] = nxt[slot]
                    else:                  # non-speculative rows: for real
                        self._pos[slot] += 1
                        req._exits_all.append(int(exitl[slot]))
                        tick_energy += self._account_token(
                            req, int(nxt[slot]), slot,
                            logprob=float(lp[slot]))
            job = self._prefill_job
            if job is not None and job.next_pos + self.prefill_chunk < job.plen:
                # advance the in-flight admission at draft-step cadence —
                # but leave its FINAL chunk to the main loop: finishing it
                # here would seat the request mid-super-tick and skew this
                # tick's draft/verify bookkeeping
                self._prefill_tick()
                self._prefill_interleaved += 1

        # full-depth verify over [t0, d1..dK] at positions p0..p0+K
        with self.obs.span("verify", window=K, rows=len(slots)):
            win = np.zeros((S, K + 1), np.int64)
            mask = np.zeros(S, bool)
            pos0 = np.zeros(S, np.int64)
            for slot in spec:
                win[slot, 0] = t0[slot]
                win[slot, 1:] = drafts[slot]
                mask[slot] = True
                pos0[slot] = p0[slot]
            if paged:
                for slot in spec:
                    self.pool.prepare_append(slot, p0[slot] + K)
                tables = self.pool.device_tables()
            elif snapshot:
                # destructive draft writes (mamba recurrence, windowed
                # evictions) cannot be pos-rewound: speculative rows return
                # wholesale to the pre-draft snapshot, live rows keep their
                # caches (incl. any admission spliced in mid-draft)
                tables = jnp.zeros((0,), jnp.int32)
                self.pool.caches = self._restore(self.pool.caches, snap,
                                                 jnp.asarray(~mask))
                self.obs.count("dispatch")
            else:
                tables = jnp.zeros((0,), jnp.int32)
                # clean the draft writes out of the window first: the
                # ring's inclusive mask + self term would double-count them
                keep = np.full(S, np.iinfo(np.int32).max, np.int64)
                for slot in spec:
                    keep[slot] = p0[slot] - 1
                self.pool.caches = self._rewind(self.pool.caches,
                                                jnp.asarray(keep, jnp.int32))
                self.obs.count("dispatch")
            state_snaps = None
            vargs = (self.params, jnp.asarray(win, jnp.int32),
                     self.pool.caches, tables, jnp.asarray(pos0, jnp.int32),
                     jnp.asarray(mask))
            if self._spec_collect:
                tlogits, new_caches, state_snaps = self._verify_collect(
                    *vargs)
            else:
                tlogits, new_caches = self._verify(*vargs)
            self.obs.count("dispatch")
            self.pool.caches = new_caches
            with self.obs.wait():
                tlogits = np.asarray(tlogits)

            windows = np.asarray([eff[s] for s in slots])
            n_acc, nxt_tok, _ = accept_drafts(
                drafts[idx], tlogits[idx], windows=windows,
                temperature=self._temp[idx], top_k=self._topk[idx],
                top_p=self._topp[idx], seeds=self._seed[idx], pos0=pos0[idx],
                accept_threshold=self._pp["accept_threshold"][idx],
                draft_logits=(np.stack(dlogits, axis=1)
                              if need_dl and dlogits else None))

        with self.obs.span("bookkeeping"):
            keep = np.full(S, np.iinfo(np.int32).max, np.int64)
            accept = np.zeros(S, np.int64)
            for i, slot in enumerate(slots):
                req = spec[slot]
                a = int(n_acc[i])
                keep[slot] = p0[slot] + a
                accept[slot] = a
                dl_layer = draft_boundary_layer(self.cfg,
                                                self._pp["draft_idx"][slot])
                e = energy.speculative_step_energy(self.cfg, req.ctx_len,
                                                   dl_layer, K, K + 1)
                per_tok = e["total_j"] / (a + 1)
                req.spec_verifies += 1
                req.spec_drafted += int(windows[i])
                req.spec_accepted += a
                self._spec_verifies += 1
                self._spec_drafted += int(windows[i])
                self._spec_accepted += a
                emitted = list(drafts[slot, :a]) + [int(nxt_tok[i])]
                retired = False
                for tok in emitted:
                    # verified tokens are exact full-depth output
                    req._exits_all.append(self.cfg.num_layers)
                    tick_energy += self._account_token(req, int(tok), slot,
                                                       energy_j=per_tok)
                    self._spec_emitted += 1
                    if req.status == "done":
                        retired = True
                        break
                if retired:
                    continue              # slot released; blocks freed
                self._pos[slot] = p0[slot] + len(emitted)
                if paged:
                    self.pool.rollback_append(slot,
                                              keep_tokens=p0[slot] + a + 1)
            if snapshot:
                # per-row blend: verified entries up to keep, snapshot
                # beyond (windowed evictions restored); mamba rows commit
                # the per-step verify state at their acceptance count.
                # Non-speculative rows pass keep=INT32_MAX — their verify
                # writes were masked no-ops, so the blend is the identity.
                self.pool.caches = self._commit(
                    self.pool.caches, snap, jnp.asarray(keep, jnp.int32),
                    state_snaps, jnp.asarray(accept, jnp.int32))
                self.obs.count("dispatch")
            elif not paged:
                self.pool.caches = self._rewind(self.pool.caches,
                                                jnp.asarray(keep, jnp.int32))
                self.obs.count("dispatch")
        dt = max(time.monotonic() - t_start, 1e-6)
        self._power_w_ema = (0.9 * self._power_w_ema
                             + 0.1 * (tick_energy / dt))
        self._power_ema_t = time.monotonic()

    def _account_token(self, req: Request, token: int, slot: int,
                       energy_j: Optional[float] = None,
                       logprob: Optional[float] = None) -> float:
        """Record one produced token; retire the request when finished.
        Returns the modeled energy of the step that produced it
        (``energy_j`` overrides the exit-layer model — the speculative
        path charges amortized draft + verify cost instead, and emits its
        verified tokens without picker ``logprob``s)."""
        e = (energy_j if energy_j is not None
             else self._token_energy(req.ctx_len, req._exits_all[-1]))
        if token == self.eos_id:
            # EOS is excluded from the response; its producing step is
            # excluded from accounting too (Engine.serve semantics).
            self._retire(req, slot, "eos")
            return 0.0
        if not req.tokens:
            req.first_token_at = time.monotonic()
        req.tokens.append(token)
        if logprob is not None:
            req.logprobs.append(logprob)
        req.energy_j += e
        req._stream.put(token)
        self._exit_layer_ema = (0.95 * self._exit_layer_ema
                                + 0.05 * req._exits_all[-1])
        if req.stop_sequences:
            # string-level check at detokenize time: a stop sequence may
            # span several (byte-fallback) tokens. Only a tail window is
            # decoded per token — a match must end at the token just
            # appended, and one character consumes at most 4 byte-fallback
            # tokens — so per-token cost is O(longest stop), not O(tokens).
            longest = max(len(s) for s in req.stop_sequences)
            tail = self.tokenizer.decode(req.tokens[-(4 * longest + 8):])
            if find_stop(tail, req.stop_sequences) is not None:
                # confirmed: one full decode to find the exact cut point
                text = self.tokenizer.decode(req.tokens)
                hit = find_stop(text, req.stop_sequences)
                if hit is not None:
                    req.text = text[:hit[0]]
                    self._retire(req, slot, "stop")
                    return e
        if (req.energy_budget_j is not None
                and req.energy_j >= req.energy_budget_j):
            self._retire(req, slot, "energy_budget")
        elif len(req.tokens) >= req.max_new:
            self._retire(req, slot, "length")
        else:
            self._cur_tok[slot] = token
        return e

    def _token_energy(self, ctx_len: int, exit_layer: int) -> float:
        tab = self._ecache.get(ctx_len)
        if tab is None:
            tab = energy.decode_token_energy(
                self.cfg, ctx_len, np.arange(1, self.cfg.num_layers + 1))
            self._ecache[ctx_len] = tab
        idx = int(np.clip(exit_layer, 1, self.cfg.num_layers)) - 1
        return float(tab[idx])

    def _obs_req_begin(self, req: Request, stage: str, **args) -> None:
        """Advance a request's lifecycle span (``req/queued`` →
        ``req/prefill`` → ``req/decode``): close the open stage, open the
        next. Tracked on the request so a drain can close whatever stage
        was open when the loop stopped."""
        if req._obs_stage is not None:
            self.obs.async_end(f"req/{req._obs_stage}", req.req_id)
        req._obs_stage = stage
        self.obs.async_begin(f"req/{stage}", req.req_id, **args)

    def _obs_req_end(self, req: Request, **args) -> None:
        if req._obs_stage is not None:
            self.obs.async_end(f"req/{req._obs_stage}", req.req_id, **args)
            req._obs_stage = None

    def _retire(self, req: Request, slot: int, reason: str) -> None:
        with self.obs.span("retire", req_id=req.req_id, reason=reason):
            self._retire_inner(req, slot, reason)
        self._obs_req_end(req, tokens=len(req.tokens),
                          energy_j=req.energy_j,
                          prefill_energy_j=req.prefill_energy_j,
                          finish_reason=reason)

    def _retire_inner(self, req: Request, slot: int, reason: str) -> None:
        el = np.asarray(req._exits_all[:max(len(req.tokens), 1)], np.int32)
        req.exit_layers = el.tolist()
        req.metrics = request_metrics(self.cfg, el, req.ctx_len)
        req.finish_reason = reason
        req.finished_at = time.monotonic()
        if req.text is None and self.tokenizer is not None:
            req.text = self.tokenizer.decode(req.tokens)
        req.status = "done"
        self._slot_req[slot] = None
        self._cur_tok[slot] = self.pad_id
        self._pos[slot] = 0
        self._ids[slot] = 0                      # 'none'
        for f in self._pp:
            self._pp[f][slot] = exit_policy.field_default(f)
        self._temp[slot] = 0.0
        self._topk[slot] = 0
        self._topp[slot] = 1.0
        self._seed[slot] = 0
        self.pool.release(slot)
        with self._lock:
            self._completed += 1
            self._fleet_tokens += len(req.tokens)
            # accumulated per-token charges, NOT metrics.energy_j: for
            # speculative rows the exit-layer model would miss the draft +
            # verify cost the super-tick actually charged
            self._fleet_energy_j += req.energy_j
            self._latencies.append(req.latency_s)
            if len(self._latencies) > 4096:
                del self._latencies[:2048]
            if req.ttft_s is not None:
                self._ttfts.append(req.ttft_s)
                if len(self._ttfts) > 4096:
                    del self._ttfts[:2048]
        req._stream.put(None)
        req._done.set()

    def _drain(self, reason: str = "shutdown") -> None:
        """On stop/crash: fail queued requests, retire residents
        mid-sequence (partial tokens + metrics are kept)."""
        with self._lock:
            dropped, self._queue = self._queue, []
        if (self._admitting is not None
                and self._admitting.status != "done"):
            dropped.append(self._admitting)
        self._admitting = None
        job, self._prefill_job = self._prefill_job, None
        if job is not None:
            # mid-stream admission: hand back the claimed slot and any
            # bound-but-never-installed blocks, fail the request
            if job.ids is not None:
                self.pool.abort_bind(job.ids)
            self.pool.release(job.slot)
            if job.req.status != "done" and job.req not in dropped:
                dropped.append(job.req)
        for req in dropped:
            req.status = "done"
            req.finish_reason = reason
            req.finished_at = time.monotonic()
            self._obs_req_end(req, finish_reason=reason)
            req._stream.put(None)
            req._done.set()
        # retire spans are tick-scoped phases; drain-time retirement gets
        # its own top-level tick so the trace stays well-nested
        with self.obs.span("drain", cat="tick", reason=reason):
            for slot, req in enumerate(self._slot_req):
                if req is not None:
                    self._retire(req, slot, reason)

    # -- introspection ------------------------------------------------------
    def reset_peak_stats(self) -> None:
        """Reset high-water / cumulative admission stats — call between a
        warmup phase and a timed run so ``stats()`` covers only the run.

        The closed window folds into the ``lifetime`` sub-dict of
        ``stats()``; the throughput window (``_t0``, fleet token / energy
        cumulatives, latency samples) restarts so ``throughput_tok_s``
        and the fleet counters describe the current window only."""
        with self._lock:
            now = time.monotonic()
            lt = self._lifetime
            lt["completed_requests"] += self._completed
            lt["fleet_tokens"] += self._fleet_tokens
            lt["fleet_energy_j"] += self._fleet_energy_j
            lt["fleet_prefill_energy_j"] += self._fleet_prefill_j
            lt["uptime_s"] += max(now - self._t0, 0.0)
            self._t0 = now
            self._completed = 0
            self._fleet_tokens = 0
            self._fleet_energy_j = 0.0
            self._fleet_prefill_j = 0.0
            self._latencies.clear()
            self._ttfts.clear()
            self._peak_active = self.pool.n_used
            self._blocked_admissions = 0
            self._deferred_admissions = 0
            self._spec_verifies = 0
            self._spec_drafted = 0
            self._spec_accepted = 0
            self._spec_emitted = 0
            self._prefill_interleaved = 0
            if isinstance(self.pool, PagedKVPool):
                self.pool.reset_stats()

    def stats(self) -> dict:
        ctrs = self.obs.counters
        with self._lock:
            lt = self._lifetime
            pct = latency_percentiles(self._latencies)
            tpct = latency_percentiles(self._ttfts)
            up = max(time.monotonic() - self._t0, 1e-9)
            kv = {"kv_layout": self.kv_layout}
            if self.kv_layout == "paged":
                kv.update(self.pool.stats())
            else:
                kv["kv_bytes_total"] = self.pool.kv_bytes_total
            spec = {}
            if SPEC_POLICY in self.allowed_kinds:
                spec = {
                    "spec_window": self.spec_window,
                    "spec_verifies": self._spec_verifies,
                    "spec_drafted": self._spec_drafted,
                    "spec_accepted": self._spec_accepted,
                    "acceptance_rate": (self._spec_accepted
                                        / max(self._spec_drafted, 1)),
                    "tokens_per_verify": (self._spec_emitted
                                          / max(self._spec_verifies, 1)),
                    "prefill_interleaved_chunks": self._prefill_interleaved,
                }
            return {
                "queue_depth": len(self._queue),
                "queue_capacity": self.queue_depth,
                "draining": self._draining,
                "active_slots": self.pool.n_used,
                "peak_active_slots": self._peak_active,
                "free_slots": self.pool.n_free,
                "max_slots": self.pool.max_slots,
                "max_len": self.pool.max_len,
                "blocked_admissions": self._blocked_admissions,
                **kv,
                "chunked_prefill": self.chunked,
                "fallbacks": {
                    f: {"count": self._fallbacks.get(f, 0), "reason": r}
                    for f, r in sorted(self._fallback_reasons.items())},
                "prefill_chunk": self.prefill_chunk,
                "prefill_compiles": self.prefill_compiles,
                "prefilling": self._prefill_job is not None,
                "fleet_prefill_energy_j": self._fleet_prefill_j,
                "completed_requests": self._completed,
                "fleet_tokens": self._fleet_tokens,
                "fleet_energy_j": self._fleet_energy_j,
                "fleet_j_per_token": (self._fleet_energy_j
                                      / max(self._fleet_tokens, 1)),
                "throughput_tok_s": self._fleet_tokens / up,
                "power_w_ema": self._power_w_ema,
                "power_budget_w": self.power_budget_w,
                "deferred_admissions": self._deferred_admissions,
                "exit_layer_ema": self._exit_layer_ema,
                "latency_p50_s": pct["p50_s"],
                "latency_p95_s": pct["p95_s"],
                "ttft_p50_s": tpct["p50_s"],
                "ttft_p95_s": tpct["p95_s"],
                "step_compiles": self.step_compiles,
                "controllers": sorted(self.allowed_kinds),
                "uptime_s": up,
                "tracing": self.obs.enabled,
                "dispatches": ctrs.get("dispatch", 0),
                "sync_points": ctrs.get("sync_points", 0),
                "lifetime": {
                    "completed_requests": (lt["completed_requests"]
                                           + self._completed),
                    "fleet_tokens": lt["fleet_tokens"] + self._fleet_tokens,
                    "fleet_energy_j": (lt["fleet_energy_j"]
                                       + self._fleet_energy_j),
                    "fleet_prefill_energy_j": (lt["fleet_prefill_energy_j"]
                                               + self._fleet_prefill_j),
                    "uptime_s": lt["uptime_s"] + up,
                },
                **spec,
            }
