"""Data-parallel fleet serving: replica schedulers behind one router.

GREEN-CODE's thesis is that *inference* dominates lifetime energy
because it is a continuous, high-invocation workload — a regime one
replica, one admission stream and one power gate cannot reach. This
module scales the serving stack out instead of up: N independent
:class:`~repro.serving.scheduler.Scheduler` replicas (each with its own
KV pool, decode thread and power-gate EMA, wrapped unchanged) sit
behind a single :class:`Router` that owns request placement, fleet
lifecycle and fleet-level observability.

Placement policies (:func:`make_placement`)
  * ``rr``          — round-robin over live replicas.
  * ``least_queue`` — smallest load proxy (queue depth + active slots,
    +1 while a prefill stream is open); ties break to the lowest
    replica id.
  * ``energy``      — the headline policy: route to the replica whose
    power gate has the most *headroom*. Headroom is measured against
    **committed power** — the power EMA scaled up by the replica's
    queued-to-active ratio (``ema * (1 + queued/active)``), because the
    raw EMA is a lagging signal that herds work onto whichever replica
    most recently went idle — so ``headroom = power_budget_w -
    committed`` when a budget is set, ``-committed`` otherwise (the
    per-replica admission power gate generalized to fleet level).
    Prefix-cache **affinity** tiebreaks: a prompt whose prefix was
    routed before goes back to the replica likely to still hold those
    KV blocks, as long as that replica's headroom is within
    ``AFFINITY_SLACK`` of the best.

All three are deterministic functions of (submission order, replica
snapshots): the virtual-clock fleet trace
(``benchmarks.serving_load.run_fleet_trace``) replays them against pool
bookkeeping with a modeled per-tick energy stream, so routing behavior
is CI-testable bit-for-bit without hardware.

Lifecycle
  ``Router.spawn_replica()`` adds capacity live. ``drain_replica(rid)``
  gracefully removes one: the replica stops taking placements, its
  queued-but-unstarted requests are **rebalanced** to the remaining
  replicas (their :class:`FleetRequest` handles rebind transparently —
  callers never notice), its in-flight requests run to completion
  (bounded by ``timeout``), then its scheduler stops. ``Router.drain()``
  does the same for the whole fleet — the server's graceful-shutdown
  path. ``stop()`` is the abrupt variant (replica ``_drain`` semantics:
  queued requests fail, residents retire mid-sequence).

Observability
  ``Router.stats()`` returns a ``fleet`` aggregate plus ``per_replica``
  breakdowns (queue depth, active slots, power EMA, blocked admissions —
  exactly the router's placement inputs, so its decisions are
  inspectable from ``GET /queue``). ``Router.prometheus()`` renders
  per-replica-labeled series (``repro_queue_depth{replica="1"}``).
  ``Router.drain_events()`` merges the replicas' Chrome traces into one
  log with replica-scoped tids (replica ``r``, local thread ``t`` →
  tid ``r * TID_STRIDE + t``), so one Perfetto timeline shows the whole
  fleet with one track group per replica.
"""
from __future__ import annotations

import queue as _queue
import threading
import time
from dataclasses import dataclass, field
from typing import Callable, Optional, Sequence

from repro.obs.prom import render_fleet_prometheus
from repro.serving.scheduler import (Request, Scheduler,
                                     SchedulerQueueFull)

#: merged-trace tid layout: replica r's local thread t maps to
#: r * TID_STRIDE + t (local tids are first-seen-order small ints).
TID_STRIDE = 100

#: energy policy: the prefix-affinity tiebreak only overrides the
#: max-headroom pick while the affine replica's headroom is within this
#: fraction of the best replica's.
AFFINITY_SLACK = 0.25

PLACEMENTS = ("rr", "least_queue", "energy")


# ---------------------------------------------------------------------------
# Placement policies
# ---------------------------------------------------------------------------
@dataclass
class ReplicaSnapshot:
    """What the router knows about one replica when it places a request.

    Built from :meth:`Scheduler.placement_snapshot` — the same numbers
    ``GET /queue`` exposes per replica, so every placement decision is
    reproducible from observable state.
    """
    replica_id: int
    queue_depth: int
    active_slots: int
    prefilling: bool
    power_w_ema: float
    power_budget_w: Optional[float]
    blocked_admissions: int = 0
    # joules retired on this replica in the current stats window — the
    # spreading signal when the whole fleet idles between paced arrivals
    # and committed power carries no information
    energy_j: float = 0.0

    @property
    def load(self) -> int:
        return (self.queue_depth + self.active_slots
                + (1 if self.prefilling else 0))

    @property
    def committed_power_w(self) -> float:
        """Projected power once queued work starts burning.

        The raw EMA is a *lagging* signal: a replica with a deep queue
        still reads cool until those requests actually decode, so
        routing on raw EMA herds new work onto whichever replica most
        recently went idle. Scale the EMA by the queued-to-active ratio
        — each queued request is projected to cost about what a current
        resident costs — and the herding disappears (measured in
        ``run_fleet_trace``: raw-EMA routing ends ~25% more concentrated
        than round-robin; committed-power routing beats it).

        The EMA the snapshot carries must also be *fresh*: an idle
        scheduler's decode loop stops blending, so
        :meth:`Scheduler.placement_snapshot` decays the reported EMA by
        the time since the last decode tick (the same 0.9/s blend a
        zero-power tick would apply). Without that decay a frozen-high
        EMA repels work forever — measured under paced arrivals: one
        replica absorbs the entire workload because the other's warmup
        EMA never cools."""
        return self.power_w_ema * (1.0 + self.queue_depth
                                   / max(self.active_slots, 1))

    @property
    def headroom(self) -> float:
        """Power-gate headroom: how far this replica's committed power
        sits below its admission budget (no budget: just the negated
        committed power, so 'most headroom' still means 'coolest
        replica')."""
        if self.power_budget_w is not None:
            return self.power_budget_w - self.committed_power_w
        return -self.committed_power_w


class PlacementPolicy:
    """Base: ``choose`` picks a replica id from live snapshots.

    ``prefix_home`` is the id of the replica that last served this
    prompt's prefix (or None) — only the energy policy uses it today,
    but every policy receives it so new affinity-aware policies slot in.
    """

    name = "base"

    def choose(self, snaps: Sequence[ReplicaSnapshot],
               prefix_home: Optional[int] = None) -> int:
        raise NotImplementedError


class RoundRobin(PlacementPolicy):
    name = "rr"

    def __init__(self) -> None:
        self._next = 0

    def choose(self, snaps, prefix_home=None) -> int:
        pick = snaps[self._next % len(snaps)]
        self._next += 1
        return pick.replica_id


class LeastQueue(PlacementPolicy):
    name = "least_queue"

    def choose(self, snaps, prefix_home=None) -> int:
        return min(snaps, key=lambda s: (s.load, s.replica_id)).replica_id


class EnergyHeadroom(PlacementPolicy):
    name = "energy"

    def __init__(self, affinity_slack: float = AFFINITY_SLACK) -> None:
        self.affinity_slack = affinity_slack

    def choose(self, snaps, prefix_home=None) -> int:
        # two regimes. Fleet fully idle at routing time (paced arrivals:
        # nothing queued, resident or prefilling anywhere): committed
        # power is decayed-EMA residue, not signal — chasing it herds
        # the entire workload onto one replica (measured: >0.95
        # max-replica energy share). Balance the window's cumulative
        # joules instead: coolest history first, greedy minimization of
        # the very share the fleet stats report. Any live work anywhere:
        # power-gate headroom decides; equal-headroom ties (a cold
        # fleet) break to the least-loaded replica so requests spread
        # before the EMAs diverge.
        if all(s.load == 0 for s in snaps):
            best = min(snaps, key=lambda s: (s.energy_j, s.replica_id))
        else:
            best = max(snaps,
                       key=lambda s: (s.headroom, -s.load, -s.replica_id))
        if prefix_home is not None and prefix_home != best.replica_id:
            home = next((s for s in snaps
                         if s.replica_id == prefix_home), None)
            if home is not None:
                # affinity tiebreak: reuse of warm prefix blocks is worth
                # a bounded headroom sacrifice, never an unbounded one —
                # a genuinely hot replica loses its repeat prompts
                top = max(s.headroom for s in snaps)
                cutoff = (top - self.affinity_slack * abs(top) - 1e-12)
                if home.headroom >= cutoff:
                    return home.replica_id
        return best.replica_id


def make_placement(name: str) -> PlacementPolicy:
    """Fresh policy instance by name (policies may carry state — rr's
    cursor — so the router and each virtual-trace replay get their own).
    """
    try:
        cls = {"rr": RoundRobin, "least_queue": LeastQueue,
               "energy": EnergyHeadroom}[name]
    except KeyError:
        raise ValueError(f"unknown placement {name!r} "
                         f"(choose from {PLACEMENTS})") from None
    return cls()


# ---------------------------------------------------------------------------
# Fleet request handle
# ---------------------------------------------------------------------------
class FleetRequest:
    """Caller handle for a routed request.

    Delegates everything to the underlying scheduler
    :class:`~repro.serving.scheduler.Request`; if the router rebalances
    the (still queued, never started) request to another replica during
    a drain, the handle rebinds transparently — ``result()`` and
    ``stream()`` keep working and ``replica_id`` reports the replica
    that actually served it.
    """

    def __init__(self, fleet_id: int):
        self.fleet_id = fleet_id
        self._inner: Optional[Request] = None
        self._rid: Optional[int] = None

    def _bind(self, inner: Request, replica_id: int) -> None:
        inner.replica_id = replica_id
        inner._fleet_handle = self
        # rebind point: publish the replica id first so a concurrent
        # reader never sees the new inner with the old id
        self._rid = replica_id
        self._inner = inner

    @property
    def replica_id(self) -> Optional[int]:
        return self._rid

    @property
    def rebalanced(self) -> bool:
        return getattr(self._inner, "_rebalanced_from", None) is not None

    def result(self, timeout: Optional[float] = None) -> "FleetRequest":
        deadline = (None if timeout is None
                    else time.monotonic() + timeout)
        while True:
            inner = self._inner
            step = 0.05
            if deadline is not None:
                step = min(step, max(deadline - time.monotonic(), 0.001))
            try:
                inner.result(step)
                return self
            except TimeoutError:
                if deadline is not None and time.monotonic() >= deadline:
                    raise
            except RuntimeError:
                if self._inner is inner:
                    raise          # genuinely aborted, not rebalanced
                # rebalanced mid-wait: retry against the new inner

    def stream(self, timeout: Optional[float] = None):
        """Yield tokens as generated (per-token ``timeout``, like
        ``Request.stream``); survives a rebalance — a rebalanced request
        never started, so no token is ever lost in the handoff."""
        while True:
            inner = self._inner
            tok_deadline = (None if timeout is None
                            else time.monotonic() + timeout)
            while True:
                try:
                    tok = inner._stream.get(timeout=0.05)
                    break
                except _queue.Empty:
                    if self._inner is not inner:
                        inner = self._inner          # rebound: fresh queue
                        continue
                    if (tok_deadline is not None
                            and time.monotonic() >= tok_deadline):
                        raise TimeoutError(
                            f"fleet request {self.fleet_id} stream "
                            f"stalled") from None
            if tok is None:
                return
            yield tok

    def __getattr__(self, name: str):
        # tokens/text/metrics/to_result/... all live on the inner Request
        inner = self.__dict__.get("_inner")
        if inner is None:
            raise AttributeError(name)
        return getattr(inner, name)


# ---------------------------------------------------------------------------
# Router
# ---------------------------------------------------------------------------
@dataclass
class _Replica:
    replica_id: int
    scheduler: Scheduler
    draining: bool = False
    routed: int = 0
    spawned_at: float = field(default_factory=time.monotonic)


class Router:
    """N scheduler replicas behind one placement-policy front door.

    ``make_scheduler(replica_id) -> Scheduler`` builds one (unstarted)
    replica; the router owns start/stop/drain for all of them. Replicas
    are expected to share model params and geometry — placement assumes
    any live replica can serve any request (the routing-invariance
    property: per-request output is bit-identical wherever it runs,
    because sampling is keyed by request seed + position, never by batch
    composition or replica identity).
    """

    def __init__(self, make_scheduler: Callable[[int], Scheduler], *,
                 n_replicas: int = 2, placement: str = "energy",
                 affinity_prefix: int = 16):
        if n_replicas < 1:
            raise ValueError("n_replicas must be >= 1")
        self._make = make_scheduler
        self.placement = make_placement(placement)
        self.placement_name = self.placement.name
        self.affinity_prefix = int(affinity_prefix)
        self._replicas: dict[int, _Replica] = {}
        self._next_rid = 0
        self._seq = 0
        self._lock = threading.Lock()
        self._started = False
        self._prefix_home: dict = {}          # prompt-prefix key -> rid
        self._rebalanced = 0
        for _ in range(n_replicas):
            self.spawn_replica()

    # -- lifecycle ----------------------------------------------------------
    def start(self) -> "Router":
        with self._lock:
            reps = list(self._replicas.values())
            self._started = True
        for rep in reps:
            rep.scheduler.start()
        return self

    def stop(self, timeout: float = 10.0) -> None:
        """Abrupt stop of every replica (queued requests fail, residents
        retire mid-sequence — scheduler ``_drain`` semantics)."""
        with self._lock:
            reps = list(self._replicas.values())
        for rep in reps:
            rep.scheduler.stop(timeout)

    def drain(self, timeout: float = 30.0) -> bool:
        """Graceful fleet shutdown: every replica stops admissions, all
        queued + in-flight requests run to completion (bounded by
        ``timeout``), then the decode loops stop. Returns True when
        everything finished inside the budget."""
        with self._lock:
            reps = list(self._replicas.values())
            for rep in reps:
                rep.draining = True
        for rep in reps:
            rep.scheduler.begin_drain()
        deadline = time.monotonic() + timeout
        ok = True
        for rep in reps:
            left = max(deadline - time.monotonic(), 0.001)
            ok = rep.scheduler.drain(left) and ok
        return ok

    def __enter__(self) -> "Router":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    def __len__(self) -> int:
        with self._lock:
            return sum(1 for r in self._replicas.values()
                       if not r.draining)

    def spawn_replica(self) -> int:
        """Add one replica (started immediately when the router runs)."""
        with self._lock:
            rid = self._next_rid
            self._next_rid += 1
        sched = self._make(rid)
        rep = _Replica(rid, sched)
        with self._lock:
            self._replicas[rid] = rep
            started = self._started
        if started:
            sched.start()
        return rid

    def drain_replica(self, replica_id: int, timeout: float = 30.0) -> int:
        """Gracefully remove one replica.

        The replica stops taking placements and submissions, its
        queued-but-unstarted requests are rebalanced to the remaining
        live replicas (handles rebind — callers never notice), its
        in-flight requests run to completion (bounded by ``timeout``),
        then its scheduler stops and the replica is removed. Returns the
        number of rebalanced requests.
        """
        with self._lock:
            rep = self._replicas.get(replica_id)
            if rep is None:
                raise KeyError(f"no replica {replica_id}")
            live = [r for r in self._replicas.values() if not r.draining]
            if len(live) <= 1 and rep in live:
                raise ValueError("cannot drain the last live replica")
            rep.draining = True
        sched = rep.scheduler
        sched.begin_drain()
        stolen = sched.take_queued()
        for old in stolen:
            self._rebalance(old)
        with self._lock:
            self._rebalanced += len(stolen)
        sched.drain(timeout)
        with self._lock:
            self._replicas.pop(replica_id, None)
        return len(stolen)

    def _rebalance(self, old: Request) -> None:
        """Resubmit a queued-but-unstarted request elsewhere and rebind
        its fleet handle. The prompt was already tail-clipped at the
        original submit, so it resubmits verbatim."""
        new = self._place_and_submit(
            list(old.prompt), dict(
                max_new=old.max_new, policy=old.spec,
                sampling=old.sampling,
                stop_sequences=old.stop_sequences or None,
                request_class=old.request_class,
                energy_budget_j=old.energy_budget_j))
        new.truncated = old.truncated
        new._rebalanced_from = old.replica_id
        handle = getattr(old, "_fleet_handle", None)
        if handle is not None:
            handle._bind(new, new.replica_id)

    # -- placement ----------------------------------------------------------
    def _prefix_key(self, prompt):
        if isinstance(prompt, str):
            return prompt[:4 * self.affinity_prefix]
        return tuple(prompt[: self.affinity_prefix])

    def _snapshots(self) -> list[tuple[_Replica, ReplicaSnapshot]]:
        with self._lock:
            reps = [r for _, r in sorted(self._replicas.items())
                    if not r.draining]
        return [(r, ReplicaSnapshot(replica_id=r.replica_id,
                                    **r.scheduler.placement_snapshot()))
                for r in reps]

    def _place_and_submit(self, request, kwargs: dict) -> Request:
        pairs = self._snapshots()
        if not pairs:
            raise RuntimeError("router has no live replicas")
        prompt = (request.prompt
                  if hasattr(request, "prompt") else request)
        key = self._prefix_key(prompt)
        with self._lock:
            home = self._prefix_home.get(key)
            rid = self.placement.choose([s for _, s in pairs],
                                        prefix_home=home)
        by_id = {rep.replica_id: rep for rep, _ in pairs}
        # placement-order fallback on a full replica queue: the pick
        # first, then the rest coolest-first — only when every live
        # queue is full does the caller see SchedulerQueueFull
        order = [rid] + [s.replica_id
                         for _, s in sorted(pairs,
                                            key=lambda p: (p[1].load,
                                                           p[1].replica_id))
                         if s.replica_id != rid]
        last_err = None
        for try_rid in order:
            rep = by_id[try_rid]
            try:
                inner = rep.scheduler.submit(request, **kwargs)
            except SchedulerQueueFull as e:
                last_err = e
                continue
            inner.replica_id = try_rid
            with self._lock:
                rep.routed += 1
                self._prefix_home[key] = try_rid
                if len(self._prefix_home) > 65536:
                    self._prefix_home.clear()     # bounded affinity memory
            return inner
        raise last_err

    def submit(self, request, **kwargs) -> FleetRequest:
        """Scheduler-compatible submit: place the request on a replica
        per the placement policy, return a :class:`FleetRequest`."""
        replica_id = kwargs.pop("replica_id", None)
        with self._lock:
            fleet_id = self._seq
            self._seq += 1
        handle = FleetRequest(fleet_id)
        if replica_id is not None:                 # explicit pin
            with self._lock:
                rep = self._replicas[replica_id]
                if rep.draining:
                    raise ValueError(f"replica {replica_id} is draining")
            inner = rep.scheduler.submit(request, **kwargs)
            with self._lock:
                rep.routed += 1
            handle._bind(inner, replica_id)
            return handle
        inner = self._place_and_submit(request, kwargs)
        handle._bind(inner, inner.replica_id)
        return handle

    # -- introspection ------------------------------------------------------
    @property
    def replica_ids(self) -> list[int]:
        with self._lock:
            return sorted(self._replicas)

    @property
    def tracing(self) -> bool:
        with self._lock:
            reps = list(self._replicas.values())
        return any(r.scheduler.obs.enabled for r in reps)

    def reset_peak_stats(self) -> None:
        with self._lock:
            reps = list(self._replicas.values())
        for rep in reps:
            rep.scheduler.reset_peak_stats()

    def stats(self) -> dict:
        """Fleet aggregate + per-replica breakdown (``GET /queue``)."""
        with self._lock:
            reps = sorted(self._replicas.items())
            rebalanced = self._rebalanced
            prefix_homes = len(self._prefix_home)
        per = []
        for rid, rep in reps:
            st = rep.scheduler.stats()
            st.update(replica_id=rid, draining=rep.draining,
                      routed=rep.routed)
            per.append(st)
        n = max(len(per), 1)
        energies = [st["fleet_energy_j"] for st in per]
        total_e = sum(energies)
        fleet = {
            "replicas": len(per),
            "queue_depth": sum(st["queue_depth"] for st in per),
            "active_slots": sum(st["active_slots"] for st in per),
            "max_slots": sum(st["max_slots"] for st in per),
            "completed_requests": sum(st["completed_requests"]
                                      for st in per),
            "fleet_tokens": sum(st["fleet_tokens"] for st in per),
            "fleet_energy_j": total_e,
            "fleet_prefill_energy_j": sum(st["fleet_prefill_energy_j"]
                                          for st in per),
            "blocked_admissions": sum(st["blocked_admissions"]
                                      for st in per),
            "deferred_admissions": sum(st["deferred_admissions"]
                                       for st in per),
            "throughput_tok_s": (sum(st["fleet_tokens"] for st in per)
                                 / max(max((st["uptime_s"]
                                            for st in per), default=0.0),
                                       1e-9)),
            "fleet_j_per_token": (total_e
                                  / max(sum(st["fleet_tokens"]
                                            for st in per), 1)),
            "power_w_ema_mean": (sum(st["power_w_ema"] for st in per)
                                 / n),
            "power_w_ema_max": max((st["power_w_ema"] for st in per),
                                   default=0.0),
            # load-balance quality: the hottest replica's share of fleet
            # energy (1/N is perfect balance; rr drifts above it under
            # heterogeneous load, the energy policy pulls it back down)
            "max_replica_energy_share": (max(energies) / total_e
                                         if total_e > 0 else 0.0),
            "latency_p95_s": max((st["latency_p95_s"] for st in per
                                  if st["latency_p95_s"] is not None),
                                 default=None),
            "ttft_p95_s": max((st["ttft_p95_s"] for st in per
                               if st.get("ttft_p95_s") is not None),
                              default=None),
            "rebalanced_requests": rebalanced,
            "prefix_homes": prefix_homes,
        }
        return {"placement": self.placement_name,
                "replicas": len(per),
                "fleet": fleet,
                "per_replica": per}

    def prometheus(self, prefix: str = "repro_") -> str:
        """Per-replica-labeled Prometheus exposition (``GET /metrics``)."""
        st = self.stats()
        with self._lock:
            reps = sorted(self._replicas.items())
        replicas = []
        for (rid, rep), rst in zip(reps, st["per_replica"]):
            obs = rep.scheduler.obs
            replicas.append(({"replica": str(rid)}, rst,
                             obs if obs.enabled else None))
        return render_fleet_prometheus(st["fleet"], replicas,
                                       prefix=prefix,
                                       placement=self.placement_name)

    def drain_events(self) -> list[dict]:
        """Merged Chrome-trace events across replicas: replica ``r``'s
        local thread ``t`` becomes tid ``r * TID_STRIDE + t``, with a
        ``thread_name`` metadata event per replica so Perfetto labels
        the track groups."""
        with self._lock:
            reps = sorted(self._replicas.items())
        merged: list[dict] = []
        for rid, rep in reps:
            obs = rep.scheduler.obs
            if not obs.enabled:
                continue
            merged.append({"ph": "M", "tid": rid * TID_STRIDE,
                           "name": "thread_name",
                           "args": {"name": f"replica-{rid}"}})
            for ev in obs.drain():
                ev = dict(ev)
                ev["tid"] = rid * TID_STRIDE + int(ev.get("tid", 0))
                if "id" in ev:
                    # async (req-lifecycle) span ids are per-replica
                    # sequences; scope them so request 3 on replica 0
                    # and request 3 on replica 1 stay distinct spans
                    ev["id"] = rid * 1_000_000 + int(ev["id"])
                merged.append(ev)
        return merged


__all__ = ["Router", "FleetRequest", "ReplicaSnapshot", "PlacementPolicy",
           "RoundRobin", "LeastQueue", "EnergyHeadroom", "make_placement",
           "PLACEMENTS", "AFFINITY_SLACK", "TID_STRIDE"]
