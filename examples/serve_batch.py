"""Batched early-exit serving demo (paper §V deployment, CPU scale).

    PYTHONPATH=src python examples/serve_batch.py --controller confidence

Shows the exit-policy families on one batch of code-completion requests,
comparing quality proxies and modeled energy. With ``--controller all`` the
policies are served *heterogeneously*: every (policy x request) pair is one
``GenerationRequest`` and the whole mix runs as a single stacked batch
(``Engine.serve_requests``) under one compiled step — no per-policy
closures, no retracing. The 'policy' controller trains a quick PPO agent
first.
"""
import argparse

from repro.api import GenerationRequest, PolicySpec
from repro.configs.opt_2_7b import paper_mini
from repro.data import CodeCompletionDataset
from repro.serving import Engine
from repro.serving.metrics import aggregate_metrics
from repro.training import train_model

SPECS = {
    "none": PolicySpec("none"),
    "fixed": PolicySpec("fixed", {"exit_idx": 0}),
    "confidence": PolicySpec("confidence", {"threshold": 0.7}),
    "entropy": PolicySpec("entropy", {"threshold": 0.7}),
    "policy": PolicySpec("policy", {"threshold": 0.7}),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--controller", default="all",
                    choices=["all", *SPECS])
    ap.add_argument("--requests", type=int, default=6)
    args = ap.parse_args()

    cfg = paper_mini(num_layers=12, d_model=192, vocab_size=2048)
    ds = CodeCompletionDataset(language="python", n_files=120, seq_len=256,
                               vocab_size=2048)
    print("fine-tuning mini OPT (LITE) ...")
    params, _ = train_model(cfg, ds, kind="lite", steps=60, batch_size=4,
                            lr=1e-3, log_every=30)

    agent = None
    kinds = [args.controller] if args.controller != "all" else list(SPECS)
    if "policy" in kinds:
        from repro.rl import PPOConfig, train_agent
        print("training PPO exit agent ...")
        agent, _, _ = train_agent(params, cfg, ds, n_episodes=16,
                                  gen_tokens=8,
                                  ppo=PPOConfig(total_steps=30_000),
                                  log_every=0)

    tasks = ds.completion_tasks("test", args.requests, max_context=128)
    eng = Engine(params, cfg, max_new=10, max_context=128,
                 agent_params=agent, tokenizer=ds.tokenizer)
    # one heterogeneous batch: every (policy, request) pair is a row
    reqs = [GenerationRequest(prompt=c, max_new_tokens=10,
                              policy=SPECS[kind])
            for kind in kinds for c, _ in tasks]
    results = eng.serve_requests(reqs)
    for ki, kind in enumerate(kinds):
        chunk = results[ki * len(tasks):(ki + 1) * len(tasks)]
        agg = aggregate_metrics([r.metrics for r in chunk])
        print(f"[{kind:10s}] layers {agg['mean_layers']:5.2f}"
              f"/{cfg.num_layers}  energy saving "
              f"{agg['energy_saving_frac']*100:5.1f}%  "
              f"tokens {agg['tokens']}")
        txt = (chunk[0].text or "").replace("\n", "\\n")
        print(f"    e.g. {txt!r}")


if __name__ == "__main__":
    main()
