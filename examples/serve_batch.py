"""Batched early-exit serving demo (paper §V deployment, CPU scale).

    PYTHONPATH=src python examples/serve_batch.py --controller confidence

Shows the four controller families on one batch of code-completion
requests, comparing quality proxies and modeled energy. The 'policy'
controller trains a quick PPO agent first.
"""
import argparse

import numpy as np

from repro.configs.opt_2_7b import paper_mini
from repro.core.controller import make_controller
from repro.data import CodeCompletionDataset
from repro.serving import Engine
from repro.serving.metrics import aggregate_metrics
from repro.training import train_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--controller", default="all",
                    choices=["all", "none", "fixed", "confidence",
                             "entropy", "policy"])
    ap.add_argument("--requests", type=int, default=6)
    args = ap.parse_args()

    cfg = paper_mini(num_layers=12, d_model=192, vocab_size=2048)
    ds = CodeCompletionDataset(language="python", n_files=120, seq_len=256,
                               vocab_size=2048)
    print("fine-tuning mini OPT (LITE) ...")
    params, _ = train_model(cfg, ds, kind="lite", steps=60, batch_size=4,
                            lr=1e-3, log_every=30)

    agent = None
    kinds = ([args.controller] if args.controller != "all"
             else ["none", "fixed", "confidence", "entropy", "policy"])
    if "policy" in kinds:
        from repro.rl import PPOConfig, train_agent
        print("training PPO exit agent ...")
        agent, _, _ = train_agent(params, cfg, ds, n_episodes=16,
                                  gen_tokens=8,
                                  ppo=PPOConfig(total_steps=30_000),
                                  log_every=0)

    tasks = ds.completion_tasks("test", args.requests, max_context=128)
    for kind in kinds:
        ctrl = make_controller(kind, params=params, cfg=cfg,
                               agent_params=agent, threshold=0.7,
                               exit_idx=0)
        eng = Engine(params, cfg, ctrl, max_new=10, max_context=128)
        res = eng.serve([c for c, _ in tasks])
        agg = aggregate_metrics(res.metrics)
        print(f"[{kind:10s}] layers {agg['mean_layers']:5.2f}"
              f"/{cfg.num_layers}  energy saving "
              f"{agg['energy_saving_frac']*100:5.1f}%  "
              f"tokens {agg['tokens']}")
        txt = ds.tokenizer.decode(res.tokens[0]).replace("\n", "\\n")
        print(f"    e.g. {txt!r}")


if __name__ == "__main__":
    main()
